//! Parallel-safety analyzer demo: the three surfaces of the lint layer.
//!
//! 1. Default `lint = "warn"`: an unsafe body still runs, but a classed
//!    `FuturizeLintWarning` is relayed once per map call.
//! 2. `lint = "error"`: the same body raises a classed
//!    `FuturizeLintError` at freeze time, before any worker is touched.
//! 3. `lint_source()`: the script-level pass behind `futurize-rs lint`.
//!
//! Run: `cargo run --example lint_demo`

use futurize::prelude::*;
use futurize::transpile::analysis;

fn main() {
    // Host worker subprocesses when spawned by the multisession backend.
    futurize::backend::worker::maybe_worker();

    let dirty = "
        total <- 0
        unlist(lapply(1:4, function(x) {
          total <<- total + x
          runif(1) * total
        }) |> futurize())
    ";

    println!("== lint = \"warn\" (default): runs, relays classed warnings ==");
    let mut s = Session::new();
    s.eval_str("plan(multicore, workers = 2)").unwrap();
    let (r, out) = s.eval_captured(dirty);
    println!("result ok: {}", r.is_ok());
    for line in out.lines().filter(|l| l.contains("FZ")) {
        println!("  relayed: {line}");
    }

    println!("\n== lint = \"error\": raises before any worker spawns ==");
    let mut s = Session::new();
    s.eval_str("plan(multicore, workers = 2)").unwrap();
    let program = dirty.replace("futurize()", "futurize(lint = \"error\")");
    match s.eval_str(&program) {
        Ok(_) => println!("unexpectedly succeeded"),
        Err(e) => println!("raised: {e}"),
    }

    println!("\n== script-level pass (futurize-rs lint) ==");
    let findings = analysis::lint_source(dirty).expect("parses");
    for f in &findings {
        println!("statement {}:", f.stmt);
        print!("{}", futurize::rlite::diag::render_table(&f.diags));
    }

    println!("\n== fusion_report(): why bodies were (not) fused ==");
    println!("{}", fusion_report().render());
}
