//! Paper §4.6–4.7 (Table 2): the domain-specific packages, each
//! futurized with the same one-gesture API that hides the package's own
//! parallel sub-API (boot's parallel/ncpus/cl, glmnet's adapter
//! registration, mgcv's cluster argument, ...).
//!
//! Run: `cargo run --example domains`

use futurize::prelude::*;

fn show(session: &mut Session, title: &str, src: &str) {
    let t0 = std::time::Instant::now();
    let v = session.eval_str(src).unwrap_or_else(|e| panic!("{title}: {e}"));
    println!("{title}\n  -> {v}   ({:.2}s)\n", t0.elapsed().as_secs_f64());
}

fn main() {
    futurize::backend::worker::maybe_worker();
    let mut session = Session::new();
    session.eval_str("plan(multisession, workers = 3)").unwrap();
    session.eval_str("futureSeed(2026)").unwrap();

    show(
        &mut session,
        "boot (§4.6): bigcity population-ratio bootstrap, R = 999",
        "data(bigcity)\n\
         ratio <- function(d, w) hlo_boot_stat(d$x, d$u, w)\n\
         b <- boot(bigcity, statistic = ratio, R = 999, stype = \"w\") |> futurize()\n\
         ci <- boot.ci(b)\n\
         round(c(t0 = b$t0, lower = ci[\"lower\"], upper = ci[\"upper\"]), 4)",
    );

    show(
        &mut session,
        "glmnet (§4.6): cv.glmnet over 1000 x 100 design",
        "set.seed(9)\nn <- 1000\np <- 100\n\
         x <- matrix(rnorm(n * p), nrow = n, ncol = p)\n\
         y <- rnorm(n)\n\
         cv <- cv.glmnet(x, y) |> futurize()\n\
         round(c(lambda.min = cv$lambda.min, cvm.best = min(cv$cvm)), 4)",
    );

    show(
        &mut session,
        "lme4 (§4.6): glmer on cbpp, then allFit across 7 optimizers",
        "data(cbpp)\n\
         m <- glmer(cbind(incidence, size - incidence) ~ period + (1 | herd), data = cbpp, family = \"binomial\")\n\
         fits <- allFit(m) |> futurize()\n\
         devs <- sapply(fits, function(f) f$deviance)\n\
         round(max(devs) - min(devs), 6)",
    );

    show(
        &mut session,
        "caret (§4.6): train rf on iris, 10-fold CV",
        "data(iris)\nctrl <- trainControl(method = \"cv\", number = 10)\n\
         model <- train(Species ~ ., data = iris, model = \"rf\", trControl = ctrl) |> futurize()\n\
         round(c(best = model$bestTune, accuracy = model$bestAccuracy), 3)",
    );

    show(
        &mut session,
        "mgcv (§4.7): bam on 4000 obs, chunked gram on the PJRT kernel",
        "set.seed(10)\nn <- 4000\nxv <- runif(n, 0, 10)\nyv <- sin(xv) + rnorm(n, sd = 0.1)\n\
         df <- data.frame(y = yv, x = xv)\n\
         m <- bam(y ~ s(x), data = df, sp = 0.5) |> futurize()\n\
         round(c(rmse = m$rmse, chunks = m$n_chunks), 3)",
    );

    show(
        &mut session,
        "tm (§4.7): corpus transform + term-document matrix",
        "data(crude)\ncorpus <- Corpus(VectorSource(crude))\n\
         clean <- tm_map(corpus, tolower) |> futurize()\n\
         tdm <- TermDocumentMatrix(clean)\n\
         c(docs = tdm$n_docs, terms = length(tdm$terms))",
    );

    println!("pjrt artifacts in use: {}", futurize::runtime::pjrt_available());
}
