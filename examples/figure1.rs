//! Figure 1 reproduction: eight `fcn()` calls, sequential vs futurized
//! with three workers — printing the task→worker timeline the paper
//! draws.
//!
//! Run: `cargo run --example figure1`

use futurize::prelude::*;

fn main() {
    futurize::backend::worker::maybe_worker();
    let mut session = Session::with_config(SessionConfig { time_scale: 0.02 });

    session
        .eval_str("fcn <- function(x) { Sys.sleep(1)\nx^2 }\nxs <- 1:8")
        .unwrap();

    println!("Figure 1 — lapply(xs, fcn), 8 tasks\n");

    let (_, seq) = session.eval_timed("ys <- lapply(xs, fcn)").unwrap();
    println!("sequential: {:.2} task-units walltime", seq / 0.02);

    session.eval_str("plan(multicore, workers = 3)").unwrap();
    let (_, par) = session
        .eval_timed("ys <- lapply(xs, fcn) |> futurize(scheduling = Inf)")
        .unwrap();
    println!(
        "futurized (3 workers): {:.2} task-units walltime (ideal ceil(8/3) = 3)\n",
        par / 0.02
    );
    println!("task→worker timeline (one letter per task):");
    println!("{}", session.render_trace());
    println!("speedup: {:.2}x (ideal 8/3 = 2.67x)", seq / par);
}
