//! Quickstart (paper §4.1): parallelize `lapply()` by appending
//! `|> futurize()`.
//!
//! Run: `cargo run --example quickstart`

use futurize::prelude::*;

fn main() {
    // Host worker subprocesses when spawned by the multisession backend.
    futurize::backend::worker::maybe_worker();

    // The paper's slow_fcn sleeps 1s; scale time down 100x so the demo
    // finishes quickly while keeping the same shape.
    let mut session = Session::with_config(SessionConfig { time_scale: 0.01 });

    println!("== sequential ==");
    let (v, secs) = session
        .eval_timed(
            r#"
            slow_fcn <- function(x) {
              Sys.sleep(1.0) # Simulate work
              x^2
            }
            xs <- 1:24
            ys <- lapply(xs, slow_fcn)
            sum(unlist(ys))
            "#,
        )
        .expect("sequential run");
    println!("sum = {v}, walltime = {secs:.2}s (scaled)");

    println!("\n== futurized: plan(multicore, workers = 4) ==");
    session.eval_str("plan(multicore, workers = 4)").unwrap();
    let (v, par_secs) = session
        .eval_timed("ys <- lapply(xs, slow_fcn) |> futurize()\nsum(unlist(ys))")
        .expect("parallel run");
    println!("sum = {v}, walltime = {par_secs:.2}s (scaled)");
    println!("speedup: {:.1}x with 4 workers", secs / par_secs);

    // replicate() defaults to seed = TRUE under futurize (§4.1).
    println!("\n== futurized replicate() on process workers (multisession) ==");
    session.eval_str("plan(multisession, workers = 4)").unwrap();
    let v = session
        .eval_str("samples <- replicate(100, rnorm(10)) |> futurize()\nlength(samples)")
        .unwrap();
    println!("drew {v} reproducible random numbers across workers");

    // The transpiler is inspectable (§3.2): eval = FALSE.
    let v = session
        .eval_str("lapply(xs, slow_fcn) |> futurize(eval = FALSE, seed = TRUE, chunk_size = 2)")
        .unwrap();
    println!("\ntranspiled form:\n  {}", v.as_str().unwrap());
}
