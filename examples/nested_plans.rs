//! Plan topologies (ISSUE 5): `plan(list(...))` stacks give nested
//! futurized maps their *own* inner backend — the paper/future
//! framework's "cluster of multicore nodes" shape — instead of silently
//! degrading to sequential at depth 2.
//!
//! Run: `cargo run --release --example nested_plans`

use futurize::prelude::*;

/// An outer map of 4 slow groups, each internally mapping 4 slow items:
/// 16 units of work with two levels of latent parallelism.
const PROG: &str = "ys <- lapply(1:4, function(g) \
    sum(future_sapply(1:4, function(i) { Sys.sleep(1.0)\ng * 10 + i }, \
    future.seed = TRUE))) |> futurize(seed = TRUE)\nsum(unlist(ys))";

fn run(label: &str, plan: &str) -> (f64, f64) {
    let mut s = Session::with_config(SessionConfig { time_scale: 0.02 });
    s.eval_str(plan).unwrap();
    s.eval_str("futureSeed(42)").unwrap();
    let (v, secs) = s.eval_timed(PROG).expect(label);
    let inner: Vec<usize> = s.last_trace().iter().map(|e| e.inner_workers).collect();
    println!(
        "{label:<44} sum = {v}, walltime = {secs:.2}s (scaled), inner workers per chunk = \
         {inner:?}"
    );
    (v.as_f64().unwrap(), secs)
}

fn main() {
    // Host worker subprocesses when spawned by the multisession backend.
    futurize::backend::worker::maybe_worker();

    println!("== nested map under three plan topologies ==\n");
    let (v_seq, t_seq) = run("plan(sequential)", "plan(sequential)");
    let (v_outer, t_outer) =
        run("plan(multisession, workers = 2)", "plan(multisession, workers = 2)");
    let (v_stack, t_stack) = run(
        "plan(list(multisession(2), multicore(2)))",
        "plan(list(multisession(2), multicore(2)))",
    );

    // The *what* is invariant: results (and seed = TRUE draws) are
    // bit-identical under every topology; only the *how* changed.
    assert_eq!(v_seq, v_outer);
    assert_eq!(v_seq, v_stack);

    println!("\nouter-only speedup:  {:.1}x (2 workers)", t_seq / t_outer);
    println!("stacked speedup:     {:.1}x (2 x 2 workers)", t_seq / t_stack);
    println!(
        "\nThe stack's second level rides to the workers inside every \
         RegisterContext;\na worker evaluating the nested future_sapply() \
         instantiates its own 2-thread\nmulticore backend from it — 4-way \
         effective parallelism, visible above as\ninner workers per chunk. \
         Without a second level the nested map runs on the\nimplicit \
         sequential plan (the future framework's nesting guard), and an \
         inherited\n'all cores' level divides the machine's cores by the \
         outer worker count instead\nof oversubscribing cores^2 ways."
    );
}
