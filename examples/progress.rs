//! Paper §4.10: near-live progress reporting from parallel workers via
//! the progressr analog. Note how futurize() unwraps `local({ ... })` to
//! find the lapply() call (§3.3).
//!
//! Run: `cargo run --example progress`

use futurize::prelude::*;

fn main() {
    futurize::backend::worker::maybe_worker();
    let mut session = Session::with_config(SessionConfig { time_scale: 0.03 });
    session.eval_str("plan(multisession, workers = 3)").unwrap();
    session.eval_str("handlers(global = TRUE)").unwrap();

    println!("running 30 slow tasks with near-live progress:\n");
    let v = session
        .eval_str(
            r#"
            slow_fcn <- function(x) { Sys.sleep(1)
            x^2 }
            xs <- 1:30
            ys <- local({
              p <- progressor(along = xs)
              lapply(xs, function(x) {
                p()
                slow_fcn(x)
              })
            }) |> futurize(scheduling = Inf)
            sum(unlist(ys))
            "#,
        )
        .unwrap();
    println!("\ndone: sum = {v}");
}
