//! End-to-end driver (DESIGN.md exp E2E): exercises every layer of the
//! stack on a real small workload and reports the paper's headline
//! metric (speedup of `|> futurize()` over sequential, across backends).
//!
//! Pipeline per backend:
//!   1. parse an rlite script (L3 substrate),
//!   2. futurize() transpiles the map-reduce calls (the contribution),
//!   3. the plan's backend distributes chunk tasks — multisession uses
//!      real worker subprocesses over the JSON stdio protocol,
//!   4. each task's statistic runs the AOT JAX/Pallas `boot_stat` kernel
//!      through PJRT (L1/L2),
//!   5. results, stdout, conditions and RNG streams relay back.
//!
//! The workload is the paper's §4.6 bootstrap: R = 400 resamples of the
//! bigcity population ratio. Run: `cargo run --release --example e2e_pipeline`

use futurize::prelude::*;

const SCRIPT: &str = r#"
data(bigcity)
ratio <- function(d, w) hlo_boot_stat(d$x, d$u, w)
b <- boot(bigcity, statistic = ratio, R = 400, stype = "w") |> futurize()
c(b$t0, mean(b$t), sd(b$t))
"#;

fn run_backend(plan: &str, reference: Option<&[f64]>) -> (Vec<f64>, f64) {
    let mut session = Session::new();
    session.eval_str(&format!("plan({plan})")).unwrap();
    session.eval_str("futureSeed(2026)").unwrap();
    let t0 = std::time::Instant::now();
    let v = session.eval_str(SCRIPT).unwrap_or_else(|e| panic!("{plan}: {e}"));
    let secs = t0.elapsed().as_secs_f64();
    let stats = v.as_dbl_vec().unwrap();
    if let Some(r) = reference {
        assert!(
            (stats[1] - r[1]).abs() < 1e-9,
            "{plan}: bootstrap mean diverged ({} vs {})",
            stats[1],
            r[1]
        );
    }
    (stats, secs)
}

/// Phase 2 workload: the paper's latency-bound slow_fcn pipeline, where
/// concurrency wins even on a single-core testbed.
fn run_latency_phase(plan: &str) -> f64 {
    let mut session = Session::with_config(SessionConfig { time_scale: 0.01 });
    session.eval_str(&format!("plan({plan})")).unwrap();
    session
        .eval_str("slow_fcn <- function(x) { Sys.sleep(1)\nsum(hlo_chunk_map(c(x))) }\nxs <- 1:24")
        .unwrap();
    session.eval_str("invisible(lapply(1:2, slow_fcn) |> futurize())").unwrap(); // warm pool
    let t0 = std::time::Instant::now();
    session.eval_str("ys <- lapply(xs, slow_fcn) |> futurize()").unwrap();
    t0.elapsed().as_secs_f64()
}

fn main() {
    futurize::backend::worker::maybe_worker();

    println!("E2E phase 1: bigcity ratio bootstrap (R = 400) through the boot_stat kernel");
    println!("pjrt artifacts: {}\n", futurize::runtime::pjrt_available());
    println!("{:<46}{:>10}", "backend", "walltime");

    let (reference, seq_secs) = run_backend("sequential", None);
    println!("{:<46}{:>9.2}s", "sequential", seq_secs);

    let plans = [
        "multicore, workers = 3",
        "multisession, workers = 3",
        "future.mirai::mirai_multisession, workers = 3",
        "cluster, workers = c(\"n1\", \"n2\", \"n3\"), latency_ms = 0.2",
        "future.batchtools::batchtools_slurm, workers = 3, poll_ms = 5",
    ];
    for plan in plans {
        let (_stats, secs) = run_backend(plan, Some(&reference));
        println!("{:<46}{:>9.2}s", plan.split(',').next().unwrap(), secs);
    }
    println!(
        "\nstatistic: t0 = {:.4}, bootstrap mean = {:.4}, se = {:.4}",
        reference[0], reference[1], reference[2]
    );
    println!("identical bootstrap mean on every backend: seed = TRUE per-element streams");

    println!("\nE2E phase 2: 24 latency-bound tasks (the paper's slow_fcn shape)");
    println!("{:<46}{:>10}{:>9}", "backend", "walltime", "speedup");
    let seq_lat = run_latency_phase("sequential");
    println!("{:<46}{:>9.2}s{:>9}", "sequential", seq_lat, "1.0x");
    for plan in plans {
        let secs = run_latency_phase(plan);
        println!(
            "{:<46}{:>9.2}s{:>8.1}x",
            plan.split(',').next().unwrap(),
            secs,
            seq_lat / secs
        );
    }
}
