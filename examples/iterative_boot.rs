//! Iterative bootstrap over a fixed dataset (PR 9): the workload the
//! content-addressed data-plane cache exists for.
//!
//! Each round draws fresh weights and recomputes a ratio statistic over
//! the *same* ~1.6 MiB dataset. Without the cache every round re-ships
//! the dataset to every worker; with it, round 1 ships `CachePut` blobs
//! (once per worker) and later rounds reference them by FNV digest —
//! observable below as the per-round physical wire bytes collapsing
//! after round 1 while `cache hits` tick instead of `puts`.
//!
//! Run: `cargo run --release --example iterative_boot`

use futurize::prelude::*;
use futurize::wire::stats;

/// One bootstrap round: 16 weighted replicates of sum(xw)/sum(uw),
/// seeded so the demo is reproducible run to run.
const ROUND: &str = "future_sapply(1:16, function(i) { \
    w <- runif(length(x))\nsum(x * w) / sum(u * w) }, future.seed = TRUE)";

fn main() {
    // Host worker subprocesses when spawned by the multisession backend.
    futurize::backend::worker::maybe_worker();

    let mut s = Session::new();
    s.eval_str("plan(multisession, workers = 2)").unwrap();
    s.eval_str("futureSeed(7)").unwrap();
    s.eval_str("x <- sin(1:200000)\nu <- cos(1:200000) + 2").unwrap();

    println!("== iterative bootstrap: 5 rounds over one 1.6 MiB dataset ==\n");
    println!("{:>5}  {:>12}  {:>6}  {:>6}  {:>10}", "round", "wire bytes", "puts", "hits", "mean");
    stats::reset();
    let mut first_round = 0.0;
    let mut last_round = 0.0;
    for round in 1..=5 {
        let (bytes0, puts0, hits0) = (stats::bytes(), stats::cache_puts(), stats::cache_hits());
        let reps = s.eval_str(ROUND).unwrap().as_dbl_vec().unwrap();
        let mean = reps.iter().sum::<f64>() / reps.len() as f64;
        let bytes = (stats::bytes() - bytes0) as f64;
        println!(
            "{round:>5}  {bytes:>12.0}  {:>6}  {:>6}  {mean:>10.6}",
            stats::cache_puts() - puts0,
            stats::cache_hits() - hits0,
        );
        if round == 1 {
            first_round = bytes;
        }
        last_round = bytes;
    }
    println!(
        "\nround-1 vs round-5 wire volume: {:.0}x — the dataset crossed the \
         process boundary once per worker, then traveled as a digest.",
        first_round / last_round.max(1.0)
    );
    println!(
        "Counters: {} puts ({} KiB shipped), {} hits ({} KiB saved). \
         Set FUTURIZE_NO_CACHE=1 to watch every round pay full freight.",
        stats::cache_puts(),
        stats::cache_put_bytes() >> 10,
        stats::cache_hits(),
        stats::cache_hit_bytes() >> 10,
    );
}
