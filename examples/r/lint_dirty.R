# Known-dirty fixture for `futurize-rs lint`: the classic loop-carried
# accumulator plus unseeded RNG. CI asserts a nonzero exit code and the
# FZ001/FZ002 codes in the report.

plan(multicore, workers = 2)

total <- 0
xs <- c(1, 2, 3, 4)

r <- lapply(xs, function(x) {
  total <<- total + x        # FZ001: element i depends on element i-1
  runif(1) * total           # FZ002: RNG without seed = TRUE
}) |> futurize()

s <- lapply(xs, function(x) x * missing_scale) |> futurize()  # FZ003
