# Clean fixture for `futurize-rs lint`: every futurized map is
# parallel-safe — globals defined, RNG seeded, no cross-iteration
# state. CI asserts exit code 0 on this file.

plan(multicore, workers = 2)

scale <- 2.5
xs <- c(1, 2, 3, 4)

squares <- lapply(xs, function(x) x * x * scale) |> futurize()

draws <- lapply(xs, function(x) rnorm(1) + x) |> futurize(seed = TRUE)

boots <- replicate(8, mean(rnorm(4))) |> futurize()

total <- sum(unlist(lapply(xs, function(x) x * 2) |> futurize()))
