//! Paper §4.2–4.5: the same `|> futurize()` gesture across every
//! supported map-reduce API family — purrr, foreach (+iterators), plyr,
//! crossmap, BiocParallel.
//!
//! Run: `cargo run --example map_apis`

use futurize::prelude::*;

fn show(session: &mut Session, title: &str, src: &str) {
    let v = session.eval_str(src).unwrap_or_else(|e| panic!("{title}: {e}"));
    println!("{title}\n  -> {v}\n");
}

fn main() {
    futurize::backend::worker::maybe_worker();
    let mut session = Session::with_config(SessionConfig { time_scale: 0.002 });
    session.eval_str("plan(multisession, workers = 3)").unwrap();
    session
        .eval_str("slow_fcn <- function(x) { Sys.sleep(1)\nx^2 }\nxs <- 1:12")
        .unwrap();

    show(
        &mut session,
        "purrr: map(xs, slow_fcn) |> futurize()",
        "ys <- map(xs, slow_fcn) |> futurize()\nsum(unlist(ys))",
    );

    show(
        &mut session,
        "purrr pipeline (§4.2): both stages futurized",
        "ys <- 1:100 |>\n  map(rnorm, n = 10) |> futurize(seed = TRUE) |>\n  map_dbl(mean) |> futurize()\nround(mean(ys), 3)",
    );

    show(
        &mut session,
        "foreach (§4.3): %do% futurized without changing the operator",
        "ys <- foreach(x = xs, .combine = c) %do% { slow_fcn(x) } |> futurize()\nsum(ys)",
    );

    show(
        &mut session,
        "foreach + iterators (§4.3): icount() indices",
        "df <- data.frame(a = 1:4, b = letters[1:4])\nys <- foreach(d = df, i = icount()) %do% { list(index = i) } |> futurize()\nlength(ys)",
    );

    show(
        &mut session,
        "times (§4.3): seed defaults to TRUE",
        "samples <- times(20) %do% rnorm(5) |> futurize()\nlength(samples)",
    );

    show(
        &mut session,
        "plyr (§4.4): llply futurized via its own .parallel sub-API",
        "ys <- llply(xs, slow_fcn) |> futurize()\nsum(unlist(ys))",
    );

    show(
        &mut session,
        "crossmap (§4.5): xmap over all combinations",
        "ys <- crossmap::xmap_dbl(list(1:4, 1:3), function(a, b) a * b) |> futurize()\nsum(ys)",
    );

    show(
        &mut session,
        "BiocParallel (§4.5): bplapply through FutureParam",
        "ys <- bplapply(xs, slow_fcn) |> futurize()\nsum(unlist(ys))",
    );

    println!("supported packages: {:?}", futurize::transpile::supported_packages());
}
