//! Paper §4.9: stdout and conditions relay "as-is" from parallel
//! workers — and can be handled with the ordinary sequential tools.
//!
//! Run: `cargo run --example conditions`

use futurize::prelude::*;

fn main() {
    futurize::backend::worker::maybe_worker();
    let mut session = Session::new();
    session.eval_str("plan(multisession, workers = 2)").unwrap();

    println!("== messages relayed from workers (§4.9) ==");
    let (v, out) = session.eval_captured(
        "ys <- 1:4 |> map_dbl(\\(x) {\n  message(\"x = \", x)\n  sqrt(x)\n}) |> futurize()\nys",
    );
    print!("{out}");
    println!("values: {}\n", v.unwrap());

    println!("== same code under suppressMessages(): silence ==");
    let (v, out) = session.eval_captured(
        "ys <- 1:4 |> map_dbl(\\(x) {\n  message(\"x = \", x)\n  sqrt(x)\n}) |> suppressMessages() |> futurize()\nys",
    );
    print!("{out}");
    println!("values: {}\n", v.unwrap());

    println!("== stdout (cat) relays too ==");
    let (_, out) = session.eval_captured(
        "invisible(lapply(1:3, function(x) cat(\"worker says\", x, \"\\n\")) |> futurize())",
    );
    print!("{out}");

    println!("\n== errors keep the original condition object ==");
    let v = session
        .eval_str(
            "r <- tryCatch({\n  lapply(1:3, function(x) if (x == 2) stop(\"boom at 2\") else x) |> futurize()\n}, error = function(e) conditionMessage(e))\nr",
        )
        .unwrap();
    println!("caught: {v}");

    println!("\n== RNG misuse detection (§5.2) ==");
    let (_, out) = session.eval_captured(
        "invisible(lapply(1:2, function(x) rnorm(1)) |> futurize())",
    );
    print!("{out}");
    println!("(fix: lapply(...) |> futurize(seed = TRUE))");
}
