"""Kernel-vs-reference correctness — the CORE L1 signal.

Every Pallas kernel is checked against its pure-jnp oracle, with
hypothesis sweeping input distributions and (where the kernel supports
it) shapes/dtypes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import boot_stat, chunk_map, gram, ref

finite_f32 = st.floats(
    min_value=-1e3, max_value=1e3, allow_nan=False, allow_infinity=False, width=32
)


# ---------------------------------------------------------------------------
# chunk_map
# ---------------------------------------------------------------------------


def test_chunk_map_matches_ref_basic():
    x = jnp.arange(chunk_map.CHUNK_N, dtype=jnp.float32) / 7.0
    got = chunk_map.chunk_map(x)
    want = ref.chunk_map_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.lists(finite_f32, min_size=chunk_map.CHUNK_N, max_size=chunk_map.CHUNK_N))
def test_chunk_map_matches_ref_hypothesis(vals):
    x = jnp.asarray(vals, dtype=jnp.float32)
    got = chunk_map.chunk_map(x)
    want = ref.chunk_map_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_chunk_map_zero_padding_is_benign():
    # Padding with zeros maps to the constant term only.
    x = jnp.zeros(chunk_map.CHUNK_N, dtype=jnp.float32)
    got = chunk_map.chunk_map(x)
    np.testing.assert_allclose(got, jnp.ones_like(x))


# ---------------------------------------------------------------------------
# boot_stat
# ---------------------------------------------------------------------------


def test_boot_stat_matches_ref_basic():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(40, 900, boot_stat.BOOT_N), dtype=jnp.float32)
    u = jnp.asarray(rng.uniform(40, 900, boot_stat.BOOT_N), dtype=jnp.float32)
    w = jnp.asarray(rng.uniform(0, 2, boot_stat.BOOT_N), dtype=jnp.float32)
    num, den = boot_stat.boot_stat(x, u, w)
    rnum, rden = ref.boot_stat_ref(x, u, w)
    np.testing.assert_allclose(num, rnum, rtol=1e-5)
    np.testing.assert_allclose(den, rden, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    st.lists(finite_f32, min_size=boot_stat.BOOT_N, max_size=boot_stat.BOOT_N),
    st.lists(finite_f32, min_size=boot_stat.BOOT_N, max_size=boot_stat.BOOT_N),
)
def test_boot_stat_hypothesis(xv, uv):
    x = jnp.asarray(xv, dtype=jnp.float32)
    u = jnp.asarray(uv, dtype=jnp.float32)
    w = jnp.ones(boot_stat.BOOT_N, dtype=jnp.float32)
    num, den = boot_stat.boot_stat(x, u, w)
    rnum, rden = ref.boot_stat_ref(x, u, w)
    np.testing.assert_allclose(num, rnum, rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(den, rden, rtol=1e-4, atol=1e-2)


def test_boot_stat_zero_weights_drop_rows():
    # Padding rows (w = 0) contribute nothing.
    x = jnp.full(boot_stat.BOOT_N, 100.0, dtype=jnp.float32)
    u = jnp.full(boot_stat.BOOT_N, 50.0, dtype=jnp.float32)
    w = jnp.zeros(boot_stat.BOOT_N, dtype=jnp.float32).at[:10].set(1.0)
    num, den = boot_stat.boot_stat(x, u, w)
    np.testing.assert_allclose(num, 1000.0, rtol=1e-6)
    np.testing.assert_allclose(den, 500.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------


def test_gram_matches_ref_basic():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(gram.GRAM_N, gram.GRAM_P)), dtype=jnp.float32)
    y = jnp.asarray(rng.normal(size=gram.GRAM_N), dtype=jnp.float32)
    g, xty = gram.gram(x, y)
    rg, rxty = ref.gram_ref(x, y)
    np.testing.assert_allclose(g, rg, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(xty, rxty, rtol=1e-4, atol=1e-3)


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(gram.GRAM_N, gram.GRAM_P)), dtype=jnp.float32)
    y = jnp.zeros(gram.GRAM_N, dtype=jnp.float32)
    g, _ = gram.gram(x, y)
    np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-3)
    eigs = np.linalg.eigvalsh(np.asarray(g, dtype=np.float64))
    assert eigs.min() > -1e-2


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=1_000_000))
def test_gram_hypothesis_random_seeds(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.uniform(-3, 3, size=(gram.GRAM_N, gram.GRAM_P)), dtype=jnp.float32
    )
    y = jnp.asarray(rng.uniform(-3, 3, size=gram.GRAM_N), dtype=jnp.float32)
    g, xty = gram.gram(x, y)
    rg, rxty = ref.gram_ref(x, y)
    np.testing.assert_allclose(g, rg, rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(xty, rxty, rtol=1e-4, atol=1e-2)


def test_gram_zero_padding_rows_are_benign():
    # Zero rows (the Rust side pads n < GRAM_N) leave G unchanged.
    rng = np.random.default_rng(3)
    half = gram.GRAM_N // 2
    xs = rng.normal(size=(half, gram.GRAM_P)).astype(np.float32)
    x_pad = jnp.asarray(np.vstack([xs, np.zeros((half, gram.GRAM_P), np.float32)]))
    y_pad = jnp.zeros(gram.GRAM_N, dtype=jnp.float32)
    g, _ = gram.gram(x_pad, y_pad)
    np.testing.assert_allclose(g, xs.T @ xs, rtol=1e-4, atol=1e-2)


# ---------------------------------------------------------------------------
# model-level shapes (L2)
# ---------------------------------------------------------------------------


def test_models_produce_expected_shapes():
    from compile.model import ARTIFACTS

    import jax

    for name, (fn, args) in ARTIFACTS.items():
        out = jax.eval_shape(fn, *args)
        assert isinstance(out, tuple), name
        assert all(hasattr(o, "shape") for o in out), name


def test_models_lower_to_hlo_text():
    import jax

    from compile.aot import to_hlo_text
    from compile.model import ARTIFACTS

    for name, (fn, args) in ARTIFACTS.items():
        text = to_hlo_text(jax.jit(fn).lower(*args))
        assert "HloModule" in text, name
        assert len(text) > 100, name


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
