"""L2: JAX compute graphs for the map-task payloads.

Each function composes the L1 Pallas kernels into the jitted computation
that `aot.py` lowers to HLO text (one artifact per function). All return
tuples, matching the Rust loader's `to_tuple()` unwrapping.
"""

import jax
import jax.numpy as jnp

from .kernels import boot_stat as boot_stat_k
from .kernels import chunk_map as chunk_map_k
from .kernels import gram as gram_k


def chunk_map_model(x):
    """f32[128] -> (f32[128],): the slow_fcn compute payload."""
    return (chunk_map_k.chunk_map(x),)


def boot_stat_model(x, u, w):
    """f32[64] x3 -> (f32[2],): weighted-ratio statistic (num, den)."""
    num, den = boot_stat_k.boot_stat(x, u, w)
    return (jnp.stack([num, den]),)


def gram_model(x, y):
    """f32[256,32], f32[256] -> (f32[32,32], f32[32])."""
    g, xty = gram_k.gram(x, y)
    return (g, xty)


#: name -> (fn, example-argument shapes)
ARTIFACTS = {
    "chunk_map": (
        chunk_map_model,
        (jax.ShapeDtypeStruct((chunk_map_k.CHUNK_N,), jnp.float32),),
    ),
    "boot_stat": (
        boot_stat_model,
        (
            jax.ShapeDtypeStruct((boot_stat_k.BOOT_N,), jnp.float32),
            jax.ShapeDtypeStruct((boot_stat_k.BOOT_N,), jnp.float32),
            jax.ShapeDtypeStruct((boot_stat_k.BOOT_N,), jnp.float32),
        ),
    ),
    "gram": (
        gram_model,
        (
            jax.ShapeDtypeStruct((gram_k.GRAM_N, gram_k.GRAM_P), jnp.float32),
            jax.ShapeDtypeStruct((gram_k.GRAM_N,), jnp.float32),
        ),
    ),
}
