"""AOT lowering: JAX -> HLO *text* -> artifacts/<name>.hlo.txt.

HLO text (not `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that xla_extension 0.5.1 (the version the
published `xla` 0.1.6 rust crate links) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS


def to_hlo_text(lowered) -> str:
    """Lower to HLO text.

    Preferred path: `compiler_ir(dialect="hlo")` — emits classic HLO
    directly, bypassing the StableHLO round-trip (jax 0.8's StableHLO
    emits `dynamic_slice` attribute syntax the old parser bundled with
    xla_extension 0.5.1 rejects). Fallback: stablehlo -> XlaComputation,
    which works for grid-free kernels.

    Single-output computations have a non-tuple root; multi-output ones a
    tuple root. The Rust loader handles both (runtime::pjrt_execute).
    """
    try:
        return lowered.compiler_ir(dialect="hlo").as_hlo_text()
    except Exception:
        mlir_mod = lowered.compiler_ir("stablehlo")
        comp = xc._xla.mlir.mlir_module_to_xla_computation(
            str(mlir_mod), use_tuple_args=False, return_tuple=True
        )
        return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, (fn, example_args) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
