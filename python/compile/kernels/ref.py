"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are validated against at build
time (pytest + hypothesis), and they define the exact math the Rust
native fallbacks replicate (rust/src/runtime/kernels.rs).
"""

import jax.numpy as jnp


def chunk_map_ref(x):
    """Elementwise 3x^2 + 2x + 1 — the paper's `slow_fcn` compute payload."""
    return 3.0 * x * x + 2.0 * x + 1.0


def boot_stat_ref(x, u, w):
    """Weighted-ratio bootstrap statistic: (sum(w*x), sum(w*u)).

    Returned as (numerator, denominator) so the division happens in f64
    on the Rust side (padding rows carry w = 0 and drop out).
    """
    num = jnp.sum(w * x)
    den = jnp.sum(w * u)
    return num, den


def gram_ref(x, y):
    """Gram matrix X^T X and moment vector X^T y for a design matrix."""
    return x.T @ x, x.T @ y
