"""L1 Pallas kernel: tiled elementwise map (the `slow_fcn` payload).

The paper's map bodies are embarrassingly parallel over elements; on TPU
the natural mapping is one map *chunk* per grid step with the chunk tiled
into VMEM-resident blocks (DESIGN.md §Hardware-Adaptation). `interpret=
True` everywhere: the CPU PJRT plugin cannot execute Mosaic custom-calls.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK_N = 128  # must match rust/src/runtime/mod.rs::CHUNK_N
BLOCK = 64  # VMEM tile per grid step


def _kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = 3.0 * x * x + 2.0 * x + 1.0


def chunk_map(x):
    """Apply 3x^2 + 2x + 1 over an f32[CHUNK_N] block, tiled by BLOCK."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((CHUNK_N,), jnp.float32),
        grid=(CHUNK_N // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=True,
    )(x)
