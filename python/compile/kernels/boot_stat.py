"""L1 Pallas kernel: weighted-ratio bootstrap statistic.

One bootstrap replicate's statistic over the (padded) bigcity block:
numerator sum(w*x) and denominator sum(w*u) accumulated across VMEM
tiles. Padding rows carry w = 0, so the masked accumulation is exact.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BOOT_N = 64  # must match rust/src/runtime/mod.rs::BOOT_N
BLOCK = 32


def _kernel(x_ref, u_ref, w_ref, num_ref, den_ref):
    i = pl.program_id(0)
    x = x_ref[...]
    u = u_ref[...]
    w = w_ref[...]
    num = jnp.sum(w * x)
    den = jnp.sum(w * u)

    @pl.when(i == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    num_ref[...] += num
    den_ref[...] += den


def boot_stat(x, u, w):
    """Return (sum(w*x), sum(w*u)) over f32[BOOT_N] blocks."""
    num, den = pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        ),
        grid=(BOOT_N // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((), lambda i: ()),
            pl.BlockSpec((), lambda i: ()),
        ),
        interpret=True,
    )(x, u, w)
    return num, den
