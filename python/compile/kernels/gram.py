"""L1 Pallas kernel: blocked Gram matrix (X^T X, X^T y).

The heavy O(n·p²) half of every least-squares fold solver in the domain
packages (mgcv's bam chunks, glmnet's fold fits). On TPU this is the MXU
sweet spot: row blocks of X stream HBM→VMEM, each grid step contracts a
(BLOCK_N, P) tile into the resident (P, P) accumulator. The cheap O(p³)
solve stays on the Rust side.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GRAM_N = 256  # rows  (must match rust/src/runtime/mod.rs::GRAM_N)
GRAM_P = 32  # cols  (GRAM_P)
BLOCK_N = 64  # row block per grid step


def _kernel(x_ref, y_ref, g_ref, xty_ref):
    i = pl.program_id(0)
    xb = x_ref[...]  # (BLOCK_N, P)
    yb = y_ref[...]  # (BLOCK_N,)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        xty_ref[...] = jnp.zeros_like(xty_ref)

    g_ref[...] += jnp.dot(xb.T, xb, preferred_element_type=jnp.float32)
    xty_ref[...] += jnp.dot(xb.T, yb, preferred_element_type=jnp.float32)


def gram(x, y):
    """X^T X and X^T y over f32[GRAM_N, GRAM_P] / f32[GRAM_N] blocks."""
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((GRAM_P, GRAM_P), jnp.float32),
            jax.ShapeDtypeStruct((GRAM_P,), jnp.float32),
        ),
        grid=(GRAM_N // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N, GRAM_P), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((GRAM_P, GRAM_P), lambda i: (0, 0)),
            pl.BlockSpec((GRAM_P,), lambda i: (0,)),
        ),
        interpret=True,
    )(x, y)
