# L1: Pallas kernels for the map-task compute hot-spots.
from . import boot_stat, chunk_map, gram, ref  # noqa: F401
