//! L'Ecuyer MRG32k3a combined multiple recursive generator with stream
//! jumping — the engine behind `seed = TRUE`.
//!
//! This is the same generator R's `parallel` package exposes as
//! `"L'Ecuyer-CMRG"` and that the future ecosystem uses to give every
//! map-reduce *element* its own pre-allocated, statistically independent
//! random-number stream (paper §2.4, §4.1). Per-element streams make
//! results independent of chunking, scheduling order, and backend — the
//! property the paper's "parallelization litmus test" (§5.2) relies on.
//!
//! Implementation follows L'Ecuyer (1999) and L'Ecuyer et al. (2002),
//! including the published 2^127 jump matrices used by `RngStream` /
//! R's `nextRNGStream()`.

mod stream;

pub use stream::{RngState, RngStream};

/// Generate `n` per-element streams from a user seed, one per map-reduce
/// element (the future.apply `future.seed = TRUE` behaviour).
pub fn make_streams(seed: u64, n: usize) -> Vec<RngState> {
    let mut stream = RngStream::from_seed(seed);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        stream = stream.next_stream();
        out.push(stream.state());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_distinct_and_deterministic() {
        let a = make_streams(7, 4);
        let b = make_streams(7, 4);
        assert_eq!(a, b);
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert_ne!(a[i], a[j], "streams {i} and {j} collide");
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(make_streams(1, 2), make_streams(2, 2));
    }
}
