//! L'Ecuyer MRG32k3a combined multiple recursive generator with stream
//! jumping — the engine behind `seed = TRUE`.
//!
//! This is the same generator R's `parallel` package exposes as
//! `"L'Ecuyer-CMRG"` and that the future ecosystem uses to give every
//! map-reduce *element* its own pre-allocated, statistically independent
//! random-number stream (paper §2.4, §4.1). Per-element streams make
//! results independent of chunking, scheduling order, and backend — the
//! property the paper's "parallelization litmus test" (§5.2) relies on.
//!
//! Implementation follows L'Ecuyer (1999) and L'Ecuyer et al. (2002),
//! including the published 2^127 jump matrices used by `RngStream` /
//! R's `nextRNGStream()`.

mod stream;

pub use stream::{RngState, RngStream};

/// Generate `n` per-element streams from a user seed, one per map-reduce
/// element (the future.apply `future.seed = TRUE` behaviour).
pub fn make_streams(seed: u64, n: usize) -> Vec<RngState> {
    let mut stream = RngStream::from_seed(seed);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        stream = stream.next_stream();
        out.push(stream.state());
    }
    out
}

/// Advance a session root seed after a `seed = TRUE` map call consumed
/// it: two sibling seeded maps in one session must draw *independent*
/// stream families (as two sequential `rnorm()` calls would advance the
/// session RNG), while staying fully deterministic — the advance
/// depends only on the previous root, never on topology or timing.
pub fn advance_root_seed(seed: u64) -> u64 {
    // One splitmix64 step.
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive the root seed of a *nested* session from one element's stream
/// state — the per-level RNG fork behind plan stacks: a nested
/// `seed = TRUE` map inside element `k` of an outer map derives its own
/// per-element streams from `nested_root_seed(streams[k])`, so the whole
/// RNG tree depends only on the outer root seed and element indices.
/// Results are therefore bit-identical for any stack shape, chunking,
/// or worker placement, while distinct outer elements still get
/// statistically unrelated nested streams.
pub fn nested_root_seed(state: &RngState) -> u64 {
    // splitmix-style fold of the six state words into one seed.
    let mut h: u64 = 0x9E37_79B9_7F4A_7C15;
    for w in state {
        h ^= w.wrapping_add(0x100_0000_01B3).wrapping_add(h << 6).wrapping_add(h >> 2);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_distinct_and_deterministic() {
        let a = make_streams(7, 4);
        let b = make_streams(7, 4);
        assert_eq!(a, b);
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert_ne!(a[i], a[j], "streams {i} and {j} collide");
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(make_streams(1, 2), make_streams(2, 2));
    }

    #[test]
    fn nested_roots_are_deterministic_and_distinct_per_element() {
        let streams = make_streams(7, 4);
        let roots: Vec<u64> = streams.iter().map(nested_root_seed).collect();
        let again: Vec<u64> = make_streams(7, 4).iter().map(nested_root_seed).collect();
        assert_eq!(roots, again);
        for i in 0..roots.len() {
            for j in (i + 1)..roots.len() {
                assert_ne!(roots[i], roots[j], "nested roots {i} and {j} collide");
            }
        }
    }
}
