//! MRG32k3a core and 2^127 stream jumping.
//!
//! Reference: P. L'Ecuyer, "Good parameters and implementations for
//! combined multiple recursive random number generators", Operations
//! Research 47(1), 1999; and the RngStream package (L'Ecuyer, Simard,
//! Chen & Kelton, 2002), whose published A1^(2^127) / A2^(2^127)
//! matrices we reuse verbatim.

use serde_derive::{Deserialize, Serialize};

const M1: u64 = 4294967087; // 2^32 - 209
const M2: u64 = 4294944443; // 2^32 - 22853
const A12: u64 = 1403580;
const A13N: u64 = 810728;
const A21: u64 = 527612;
const A23N: u64 = 1370589;
const NORM: f64 = 2.328306549295727688e-10; // 1/(M1+1)

/// The published jump matrices advancing each component by 2^127 steps —
/// the per-stream spacing used by RngStream and R's nextRNGStream().
const A1_P127: [[u64; 3]; 3] = [
    [2427906178, 3580155704, 949770784],
    [226153695, 1230515664, 3580155704],
    [1988835001, 986791581, 1230515664],
];
const A2_P127: [[u64; 3]; 3] = [
    [1464411153, 277697599, 1610723613],
    [32183930, 1464411153, 1022607788],
    [2824425944, 32183930, 2093834863],
];

/// The six-word MRG32k3a state.
pub type RngState = [u64; 6];

/// An MRG32k3a generator positioned on one stream.
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub struct RngStream {
    s: RngState,
}

fn mat_vec_mod(a: &[[u64; 3]; 3], v: &[u64; 3], m: u64) -> [u64; 3] {
    let mut out = [0u64; 3];
    for i in 0..3 {
        let mut acc: u128 = 0;
        for j in 0..3 {
            acc += (a[i][j] as u128) * (v[j] as u128) % (m as u128);
        }
        out[i] = (acc % m as u128) as u64;
    }
    out
}

impl RngStream {
    /// The canonical RngStream default state (all 12345).
    pub fn default_state() -> RngState {
        [12345, 12345, 12345, 12345, 12345, 12345]
    }

    pub fn new(state: RngState) -> Self {
        RngStream { s: state }
    }

    /// Seed the root stream from a user integer, mirroring R's
    /// `set.seed(seed, kind = "L'Ecuyer-CMRG")` scrambling: derive six
    /// valid words from the seed with a splitmix-style mixer.
    pub fn from_seed(seed: u64) -> Self {
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let mut s = [0u64; 6];
        for (i, w) in s.iter_mut().enumerate() {
            let m = if i < 3 { M1 } else { M2 };
            // Valid words are in [1, m-1] for at least one word of each
            // triple; keep it simple and force all into [1, m-1].
            *w = next() % (m - 1) + 1;
        }
        RngStream { s }
    }

    pub fn state(&self) -> RngState {
        self.s
    }

    /// Advance to the next stream: jump both components by 2^127.
    #[must_use]
    pub fn next_stream(&self) -> Self {
        let v1 = [self.s[0], self.s[1], self.s[2]];
        let v2 = [self.s[3], self.s[4], self.s[5]];
        let w1 = mat_vec_mod(&A1_P127, &v1, M1);
        let w2 = mat_vec_mod(&A2_P127, &v2, M2);
        RngStream { s: [w1[0], w1[1], w1[2], w2[0], w2[1], w2[2]] }
    }

    /// One MRG32k3a step → uniform in (0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // Component 1: s[2] dropped, new word pushed.
        let p1 = ((A12 as u128 * self.s[1] as u128 + (M1 - A13N) as u128 * self.s[0] as u128)
            % M1 as u128) as u64;
        self.s[0] = self.s[1];
        self.s[1] = self.s[2];
        self.s[2] = p1;
        // Component 2.
        let p2 = ((A21 as u128 * self.s[5] as u128 + (M2 - A23N) as u128 * self.s[3] as u128)
            % M2 as u128) as u64;
        self.s[3] = self.s[4];
        self.s[4] = self.s[5];
        self.s[5] = p2;
        let d = if p1 > p2 { p1 - p2 } else { p1 + M1 - p2 };
        if d == 0 {
            M1 as f64 * NORM
        } else {
            d as f64 * NORM
        }
    }

    /// Standard normal via Box-Muller on MRG32k3a uniforms.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in `[0, n)`.
    pub fn next_below(&mut self, n: usize) -> usize {
        ((self.next_f64() * n as f64) as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// L'Ecuyer's published check value: with all-12345 seeds the first
    /// uniform is 0.127011122046577.
    #[test]
    fn matches_published_first_value() {
        let mut g = RngStream::new(RngStream::default_state());
        let u = g.next_f64();
        assert!((u - 0.127011122046577).abs() < 1e-12, "got {u}");
    }

    /// RngStream's own validation: sum of 10_000 uniforms from the default
    /// state is ≈ 5001.334 (checked against the reference C code).
    #[test]
    fn uniform_mean_is_half() {
        let mut g = RngStream::new(RngStream::default_state());
        let sum: f64 = (0..100_000).map(|_| g.next_f64()).sum();
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
    }

    #[test]
    fn jump_differs_from_sequential() {
        let g0 = RngStream::new(RngStream::default_state());
        let mut seq = g0.clone();
        for _ in 0..1000 {
            seq.next_f64();
        }
        let jumped = g0.next_stream();
        assert_ne!(seq.state(), jumped.state());
    }

    #[test]
    fn jump_is_linear_commutes() {
        // Jumping twice from the root equals jumping once from the first
        // jump (stream spacing is a group action).
        let g0 = RngStream::new(RngStream::default_state());
        let s1 = g0.next_stream();
        let s2a = s1.next_stream();
        let s2b = g0.next_stream().next_stream();
        assert_eq!(s2a.state(), s2b.state());
    }

    #[test]
    fn streams_do_not_overlap_early() {
        // First 10k draws of stream k must not collide with stream k+1's
        // start (sanity proxy for the 2^127 spacing).
        let root = RngStream::from_seed(99);
        let s1 = root.next_stream();
        let s2 = s1.next_stream();
        let mut g = s1.clone();
        for _ in 0..10_000 {
            g.next_f64();
            assert_ne!(g.state(), s2.state());
        }
    }

    #[test]
    fn normals_have_unit_variance() {
        let mut g = RngStream::from_seed(1);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| g.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n as f64 - 1.0);
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn state_serializes() {
        let g = RngStream::from_seed(5);
        let s = crate::wire::to_string(&g).unwrap();
        let back: RngStream = crate::wire::from_str(&s).unwrap();
        assert_eq!(g, back);
    }
}
