//! # futurize-rs
//!
//! A Rust reproduction of the *futurize* paper ("A Unified Approach to
//! Concurrent, Parallel Map-Reduce in R using Futures", Bengtsson 2026).
//!
//! The crate is organised as the paper's ecosystem is:
//!
//! - [`rlite`] — the language substrate: a mini-R interpreter (lexer,
//!   parser, evaluator, condition system, builtin library). The paper's
//!   mechanism is source-to-source transpilation of R expressions; this
//!   module provides the expressions.
//! - [`rng`] — L'Ecuyer MRG32k3a combined multiple recursive generator
//!   with 2^127 stream jumping (the `parallel`-package L'Ecuyer-CMRG
//!   analog used for `seed = TRUE`).
//! - [`globals`] — static free-variable analysis used to identify and
//!   export globals to parallel workers.
//! - [`future_core`] — the future abstraction: handles, lifecycle,
//!   `plan()` stack, and the streaming dispatch core (`FutureSet`):
//!   shared task contexts, incremental backpressured chunk feeding,
//!   fail-fast cancellation (structured concurrency).
//! - [`backend`] — execution backends: `sequential`, `multicore`
//!   (threads), `multisession` (worker subprocesses over stdio),
//!   `cluster_sim` (latency-injected processes) and `batchtools_sim`
//!   (file-based job queue with scheduler polling).
//! - [`scheduling`] — chunking and load-balancing (`chunk_size`,
//!   `scheduling`), ordered result reassembly.
//! - [`transpile`] — **the paper's contribution**: `futurize()`, the
//!   registry of per-function transpilers, expression unwrapping, and
//!   the unified options surface.
//! - [`apis`] — the supported map-reduce API families of Table 1
//!   (base, stats, purrr, crossmap, foreach, plyr, BiocParallel) in both
//!   sequential and future-based forms.
//! - [`domains`] — the domain-specific packages of Table 2 (boot,
//!   caret, glmnet, lme4, mgcv, tm analogs).
//! - [`progress`] — the progressr analog: near-live progress conditions
//!   relayed from workers.
//! - [`runtime`] — the PJRT engine that loads and executes the AOT
//!   JAX/Pallas artifacts (`artifacts/*.hlo.txt`) from map-task bodies.
//! - [`coordinator`] — the session object that wires everything
//!   together, plus tracing and metrics.
//!
//! ## Quickstart
//!
//! (`no_run`: rustdoc test binaries don't inherit the cargo-config
//! rpath to libxla_extension's bundled libstdc++; the same snippet runs
//! as `coordinator::tests::session_quickstart`.)
//!
//! ```no_run
//! use futurize::prelude::*;
//!
//! let mut session = Session::new();
//! session.eval_str("plan(multicore, workers = 2)").unwrap();
//! let ys = session
//!     .eval_str("lapply(1:8, function(x) x^2) |> futurize()")
//!     .unwrap();
//! assert_eq!(ys.len(), 8);
//! ```

pub mod apis;
pub mod backend;
pub mod bench_harness;
pub mod coordinator;
pub mod domains;
pub mod future_core;
pub mod globals;
pub mod progress;
pub mod rlite;
pub mod rng;
pub mod runtime;
pub mod scheduling;
pub mod transpile;
pub mod wire;

/// Convenience re-exports covering the public API surface used by the
/// examples, tests, and benchmarks.
pub mod prelude {
    pub use crate::backend::PlanSpec;
    pub use crate::coordinator::{Session, SessionConfig};
    pub use crate::rlite::conditions::{RCondition, Severity};
    pub use crate::rlite::value::RVal;
    pub use crate::rlite::{parse_program, parse_expr};
    pub use crate::transpile::FuturizeOptions;
    pub use crate::{fusion_report, FusionReport};
}

/// Snapshot of the fusion/reduction trace counters, including the
/// per-reason rejection labels the parallel-safety analyzer surfaces
/// as FZ007/FZ008 — the "silent rejection" observability hook.
/// Counters are process-cumulative (slice counters tick wherever the
/// slice runs, so subprocess backends accumulate them worker-side).
#[derive(Clone, Debug)]
pub struct FusionReport {
    pub kernel_recognized: u64,
    pub kernel_unmatched: u64,
    pub kernel_slices_fused: u64,
    pub kernel_slices_fallback: u64,
    /// Kernel-recognition rejections by reason label
    /// (`not-closure`, `params`, `env-mutation`, `named-args`,
    /// `shadowed`, `shape`).
    pub kernel_rejections: Vec<(&'static str, u64)>,
    pub reduce_plans_attached: u64,
    pub reduce_slices_folded: u64,
    pub reduce_slices_fallback: u64,
    /// Reduce-plan rejections by reason label
    /// (`shadowed`, `not-in-catalog`, `vec-gate`).
    pub reduce_rejections: Vec<(&'static str, u64)>,
    /// Data-plane cache: blobs actually written to a worker/spool.
    pub cache_puts: u64,
    /// Bytes those puts shipped (approximate in-memory size).
    pub cache_put_bytes: u64,
    /// Task dispatches that referenced an already-resident blob.
    pub cache_hits: u64,
    /// Bytes those hits did *not* re-ship (the wire savings).
    pub cache_hit_bytes: u64,
    /// Worker-side negative acks (blob evicted under memory pressure,
    /// re-shipped on demand).
    pub cache_misses: u64,
    /// Bytes reclaimed by LRU eviction in worker blob stores.
    pub cache_evict_bytes: u64,
}

impl FusionReport {
    /// Multi-line human rendering (diagnostics/debug output).
    pub fn render(&self) -> String {
        let fmt_reasons = |rs: &[(&'static str, u64)]| {
            rs.iter()
                .map(|(l, n)| format!("{l}={n}"))
                .collect::<Vec<_>>()
                .join(" ")
        };
        format!(
            "kernel: recognized={} unmatched={} slices_fused={} slices_fallback={}\n\
             kernel rejections: {}\n\
             reduce: plans_attached={} slices_folded={} slices_fallback={}\n\
             reduce rejections: {}\n\
             cache: puts={} put_bytes={} hits={} hit_bytes={} misses={} evict_bytes={}",
            self.kernel_recognized,
            self.kernel_unmatched,
            self.kernel_slices_fused,
            self.kernel_slices_fallback,
            fmt_reasons(&self.kernel_rejections),
            self.reduce_plans_attached,
            self.reduce_slices_folded,
            self.reduce_slices_fallback,
            fmt_reasons(&self.reduce_rejections),
            self.cache_puts,
            self.cache_put_bytes,
            self.cache_hits,
            self.cache_hit_bytes,
            self.cache_misses,
            self.cache_evict_bytes,
        )
    }
}

/// Read the current fusion/reduction counters (test + diagnostics
/// hook; satellite of the parallel-safety analyzer).
pub fn fusion_report() -> FusionReport {
    FusionReport {
        kernel_recognized: transpile::fusion::contexts_recognized(),
        kernel_unmatched: transpile::fusion::contexts_unmatched(),
        kernel_slices_fused: transpile::fusion::slices_fused(),
        kernel_slices_fallback: transpile::fusion::slices_fallback(),
        kernel_rejections: transpile::fusion::rejection_counts(),
        reduce_plans_attached: transpile::reduce::plans_attached(),
        reduce_slices_folded: transpile::reduce::slices_folded(),
        reduce_slices_fallback: transpile::reduce::slices_fallback(),
        reduce_rejections: transpile::reduce::plan_rejections(),
        cache_puts: wire::stats::cache_puts(),
        cache_put_bytes: wire::stats::cache_put_bytes(),
        cache_hits: wire::stats::cache_hits(),
        cache_hit_bytes: wire::stats::cache_hit_bytes(),
        cache_misses: wire::stats::cache_misses(),
        cache_evict_bytes: wire::stats::cache_evict_bytes(),
    }
}
