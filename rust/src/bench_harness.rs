//! Tiny benchmark harness (criterion is not available offline).
//!
//! Provides warmup + repeated timing with mean/min/max/stddev reporting
//! in a stable, grep-friendly format that EXPERIMENTS.md quotes:
//!
//! ```text
//! bench <group>/<name>  mean 12.34ms  min 11.90ms  max 13.00ms  sd 0.35ms  (n=10)
//! ```

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Clone, Debug)]
pub struct Stats {
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub sd_s: f64,
    pub n: usize,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / (n as f64 - 1.0)
        } else {
            0.0
        };
        Stats {
            mean_s: mean,
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: samples.iter().cloned().fold(0.0, f64::max),
            sd_s: var.sqrt(),
            n,
        }
    }
}

fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Run `f` `n` times after `warmup` runs; print and return stats.
pub fn bench<F: FnMut()>(group: &str, name: &str, warmup: usize, n: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let st = Stats::from_samples(&samples);
    println!(
        "bench {group}/{name}  mean {}  min {}  max {}  sd {}  (n={})",
        fmt_secs(st.mean_s),
        fmt_secs(st.min_s),
        fmt_secs(st.max_s),
        fmt_secs(st.sd_s),
        st.n
    );
    st
}

/// Print a table header / row (for the paper-style result tables).
pub fn table_header(title: &str, cols: &[&str]) {
    println!("\n== {title} ==");
    println!("{}", cols.join("\t"));
}

pub fn table_row(cells: &[String]) {
    println!("{}", cells.join("\t"));
}

/// Is the bench running in CI smoke mode (`BENCH_SMOKE=1`)? Smoke runs
/// shrink payloads/iterations so the perf jobs finish in seconds while
/// still exercising every measured code path.
pub fn smoke_mode() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// A machine-readable benchmark report, accumulated as JSON and written
/// to disk so the repo's perf trajectory has recorded datapoints (e.g.
/// `BENCH_wire.json`).
pub struct JsonReport {
    path: String,
    entries: Vec<(String, crate::wire::JsonValue)>,
}

impl JsonReport {
    pub fn new(path: &str) -> JsonReport {
        JsonReport { path: path.to_string(), entries: Vec::new() }
    }

    pub fn push(&mut self, key: &str, value: crate::wire::JsonValue) {
        self.entries.push((key.to_string(), value));
    }

    pub fn push_num(&mut self, key: &str, value: f64) {
        self.push(key, crate::wire::JsonValue::Number(value));
    }

    /// Write the report as plain JSON; returns the rendered text.
    pub fn write(&self) -> std::io::Result<String> {
        let text = crate::wire::JsonValue::Object(self.entries.clone()).render();
        std::fs::write(&self.path, &text)?;
        println!("wrote {} ({} entries)", self.path, self.entries.len());
        Ok(text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let st = Stats::from_samples(&[1.0, 2.0, 3.0]);
        assert_eq!(st.mean_s, 2.0);
        assert_eq!(st.min_s, 1.0);
        assert_eq!(st.max_s, 3.0);
        assert_eq!(st.n, 3);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut hits = 0;
        let st = bench("t", "noop", 1, 3, || hits += 1);
        assert_eq!(hits, 4);
        assert_eq!(st.n, 3);
    }
}
