//! Connection handshake for the TCP cluster transport.
//!
//! A connecting worker speaks first: one `Hello` frame carrying a magic
//! number, the protocol version, the codecs it can decode, a display
//! tag, and its capability set. The parent answers with one
//! `HandshakeReply` frame — `Welcome` assigns the worker its slot and
//! pins the codec and heartbeat interval for the rest of the
//! connection, `Reject` names why the worker is unusable (version skew,
//! no codec in common) before the socket closes.
//!
//! Handshake frames are always encoded with the **binary** codec,
//! whatever the session's transport codec is: the negotiation must be
//! decodable before its own outcome is known. Everything after the
//! reply uses the codec the `Welcome` named.

use serde_derive::{Deserialize, Serialize};

use super::codec::{read_frame, write_frame};
use super::WireCodec;

/// First bytes of every `Hello`: rejects non-futurize peers (a port
/// scanner, a stray HTTP client) before any state is built.
pub const HANDSHAKE_MAGIC: u32 = 0x465A_5443; // "FZTC"

/// Bumped whenever the worker protocol changes incompatibly; a parent
/// rejects workers speaking a different version instead of desyncing
/// mid-map.
pub const PROTOCOL_VERSION: u32 = 1;

/// Worker → parent: connection opener.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct Hello {
    pub magic: u32,
    pub version: u32,
    /// Codec names this worker can decode (values of
    /// [`WireCodec::env_value`]); the parent picks its session codec if
    /// listed.
    pub codecs: Vec<String>,
    /// Display tag for logs (hostname/pid by default).
    pub tag: String,
    /// Cores available on the worker's machine — capability
    /// registration for nested plan levels.
    pub cores: u32,
    /// Feature capabilities (e.g. "data-cache", "nested-plans").
    pub capabilities: Vec<String>,
}

impl Hello {
    /// A `Hello` describing this process.
    pub fn current(tag: String) -> Hello {
        Hello {
            magic: HANDSHAKE_MAGIC,
            version: PROTOCOL_VERSION,
            codecs: vec![
                WireCodec::Binary.env_value().to_string(),
                WireCodec::Json.env_value().to_string(),
            ],
            tag,
            cores: std::thread::available_parallelism().map(|n| n.get() as u32).unwrap_or(1),
            capabilities: vec!["data-cache".into(), "nested-plans".into()],
        }
    }

    /// Check this peer can join a session speaking `codec`.
    pub fn validate(&self, codec: WireCodec) -> Result<(), String> {
        if self.magic != HANDSHAKE_MAGIC {
            return Err(format!("bad handshake magic {:#010x}", self.magic));
        }
        if self.version != PROTOCOL_VERSION {
            return Err(format!(
                "protocol version mismatch: worker speaks v{}, parent v{PROTOCOL_VERSION}",
                self.version
            ));
        }
        if !self.codecs.iter().any(|c| c == codec.env_value()) {
            return Err(format!(
                "no codec in common: session uses '{}', worker offers {:?}",
                codec.env_value(),
                self.codecs
            ));
        }
        Ok(())
    }
}

/// Parent → worker: handshake outcome.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub enum HandshakeReply {
    Welcome {
        /// Slot index assigned to this worker (stable across the
        /// connection; a respawn gets a fresh connection).
        worker_idx: u32,
        /// Codec for every subsequent frame ([`WireCodec::env_value`]).
        codec: String,
        /// Interval at which the worker must emit heartbeat frames;
        /// the parent reaps the connection after ~2.5 missed intervals.
        heartbeat_ms: f64,
    },
    Reject {
        reason: String,
    },
}

/// Send one handshake message (binary-encoded frame).
pub fn send<T: serde::Serialize, W: std::io::Write>(w: &mut W, msg: &T) -> std::io::Result<()> {
    let bytes = WireCodec::Binary
        .encode(msg)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    write_frame(w, &bytes)
}

/// Receive one handshake message. EOF before a full frame is an error:
/// a handshake is never optional.
pub fn recv<T: for<'a> serde::Deserialize<'a>, R: std::io::Read>(
    r: &mut R,
) -> std::io::Result<T> {
    let frame = read_frame(r)?.ok_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "peer closed during handshake")
    })?;
    WireCodec::Binary
        .decode(&frame)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_roundtrips_and_validates() {
        let h = Hello::current("test-host".into());
        let mut buf = Vec::new();
        send(&mut buf, &h).unwrap();
        let back: Hello = recv(&mut &buf[..]).unwrap();
        assert_eq!(back.magic, HANDSHAKE_MAGIC);
        assert_eq!(back.version, PROTOCOL_VERSION);
        assert_eq!(back.tag, "test-host");
        back.validate(WireCodec::Binary).unwrap();
        back.validate(WireCodec::Json).unwrap();
    }

    #[test]
    fn bad_peers_are_rejected() {
        let mut h = Hello::current("t".into());
        h.magic = 0xDEAD_BEEF;
        assert!(h.validate(WireCodec::Binary).unwrap_err().contains("magic"));
        let mut h = Hello::current("t".into());
        h.version = PROTOCOL_VERSION + 1;
        assert!(h.validate(WireCodec::Binary).unwrap_err().contains("version"));
        let mut h = Hello::current("t".into());
        h.codecs = vec!["carrier-pigeon".into()];
        assert!(h.validate(WireCodec::Binary).unwrap_err().contains("codec"));
    }

    #[test]
    fn reply_roundtrips() {
        let r = HandshakeReply::Welcome {
            worker_idx: 3,
            codec: "binary".into(),
            heartbeat_ms: 500.0,
        };
        let mut buf = Vec::new();
        send(&mut buf, &r).unwrap();
        match recv::<HandshakeReply, _>(&mut &buf[..]).unwrap() {
            HandshakeReply::Welcome { worker_idx, codec, heartbeat_ms } => {
                assert_eq!(worker_idx, 3);
                assert_eq!(codec, "binary");
                assert_eq!(heartbeat_ms, 500.0);
            }
            other => panic!("{other:?}"),
        }
        // A non-futurize peer speaking garbage fails the decode cleanly.
        assert!(recv::<HandshakeReply, _>(&mut &b""[..]).is_err());
    }
}
