//! Dynamic JSON value with hand-written serde impls.

use std::fmt;

/// A JSON value. Object keys keep insertion order (Vec of pairs).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(Vec<(String, JsonValue)>),
}

impl JsonValue {
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    /// Convenience constructor for small objects.
    pub fn obj(pairs: Vec<(&str, JsonValue)>) -> JsonValue {
        JsonValue::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> JsonValue {
        JsonValue::Number(n)
    }
}

impl fmt::Display for JsonValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", super::to_string(self).unwrap_or_default())
    }
}

impl serde::Serialize for JsonValue {
    fn serialize<S: serde::Serializer>(&self, s: S) -> Result<S::Ok, S::Error> {
        use serde::ser::{SerializeMap, SerializeSeq};
        match self {
            JsonValue::Null => s.serialize_unit(),
            JsonValue::Bool(b) => s.serialize_bool(*b),
            JsonValue::Number(n) => s.serialize_f64(*n),
            JsonValue::String(x) => s.serialize_str(x),
            JsonValue::Array(items) => {
                let mut seq = s.serialize_seq(Some(items.len()))?;
                for it in items {
                    seq.serialize_element(it)?;
                }
                seq.end()
            }
            JsonValue::Object(pairs) => {
                let mut map = s.serialize_map(Some(pairs.len()))?;
                for (k, v) in pairs {
                    map.serialize_entry(k, v)?;
                }
                map.end()
            }
        }
    }
}

impl<'de> serde::Deserialize<'de> for JsonValue {
    fn deserialize<D: serde::Deserializer<'de>>(d: D) -> Result<Self, D::Error> {
        struct V;
        impl<'de> serde::de::Visitor<'de> for V {
            type Value = JsonValue;
            fn expecting(&self, f: &mut fmt::Formatter) -> fmt::Result {
                write!(f, "any JSON value")
            }
            fn visit_unit<E>(self) -> Result<JsonValue, E> {
                Ok(JsonValue::Null)
            }
            fn visit_none<E>(self) -> Result<JsonValue, E> {
                Ok(JsonValue::Null)
            }
            fn visit_some<D2: serde::Deserializer<'de>>(
                self,
                d: D2,
            ) -> Result<JsonValue, D2::Error> {
                serde::Deserialize::deserialize(d)
            }
            fn visit_bool<E>(self, v: bool) -> Result<JsonValue, E> {
                Ok(JsonValue::Bool(v))
            }
            fn visit_i64<E>(self, v: i64) -> Result<JsonValue, E> {
                Ok(JsonValue::Number(v as f64))
            }
            fn visit_u64<E>(self, v: u64) -> Result<JsonValue, E> {
                Ok(JsonValue::Number(v as f64))
            }
            fn visit_f64<E>(self, v: f64) -> Result<JsonValue, E> {
                Ok(JsonValue::Number(v))
            }
            fn visit_str<E>(self, v: &str) -> Result<JsonValue, E> {
                Ok(JsonValue::String(v.to_string()))
            }
            fn visit_string<E>(self, v: String) -> Result<JsonValue, E> {
                Ok(JsonValue::String(v))
            }
            fn visit_seq<A: serde::de::SeqAccess<'de>>(
                self,
                mut seq: A,
            ) -> Result<JsonValue, A::Error> {
                let mut out = Vec::new();
                while let Some(v) = seq.next_element::<JsonValue>()? {
                    out.push(v);
                }
                Ok(JsonValue::Array(out))
            }
            fn visit_map<A: serde::de::MapAccess<'de>>(
                self,
                mut map: A,
            ) -> Result<JsonValue, A::Error> {
                let mut out = Vec::new();
                while let Some((k, v)) = map.next_entry::<String, JsonValue>()? {
                    out.push((k, v));
                }
                Ok(JsonValue::Object(out))
            }
        }
        d.deserialize_any(V)
    }
}
