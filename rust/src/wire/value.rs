//! Dynamic JSON value.
//!
//! On the wire, `JsonValue` travels like every other protocol type:
//! derive-generated, externally-tagged serde impls (`{"Number":1.0}` in
//! the JSON codec, a varint variant tag in the binary codec). The
//! hand-written `deserialize_any`-based impls it used to have were
//! incompatible with the non-self-describing binary codec.
//!
//! [`JsonValue::render`] produces *plain* (untagged) JSON text for
//! human-facing output — `Display`, bench reports — where the value is
//! a document, not a protocol message.

use std::fmt;

use serde_derive::{Deserialize, Serialize};

/// A JSON value. Object keys keep insertion order (Vec of pairs).
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(Vec<(String, JsonValue)>),
}

impl JsonValue {
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    /// Convenience constructor for small objects.
    pub fn obj(pairs: Vec<(&str, JsonValue)>) -> JsonValue {
        JsonValue::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> JsonValue {
        JsonValue::Number(n)
    }

    /// Render as compact plain JSON text (the untagged document form,
    /// not the tagged protocol form `to_string` would produce).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Number(n) => super::ser::fmt_f64(out, *n),
            JsonValue::String(s) => super::ser::escape_into(out, s),
            JsonValue::Array(items) => {
                out.push('[');
                for (k, it) in items.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    it.render_into(out);
                }
                out.push(']');
            }
            JsonValue::Object(pairs) => {
                out.push('{');
                for (k, (key, v)) in pairs.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    super::ser::escape_into(out, key);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for JsonValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_is_plain_json() {
        let v = JsonValue::obj(vec![
            ("amount", JsonValue::num(1.0)),
            ("label", JsonValue::String("a \"b\"".into())),
            ("xs", JsonValue::Array(vec![JsonValue::Bool(true), JsonValue::Null])),
        ]);
        assert_eq!(
            v.render(),
            "{\"amount\":1.0,\"label\":\"a \\\"b\\\"\",\"xs\":[true,null]}"
        );
    }
}
