//! Compact-JSON `serde::Serializer`.

use std::fmt::Write as _;

#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json serialize error: {}", self.0)
    }
}
impl std::error::Error for Error {}
impl serde::ser::Error for Error {
    fn custom<T: std::fmt::Display>(msg: T) -> Self {
        Error(msg.to_string())
    }
}

/// Serialize any `Serialize` value to compact JSON text. Byte
/// accounting happens in [`super::codec`], which wraps this for
/// protocol transport; direct callers (trace rendering, tests) don't
/// count against the wire stats.
pub fn to_string<T: serde::Serialize + ?Sized>(value: &T) -> Result<String, Error> {
    let mut s = Ser { out: String::new() };
    value.serialize(&mut s)?;
    Ok(s.out)
}

pub(crate) fn escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub(crate) fn fmt_f64(out: &mut String, v: f64) {
    if v.is_nan() || v.is_infinite() {
        // JSON has no NaN/Inf; encode as tagged strings the deserializer
        // understands (used by rlite's NA-as-NaN model).
        if v.is_nan() {
            out.push_str("\"__f64_nan__\"");
        } else if v > 0.0 {
            out.push_str("\"__f64_inf__\"");
        } else {
            out.push_str("\"__f64_ninf__\"");
        }
    } else if v == v.trunc() && v.abs() < 9.0e15 {
        let _ = write!(out, "{:.1}", v); // keep float-ness: "2.0"
    } else {
        // Round-trippable shortest representation.
        let _ = write!(out, "{v:?}");
    }
}

struct Ser {
    out: String,
}

pub struct SeqSer<'a> {
    ser: &'a mut Ser,
    first: bool,
    close: &'static str,
}

impl<'a> SeqSer<'a> {
    fn comma(&mut self) {
        if self.first {
            self.first = false;
        } else {
            self.ser.out.push(',');
        }
    }
}

impl<'a> serde::Serializer for &'a mut Ser {
    type Ok = ();
    type Error = Error;
    type SerializeSeq = SeqSer<'a>;
    type SerializeTuple = SeqSer<'a>;
    type SerializeTupleStruct = SeqSer<'a>;
    type SerializeTupleVariant = SeqSer<'a>;
    type SerializeMap = SeqSer<'a>;
    type SerializeStruct = SeqSer<'a>;
    type SerializeStructVariant = SeqSer<'a>;

    fn serialize_bool(self, v: bool) -> Result<(), Error> {
        self.out.push_str(if v { "true" } else { "false" });
        Ok(())
    }
    fn serialize_i8(self, v: i8) -> Result<(), Error> {
        self.serialize_i64(v as i64)
    }
    fn serialize_i16(self, v: i16) -> Result<(), Error> {
        self.serialize_i64(v as i64)
    }
    fn serialize_i32(self, v: i32) -> Result<(), Error> {
        self.serialize_i64(v as i64)
    }
    fn serialize_i64(self, v: i64) -> Result<(), Error> {
        let _ = write!(self.out, "{v}");
        Ok(())
    }
    fn serialize_u8(self, v: u8) -> Result<(), Error> {
        self.serialize_u64(v as u64)
    }
    fn serialize_u16(self, v: u16) -> Result<(), Error> {
        self.serialize_u64(v as u64)
    }
    fn serialize_u32(self, v: u32) -> Result<(), Error> {
        self.serialize_u64(v as u64)
    }
    fn serialize_u64(self, v: u64) -> Result<(), Error> {
        let _ = write!(self.out, "{v}");
        Ok(())
    }
    fn serialize_f32(self, v: f32) -> Result<(), Error> {
        self.serialize_f64(v as f64)
    }
    fn serialize_f64(self, v: f64) -> Result<(), Error> {
        fmt_f64(&mut self.out, v);
        Ok(())
    }
    fn serialize_char(self, v: char) -> Result<(), Error> {
        escape_into(&mut self.out, &v.to_string());
        Ok(())
    }
    fn serialize_str(self, v: &str) -> Result<(), Error> {
        escape_into(&mut self.out, v);
        Ok(())
    }
    fn serialize_bytes(self, v: &[u8]) -> Result<(), Error> {
        use serde::ser::SerializeSeq;
        let mut seq = self.serialize_seq(Some(v.len()))?;
        for b in v {
            seq.serialize_element(b)?;
        }
        seq.end()
    }
    fn serialize_none(self) -> Result<(), Error> {
        self.out.push_str("null");
        Ok(())
    }
    fn serialize_some<T: serde::Serialize + ?Sized>(self, value: &T) -> Result<(), Error> {
        value.serialize(self)
    }
    fn serialize_unit(self) -> Result<(), Error> {
        self.out.push_str("null");
        Ok(())
    }
    fn serialize_unit_struct(self, _name: &'static str) -> Result<(), Error> {
        self.serialize_unit()
    }
    fn serialize_unit_variant(
        self,
        _name: &'static str,
        _idx: u32,
        variant: &'static str,
    ) -> Result<(), Error> {
        escape_into(&mut self.out, variant);
        Ok(())
    }
    fn serialize_newtype_struct<T: serde::Serialize + ?Sized>(
        self,
        _name: &'static str,
        value: &T,
    ) -> Result<(), Error> {
        value.serialize(self)
    }
    fn serialize_newtype_variant<T: serde::Serialize + ?Sized>(
        self,
        _name: &'static str,
        _idx: u32,
        variant: &'static str,
        value: &T,
    ) -> Result<(), Error> {
        self.out.push('{');
        escape_into(&mut self.out, variant);
        self.out.push(':');
        value.serialize(&mut *self)?;
        self.out.push('}');
        Ok(())
    }
    fn serialize_seq(self, _len: Option<usize>) -> Result<SeqSer<'a>, Error> {
        self.out.push('[');
        Ok(SeqSer { ser: self, first: true, close: "]" })
    }
    fn serialize_tuple(self, len: usize) -> Result<SeqSer<'a>, Error> {
        self.serialize_seq(Some(len))
    }
    fn serialize_tuple_struct(
        self,
        _name: &'static str,
        len: usize,
    ) -> Result<SeqSer<'a>, Error> {
        self.serialize_seq(Some(len))
    }
    fn serialize_tuple_variant(
        self,
        _name: &'static str,
        _idx: u32,
        variant: &'static str,
        _len: usize,
    ) -> Result<SeqSer<'a>, Error> {
        self.out.push('{');
        escape_into(&mut self.out, variant);
        self.out.push_str(":[");
        Ok(SeqSer { ser: self, first: true, close: "]}" })
    }
    fn serialize_map(self, _len: Option<usize>) -> Result<SeqSer<'a>, Error> {
        self.out.push('{');
        Ok(SeqSer { ser: self, first: true, close: "}" })
    }
    fn serialize_struct(
        self,
        _name: &'static str,
        _len: usize,
    ) -> Result<SeqSer<'a>, Error> {
        self.out.push('{');
        Ok(SeqSer { ser: self, first: true, close: "}" })
    }
    fn serialize_struct_variant(
        self,
        _name: &'static str,
        _idx: u32,
        variant: &'static str,
        _len: usize,
    ) -> Result<SeqSer<'a>, Error> {
        self.out.push('{');
        escape_into(&mut self.out, variant);
        self.out.push_str(":{");
        Ok(SeqSer { ser: self, first: true, close: "}}" })
    }
}

impl serde::ser::SerializeSeq for SeqSer<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_element<T: serde::Serialize + ?Sized>(&mut self, value: &T) -> Result<(), Error> {
        self.comma();
        value.serialize(&mut *self.ser)
    }
    fn end(self) -> Result<(), Error> {
        self.ser.out.push_str(self.close);
        Ok(())
    }
}

impl serde::ser::SerializeTuple for SeqSer<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_element<T: serde::Serialize + ?Sized>(&mut self, value: &T) -> Result<(), Error> {
        serde::ser::SerializeSeq::serialize_element(self, value)
    }
    fn end(self) -> Result<(), Error> {
        serde::ser::SerializeSeq::end(self)
    }
}

impl serde::ser::SerializeTupleStruct for SeqSer<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_field<T: serde::Serialize + ?Sized>(&mut self, value: &T) -> Result<(), Error> {
        serde::ser::SerializeSeq::serialize_element(self, value)
    }
    fn end(self) -> Result<(), Error> {
        serde::ser::SerializeSeq::end(self)
    }
}

impl serde::ser::SerializeTupleVariant for SeqSer<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_field<T: serde::Serialize + ?Sized>(&mut self, value: &T) -> Result<(), Error> {
        serde::ser::SerializeSeq::serialize_element(self, value)
    }
    fn end(self) -> Result<(), Error> {
        serde::ser::SerializeSeq::end(self)
    }
}

impl serde::ser::SerializeMap for SeqSer<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_key<T: serde::Serialize + ?Sized>(&mut self, key: &T) -> Result<(), Error> {
        self.comma();
        // Keys must be strings in JSON; serialize then ensure quoting.
        let k = to_string(key)?;
        if k.starts_with('"') {
            self.ser.out.push_str(&k);
        } else {
            escape_into(&mut self.ser.out, &k);
        }
        Ok(())
    }
    fn serialize_value<T: serde::Serialize + ?Sized>(&mut self, value: &T) -> Result<(), Error> {
        self.ser.out.push(':');
        value.serialize(&mut *self.ser)
    }
    fn end(self) -> Result<(), Error> {
        self.ser.out.push_str(self.close);
        Ok(())
    }
}

impl serde::ser::SerializeStruct for SeqSer<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_field<T: serde::Serialize + ?Sized>(
        &mut self,
        key: &'static str,
        value: &T,
    ) -> Result<(), Error> {
        self.comma();
        escape_into(&mut self.ser.out, key);
        self.ser.out.push(':');
        value.serialize(&mut *self.ser)
    }
    fn end(self) -> Result<(), Error> {
        self.ser.out.push_str(self.close);
        Ok(())
    }
}

impl serde::ser::SerializeStructVariant for SeqSer<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_field<T: serde::Serialize + ?Sized>(
        &mut self,
        key: &'static str,
        value: &T,
    ) -> Result<(), Error> {
        serde::ser::SerializeStruct::serialize_field(self, key, value)
    }
    fn end(self) -> Result<(), Error> {
        serde::ser::SerializeStruct::end(self)
    }
}
