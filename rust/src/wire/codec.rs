//! Codec selection and transport framing for the worker protocol.
//!
//! Every process-crossing message (`ParentMsg`/`WorkerMsg` on worker
//! pipes, job/context spool files) is encoded by a [`WireCodec`] and
//! carried as a length-prefixed frame (4-byte little-endian payload
//! length + payload). The frame layer is codec-agnostic: the payload is
//! compact binary by default ([`crate::wire::bin`]) and JSON text when
//! debugging with `FUTURIZE_WIRE_CODEC=json` (human-readable traces at
//! the cost of 3–6× the bytes).
//!
//! The codec is captured **once per backend instance** at construction
//! and forced onto spawned workers through the same environment
//! variable, so a parent and its workers can never disagree mid-stream.
//!
//! Byte accounting: [`WireCodec::encode`] records *logical* bytes (one
//! encode per message) and [`write_frame`] records *physical* bytes
//! (once per transport copy — a context broadcast to N workers costs N
//! physical copies of one logical encode). See [`crate::wire::stats`].

use std::io::{Read, Write};

/// Environment variable selecting the wire codec (`json` forces the
/// debug codec; anything else, or unset, selects binary).
pub const WIRE_CODEC_ENV: &str = "FUTURIZE_WIRE_CODEC";

/// Environment variable bounding the length a frame reader will accept
/// (bytes; plain integer). The 4-byte length prefix is otherwise
/// attacker-/corruption-controlled: a flipped bit in the header would
/// ask the reader to allocate up to 4 GiB before the decode even runs.
pub const MAX_FRAME_ENV: &str = "FUTURIZE_MAX_FRAME_BYTES";

/// Default frame-length cap: 256 MiB, aligned with the data-plane
/// cache budget (`FUTURIZE_CACHE_BYTES`) — the largest legitimate
/// frames are `CachePut` blobs, which that budget already bounds.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 256 << 20;

/// The active frame-length cap. Resolved from [`MAX_FRAME_ENV`] once
/// per process (readers run on hot paths and in tight loops; worker
/// processes inherit the parent's environment, so both sides of a
/// connection agree for the process lifetime).
pub fn max_frame_bytes() -> usize {
    static CAP: once_cell::sync::Lazy<usize> =
        once_cell::sync::Lazy::new(|| frame_cap_from_env(std::env::var(MAX_FRAME_ENV).ok()));
    *CAP
}

/// Parse an optional env override into a cap; 0 or garbage falls back
/// to the default (a zero cap would reject every frame, including the
/// handshake that could report the misconfiguration).
fn frame_cap_from_env(v: Option<String>) -> usize {
    v.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(DEFAULT_MAX_FRAME_BYTES)
}

/// The message-payload encoding used by a process transport.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireCodec {
    /// Compact binary ([`crate::wire::bin`]) — the default.
    Binary,
    /// Compact JSON ([`crate::wire::to_string`]) — human-readable debug
    /// transport, selected with `FUTURIZE_WIRE_CODEC=json`.
    Json,
}

impl WireCodec {
    /// Resolve the session-wide default from the environment.
    pub fn active() -> WireCodec {
        match std::env::var(WIRE_CODEC_ENV) {
            Ok(v) if v.eq_ignore_ascii_case("json") => WireCodec::Json,
            _ => WireCodec::Binary,
        }
    }

    /// The value to set [`WIRE_CODEC_ENV`] to when spawning a worker
    /// that must speak this codec.
    pub fn env_value(&self) -> &'static str {
        match self {
            WireCodec::Binary => "binary",
            WireCodec::Json => "json",
        }
    }

    /// Encode one protocol message; records the logical byte count.
    pub fn encode<T: serde::Serialize + ?Sized>(&self, value: &T) -> Result<Vec<u8>, String> {
        let bytes = match self {
            WireCodec::Binary => {
                super::bin::to_bytes(value).map_err(|e| e.to_string())?
            }
            WireCodec::Json => super::to_string(value).map_err(|e| e.to_string())?.into_bytes(),
        };
        super::stats::record_logical(bytes.len());
        Ok(bytes)
    }

    /// Decode one protocol message.
    pub fn decode<T: for<'a> serde::Deserialize<'a>>(&self, bytes: &[u8]) -> Result<T, String> {
        match self {
            WireCodec::Binary => super::bin::from_bytes(bytes).map_err(|e| e.to_string()),
            WireCodec::Json => {
                let s = std::str::from_utf8(bytes)
                    .map_err(|e| format!("non-UTF-8 JSON frame: {e}"))?;
                super::from_str(s).map_err(|e| e.to_string())
            }
        }
    }
}

/// Write one length-prefixed frame; records the physical byte count.
/// Header and payload are written back-to-back without building a
/// combined buffer — every transport has exactly one writer (serialized
/// by `&mut`), so frames cannot interleave and the copy would be pure
/// overhead (an N-worker context broadcast would otherwise re-copy the
/// whole payload N times).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> std::io::Result<()> {
    let len = u32::try_from(payload.len()).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, "wire frame over 4 GiB")
    })?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    super::stats::record_physical(4 + payload.len());
    Ok(())
}

/// Read one length-prefixed frame. Returns `Ok(None)` on a clean EOF
/// (no header bytes at all); a mid-frame EOF is an error, and so is a
/// length prefix over [`max_frame_bytes`] — a header that large is a
/// desynced or corrupt stream, and trusting it would commit a multi-GiB
/// allocation before the decode could fail. Callers already treat any
/// `Err` as the peer being dead (worker exits; parent supervises), so
/// the oversize path needs no new plumbing.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Option<Vec<u8>>> {
    read_frame_capped(r, max_frame_bytes())
}

/// [`read_frame`] with an explicit length cap (tests exercise caps
/// without touching the process-global environment).
pub fn read_frame_capped<R: Read>(r: &mut R, cap: usize) -> std::io::Result<Option<Vec<u8>>> {
    let mut hdr = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut hdr[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(None)
                } else {
                    Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "truncated wire frame header",
                    ))
                };
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    if len > cap {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("wire frame length {len} exceeds cap {cap} (protocol desync?)"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_over_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[0u8, 10, 13, 255]).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), vec![0u8, 10, 13, 255]);
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn oversize_length_prefix_is_a_desync_error() {
        // A corrupt header asking for more than the cap must fail fast,
        // before any payload allocation — not attempt a huge read.
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        buf.extend_from_slice(b"junk");
        let mut r = &buf[..];
        let err = read_frame_capped(&mut r, 1024).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("exceeds cap"), "{err}");
        // A frame exactly at the cap still passes.
        let mut buf = Vec::new();
        write_frame(&mut buf, &[7u8; 16]).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame_capped(&mut r, 16).unwrap().unwrap(), vec![7u8; 16]);
        // One past it does not.
        let mut r = &buf[..];
        assert!(read_frame_capped(&mut r, 15).is_err());
    }

    #[test]
    fn frame_cap_env_parsing() {
        assert_eq!(frame_cap_from_env(None), DEFAULT_MAX_FRAME_BYTES);
        assert_eq!(frame_cap_from_env(Some("1048576".into())), 1 << 20);
        assert_eq!(frame_cap_from_env(Some(" 4096 ".into())), 4096);
        // Garbage and the self-defeating zero fall back to the default.
        assert_eq!(frame_cap_from_env(Some("not-a-number".into())), DEFAULT_MAX_FRAME_BYTES);
        assert_eq!(frame_cap_from_env(Some("0".into())), DEFAULT_MAX_FRAME_BYTES);
        // The default stays aligned with the cache budget default.
        assert_eq!(DEFAULT_MAX_FRAME_BYTES, crate::backend::blobstore::DEFAULT_CACHE_BYTES);
    }

    #[test]
    fn truncated_frame_is_an_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"payload").unwrap();
        let mut r = &buf[..buf.len() - 2];
        assert!(read_frame(&mut r).is_err());
        let mut r = &buf[..2];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn both_codecs_roundtrip_protocol_messages() {
        let v = vec![(String::from("x"), 1.5f64), (String::from("y"), f64::INFINITY)];
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let bytes = codec.encode(&v).unwrap();
            let back: Vec<(String, f64)> = codec.decode(&bytes).unwrap();
            assert_eq!(back, v, "{codec:?}");
        }
    }

    #[test]
    fn binary_is_the_default_codec() {
        // The env override is exercised end-to-end by the multisession
        // tests; here we only pin the default.
        if std::env::var(WIRE_CODEC_ENV).is_err() {
            assert_eq!(WireCodec::active(), WireCodec::Binary);
        }
    }
}
