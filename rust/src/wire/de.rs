//! Self-describing JSON `serde::Deserializer`.

use serde::de::{
    DeserializeSeed, EnumAccess, IntoDeserializer, MapAccess, SeqAccess, VariantAccess, Visitor,
};

#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error: {}", self.0)
    }
}
impl std::error::Error for Error {}
impl serde::de::Error for Error {
    fn custom<T: std::fmt::Display>(msg: T) -> Self {
        Error(msg.to_string())
    }
}

/// Deserialize a value from JSON text.
pub fn from_str<'a, T: serde::Deserialize<'a>>(s: &'a str) -> Result<T, Error> {
    let mut de = De { input: s.as_bytes(), pos: 0 };
    let v = T::deserialize(&mut de)?;
    de.skip_ws();
    if de.pos != de.input.len() {
        return Err(Error(format!("trailing characters at byte {}", de.pos)));
    }
    Ok(v)
}

struct De<'a> {
    input: &'a [u8],
    pos: usize,
}

impl<'a> De<'a> {
    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.input.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.input.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn bump(&mut self) -> Result<u8, Error> {
        let c = self
            .input
            .get(self.pos)
            .copied()
            .ok_or_else(|| Error("unexpected end of input".into()))?;
        self.pos += 1;
        Ok(c)
    }

    fn expect(&mut self, c: u8) -> Result<(), Error> {
        self.skip_ws();
        let got = self.bump()?;
        if got != c {
            return Err(Error(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.pos - 1,
                got as char
            )));
        }
        Ok(())
    }

    fn expect_keyword(&mut self, kw: &str) -> Result<(), Error> {
        for &b in kw.as_bytes() {
            if self.bump()? != b {
                return Err(Error(format!("invalid literal (expected {kw})")));
            }
        }
        Ok(())
    }

    fn parse_string(&mut self) -> Result<String, Error> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.bump()?;
            match c {
                b'"' => break,
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump()?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| Error("bad \\u escape".into()))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(Error(format!("bad escape \\{}", other as char))),
                },
                // Multi-byte UTF-8: copy raw continuation bytes.
                c if c >= 0x80 => {
                    let start = self.pos - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    for _ in 1..len {
                        self.bump()?;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.input[start..start + len])
                            .map_err(|e| Error(e.to_string()))?,
                    );
                }
                c => out.push(c as char),
            }
        }
        Ok(out)
    }

    fn parse_number(&mut self) -> Result<f64, Error> {
        self.skip_ws();
        let start = self.pos;
        if matches!(self.input.get(self.pos), Some(b'-')) {
            self.pos += 1;
        }
        while matches!(
            self.input.get(self.pos),
            Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.input[start..self.pos])
            .map_err(|e| Error(e.to_string()))?;
        text.parse::<f64>().map_err(|e| Error(format!("bad number '{text}': {e}")))
    }
}

/// Non-finite float escape hatch (see ser.rs fmt_f64).
fn special_float(s: &str) -> Option<f64> {
    match s {
        "__f64_nan__" => Some(f64::NAN),
        "__f64_inf__" => Some(f64::INFINITY),
        "__f64_ninf__" => Some(f64::NEG_INFINITY),
        _ => None,
    }
}

impl<'de> serde::Deserializer<'de> for &mut De<'_> {
    type Error = Error;

    fn deserialize_any<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        match self.peek() {
            Some(b'n') => {
                self.expect_keyword("null")?;
                visitor.visit_unit()
            }
            Some(b't') => {
                self.expect_keyword("true")?;
                visitor.visit_bool(true)
            }
            Some(b'f') => {
                self.expect_keyword("false")?;
                visitor.visit_bool(false)
            }
            Some(b'"') => {
                let s = self.parse_string()?;
                if let Some(f) = special_float(&s) {
                    return visitor.visit_f64(f);
                }
                visitor.visit_string(s)
            }
            Some(b'[') => {
                self.expect(b'[')?;
                let v = visitor.visit_seq(Elems { de: self, first: true })?;
                self.expect(b']')?;
                Ok(v)
            }
            Some(b'{') => {
                self.expect(b'{')?;
                let v = visitor.visit_map(Fields { de: self, first: true })?;
                self.expect(b'}')?;
                Ok(v)
            }
            Some(_) => {
                let n = self.parse_number()?;
                if n == n.trunc() && n.abs() < 9.0e18 {
                    if n < 0.0 {
                        visitor.visit_i64(n as i64)
                    } else {
                        visitor.visit_u64(n as u64)
                    }
                } else {
                    visitor.visit_f64(n)
                }
            }
            None => Err(Error("unexpected end of input".into())),
        }
    }

    fn deserialize_option<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        if self.peek() == Some(b'n') {
            self.expect_keyword("null")?;
            visitor.visit_none()
        } else {
            visitor.visit_some(self)
        }
    }

    fn deserialize_f64<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        match self.peek() {
            Some(b'"') => {
                let s = self.parse_string()?;
                match special_float(&s) {
                    Some(f) => visitor.visit_f64(f),
                    None => Err(Error(format!("expected number, got \"{s}\""))),
                }
            }
            _ => visitor.visit_f64(self.parse_number()?),
        }
    }

    fn deserialize_f32<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        self.deserialize_f64(visitor)
    }

    fn deserialize_enum<V: Visitor<'de>>(
        self,
        _name: &'static str,
        _variants: &'static [&'static str],
        visitor: V,
    ) -> Result<V::Value, Error> {
        match self.peek() {
            // Unit variant: "Name"
            Some(b'"') => {
                let s = self.parse_string()?;
                visitor.visit_enum(s.into_deserializer())
            }
            // Data variant: {"Name": payload}
            Some(b'{') => {
                self.expect(b'{')?;
                let v = visitor.visit_enum(Enum { de: self })?;
                self.expect(b'}')?;
                Ok(v)
            }
            other => Err(Error(format!("expected enum, found {other:?}"))),
        }
    }

    serde::forward_to_deserialize_any! {
        bool i8 i16 i32 i64 i128 u8 u16 u32 u64 u128 char str string bytes
        byte_buf unit unit_struct newtype_struct seq tuple tuple_struct map
        struct identifier ignored_any
    }
}

struct Elems<'a, 'b> {
    de: &'a mut De<'b>,
    first: bool,
}

impl<'de> SeqAccess<'de> for Elems<'_, '_> {
    type Error = Error;
    fn next_element_seed<T: DeserializeSeed<'de>>(
        &mut self,
        seed: T,
    ) -> Result<Option<T::Value>, Error> {
        if self.de.peek() == Some(b']') {
            return Ok(None);
        }
        if !self.first {
            self.de.expect(b',')?;
        }
        self.first = false;
        if self.de.peek() == Some(b']') {
            return Err(Error("trailing comma in array".into()));
        }
        seed.deserialize(&mut *self.de).map(Some)
    }
}

struct Fields<'a, 'b> {
    de: &'a mut De<'b>,
    first: bool,
}

impl<'de> MapAccess<'de> for Fields<'_, '_> {
    type Error = Error;
    fn next_key_seed<K: DeserializeSeed<'de>>(
        &mut self,
        seed: K,
    ) -> Result<Option<K::Value>, Error> {
        if self.de.peek() == Some(b'}') {
            return Ok(None);
        }
        if !self.first {
            self.de.expect(b',')?;
        }
        self.first = false;
        let key = self.de.parse_string()?;
        seed.deserialize(key.into_deserializer()).map(Some)
    }
    fn next_value_seed<V: DeserializeSeed<'de>>(&mut self, seed: V) -> Result<V::Value, Error> {
        self.de.expect(b':')?;
        seed.deserialize(&mut *self.de)
    }
}

struct Enum<'a, 'b> {
    de: &'a mut De<'b>,
}

impl<'de, 'a, 'b> EnumAccess<'de> for Enum<'a, 'b> {
    type Error = Error;
    type Variant = Variant<'a, 'b>;
    fn variant_seed<V: DeserializeSeed<'de>>(
        self,
        seed: V,
    ) -> Result<(V::Value, Self::Variant), Error> {
        let name = self.de.parse_string()?;
        self.de.expect(b':')?;
        let v = seed.deserialize(name.into_deserializer())?;
        Ok((v, Variant { de: self.de }))
    }
}

struct Variant<'a, 'b> {
    de: &'a mut De<'b>,
}

impl<'de> VariantAccess<'de> for Variant<'_, '_> {
    type Error = Error;
    fn unit_variant(self) -> Result<(), Error> {
        self.de.expect_keyword("null")
    }
    fn newtype_variant_seed<T: DeserializeSeed<'de>>(self, seed: T) -> Result<T::Value, Error> {
        seed.deserialize(&mut *self.de)
    }
    fn tuple_variant<V: Visitor<'de>>(self, _len: usize, visitor: V) -> Result<V::Value, Error> {
        serde::Deserializer::deserialize_any(&mut *self.de, visitor)
    }
    fn struct_variant<V: Visitor<'de>>(
        self,
        _fields: &'static [&'static str],
        visitor: V,
    ) -> Result<V::Value, Error> {
        serde::Deserializer::deserialize_any(&mut *self.de, visitor)
    }
}
