//! `wire::bin` — the compact binary wire codec.
//!
//! A non-self-describing (bincode-style) serde codec used as the default
//! transport encoding for the worker protocol. Layout:
//!
//! - **bool** — one byte (`0`/`1`);
//! - **unsigned ints** (ids, lengths, enum variant tags, chars) —
//!   ULEB128 varints;
//! - **signed ints** — zigzag-mapped ULEB128 varints (small magnitudes,
//!   the common case for R integer vectors, stay 1–2 bytes);
//! - **f64/f32** — raw little-endian bits (8/4 bytes), so a
//!   `Vec<f64>` is a length prefix followed by a flat little-endian
//!   array and NaN/±Inf round-trip bit-exactly (no `"__f64_nan__"`
//!   tagging as in the JSON codec);
//! - **strings/bytes** — varint length + raw UTF-8/bytes;
//! - **Option** — one tag byte, then the value if present;
//! - **sequences/maps** — varint element count + elements;
//! - **tuples/structs** — fields in declaration order, no tags, no
//!   names (the count is statically known on both sides);
//! - **enums** — varint variant index + payload (externally tagged by
//!   *index*, compatible with the same derive-generated impls the JSON
//!   codec uses — both sides of the pipe are always the same build).
//!
//! Because the format is not self-describing, `deserialize_any` is
//! unsupported; every protocol type (including [`crate::wire::JsonValue`])
//! therefore uses derived, hint-driven impls.

use serde::de::{DeserializeSeed, EnumAccess, IntoDeserializer, MapAccess, SeqAccess, Visitor};

#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "binary wire codec error: {}", self.0)
    }
}
impl std::error::Error for Error {}
impl serde::ser::Error for Error {
    fn custom<T: std::fmt::Display>(msg: T) -> Self {
        Error(msg.to_string())
    }
}
impl serde::de::Error for Error {
    fn custom<T: std::fmt::Display>(msg: T) -> Self {
        Error(msg.to_string())
    }
}

/// Serialize any `Serialize` value to the compact binary form.
pub fn to_bytes<T: serde::Serialize + ?Sized>(value: &T) -> Result<Vec<u8>, Error> {
    let mut s = Ser { out: Vec::new() };
    value.serialize(&mut s)?;
    Ok(s.out)
}

/// Deserialize a value from the compact binary form. The whole input
/// must be consumed (a length-prefixed frame holds exactly one value).
pub fn from_bytes<'a, T: serde::Deserialize<'a>>(bytes: &'a [u8]) -> Result<T, Error> {
    let mut de = De { input: bytes, pos: 0 };
    let v = T::deserialize(&mut de)?;
    if de.pos != de.input.len() {
        return Err(Error(format!(
            "trailing bytes: consumed {} of {}",
            de.pos,
            de.input.len()
        )));
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Varint helpers (shared with `WireVal::approx_size`, which mirrors this
// codec's actual sizes).
// ---------------------------------------------------------------------------

pub(crate) fn put_uvarint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// Encoded size of a ULEB128 varint, in bytes.
pub(crate) fn uvarint_len(v: u64) -> usize {
    if v == 0 {
        1
    } else {
        (64 - v.leading_zeros() as usize).div_ceil(7)
    }
}

/// Zigzag-map a signed integer onto the unsigned varint space.
pub(crate) fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

pub(crate) fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

// ---------------------------------------------------------------------------
// Serializer
// ---------------------------------------------------------------------------

struct Ser {
    out: Vec<u8>,
}

pub struct Compound<'a> {
    ser: &'a mut Ser,
}

impl<'a> serde::Serializer for &'a mut Ser {
    type Ok = ();
    type Error = Error;
    type SerializeSeq = Compound<'a>;
    type SerializeTuple = Compound<'a>;
    type SerializeTupleStruct = Compound<'a>;
    type SerializeTupleVariant = Compound<'a>;
    type SerializeMap = Compound<'a>;
    type SerializeStruct = Compound<'a>;
    type SerializeStructVariant = Compound<'a>;

    fn is_human_readable(&self) -> bool {
        false
    }

    fn serialize_bool(self, v: bool) -> Result<(), Error> {
        self.out.push(v as u8);
        Ok(())
    }
    fn serialize_i8(self, v: i8) -> Result<(), Error> {
        self.serialize_i64(v as i64)
    }
    fn serialize_i16(self, v: i16) -> Result<(), Error> {
        self.serialize_i64(v as i64)
    }
    fn serialize_i32(self, v: i32) -> Result<(), Error> {
        self.serialize_i64(v as i64)
    }
    fn serialize_i64(self, v: i64) -> Result<(), Error> {
        put_uvarint(&mut self.out, zigzag(v));
        Ok(())
    }
    fn serialize_u8(self, v: u8) -> Result<(), Error> {
        self.serialize_u64(v as u64)
    }
    fn serialize_u16(self, v: u16) -> Result<(), Error> {
        self.serialize_u64(v as u64)
    }
    fn serialize_u32(self, v: u32) -> Result<(), Error> {
        self.serialize_u64(v as u64)
    }
    fn serialize_u64(self, v: u64) -> Result<(), Error> {
        put_uvarint(&mut self.out, v);
        Ok(())
    }
    fn serialize_f32(self, v: f32) -> Result<(), Error> {
        self.out.extend_from_slice(&v.to_le_bytes());
        Ok(())
    }
    fn serialize_f64(self, v: f64) -> Result<(), Error> {
        self.out.extend_from_slice(&v.to_le_bytes());
        Ok(())
    }
    fn serialize_char(self, v: char) -> Result<(), Error> {
        put_uvarint(&mut self.out, v as u64);
        Ok(())
    }
    fn serialize_str(self, v: &str) -> Result<(), Error> {
        put_uvarint(&mut self.out, v.len() as u64);
        self.out.extend_from_slice(v.as_bytes());
        Ok(())
    }
    fn serialize_bytes(self, v: &[u8]) -> Result<(), Error> {
        put_uvarint(&mut self.out, v.len() as u64);
        self.out.extend_from_slice(v);
        Ok(())
    }
    fn serialize_none(self) -> Result<(), Error> {
        self.out.push(0);
        Ok(())
    }
    fn serialize_some<T: serde::Serialize + ?Sized>(self, value: &T) -> Result<(), Error> {
        self.out.push(1);
        value.serialize(self)
    }
    fn serialize_unit(self) -> Result<(), Error> {
        Ok(())
    }
    fn serialize_unit_struct(self, _name: &'static str) -> Result<(), Error> {
        Ok(())
    }
    fn serialize_unit_variant(
        self,
        _name: &'static str,
        idx: u32,
        _variant: &'static str,
    ) -> Result<(), Error> {
        put_uvarint(&mut self.out, idx as u64);
        Ok(())
    }
    fn serialize_newtype_struct<T: serde::Serialize + ?Sized>(
        self,
        _name: &'static str,
        value: &T,
    ) -> Result<(), Error> {
        value.serialize(self)
    }
    fn serialize_newtype_variant<T: serde::Serialize + ?Sized>(
        self,
        _name: &'static str,
        idx: u32,
        _variant: &'static str,
        value: &T,
    ) -> Result<(), Error> {
        put_uvarint(&mut self.out, idx as u64);
        value.serialize(self)
    }
    fn serialize_seq(self, len: Option<usize>) -> Result<Compound<'a>, Error> {
        let len = len.ok_or_else(|| {
            Error("sequences of unknown length are unsupported".into())
        })?;
        put_uvarint(&mut self.out, len as u64);
        Ok(Compound { ser: self })
    }
    fn serialize_tuple(self, _len: usize) -> Result<Compound<'a>, Error> {
        Ok(Compound { ser: self })
    }
    fn serialize_tuple_struct(
        self,
        _name: &'static str,
        _len: usize,
    ) -> Result<Compound<'a>, Error> {
        Ok(Compound { ser: self })
    }
    fn serialize_tuple_variant(
        self,
        _name: &'static str,
        idx: u32,
        _variant: &'static str,
        _len: usize,
    ) -> Result<Compound<'a>, Error> {
        put_uvarint(&mut self.out, idx as u64);
        Ok(Compound { ser: self })
    }
    fn serialize_map(self, len: Option<usize>) -> Result<Compound<'a>, Error> {
        let len =
            len.ok_or_else(|| Error("maps of unknown length are unsupported".into()))?;
        put_uvarint(&mut self.out, len as u64);
        Ok(Compound { ser: self })
    }
    fn serialize_struct(self, _name: &'static str, _len: usize) -> Result<Compound<'a>, Error> {
        Ok(Compound { ser: self })
    }
    fn serialize_struct_variant(
        self,
        _name: &'static str,
        idx: u32,
        _variant: &'static str,
        _len: usize,
    ) -> Result<Compound<'a>, Error> {
        put_uvarint(&mut self.out, idx as u64);
        Ok(Compound { ser: self })
    }
}

impl serde::ser::SerializeSeq for Compound<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_element<T: serde::Serialize + ?Sized>(&mut self, value: &T) -> Result<(), Error> {
        value.serialize(&mut *self.ser)
    }
    fn end(self) -> Result<(), Error> {
        Ok(())
    }
}

impl serde::ser::SerializeTuple for Compound<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_element<T: serde::Serialize + ?Sized>(&mut self, value: &T) -> Result<(), Error> {
        value.serialize(&mut *self.ser)
    }
    fn end(self) -> Result<(), Error> {
        Ok(())
    }
}

impl serde::ser::SerializeTupleStruct for Compound<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_field<T: serde::Serialize + ?Sized>(&mut self, value: &T) -> Result<(), Error> {
        value.serialize(&mut *self.ser)
    }
    fn end(self) -> Result<(), Error> {
        Ok(())
    }
}

impl serde::ser::SerializeTupleVariant for Compound<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_field<T: serde::Serialize + ?Sized>(&mut self, value: &T) -> Result<(), Error> {
        value.serialize(&mut *self.ser)
    }
    fn end(self) -> Result<(), Error> {
        Ok(())
    }
}

impl serde::ser::SerializeMap for Compound<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_key<T: serde::Serialize + ?Sized>(&mut self, key: &T) -> Result<(), Error> {
        key.serialize(&mut *self.ser)
    }
    fn serialize_value<T: serde::Serialize + ?Sized>(&mut self, value: &T) -> Result<(), Error> {
        value.serialize(&mut *self.ser)
    }
    fn end(self) -> Result<(), Error> {
        Ok(())
    }
}

impl serde::ser::SerializeStruct for Compound<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_field<T: serde::Serialize + ?Sized>(
        &mut self,
        _key: &'static str,
        value: &T,
    ) -> Result<(), Error> {
        value.serialize(&mut *self.ser)
    }
    fn end(self) -> Result<(), Error> {
        Ok(())
    }
}

impl serde::ser::SerializeStructVariant for Compound<'_> {
    type Ok = ();
    type Error = Error;
    fn serialize_field<T: serde::Serialize + ?Sized>(
        &mut self,
        _key: &'static str,
        value: &T,
    ) -> Result<(), Error> {
        value.serialize(&mut *self.ser)
    }
    fn end(self) -> Result<(), Error> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Deserializer
// ---------------------------------------------------------------------------

struct De<'de> {
    input: &'de [u8],
    pos: usize,
}

impl<'de> De<'de> {
    fn take(&mut self, n: usize) -> Result<&'de [u8], Error> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.input.len())
            .ok_or_else(|| {
                Error(format!("unexpected end of input (want {n} bytes at {})", self.pos))
            })?;
        let s = &self.input[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn byte(&mut self) -> Result<u8, Error> {
        Ok(self.take(1)?[0])
    }

    fn uvarint(&mut self) -> Result<u64, Error> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.byte()?;
            // The 10th byte holds only bit 64 of the value: any higher
            // payload bit or a continuation bit is an overlong/overflowing
            // encoding and must error rather than silently lose bits.
            if shift >= 64 || (shift == 63 && b & 0xfe != 0) {
                return Err(Error("varint overflows u64".into()));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    fn ivarint(&mut self) -> Result<i64, Error> {
        Ok(unzigzag(self.uvarint()?))
    }

    fn str_slice(&mut self) -> Result<&'de str, Error> {
        let n = self.uvarint()? as usize;
        let bytes = self.take(n)?;
        std::str::from_utf8(bytes).map_err(|e| Error(format!("invalid UTF-8 string: {e}")))
    }
}

impl<'de> serde::Deserializer<'de> for &mut De<'de> {
    type Error = Error;

    fn is_human_readable(&self) -> bool {
        false
    }

    fn deserialize_any<V: Visitor<'de>>(self, _visitor: V) -> Result<V::Value, Error> {
        Err(Error("the binary codec is not self-describing (deserialize_any)".into()))
    }

    fn deserialize_ignored_any<V: Visitor<'de>>(self, _visitor: V) -> Result<V::Value, Error> {
        Err(Error("the binary codec cannot skip unknown fields".into()))
    }

    fn deserialize_bool<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        match self.byte()? {
            0 => visitor.visit_bool(false),
            1 => visitor.visit_bool(true),
            other => Err(Error(format!("invalid bool byte {other}"))),
        }
    }

    fn deserialize_i8<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let v = self.ivarint()?;
        visitor.visit_i64(v)
    }
    fn deserialize_i16<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let v = self.ivarint()?;
        visitor.visit_i64(v)
    }
    fn deserialize_i32<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let v = self.ivarint()?;
        visitor.visit_i64(v)
    }
    fn deserialize_i64<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let v = self.ivarint()?;
        visitor.visit_i64(v)
    }
    fn deserialize_u8<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let v = self.uvarint()?;
        visitor.visit_u64(v)
    }
    fn deserialize_u16<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let v = self.uvarint()?;
        visitor.visit_u64(v)
    }
    fn deserialize_u32<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let v = self.uvarint()?;
        visitor.visit_u64(v)
    }
    fn deserialize_u64<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let v = self.uvarint()?;
        visitor.visit_u64(v)
    }
    fn deserialize_f32<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let b = self.take(4)?;
        visitor.visit_f32(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn deserialize_f64<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let b = self.take(8)?;
        visitor.visit_f64(f64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
    fn deserialize_char<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let v = self.uvarint()?;
        let c = u32::try_from(v)
            .ok()
            .and_then(char::from_u32)
            .ok_or_else(|| Error(format!("invalid char scalar {v}")))?;
        visitor.visit_char(c)
    }
    fn deserialize_str<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let s = self.str_slice()?;
        visitor.visit_borrowed_str(s)
    }
    fn deserialize_string<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        self.deserialize_str(visitor)
    }
    fn deserialize_bytes<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let n = self.uvarint()? as usize;
        let bytes = self.take(n)?;
        visitor.visit_borrowed_bytes(bytes)
    }
    fn deserialize_byte_buf<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        self.deserialize_bytes(visitor)
    }
    fn deserialize_option<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        match self.byte()? {
            0 => visitor.visit_none(),
            1 => visitor.visit_some(self),
            other => Err(Error(format!("invalid option tag {other}"))),
        }
    }
    fn deserialize_unit<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        visitor.visit_unit()
    }
    fn deserialize_unit_struct<V: Visitor<'de>>(
        self,
        _name: &'static str,
        visitor: V,
    ) -> Result<V::Value, Error> {
        visitor.visit_unit()
    }
    fn deserialize_newtype_struct<V: Visitor<'de>>(
        self,
        _name: &'static str,
        visitor: V,
    ) -> Result<V::Value, Error> {
        visitor.visit_newtype_struct(self)
    }
    fn deserialize_seq<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let len = self.uvarint()? as usize;
        visitor.visit_seq(Elems { de: self, remaining: len })
    }
    fn deserialize_tuple<V: Visitor<'de>>(self, len: usize, visitor: V) -> Result<V::Value, Error> {
        visitor.visit_seq(Elems { de: self, remaining: len })
    }
    fn deserialize_tuple_struct<V: Visitor<'de>>(
        self,
        _name: &'static str,
        len: usize,
        visitor: V,
    ) -> Result<V::Value, Error> {
        visitor.visit_seq(Elems { de: self, remaining: len })
    }
    fn deserialize_map<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let len = self.uvarint()? as usize;
        visitor.visit_map(Pairs { de: self, remaining: len })
    }
    fn deserialize_struct<V: Visitor<'de>>(
        self,
        _name: &'static str,
        fields: &'static [&'static str],
        visitor: V,
    ) -> Result<V::Value, Error> {
        visitor.visit_seq(Elems { de: self, remaining: fields.len() })
    }
    fn deserialize_enum<V: Visitor<'de>>(
        self,
        _name: &'static str,
        _variants: &'static [&'static str],
        visitor: V,
    ) -> Result<V::Value, Error> {
        visitor.visit_enum(Variant { de: self })
    }
    fn deserialize_identifier<V: Visitor<'de>>(self, visitor: V) -> Result<V::Value, Error> {
        let v = self.uvarint()?;
        visitor.visit_u64(v)
    }
}

struct Elems<'a, 'de> {
    de: &'a mut De<'de>,
    remaining: usize,
}

impl<'de> SeqAccess<'de> for Elems<'_, 'de> {
    type Error = Error;
    fn next_element_seed<T: DeserializeSeed<'de>>(
        &mut self,
        seed: T,
    ) -> Result<Option<T::Value>, Error> {
        if self.remaining == 0 {
            return Ok(None);
        }
        self.remaining -= 1;
        seed.deserialize(&mut *self.de).map(Some)
    }
    fn size_hint(&self) -> Option<usize> {
        Some(self.remaining)
    }
}

struct Pairs<'a, 'de> {
    de: &'a mut De<'de>,
    remaining: usize,
}

impl<'de> MapAccess<'de> for Pairs<'_, 'de> {
    type Error = Error;
    fn next_key_seed<K: DeserializeSeed<'de>>(
        &mut self,
        seed: K,
    ) -> Result<Option<K::Value>, Error> {
        if self.remaining == 0 {
            return Ok(None);
        }
        self.remaining -= 1;
        seed.deserialize(&mut *self.de).map(Some)
    }
    fn next_value_seed<V: DeserializeSeed<'de>>(&mut self, seed: V) -> Result<V::Value, Error> {
        seed.deserialize(&mut *self.de)
    }
    fn size_hint(&self) -> Option<usize> {
        Some(self.remaining)
    }
}

struct Variant<'a, 'de> {
    de: &'a mut De<'de>,
}

impl<'de> EnumAccess<'de> for Variant<'_, 'de> {
    type Error = Error;
    type Variant = Self;
    fn variant_seed<V: DeserializeSeed<'de>>(self, seed: V) -> Result<(V::Value, Self), Error> {
        let idx = self.de.uvarint()?;
        let idx = u32::try_from(idx)
            .map_err(|_| Error(format!("enum variant tag {idx} out of range")))?;
        let val = seed.deserialize(idx.into_deserializer())?;
        Ok((val, self))
    }
}

impl<'de> serde::de::VariantAccess<'de> for Variant<'_, 'de> {
    type Error = Error;
    fn unit_variant(self) -> Result<(), Error> {
        Ok(())
    }
    fn newtype_variant_seed<T: DeserializeSeed<'de>>(self, seed: T) -> Result<T::Value, Error> {
        seed.deserialize(&mut *self.de)
    }
    fn tuple_variant<V: Visitor<'de>>(self, len: usize, visitor: V) -> Result<V::Value, Error> {
        visitor.visit_seq(Elems { de: self.de, remaining: len })
    }
    fn struct_variant<V: Visitor<'de>>(
        self,
        fields: &'static [&'static str],
        visitor: V,
    ) -> Result<V::Value, Error> {
        visitor.visit_seq(Elems { de: self.de, remaining: fields.len() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_edge_cases() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_uvarint(&mut buf, v);
            assert_eq!(buf.len(), uvarint_len(v), "len mismatch for {v}");
            let mut de = De { input: &buf, pos: 0 };
            assert_eq!(de.uvarint().unwrap(), v);
            assert_eq!(de.pos, buf.len());
        }
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, 1, -1, 63, -64, 1 << 40, i64::MIN, i64::MAX] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        // Small magnitudes stay small on the wire.
        assert_eq!(uvarint_len(zigzag(-1)), 1);
        assert_eq!(uvarint_len(zigzag(63)), 1);
    }

    #[test]
    fn overlong_varint_is_an_error_not_silent_truncation() {
        // 10th byte carrying payload above bit 64 would lose bits.
        let bad = [0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x7e];
        let mut de = De { input: &bad, pos: 0 };
        assert!(de.uvarint().is_err());
        // Continuation bit on the 10th byte is equally invalid.
        let bad = [0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x81, 0x00];
        let mut de = De { input: &bad, pos: 0 };
        assert!(de.uvarint().is_err());
        // u64::MAX itself (9 × 0xFF + 0x01) still decodes.
        let mut buf = Vec::new();
        put_uvarint(&mut buf, u64::MAX);
        let mut de = De { input: &buf, pos: 0 };
        assert_eq!(de.uvarint().unwrap(), u64::MAX);
    }

    #[test]
    fn truncated_input_is_an_error() {
        let bytes = to_bytes(&vec![1.0f64, 2.0]).unwrap();
        assert!(from_bytes::<Vec<f64>>(&bytes[..bytes.len() - 1]).is_err());
        assert!(from_bytes::<Vec<f64>>(&[]).is_err());
    }

    #[test]
    fn trailing_bytes_are_an_error() {
        let mut bytes = to_bytes(&42u64).unwrap();
        bytes.push(0);
        assert!(from_bytes::<u64>(&bytes).is_err());
    }

    #[test]
    fn doubles_are_flat_little_endian() {
        let xs = vec![1.5f64, -2.25, f64::NAN];
        let bytes = to_bytes(&xs).unwrap();
        // 1-byte length prefix + 8 bytes per element.
        assert_eq!(bytes.len(), 1 + 8 * xs.len());
        let back: Vec<f64> = from_bytes(&bytes).unwrap();
        assert_eq!(back[0], 1.5);
        assert_eq!(back[1], -2.25);
        assert!(back[2].is_nan());
    }
}
