//! Minimal JSON substrate (serde_json is unavailable offline).
//!
//! Provides three things, enough for the whole stack:
//!
//! - [`JsonValue`] — a dynamic JSON value (used for structured condition
//!   payloads such as progress amounts);
//! - [`to_string`] — serialize any `serde::Serialize` type to compact
//!   JSON (a full `serde::Serializer`);
//! - [`from_str`] — deserialize any `serde::Deserialize` type from JSON
//!   (a full self-describing `serde::Deserializer`).
//!
//! Enum representation matches serde's default externally-tagged form,
//! so the worker protocol is derive-compatible: unit variants are
//! strings, data variants are `{"Variant": ...}` objects.

mod de;
mod ser;
mod value;

pub use de::from_str;
pub use ser::to_string;
pub use value::JsonValue;

/// Serialized-byte accounting, used by benches and the dispatch tests to
/// assert the O(chunks × payload) → O(workers × payload) reduction the
/// shared-context protocol delivers. Every [`to_string`] records its
/// output length here; backends that re-send an already-serialized line
/// (the multisession context broadcast) record the extra copies
/// explicitly.
pub mod stats {
    use std::sync::atomic::{AtomicU64, Ordering};

    static BYTES: AtomicU64 = AtomicU64::new(0);

    /// Add `n` serialized bytes to the session-wide counter.
    pub fn record(n: usize) {
        BYTES.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Total serialized bytes since process start (or the last `reset`).
    pub fn bytes() -> u64 {
        BYTES.load(Ordering::Relaxed)
    }

    pub fn reset() {
        BYTES.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use serde_derive::{Deserialize, Serialize};

    #[derive(Serialize, Deserialize, Debug, PartialEq)]
    enum Kind {
        Unit,
        New(f64),
        Tup(i64, String),
        Struct { xs: Vec<f64>, name: Option<String> },
    }

    #[derive(Serialize, Deserialize, Debug, PartialEq)]
    struct Payload {
        id: u64,
        kind: Kind,
        tags: Vec<String>,
        nested: Option<Box<Payload>>,
    }

    fn roundtrip<T: serde::Serialize + for<'a> serde::Deserialize<'a> + PartialEq + std::fmt::Debug>(
        v: &T,
    ) {
        let s = to_string(v).unwrap();
        let back: T = from_str(&s).unwrap_or_else(|e| panic!("{e}: {s}"));
        assert_eq!(&back, v, "json was: {s}");
    }

    #[test]
    fn roundtrips_enums_and_structs() {
        roundtrip(&Kind::Unit);
        roundtrip(&Kind::New(2.5));
        roundtrip(&Kind::Tup(-3, "a \"quoted\" string\nwith newline".into()));
        roundtrip(&Kind::Struct { xs: vec![1.0, -2.5, 1e-8], name: None });
        roundtrip(&Payload {
            id: 42,
            kind: Kind::Struct { xs: vec![], name: Some("x".into()) },
            tags: vec!["a".into(), "b".into()],
            nested: Some(Box::new(Payload {
                id: 1,
                kind: Kind::Unit,
                tags: vec![],
                nested: None,
            })),
        });
    }

    #[test]
    fn roundtrips_collections() {
        roundtrip(&vec![1i64, 2, 3]);
        roundtrip(&vec![(Some("k".to_string()), 1.5f64)]);
        roundtrip(&Some(vec![true, false]));
        let m: std::collections::BTreeMap<String, i64> =
            [("a".to_string(), 1i64), ("b".to_string(), 2)].into_iter().collect();
        roundtrip(&m);
    }

    #[test]
    fn special_floats_and_unicode() {
        roundtrip(&vec![f64::MAX, f64::MIN_POSITIVE, 0.1 + 0.2]);
        roundtrip(&"héllo ✓ world".to_string());
    }

    #[test]
    fn json_value_roundtrip() {
        let v = JsonValue::Object(vec![
            ("amount".into(), JsonValue::Number(1.0)),
            ("total".into(), JsonValue::Number(100.0)),
            ("tags".into(), JsonValue::Array(vec![JsonValue::String("x".into())])),
            ("none".into(), JsonValue::Null),
            ("ok".into(), JsonValue::Bool(true)),
        ]);
        roundtrip(&v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_str::<Vec<i64>>("[1, 2,").is_err());
        assert!(from_str::<Vec<i64>>("{").is_err());
        assert!(from_str::<f64>("nope").is_err());
    }

    #[test]
    fn real_payload_roundtrips() {
        // The actual worker-protocol types.
        use crate::future_core::{TaskKind, TaskPayload};
        let t = TaskPayload {
            id: 9,
            kind: TaskKind::Expr {
                expr: crate::rlite::parse_expr("lapply(xs, function(x) x + 1)").unwrap(),
                globals: vec![(
                    "xs".into(),
                    crate::rlite::serialize::WireVal::Dbl(vec![1.0, 2.0], None),
                )],
            },
            time_scale: 0.5,
            capture_stdout: true,
        };
        let s = to_string(&t).unwrap();
        let back: TaskPayload = from_str(&s).unwrap();
        assert_eq!(back.id, 9);
        match back.kind {
            TaskKind::Expr { globals, .. } => assert_eq!(globals.len(), 1),
            other => panic!("{other:?}"),
        }
    }
}
