//! Wire-format substrate for the worker protocol.
//!
//! Two codecs share the same derive-based protocol types:
//!
//! - [`bin`] — the **default transport**: a compact, non-self-describing
//!   binary codec (length-prefixed little-endian doubles, varint-packed
//!   integers/lengths/tags). See [`bin`] for the exact layout.
//! - JSON — the original hand-rolled text codec ([`to_string`] /
//!   [`from_str`]; serde_json is unavailable offline), kept as a
//!   human-readable debug transport behind `FUTURIZE_WIRE_CODEC=json`
//!   and for structured-text uses (trace rendering, bench reports).
//!
//! [`codec`] selects between them per backend instance and owns the
//! length-prefixed frame layer every process transport uses.
//!
//! Enum representation matches serde's default externally-tagged form
//! in JSON (unit variants are strings, data variants are
//! `{"Variant": ...}` objects) and tagged-by-index in binary, so the
//! worker protocol is derive-compatible under both.

pub mod bin;
pub mod codec;
pub mod handshake;
mod de;
mod ser;
mod value;

pub use codec::WireCodec;
pub use de::from_str;
pub use ser::to_string;
pub use value::JsonValue;

/// Serialized-byte accounting, used by the benches and the dispatch
/// tests to assert the transport properties the protocol promises:
/// O(workers × payload) context shipping, ~0 bytes on the in-process
/// zero-copy fast path, and the binary codec's shrink over JSON.
///
/// Two counters are kept:
///
/// - **logical** bytes — one record per message *encode*
///   ([`WireCodec::encode`]), independent of how many transport copies
///   are made;
/// - **physical** bytes — one record per transport *write*
///   ([`codec::write_frame`], spool-file writes), so a context
///   broadcast to N workers costs N physical copies of one logical
///   encode.
///
/// Counters are **thread-local**. All encoding and transport writes of
/// a session happen on the thread driving it (worker subprocesses keep
/// their own, invisible counters), so concurrently running `cargo test`
/// threads no longer race each other's byte-bound assertions — each
/// test observes exactly the traffic of the session it drives.
pub mod stats {
    use std::cell::Cell;
    use std::sync::atomic::{AtomicU64, Ordering};

    thread_local! {
        static LOGICAL: Cell<u64> = const { Cell::new(0) };
        static PHYSICAL: Cell<u64> = const { Cell::new(0) };
    }

    /// Result frames arriving from worker processes, in bytes. This is
    /// the number reduction fusion shrinks: a fused reduction ships one
    /// constant-size partial per chunk instead of O(n) values. Unlike
    /// the encode-side counters this one is ticked on the per-worker
    /// *reader threads*, so it is process-global and atomic; tests that
    /// assert on it serialize behind a lock and call [`reset`] first.
    static RESULT: AtomicU64 = AtomicU64::new(0);

    /// Record `n` result-frame bytes read back from a worker process.
    pub fn record_result(n: usize) {
        RESULT.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Result bytes read from worker processes since start (or `reset`).
    pub fn result_bytes() -> u64 {
        RESULT.load(Ordering::Relaxed)
    }

    /// Record `n` encoded payload bytes (one per message encode).
    pub fn record_logical(n: usize) {
        LOGICAL.with(|c| c.set(c.get() + n as u64));
    }

    /// Record `n` bytes written to a process transport (one per copy).
    pub fn record_physical(n: usize) {
        PHYSICAL.with(|c| c.set(c.get() + n as u64));
    }

    /// Logical encoded bytes on this thread since start (or `reset`).
    pub fn logical_bytes() -> u64 {
        LOGICAL.with(|c| c.get())
    }

    /// Physical transport bytes on this thread since start (or `reset`).
    /// This is the headline "bytes crossing a process boundary" number;
    /// the in-process fast path keeps it at zero.
    pub fn bytes() -> u64 {
        PHYSICAL.with(|c| c.get())
    }

    // Data-plane cache counters (see `backend::blobstore`). Like
    // RESULT these are process-global atomics: puts/hits are recorded
    // on the parent dispatch thread, evictions inside worker processes
    // never reach the parent's counters, but the batchtools job
    // threads and tests run off the driving thread.
    static CACHE_PUTS: AtomicU64 = AtomicU64::new(0);
    static CACHE_PUT_BYTES: AtomicU64 = AtomicU64::new(0);
    static CACHE_HITS: AtomicU64 = AtomicU64::new(0);
    static CACHE_HIT_BYTES: AtomicU64 = AtomicU64::new(0);
    static CACHE_MISSES: AtomicU64 = AtomicU64::new(0);
    static CACHE_EVICT_BYTES: AtomicU64 = AtomicU64::new(0);

    /// Record one blob shipped to a worker (`CachePut`), `n` payload bytes.
    pub fn record_cache_put(n: u64) {
        CACHE_PUTS.fetch_add(1, Ordering::Relaxed);
        CACHE_PUT_BYTES.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one blob *not* shipped because the worker already holds
    /// it; `n` is the payload bytes saved.
    pub fn record_cache_hit(n: u64) {
        CACHE_HITS.fetch_add(1, Ordering::Relaxed);
        CACHE_HIT_BYTES.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one `CacheMiss` negative-ack (cold/evicted worker store).
    pub fn record_cache_miss() {
        CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` bytes evicted from a blob store under budget pressure.
    pub fn record_cache_evict(n: u64) {
        CACHE_EVICT_BYTES.fetch_add(n, Ordering::Relaxed);
    }

    pub fn cache_puts() -> u64 {
        CACHE_PUTS.load(Ordering::Relaxed)
    }

    pub fn cache_put_bytes() -> u64 {
        CACHE_PUT_BYTES.load(Ordering::Relaxed)
    }

    pub fn cache_hits() -> u64 {
        CACHE_HITS.load(Ordering::Relaxed)
    }

    pub fn cache_hit_bytes() -> u64 {
        CACHE_HIT_BYTES.load(Ordering::Relaxed)
    }

    pub fn cache_misses() -> u64 {
        CACHE_MISSES.load(Ordering::Relaxed)
    }

    pub fn cache_evict_bytes() -> u64 {
        CACHE_EVICT_BYTES.load(Ordering::Relaxed)
    }

    pub fn reset() {
        LOGICAL.with(|c| c.set(0));
        PHYSICAL.with(|c| c.set(0));
        RESULT.store(0, Ordering::Relaxed);
        CACHE_PUTS.store(0, Ordering::Relaxed);
        CACHE_PUT_BYTES.store(0, Ordering::Relaxed);
        CACHE_HITS.store(0, Ordering::Relaxed);
        CACHE_HIT_BYTES.store(0, Ordering::Relaxed);
        CACHE_MISSES.store(0, Ordering::Relaxed);
        CACHE_EVICT_BYTES.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use serde_derive::{Deserialize, Serialize};

    #[derive(Serialize, Deserialize, Debug, PartialEq)]
    enum Kind {
        Unit,
        New(f64),
        Tup(i64, String),
        Struct { xs: Vec<f64>, name: Option<String> },
    }

    #[derive(Serialize, Deserialize, Debug, PartialEq)]
    struct Payload {
        id: u64,
        kind: Kind,
        tags: Vec<String>,
        nested: Option<Box<Payload>>,
    }

    /// Roundtrip through *both* codecs — the protocol types must be
    /// representable identically under JSON and binary.
    fn roundtrip<T>(v: &T)
    where
        T: serde::Serialize + for<'a> serde::Deserialize<'a> + PartialEq + std::fmt::Debug,
    {
        let s = to_string(v).unwrap();
        let back: T = from_str(&s).unwrap_or_else(|e| panic!("{e}: {s}"));
        assert_eq!(&back, v, "json was: {s}");
        let b = bin::to_bytes(v).unwrap();
        let back: T = bin::from_bytes(&b).unwrap_or_else(|e| panic!("{e} (json form: {s})"));
        assert_eq!(&back, v, "binary roundtrip (json form: {s})");
    }

    #[test]
    fn roundtrips_enums_and_structs() {
        roundtrip(&Kind::Unit);
        roundtrip(&Kind::New(2.5));
        roundtrip(&Kind::Tup(-3, "a \"quoted\" string\nwith newline".into()));
        roundtrip(&Kind::Struct { xs: vec![1.0, -2.5, 1e-8], name: None });
        roundtrip(&Payload {
            id: 42,
            kind: Kind::Struct { xs: vec![], name: Some("x".into()) },
            tags: vec!["a".into(), "b".into()],
            nested: Some(Box::new(Payload {
                id: 1,
                kind: Kind::Unit,
                tags: vec![],
                nested: None,
            })),
        });
    }

    #[test]
    fn roundtrips_collections() {
        roundtrip(&vec![1i64, 2, 3]);
        roundtrip(&vec![(Some("k".to_string()), 1.5f64)]);
        roundtrip(&Some(vec![true, false]));
        let m: std::collections::BTreeMap<String, i64> =
            [("a".to_string(), 1i64), ("b".to_string(), 2)].into_iter().collect();
        roundtrip(&m);
    }

    #[test]
    fn special_floats_and_unicode() {
        roundtrip(&vec![f64::MAX, f64::MIN_POSITIVE, 0.1 + 0.2]);
        roundtrip(&"héllo ✓ world".to_string());
    }

    #[test]
    fn json_value_roundtrip() {
        let v = JsonValue::Object(vec![
            ("amount".into(), JsonValue::Number(1.0)),
            ("total".into(), JsonValue::Number(100.0)),
            ("tags".into(), JsonValue::Array(vec![JsonValue::String("x".into())])),
            ("none".into(), JsonValue::Null),
            ("ok".into(), JsonValue::Bool(true)),
        ]);
        roundtrip(&v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_str::<Vec<i64>>("[1, 2,").is_err());
        assert!(from_str::<Vec<i64>>("{").is_err());
        assert!(from_str::<f64>("nope").is_err());
    }

    #[test]
    fn real_payload_roundtrips() {
        // The actual worker-protocol types.
        use crate::future_core::{TaskKind, TaskPayload};
        let t = TaskPayload {
            id: 9,
            kind: TaskKind::Expr {
                expr: crate::rlite::parse_expr("lapply(xs, function(x) x + 1)").unwrap(),
                globals: vec![(
                    "xs".into(),
                    crate::rlite::serialize::WireVal::Dbl(vec![1.0, 2.0], None),
                )],
                nesting: Default::default(),
            },
            time_scale: 0.5,
            capture_stdout: true,
        };
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let bytes = codec.encode(&t).unwrap();
            let back: TaskPayload = codec.decode(&bytes).unwrap();
            assert_eq!(back.id, 9, "{codec:?}");
            match back.kind {
                TaskKind::Expr { globals, .. } => assert_eq!(globals.len(), 1, "{codec:?}"),
                other => panic!("{codec:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn stats_split_logical_and_physical() {
        stats::reset();
        let payload = WireCodec::Binary.encode(&vec![1.0f64; 16]).unwrap();
        assert_eq!(stats::logical_bytes(), payload.len() as u64);
        assert_eq!(stats::bytes(), 0, "no transport write yet");
        let mut sink = Vec::new();
        codec::write_frame(&mut sink, &payload).unwrap();
        codec::write_frame(&mut sink, &payload).unwrap();
        assert_eq!(stats::bytes(), 2 * (payload.len() as u64 + 4), "two physical copies");
        stats::reset();
        assert_eq!(stats::logical_bytes(), 0);
        assert_eq!(stats::bytes(), 0);
    }
}
