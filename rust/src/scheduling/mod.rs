//! Chunking and load balancing (`scheduling` / `chunk_size`, paper §2.4).
//!
//! Two policies:
//!
//! - [`ChunkPolicy::Static`] mirrors future.apply's semantics: by default
//!   each worker gets one chunk (`scheduling = 1`); `scheduling = k`
//!   makes ~k chunks per worker (finer-grained balancing at higher
//!   messaging cost); `chunk_size` overrides directly.
//! - [`ChunkPolicy::Adaptive`] is guided self-scheduling: early chunks
//!   are large (`remaining / (GUIDED_FACTOR × workers)` elements), later
//!   chunks decay geometrically down to `min_chunk`. Combined with the
//!   dispatch core's incremental submission this eliminates stragglers —
//!   a slow element only ever delays the (small, late) chunk it lands in
//!   — without paying per-element messaging cost for the whole input.
//!
//! Chunks are contiguous index ranges in both policies, so results
//! reassemble in input order regardless of completion order, and
//! `seed = TRUE` per-element RNG streams stay chunking-invariant.

/// How to split `n` elements over `workers` workers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ChunkPolicy {
    /// Pre-sized contiguous chunks (future.apply's `future.chunk.size` /
    /// `future.scheduling` semantics).
    Static {
        chunk_size: Option<usize>,
        /// Average number of chunks per worker (future.apply's
        /// `future.scheduling`). `f64::INFINITY` means one element per
        /// chunk.
        scheduling: f64,
    },
    /// Guided self-scheduling: chunk sizes decay from
    /// `n / (GUIDED_FACTOR × workers)` down to `min_chunk`.
    Adaptive {
        /// Smallest chunk the decay is allowed to reach (≥ 1).
        min_chunk: usize,
    },
}

/// Decay divisor for guided chunks: next chunk covers
/// `remaining / (GUIDED_FACTOR × workers)` elements.
pub const GUIDED_FACTOR: f64 = 2.0;

impl Default for ChunkPolicy {
    fn default() -> Self {
        ChunkPolicy::Static { chunk_size: None, scheduling: 1.0 }
    }
}

impl ChunkPolicy {
    /// The static policy as future.apply spells it.
    pub fn balanced(chunk_size: Option<usize>, scheduling: f64) -> Self {
        ChunkPolicy::Static { chunk_size, scheduling }
    }

    /// Guided self-scheduling with single-element minimum chunks.
    pub fn adaptive() -> Self {
        ChunkPolicy::Adaptive { min_chunk: 1 }
    }

    /// How many chunks the dispatch core keeps in flight (submitted but
    /// not yet `Done`) at once — the backpressure cap. Roughly
    /// `scheduling × workers`, but never below `2 × workers`
    /// (double-buffering: each worker has one chunk running and one
    /// queued, so a Done→refill round trip never starves the pool —
    /// this matters on high-latency backends like batchtools).
    pub fn in_flight_cap(&self, workers: usize) -> usize {
        let w = workers.max(1);
        match self {
            ChunkPolicy::Static { scheduling, .. } if scheduling.is_finite() => {
                (((w as f64) * scheduling.max(1.0)).ceil() as usize).max(2 * w)
            }
            _ => 2 * w,
        }
    }
}

/// Compute contiguous chunk ranges `[start, end)` covering `0..n`.
///
/// For [`ChunkPolicy::Adaptive`] the *sizes* are deterministic (they
/// depend only on `n` and `workers`, not on completion order); the
/// dynamic part of adaptive scheduling is that the dispatch core feeds
/// these chunks to the backend incrementally, so whichever worker frees
/// up first takes the next (smaller) chunk.
pub fn make_chunks(n: usize, workers: usize, policy: &ChunkPolicy) -> Vec<(usize, usize)> {
    if n == 0 {
        return vec![];
    }
    let workers = workers.max(1);
    match policy {
        ChunkPolicy::Static { chunk_size, scheduling } => {
            let n_chunks = match chunk_size {
                Some(cs) => n.div_ceil((*cs).max(1)),
                None => {
                    if scheduling.is_infinite() {
                        n
                    } else {
                        let target = (workers as f64 * scheduling.max(0.0)).round() as usize;
                        target.clamp(1, n)
                    }
                }
            };
            let n_chunks = n_chunks.clamp(1, n);
            // Balanced split: first (n % n_chunks) chunks get one extra element.
            let base = n / n_chunks;
            let extra = n % n_chunks;
            let mut out = Vec::with_capacity(n_chunks);
            let mut start = 0;
            for i in 0..n_chunks {
                let len = base + usize::from(i < extra);
                out.push((start, start + len));
                start += len;
            }
            debug_assert_eq!(start, n);
            out
        }
        ChunkPolicy::Adaptive { min_chunk } => {
            let min_chunk = (*min_chunk).max(1);
            let divisor = (workers as f64 * GUIDED_FACTOR).max(1.0);
            let mut out = Vec::new();
            let mut start = 0;
            while start < n {
                let remaining = n - start;
                let guided = ((remaining as f64) / divisor).ceil() as usize;
                // min_chunk floor first, then cap at what's left — the
                // tail remainder may be smaller than min_chunk.
                let len = guided.max(min_chunk).min(remaining);
                out.push((start, start + len));
                start += len;
            }
            debug_assert_eq!(start, n);
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_one_chunk_per_worker() {
        let chunks = make_chunks(100, 4, &ChunkPolicy::default());
        assert_eq!(chunks.len(), 4);
        assert_eq!(chunks[0], (0, 25));
        assert_eq!(chunks[3], (75, 100));
    }

    #[test]
    fn chunk_size_overrides() {
        let chunks =
            make_chunks(10, 4, &ChunkPolicy::Static { chunk_size: Some(2), scheduling: 1.0 });
        assert_eq!(chunks.len(), 5);
        assert!(chunks.iter().all(|(s, e)| e - s == 2));
    }

    #[test]
    fn infinite_scheduling_is_one_element_chunks() {
        let chunks = make_chunks(
            7,
            2,
            &ChunkPolicy::Static { chunk_size: None, scheduling: f64::INFINITY },
        );
        assert_eq!(chunks.len(), 7);
    }

    #[test]
    fn covers_all_elements_exactly_once() {
        for n in [1usize, 2, 3, 7, 100, 101] {
            for w in [1usize, 2, 3, 8] {
                for sched in [0.5, 1.0, 2.0, 4.0] {
                    let chunks = make_chunks(
                        n,
                        w,
                        &ChunkPolicy::Static { chunk_size: None, scheduling: sched },
                    );
                    let total: usize = chunks.iter().map(|(s, e)| e - s).sum();
                    assert_eq!(total, n, "n={n} w={w} sched={sched}");
                    for win in chunks.windows(2) {
                        assert_eq!(win[0].1, win[1].0, "contiguous");
                    }
                }
            }
        }
    }

    #[test]
    fn adaptive_covers_all_elements_exactly_once() {
        for n in [1usize, 2, 3, 7, 48, 100, 101, 1000] {
            for w in [1usize, 2, 4, 8] {
                for min_chunk in [1usize, 2, 5] {
                    let chunks = make_chunks(n, w, &ChunkPolicy::Adaptive { min_chunk });
                    let total: usize = chunks.iter().map(|(s, e)| e - s).sum();
                    assert_eq!(total, n, "n={n} w={w} min={min_chunk}");
                    for win in chunks.windows(2) {
                        assert_eq!(win[0].1, win[1].0, "contiguous");
                    }
                }
            }
        }
    }

    #[test]
    fn adaptive_chunk_sizes_decay() {
        let chunks = make_chunks(128, 4, &ChunkPolicy::adaptive());
        let sizes: Vec<usize> = chunks.iter().map(|(s, e)| e - s).collect();
        // Guided: monotonically non-increasing, starting at n/(2·workers).
        assert_eq!(sizes[0], 16);
        for win in sizes.windows(2) {
            assert!(win[0] >= win[1], "sizes must decay: {sizes:?}");
        }
        // Tail reaches the minimum chunk size.
        assert_eq!(*sizes.last().unwrap(), 1);
        // Far fewer messages than per-element chunking.
        assert!(chunks.len() < 128 / 2, "guided should need ≪ n chunks: {}", chunks.len());
    }

    #[test]
    fn adaptive_respects_min_chunk() {
        let chunks = make_chunks(100, 4, &ChunkPolicy::Adaptive { min_chunk: 5 });
        // Every chunk except possibly the last is ≥ min_chunk.
        for (i, (s, e)) in chunks.iter().enumerate() {
            if i + 1 < chunks.len() {
                assert!(e - s >= 5, "chunk {i} too small: {chunks:?}");
            }
        }
    }

    #[test]
    fn in_flight_cap_tracks_scheduling() {
        // Every policy double-buffers per worker at minimum.
        assert_eq!(ChunkPolicy::default().in_flight_cap(4), 8);
        assert_eq!(
            ChunkPolicy::Static { chunk_size: None, scheduling: 2.0 }.in_flight_cap(4),
            8
        );
        assert_eq!(
            ChunkPolicy::Static { chunk_size: None, scheduling: 4.0 }.in_flight_cap(4),
            16
        );
        assert_eq!(
            ChunkPolicy::Static { chunk_size: None, scheduling: f64::INFINITY }.in_flight_cap(4),
            8
        );
        assert_eq!(ChunkPolicy::adaptive().in_flight_cap(4), 8);
        assert!(
            ChunkPolicy::Static { chunk_size: Some(1), scheduling: 0.1 }.in_flight_cap(4) >= 8
        );
    }

    #[test]
    fn more_chunks_than_elements_clamps() {
        let chunks = make_chunks(2, 8, &ChunkPolicy::default());
        assert_eq!(chunks.len(), 2);
    }

    #[test]
    fn empty_input_no_chunks() {
        assert!(make_chunks(0, 4, &ChunkPolicy::default()).is_empty());
        assert!(make_chunks(0, 4, &ChunkPolicy::adaptive()).is_empty());
    }
}
