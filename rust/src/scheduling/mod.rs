//! Chunking and load balancing (`scheduling` / `chunk_size`, paper §2.4).
//!
//! Mirrors future.apply's semantics: by default each worker gets one
//! chunk (`scheduling = 1`); `scheduling = k` makes ~k chunks per worker
//! (finer-grained balancing at higher messaging cost); `chunk_size`
//! overrides directly. Chunks are contiguous index ranges so results
//! reassemble in input order regardless of completion order.

/// How to split `n` elements over `workers` workers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChunkPolicy {
    pub chunk_size: Option<usize>,
    /// Average number of chunks per worker (future.apply's
    /// `future.scheduling`). `f64::INFINITY` means one element per chunk.
    pub scheduling: f64,
}

impl Default for ChunkPolicy {
    fn default() -> Self {
        ChunkPolicy { chunk_size: None, scheduling: 1.0 }
    }
}

/// Compute contiguous chunk ranges `[start, end)` covering `0..n`.
pub fn make_chunks(n: usize, workers: usize, policy: &ChunkPolicy) -> Vec<(usize, usize)> {
    if n == 0 {
        return vec![];
    }
    let workers = workers.max(1);
    let n_chunks = match policy.chunk_size {
        Some(cs) => n.div_ceil(cs.max(1)),
        None => {
            if policy.scheduling.is_infinite() {
                n
            } else {
                let target = (workers as f64 * policy.scheduling.max(0.0)).round() as usize;
                target.clamp(1, n)
            }
        }
    };
    let n_chunks = n_chunks.clamp(1, n);
    // Balanced split: first (n % n_chunks) chunks get one extra element.
    let base = n / n_chunks;
    let extra = n % n_chunks;
    let mut out = Vec::with_capacity(n_chunks);
    let mut start = 0;
    for i in 0..n_chunks {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_one_chunk_per_worker() {
        let chunks = make_chunks(100, 4, &ChunkPolicy::default());
        assert_eq!(chunks.len(), 4);
        assert_eq!(chunks[0], (0, 25));
        assert_eq!(chunks[3], (75, 100));
    }

    #[test]
    fn chunk_size_overrides() {
        let chunks =
            make_chunks(10, 4, &ChunkPolicy { chunk_size: Some(2), scheduling: 1.0 });
        assert_eq!(chunks.len(), 5);
        assert!(chunks.iter().all(|(s, e)| e - s == 2));
    }

    #[test]
    fn infinite_scheduling_is_one_element_chunks() {
        let chunks =
            make_chunks(7, 2, &ChunkPolicy { chunk_size: None, scheduling: f64::INFINITY });
        assert_eq!(chunks.len(), 7);
    }

    #[test]
    fn covers_all_elements_exactly_once() {
        for n in [1usize, 2, 3, 7, 100, 101] {
            for w in [1usize, 2, 3, 8] {
                for sched in [0.5, 1.0, 2.0, 4.0] {
                    let chunks =
                        make_chunks(n, w, &ChunkPolicy { chunk_size: None, scheduling: sched });
                    let total: usize = chunks.iter().map(|(s, e)| e - s).sum();
                    assert_eq!(total, n, "n={n} w={w} sched={sched}");
                    for win in chunks.windows(2) {
                        assert_eq!(win[0].1, win[1].0, "contiguous");
                    }
                }
            }
        }
    }

    #[test]
    fn more_chunks_than_elements_clamps() {
        let chunks = make_chunks(2, 8, &ChunkPolicy::default());
        assert_eq!(chunks.len(), 2);
    }

    #[test]
    fn empty_input_no_chunks() {
        assert!(make_chunks(0, 4, &ChunkPolicy::default()).is_empty());
    }
}
