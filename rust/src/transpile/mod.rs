//! `futurize()` — the paper's contribution: a source-to-source transpiler
//! from sequential map-reduce calls to their future-ecosystem
//! equivalents.
//!
//! Implementation follows paper §3.2 step by step:
//!
//! 1. **Expression capture** — `futurize` is a special form; it receives
//!    the unevaluated [`Expr`] of its first argument (R's `substitute()`).
//! 2. **Function identification** — the call head is resolved to a
//!    `(namespace, name)` pair via the builtin registry (explicit
//!    `pkg::fn` qualification wins).
//! 3. **Transpiler lookup** — an internal registry maps `(namespace,
//!    name)` to a transpiler.
//! 4. **Expression rewriting** — the transpiler rewrites the call,
//!    mapping the *unified* options (`seed`, `chunk_size`, `scheduling`,
//!    `stdout`, `conditions`, `globals`, `packages`) onto the target
//!    API's own conventions (`future.seed=`, `furrr_options()`,
//!    `.options.future=`, domain sub-APIs).
//! 5. **Evaluation** — the rewritten expression is evaluated in the
//!    caller's environment.
//!
//! Wrapper expressions (`{}`, `()`, `local()`, `I()`, `identity()`,
//! `suppressMessages()`, `suppressWarnings()`) are unwrapped per §3.3 —
//! the transpiler descends to the transpilable call and rewrites it *in
//! place*, preserving the wrappers.

pub mod analysis;
pub mod fusion;
pub mod reduce;
pub mod registry;

use std::collections::HashMap;

use once_cell::sync::Lazy;

use crate::future_core::driver::{MapOptions, SeedOption};
use crate::rlite::ast::{Arg, Expr};
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::deparse::deparse;
use crate::rlite::diag::LintMode;
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::RVal;
use crate::scheduling::ChunkPolicy;

/// The unified options surface of `futurize()` (paper §2.4).
#[derive(Clone, Debug)]
pub struct FuturizeOptions {
    pub seed: Option<SeedSetting>,
    pub chunk_size: Option<usize>,
    pub scheduling: Option<f64>,
    /// `scheduling = "adaptive"`: guided self-scheduling (large chunks
    /// early, small chunks late) via the streaming dispatch core.
    pub adaptive: Option<bool>,
    pub stdout: Option<bool>,
    pub conditions: Option<bool>,
    /// Fail fast: cancel queued chunks on the first worker error.
    pub stop_on_error: Option<bool>,
    /// Worker-crash resilience: how many times a chunk lost with a dead
    /// worker may be resubmitted before the call raises a
    /// `FutureError`-style condition. Default 0 = fail fast (R future's
    /// unreliable-worker behaviour).
    pub retries: Option<u32>,
    /// `globals = FALSE` disables automatic identification (advanced).
    pub globals: Option<bool>,
    /// Extra packages to require on workers.
    pub packages: Vec<String>,
    /// `eval = FALSE`: return the transpiled call unevaluated (deparsed).
    pub eval: bool,
    /// Reduction-fusion mode: `"exact"` (default — only
    /// reassociation-exact combines fold worker-side), `"assoc"`
    /// (accept reassociated floating-point folding, documented ULP
    /// contract), `"off"` (never fold worker-side).
    pub reduce: Option<String>,
    /// The recognized reduction head/combine symbol (set by the
    /// transpiler's enclosing-call recognition, carried to the target
    /// API as `future.reduce.op`).
    pub reduce_op: Option<String>,
    /// `Reduce(f, <map>)` form: the fused result must come back wrapped
    /// in a length-1 list so the kept outer `Reduce` is an identity.
    pub reduce_wrap: bool,
    /// Parallel-safety analyzer mode: `"warn"` (default), `"error"`
    /// (promote findings to a classed condition before dispatch) or
    /// `"off"`. `FUTURIZE_LINT` overrides per call.
    pub lint: Option<String>,
    /// Data-plane cache mode: `"auto"` (default — oversized exports and
    /// the frozen element vector ship as content-addressed blobs, once
    /// per worker) or `"off"`. `FUTURIZE_NO_CACHE=1` overrides per
    /// process.
    pub cache: Option<String>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SeedSetting {
    True,
    False,
    Value(u64),
}

impl Default for FuturizeOptions {
    fn default() -> Self {
        FuturizeOptions {
            seed: None,
            chunk_size: None,
            scheduling: None,
            adaptive: None,
            stdout: None,
            conditions: None,
            stop_on_error: None,
            retries: None,
            globals: None,
            packages: vec![],
            eval: true,
            reduce: None,
            reduce_op: None,
            reduce_wrap: false,
            lint: None,
            cache: None,
        }
    }
}

impl FuturizeOptions {
    /// Distill into execution options, given the per-function default for
    /// `seed` (e.g. `replicate()`/`times()` default to `seed = TRUE`,
    /// paper §4.1/§4.3).
    pub fn to_map_options(&self, seed_default: bool) -> MapOptions {
        let seed = match self.seed {
            Some(SeedSetting::True) => SeedOption::True,
            Some(SeedSetting::Value(v)) => SeedOption::Seed(v),
            Some(SeedSetting::False) => SeedOption::False,
            None => {
                if seed_default {
                    SeedOption::True
                } else {
                    SeedOption::False
                }
            }
        };
        let policy = if self.adaptive.unwrap_or(false) {
            ChunkPolicy::adaptive()
        } else {
            ChunkPolicy::Static {
                chunk_size: self.chunk_size,
                scheduling: self.scheduling.unwrap_or(1.0),
            }
        };
        let reduce = self.reduce_spec();
        let mut lint = crate::rlite::diag::LintSettings {
            mode: self.lint.as_deref().and_then(LintMode::parse).unwrap_or_default(),
            assoc_requested: self.reduce.as_deref() == Some("assoc"),
            reduce_op: self.reduce_op.clone(),
            nonassoc_combine: None,
            reduce_rejected: None,
        };
        if let Some(op) = &self.reduce_op {
            if reduce.is_none() && self.reduce.as_deref() != Some("off") {
                reduce::note_plan_rejected_catalog();
                lint.reduce_rejected =
                    Some(format!("'{op}' is not in the worker-side fold catalog"));
            }
        }
        MapOptions {
            seed,
            policy,
            stdout: self.stdout.unwrap_or(true),
            conditions: self.conditions.unwrap_or(true),
            stop_on_error: self.stop_on_error.unwrap_or(false),
            retries: self.retries.unwrap_or(0),
            reduce,
            lint,
            cache: self.cache.as_deref() != Some("off"),
        }
    }

    /// The reduction-fusion request distilled from the recognized op
    /// marker and the user's `reduce =` mode.
    pub fn reduce_spec(&self) -> Option<reduce::ReduceSpec> {
        if self.reduce.as_deref() == Some("off") {
            return None;
        }
        let op = reduce::ReduceOp::parse(self.reduce_op.as_deref()?)?;
        Some(reduce::ReduceSpec {
            plan: reduce::ReducePlan { op, assoc: self.reduce.as_deref() == Some("assoc") },
            wrap: self.reduce_wrap,
        })
    }
}

/// A transpiler: rewrite one call per the unified options.
pub type TranspilerFn = fn(&Expr, &FuturizeOptions) -> Result<Expr, String>;

pub(crate) static TRANSPILERS: Lazy<HashMap<(&'static str, &'static str), TranspilerFn>> =
    Lazy::new(registry::build);

pub fn register_builtins(r: &mut Reg) {
    r.special("futurize", "futurize", futurize_fn);
    r.normal("futurize", "futurize_supported_packages", supported_packages_fn);
    r.normal("futurize", "futurize_supported_functions", supported_functions_fn);
    r.normal("furrr", "furrr_options", furrr_options_fn);
}

/// The `futurize()` special form.
fn futurize_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    // Global toggle: futurize(TRUE) / futurize(FALSE) (paper §2.1).
    if args.len() == 1 && args[0].name.is_none() {
        if let Expr::Bool(b) = args[0].value {
            i.futurize_enabled = b;
            return Ok(RVal::scalar_bool(b));
        }
    }
    let Some(first) = args.first().filter(|a| a.name.is_none()) else {
        return Err(Signal::error("futurize: nothing to futurize"));
    };
    let opts = parse_options(i, &args[1..], env)?;

    if !i.futurize_enabled {
        // Disabled: pass through as if `|> futurize()` were absent.
        return i.eval(&first.value, env);
    }

    let rewritten = transpile_expr(&first.value, &opts).map_err(Signal::error)?;
    if !opts.eval {
        return Ok(RVal::scalar_str(deparse(&rewritten)));
    }
    i.eval(&rewritten, env)
}

/// Parse the unified option arguments of a `futurize()` call.
fn parse_options(i: &mut Interp, args: &[Arg], env: &EnvRef) -> Result<FuturizeOptions, Signal> {
    let mut o = FuturizeOptions::default();
    for a in args {
        let Some(name) = a.name.as_deref() else {
            return Err(Signal::error(
                "futurize: unexpected unnamed argument (options must be named)",
            ));
        };
        let v = i.eval(&a.value, env)?;
        match name {
            "seed" => {
                o.seed = Some(match &v {
                    RVal::Lgl(b) if !b.vals.is_empty() => {
                        if b.vals[0] {
                            SeedSetting::True
                        } else {
                            SeedSetting::False
                        }
                    }
                    other => SeedSetting::Value(other.as_i64().map_err(Signal::error)? as u64),
                });
            }
            "chunk_size" => o.chunk_size = Some(v.as_usize().map_err(Signal::error)?),
            "scheduling" => match v.as_str().ok().as_deref() {
                Some("adaptive") => o.adaptive = Some(true),
                Some(other) => {
                    return Err(Signal::error(format!(
                        "futurize: scheduling must be a number or \"adaptive\", got \"{other}\""
                    )))
                }
                None => o.scheduling = Some(v.as_f64().map_err(Signal::error)?),
            },
            "stdout" => o.stdout = Some(v.as_bool().map_err(Signal::error)?),
            "conditions" => o.conditions = Some(v.as_bool().map_err(Signal::error)?),
            "stop_on_error" => o.stop_on_error = Some(v.as_bool().map_err(Signal::error)?),
            "retries" => o.retries = Some(v.as_usize().map_err(Signal::error)? as u32),
            "globals" => o.globals = Some(v.as_bool().map_err(Signal::error)?),
            "packages" => o.packages = v.as_str_vec().map_err(Signal::error)?,
            "eval" => o.eval = v.as_bool().map_err(Signal::error)?,
            "reduce" => match v.as_str().ok().as_deref() {
                Some(m @ ("exact" | "assoc" | "off")) => o.reduce = Some(m.to_string()),
                other => {
                    return Err(Signal::error(format!(
                        "futurize: reduce must be \"exact\", \"assoc\" or \"off\", got {other:?}"
                    )))
                }
            },
            "lint" => match v.as_str().ok().as_deref() {
                Some(m @ ("warn" | "error" | "off")) => o.lint = Some(m.to_string()),
                other => {
                    return Err(Signal::error(format!(
                        "futurize: lint must be \"warn\", \"error\" or \"off\", got {other:?}"
                    )))
                }
            },
            "cache" => match v.as_str().ok().as_deref() {
                Some(m @ ("auto" | "off")) => o.cache = Some(m.to_string()),
                other => {
                    return Err(Signal::error(format!(
                        "futurize: cache must be \"auto\" or \"off\", got {other:?}"
                    )))
                }
            },
            other => {
                return Err(Signal::error(format!("futurize: unknown option '{other}'")))
            }
        }
    }
    Ok(o)
}

/// Wrappers the transpiler descends through (paper §3.3).
const UNWRAPPABLE: &[&str] =
    &["(", "local", "I", "identity", "suppressMessages", "suppressWarnings"];

/// Transpile `expr`, descending through wrapper constructs and rewriting
/// the innermost transpilable call in place.
pub fn transpile_expr(expr: &Expr, opts: &FuturizeOptions) -> Result<Expr, String> {
    // Enclosing-reduction recognition: `sum(lapply(...))`,
    // `Reduce(min, lapply(...))` and friends futurize the inner map and
    // mark it with the reduction so workers can fold slices locally
    // (`reduce = "off"` still transpiles this way — the marker is
    // ignored at dispatch time).
    if let Some(rewritten) = transpile_reduction(expr, opts)? {
        return Ok(rewritten);
    }
    // Direct hit?
    if let Some(t) = lookup_transpiler(expr) {
        return t(expr, opts);
    }
    // Unwrap one level and recurse, preserving the wrapper.
    match expr {
        Expr::Block(stmts) if !stmts.is_empty() => {
            let mut out = stmts.clone();
            let last = out.len() - 1;
            out[last] = transpile_expr(&out[last], opts)?;
            Ok(Expr::Block(out))
        }
        Expr::Call { func, args } if !args.is_empty() => {
            let head = match func.as_ref() {
                Expr::Sym(s) => Some(s.as_str()),
                Expr::Ns { name, .. } => Some(name.as_str()),
                _ => None,
            };
            match head {
                Some(h) if UNWRAPPABLE.contains(&h) => {
                    let mut new_args = args.clone();
                    new_args[0].value = transpile_expr(&args[0].value, opts)?;
                    Ok(Expr::Call { func: func.clone(), args: new_args })
                }
                Some(h) => Err(format!(
                    "futurize: don't know how to futurize '{h}()'; see futurize_supported_packages()"
                )),
                None => Err(format!(
                    "futurize: cannot futurize expression: {}",
                    deparse(expr)
                )),
            }
        }
        other => Err(format!("futurize: cannot futurize expression: {}", deparse(other))),
    }
}

/// Reduction heads recognized over a transpilable map call. The outer
/// call is *kept* in the rewritten source — it normalizes the fused
/// partial exactly (`sum` of a folded scalar is that scalar; `length`
/// measures the dummy) and provides the exact legacy semantics whenever
/// the map falls back to shipping full results.
const REDUCE_HEADS: &[&str] = &["sum", "prod", "mean", "min", "max", "any", "all", "length"];

/// Pairwise folds recognized in the `Reduce(f, <map>)` form.
const REDUCE_FOLDS: &[&str] = &["+", "*", "min", "max", "c"];

/// Map heads whose futurized targets understand the reduction markers.
const REDUCIBLE_MAPS: &[&str] = &["lapply", "sapply", "map", "map_dbl"];

/// Recognize a reduction enclosing a transpilable map call and rewrite
/// the inner map with `future.reduce.*` markers, keeping the enclosing
/// call in place. Returns `None` when `expr` is not such a shape.
fn transpile_reduction(expr: &Expr, opts: &FuturizeOptions) -> Result<Option<Expr>, String> {
    let Expr::Call { func, args } = expr else { return Ok(None) };
    let Expr::Sym(head) = func.as_ref() else { return Ok(None) };

    // sum(<map>) / sum(unlist(<map>)) and friends.
    if REDUCE_HEADS.contains(&head.as_str()) && args.len() == 1 && args[0].name.is_none() {
        // Descend through an `unlist()` wrapper (kept, like the head).
        let (map_expr, through_unlist) = match &args[0].value {
            Expr::Call { func: f2, args: a2 }
                if matches!(f2.as_ref(), Expr::Sym(s) if s == "unlist")
                    && a2.len() == 1
                    && a2[0].name.is_none() =>
            {
                (&a2[0].value, true)
            }
            v => (v, false),
        };
        if !is_reducible_map(map_expr) {
            return Ok(None);
        }
        let mut inner = transpile_expr(map_expr, opts)?;
        push_reduce_markers(&mut inner, head, false);
        let body =
            if through_unlist { Expr::call("unlist", vec![Arg::pos(inner)]) } else { inner };
        return Ok(Some(Expr::Call { func: func.clone(), args: vec![Arg::pos(body)] }));
    }

    // Reduce(f, <map>) with a recognized fold symbol and no init/
    // accumulate arguments. The outer `Reduce` is kept: the fused path
    // hands it the folded value wrapped in a length-1 list (a single
    // element is returned verbatim), while fallback paths hand it the
    // full result list for the exact legacy fold — including when `f`
    // was shadowed by a user function.
    if head.as_str() == "Reduce" && args.len() == 2 && args.iter().all(|a| a.name.is_none()) {
        let Expr::Sym(op) = &args[0].value else { return Ok(None) };
        if !REDUCE_FOLDS.contains(&op.as_str()) || !is_reducible_map(&args[1].value) {
            return Ok(None);
        }
        let mut inner = transpile_expr(&args[1].value, opts)?;
        push_reduce_markers(&mut inner, op, true);
        return Ok(Some(Expr::Call {
            func: func.clone(),
            args: vec![args[0].clone(), Arg::pos(inner)],
        }));
    }

    Ok(None)
}

fn is_reducible_map(expr: &Expr) -> bool {
    matches!(expr.call_name(), Some(n) if REDUCIBLE_MAPS.contains(&n))
        && lookup_transpiler(expr).is_some()
}

fn push_reduce_markers(call: &mut Expr, op: &str, wrap: bool) {
    if let Expr::Call { args, .. } = call {
        args.push(Arg::named("future.reduce.op", Expr::Str(op.to_string())));
        if wrap {
            args.push(Arg::named("future.reduce.wrap", Expr::Bool(true)));
        }
    }
}

/// Step 2 + 3: identify the function and look up its transpiler.
fn lookup_transpiler(expr: &Expr) -> Option<&'static TranspilerFn> {
    let name = expr.call_name()?;
    let ns = match expr.call_namespace() {
        Some(ns) => ns.to_string(),
        None => crate::rlite::builtins::namespace_of(name)?.to_string(),
    };
    // `Box::leak`-free lookup: registry keys are 'static strs; match on
    // string content.
    TRANSPILERS
        .iter()
        .find(|((p, n), _)| *p == ns && *n == name)
        .map(|(_, f)| f)
}

/// Is `(pkg, name)` transpilable? (Used by coverage tests.)
pub fn is_supported(pkg: &str, name: &str) -> bool {
    TRANSPILERS.keys().any(|(p, n)| *p == pkg && *n == name)
}

/// All packages with at least one registered transpiler, sorted —
/// `futurize_supported_packages()` in the paper.
pub fn supported_packages() -> Vec<&'static str> {
    let mut pkgs: Vec<&'static str> = TRANSPILERS.keys().map(|(p, _)| *p).collect();
    pkgs.sort();
    pkgs.dedup();
    pkgs
}

/// All supported functions in a package, sorted.
pub fn supported_functions(pkg: &str) -> Vec<&'static str> {
    let mut fns: Vec<&'static str> =
        TRANSPILERS.keys().filter(|(p, _)| *p == pkg).map(|(_, n)| *n).collect();
    fns.sort();
    fns
}

fn supported_packages_fn(_i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    Ok(RVal::chr(supported_packages().iter().map(|s| s.to_string()).collect()))
}

fn supported_functions_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let pkg = args.bind(&["package"]).req(0, "package")?.as_str().map_err(Signal::error)?;
    Ok(RVal::chr(supported_functions(&pkg).iter().map(|s| s.to_string()).collect()))
}

/// `furrr_options(seed = , chunk_size = , scheduling = )` — furrr's own
/// options object, produced by the transpiler when targeting furrr.
fn furrr_options_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut l = crate::rlite::value::RList::default();
    for (name, v) in &args.items {
        if let Some(n) = name {
            l.set(n, v.clone());
        }
    }
    l.class = Some("furrr_options".into());
    Ok(RVal::List(l))
}

// ---------------------------------------------------------------------------
// Shared option-mapping helpers used by the registry's transpilers.
// ---------------------------------------------------------------------------

/// Append `future.*`-style options (future.apply's convention).
pub(crate) fn future_dot_args(opts: &FuturizeOptions, args: &mut Vec<Arg>) {
    if let Some(seed) = opts.seed {
        args.push(Arg::named("future.seed", seed_expr(seed)));
    }
    if let Some(cs) = opts.chunk_size {
        args.push(Arg::named("future.chunk.size", Expr::Num(cs as f64)));
    }
    if let Some(s) = opts.scheduling {
        args.push(Arg::named("future.scheduling", Expr::Num(s)));
    }
    if opts.adaptive.unwrap_or(false) {
        args.push(Arg::named("future.scheduling", Expr::Str("adaptive".into())));
    }
    if let Some(b) = opts.stdout {
        args.push(Arg::named("future.stdout", Expr::Bool(b)));
    }
    if let Some(b) = opts.conditions {
        args.push(Arg::named("future.conditions", Expr::Bool(b)));
    }
    if let Some(b) = opts.stop_on_error {
        args.push(Arg::named("future.stop.on.error", Expr::Bool(b)));
    }
    if let Some(n) = opts.retries {
        args.push(Arg::named("future.retries", Expr::Num(n as f64)));
    }
    if !opts.packages.is_empty() {
        args.push(Arg::named("future.packages", packages_expr(&opts.packages)));
    }
    if let Some(r) = &opts.reduce {
        args.push(Arg::named("future.reduce", Expr::Str(r.clone())));
    }
    if let Some(l) = &opts.lint {
        args.push(Arg::named("future.lint", Expr::Str(l.clone())));
    }
    if let Some(c) = &opts.cache {
        args.push(Arg::named("future.cache", Expr::Str(c.clone())));
    }
}

/// Append `.options = furrr_options(...)` (furrr's convention).
pub(crate) fn furrr_option_args(opts: &FuturizeOptions, args: &mut Vec<Arg>) {
    let mut inner: Vec<Arg> = Vec::new();
    if let Some(seed) = opts.seed {
        inner.push(Arg::named("seed", seed_expr(seed)));
    }
    if let Some(cs) = opts.chunk_size {
        inner.push(Arg::named("chunk_size", Expr::Num(cs as f64)));
    }
    if let Some(s) = opts.scheduling {
        inner.push(Arg::named("scheduling", Expr::Num(s)));
    }
    if opts.adaptive.unwrap_or(false) {
        inner.push(Arg::named("scheduling", Expr::Str("adaptive".into())));
    }
    if let Some(b) = opts.stdout {
        inner.push(Arg::named("stdout", Expr::Bool(b)));
    }
    if let Some(b) = opts.conditions {
        inner.push(Arg::named("conditions", Expr::Bool(b)));
    }
    if let Some(b) = opts.stop_on_error {
        inner.push(Arg::named("stop_on_error", Expr::Bool(b)));
    }
    if let Some(n) = opts.retries {
        inner.push(Arg::named("retries", Expr::Num(n as f64)));
    }
    if !opts.packages.is_empty() {
        inner.push(Arg::named("packages", packages_expr(&opts.packages)));
    }
    if let Some(r) = &opts.reduce {
        inner.push(Arg::named("reduce", Expr::Str(r.clone())));
    }
    if let Some(l) = &opts.lint {
        inner.push(Arg::named("lint", Expr::Str(l.clone())));
    }
    if let Some(c) = &opts.cache {
        inner.push(Arg::named("cache", Expr::Str(c.clone())));
    }
    if !inner.is_empty() {
        args.push(Arg::named(".options", Expr::ns_call("furrr", "furrr_options", inner)));
    }
}

/// Append `.options.future = list(...)` (doFuture's `%dofuture%`
/// convention) to a foreach() call's arguments.
pub(crate) fn dofuture_option_args(opts: &FuturizeOptions, args: &mut Vec<Arg>) {
    let mut inner: Vec<Arg> = Vec::new();
    if let Some(seed) = opts.seed {
        inner.push(Arg::named("seed", seed_expr(seed)));
    }
    if let Some(cs) = opts.chunk_size {
        inner.push(Arg::named("chunk.size", Expr::Num(cs as f64)));
    }
    if let Some(s) = opts.scheduling {
        inner.push(Arg::named("scheduling", Expr::Num(s)));
    }
    if opts.adaptive.unwrap_or(false) {
        inner.push(Arg::named("scheduling", Expr::Str("adaptive".into())));
    }
    if let Some(b) = opts.stdout {
        inner.push(Arg::named("stdout", Expr::Bool(b)));
    }
    if let Some(b) = opts.conditions {
        inner.push(Arg::named("conditions", Expr::Bool(b)));
    }
    if let Some(b) = opts.stop_on_error {
        inner.push(Arg::named("stop.on.error", Expr::Bool(b)));
    }
    if let Some(n) = opts.retries {
        inner.push(Arg::named("retries", Expr::Num(n as f64)));
    }
    if !opts.packages.is_empty() {
        inner.push(Arg::named("packages", packages_expr(&opts.packages)));
    }
    if let Some(r) = &opts.reduce {
        inner.push(Arg::named("reduce", Expr::Str(r.clone())));
    }
    if let Some(l) = &opts.lint {
        inner.push(Arg::named("lint", Expr::Str(l.clone())));
    }
    if let Some(c) = &opts.cache {
        inner.push(Arg::named("cache", Expr::Str(c.clone())));
    }
    if !inner.is_empty() {
        args.push(Arg::named(".options.future", Expr::call("list", inner)));
    }
}

/// Append `.futurize_opts = list(...)` (the internal sub-API the domain
/// packages consume; analogous to boot's parallel/ncpus/cl or mgcv's
/// cluster argument, which futurize hides).
pub(crate) fn domain_option_args(opts: &FuturizeOptions, args: &mut Vec<Arg>) {
    let mut inner: Vec<Arg> = Vec::new();
    if let Some(seed) = opts.seed {
        inner.push(Arg::named("seed", seed_expr(seed)));
    }
    if let Some(cs) = opts.chunk_size {
        inner.push(Arg::named("chunk.size", Expr::Num(cs as f64)));
    }
    if let Some(s) = opts.scheduling {
        inner.push(Arg::named("scheduling", Expr::Num(s)));
    }
    if opts.adaptive.unwrap_or(false) {
        inner.push(Arg::named("scheduling", Expr::Str("adaptive".into())));
    }
    if let Some(b) = opts.stop_on_error {
        inner.push(Arg::named("stop.on.error", Expr::Bool(b)));
    }
    if let Some(n) = opts.retries {
        inner.push(Arg::named("retries", Expr::Num(n as f64)));
    }
    if let Some(l) = &opts.lint {
        inner.push(Arg::named("lint", Expr::Str(l.clone())));
    }
    if let Some(c) = &opts.cache {
        inner.push(Arg::named("cache", Expr::Str(c.clone())));
    }
    args.push(Arg::named(".futurize_opts", Expr::call("list", inner)));
}

fn seed_expr(seed: SeedSetting) -> Expr {
    match seed {
        SeedSetting::True => Expr::Bool(true),
        SeedSetting::False => Expr::Bool(false),
        SeedSetting::Value(v) => Expr::Num(v as f64),
    }
}

fn packages_expr(pkgs: &[String]) -> Expr {
    Expr::call(
        "c",
        pkgs.iter().map(|p| Arg::pos(Expr::Str(p.clone()))).collect(),
    )
}

/// Parse an options value produced by the option-mapping helpers back into
/// [`FuturizeOptions`] — used by the target implementations
/// (future_lapply's `future.*` args, furrr's `.options`, `%dofuture%`'s
/// `.options.future`, the domains' `.futurize_opts`).
pub fn options_from_pairs(pairs: &[(String, RVal)]) -> FuturizeOptions {
    let mut o = FuturizeOptions::default();
    apply_option_pairs(&mut o, pairs);
    o
}

/// Fold option pairs into existing options — for callers with two
/// option channels (furrr's `.options` list plus the transpiler's
/// `future.reduce.*` marker arguments).
pub fn apply_option_pairs(o: &mut FuturizeOptions, pairs: &[(String, RVal)]) {
    for (name, v) in pairs {
        let key = name.trim_start_matches("future.").replace(['.', '-'], "_");
        match key.as_str() {
            "seed" => {
                o.seed = Some(match v {
                    RVal::Lgl(b) if !b.vals.is_empty() && b.vals[0] => SeedSetting::True,
                    RVal::Lgl(_) => SeedSetting::False,
                    other => SeedSetting::Value(other.as_i64().unwrap_or(0) as u64),
                })
            }
            "chunk_size" => o.chunk_size = v.as_usize().ok(),
            "scheduling" => match v.as_str().ok().as_deref() {
                Some("adaptive") => o.adaptive = Some(true),
                Some(_) => {}
                None => o.scheduling = v.as_f64().ok(),
            },
            "stdout" => o.stdout = v.as_bool().ok(),
            "conditions" => o.conditions = v.as_bool().ok(),
            "stop_on_error" => o.stop_on_error = v.as_bool().ok(),
            "retries" => o.retries = v.as_usize().ok().map(|n| n as u32),
            "packages" => o.packages = v.as_str_vec().unwrap_or_default(),
            "reduce" => o.reduce = v.as_str().ok(),
            "reduce_op" => o.reduce_op = v.as_str().ok(),
            "reduce_wrap" => o.reduce_wrap = v.as_bool().unwrap_or(false),
            "lint" => o.lint = v.as_str().ok(),
            "cache" => o.cache = v.as_str().ok(),
            _ => {}
        }
    }
}

/// Extract option pairs from a named-list RVal (furrr_options result,
/// `.options.future` list, `.futurize_opts` list).
pub fn options_from_value(v: &RVal) -> FuturizeOptions {
    match v {
        RVal::List(l) => {
            let pairs: Vec<(String, RVal)> = l
                .names
                .iter()
                .flatten()
                .cloned()
                .zip(l.vals.iter().cloned())
                .collect();
            options_from_pairs(&pairs)
        }
        _ => FuturizeOptions::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::eval::Interp;
    use crate::rlite::parse_expr;

    /// Transpile `src` with `opts` (unified options text) and return the
    /// deparsed rewritten call via `eval = FALSE`.
    fn transpiled_with(src: &str, opts: &str) -> String {
        let mut i = Interp::new();
        let program = if opts.is_empty() {
            format!("{src} |> futurize(eval = FALSE)")
        } else {
            format!("{src} |> futurize(eval = FALSE, {opts})")
        };
        let v = i.eval_program(&program).unwrap_or_else(|e| panic!("{src}: {e:?}"));
        v.as_str().unwrap()
    }

    fn transpiled(src: &str) -> String {
        transpiled_with(src, "")
    }

    #[test]
    fn lapply_transpiles_to_future_lapply() {
        let mut i = Interp::new();
        i.eval_program("xs <- 1:3\nfcn <- function(x) x").unwrap();
        let got = {
            let v = i
                .eval_program("lapply(xs, fcn) |> futurize(eval = FALSE)")
                .unwrap();
            v.as_str().unwrap()
        };
        assert_eq!(got, "future.apply::future_lapply(xs, fcn)");
    }

    #[test]
    fn options_map_to_future_dot_convention() {
        let got = transpiled_with("lapply(xs, fcn)", "seed = TRUE, chunk_size = 2");
        assert!(got.contains("future.seed = TRUE"), "{got}");
        assert!(got.contains("future.chunk.size = 2"), "{got}");
    }

    #[test]
    fn map_transpiles_to_furrr_with_options() {
        let got = transpiled_with("map(xs, fcn)", "seed = TRUE");
        assert!(got.starts_with("furrr::future_map(xs, fcn"), "{got}");
        assert!(got.contains("furrr::furrr_options(seed = TRUE)"), "{got}");
    }

    #[test]
    fn foreach_do_transpiles_to_dofuture() {
        let got = transpiled("foreach(x = xs) %do% { f(x) }");
        assert!(got.contains("%dofuture%"), "{got}");
    }

    #[test]
    fn unwraps_suppress_messages() {
        let got = transpiled("{ lapply(xs, fcn) } |> suppressMessages()");
        // The wrapper chain is preserved around the rewritten call.
        assert!(got.contains("suppressMessages"), "{got}");
        assert!(got.contains("future_lapply"), "{got}");
    }

    #[test]
    fn unwraps_local_blocks() {
        let got = transpiled("local({ p <- 1\nlapply(xs, fcn) })");
        assert!(got.contains("local"), "{got}");
        assert!(got.contains("future_lapply"), "{got}");
        assert!(got.contains("p <- 1"), "{got}");
    }

    #[test]
    fn unsupported_function_errors_helpfully() {
        let mut i = Interp::new();
        let err = i.eval_program("print(1) |> futurize()").unwrap_err();
        match err {
            Signal::Error(c) => {
                assert!(c.message.contains("don't know how to futurize"), "{}", c.message)
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn global_toggle_passes_through() {
        let mut i = Interp::new();
        let v = i
            .eval_program(
                "futurize(FALSE)\nxs <- 1:3\nr <- lapply(xs, function(x) x * 2) |> futurize()\nfuturize(TRUE)\nunlist(r)",
            )
            .unwrap();
        assert_eq!(v.as_dbl_vec().unwrap(), vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn supported_packages_matches_paper_table() {
        let pkgs = supported_packages();
        for expected in [
            "base", "BiocParallel", "boot", "caret", "crossmap", "foreach", "glmnet", "lme4",
            "mgcv", "plyr", "purrr", "stats", "tm",
        ] {
            assert!(pkgs.contains(&expected), "missing {expected}: {pkgs:?}");
        }
    }

    #[test]
    fn stop_on_error_and_adaptive_map_through() {
        let got = transpiled_with(
            "lapply(xs, fcn)",
            "stop_on_error = TRUE, scheduling = \"adaptive\"",
        );
        assert!(got.contains("future.stop.on.error = TRUE"), "{got}");
        assert!(got.contains("adaptive"), "{got}");
        // And the round trip back into unified options.
        let o = options_from_pairs(&[
            ("future.stop.on.error".into(), crate::rlite::value::RVal::scalar_bool(true)),
            ("future.scheduling".into(), crate::rlite::value::RVal::scalar_str("adaptive")),
        ]);
        assert_eq!(o.stop_on_error, Some(true));
        assert_eq!(o.adaptive, Some(true));
        let mo = o.to_map_options(false);
        assert!(mo.stop_on_error);
        assert_eq!(mo.policy, crate::scheduling::ChunkPolicy::adaptive());
    }

    #[test]
    fn retries_maps_through_every_convention() {
        // future.apply convention.
        let got = transpiled_with("lapply(xs, fcn)", "retries = 2");
        assert!(got.contains("future.retries = 2"), "{got}");
        // furrr convention.
        let got = transpiled_with("map(xs, fcn)", "retries = 1");
        assert!(got.contains("retries = 1"), "{got}");
        // Round trip back into unified options and MapOptions.
        let o = options_from_pairs(&[(
            "future.retries".into(),
            crate::rlite::value::RVal::scalar_dbl(2.0),
        )]);
        assert_eq!(o.retries, Some(2));
        let mo = o.to_map_options(false);
        assert_eq!(mo.retries, 2);
        // Default is fail-fast.
        assert_eq!(FuturizeOptions::default().to_map_options(false).retries, 0);
    }

    #[test]
    fn namespaced_calls_transpile() {
        let got = transpiled("purrr::map(xs, fcn)");
        assert!(got.starts_with("furrr::future_map"), "{got}");
    }

    #[test]
    fn replicate_defaults_seed_true() {
        // §4.1: futurize() defaults to seed = TRUE for replicate().
        let got = transpiled("replicate(100, rnorm(10))");
        assert!(got.contains("future.seed = TRUE"), "{got}");
    }

    #[test]
    fn parse_expr_roundtrip_of_transpiled_output() {
        let got = transpiled_with("lapply(xs, fcn)", "seed = TRUE");
        assert!(parse_expr(&got).is_ok(), "{got}");
    }

    #[test]
    fn reduction_heads_futurize_the_inner_map() {
        let got = transpiled("sum(lapply(xs, fcn))");
        assert_eq!(
            got,
            "sum(future.apply::future_lapply(xs, fcn, future.reduce.op = \"sum\"))"
        );
        let got = transpiled("mean(unlist(sapply(xs, fcn)))");
        assert!(got.starts_with("mean(unlist(future.apply::future_sapply("), "{got}");
        assert!(got.contains("future.reduce.op = \"mean\""), "{got}");
        let got = transpiled("length(map(xs, fcn))");
        assert!(got.contains("future.reduce.op = \"length\""), "{got}");
    }

    #[test]
    fn reduce_fold_form_keeps_outer_reduce_and_wraps() {
        let got = transpiled("Reduce(min, lapply(xs, fcn))");
        assert!(got.starts_with("Reduce(min, future.apply::future_lapply("), "{got}");
        assert!(got.contains("future.reduce.op = \"min\""), "{got}");
        assert!(got.contains("future.reduce.wrap = TRUE"), "{got}");
        // Backtick-quoted operator symbols are recognized too.
        let got = transpiled("Reduce(`+`, lapply(xs, fcn))");
        assert!(got.contains("future.reduce.op = \"+\""), "{got}");
        // An `init` argument defeats recognition: plain transpile error
        // for the unsupported `Reduce` head.
        let mut i = Interp::new();
        let err =
            i.eval_program("Reduce(min, lapply(xs, fcn), 0) |> futurize(eval = FALSE)");
        assert!(err.is_err());
    }

    #[test]
    fn reduce_mode_round_trips_to_map_options() {
        let got = transpiled_with("sum(lapply(xs, fcn))", "reduce = \"assoc\"");
        assert!(got.contains("future.reduce = \"assoc\""), "{got}");

        let o = options_from_pairs(&[
            ("future.reduce".into(), RVal::scalar_str("assoc")),
            ("future.reduce.op".into(), RVal::scalar_str("sum")),
        ]);
        let spec = o.reduce_spec().unwrap();
        assert_eq!(spec.plan.op, reduce::ReduceOp::Sum);
        assert!(spec.plan.assoc);
        assert!(!spec.wrap);

        // "off" kills the plan even with a recognized op marker.
        let o = options_from_pairs(&[
            ("future.reduce".into(), RVal::scalar_str("off")),
            ("future.reduce.op".into(), RVal::scalar_str("sum")),
        ]);
        assert!(o.reduce_spec().is_none());

        // The wrap marker survives the round trip.
        let o = options_from_pairs(&[
            ("future.reduce.op".into(), RVal::scalar_str("c")),
            ("future.reduce.wrap".into(), RVal::scalar_bool(true)),
        ]);
        let spec = o.reduce_spec().unwrap();
        assert_eq!(spec.plan.op, reduce::ReduceOp::Concat);
        assert!(!spec.plan.assoc);
        assert!(spec.wrap);
    }
}
