//! AOT kernel fusion: recognize map-body shapes at context-freeze time
//! and dispatch matched chunks to native kernels (ISSUE 6 tentpole).
//!
//! The futurize contract is that users declare *what* to parallelize
//! and the runtime chooses *how* — which licenses executing a
//! recognized map body as a fused native kernel, as long as results
//! stay bit-identical to the interpreted path. When the parent freezes
//! a map context ([`maybe_recognize`], called from `run_map`), the
//! closure body is pattern-matched against a small catalog:
//!
//! - **elementwise** — arbitrary arithmetic expression trees over the
//!   scalar element and captured scalars (`x * 2 + 1`,
//!   `3 * x^2 + sqrt(a) * x`, ...), compiled to a postorder
//!   [`ElemOp`] program for `runtime::elementwise::eval`;
//! - **boot_stat** — the boot weighted-ratio statistic
//!   `sum(x * w) / sum(u * w)` over a weight-vector element, with `x`
//!   and `u` resolvable captured vectors (bare symbols or `d$field`
//!   list accesses), dispatched to `kernels::weighted_ratio`;
//! - **gram** — `hlo_gram(x, y)` cross-product blocks with a captured
//!   response vector, dispatched to `kernels::gram`;
//! - **ridge** — `hlo_ridge(x, y, lam)` with a captured response vector
//!   and constant penalty: the gram half plus the native Cholesky
//!   solve (`kernels::ridge_solve`), fused end to end.
//!
//! A match produces a [`KernelPlan`] that ships inside `TaskContext`;
//! workers run matched slices through [`KernelPlan::run_slice`] instead
//! of the interpreter. Recognition is conservative by construction —
//! any shape the catalog cannot prove bit-identical (shadowed builtins,
//! named arguments, env mutation, conditions, RNG, vector elements for
//! scalar kernels, named values whose propagation the kernel would
//! drop) stays on the interpreted path, either at recognition time
//! (no plan) or per-slice (`run_slice` returns `None` on any item that
//! misses the runtime gate). `FUTURIZE_NO_FUSION=1` is the kill switch:
//! it suppresses plan attachment at freeze time, so it works across
//! process backends without re-spawning workers.

use std::sync::atomic::{AtomicU64, Ordering};

use serde_derive::{Deserialize, Serialize};

use crate::rlite::ast::Expr;
use crate::rlite::intern::Symbol;
use crate::rlite::serialize::{WireSlice, WireVal};
use crate::rlite::shape::{callee, fingerprint, peel};
use crate::runtime::elementwise::{self, ElemOp};
use crate::runtime::kernels;

/// Set to `1` to disable fusion entirely (every map runs interpreted).
pub const NO_FUSION_ENV: &str = "FUTURIZE_NO_FUSION";

/// Read the kill switch per call (not cached) so tests and operators
/// can toggle it without restarting the session.
pub fn enabled() -> bool {
    std::env::var(NO_FUSION_ENV).map(|v| v != "1").unwrap_or(true)
}

// Trace counters (process-local, for tests/benches/diagnostics).
// Recognition counters tick in the parent at freeze time; slice
// counters tick wherever the slice executes, so process backends
// accumulate them worker-side.
static RECOGNIZED: AtomicU64 = AtomicU64::new(0);
static UNMATCHED: AtomicU64 = AtomicU64::new(0);
static FUSED_SLICES: AtomicU64 = AtomicU64::new(0);
static FALLBACK_SLICES: AtomicU64 = AtomicU64::new(0);

/// Map contexts whose body matched a kernel at freeze time.
pub fn contexts_recognized() -> u64 {
    RECOGNIZED.load(Ordering::Relaxed)
}

/// Map contexts frozen with no matching kernel (interpreted path).
pub fn contexts_unmatched() -> u64 {
    UNMATCHED.load(Ordering::Relaxed)
}

/// Slices executed through a kernel.
pub fn slices_fused() -> u64 {
    FUSED_SLICES.load(Ordering::Relaxed)
}

/// Slices of kernel-planned contexts that fell back to the interpreter
/// (an item missed the runtime gate).
pub fn slices_fallback() -> u64 {
    FALLBACK_SLICES.load(Ordering::Relaxed)
}

pub fn note_fused_slice() {
    FUSED_SLICES.fetch_add(1, Ordering::Relaxed);
}

pub fn note_fallback_slice() {
    FALLBACK_SLICES.fetch_add(1, Ordering::Relaxed);
}

/// Why recognition rejected a map body — the label the rejection
/// counter ticks under and the parallel-safety analyzer turns into an
/// FZ007 diagnostic. Classification is best-effort and ordered: the
/// first blocker found wins (a body can have several).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The mapped function is not a wire closure (builtin reference).
    NotClosure,
    /// Empty parameter list or `...` — arguments cannot be bound
    /// statically.
    Params,
    /// The body mutates an enclosing environment (`<<-`, `assign`,
    /// `rm`).
    EnvMutation,
    /// A call passes named arguments, which the catalog does not model.
    NamedArgs,
    /// A builtin callee is shadowed by a user binding.
    Shadowed,
    /// Everything bindable, just not a catalog shape.
    Shape,
}

impl RejectReason {
    pub fn label(self) -> &'static str {
        match self {
            RejectReason::NotClosure => "not-closure",
            RejectReason::Params => "params",
            RejectReason::EnvMutation => "env-mutation",
            RejectReason::NamedArgs => "named-args",
            RejectReason::Shadowed => "shadowed",
            RejectReason::Shape => "shape",
        }
    }

    fn index(self) -> usize {
        match self {
            RejectReason::NotClosure => 0,
            RejectReason::Params => 1,
            RejectReason::EnvMutation => 2,
            RejectReason::NamedArgs => 3,
            RejectReason::Shadowed => 4,
            RejectReason::Shape => 5,
        }
    }
}

const REJECT_LABELS: [&str; 6] =
    ["not-closure", "params", "env-mutation", "named-args", "shadowed", "shape"];

static REJECTIONS: [AtomicU64; 6] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// Per-reason rejection counts `(label, count)`, in a stable order.
/// Exposed through `futurize::fusion_report()`.
pub fn rejection_counts() -> Vec<(&'static str, u64)> {
    REJECT_LABELS
        .iter()
        .enumerate()
        .map(|(i, l)| (*l, REJECTIONS[i].load(Ordering::Relaxed)))
        .collect()
}

/// Classify why `recognize` would reject this context. Pure (no
/// counters); also callable on bodies that *would* match, in which
/// case it answers [`RejectReason::Shape`].
pub fn classify_rejection(
    f: &WireVal,
    extra: &[(Option<String>, WireVal)],
    globals: &[(String, WireVal)],
) -> RejectReason {
    let WireVal::Closure { params, body, captured } = f else {
        return RejectReason::NotClosure;
    };
    if params.is_empty() || params.iter().any(|p| p.name.as_str() == "...") {
        return RejectReason::Params;
    }
    let mut mutates = false;
    let mut named = false;
    let mut shadowed = false;
    crate::transpile::analysis::walk(body, &mut |e| match e {
        Expr::SuperAssign { .. } => mutates = true,
        Expr::Call { args, .. } => {
            if matches!(e.call_name(), Some("assign" | "rm")) {
                mutates = true;
            }
            if args.iter().any(|a| a.name.is_some()) {
                named = true;
            }
            if let Some(name) = e.call_name() {
                if crate::rlite::builtins::lookup_builtin(name).is_some() {
                    let bound = params.iter().any(|p| p.name.as_str() == name)
                        || captured.iter().any(|(n, _)| n == name)
                        || globals.iter().any(|(n, _)| n == name)
                        || extra.iter().any(|(n, _)| n.as_deref() == Some(name));
                    if bound {
                        shadowed = true;
                    }
                }
            }
        }
        _ => {}
    });
    if mutates {
        RejectReason::EnvMutation
    } else if named {
        RejectReason::NamedArgs
    } else if shadowed {
        RejectReason::Shadowed
    } else {
        RejectReason::Shape
    }
}

/// A recognized kernel for one map context, shipped inside
/// `TaskContext` to wherever its slices execute.
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub struct KernelPlan {
    /// Canonical label (`catalog entry:fingerprint`) for trace output,
    /// bench series, and test assertions.
    pub shape: String,
    pub kind: KernelKind,
}

#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub enum KernelKind {
    /// Scalar arithmetic program over the element (postorder stack VM).
    Elementwise { prog: Vec<ElemOp> },
    /// `sum(x·w) / sum(u·w)` with the element as weight vector `w`.
    BootStat { x: Vec<f64>, u: Vec<f64> },
    /// `hlo_gram(x, y)` with the element as the design matrix.
    Gram { y: Vec<f64> },
    /// `hlo_ridge(x, y, lam)` with the element as the design matrix:
    /// the gram half plus the native Cholesky solve, fused end to end.
    Ridge { y: Vec<f64>, lam: f64 },
}

/// Freeze-time entry point: recognition gated on the kill switch, with
/// trace accounting. Returns the plan to ship in the context, if any.
pub fn maybe_recognize(
    f: &WireVal,
    extra: &[(Option<String>, WireVal)],
    globals: &[(String, WireVal)],
) -> Option<KernelPlan> {
    if !enabled() {
        return None;
    }
    match recognize(f, extra, globals) {
        Some(p) => {
            RECOGNIZED.fetch_add(1, Ordering::Relaxed);
            Some(p)
        }
        None => {
            UNMATCHED.fetch_add(1, Ordering::Relaxed);
            let reason = classify_rejection(f, extra, globals);
            REJECTIONS[reason.index()].fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

/// Name-resolution scope for recognition: the element parameter, extra
/// arguments bound to the remaining parameters, the closure's captured
/// snapshot, and the context's exported globals — in that order, which
/// mirrors the worker-side environment chain (params → closure env →
/// globals). Builtins never appear in captured/globals snapshots
/// (serialization skips them), so *any* binding for a callee name means
/// the builtin is shadowed.
struct Scope<'a> {
    elem: Symbol,
    bound: &'a [(Symbol, WireVal)],
    captured: &'a [(String, WireVal)],
    globals: &'a [(String, WireVal)],
}

impl Scope<'_> {
    fn resolve(&self, s: Symbol) -> Option<&WireVal> {
        if s == self.elem {
            return None;
        }
        if let Some((_, v)) = self.bound.iter().find(|(n, _)| *n == s) {
            return Some(v);
        }
        let name = s.as_str();
        if let Some((_, v)) = self.captured.iter().rev().find(|(n, _)| n == name) {
            return Some(v);
        }
        self.globals.iter().rev().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    /// A callee is fusable only when it will resolve to the base
    /// builtin on the worker: no user binding may shadow it.
    fn callee_is_builtin(&self, s: Symbol) -> bool {
        s != self.elem && self.resolve(s).is_none()
    }
}

/// A captured value usable as an elementwise constant: an *unnamed*
/// scalar (names would propagate through the interpreter's slow-path
/// binop and change the result shape). Int scalars are acceptable under
/// an operator (the interpreter coerces `i as f64` identically) but not
/// at the body root, where the interpreter returns them verbatim as Int.
fn scalar_const(v: &WireVal, at_root: bool) -> Option<f64> {
    match v {
        WireVal::Dbl(vals, None) if vals.len() == 1 => Some(vals[0]),
        WireVal::Int(vals, None) if vals.len() == 1 && !at_root => Some(vals[0] as f64),
        _ => None,
    }
}

/// A captured value usable as a constant numeric vector. Names are fine
/// here: these feed `sum(...)` reductions and `hlo_gram`, which drop
/// names exactly as the fused kernels do.
fn const_dbl_vec(v: &WireVal) -> Option<Vec<f64>> {
    match v {
        WireVal::Dbl(vals, _) => Some(vals.clone()),
        WireVal::Int(vals, _) => Some(vals.iter().map(|&x| x as f64).collect()),
        _ => None,
    }
}

/// Recognize a frozen map closure against the kernel catalog. Pure
/// analysis — no counters, no kill switch — so tests and benches can
/// call it directly.
pub fn recognize(
    f: &WireVal,
    extra: &[(Option<String>, WireVal)],
    globals: &[(String, WireVal)],
) -> Option<KernelPlan> {
    let WireVal::Closure { params, body, captured } = f else {
        return None;
    };
    if params.is_empty() || params.iter().any(|p| p.name.as_str() == "...") {
        return None;
    }
    let elem = params[0].name;

    // Bind extras to the remaining parameters exactly as the map driver
    // will: named extras match parameter names exactly, positional
    // extras fill the remaining slots in order. Anything the static
    // binding cannot prove (unknown names, unbound parameters needing
    // defaults, surplus extras) rejects the match.
    let rest = &params[1..];
    let mut slots: Vec<Option<WireVal>> = vec![None; rest.len()];
    let mut positional: Vec<WireVal> = Vec::new();
    for (name, v) in extra {
        match name {
            Some(n) => {
                if n == elem.as_str() {
                    return None;
                }
                let i = rest.iter().position(|p| p.name.as_str() == n)?;
                if slots[i].is_some() {
                    return None;
                }
                slots[i] = Some(v.clone());
            }
            None => positional.push(v.clone()),
        }
    }
    let mut pos = positional.into_iter();
    for slot in slots.iter_mut() {
        if slot.is_none() {
            *slot = pos.next();
        }
    }
    if pos.next().is_some() {
        return None;
    }
    let mut bound: Vec<(Symbol, WireVal)> = Vec::with_capacity(rest.len());
    for (p, s) in rest.iter().zip(slots) {
        bound.push((p.name, s?));
    }

    let scope = Scope { elem, bound: &bound, captured, globals };
    let body = peel(body);
    let label = |prefix: &str| {
        format!("{prefix}:{}", fingerprint(body, elem, &|s| scope.resolve(s).is_some()))
    };
    if let Some(kind) = recognize_boot(body, &scope) {
        return Some(KernelPlan { shape: label("boot_stat"), kind });
    }
    if let Some(kind) = recognize_gram(body, &scope) {
        return Some(KernelPlan { shape: label("gram"), kind });
    }
    if let Some(kind) = recognize_ridge(body, &scope) {
        return Some(KernelPlan { shape: label("ridge"), kind });
    }
    let mut prog = Vec::new();
    compile_elementwise(body, &scope, &mut prog, 0)?;
    Some(KernelPlan { shape: label("elementwise"), kind: KernelKind::Elementwise { prog } })
}

/// The call's (namespace-checked, shadow-checked) builtin head and its
/// unnamed arguments — `None` if the callee is computed, namespaced
/// outside `allowed_ns`, shadowed, or any argument is named.
fn builtin_call<'a>(
    e: &'a Expr,
    scope: &Scope,
    allowed_ns: &[&str],
) -> Option<(Symbol, Vec<&'a Expr>)> {
    let Expr::Call { func, args } = e else {
        return None;
    };
    let (ns, name) = callee(func)?;
    if let Some(pkg) = ns {
        if !allowed_ns.contains(&pkg) {
            return None;
        }
    }
    if !scope.callee_is_builtin(name) {
        return None;
    }
    if args.iter().any(|a| a.name.is_some()) {
        return None;
    }
    Some((name, args.iter().map(|a| &a.value).collect()))
}

/// Compile an arithmetic expression tree to a postorder [`ElemOp`]
/// program. `depth == 0` marks the body root, where the interpreter
/// returns non-Dbl leaves verbatim and the program must therefore
/// reject them.
fn compile_elementwise(
    e: &Expr,
    scope: &Scope,
    out: &mut Vec<ElemOp>,
    depth: usize,
) -> Option<()> {
    match peel(e) {
        Expr::Num(v) => {
            out.push(ElemOp::Const(*v));
            Some(())
        }
        Expr::Int(v) if depth > 0 => {
            out.push(ElemOp::Const(*v as f64));
            Some(())
        }
        Expr::Sym(s) if *s == scope.elem => {
            out.push(ElemOp::Par);
            Some(())
        }
        Expr::Sym(s) => {
            let c = scalar_const(scope.resolve(*s)?, depth == 0)?;
            out.push(ElemOp::Const(c));
            Some(())
        }
        call @ Expr::Call { .. } => {
            let (name, args) = builtin_call(call, scope, &["base"])?;
            let n = name.as_str();
            if let Some(op) = match (n, args.len()) {
                ("+", 2) => Some(ElemOp::Add),
                ("-", 2) => Some(ElemOp::Sub),
                ("*", 2) => Some(ElemOp::Mul),
                ("/", 2) => Some(ElemOp::Div),
                ("^", 2) => Some(ElemOp::Pow),
                ("%%", 2) => Some(ElemOp::Mod),
                ("%/%", 2) => Some(ElemOp::IntDiv),
                _ => None,
            } {
                compile_elementwise(args[0], scope, out, depth + 1)?;
                compile_elementwise(args[1], scope, out, depth + 1)?;
                out.push(op);
                return Some(());
            }
            // Unary `+` is the interpreter's identity: compile the
            // operand at the *same* depth (root stays root).
            if n == "+" && args.len() == 1 {
                return compile_elementwise(args[0], scope, out, depth);
            }
            let un = match (n, args.len()) {
                ("-", 1) => ElemOp::Neg,
                ("sqrt", 1) => ElemOp::Sqrt,
                ("exp", 1) => ElemOp::Exp,
                ("log", 1) => ElemOp::Ln,
                ("log2", 1) => ElemOp::Log2,
                ("log10", 1) => ElemOp::Log10,
                ("abs", 1) => ElemOp::Abs,
                ("floor", 1) => ElemOp::Floor,
                ("ceiling", 1) => ElemOp::Ceil,
                ("sin", 1) => ElemOp::Sin,
                ("cos", 1) => ElemOp::Cos,
                _ => return None,
            };
            compile_elementwise(args[0], scope, out, depth + 1)?;
            out.push(un);
            Some(())
        }
        _ => None,
    }
}

/// A resolvable constant numeric vector operand: a bare symbol, or a
/// `d$field` access on a resolvable named list.
fn resolve_vec(e: &Expr, scope: &Scope) -> Option<Vec<f64>> {
    match peel(e) {
        Expr::Sym(s) => const_dbl_vec(scope.resolve(*s)?),
        Expr::Dollar { obj, name } => {
            let Expr::Sym(s) = peel(obj) else {
                return None;
            };
            let WireVal::List(vals, Some(names), _) = scope.resolve(*s)? else {
                return None;
            };
            let i = names.iter().position(|n| n == name)?;
            const_dbl_vec(&vals[i])
        }
        _ => None,
    }
}

/// `sum(<vec> * elem)` (either factor order): the constant-vector half
/// of one weighted sum.
fn weighted_sum_vec(e: &Expr, scope: &Scope) -> Option<Vec<f64>> {
    let (name, args) = builtin_call(peel(e), scope, &["base"])?;
    if name.as_str() != "sum" || args.len() != 1 {
        return None;
    }
    let (mul, factors) = builtin_call(peel(args[0]), scope, &["base"])?;
    if mul.as_str() != "*" || factors.len() != 2 {
        return None;
    }
    let is_elem = |e: &Expr| matches!(peel(e), Expr::Sym(s) if *s == scope.elem);
    match (is_elem(factors[0]), is_elem(factors[1])) {
        (true, false) => resolve_vec(factors[1], scope),
        (false, true) => resolve_vec(factors[0], scope),
        _ => None,
    }
}

/// `sum(x * w) / sum(u * w)` with the element as weight vector.
fn recognize_boot(body: &Expr, scope: &Scope) -> Option<KernelKind> {
    let (name, args) = builtin_call(body, scope, &["base"])?;
    if name.as_str() != "/" || args.len() != 2 {
        return None;
    }
    let x = weighted_sum_vec(args[0], scope)?;
    let u = weighted_sum_vec(args[1], scope)?;
    // Equal lengths mean the interpreter never recycles and the kernel's
    // exact zip reproduces it; the slice gate pins the element length.
    if x.len() != u.len() {
        return None;
    }
    Some(KernelKind::BootStat { x, u })
}

/// `hlo_gram(elem, y)` with a resolvable response vector.
fn recognize_gram(body: &Expr, scope: &Scope) -> Option<KernelKind> {
    let (name, args) = builtin_call(body, scope, &["futurize"])?;
    if name.as_str() != "hlo_gram" || args.len() != 2 {
        return None;
    }
    if !matches!(peel(args[0]), Expr::Sym(s) if *s == scope.elem) {
        return None;
    }
    Some(KernelKind::Gram { y: resolve_vec(args[1], scope)? })
}

/// `hlo_ridge(elem, y, lam)` with a resolvable response vector and a
/// constant penalty.
fn recognize_ridge(body: &Expr, scope: &Scope) -> Option<KernelKind> {
    let (name, args) = builtin_call(body, scope, &["futurize"])?;
    if name.as_str() != "hlo_ridge" || args.len() != 3 {
        return None;
    }
    if !matches!(peel(args[0]), Expr::Sym(s) if *s == scope.elem) {
        return None;
    }
    let y = resolve_vec(args[1], scope)?;
    let lam = resolve_scalar(args[2], scope)?;
    Some(KernelKind::Ridge { y, lam })
}

/// A constant scalar operand: a numeric literal, or a binding resolving
/// to an unnamed length-1 numeric.
fn resolve_scalar(e: &Expr, scope: &Scope) -> Option<f64> {
    match peel(e) {
        Expr::Num(v) => Some(*v),
        Expr::Sym(s) => scalar_const(scope.resolve(*s)?, false),
        _ => None,
    }
}

impl KernelPlan {
    /// Execute a slice through the kernel. `None` means some item
    /// missed the runtime gate and the *whole* slice must run
    /// interpreted — safe because every cataloged shape is pure, so
    /// re-execution has no observable side effects.
    pub fn run_slice(&self, items: &WireSlice<WireVal>) -> Option<Vec<WireVal>> {
        match &self.kind {
            KernelKind::Elementwise { prog } => {
                let mut out = Vec::with_capacity(items.len());
                let mut stack = Vec::with_capacity(elementwise::max_depth(prog));
                // A program that never reads the element is a constant:
                // the interpreter returns a scalar for it, so mapping it
                // over a vector item would change the result length.
                let uses_elem = prog.iter().any(|op| matches!(op, ElemOp::Par));
                for item in items.iter() {
                    // Unnamed numeric only: names would propagate
                    // through the interpreter, and a bare-Int identity
                    // body would return Int verbatim (prog.len() > 1
                    // guarantees a root operation, which always produces
                    // unnamed Dbl). Vector items run the program per
                    // component — exactly the interpreter's recycling
                    // binops and vectorized unary builtins, since every
                    // non-element operand is a scalar constant.
                    let vec_ok = |len: usize| uses_elem || len == 1;
                    match item {
                        WireVal::Dbl(v, None) if vec_ok(v.len()) => {
                            out.push(WireVal::Dbl(
                                v.iter().map(|&x| elementwise::eval(prog, x, &mut stack)).collect(),
                                None,
                            ));
                        }
                        WireVal::Int(v, None) if vec_ok(v.len()) && prog.len() > 1 => {
                            out.push(WireVal::Dbl(
                                v.iter()
                                    .map(|&x| elementwise::eval(prog, x as f64, &mut stack))
                                    .collect(),
                                None,
                            ));
                        }
                        _ => return None,
                    }
                }
                Some(out)
            }
            KernelKind::BootStat { x, u } => {
                let mut out = Vec::with_capacity(items.len());
                let mut scratch: Vec<f64> = Vec::new();
                for item in items.iter() {
                    let w: &[f64] = match item {
                        WireVal::Dbl(v, _) if v.len() == x.len() => v,
                        WireVal::Int(v, _) if v.len() == x.len() => {
                            scratch.clear();
                            scratch.extend(v.iter().map(|&i| i as f64));
                            &scratch
                        }
                        _ => return None,
                    };
                    out.push(WireVal::Dbl(vec![kernels::weighted_ratio(x, u, w)], None));
                }
                Some(out)
            }
            KernelKind::Gram { y } => {
                let mut out = Vec::with_capacity(items.len());
                for item in items.iter() {
                    out.push(gram_item(item, y)?);
                }
                Some(out)
            }
            KernelKind::Ridge { y, lam } => {
                let mut out = Vec::with_capacity(items.len());
                for item in items.iter() {
                    out.push(ridge_item(item, y, *lam)?);
                }
                Some(out)
            }
        }
    }
}

/// One gram item: a list of numeric columns (or a single numeric
/// vector), checked rectangular against `y`. Dimension errors gate to
/// `None` so the interpreter raises its own condition verbatim.
fn gram_item(item: &WireVal, y: &[f64]) -> Option<WireVal> {
    let cols: Vec<Vec<f64>> = match item {
        WireVal::List(vals, _, _) => vals.iter().map(const_dbl_vec).collect::<Option<_>>()?,
        WireVal::Dbl(..) | WireVal::Int(..) => vec![const_dbl_vec(item)?],
        _ => return None,
    };
    let n = cols.first()?.len();
    if cols.iter().any(|c| c.len() != n) || y.len() != n {
        return None;
    }
    let (g, xty) = kernels::gram(&cols, y).ok()?;
    let p = cols.len();
    let mut parts: Vec<WireVal> =
        g.chunks(p).map(|row| WireVal::Dbl(row.to_vec(), None)).collect();
    parts.push(WireVal::Dbl(xty, None));
    Some(WireVal::List(parts, None, None))
}

/// One ridge item: the gram half on the item's columns, then the native
/// Cholesky solve of `(G + λI) β = X^T y`. Dimension errors and non-SPD
/// systems gate to `None` so the interpreted `hlo_ridge` raises its own
/// condition verbatim.
fn ridge_item(item: &WireVal, y: &[f64], lam: f64) -> Option<WireVal> {
    let cols: Vec<Vec<f64>> = match item {
        WireVal::List(vals, _, _) => vals.iter().map(const_dbl_vec).collect::<Option<_>>()?,
        WireVal::Dbl(..) | WireVal::Int(..) => vec![const_dbl_vec(item)?],
        _ => return None,
    };
    let n = cols.first()?.len();
    if cols.iter().any(|c| c.len() != n) || y.len() != n {
        return None;
    }
    let (g, xty) = kernels::gram(&cols, y).ok()?;
    let beta = kernels::ridge_solve(&g, &xty, lam).ok()?;
    Some(WireVal::Dbl(beta, None))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::parse_expr;

    /// Build a frozen map closure the way `closure_to_wire` would.
    fn closure(src: &str, captured: &[(&str, WireVal)]) -> WireVal {
        let Expr::Function { params, body } = parse_expr(src).unwrap() else {
            panic!("fixture must be a function: {src}");
        };
        WireVal::Closure {
            params,
            body: *body,
            captured: captured.iter().map(|(n, v)| (n.to_string(), v.clone())).collect(),
        }
    }

    fn rec(src: &str, captured: &[(&str, WireVal)]) -> Option<KernelPlan> {
        recognize(&closure(src, captured), &[], &[])
    }

    fn dbl(v: &[f64]) -> WireVal {
        WireVal::Dbl(v.to_vec(), None)
    }

    #[test]
    fn recognizes_polynomial_and_runs_it() {
        let plan = rec("function(x) 3 * x * x + 2 * x + 1", &[]).expect("should match");
        assert!(plan.shape.starts_with("elementwise:"), "{}", plan.shape);
        let items: WireSlice<WireVal> =
            vec![dbl(&[0.0]), dbl(&[1.0]), dbl(&[2.0])].into();
        let out = plan.run_slice(&items).expect("gate passes");
        assert_eq!(out, vec![dbl(&[1.0]), dbl(&[6.0]), dbl(&[17.0])]);
    }

    #[test]
    fn captured_scalars_and_extras_become_constants() {
        let a = dbl(&[2.5]);
        let plan = rec("function(x) a * x + 1", &[("a", a.clone())]).expect("captured");
        let out = plan.run_slice(&vec![dbl(&[2.0])].into()).unwrap();
        assert_eq!(out, vec![dbl(&[6.0])]);
        // The same body with `a` as a positional extra argument.
        let f = closure("function(x, a) a * x + 1", &[]);
        let plan = recognize(&f, &[(None, a.clone())], &[]).expect("positional extra");
        assert_eq!(plan.run_slice(&vec![dbl(&[2.0])].into()).unwrap(), vec![dbl(&[6.0])]);
        // And as a named extra.
        let plan =
            recognize(&f, &[(Some("a".into()), a)], &[]).expect("named extra");
        assert_eq!(plan.run_slice(&vec![dbl(&[2.0])].into()).unwrap(), vec![dbl(&[6.0])]);
    }

    #[test]
    fn rejects_unfusable_bodies() {
        // Env mutation, conditions, RNG, control flow, vector ops.
        for src in [
            "function(x) { s <<- s + x\ns }",
            "function(x) { message(\"hi\")\nx * 2 }",
            "function(x) rnorm(1) + x",
            "function(x) if (x > 0) x else 0",
            "function(x) sum(x)",
            "function(x) (function(y) y + 1)(x)",
            "function(x) x * unknown_sym",
            "function(...) 1",
        ] {
            assert!(rec(src, &[]).is_none(), "must not fuse: {src}");
        }
        // A shadowed builtin is not the builtin.
        let shadow = closure("function(x) x * 2", &[("*", dbl(&[1.0]))]);
        assert!(recognize(&shadow, &[], &[]).is_none(), "shadowed `*` must reject");
        // Named scalars would propagate names through the interpreter.
        let named = WireVal::Dbl(vec![2.0], Some(vec!["n".into()]));
        assert!(rec("function(x) a * x", &[("a", named)]).is_none());
        // Unbound second parameter (its default would need evaluation).
        let f = closure("function(x, a = 2) a * x", &[]);
        assert!(recognize(&f, &[], &[]).is_none());
    }

    #[test]
    fn elementwise_maps_vector_items_per_component() {
        let plan = rec("function(x) x * 2 + 1", &[]).unwrap();
        // Numeric vector items run the program per component, exactly
        // like the interpreter's recycling binops.
        let out = plan.run_slice(&vec![dbl(&[1.0, 2.0]), dbl(&[])].into()).unwrap();
        assert_eq!(out, vec![dbl(&[3.0, 5.0]), dbl(&[])]);
        let out = plan.run_slice(&vec![WireVal::Int(vec![1, 2, 3], None)].into()).unwrap();
        assert_eq!(out, vec![dbl(&[3.0, 5.0, 7.0])]);
        // The identity program must keep Int vectors on the interpreted
        // path (they would come back Int verbatim, not Dbl).
        let ident = rec("function(x) x", &[]).unwrap();
        assert!(ident.run_slice(&vec![WireVal::Int(vec![1, 2], None)].into()).is_none());
        assert_eq!(
            ident.run_slice(&vec![dbl(&[1.0, 2.0])].into()).unwrap(),
            vec![dbl(&[1.0, 2.0])]
        );
        // A constant body returns a scalar whatever the element length:
        // vector items must not broadcast it.
        let konst = rec("function(x) 1 + 1", &[]).unwrap();
        assert!(konst.run_slice(&vec![dbl(&[1.0, 2.0])].into()).is_none());
        assert_eq!(konst.run_slice(&vec![dbl(&[9.0])].into()).unwrap(), vec![dbl(&[2.0])]);
    }

    #[test]
    fn elementwise_gate_rejects_non_numeric_items() {
        let plan = rec("function(x) x * 2 + 1", &[]).unwrap();
        let named = WireVal::Dbl(vec![1.0], Some(vec!["n".into()]));
        assert!(plan.run_slice(&vec![named].into()).is_none(), "named item");
        assert!(
            plan.run_slice(&vec![WireVal::Chr(vec!["a".into()], None)].into()).is_none(),
            "character item"
        );
        // Int scalars coerce exactly under an arithmetic root...
        let out = plan.run_slice(&vec![WireVal::Int(vec![3], None)].into()).unwrap();
        assert_eq!(out, vec![dbl(&[7.0])]);
        // ...but the identity body returns Int verbatim interpreted, so
        // the fused path must refuse it.
        let ident = rec("function(x) x", &[]).unwrap();
        assert!(ident.run_slice(&vec![WireVal::Int(vec![3], None)].into()).is_none());
        assert_eq!(ident.run_slice(&vec![dbl(&[3.0])].into()).unwrap(), vec![dbl(&[3.0])]);
    }

    #[test]
    fn recognizes_boot_statistic_both_factor_orders_and_dollar_form() {
        let x = dbl(&[5.0, 6.0]);
        let u = dbl(&[1.0, 2.0]);
        let plan =
            rec("function(w) sum(x * w) / sum(w * u)", &[("x", x.clone()), ("u", u.clone())])
                .expect("boot shape");
        assert!(plan.shape.starts_with("boot_stat:"), "{}", plan.shape);
        let out = plan.run_slice(&vec![dbl(&[1.0, 1.0])].into()).unwrap();
        assert_eq!(out, vec![dbl(&[11.0 / 3.0])]);
        // d$x / d$u on a captured named list.
        let d = WireVal::List(vec![x, u], Some(vec!["x".into(), "u".into()]), None);
        let plan = rec("function(w) sum(d$x * w) / sum(d$u * w)", &[("d", d)]).unwrap();
        assert_eq!(plan.run_slice(&vec![dbl(&[1.0, 1.0])].into()).unwrap(), vec![
            dbl(&[11.0 / 3.0])
        ]);
        // Length-mismatched weights gate to the interpreter.
        assert!(plan.run_slice(&vec![dbl(&[1.0, 1.0, 1.0])].into()).is_none());
        // Zero denominator flows through as the interpreter's NaN/Inf,
        // not an error.
        let z = plan.run_slice(&vec![dbl(&[0.0, 0.0])].into()).unwrap();
        let WireVal::Dbl(v, None) = &z[0] else { panic!() };
        assert!(v[0].is_nan());
    }

    #[test]
    fn recognizes_gram_and_gates_ragged_items() {
        let y = dbl(&[1.0, 0.0, 1.0]);
        let plan = rec("function(x) hlo_gram(x, y)", &[("y", y)]).expect("gram shape");
        assert!(plan.shape.starts_with("gram:"), "{}", plan.shape);
        let cols = WireVal::List(
            vec![dbl(&[1.0, 2.0, 3.0]), dbl(&[0.5, -1.0, 2.0])],
            None,
            None,
        );
        let out = plan.run_slice(&vec![cols].into()).unwrap();
        let WireVal::List(parts, None, None) = &out[0] else {
            panic!("gram output shape: {out:?}")
        };
        assert_eq!(parts.len(), 3); // 2 gram rows + xty
        assert_eq!(parts[0], dbl(&[14.0, 4.5]));
        // Ragged item → interpreter (which raises its own error).
        let ragged = WireVal::List(vec![dbl(&[1.0]), dbl(&[1.0, 2.0])], None, None);
        assert!(plan.run_slice(&vec![ragged].into()).is_none());
    }

    #[test]
    fn recognizes_ridge_with_literal_and_captured_lambda() {
        let y = dbl(&[3.0, 4.0]);
        let plan = rec("function(x) hlo_ridge(x, y, 1)", &[("y", y.clone())])
            .expect("ridge shape");
        assert!(plan.shape.starts_with("ridge:"), "{}", plan.shape);
        // Identity design, λ = 1: (I + I) β = X^T y → β = y / 2.
        let eye = WireVal::List(vec![dbl(&[1.0, 0.0]), dbl(&[0.0, 1.0])], None, None);
        let out = plan.run_slice(&vec![eye].into()).unwrap();
        assert_eq!(out[0], dbl(&[1.5, 2.0]));
        // Captured scalar penalty resolves too.
        let plan2 = rec(
            "function(x) hlo_ridge(x, y, lam)",
            &[("y", y), ("lam", dbl(&[1.0]))],
        )
        .expect("captured lambda");
        let KernelKind::Ridge { lam, .. } = plan2.kind else { panic!("{plan2:?}") };
        assert_eq!(lam, 1.0);
        // A mismatched response length gates the item to the interpreter.
        let short = rec("function(x) hlo_ridge(x, y, 1)", &[("y", dbl(&[1.0]))]).unwrap();
        let eye = WireVal::List(vec![dbl(&[1.0, 0.0]), dbl(&[0.0, 1.0])], None, None);
        assert!(short.run_slice(&vec![eye].into()).is_none());
    }

    #[test]
    fn plan_roundtrips_both_codecs() {
        use crate::wire::codec::WireCodec;
        let plan = rec("function(x) sqrt(x) + 2 ^ x", &[]).unwrap();
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let bytes = codec.encode(&plan).unwrap();
            assert_eq!(codec.decode::<KernelPlan>(&bytes).unwrap(), plan, "{codec:?}");
        }
    }
}
