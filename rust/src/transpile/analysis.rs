//! Parallel-safety analyzer — the freeze-time static pass over every
//! futurized map/reduce expression.
//!
//! The paper's contract is "declare *what* to parallelize, let the end
//! user choose *how*" — which silently assumes the declared body is
//! actually safe to parallelize. This pass checks that assumption at
//! the same moment the transpiler freezes the map (closure + captures
//! already in wire form, kernel/reduce recognition already decided) and
//! reports violations in the *parent*, before any worker is touched:
//!
//! - FZ001 cross-iteration dependence (`<<-`/`assign()` into a binding
//!   the body also reads),
//! - FZ002 RNG draws without `seed = TRUE`,
//! - FZ003 free variables that resolve to nothing at freeze time,
//! - FZ004 oversized captured/global exports,
//! - FZ005 order-dependent reductions under `reduce = "assoc"`,
//! - FZ006/FZ007/FZ008 Info-level explanations (assoc float-fold ULP
//!   contract, kernel-fusion and reduce-fusion rejection reasons),
//! - FZ009 Info-level data-plane cache report (which exports ride the
//!   content-addressed blob cache, plus session hit/miss counters).
//!
//! Findings surface per [`LintMode`]: relayed once per map call as
//! classed warnings (default), promoted to a classed
//! `FuturizeLintError` before dispatch (`lint = "error"` /
//! `FUTURIZE_LINT=error`), or skipped entirely (`"off"`). The same
//! detectors back the `futurize-rs lint` CLI subcommand, which runs
//! them over a parsed script with no session at all ([`lint_source`]).

use std::collections::{HashMap, HashSet};

use crate::future_core::driver::{MapOptions, SeedOption};
use crate::globals::free_variables;
use crate::rlite::ast::{Arg, Expr, Param};
use crate::rlite::builtins;
use crate::rlite::conditions::RCondition;
use crate::rlite::deparse::deparse;
use crate::rlite::diag::{DiagCode, Diagnostic, LintLevel, LintMode};
use crate::rlite::eval::{Interp, Signal};
use crate::rlite::intern::Symbol;
use crate::rlite::serialize::WireVal;
use crate::transpile::fusion::{self, RejectReason};
use crate::transpile::reduce::ReduceOp;

/// Captured + global export volume above which FZ004 fires. Shipping
/// multiple megabytes per map call usually means a dataset leaked into
/// the closure environment instead of being chunked as items.
pub const OVERSIZE_BYTES: usize = 4 << 20;

/// Builtins whose evaluation draws from the RNG stream (mirrors
/// `rlite::builtins::stats_rng` plus `set.seed`, which silently
/// overrides the per-element L'Ecuyer streams).
const RNG_BUILTINS: &[&str] =
    &["set.seed", "rnorm", "runif", "rexp", "rbinom", "rpois", "sample"];

// ---------------------------------------------------------------------------
// AST walking primitives
// ---------------------------------------------------------------------------

/// Pre-order walk over every sub-expression, including nested function
/// bodies and parameter defaults.
pub fn walk(e: &Expr, f: &mut dyn FnMut(&Expr)) {
    f(e);
    match e {
        Expr::Call { func, args } => {
            walk(func, f);
            walk_args(args, f);
        }
        Expr::Function { params, body } => {
            for p in params {
                if let Some(d) = &p.default {
                    walk(d, f);
                }
            }
            walk(body, f);
        }
        Expr::Block(es) => {
            for x in es {
                walk(x, f);
            }
        }
        Expr::If { cond, then, els } => {
            walk(cond, f);
            walk(then, f);
            if let Some(x) = els {
                walk(x, f);
            }
        }
        Expr::For { seq, body, .. } => {
            walk(seq, f);
            walk(body, f);
        }
        Expr::While { cond, body } => {
            walk(cond, f);
            walk(body, f);
        }
        Expr::Assign { target, value } | Expr::SuperAssign { target, value } => {
            walk(target, f);
            walk(value, f);
        }
        Expr::Index { obj, args, .. } => {
            walk(obj, f);
            walk_args(args, f);
        }
        Expr::Dollar { obj, .. } => walk(obj, f),
        _ => {}
    }
}

fn walk_args(args: &[Arg], f: &mut dyn FnMut(&Expr)) {
    for a in args {
        walk(&a.value, f);
    }
}

/// The base symbol of an assignment target: `x` for `x`, `x[i]`,
/// `x[[i]]$field` alike.
fn base_sym(e: &Expr) -> Option<Symbol> {
    match e {
        Expr::Sym(s) => Some(*s),
        Expr::Index { obj, .. } => base_sym(obj),
        Expr::Dollar { obj, .. } => base_sym(obj),
        _ => None,
    }
}

/// Bindings the body writes into an *enclosing* frame: `name <<- ...`
/// (any target shape, reduced to its base symbol) and
/// `assign("name", ...)`. Returns `(name, offending-snippet)` pairs in
/// first-occurrence order.
fn escaping_writes(body: &Expr) -> Vec<(Symbol, String)> {
    let mut out: Vec<(Symbol, String)> = Vec::new();
    let mut seen: HashSet<Symbol> = HashSet::new();
    walk(body, &mut |e| match e {
        Expr::SuperAssign { target, .. } => {
            if let Some(s) = base_sym(target) {
                if seen.insert(s) {
                    out.push((s, deparse(e)));
                }
            }
        }
        Expr::Call { args, .. } if e.call_name() == Some("assign") => {
            if let Some(Arg { name: None, value: Expr::Str(n) }) = args.first() {
                let s = Symbol::from(n.as_str());
                if seen.insert(s) {
                    out.push((s, deparse(e)));
                }
            }
        }
        _ => {}
    });
    out
}

/// Symbols the body *reads*. Plain assignment targets are writes, not
/// reads; an `x[i] <- v` or `x$f <<- v` target reads its base object
/// (read-modify-write), so those do count.
fn collect_reads(e: &Expr, reads: &mut HashSet<Symbol>) {
    match e {
        Expr::Sym(s) => {
            reads.insert(*s);
        }
        Expr::Assign { target, value } | Expr::SuperAssign { target, value } => {
            if !matches!(&**target, Expr::Sym(_)) {
                collect_reads(target, reads);
            }
            collect_reads(value, reads);
        }
        // Recurse by hand (not via `walk`) so nested assignments keep
        // their write/read distinction.
        _ => collect_reads_children(e, reads),
    }
}

fn collect_reads_children(e: &Expr, reads: &mut HashSet<Symbol>) {
    match e {
        Expr::Call { func, args } => {
            collect_reads(func, reads);
            for a in args {
                collect_reads(&a.value, reads);
            }
        }
        Expr::Function { params, body } => {
            for p in params {
                if let Some(d) = &p.default {
                    collect_reads(d, reads);
                }
            }
            collect_reads(body, reads);
        }
        Expr::Block(es) => {
            for x in es {
                collect_reads(x, reads);
            }
        }
        Expr::If { cond, then, els } => {
            collect_reads(cond, reads);
            collect_reads(then, reads);
            if let Some(x) = els {
                collect_reads(x, reads);
            }
        }
        Expr::For { seq, body, .. } => {
            collect_reads(seq, reads);
            collect_reads(body, reads);
        }
        Expr::While { cond, body } => {
            collect_reads(cond, reads);
            collect_reads(body, reads);
        }
        Expr::Index { obj, args, .. } => {
            collect_reads(obj, reads);
            for a in args {
                collect_reads(&a.value, reads);
            }
        }
        Expr::Dollar { obj, .. } => collect_reads(obj, reads),
        _ => {}
    }
}

// ---------------------------------------------------------------------------
// Body detectors (shared by the runtime hook and the CLI)
// ---------------------------------------------------------------------------

/// Run the body-level detectors (FZ001, FZ002, FZ003) over one map
/// function. `resolve` answers "does this free variable resolve to a
/// value at freeze time?" — captured bindings plus explicit globals at
/// runtime, top-level script definitions in the CLI.
pub fn analyze_body(
    params: &[Param],
    body: &Expr,
    seed_on: bool,
    resolve: &dyn Fn(&str) -> bool,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();

    // FZ001 — cross-iteration dependence.
    let writes = escaping_writes(body);
    if !writes.is_empty() {
        let mut reads: HashSet<Symbol> = HashSet::new();
        collect_reads(body, &mut reads);
        for (name, snippet) in &writes {
            if reads.contains(name) {
                diags.push(Diagnostic::new(
                    DiagCode::CrossIterationDependence,
                    snippet.clone(),
                    format!(
                        "the body writes `{name}` into an enclosing frame and also reads \
                         it, so element i depends on element i-1 — a parallel map cannot \
                         honor that ordering (each worker sees its own copy)"
                    ),
                    "return per-element values and fold them in the parent \
                     (e.g. sum(...), Reduce(...), or futurize(reduce = \"exact\"))",
                ));
            }
        }
    }

    // FZ002 — non-reproducible RNG.
    if !seed_on {
        let mut rng_names: Vec<&'static str> = Vec::new();
        let mut first_snippet: Option<String> = None;
        walk(body, &mut |e| {
            if let Some(name) = e.call_name() {
                if let Some(hit) = RNG_BUILTINS.iter().copied().find(|b| *b == name) {
                    if !rng_names.contains(&hit) {
                        rng_names.push(hit);
                    }
                    if first_snippet.is_none() {
                        first_snippet = Some(deparse(e));
                    }
                }
            }
        });
        if let Some(snippet) = first_snippet {
            diags.push(Diagnostic::new(
                DiagCode::NonReproducibleRng,
                snippet,
                format!(
                    "the body draws random numbers ({}) without `seed = TRUE`, so \
                     results are irreproducible and statistically unsound across \
                     workers",
                    rng_names.join(", ")
                ),
                "pass seed = TRUE (or seed = <int>) to futurize() for per-element \
                 L'Ecuyer streams",
            ));
        }
    }

    // FZ003 — unresolvable globals, reported at the parent instead of
    // as a worker-side "object not found" error.
    let body_fn =
        Expr::Function { params: params.to_vec(), body: Box::new(body.clone()) };
    for sym in free_variables(&body_fn) {
        let name = sym.as_str();
        if name == "..." || builtins::lookup_builtin(name).is_some() || resolve(name) {
            continue;
        }
        diags.push(Diagnostic::new(
            DiagCode::UnresolvableGlobal,
            name,
            format!(
                "`{name}` resolves to nothing at freeze time; the worker would fail \
                 with \"object '{name}' not found\""
            ),
            format!(
                "define `{name}` before the futurize() call or export it explicitly \
                 via futurize(globals = c(\"{name}\"))"
            ),
        ));
    }

    diags
}

// ---------------------------------------------------------------------------
// Runtime entry points (called from future_core::dispatch at freeze time)
// ---------------------------------------------------------------------------

/// Analyze one frozen map call: the wire closure, its extra arguments,
/// explicit globals, whether kernel fusion matched, and the map
/// options (seed + distilled lint/reduce facts).
pub fn analyze_map(
    f: &WireVal,
    extra: &[(Option<String>, WireVal)],
    globals: &[(String, WireVal)],
    kernel_attached: bool,
    opts: &MapOptions,
) -> Vec<Diagnostic> {
    let seed_on = !matches!(opts.seed, SeedOption::False);
    let mut diags = Vec::new();

    if let WireVal::Closure { params, body, captured } = f {
        let resolve = |name: &str| {
            captured.iter().any(|(n, _)| n == name)
                || globals.iter().any(|(n, _)| n == name)
                || extra.iter().any(|(n, _)| n.as_deref() == Some(name))
        };
        diags.extend(analyze_body(params, body, seed_on, &resolve));
    }

    // FZ004 — oversized capture/global export.
    let export: usize = f.approx_size()
        + globals.iter().map(|(n, v)| n.len() + v.approx_size()).sum::<usize>()
        + extra.iter().map(|(_, v)| v.approx_size()).sum::<usize>();
    if export > OVERSIZE_BYTES {
        let largest = largest_binding(f, globals);
        diags.push(Diagnostic::new(
            DiagCode::OversizedCapture,
            largest.clone().unwrap_or_else(|| "<captures>".into()),
            format!(
                "the frozen closure exports ~{:.1} MiB to every worker{} — likely a \
                 dataset captured by the closure instead of chunked as map items",
                export as f64 / (1024.0 * 1024.0),
                largest
                    .map(|n| format!(" (largest binding: `{n}`)"))
                    .unwrap_or_default()
            ),
            "pass large inputs as map items (they chunk and ship once per worker), \
             slim the captured environment, or rely on the data-plane cache \
             (cache = \"auto\", on by default): oversized exports ship as \
             content-addressed blobs once per worker and repeat calls send only \
             digests",
        ));
    }

    // FZ009 — data-plane cache activity (Info: shown by the lint CLI
    // and `fusion_report()`, never relayed). Mirrors the freeze-time
    // extraction rule in `future_core::dispatch`: exports at or over
    // the blob threshold ride the cache on process backends.
    if opts.cache && crate::backend::blobstore::cache_enabled() {
        let cacheable: Vec<&str> = globals
            .iter()
            .filter(|(_, v)| v.approx_size() >= crate::backend::blobstore::CACHE_MIN_BYTES)
            .map(|(n, _)| n.as_str())
            .collect();
        if !cacheable.is_empty() {
            let names =
                cacheable.iter().map(|s| format!("`{s}`")).collect::<Vec<_>>().join(", ");
            diags.push(Diagnostic::new(
                DiagCode::CacheReport,
                cacheable[0].to_string(),
                format!(
                    "data-plane cache: {} oversized export(s) ({names}) ship as \
                     content-addressed blobs — once per worker, referenced by \
                     digest on repeat calls (session counters: {} puts, {} hits, \
                     {} misses)",
                    cacheable.len(),
                    crate::wire::stats::cache_puts(),
                    crate::wire::stats::cache_hits(),
                    crate::wire::stats::cache_misses(),
                ),
                "cache = \"auto\" is the default; futurize(cache = \"off\") or \
                 FUTURIZE_NO_CACHE=1 disables it for differential testing",
            ));
        }
    }

    diags.extend(reduction_diags(opts));

    // FZ007 — explain why kernel fusion rejected this body, for the
    // blockers a user can actually act on.
    if !kernel_attached && fusion::enabled() {
        match fusion::classify_rejection(f, extra, globals) {
            RejectReason::Params => diags.push(Diagnostic::new(
                DiagCode::KernelFusionRejected,
                closure_head(f),
                "kernel fusion rejected this body: parameter list uses `...` or is \
                 empty, so arguments cannot be statically bound",
                "use explicitly named parameters",
            )),
            RejectReason::NamedArgs => diags.push(Diagnostic::new(
                DiagCode::KernelFusionRejected,
                closure_head(f),
                "kernel fusion rejected this body: a call passes named arguments, \
                 which the kernel catalog does not model",
                "pass arguments positionally inside the map body",
            )),
            RejectReason::EnvMutation => diags.push(Diagnostic::new(
                DiagCode::KernelFusionRejected,
                closure_head(f),
                "kernel fusion rejected this body: it mutates an enclosing \
                 environment (`<<-`/`assign`), which kernels cannot replay",
                "make the body a pure function of its element",
            )),
            RejectReason::Shadowed => diags.push(Diagnostic::new(
                DiagCode::KernelFusionRejected,
                closure_head(f),
                "kernel fusion rejected this body: an arithmetic builtin is \
                 shadowed by a local binding, so calls carry user semantics",
                "rename the shadowing binding if builtin semantics were intended",
            )),
            RejectReason::NotClosure | RejectReason::Shape => {}
        }
    }

    diags
}

/// Analyze one frozen foreach call (the body is a bare expression, the
/// iteration variables arrive as per-element bindings).
pub fn analyze_foreach(
    body: &Expr,
    binding_names: &[String],
    globals: &[(String, WireVal)],
    opts: &MapOptions,
) -> Vec<Diagnostic> {
    let seed_on = !matches!(opts.seed, SeedOption::False);
    let params: Vec<Param> = binding_names
        .iter()
        .map(|n| Param { name: Symbol::from(n.as_str()), default: None })
        .collect();
    let resolve = |name: &str| globals.iter().any(|(n, _)| n == name);
    let mut diags = analyze_body(&params, body, seed_on, &resolve);
    diags.extend(reduction_diags(opts));
    diags
}

/// FZ005/FZ006/FZ008 — reduction-order findings shared by map and
/// foreach, from the facts `to_map_options`/`do_future` distilled into
/// `opts.lint`.
fn reduction_diags(opts: &MapOptions) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if opts.lint.assoc_requested {
        if let Some(combine) = &opts.lint.nonassoc_combine {
            diags.push(Diagnostic::new(
                DiagCode::OrderDependentReduction,
                combine.clone(),
                format!(
                    "`{combine}` cannot be proven associative, and reduce = \"assoc\" \
                     reassociates the fold across chunks — the result becomes \
                     chunking-order dependent"
                ),
                "use reduce = \"exact\" (order-preserving) or a builtin associative \
                 combine (+, *, min, max, c)",
            ));
        }
    }
    if let Some(spec) = &opts.reduce {
        if spec.plan.assoc
            && matches!(
                spec.plan.op,
                ReduceOp::Sum | ReduceOp::Prod | ReduceOp::Mean | ReduceOp::Add | ReduceOp::Mul
            )
        {
            diags.push(Diagnostic::new(
                DiagCode::FloatFoldUlp,
                spec.plan.op.source_name(),
                "floating-point fold under reduce = \"assoc\": workers reassociate \
                 the accumulation, so the result may differ from sequential order \
                 in the last ULPs (documented contract)",
                "use reduce = \"exact\" if bit-identical results are required",
            ));
        }
    }
    if let Some(reason) = &opts.lint.reduce_rejected {
        diags.push(Diagnostic::new(
            DiagCode::ReduceFusionRejected,
            opts.lint.reduce_op.clone().unwrap_or_else(|| "reduce".into()),
            format!("reduction fusion rejected this call: {reason}; workers ship full \
                 per-element results instead of O(1) partials"),
            "check fusion_report() for counters; the fallback path is exact but \
             ships O(n) result bytes",
        ));
    }
    diags
}

fn closure_head(f: &WireVal) -> String {
    match f {
        WireVal::Closure { params, .. } => format!(
            "function({})",
            params.iter().map(|p| p.name.as_str()).collect::<Vec<_>>().join(", ")
        ),
        WireVal::Builtin(n) => n.clone(),
        _ => "<function>".into(),
    }
}

fn largest_binding(f: &WireVal, globals: &[(String, WireVal)]) -> Option<String> {
    let captured: &[(String, WireVal)] = match f {
        WireVal::Closure { captured, .. } => captured,
        _ => &[],
    };
    captured
        .iter()
        .chain(globals.iter())
        .max_by_key(|(_, v)| v.approx_size())
        .map(|(n, _)| n.clone())
}

/// Surface findings per the effective mode. Warn-level and above only
/// (Info findings are for the CLI and `fusion_report()`):
///
/// - `Error` → one classed `FuturizeLintError` raised immediately,
///   joining every finding, *before* any backend/worker exists;
/// - `Warn` → each finding relayed once per map call as a classed
///   `FuturizeLintWarning` through the ordered condition machinery;
/// - `Off` → nothing.
pub fn surface(
    i: &mut Interp,
    diags: &[Diagnostic],
    mode: LintMode,
) -> Result<(), Signal> {
    let actionable: Vec<&Diagnostic> =
        diags.iter().filter(|d| d.level >= LintLevel::Warn).collect();
    if actionable.is_empty() || mode == LintMode::Off {
        return Ok(());
    }
    match mode {
        LintMode::Error => {
            let joined =
                actionable.iter().map(|d| d.render()).collect::<Vec<_>>().join("\n  ");
            let mut cond = RCondition::error_cond(format!("futurize lint: {joined}"));
            cond.classes = vec![
                "FuturizeLintError".into(),
                "FutureError".into(),
                "error".into(),
                "condition".into(),
            ];
            Err(Signal::Error(cond))
        }
        _ => {
            for d in actionable {
                let mut cond =
                    RCondition::warning_cond(format!("futurize lint: {}", d.render()));
                cond.classes = vec![
                    "FuturizeLintWarning".into(),
                    "warning".into(),
                    "condition".into(),
                ];
                i.signal_condition(cond)?;
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// Script-level analysis (the `futurize-rs lint` CLI)
// ---------------------------------------------------------------------------

/// One analyzed `futurize()` call site in a script.
#[derive(Clone, Debug)]
pub struct ScriptFinding {
    /// 1-based top-level statement index.
    pub stmt: usize,
    /// Deparsed futurize call (for the report header).
    pub call: String,
    pub diags: Vec<Diagnostic>,
}

/// Heads whose first argument is "the thing being reduced/unwrapped" —
/// the analyzer descends through them to find the map call.
const UNWRAP_HEADS: &[&str] = &[
    "unlist",
    "suppressWarnings",
    "suppressMessages",
    "sum",
    "prod",
    "mean",
    "min",
    "max",
    "length",
    "any",
    "all",
];

/// Map-family heads: `(items, fn, ...)` — the function is the second
/// positional argument.
const MAP_HEADS: &[&str] = &[
    "lapply",
    "sapply",
    "vapply",
    "map",
    "map_dbl",
    "map_chr",
    "map_lgl",
    "map_int",
    "walk",
    "llply",
    "bplapply",
    "xmap",
    "xmap_dbl",
    "xmap_chr",
    "xwalk",
    "future_lapply",
    "future_sapply",
    "future_vapply",
    "future_map",
    "future_map_dbl",
    "future_map_chr",
    "future_map_lgl",
    "future_map_int",
    "future_walk",
    "future_xmap",
    "future_xmap_dbl",
    "future_xmap_chr",
    "future_xwalk",
];

/// Combines provably associative for FZ005 purposes.
const ASSOC_COMBINES: &[&str] = &["+", "*", "min", "max", "c", "sum", "prod"];

/// Statically analyze a whole script: find every `futurize()` call,
/// locate the map expression under it, and run the freeze-time
/// detectors against top-level definitions. Purely syntactic — no
/// session, no workers. Used by `futurize-rs lint`.
pub fn lint_source(src: &str) -> Result<Vec<ScriptFinding>, String> {
    let prog = crate::rlite::parse_program(src)?;

    // Pass 1: top-level bindings are what free variables can resolve
    // to at freeze time; keep function literals for indirect bodies
    // (`f <- function(x) ...; lapply(xs, f) |> futurize()`).
    let mut defined: HashSet<String> = HashSet::new();
    let mut fns: HashMap<String, (Vec<Param>, Expr)> = HashMap::new();
    for e in &prog {
        if let Expr::Assign { target, value } = e {
            if let Expr::Sym(s) = &**target {
                defined.insert(s.as_str().to_string());
                if let Expr::Function { params, body } = &**value {
                    fns.insert(s.as_str().to_string(), (params.clone(), (**body).clone()));
                }
            }
        }
    }

    // Pass 2: analyze every futurize() call, wherever it nests.
    let mut findings: Vec<ScriptFinding> = Vec::new();
    for (idx, stmt) in prog.iter().enumerate() {
        walk(stmt, &mut |e| {
            if e.call_name() != Some("futurize") {
                return;
            }
            if let Some(diags) = lint_futurize_call(e, &defined, &fns) {
                if !diags.is_empty() {
                    findings.push(ScriptFinding {
                        stmt: idx + 1,
                        call: deparse(e),
                        diags,
                    });
                }
            }
        });
    }
    Ok(findings)
}

/// Literal options of one futurize() call the static pass understands.
#[derive(Default)]
struct CallOpts {
    seed_on: bool,
    reduce: Option<String>,
    lint: Option<String>,
}

fn literal_opts(args: &[Arg]) -> CallOpts {
    let mut o = CallOpts::default();
    for a in args {
        let Some(name) = a.name.as_deref() else { continue };
        let key = name.trim_start_matches("future.").replace(['.', '-'], "_");
        match (key.as_str(), &a.value) {
            ("seed", Expr::Bool(b)) => o.seed_on = *b,
            ("seed", Expr::Int(_) | Expr::Num(_)) => o.seed_on = true,
            ("reduce", Expr::Str(s)) => o.reduce = Some(s.clone()),
            ("lint", Expr::Str(s)) => o.lint = Some(s.clone()),
            _ => {}
        }
    }
    o
}

/// Analyze one `futurize(<expr>, opts...)` call. Returns `None` when
/// linting is off for this call or no analyzable map shape was found.
fn lint_futurize_call(
    call: &Expr,
    defined: &HashSet<String>,
    fns: &HashMap<String, (Vec<Param>, Expr)>,
) -> Option<Vec<Diagnostic>> {
    let (_, args) = call.as_call()?;
    let target = &args.iter().find(|a| a.name.is_none())?.value;
    let opts = literal_opts(args);

    let mode = crate::rlite::diag::effective_mode(
        opts.lint.as_deref().and_then(LintMode::parse).unwrap_or_default(),
    );
    if mode == LintMode::Off {
        return None;
    }

    let mut diags = Vec::new();
    let assoc = opts.reduce.as_deref() == Some("assoc");

    // Descend through reduction/unwrap heads to the map call.
    let mut cur = target;
    let mut fold_head: Option<&str> = None;
    loop {
        let Some(name) = cur.call_name() else { break };
        let (_, cargs) = cur.as_call()?;
        if UNWRAP_HEADS.contains(&name) {
            if matches!(name, "sum" | "prod" | "mean") {
                fold_head = Some(name);
            }
            cur = &cargs.iter().find(|a| a.name.is_none())?.value;
            continue;
        }
        if name == "Reduce" {
            let mut pos = cargs.iter().filter(|a| a.name.is_none());
            let combine = &pos.next()?.value;
            let inner = &pos.next()?.value;
            match combine {
                Expr::Sym(s) if ASSOC_COMBINES.contains(&s.as_str()) => {
                    if matches!(s.as_str(), "+" | "*" | "sum" | "prod") {
                        fold_head = Some("Reduce");
                    }
                }
                _ if assoc => diags.push(Diagnostic::new(
                    DiagCode::OrderDependentReduction,
                    deparse(combine),
                    "`Reduce` uses a combine that cannot be proven associative while \
                     reduce = \"assoc\" reassociates the fold across chunks",
                    "use reduce = \"exact\" or a builtin associative combine \
                     (+, *, min, max, c)",
                )),
                _ => {}
            }
            cur = inner;
            continue;
        }
        break;
    }

    if assoc && fold_head.is_some() {
        diags.push(Diagnostic::new(
            DiagCode::FloatFoldUlp,
            fold_head.unwrap_or("sum"),
            "floating-point fold under reduce = \"assoc\": workers reassociate the \
             accumulation, so results may differ in the last ULPs",
            "use reduce = \"exact\" if bit-identical results are required",
        ));
    }

    // Locate the map body.
    let resolve = |name: &str| defined.contains(name);
    let shape = map_shape(cur, fns);
    match shape {
        Some(MapShape::Fn { params, body, seed_default }) => {
            diags.extend(analyze_body(
                &params,
                &body,
                opts.seed_on || seed_default,
                &resolve,
            ));
        }
        Some(MapShape::Foreach { bindings, body, combine }) => {
            let params: Vec<Param> = bindings
                .iter()
                .map(|n| Param { name: Symbol::from(n.as_str()), default: None })
                .collect();
            diags.extend(analyze_body(&params, &body, opts.seed_on, &resolve));
            if assoc {
                if let Some(c) = combine {
                    if !ASSOC_COMBINES.contains(&c.as_str()) {
                        diags.push(Diagnostic::new(
                            DiagCode::OrderDependentReduction,
                            c,
                            "`.combine` cannot be proven associative while \
                             reduce = \"assoc\" reassociates the fold across chunks",
                            "use reduce = \"exact\" or a builtin associative combine \
                             (+, *, min, max, c)",
                        ));
                    }
                }
            }
        }
        None => {
            if diags.is_empty() {
                return None;
            }
        }
    }
    Some(diags)
}

enum MapShape {
    Fn { params: Vec<Param>, body: Expr, seed_default: bool },
    Foreach { bindings: Vec<String>, body: Expr, combine: Option<String> },
}

/// Recognize the map call itself and extract the analyzable body.
fn map_shape(e: &Expr, fns: &HashMap<String, (Vec<Param>, Expr)>) -> Option<MapShape> {
    let name = e.call_name()?;
    let (_, args) = e.as_call()?;

    if MAP_HEADS.contains(&name) {
        let f = &args.iter().filter(|a| a.name.is_none()).nth(1)?.value;
        let (params, body) = fn_literal(f, fns)?;
        return Some(MapShape::Fn { params, body, seed_default: false });
    }
    if name == "replicate" || name == "times" {
        // replicate(n, body): the body is the second positional arg and
        // runs under seed-by-default semantics (resampling APIs).
        let body = args.iter().filter(|a| a.name.is_none()).nth(1)?.value.clone();
        return Some(MapShape::Fn { params: Vec::new(), body, seed_default: true });
    }
    if matches!(name, "%do%" | "%dopar%" | "%dofuture%") {
        let mut pos = args.iter().filter(|a| a.name.is_none());
        let lhs = &pos.next()?.value;
        let body = pos.next()?.value.clone();
        if lhs.call_name() == Some("times") {
            return Some(MapShape::Fn { params: Vec::new(), body, seed_default: true });
        }
        if lhs.call_name() != Some("foreach") {
            return None;
        }
        let (_, fargs) = lhs.as_call()?;
        let mut bindings = Vec::new();
        let mut combine = None;
        for a in fargs {
            match a.name.as_deref() {
                Some(".combine") => {
                    combine = match &a.value {
                        Expr::Sym(s) => Some(s.as_str().to_string()),
                        Expr::Str(s) => Some(s.clone()),
                        other => Some(deparse(other)),
                    };
                }
                Some(n) if !n.starts_with('.') => bindings.push(n.to_string()),
                _ => {}
            }
        }
        return Some(MapShape::Foreach { bindings, body, combine });
    }
    None
}

fn fn_literal(
    e: &Expr,
    fns: &HashMap<String, (Vec<Param>, Expr)>,
) -> Option<(Vec<Param>, Expr)> {
    match e {
        Expr::Function { params, body } => Some((params.clone(), (**body).clone())),
        Expr::Sym(s) => fns.get(s.as_str()).cloned(),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::parse_expr;

    fn closure(src: &str, captured: Vec<(String, WireVal)>) -> WireVal {
        let Expr::Function { params, body } = parse_expr(src).unwrap() else {
            panic!("not a function: {src}");
        };
        WireVal::Closure { params, body: *body, captured }
    }

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code.as_str()).collect()
    }

    fn body_diags(src: &str, seed_on: bool, defined: &[&str]) -> Vec<Diagnostic> {
        let Expr::Function { params, body } = parse_expr(src).unwrap() else {
            panic!("not a function: {src}");
        };
        analyze_body(&params, &body, seed_on, &|n| defined.contains(&n))
    }

    #[test]
    fn fz001_fires_on_read_write_superassign_only() {
        let d = body_diags("function(x) { total <<- total + x\ntotal }", false, &["total"]);
        assert_eq!(codes(&d), vec!["FZ001"], "{d:?}");
        assert!(d[0].render().contains("total <<- total + x"), "{}", d[0].render());
        // Write-only superassign (no read of the binding) is not a
        // cross-iteration dependence.
        let d = body_diags("function(x) { last <<- x\nx * 2 }", false, &["last"]);
        assert!(codes(&d).is_empty(), "{d:?}");
        // assign() form.
        let d = body_diags(
            "function(x) assign(\"acc\", acc + x)",
            false,
            &["acc"],
        );
        assert_eq!(codes(&d), vec!["FZ001"], "{d:?}");
        // Indexed super-assignment is a read-modify-write.
        let d = body_diags("function(x) out[[x]] <<- x * 2", false, &["out"]);
        assert_eq!(codes(&d), vec!["FZ001"], "{d:?}");
    }

    #[test]
    fn fz002_respects_seed_flag() {
        let d = body_diags("function(x) runif(1) * x", false, &[]);
        assert_eq!(codes(&d), vec!["FZ002"], "{d:?}");
        assert!(d[0].message.contains("runif"), "{}", d[0].message);
        let d = body_diags("function(x) runif(1) * x", true, &[]);
        assert!(codes(&d).is_empty(), "{d:?}");
        // Plain local assignment is not RNG and not FZ001.
        let d = body_diags("function(x) { y <- x + 1\ny }", false, &[]);
        assert!(codes(&d).is_empty(), "{d:?}");
    }

    #[test]
    fn fz003_reports_missing_globals_at_parent() {
        let d = body_diags("function(x) scale * x", false, &[]);
        assert_eq!(codes(&d), vec!["FZ003"], "{d:?}");
        assert!(d[0].message.contains("scale"), "{}", d[0].message);
        let d = body_diags("function(x) scale * x", false, &["scale"]);
        assert!(codes(&d).is_empty(), "{d:?}");
        // Builtins and locally-assigned names never fire.
        let d = body_diags("function(x) { y <- sum(x)\nsqrt(y) }", false, &[]);
        assert!(codes(&d).is_empty(), "{d:?}");
    }

    #[test]
    fn fz004_flags_oversized_capture() {
        let big = WireVal::Dbl(vec![0.0; (OVERSIZE_BYTES / 8) + 16], None);
        let f = closure("function(x) x + big", vec![("big".to_string(), big)]);
        let opts = MapOptions::default();
        let d = analyze_map(&f, &[], &[], false, &opts);
        assert!(codes(&d).contains(&"FZ004"), "{d:?}");
        let small = closure(
            "function(x) x + k",
            vec![("k".to_string(), WireVal::Dbl(vec![1.0], None))],
        );
        let d = analyze_map(&small, &[], &[], false, &opts);
        assert!(!codes(&d).contains(&"FZ004"), "{d:?}");
    }

    #[test]
    fn fz007_explains_env_mutation_rejection() {
        let f = closure(
            "function(x) { cnt <<- cnt + 1\nx * 2 }",
            vec![("cnt".to_string(), WireVal::Dbl(vec![0.0], None))],
        );
        let d = analyze_map(&f, &[], &[], false, &MapOptions::default());
        assert!(codes(&d).contains(&"FZ001"), "{d:?}");
        if fusion::enabled() {
            let info: Vec<_> =
                d.iter().filter(|x| x.code == DiagCode::KernelFusionRejected).collect();
            assert_eq!(info.len(), 1, "{d:?}");
            assert!(info[0].message.contains("mutates"), "{}", info[0].message);
            assert_eq!(info[0].level, LintLevel::Info);
        }
    }

    #[test]
    fn lint_source_finds_dirty_and_passes_clean() {
        let dirty = "
            total <- 0
            xs <- c(1, 2, 3)
            r <- lapply(xs, function(x) {
              total <<- total + x
              runif(1) * total
            }) |> futurize()
        ";
        let f = lint_source(dirty).unwrap();
        assert_eq!(f.len(), 1, "{f:?}");
        let c = codes(&f[0].diags);
        assert!(c.contains(&"FZ001") && c.contains(&"FZ002"), "{c:?}");

        let clean = "
            scale <- 2
            xs <- c(1, 2, 3)
            r <- lapply(xs, function(x) x * scale) |> futurize()
            d <- replicate(4, rnorm(2)) |> futurize()
        ";
        assert!(lint_source(clean).unwrap().is_empty());
    }

    #[test]
    fn lint_source_handles_foreach_and_indirect_fn() {
        let src = "
            f <- function(x) missing_thing + x
            r <- (foreach(x = 1:3, .combine = c) %dofuture% { f(x) }) |> futurize()
            s <- lapply(1:3, f) |> futurize()
        ";
        let f = lint_source(src).unwrap();
        // Both call sites flag the missing global inside `f`'s body.
        assert_eq!(f.len(), 1, "{f:?}"); // foreach body calls f (resolves); only lapply(f) descends
        assert!(codes(&f[0].diags).contains(&"FZ003"), "{f:?}");

        let combine = "
            r <- (foreach(x = 1:3, .combine = mycomb) %dofuture% { x * 2 }) \
                |> futurize(reduce = \"assoc\")
        ";
        let f = lint_source(combine).unwrap();
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(codes(&f[0].diags).contains(&"FZ005"), "{f:?}");
    }

    #[test]
    fn lint_source_respects_per_call_off() {
        let src = "
            total <- 0
            r <- lapply(1:3, function(x) { total <<- total + x\ntotal }) \
                |> futurize(lint = \"off\")
        ";
        if std::env::var(crate::rlite::diag::LINT_ENV).is_err() {
            assert!(lint_source(src).unwrap().is_empty());
        }
    }
}
