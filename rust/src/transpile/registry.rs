//! The transpiler registry — one entry per function in the paper's
//! Table 1 (map-reduce APIs) and Table 2 (domain-specific APIs).

use std::collections::HashMap;

use super::{
    dofuture_option_args, domain_option_args, furrr_option_args, future_dot_args,
    FuturizeOptions, SeedSetting, TranspilerFn,
};
use crate::rlite::ast::{Arg, Expr};

/// Build the full registry.
pub fn build() -> HashMap<(&'static str, &'static str), TranspilerFn> {
    let mut m: HashMap<(&'static str, &'static str), TranspilerFn> = HashMap::new();

    // ---- Table 1: base R → future.apply ---------------------------------
    for name in BASE_FUNCTIONS {
        m.insert(("base", name), base_transpiler as TranspilerFn);
    }
    m.insert(("stats", "kernapply"), base_transpiler as TranspilerFn);

    // ---- Table 1: purrr → furrr ------------------------------------------
    for name in PURRR_FUNCTIONS {
        m.insert(("purrr", name), purrr_transpiler as TranspilerFn);
    }

    // ---- Table 1: crossmap (futurizes itself) ----------------------------
    for name in CROSSMAP_FUNCTIONS {
        m.insert(("crossmap", name), crossmap_transpiler as TranspilerFn);
    }

    // ---- Table 1: foreach %do% → %dofuture% ------------------------------
    m.insert(("foreach", "%do%"), foreach_transpiler as TranspilerFn);

    // ---- Table 1: plyr → .parallel = TRUE + doFuture ----------------------
    for name in PLYR_FUNCTIONS {
        m.insert(("plyr", name), plyr_transpiler as TranspilerFn);
    }

    // ---- Table 1: BiocParallel → FutureParam -----------------------------
    for name in BIOCPARALLEL_FUNCTIONS {
        m.insert(("BiocParallel", name), biocparallel_transpiler as TranspilerFn);
    }

    // ---- Table 2: domain-specific packages --------------------------------
    for name in ["boot", "censboot", "tsboot"] {
        m.insert(("boot", name), domain_seeded_transpiler as TranspilerFn);
    }
    for name in ["bag", "gafs", "nearZeroVar", "rfe", "safs", "sbf", "train"] {
        m.insert(("caret", name), domain_transpiler as TranspilerFn);
    }
    m.insert(("glmnet", "cv.glmnet"), domain_transpiler as TranspilerFn);
    for name in ["allFit", "bootMer"] {
        m.insert(("lme4", name), domain_seeded_transpiler as TranspilerFn);
    }
    for name in ["bam", "predict.bam"] {
        m.insert(("mgcv", name), domain_transpiler as TranspilerFn);
    }
    for name in ["TermDocumentMatrix", "tm_index", "tm_map"] {
        m.insert(("tm", name), domain_transpiler as TranspilerFn);
    }

    m
}

/// base-R functions transpiled to future.apply (paper Table 1 row 1).
pub const BASE_FUNCTIONS: &[&str] = &[
    "lapply", "sapply", "tapply", "vapply", "mapply", ".mapply", "Map", "eapply", "apply", "by",
    "replicate", "Filter",
];

/// purrr functions transpiled to furrr (Table 1).
pub const PURRR_FUNCTIONS: &[&str] = &[
    "map", "map_chr", "map_dbl", "map_int", "map_lgl", "map2", "map2_chr", "map2_dbl",
    "map2_int", "map2_lgl", "pmap", "pmap_dbl", "pmap_chr", "imap", "imap_dbl", "imap_chr",
    "modify", "modify_if", "modify_at", "map_if", "map_at", "invoke_map", "walk",
];

/// crossmap functions (Table 1).
pub const CROSSMAP_FUNCTIONS: &[&str] = &[
    "xmap", "xmap_dbl", "xmap_chr", "xwalk", "map_vec", "map2_vec", "pmap_vec", "imap_vec",
];

/// plyr functions (Table 1).
pub const PLYR_FUNCTIONS: &[&str] = &[
    "aaply", "adply", "alply", "daply", "ddply", "dlply", "laply", "ldply", "llply", "maply",
    "mdply", "mlply",
];

/// BiocParallel functions (Table 1).
pub const BIOCPARALLEL_FUNCTIONS: &[&str] =
    &["bplapply", "bpmapply", "bpvec", "bpiterate", "bpaggregate"];

/// Functions whose futurization defaults to `seed = TRUE` because they
/// exist for resampling (paper §4.1: replicate; §4.3: times).
pub const SEED_DEFAULT_TRUE: &[&str] =
    &["replicate", "times", "boot", "censboot", "tsboot", "bootMer", "allFit"];

fn call_parts(expr: &Expr) -> Result<(&str, Vec<Arg>), String> {
    let name = expr.call_name().ok_or("not a call")?;
    match expr {
        Expr::Call { args, .. } => Ok((name, args.clone())),
        _ => Err("not a call".into()),
    }
}

/// Effective options: apply per-function seed defaults.
fn with_seed_default(name: &str, opts: &FuturizeOptions) -> FuturizeOptions {
    let mut o = opts.clone();
    if o.seed.is_none() && SEED_DEFAULT_TRUE.contains(&name) {
        o.seed = Some(SeedSetting::True);
    }
    o
}

/// base::lapply(xs, f) → future.apply::future_lapply(xs, f, future.seed=...).
fn base_transpiler(expr: &Expr, opts: &FuturizeOptions) -> Result<Expr, String> {
    let (name, mut args) = call_parts(expr)?;
    let opts = with_seed_default(name, opts);
    // `.mapply` keeps its dot: its dots-list signature differs from
    // `mapply`, so it has a dedicated future form.
    let target = format!("future_{name}");
    future_dot_args(&opts, &mut args);
    Ok(Expr::Call {
        func: Box::new(Expr::Ns {
            pkg: "future.apply".into(),
            name: target,
        }),
        args,
    })
}

/// purrr::map(xs, f) → furrr::future_map(xs, f, .options = furrr_options(...)).
fn purrr_transpiler(expr: &Expr, opts: &FuturizeOptions) -> Result<Expr, String> {
    let (name, mut args) = call_parts(expr)?;
    let opts = with_seed_default(name, opts);
    furrr_option_args(&opts, &mut args);
    Ok(Expr::Call {
        func: Box::new(Expr::Ns { pkg: "furrr".into(), name: format!("future_{name}") }),
        args,
    })
}

/// crossmap::xmap(...) → crossmap::future_xmap(...) (crossmap hosts its
/// own future variants; "Requires: (itself)" in Table 1).
fn crossmap_transpiler(expr: &Expr, opts: &FuturizeOptions) -> Result<Expr, String> {
    let (name, mut args) = call_parts(expr)?;
    let opts = with_seed_default(name, opts);
    furrr_option_args(&opts, &mut args);
    Ok(Expr::Call {
        func: Box::new(Expr::Ns { pkg: "crossmap".into(), name: format!("future_{name}") }),
        args,
    })
}

/// `foreach(...) %do% body` → `foreach(..., .options.future = list(...))
/// %dofuture% body`. Also handles `times(n) %do% body` (seed defaults to
/// TRUE for times, §4.3).
fn foreach_transpiler(expr: &Expr, opts: &FuturizeOptions) -> Result<Expr, String> {
    let Expr::Call { args, .. } = expr else { return Err("not a call".into()) };
    if args.len() != 2 {
        return Err("%do% expects lhs and rhs".into());
    }
    let lhs = &args[0].value;
    let body = args[1].value.clone();
    let lhs_name = lhs.call_name().unwrap_or("");
    let opts = with_seed_default(lhs_name, opts);
    // Attach options to the foreach()/times() call.
    let new_lhs = match lhs {
        Expr::Call { func, args: fargs } => {
            let mut fargs = fargs.clone();
            dofuture_option_args(&opts, &mut fargs);
            Expr::Call { func: func.clone(), args: fargs }
        }
        other => other.clone(),
    };
    Ok(Expr::call("%dofuture%", vec![Arg::pos(new_lhs), Arg::pos(body)]))
}

/// plyr::llply(...) → plyr::llply(..., .parallel = TRUE): plyr's own
/// sub-API, served by the doFuture adapter underneath.
fn plyr_transpiler(expr: &Expr, opts: &FuturizeOptions) -> Result<Expr, String> {
    let (_name, mut args) = call_parts(expr)?;
    args.push(Arg::named(".parallel", Expr::Bool(true)));
    domain_option_args(opts, &mut args);
    let Expr::Call { func, .. } = expr else { return Err("not a call".into()) };
    Ok(Expr::Call { func: func.clone(), args })
}

/// BiocParallel::bplapply(...) → bplapply(..., BPPARAM = FutureParam(...)).
fn biocparallel_transpiler(expr: &Expr, opts: &FuturizeOptions) -> Result<Expr, String> {
    let (_name, mut args) = call_parts(expr)?;
    let mut inner = Vec::new();
    if let Some(seed) = opts.seed {
        inner.push(Arg::named(
            "seed",
            match seed {
                SeedSetting::True => Expr::Bool(true),
                SeedSetting::False => Expr::Bool(false),
                SeedSetting::Value(v) => Expr::Num(v as f64),
            },
        ));
    }
    if let Some(cs) = opts.chunk_size {
        inner.push(Arg::named("chunk.size", Expr::Num(cs as f64)));
    }
    args.push(Arg::named("BPPARAM", Expr::ns_call("BiocParallel", "FutureParam", inner)));
    let Expr::Call { func, .. } = expr else { return Err("not a call".into()) };
    Ok(Expr::Call { func: func.clone(), args })
}

/// Domain functions: keep the call, inject the internal `.futurize_opts`
/// sub-API (the transpiler hides the package's own parallel/ncpus/cl
/// knobs, paper §4.6).
fn domain_transpiler(expr: &Expr, opts: &FuturizeOptions) -> Result<Expr, String> {
    let (_name, mut args) = call_parts(expr)?;
    domain_option_args(opts, &mut args);
    let Expr::Call { func, .. } = expr else { return Err("not a call".into()) };
    Ok(Expr::Call { func: func.clone(), args })
}

/// Domain functions that resample (boot, bootMer, ...): seed defaults to
/// TRUE.
fn domain_seeded_transpiler(expr: &Expr, opts: &FuturizeOptions) -> Result<Expr, String> {
    let (name, mut args) = call_parts(expr)?;
    let opts = with_seed_default(name, opts);
    domain_option_args(&opts, &mut args);
    let Expr::Call { func, .. } = expr else { return Err("not a call".into()) };
    Ok(Expr::Call { func: func.clone(), args })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_table1_and_table2() {
        let m = build();
        // Spot-check one function per Table-1 row and per Table-2 row.
        for key in [
            ("base", "lapply"),
            ("stats", "kernapply"),
            ("purrr", "map"),
            ("crossmap", "xmap"),
            ("foreach", "%do%"),
            ("plyr", "llply"),
            ("BiocParallel", "bplapply"),
            ("boot", "boot"),
            ("caret", "train"),
            ("glmnet", "cv.glmnet"),
            ("lme4", "allFit"),
            ("mgcv", "bam"),
            ("tm", "tm_map"),
        ] {
            assert!(m.contains_key(&key), "missing transpiler for {key:?}");
        }
    }

    #[test]
    fn registry_size_matches_tables() {
        let m = build();
        let expected = BASE_FUNCTIONS.len()
            + 1 // kernapply
            + PURRR_FUNCTIONS.len()
            + CROSSMAP_FUNCTIONS.len()
            + 1 // %do%
            + PLYR_FUNCTIONS.len()
            + BIOCPARALLEL_FUNCTIONS.len()
            + 3 // boot
            + 7 // caret
            + 1 // glmnet
            + 2 // lme4
            + 2 // mgcv
            + 3; // tm
        assert_eq!(m.len(), expected);
    }
}
