//! Worker-side reduction fusion (ISSUE 7): ship O(1) partial
//! aggregates instead of O(n) per-element results.
//!
//! When the transpiler recognizes that a map call's results feed a
//! known reduction (`sum(lapply(xs, f))`, `Reduce(min, ...)`,
//! `foreach(.combine = +)`), a [`ReducePlan`] rides the map's
//! [`TaskContext`](crate::future_core::TaskContext) alongside the PR 6
//! [`KernelPlan`](super::fusion::KernelPlan). The task runner then folds
//! each slice locally ([`fold_slice`]) and ships a constant-size
//! [`ReducePartial`] per chunk; the dispatch core merges partials in
//! chunk order as they stream in ([`ReduceState`]).
//!
//! ## Exactness contract
//!
//! Worker-side folding reassociates the reduction (per-chunk sub-folds
//! merged at the parent), so by default the fold only runs when
//! reassociation is bit-exact:
//!
//! - `sum`/`mean`/`+`: every operand integral and the running magnitude
//!   within f64's integer-exact range (|Σ|x|| ≤ 2^53) — integer and
//!   logical sums, exactly;
//! - `min`/`max`, `any`/`all`, length-style counts: always (NaN-ignoring
//!   f64 min/max and boolean folds are associative; mixed-sign zeros are
//!   rejected because reassociation could flip which zero wins);
//! - `c`: order-preserving concatenation of atomic, unnamed results
//!   (coercion is deferred to the parent merge, which replays rlite's
//!   own `c()` semantics).
//!
//! Anything else — `prod`/`*`, non-integral sums — only folds under
//! `futurize(reduce = "assoc")`, which accepts reassociated floating
//! point (results may differ from `plan(sequential)` in the last ULPs;
//! the magnitude of the difference is the usual pairwise-vs-sequential
//! summation error). A slice whose *values* fail the gate falls back to
//! shipping full results for that chunk; the parent folds those
//! elements in order, so a map where every chunk falls back is
//! bit-identical to the sequential path.

use std::sync::atomic::{AtomicU64, Ordering};

use serde_derive::{Deserialize, Serialize};

use crate::rlite::builtins::core::combine;
use crate::rlite::eval::{Interp, Signal};
use crate::rlite::serialize::WireVal;
use crate::rlite::value::RVal;

/// Largest double magnitude at which every integer is exactly
/// representable (2^53): the boundary of reassociation-exact integer
/// summation.
const EXACT_INT_MAX: f64 = 9_007_199_254_740_992.0;

// ---- trace counters ---------------------------------------------------------

static PLANS_ATTACHED: AtomicU64 = AtomicU64::new(0);
static SLICES_FOLDED: AtomicU64 = AtomicU64::new(0);
static SLICES_FALLBACK: AtomicU64 = AtomicU64::new(0);

/// Map calls that were dispatched with a reduction plan attached.
pub fn plans_attached() -> u64 {
    PLANS_ATTACHED.load(Ordering::Relaxed)
}

/// Slices folded worker-side into a partial aggregate (ticks in the
/// worker process; visible here for in-process backends).
pub fn slices_folded() -> u64 {
    SLICES_FOLDED.load(Ordering::Relaxed)
}

/// Slices whose values failed the exactness gate and shipped full
/// results instead.
pub fn slices_fallback() -> u64 {
    SLICES_FALLBACK.load(Ordering::Relaxed)
}

pub(crate) fn note_plan_attached() {
    PLANS_ATTACHED.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn note_slice_folded() {
    SLICES_FOLDED.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn note_slice_fallback() {
    SLICES_FALLBACK.fetch_add(1, Ordering::Relaxed);
}

// Plan-level rejections, by reason: "shadowed" — the fold's surface
// symbol no longer resolves to the genuine builtin in the calling
// environment; "not-in-catalog" — a reduce was requested but the
// recognized head/combine has no worker-side fold. (Slice-level
// exactness-gate fallbacks — the "vec-gate" — are `slices_fallback`.)
static PLANS_REJECTED_SHADOWED: AtomicU64 = AtomicU64::new(0);
static PLANS_REJECTED_CATALOG: AtomicU64 = AtomicU64::new(0);

/// Per-reason plan rejection counts `(label, count)`, in a stable
/// order. Exposed through `futurize::fusion_report()`.
pub fn plan_rejections() -> Vec<(&'static str, u64)> {
    vec![
        ("shadowed", PLANS_REJECTED_SHADOWED.load(Ordering::Relaxed)),
        ("not-in-catalog", PLANS_REJECTED_CATALOG.load(Ordering::Relaxed)),
        ("vec-gate", SLICES_FALLBACK.load(Ordering::Relaxed)),
    ]
}

pub(crate) fn note_plan_rejected_shadowed() {
    PLANS_REJECTED_SHADOWED.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn note_plan_rejected_catalog() {
    PLANS_REJECTED_CATALOG.fetch_add(1, Ordering::Relaxed);
}

// ---- plan -------------------------------------------------------------------

/// A reduction the workers may fold locally.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Serialize, Deserialize)]
pub enum ReduceOp {
    /// `sum(<map>)` — flat f64 fold seeded at 0.0 (mirrors `sum_fn`).
    Sum,
    /// `prod(<map>)` — flat f64 product seeded at 1.0 (assoc-only).
    Prod,
    /// `mean(<map>)` — `sum / flattened length`.
    Mean,
    /// `min(<map>)`, `Reduce(min, ...)`, `.combine = min`.
    Min,
    /// `max(<map>)`, `Reduce(max, ...)`, `.combine = max`.
    Max,
    /// `any(<map>)`.
    Any,
    /// `all(<map>)`.
    All,
    /// `length(<map>)` — the parent reconstructs the simplified length.
    Count,
    /// Pairwise `+` fold (`Reduce(+, ...)`, `.combine = +`).
    Add,
    /// Pairwise `*` fold (`Reduce(*, ...)`, `.combine = *`; assoc-only).
    Mul,
    /// Order-preserving `c()` (`Reduce(c, ...)`, `.combine = c`).
    Concat,
}

impl ReduceOp {
    /// Parse the `future.reduce.op` marker the transpiler injects (the
    /// recognized head or combine symbol, verbatim).
    pub fn parse(name: &str) -> Option<ReduceOp> {
        Some(match name {
            "sum" => ReduceOp::Sum,
            "prod" => ReduceOp::Prod,
            "mean" => ReduceOp::Mean,
            "min" => ReduceOp::Min,
            "max" => ReduceOp::Max,
            "any" => ReduceOp::Any,
            "all" => ReduceOp::All,
            "length" => ReduceOp::Count,
            "+" => ReduceOp::Add,
            "*" => ReduceOp::Mul,
            "c" => ReduceOp::Concat,
            _ => return None,
        })
    }

    /// The pairwise-merge builtin the parent replays for fold-style ops.
    fn pair_builtin(self) -> Option<&'static str> {
        match self {
            ReduceOp::Add => Some("+"),
            ReduceOp::Mul => Some("*"),
            ReduceOp::Min => Some("min"),
            ReduceOp::Max => Some("max"),
            _ => None,
        }
    }

    /// The surface symbol of the kept outer call this op stands in for.
    pub fn source_name(self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::Prod => "prod",
            ReduceOp::Mean => "mean",
            ReduceOp::Min => "min",
            ReduceOp::Max => "max",
            ReduceOp::Any => "any",
            ReduceOp::All => "all",
            ReduceOp::Count => "length",
            ReduceOp::Add => "+",
            ReduceOp::Mul => "*",
            ReduceOp::Concat => "c",
        }
    }
}

/// True when the symbols the fused fold stands in for no longer resolve
/// to the genuine builtins in `env` — a user shadowing. The kept outer
/// call then carries user semantics and must receive the full
/// per-element results (the fallback path is exact by construction).
pub fn shadowed(env: &crate::rlite::env::EnvRef, spec: &ReduceSpec) -> bool {
    let mut names = vec![spec.plan.op.source_name()];
    if spec.wrap {
        names.push("Reduce");
    }
    names.into_iter().any(|name| match crate::rlite::env::lookup(env, name) {
        None => false,
        Some(RVal::Builtin(id)) => match crate::rlite::builtins::lookup_builtin(name) {
            Some(d) => d.id != id,
            None => true,
        },
        Some(_) => true,
    })
}

/// The reduction attached to a map call's task context.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Serialize, Deserialize)]
pub struct ReducePlan {
    pub op: ReduceOp,
    /// `futurize(reduce = "assoc")`: accept reassociated floating-point
    /// folding (documented ULP contract) instead of the exactness gate.
    pub assoc: bool,
}

/// A parent-side reduction request: the wire-shipped plan plus how the
/// API must package the folded value. `wrap` is set for the
/// `Reduce(f, <map>)` form, whose kept outer `Reduce` call needs the
/// folded value wrapped in a length-1 list to pass through verbatim.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReduceSpec {
    pub plan: ReducePlan,
    pub wrap: bool,
}

/// A worker's constant-size partial aggregate for one slice.
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub struct ReducePartial {
    /// Op-specific payload (a folded scalar; for `Concat`, a lossless
    /// segment; for `Count`, nothing).
    pub value: WireVal,
    /// Map elements covered by this partial.
    pub n: u64,
    /// Flattened numeric components covered (the `mean` denominator).
    pub m: u64,
}

// ---- worker-side slice fold -------------------------------------------------

/// Flattened f64 view of a mapped value, mirroring `RVal::as_dbl_vec`
/// (lists flatten recursively, logicals become 0/1, `NULL` is empty).
/// Returns `false` for non-numeric values (gate failure).
fn numeric_view(v: &WireVal, out: &mut Vec<f64>) -> bool {
    match v {
        WireVal::Null => true,
        WireVal::Lgl(b, _) => {
            out.extend(b.iter().map(|&b| if b { 1.0 } else { 0.0 }));
            true
        }
        WireVal::Int(x, _) => {
            out.extend(x.iter().map(|&x| x as f64));
            true
        }
        WireVal::Dbl(x, _) => {
            out.extend_from_slice(x);
            true
        }
        WireVal::List(l, _, _) => l.iter().all(|e| numeric_view(e, out)),
        _ => false,
    }
}

/// A length-1, unnamed numeric scalar as f64 (the pairwise-fold gate:
/// rlite's scalar `+`/`*` fast path, which is a plain f64 op).
fn scalar_num(v: &WireVal) -> Option<f64> {
    match v {
        WireVal::Lgl(x, None) if x.len() == 1 => Some(if x[0] { 1.0 } else { 0.0 }),
        WireVal::Int(x, None) if x.len() == 1 => Some(x[0] as f64),
        WireVal::Dbl(x, None) if x.len() == 1 => Some(x[0]),
        _ => None,
    }
}

/// Flatten every slice value, or gate-fail.
fn flatten(vals: &[WireVal]) -> Option<Vec<f64>> {
    let mut buf = Vec::with_capacity(vals.len());
    for v in vals {
        if !numeric_view(v, &mut buf) {
            return None;
        }
    }
    Some(buf)
}

/// Fold one slice's mapped values into a partial aggregate. `None`
/// means the values failed the plan's exactness gate — the caller ships
/// full results for this chunk instead (the fallback path).
pub fn fold_slice(plan: &ReducePlan, vals: &[WireVal]) -> Option<ReducePartial> {
    if vals.is_empty() {
        return None;
    }
    let n = vals.len() as u64;
    let partial = match plan.op {
        ReduceOp::Sum | ReduceOp::Mean => {
            let buf = flatten(vals)?;
            let mut s = 0.0;
            if plan.assoc {
                for &x in &buf {
                    s += x;
                }
            } else {
                let mut abs = 0.0;
                for &x in &buf {
                    if x.fract() != 0.0 {
                        return None; // non-integral (also Inf/NaN)
                    }
                    abs += x.abs();
                    if abs > EXACT_INT_MAX {
                        return None; // beyond the integer-exact range
                    }
                    s += x;
                }
            }
            ReducePartial { value: WireVal::Dbl(vec![s], None), n, m: buf.len() as u64 }
        }
        ReduceOp::Prod => {
            if !plan.assoc {
                return None;
            }
            let buf = flatten(vals)?;
            let mut p = 1.0;
            for &x in &buf {
                p *= x;
            }
            ReducePartial { value: WireVal::Dbl(vec![p], None), n, m: buf.len() as u64 }
        }
        ReduceOp::Min | ReduceOp::Max => {
            let buf = flatten(vals)?;
            // Reassociation could change which of -0.0/+0.0 survives.
            if buf.iter().any(|&x| x == 0.0 && x.is_sign_negative()) {
                return None;
            }
            let value = if vals.len() == 1 {
                // A single element merges verbatim (`Reduce`/`.combine`
                // return it untouched when it is the only one).
                vals[0].clone()
            } else {
                let m = if plan.op == ReduceOp::Min {
                    buf.iter().fold(f64::INFINITY, |a, &x| a.min(x))
                } else {
                    buf.iter().fold(f64::NEG_INFINITY, |a, &x| a.max(x))
                };
                WireVal::Dbl(vec![m], None)
            };
            ReducePartial { value, n, m: 0 }
        }
        ReduceOp::Any | ReduceOp::All => {
            let buf = flatten(vals)?;
            let hit = if plan.op == ReduceOp::Any {
                buf.iter().any(|&x| x != 0.0)
            } else {
                buf.iter().all(|&x| x != 0.0)
            };
            ReducePartial { value: WireVal::Lgl(vec![hit], None), n, m: 0 }
        }
        ReduceOp::Count => {
            // Length-1 atomic results keep `length(simplify(...))` == n
            // regardless of kind; anything else defers to the parent's
            // simplify-aware reconstruction via fallback values.
            let scalar = |v: &WireVal| match v {
                WireVal::Lgl(x, _) => x.len() == 1,
                WireVal::Int(x, _) => x.len() == 1,
                WireVal::Dbl(x, _) => x.len() == 1,
                WireVal::Chr(x, _) => x.len() == 1,
                _ => false,
            };
            if !vals.iter().all(scalar) {
                return None;
            }
            ReducePartial { value: WireVal::Null, n, m: 0 }
        }
        ReduceOp::Add | ReduceOp::Mul => {
            if plan.op == ReduceOp::Mul && !plan.assoc {
                return None;
            }
            let mut acc: Option<f64> = None;
            let mut abs = 0.0;
            for v in vals {
                let x = scalar_num(v)?;
                if plan.op == ReduceOp::Add && !plan.assoc {
                    if x.fract() != 0.0 {
                        return None;
                    }
                    abs += x.abs();
                    if abs > EXACT_INT_MAX {
                        return None;
                    }
                }
                acc = Some(match acc {
                    None => x,
                    Some(a) if plan.op == ReduceOp::Add => a + x,
                    Some(a) => a * x,
                });
            }
            let value = if vals.len() == 1 {
                vals[0].clone() // single element returned untouched
            } else {
                WireVal::Dbl(vec![acc?], None)
            };
            ReducePartial { value, n, m: 0 }
        }
        ReduceOp::Concat => {
            let kind = |v: &WireVal| match v {
                WireVal::Lgl(x, None) => Some((0u8, x.len())),
                WireVal::Int(x, None) => Some((1, x.len())),
                WireVal::Dbl(x, None) => Some((2, x.len())),
                WireVal::Chr(x, None) => Some((3, x.len())),
                _ => None,
            };
            let mut kinds = Vec::with_capacity(vals.len());
            for v in vals {
                kinds.push(kind(v)?); // non-atomic or named → fallback
            }
            let uniform_scalars = kinds.iter().all(|&(k, len)| len == 1 && k == kinds[0].0);
            let value = if vals.len() == 1 {
                vals[0].clone()
            } else if uniform_scalars {
                // Lossless same-kind segment: one component per element,
                // so the parent can recover element granularity.
                match kinds[0].0 {
                    0 => WireVal::Lgl(
                        vals.iter()
                            .map(|v| match v {
                                WireVal::Lgl(x, _) => x[0],
                                _ => unreachable!(),
                            })
                            .collect(),
                        None,
                    ),
                    1 => WireVal::Int(
                        vals.iter()
                            .map(|v| match v {
                                WireVal::Int(x, _) => x[0],
                                _ => unreachable!(),
                            })
                            .collect(),
                        None,
                    ),
                    2 => WireVal::Dbl(
                        vals.iter()
                            .map(|v| match v {
                                WireVal::Dbl(x, _) => x[0],
                                _ => unreachable!(),
                            })
                            .collect(),
                        None,
                    ),
                    _ => WireVal::Chr(
                        vals.iter()
                            .map(|v| match v {
                                WireVal::Chr(x, _) => x[0].clone(),
                                _ => unreachable!(),
                            })
                            .collect(),
                        None,
                    ),
                }
            } else {
                // Vector elements: keep per-element structure verbatim.
                WireVal::List(vals.to_vec(), None, None)
            };
            ReducePartial { value, n, m: 0 }
        }
    };
    Some(partial)
}

// ---- parent-side streaming merge --------------------------------------------

/// One ordered piece of a `Concat` result.
enum CPart {
    /// A same-kind segment of length-1 elements (one component each).
    Seg(RVal),
    /// A single element, verbatim.
    Elem(RVal),
}

enum Acc {
    /// `Sum`/`Mean` running total (and `Prod` running product).
    Num { s: f64, m: u64 },
    /// Pairwise fold accumulator (`Add`/`Mul`/`Min`/`Max`).
    Pair(Option<RVal>),
    /// `Any`/`All`.
    Bool(bool),
    /// `Count`: enough metadata to replay `simplify`'s length rule for
    /// fallback chunks.
    Count { fb_count: u64, fb_first_len: Option<usize>, fb_uniform: bool, fb_all_num: bool },
    /// Ordered `c()` pieces, combined once at the end.
    Concat(Vec<CPart>),
}

/// The parent-side combine tree: partials (and fallback value chunks)
/// are folded **in chunk order** exactly once each — the dispatch core
/// feeds contributions as their relay turn comes up, which also makes
/// retried chunks count once.
pub struct ReduceState {
    plan: ReducePlan,
    n: u64,
    acc: Acc,
    /// Lazy interpreter for pairwise merges and `c()` replay — using
    /// the real builtins keeps the merge bit-identical to the
    /// sequential fold by construction.
    interp: Option<Box<Interp>>,
}

impl ReduceState {
    pub fn new(plan: ReducePlan) -> ReduceState {
        let acc = match plan.op {
            ReduceOp::Sum | ReduceOp::Mean => Acc::Num { s: 0.0, m: 0 },
            ReduceOp::Prod => Acc::Num { s: 1.0, m: 0 },
            ReduceOp::Add | ReduceOp::Mul | ReduceOp::Min | ReduceOp::Max => Acc::Pair(None),
            ReduceOp::Any => Acc::Bool(false),
            ReduceOp::All => Acc::Bool(true),
            ReduceOp::Count => Acc::Count {
                fb_count: 0,
                fb_first_len: None,
                fb_uniform: true,
                fb_all_num: true,
            },
            ReduceOp::Concat => Acc::Concat(Vec::new()),
        };
        ReduceState { plan, n: 0, acc, interp: None }
    }

    /// Merge one chunk's partial aggregate (already decoded to rlite
    /// values by the caller).
    pub fn push_partial(&mut self, value: RVal, n: u64, m: u64) -> Result<(), Signal> {
        match &mut self.acc {
            Acc::Num { s, m: mm } => {
                if self.plan.op == ReduceOp::Prod {
                    *s *= value.as_f64().map_err(Signal::error)?;
                } else {
                    *s += value.as_f64().map_err(Signal::error)?;
                }
                *mm += m;
            }
            Acc::Bool(b) => {
                let hit = value.as_bool().map_err(Signal::error)?;
                if self.plan.op == ReduceOp::Any {
                    *b |= hit;
                } else {
                    *b &= hit;
                }
            }
            Acc::Count { .. } => {} // n tracks everything for partials
            Acc::Concat(parts) => {
                if n <= 1 {
                    parts.push(CPart::Elem(value));
                } else if let RVal::List(l) = value {
                    parts.extend(l.vals.into_iter().map(CPart::Elem));
                } else {
                    parts.push(CPart::Seg(value));
                }
            }
            Acc::Pair(_) => {
                let acc = match &mut self.acc {
                    Acc::Pair(a) => a.take(),
                    _ => unreachable!(),
                };
                let next = match acc {
                    None => value,
                    Some(a) => self.pair(a, value)?,
                };
                match &mut self.acc {
                    Acc::Pair(a) => *a = Some(next),
                    _ => unreachable!(),
                }
            }
        }
        self.n += n;
        Ok(())
    }

    /// Fold one chunk's full results (a slice whose values failed the
    /// worker-side gate) element by element, in order — exactly the
    /// sequential reduction over that stretch.
    pub fn push_values(&mut self, values: &[RVal]) -> Result<(), Signal> {
        match &mut self.acc {
            Acc::Num { s, m } => {
                for v in values {
                    for x in v.as_dbl_vec().map_err(Signal::error)? {
                        if self.plan.op == ReduceOp::Prod {
                            *s *= x;
                        } else {
                            *s += x;
                        }
                        *m += 1;
                    }
                }
            }
            Acc::Bool(b) => {
                for v in values {
                    for x in v.as_dbl_vec().map_err(Signal::error)? {
                        if self.plan.op == ReduceOp::Any {
                            *b |= x != 0.0;
                        } else {
                            *b &= x != 0.0;
                        }
                    }
                }
            }
            Acc::Count { fb_count, fb_first_len, fb_uniform, fb_all_num } => {
                for v in values {
                    let len = v.len();
                    *fb_all_num &= matches!(v, RVal::Int(_) | RVal::Dbl(_));
                    match fb_first_len {
                        None => *fb_first_len = Some(len),
                        Some(k) => *fb_uniform &= *k == len,
                    }
                    *fb_count += 1;
                }
            }
            Acc::Concat(parts) => {
                parts.extend(values.iter().cloned().map(CPart::Elem));
            }
            Acc::Pair(_) => {
                for v in values {
                    let acc = match &mut self.acc {
                        Acc::Pair(a) => a.take(),
                        _ => unreachable!(),
                    };
                    let next = match acc {
                        None => v.clone(),
                        Some(a) => self.pair(a, v.clone())?,
                    };
                    match &mut self.acc {
                        Acc::Pair(a) => *a = Some(next),
                        _ => unreachable!(),
                    }
                }
            }
        }
        self.n += values.len() as u64;
        Ok(())
    }

    /// Finish the merge and produce the reduced value.
    pub fn finish(mut self) -> Result<RVal, Signal> {
        match self.acc {
            Acc::Num { s, m } => match self.plan.op {
                ReduceOp::Mean => {
                    if m == 0 {
                        Ok(RVal::scalar_dbl(f64::NAN))
                    } else {
                        Ok(RVal::scalar_dbl(s / m as f64))
                    }
                }
                _ => Ok(RVal::scalar_dbl(s)),
            },
            Acc::Bool(b) => Ok(RVal::scalar_bool(b)),
            Acc::Pair(v) => Ok(v.unwrap_or(RVal::Null)),
            Acc::Count { fb_count, fb_first_len, fb_uniform, fb_all_num } => {
                // Replay `RVal::simplify`'s length rule: the flattened
                // column-major case needs every element numeric with one
                // common length > 1; partial-covered elements are
                // length-1 scalars, so any partial forces length == n.
                let all_fallback = fb_count == self.n;
                let len = match fb_first_len {
                    Some(k) if all_fallback && fb_all_num && fb_uniform && k > 1 => {
                        self.n * k as u64
                    }
                    _ => self.n,
                };
                // The recognized `length(...)` call is kept in the
                // transpiled source, so hand back a dummy of the exact
                // simplified length for it to measure.
                Ok(RVal::Int(crate::rlite::value::RVec::plain(vec![0; len as usize])))
            }
            Acc::Concat(parts) => {
                if self.n <= 1 {
                    return Ok(match parts.into_iter().next() {
                        Some(CPart::Elem(v) | CPart::Seg(v)) => v,
                        None => RVal::Null,
                    });
                }
                let whole: Vec<&RVal> = parts
                    .iter()
                    .map(|p| match p {
                        CPart::Seg(v) | CPart::Elem(v) => v,
                    })
                    .collect();
                if flat_combinable_refs(&whole) {
                    // Homogeneous coercion ladder: one flat pass equals
                    // the pairwise fold (segments flatten identically).
                    return combine(
                        parts
                            .into_iter()
                            .map(|p| match p {
                                CPart::Seg(v) | CPart::Elem(v) => (None, v),
                            })
                            .collect(),
                    );
                }
                // Heterogeneous: replay the exact pairwise `c(acc, x)`
                // fold over per-element values (coercion laddering is
                // order-sensitive, e.g. logical → double → character).
                let mut elems = Vec::new();
                for p in parts {
                    match p {
                        CPart::Seg(v) => elems.extend(v.iter_elements()),
                        CPart::Elem(v) => elems.push(v),
                    }
                }
                let mut it = elems.into_iter();
                let mut acc = it.next().unwrap_or(RVal::Null);
                for e in it {
                    acc = combine(vec![(None, acc), (None, e)])?;
                }
                Ok(acc)
            }
        }
    }

    /// Pairwise merge through the real rlite builtin (`+`, `*`, `min`,
    /// `max`) so vector operands, coercions, and errors match the
    /// sequential fold exactly.
    fn pair(&mut self, a: RVal, b: RVal) -> Result<RVal, Signal> {
        let name = self.plan.op.pair_builtin().expect("pair-fold op");
        let f = crate::rlite::builtins::lookup_builtin(name)
            .map(|d| RVal::Builtin(d.id))
            .ok_or_else(|| Signal::error(format!("missing builtin '{name}'")))?;
        let i = self.interp.get_or_insert_with(|| Box::new(Interp::new()));
        let env = i.global.clone();
        i.call_function(&f, vec![(None, a), (None, b)], &env)
    }
}

// ---- shared `c()` fast path -------------------------------------------------

/// One-pass `c()` over per-iteration results, preserving rlite's
/// pairwise `c(acc, x)` fold semantics. Homogeneous runs (all numeric/
/// logical, or all character — unnamed) take a single preallocated
/// pass; heterogeneous inputs replay the exact pairwise fold, whose
/// coercion laddering is order-sensitive. Shared by
/// `foreach_pkg::reduce_combine` and the fused-`Concat` merge.
pub fn combine_results(results: Vec<RVal>) -> Result<RVal, Signal> {
    if results.len() <= 1 {
        return Ok(results.into_iter().next().unwrap_or(RVal::Null));
    }
    let refs: Vec<&RVal> = results.iter().collect();
    if !flat_combinable_refs(&refs) {
        let mut it = results.into_iter();
        let mut acc = it.next().expect("non-empty");
        for r in it {
            acc = combine(vec![(None, acc), (None, r)])?;
        }
        return Ok(acc);
    }
    if results.iter().all(|v| matches!(v, RVal::Lgl(_))) {
        let total = results.iter().map(|v| v.len()).sum();
        let mut out: Vec<bool> = Vec::with_capacity(total);
        for v in &results {
            if let RVal::Lgl(x) = v {
                out.extend(x.vals.iter().copied());
            }
        }
        return Ok(RVal::lgl(out));
    }
    if results.iter().all(|v| matches!(v, RVal::Chr(_))) {
        let total = results.iter().map(|v| v.len()).sum();
        let mut out: Vec<String> = Vec::with_capacity(total);
        for v in &results {
            if let RVal::Chr(x) = v {
                out.extend(x.vals.iter().cloned());
            }
        }
        return Ok(RVal::chr(out));
    }
    // Numeric ladder: preallocate from the known total length.
    let total = results.iter().map(|v| v.len()).sum();
    let mut out: Vec<f64> = Vec::with_capacity(total);
    for v in &results {
        match v {
            RVal::Dbl(x) => out.extend(x.vals.iter().copied()),
            RVal::Int(x) => out.extend(x.vals.iter().map(|&i| i as f64)),
            RVal::Lgl(x) => out.extend(x.vals.iter().map(|&b| if b { 1.0 } else { 0.0 })),
            _ => unreachable!("gated by flat_combinable_refs"),
        }
    }
    Ok(RVal::dbl(out))
}

/// True when a single flat `c()` pass is bit-identical to the pairwise
/// fold: every item unnamed and on one coercion ladder (numeric-ish or
/// character). `NULL`s and lists force the pairwise replay.
fn flat_combinable_refs(items: &[&RVal]) -> bool {
    let num = items
        .iter()
        .all(|v| matches!(v, RVal::Lgl(_) | RVal::Int(_) | RVal::Dbl(_)) && v.names().is_none());
    let chr = items.iter().all(|v| matches!(v, RVal::Chr(_)) && v.names().is_none());
    num || chr
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(op: ReduceOp) -> ReducePlan {
        ReducePlan { op, assoc: false }
    }

    fn dbl(x: f64) -> WireVal {
        WireVal::Dbl(vec![x], None)
    }

    #[test]
    fn integral_sum_folds_and_float_sum_falls_back() {
        let vals: Vec<WireVal> = (1..=5).map(|k| dbl(k as f64)).collect();
        let p = fold_slice(&plan(ReduceOp::Sum), &vals).expect("integral sum folds");
        assert_eq!(p.value, dbl(15.0));
        assert_eq!((p.n, p.m), (5, 5));

        let vals = vec![dbl(1.5), dbl(2.0)];
        assert!(fold_slice(&plan(ReduceOp::Sum), &vals).is_none(), "non-integral must fall back");
        let p = fold_slice(&ReducePlan { op: ReduceOp::Sum, assoc: true }, &vals).unwrap();
        assert_eq!(p.value, dbl(3.5));
    }

    #[test]
    fn sum_gate_rejects_magnitude_overflow_and_nonfinite() {
        let vals = vec![dbl(EXACT_INT_MAX), dbl(1.0)];
        assert!(fold_slice(&plan(ReduceOp::Sum), &vals).is_none());
        assert!(fold_slice(&plan(ReduceOp::Sum), &[dbl(f64::INFINITY)]).is_none());
        assert!(fold_slice(&plan(ReduceOp::Sum), &[dbl(f64::NAN)]).is_none());
    }

    #[test]
    fn min_ignores_nan_and_rejects_negative_zero() {
        let vals = vec![dbl(f64::NAN), dbl(3.0), dbl(-2.0)];
        let p = fold_slice(&plan(ReduceOp::Min), &vals).unwrap();
        assert_eq!(p.value, dbl(-2.0));
        assert!(fold_slice(&plan(ReduceOp::Min), &[dbl(-0.0), dbl(1.0)]).is_none());
    }

    #[test]
    fn prod_and_mul_are_assoc_only() {
        let vals = vec![dbl(2.0), dbl(3.0)];
        assert!(fold_slice(&plan(ReduceOp::Prod), &vals).is_none());
        assert!(fold_slice(&plan(ReduceOp::Mul), &vals).is_none());
        let p = fold_slice(&ReducePlan { op: ReduceOp::Prod, assoc: true }, &vals).unwrap();
        assert_eq!(p.value, dbl(6.0));
    }

    #[test]
    fn single_element_chunks_ship_verbatim() {
        let one = vec![WireVal::Int(vec![7], None)];
        for op in [ReduceOp::Add, ReduceOp::Min, ReduceOp::Max, ReduceOp::Concat] {
            let p = fold_slice(&plan(op), &one).unwrap_or_else(|| panic!("{op:?}"));
            assert_eq!(p.value, one[0], "{op:?}: single element must ship verbatim");
        }
    }

    #[test]
    fn concat_builds_lossless_segments() {
        let vals = vec![WireVal::Int(vec![1], None), WireVal::Int(vec![2], None)];
        let p = fold_slice(&plan(ReduceOp::Concat), &vals).unwrap();
        assert_eq!(p.value, WireVal::Int(vec![1, 2], None), "same-kind scalars → segment");

        let vals = vec![WireVal::Dbl(vec![1.0, 2.0], None), WireVal::Dbl(vec![3.0], None)];
        let p = fold_slice(&plan(ReduceOp::Concat), &vals).unwrap();
        assert!(matches!(p.value, WireVal::List(_, _, _)), "vector elements stay structured");

        let named = vec![WireVal::Dbl(vec![1.0], Some(vec!["a".into()])), dbl(2.0)];
        assert!(fold_slice(&plan(ReduceOp::Concat), &named).is_none(), "names → fallback");
    }

    #[test]
    fn state_merges_partials_and_fallback_values_in_order() {
        // sum(1..=10) split as [partial 1..=4], [fallback 5..=7], [partial 8..=10].
        let mut st = ReduceState::new(plan(ReduceOp::Sum));
        st.push_partial(RVal::scalar_dbl(10.0), 4, 4).unwrap();
        let fb: Vec<RVal> = (5..=7).map(|k| RVal::scalar_dbl(k as f64)).collect();
        st.push_values(&fb).unwrap();
        st.push_partial(RVal::scalar_dbl(27.0), 3, 3).unwrap();
        assert_eq!(st.finish().unwrap(), RVal::scalar_dbl(55.0));
    }

    #[test]
    fn count_replays_simplify_column_flattening() {
        // All-fallback, uniform length-3 numeric columns → n * 3.
        let mut st = ReduceState::new(plan(ReduceOp::Count));
        let col = RVal::dbl(vec![1.0, 2.0, 3.0]);
        st.push_values(&[col.clone(), col.clone()]).unwrap();
        assert_eq!(st.finish().unwrap().len(), 6);

        // A scalar partial alongside vector fallbacks → plain list → n.
        let mut st = ReduceState::new(plan(ReduceOp::Count));
        st.push_partial(RVal::Null, 2, 0).unwrap();
        st.push_values(&[col]).unwrap();
        assert_eq!(st.finish().unwrap().len(), 3);
    }

    #[test]
    fn pair_merge_uses_real_builtin_semantics() {
        let mut st = ReduceState::new(plan(ReduceOp::Add));
        st.push_partial(RVal::scalar_int(7), 1, 0).unwrap();
        st.push_values(&[RVal::dbl(vec![1.0, 2.0])]).unwrap(); // vector operand
        let v = st.finish().unwrap();
        assert_eq!(v, RVal::dbl(vec![8.0, 9.0]), "vectorized `+` with recycling");
    }

    #[test]
    fn combine_results_matches_pairwise_coercion_ladder() {
        // logical → double → character is order-sensitive: TRUE turns
        // into "1" (via the numeric step), not "TRUE".
        let results =
            vec![RVal::scalar_bool(true), RVal::scalar_dbl(2.0), RVal::scalar_str("a".into())];
        let flat = combine_results(results).unwrap();
        assert_eq!(flat, RVal::chr(vec!["1".into(), "2".into(), "a".into()]));

        // Homogeneous numeric takes the preallocated fast path.
        let results = vec![RVal::dbl(vec![1.0, 2.0]), RVal::scalar_int(3)];
        assert_eq!(combine_results(results).unwrap(), RVal::dbl(vec![1.0, 2.0, 3.0]));

        // All-logical stays logical.
        let results = vec![RVal::scalar_bool(true), RVal::scalar_bool(false)];
        assert_eq!(combine_results(results).unwrap(), RVal::lgl(vec![true, false]));
    }

    #[test]
    fn concat_state_heterogeneous_replay_is_pairwise_exact() {
        // Chunk 1 folds to an Int segment; chunk 2 falls back with a
        // character element. The merge must replay pairwise: the ints
        // pass through the numeric ladder before the character step.
        let mut st = ReduceState::new(plan(ReduceOp::Concat));
        st.push_partial(RVal::Int(crate::rlite::value::RVec::plain(vec![1, 2])), 2, 0).unwrap();
        st.push_values(&[RVal::scalar_str("z".into())]).unwrap();
        let v = st.finish().unwrap();
        assert_eq!(v, RVal::chr(vec!["1".into(), "2".into(), "z".into()]));
    }
}
