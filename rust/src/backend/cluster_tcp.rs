//! The real distributed cluster backend: persistent workers speaking
//! the framed worker protocol **over TCP sockets** instead of stdio.
//!
//! Two ways to populate the pool:
//!
//! - **spawn mode** (`plan(cluster_tcp, workers = n)`): the parent
//!   binds an ephemeral localhost listener and launches `n` local
//!   `futurize-rs worker --connect host:port` processes (or a
//!   user-supplied `spawn = "cmd {addr}"` command) that dial back in.
//!   Dead workers are respawned the same way.
//! - **attach mode** (`plan(cluster, workers = "tcp://host:port")`):
//!   the parent binds the given address and waits for externally
//!   launched workers — potentially on other machines — to connect.
//!
//! Every connection starts with a handshake (magic + protocol version
//! + codec negotiation + capability registration, see
//! [`crate::wire::handshake`]); the parent then pins the session codec
//! and a heartbeat interval in its `Welcome`. After that the transport
//! is byte-identical to multisession's: length-prefixed
//! [`ParentMsg`]/[`WorkerMsg`] frames, shared contexts registered once
//! per worker, the content-addressed blob cache (`CachePut`/`CacheMiss`)
//! and nested plan stacks riding along unchanged.
//!
//! ## Supervision across the connection boundary
//!
//! The PR 3 supervision ladder extends over the socket: a dropped
//! connection, an undecodable frame (protocol desync), *or a missed
//! heartbeat* (no frame from the worker within ~2.5 heartbeat
//! intervals — workers beacon every half interval even mid-task) all
//! reap the worker, claim a replacement connection (respawning first in
//! spawn mode), replay active contexts + referenced blobs, and surface
//! [`BackendEvent::WorkerLost`] per orphaned task so the dispatch core
//! can resubmit under `futurize(retries = N)` or raise a FutureError.
//!
//! ## Pipelining and cancellation
//!
//! Unlike multisession (one outstanding task per worker), this backend
//! keeps up to [`PIPELINE_DEPTH`] tasks written per worker so the next
//! task's bytes cross the network while the current one runs — real
//! sockets have real latency. That opens a window multisession never
//! has: a task can sit in a socket buffer, written but unstarted. So
//! [`Backend::cancel_queued`] here is a protocol, not a queue drain:
//! prefetched tasks get a [`ParentMsg::CancelTask`] which the worker's
//! *reader thread* services out-of-band (purging its pending queue even
//! mid-task) and acks with [`WorkerMsg::Cancelled`]; only acked tasks
//! are reported cancelled. A task that raced its cancel and started
//! anyway is reported via its normal `Done`.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::blobstore::CacheSource;
use super::multisession::{
    ensure_blob_frame, record_blob_replayed, record_worker_spawned, BlobEntry,
};
use super::worker::{ParentMsg, ParentMsgRef, WorkerMsg};
use super::{Backend, BackendEvent};
use crate::future_core::{TaskContext, TaskPayload};
use crate::wire::codec::{read_frame, write_frame, WIRE_CODEC_ENV};
use crate::wire::handshake::{self, HandshakeReply, Hello};
use crate::wire::WireCodec;

/// Maximum tasks written to one worker's socket at a time: the head is
/// running, the rest are prefetched so the network transfer overlaps
/// compute. Kept small — everything past the head is cancellation
/// surface and loss surface.
pub const PIPELINE_DEPTH: usize = 2;

/// A worker is reaped after this many heartbeat intervals without any
/// frame from it (beacons come every half interval, so this tolerates
/// several losses before declaring death).
const HEARTBEAT_REAP_FACTOR: f64 = 2.5;

/// How long construction waits for each worker's connection.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// How long supervision waits for a replacement connection before
/// retiring the slot.
const RESPAWN_TIMEOUT: Duration = Duration::from_secs(10);

/// How long `cancel_queued` waits for `Cancelled` acks. Localhost acks
/// arrive in microseconds; this only bounds the pathological case.
const CANCEL_ACK_TIMEOUT: Duration = Duration::from_secs(1);

/// How workers get into the pool (and back into it after a loss).
enum SpawnMode {
    /// Launch this binary (or `FUTURIZE_WORKER_BIN`) with
    /// `worker --connect <addr>`.
    SelfBinary,
    /// Launch a user-supplied command; `{addr}` tokens are substituted
    /// (the listener address is appended if the template never names it).
    Command(String),
    /// Never spawn: externally launched workers attach.
    Attach,
}

/// What a reader thread forwards to the backend.
enum PipeEvent {
    Msg(WorkerMsg),
    /// The connection is over: clean close, broken socket, or a frame
    /// that failed to decode (protocol desync). The worker is unusable
    /// and must be supervised.
    Exit { reason: String },
}

/// A handshake-complete connection waiting to be assigned a slot.
struct PendingWorker {
    stream: TcpStream,
    hello: Hello,
}

struct TcpWorker {
    /// Write half; the reader thread owns a `try_clone`.
    stream: TcpStream,
    /// Spawn mode only: the local process, reaped at supervision/drop.
    child: Option<Child>,
    /// Tasks written to this worker's socket, oldest first: the front
    /// is running, the rest are prefetched (written but possibly
    /// unstarted — the cancellation window).
    running: VecDeque<u64>,
    /// Incarnation counter for this slot; stale-generation events from
    /// a reaped predecessor are discarded.
    gen: u64,
    alive: bool,
    reader: Option<std::thread::JoinHandle<()>>,
    /// Blob digests resident on this worker (parent's ledger view).
    resident: HashSet<u64>,
    /// Stamped by the reader thread on *every* frame (heartbeats
    /// included); the heartbeat reaper compares against it.
    last_seen: Arc<Mutex<Instant>>,
    /// Worker's self-reported display tag, for loss diagnostics.
    tag: String,
}

/// Accept connections, run the server half of the handshake, and queue
/// valid workers for slot assignment. Invalid peers (wrong magic,
/// version skew, no codec in common) get a `Reject` and are dropped
/// without touching backend state.
fn start_acceptor(
    listener: TcpListener,
    codec: WireCodec,
    stop: Arc<AtomicBool>,
) -> Receiver<PendingWorker> {
    let (tx, rx) = channel::<PendingWorker>();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let Ok(stream) = conn else { continue };
            // A silent connection (port scanner, half-open socket) must
            // not wedge the acceptor: bound the handshake read.
            let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
            let _ = stream.set_nodelay(true);
            match handshake::recv::<Hello, _>(&mut &stream) {
                Ok(hello) => match hello.validate(codec) {
                    Ok(()) => {
                        let _ = stream.set_read_timeout(None);
                        if tx.send(PendingWorker { stream, hello }).is_err() {
                            return;
                        }
                    }
                    Err(reason) => {
                        let _ = handshake::send(&mut &stream, &HandshakeReply::Reject { reason });
                    }
                },
                Err(_) => { /* not a futurize worker; drop it */ }
            }
        }
    });
    rx
}

/// Reader thread for one worker connection: stamps liveness on every
/// frame, swallows heartbeats, forwards everything else.
fn start_reader(
    stream: TcpStream,
    codec: WireCodec,
    tx: Sender<(usize, u64, PipeEvent)>,
    idx: usize,
    gen: u64,
    last_seen: Arc<Mutex<Instant>>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut br = BufReader::new(stream);
        loop {
            let frame = match read_frame(&mut br) {
                Ok(Some(f)) => f,
                Ok(None) => {
                    let _ =
                        tx.send((idx, gen, PipeEvent::Exit { reason: "connection closed".into() }));
                    return;
                }
                Err(e) => {
                    let _ = tx.send((
                        idx,
                        gen,
                        PipeEvent::Exit { reason: format!("connection broke: {e}") },
                    ));
                    return;
                }
            };
            // Any frame proves the worker is alive — heartbeats exist
            // for the case where no other traffic flows.
            *last_seen.lock().unwrap() = Instant::now();
            match codec.decode::<WorkerMsg>(&frame) {
                Ok(WorkerMsg::Heartbeat) => continue,
                Ok(msg) => {
                    if matches!(msg, WorkerMsg::Done(_)) {
                        crate::wire::stats::record_result(frame.len());
                    }
                    if tx.send((idx, gen, PipeEvent::Msg(msg))).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    // A misdecoded frame leaves the stream untrustworthy;
                    // report the worker failed and stop reading.
                    let _ = tx.send((
                        idx,
                        gen,
                        PipeEvent::Exit { reason: format!("protocol desync: {e}") },
                    ));
                    return;
                }
            }
        }
    })
}

pub struct ClusterTcpBackend {
    codec: WireCodec,
    /// The bound listener address (workers dial this; Drop self-connects
    /// to it to unblock the acceptor).
    addr: SocketAddr,
    pending_rx: Receiver<PendingWorker>,
    accept_stop: Arc<AtomicBool>,
    spawn: SpawnMode,
    heartbeat_ms: f64,
    workers: Vec<TcpWorker>,
    /// (worker_idx, generation, event) from reader threads.
    rx: Receiver<(usize, u64, PipeEvent)>,
    tx: Sender<(usize, u64, PipeEvent)>,
    queue: VecDeque<TaskPayload>,
    /// Encoded `RegisterContext` frames of active contexts, replayed to
    /// replacement workers.
    contexts: HashMap<u64, Vec<u8>>,
    /// Events produced outside the reader channel, drained ahead of it.
    local_events: VecDeque<BackendEvent>,
    /// Reader events pulled off `rx` while salvaging a dying worker or
    /// awaiting cancel acks; re-processed ahead of `rx`.
    pipe_stash: VecDeque<(usize, u64, PipeEvent)>,
    /// Parent-side blob ledger (same structure as multisession's).
    blobs: HashMap<u64, BlobEntry>,
    ctx_blobs: HashMap<u64, Vec<u64>>,
    /// Encoded task frames kept for `CacheMiss` redelivery.
    task_frames: HashMap<u64, Vec<u8>>,
}

impl ClusterTcpBackend {
    pub fn new(n: usize, listen: &str, spawn: &str, heartbeat_ms: f64) -> Result<Self, String> {
        Self::with_codec(n, listen, spawn, heartbeat_ms, WireCodec::active())
    }

    /// Construct with an explicit codec (tests/benches compare
    /// transports without touching the process environment).
    pub fn with_codec(
        n: usize,
        listen: &str,
        spawn: &str,
        heartbeat_ms: f64,
        codec: WireCodec,
    ) -> Result<Self, String> {
        let n = n.max(1);
        let bind = if listen.is_empty() { "127.0.0.1:0" } else { listen };
        let listener = TcpListener::bind(bind)
            .map_err(|e| format!("cluster_tcp: cannot bind {bind}: {e}"))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("cluster_tcp: no local address: {e}"))?;
        let spawn_mode = match spawn {
            "" if listen.is_empty() => SpawnMode::SelfBinary,
            "" | "-" | "attach" => SpawnMode::Attach,
            cmd => SpawnMode::Command(cmd.to_string()),
        };
        let accept_stop = Arc::new(AtomicBool::new(false));
        let pending_rx = start_acceptor(listener, codec, Arc::clone(&accept_stop));
        let (tx, rx) = channel::<(usize, u64, PipeEvent)>();
        let mut backend = ClusterTcpBackend {
            codec,
            addr,
            pending_rx,
            accept_stop,
            spawn: spawn_mode,
            heartbeat_ms: heartbeat_ms.max(0.0),
            workers: Vec::with_capacity(n),
            rx,
            tx,
            queue: VecDeque::new(),
            contexts: HashMap::new(),
            local_events: VecDeque::new(),
            pipe_stash: VecDeque::new(),
            blobs: HashMap::new(),
            ctx_blobs: HashMap::new(),
            task_frames: HashMap::new(),
        };
        for idx in 0..n {
            let w = backend.claim_worker(idx, 0, CONNECT_TIMEOUT)?;
            backend.workers.push(w);
        }
        Ok(backend)
    }

    /// The address workers connect to (ephemeral port resolved).
    pub fn listen_addr(&self) -> SocketAddr {
        self.addr
    }

    /// In spawn modes, launch one local worker process that will dial
    /// back in; in attach mode, do nothing (someone else launches them).
    fn spawn_child(&self) -> Result<Option<Child>, String> {
        let addr = self.addr.to_string();
        let mut cmd = match &self.spawn {
            SpawnMode::Attach => return Ok(None),
            SpawnMode::SelfBinary => {
                let bin = super::worker::worker_binary()?;
                let mut c = Command::new(bin);
                c.args(["worker", "--connect", &addr]);
                c
            }
            SpawnMode::Command(tpl) => {
                let mut parts = tpl.split_whitespace().map(|t| t.replace("{addr}", &addr));
                let Some(prog) = parts.next() else {
                    return Err("cluster_tcp: empty spawn command".into());
                };
                let mut c = Command::new(prog);
                for p in parts {
                    c.arg(p);
                }
                if !tpl.contains("{addr}") {
                    c.arg(&addr);
                }
                c
            }
        };
        let child = cmd
            .env(WIRE_CODEC_ENV, self.codec.env_value())
            .stdin(Stdio::null())
            .stdout(Stdio::inherit())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| format!("cluster_tcp: spawn failed: {e}"))?;
        record_worker_spawned();
        Ok(Some(child))
    }

    /// Fill slot `idx` at generation `gen`: spawn (if spawning), wait
    /// for a handshake-complete connection, send its `Welcome`, and
    /// start its reader thread.
    fn claim_worker(&self, idx: usize, gen: u64, timeout: Duration) -> Result<TcpWorker, String> {
        let child = self.spawn_child()?;
        let PendingWorker { stream, hello } =
            self.pending_rx.recv_timeout(timeout).map_err(|_| {
                format!(
                    "cluster_tcp: no worker connected to {} for slot {idx} within {timeout:?}",
                    self.addr
                )
            })?;
        handshake::send(
            &mut &stream,
            &HandshakeReply::Welcome {
                worker_idx: idx as u32,
                codec: self.codec.env_value().to_string(),
                heartbeat_ms: self.heartbeat_ms,
            },
        )
        .map_err(|e| format!("cluster_tcp: welcome write to '{}' failed: {e}", hello.tag))?;
        let last_seen = Arc::new(Mutex::new(Instant::now()));
        let rd = stream
            .try_clone()
            .map_err(|e| format!("cluster_tcp: stream clone failed: {e}"))?;
        let reader =
            start_reader(rd, self.codec, self.tx.clone(), idx, gen, Arc::clone(&last_seen));
        Ok(TcpWorker {
            stream,
            child,
            running: VecDeque::new(),
            gen,
            alive: true,
            reader: Some(reader),
            resident: HashSet::new(),
            last_seen,
            tag: hello.tag,
        })
    }

    /// Surface one `WorkerLost` per orphaned task (or one informational
    /// loss when the worker was idle).
    fn push_lost(&mut self, idx: usize, lost: Vec<u64>) {
        if lost.is_empty() {
            self.local_events.push_back(BackendEvent::WorkerLost { worker: idx, task: None });
        } else {
            for t in lost {
                self.local_events
                    .push_back(BackendEvent::WorkerLost { worker: idx, task: Some(t) });
            }
        }
    }

    /// Reap a lost worker, claim a replacement into the same slot, and
    /// replay active contexts + referenced blobs to it. Returns every
    /// task orphaned by the loss (the whole pipeline, not just the
    /// head); the caller surfaces the matching `WorkerLost` events.
    fn supervise(&mut self, idx: usize, reason: &str) -> Vec<u64> {
        let (reader, cur_gen, tag) = {
            let w = &mut self.workers[idx];
            let _ = w.stream.shutdown(std::net::Shutdown::Both);
            if let Some(child) = w.child.as_mut() {
                let _ = child.kill();
                let _ = child.wait();
            }
            w.child = None;
            w.alive = false;
            (w.reader.take(), w.gen, w.tag.clone())
        };
        // Join the reader first: after the join, every frame the worker
        // managed to deliver is on the channel.
        if let Some(h) = reader {
            let _ = h.join();
        }
        // Salvage already-delivered events before the generation bump
        // would discard them: a task whose Done was queued but unread
        // *completed* and must not be reported lost (or re-executed
        // under retries). Other workers' events are stashed in order.
        while let Ok((i2, g2, ev)) = self.rx.try_recv() {
            if i2 == idx && g2 == cur_gen {
                match ev {
                    PipeEvent::Msg(WorkerMsg::Done(outcome)) => {
                        self.workers[idx].running.retain(|&t| t != outcome.id);
                        self.task_frames.remove(&outcome.id);
                        self.local_events.push_back(BackendEvent::Done(outcome));
                    }
                    PipeEvent::Msg(WorkerMsg::Progress { task_id, cond }) => {
                        self.local_events.push_back(BackendEvent::Progress { task_id, cond });
                    }
                    // A cancel ack racing the loss: the task never ran,
                    // but it was already reported *not* cancelled, so
                    // leave it in `running` — it surfaces as a lost task
                    // and the dispatch core's retry machinery decides.
                    PipeEvent::Msg(WorkerMsg::Cancelled { .. }) => {}
                    // The store answering a miss is being reaped; the
                    // task is lost and resubmitted via WorkerLost.
                    PipeEvent::Msg(WorkerMsg::CacheMiss { .. }) => {}
                    PipeEvent::Msg(WorkerMsg::Heartbeat) => {}
                    // The loss is what we are handling right now.
                    PipeEvent::Exit { .. } => {}
                }
            } else {
                self.pipe_stash.push_back((i2, g2, ev));
            }
        }
        let lost: Vec<u64> = self.workers[idx].running.drain(..).collect();
        for t in &lost {
            self.task_frames.remove(t);
        }
        let gen = cur_gen + 1;
        self.workers[idx].gen = gen;
        eprintln!(
            "futurize: cluster_tcp worker {idx} ('{tag}') lost ({reason}); claiming replacement"
        );
        match self.claim_worker(idx, gen, RESPAWN_TIMEOUT) {
            Ok(mut w) => {
                // Replay active contexts so in-flight map calls keep
                // submitting slices to the replacement.
                for payload in self.contexts.values() {
                    if write_frame(&mut w.stream, payload).is_err() {
                        let _ = w.stream.shutdown(std::net::Shutdown::Both);
                        if let Some(child) = w.child.as_mut() {
                            let _ = child.kill();
                            let _ = child.wait();
                        }
                        w.child = None;
                        w.alive = false;
                        break;
                    }
                }
                // Replay blobs referenced by still-active contexts —
                // the replacement's store is empty and an in-flight map
                // must not need a CacheMiss round for data the parent
                // already knows it requires.
                if w.alive {
                    let mut digests: Vec<u64> = self
                        .contexts
                        .keys()
                        .filter_map(|c| self.ctx_blobs.get(c))
                        .flatten()
                        .copied()
                        .collect();
                    digests.sort_unstable();
                    digests.dedup();
                    for d in digests {
                        let bytes = self.blobs.get(&d).map(|b| b.bytes).unwrap_or(0);
                        let Ok(Some(frame)) = ensure_blob_frame(self.codec, &mut self.blobs, d)
                        else {
                            continue;
                        };
                        if write_frame(&mut w.stream, frame).is_err() {
                            let _ = w.stream.shutdown(std::net::Shutdown::Both);
                            if let Some(child) = w.child.as_mut() {
                                let _ = child.kill();
                                let _ = child.wait();
                            }
                            w.child = None;
                            w.alive = false;
                            break;
                        }
                        w.resident.insert(d);
                        crate::wire::stats::record_cache_put(bytes);
                        record_blob_replayed();
                    }
                }
                self.workers[idx] = w;
            }
            Err(e) => {
                // Retire the slot (gen already bumped, so stale events
                // from the reaped connection are discarded).
                eprintln!("futurize: could not replace cluster_tcp worker {idx}: {e}");
            }
        }
        lost
    }

    /// Write an already-encoded frame to every live worker; a worker
    /// that dies mid-broadcast is supervised and reported instead of
    /// failing the call.
    fn broadcast(&mut self, payload: &[u8]) -> Result<(), String> {
        let mut lost_any = false;
        for idx in 0..self.workers.len() {
            if !self.workers[idx].alive {
                continue;
            }
            let ok = write_frame(&mut self.workers[idx].stream, payload).is_ok();
            if !ok {
                let lost = self.supervise(idx, "broadcast write failed");
                self.push_lost(idx, lost);
                lost_any = true;
            }
        }
        if lost_any {
            self.dispatch()?;
        }
        Ok(())
    }

    /// Hand queued tasks to workers with pipeline headroom, preferring
    /// the emptiest pipeline (an idle worker beats prefetching onto a
    /// busy one). Blob residency is established lazily before each task
    /// frame, exactly as in multisession.
    fn dispatch(&mut self) -> Result<(), String> {
        let mut respawns = 0usize;
        while !self.queue.is_empty() {
            let Some(idle) = (0..self.workers.len())
                .filter(|&i| {
                    self.workers[i].alive && self.workers[i].running.len() < PIPELINE_DEPTH
                })
                .min_by_key(|&i| self.workers[i].running.len())
            else {
                break;
            };
            let Some(task) = self.queue.pop_front() else { break };
            let ctx_digests: Vec<u64> = task
                .kind
                .context_id()
                .and_then(|c| self.ctx_blobs.get(&c))
                .cloned()
                .unwrap_or_default();
            let mut put_failed = false;
            for d in &ctx_digests {
                let bytes = self.blobs.get(d).map(|b| b.bytes).unwrap_or(0);
                if self.workers[idle].resident.contains(d) {
                    crate::wire::stats::record_cache_hit(bytes);
                    continue;
                }
                let Some(frame) = ensure_blob_frame(self.codec, &mut self.blobs, *d)? else {
                    continue;
                };
                if write_frame(&mut self.workers[idle].stream, frame).is_err() {
                    put_failed = true;
                    break;
                }
                self.workers[idle].resident.insert(*d);
                crate::wire::stats::record_cache_put(bytes);
            }
            if put_failed {
                self.queue.push_front(task);
                respawns += 1;
                if respawns > self.workers.len() * 2 {
                    return Err(
                        "cluster_tcp: workers are dying faster than they can be replaced".into(),
                    );
                }
                let lost = self.supervise(idle, "cache put write failed");
                self.push_lost(idle, lost);
                continue;
            }
            let payload = self
                .codec
                .encode(&ParentMsgRef::Task(&task))
                .map_err(|e| format!("serialize task: {e}"))?;
            let id = task.id;
            match write_frame(&mut self.workers[idle].stream, &payload) {
                Ok(()) => {
                    self.workers[idle].running.push_back(id);
                    if !ctx_digests.is_empty() {
                        self.task_frames.insert(id, payload);
                    }
                }
                Err(_) => {
                    // Never delivered — requeue for the replacement.
                    self.queue.push_front(task);
                    respawns += 1;
                    if respawns > self.workers.len() * 2 {
                        return Err(
                            "cluster_tcp: workers are dying faster than they can be replaced"
                                .into(),
                        );
                    }
                    let lost = self.supervise(idle, "task write failed");
                    self.push_lost(idle, lost);
                }
            }
        }
        Ok(())
    }

    /// Reap any worker whose connection has gone silent past the
    /// heartbeat deadline. Liveness is stamped by reader threads, so a
    /// busy parent never false-positives a chatty worker — and a busy
    /// *worker* never looks dead, because its heartbeat thread beacons
    /// independently of the task it is running.
    fn check_heartbeats(&mut self) -> Result<(), String> {
        if self.heartbeat_ms <= 0.0 {
            return Ok(());
        }
        let reap = Duration::from_secs_f64(self.heartbeat_ms * HEARTBEAT_REAP_FACTOR / 1000.0);
        for idx in 0..self.workers.len() {
            if !self.workers[idx].alive {
                continue;
            }
            let stale = self.workers[idx].last_seen.lock().unwrap().elapsed() > reap;
            if stale {
                let lost = self.supervise(idx, "heartbeat timeout");
                self.push_lost(idx, lost);
                self.dispatch()?;
            }
        }
        Ok(())
    }

    /// How long `next_event` may block before re-checking heartbeat
    /// deadlines.
    fn poll_interval(&self) -> Duration {
        if self.heartbeat_ms > 0.0 {
            Duration::from_secs_f64((self.heartbeat_ms / 2.0).clamp(5.0, 500.0) / 1000.0)
        } else {
            Duration::from_millis(500)
        }
    }

    /// Process one reader-channel event. `None` = internal (stale
    /// generation, absorbed, or routed through `local_events`).
    fn handle(
        &mut self,
        idx: usize,
        gen: u64,
        ev: PipeEvent,
    ) -> Result<Option<BackendEvent>, String> {
        if self.workers[idx].gen != gen {
            return Ok(None);
        }
        match ev {
            // Readers swallow heartbeats; this arm only exists for
            // events stashed during supervision salvage.
            PipeEvent::Msg(WorkerMsg::Heartbeat) => Ok(None),
            PipeEvent::Msg(WorkerMsg::Progress { task_id, cond }) => {
                Ok(Some(BackendEvent::Progress { task_id, cond }))
            }
            PipeEvent::Msg(WorkerMsg::Done(outcome)) => {
                self.workers[idx].running.retain(|&t| t != outcome.id);
                self.task_frames.remove(&outcome.id);
                self.dispatch()?;
                Ok(Some(BackendEvent::Done(outcome)))
            }
            PipeEvent::Msg(WorkerMsg::Cancelled { task_id }) => {
                // An ack that missed its cancel window (`cancel_queued`
                // already reported the task NOT cancelled and returned).
                // The worker purged it, so its Done will never come —
                // surface it as a lost task so the dispatch core's
                // resubmit/error machinery takes over instead of the
                // session waiting forever.
                self.workers[idx].running.retain(|&t| t != task_id);
                self.task_frames.remove(&task_id);
                self.dispatch()?;
                Ok(Some(BackendEvent::WorkerLost { worker: idx, task: Some(task_id) }))
            }
            PipeEvent::Msg(WorkerMsg::CacheMiss { task_id, digests }) => {
                // Re-put the blobs, then re-send the stored task frame;
                // socket FIFO makes the retry resolve. Internal: the
                // dispatch core never sees a miss.
                let mut healthy = true;
                for d in &digests {
                    crate::wire::stats::record_cache_miss();
                    let bytes = self.blobs.get(d).map(|b| b.bytes).unwrap_or(0);
                    match ensure_blob_frame(self.codec, &mut self.blobs, *d)? {
                        Some(frame) => {
                            if write_frame(&mut self.workers[idx].stream, frame).is_ok() {
                                self.workers[idx].resident.insert(*d);
                                crate::wire::stats::record_cache_put(bytes);
                            } else {
                                healthy = false;
                                break;
                            }
                        }
                        // Parent no longer holds the blob: unrecoverable
                        // for this task on this worker.
                        None => {
                            healthy = false;
                            break;
                        }
                    }
                }
                let frame = if healthy { self.task_frames.get(&task_id).cloned() } else { None };
                match frame {
                    Some(f) => {
                        if write_frame(&mut self.workers[idx].stream, &f).is_ok() {
                            Ok(None)
                        } else {
                            let lost = self.supervise(idx, "cache re-put write failed");
                            self.push_lost(idx, lost);
                            self.dispatch()?;
                            Ok(None)
                        }
                    }
                    None => {
                        let lost = self.supervise(idx, "cache state unavailable for retry");
                        self.push_lost(idx, lost);
                        self.dispatch()?;
                        Ok(None)
                    }
                }
            }
            PipeEvent::Exit { reason } => {
                let lost = self.supervise(idx, &reason);
                self.push_lost(idx, lost);
                self.dispatch()?;
                Ok(None)
            }
        }
    }
}

impl Backend for ClusterTcpBackend {
    fn name(&self) -> &'static str {
        "cluster_tcp"
    }

    fn workers(&self) -> usize {
        self.workers.len()
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        let payload = self
            .codec
            .encode(&ParentMsgRef::RegisterContext(&ctx))
            .map_err(|e| format!("serialize context: {e}"))?;
        // Cache before broadcasting: a worker replaced during (or
        // after) the broadcast gets the frame replayed from this cache.
        self.contexts.insert(ctx.id, payload.clone());
        self.broadcast(&payload)
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        self.contexts.remove(&ctx_id);
        // Release the context's blob references; worker resident
        // ledgers are deliberately untouched (the worker-side LRU keeps
        // the bytes across calls — that is the repeat-call win).
        if let Some(digests) = self.ctx_blobs.remove(&ctx_id) {
            for d in digests {
                if let Some(e) = self.blobs.get_mut(&d) {
                    e.refs.remove(&ctx_id);
                    if e.refs.is_empty() {
                        self.blobs.remove(&d);
                    }
                }
            }
        }
        let payload = self
            .codec
            .encode(&ParentMsg::DropContext(ctx_id))
            .map_err(|e| format!("serialize context drop: {e}"))?;
        self.broadcast(&payload)
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        self.queue.push_back(task);
        self.dispatch()
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        loop {
            if let Some(ev) = self.local_events.pop_front() {
                return Ok(ev);
            }
            if let Some((idx, gen, ev)) = self.pipe_stash.pop_front() {
                if let Some(ev) = self.handle(idx, gen, ev)? {
                    return Ok(ev);
                }
                continue;
            }
            if !self.workers.iter().any(|w| w.alive) {
                return Err("cluster_tcp: all workers lost and none could be replaced".into());
            }
            self.check_heartbeats()?;
            if !self.local_events.is_empty() {
                continue;
            }
            match self.rx.recv_timeout(self.poll_interval()) {
                Ok((idx, gen, ev)) => {
                    if let Some(ev) = self.handle(idx, gen, ev)? {
                        return Ok(ev);
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(e) => return Err(format!("cluster_tcp backend: {e}")),
            }
        }
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        loop {
            if let Some(ev) = self.local_events.pop_front() {
                return Ok(Some(ev));
            }
            if let Some((idx, gen, ev)) = self.pipe_stash.pop_front() {
                if let Some(ev) = self.handle(idx, gen, ev)? {
                    return Ok(Some(ev));
                }
                continue;
            }
            match self.rx.try_recv() {
                Ok((idx, gen, ev)) => {
                    if let Some(ev) = self.handle(idx, gen, ev)? {
                        return Ok(Some(ev));
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => {
                    self.check_heartbeats()?;
                    return Ok(self.local_events.pop_front());
                }
                Err(e) => return Err(format!("cluster_tcp backend: {e}")),
            }
        }
    }

    /// Parent-queue drain **plus** retraction of tasks already written
    /// to worker sockets but not yet started (the pipelined tail).
    /// Without the retraction, `stop_on_error` wall-clock bounds would
    /// regress under real network buffering: a task sitting in a socket
    /// send buffer is "queued" in every sense that matters, yet a naive
    /// drain would let it run to completion.
    fn cancel_queued(&mut self) -> Vec<u64> {
        let mut cancelled: Vec<u64> = self.queue.drain(..).map(|t| t.id).collect();
        // Ask each worker's reader thread to purge its prefetched tail
        // (everything past the running head).
        let mut awaiting: HashSet<u64> = HashSet::new();
        for idx in 0..self.workers.len() {
            if !self.workers[idx].alive {
                continue;
            }
            let pending: Vec<u64> = self.workers[idx].running.iter().skip(1).copied().collect();
            for tid in pending {
                let Ok(bytes) = self.codec.encode(&ParentMsgRef::CancelTask(tid)) else {
                    continue;
                };
                if write_frame(&mut self.workers[idx].stream, &bytes).is_ok() {
                    awaiting.insert(tid);
                } else {
                    // The worker died mid-cancel; its pipeline never
                    // ran, but it surfaces as WorkerLost (the caller
                    // already stopped waiting on cancelled ids only).
                    let lost = self.supervise(idx, "cancel write failed");
                    awaiting.retain(|t| !lost.contains(t));
                    self.push_lost(idx, lost);
                    break;
                }
            }
        }
        // Await acks with a bounded deadline, absorbing interleaved
        // traffic. Only an acked (or provably-discarded) task is
        // cancelled; one that raced its cancel and started reports via
        // its normal Done.
        let deadline = Instant::now() + CANCEL_ACK_TIMEOUT;
        while !awaiting.is_empty() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok((idx, gen, ev)) => {
                    if self.workers[idx].gen != gen {
                        continue;
                    }
                    match ev {
                        PipeEvent::Msg(WorkerMsg::Cancelled { task_id }) => {
                            if awaiting.remove(&task_id) {
                                self.workers[idx].running.retain(|&t| t != task_id);
                                self.task_frames.remove(&task_id);
                                cancelled.push(task_id);
                            }
                        }
                        PipeEvent::Msg(WorkerMsg::Done(outcome)) => {
                            // Raced: it started before the cancel
                            // arrived. It executed, so it is NOT
                            // cancelled; surface its Done normally.
                            awaiting.remove(&outcome.id);
                            self.workers[idx].running.retain(|&t| t != outcome.id);
                            self.task_frames.remove(&outcome.id);
                            self.local_events.push_back(BackendEvent::Done(outcome));
                        }
                        PipeEvent::Msg(WorkerMsg::CacheMiss { task_id, digests: _ })
                            if awaiting.contains(&task_id) =>
                        {
                            // The worker had already discarded this task
                            // awaiting blobs; simply never re-send it —
                            // that IS the cancellation.
                            awaiting.remove(&task_id);
                            self.workers[idx].running.retain(|&t| t != task_id);
                            self.task_frames.remove(&task_id);
                            cancelled.push(task_id);
                        }
                        other => self.pipe_stash.push_back((idx, gen, other)),
                    }
                }
                Err(_) => break,
            }
        }
        // Anything still awaited is treated as not cancelled: either
        // its Done arrives (it ran), or a late Cancelled ack surfaces
        // it as a lost task via `handle`.
        cancelled
    }

    fn data_cache(&self) -> bool {
        true
    }

    fn put_blob(&mut self, ctx_id: u64, digest: u64, blob: CacheSource) -> Result<(), String> {
        // Parent-side ledger only; dispatch() ships lazily per worker.
        let entry = self.blobs.entry(digest).or_insert_with(|| BlobEntry {
            bytes: blob.approx_bytes() as u64,
            source: blob,
            refs: HashSet::new(),
            frame: None,
        });
        entry.refs.insert(ctx_id);
        let list = self.ctx_blobs.entry(ctx_id).or_default();
        if !list.contains(&digest) {
            list.push(digest);
        }
        Ok(())
    }
}

impl Drop for ClusterTcpBackend {
    fn drop(&mut self) {
        if let Ok(payload) = self.codec.encode(&ParentMsg::Shutdown) {
            for w in self.workers.iter_mut().filter(|w| w.alive) {
                let _ = write_frame(&mut w.stream, &payload);
            }
        }
        // Unblock the acceptor thread so it can observe the stop flag.
        self.accept_stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
        // Grace period for spawned children, then kill. Attach-mode
        // workers are not ours to kill; they exit when their socket
        // closes below.
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let mut pending = false;
            for w in self.workers.iter_mut() {
                if let Some(child) = w.child.as_mut() {
                    match child.try_wait() {
                        Ok(Some(_)) => w.child = None,
                        Ok(None) => pending = true,
                        Err(_) => w.child = None,
                    }
                }
            }
            if !pending || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        for w in self.workers.iter_mut() {
            if let Some(child) = w.child.as_mut() {
                let _ = child.kill();
                let _ = child.wait();
            }
            let _ = w.stream.shutdown(std::net::Shutdown::Both);
        }
    }
}
