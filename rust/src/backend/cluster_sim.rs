//! The `plan(cluster, workers = c("n1", ...))` backend.
//!
//! The paper's ad-hoc clusters run PSOCK workers on *remote* machines;
//! we have one machine, so per the substitution rule we keep the real
//! process workers (framed binary transport, same as multisession) and
//! inject a configurable per-message network latency on both the submit
//! and the result path. This preserves the property that matters for
//! the evaluation: the chunking/scheduling trade-off (few large chunks
//! amortize latency; many small chunks balance load).
//!
//! Cluster-of-multicore (`plan(list(cluster(...), multicore(n)))`) —
//! the paper's flagship nested topology — needs nothing special here:
//! the inherited inner stack travels inside each `RegisterContext`
//! frame of the wrapped process pool, and the latency model charges
//! nested maps nothing extra (they run entirely on the remote node).
//!
//! Result-bytes accounting (`wire::stats::record_result`) is inherited
//! from the wrapped multisession reader threads: every `Done` frame a
//! cluster worker ships is read — and charged — by the same pipe
//! readers, so the O(result-volume) metric holds here without extra
//! code (asserted in `tests/lint_analysis.rs`).

use std::sync::Arc;
use std::time::Duration;

use super::multisession::MultisessionBackend;
use super::{Backend, BackendEvent};
use crate::future_core::{TaskContext, TaskPayload};

pub struct ClusterSimBackend {
    inner: MultisessionBackend,
    latency: Duration,
}

impl ClusterSimBackend {
    pub fn new(workers: usize, latency_ms: f64) -> Result<Self, String> {
        Ok(ClusterSimBackend {
            inner: MultisessionBackend::with_name(workers, "cluster")?,
            latency: Duration::from_secs_f64(latency_ms.max(0.0) / 1000.0),
        })
    }
}

impl Backend for ClusterSimBackend {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn workers(&self) -> usize {
        self.inner.workers()
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        // One registration message travels to each remote node; it is a
        // single trip (the nodes are written to in parallel in spirit),
        // so charge one latency, not one per worker.
        std::thread::sleep(self.latency);
        self.inner.register_context(ctx)
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        std::thread::sleep(self.latency);
        self.inner.drop_context(ctx_id)
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        // One-way trip to the remote node.
        std::thread::sleep(self.latency);
        self.inner.submit(task)
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        let ev = self.inner.next_event()?;
        if matches!(ev, BackendEvent::Done(_) | BackendEvent::WorkerLost { .. }) {
            // Results — and the news that a remote node died — travel
            // back over the wire. Supervision itself (respawn + context
            // replay) is inherited from the inner process pool.
            std::thread::sleep(self.latency);
        }
        Ok(ev)
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        let ev = self.inner.try_next_event()?;
        if matches!(ev, Some(BackendEvent::Done(_) | BackendEvent::WorkerLost { .. })) {
            std::thread::sleep(self.latency);
        }
        Ok(ev)
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        self.inner.cancel_queued()
    }

    fn data_cache(&self) -> bool {
        self.inner.data_cache()
    }

    fn put_blob(
        &mut self,
        ctx_id: u64,
        digest: u64,
        blob: super::blobstore::CacheSource,
    ) -> Result<(), String> {
        // One trip to announce the blob to the cluster; the bytes
        // themselves ship lazily inside the wrapped pool's dispatch,
        // and the whole point of the cache is that repeat calls skip
        // that shipping entirely.
        std::thread::sleep(self.latency);
        self.inner.put_blob(ctx_id, digest, blob)
    }
}
