//! The `plan(cluster, workers = c("n1", ...))` backend.
//!
//! The paper's ad-hoc clusters run PSOCK workers on *remote* machines;
//! we have one machine, so per the substitution rule we keep the real
//! process workers (framed binary transport, same as multisession) and
//! inject a configurable per-message network latency on both the submit
//! and the result path. This preserves the property that matters for
//! the evaluation: the chunking/scheduling trade-off (few large chunks
//! amortize latency; many small chunks balance load).
//!
//! (For workers on *actual* remote machines — or real local sockets —
//! see [`super::cluster_tcp`]: `tcp://` worker names promote `cluster`
//! to the socket transport, whose latency is physical, not injected.)
//!
//! ## How the latency charge is modeled
//!
//! Sender-side messages (context registration, task submission, blob
//! announcements) sleep on the caller: the driver genuinely cannot do
//! anything else until its message is on the wire, and a one-way trip
//! per message is the model. The **return path is different**: a result
//! travelling back from a remote node delays the *result*, not the
//! driver. Events are therefore stamped with an arrival deadline
//! (`now + latency` at the moment the wrapped pool surfaced them) and
//! parked until due. `try_next_event` never sleeps — a poll loop like
//! `while (!resolved(f)) { do_other_work() }` keeps running other work
//! during the simulated flight, exactly as it would against a real
//! remote cluster; only a *blocking* `next_event` sleeps out the
//! remaining flight time, because its caller asked to wait. `Progress`
//! conditions relayed from remote tasks are charged the same flight
//! time (they cross the same wire; an earlier version let them arrive
//! instantaneously, which made near-live progress look free).
//!
//! Cluster-of-multicore (`plan(list(cluster(...), multicore(n)))`) —
//! the paper's flagship nested topology — needs nothing special here:
//! the inherited inner stack travels inside each `RegisterContext`
//! frame of the wrapped process pool, and the latency model charges
//! nested maps nothing extra (they run entirely on the remote node).
//!
//! Result-bytes accounting (`wire::stats::record_result`) is inherited
//! from the wrapped multisession reader threads: every `Done` frame a
//! cluster worker ships is read — and charged — by the same pipe
//! readers, so the O(result-volume) metric holds here without extra
//! code (asserted in `tests/lint_analysis.rs`).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::multisession::MultisessionBackend;
use super::{Backend, BackendEvent};
use crate::future_core::{TaskContext, TaskPayload};

pub struct ClusterSimBackend {
    inner: MultisessionBackend,
    latency: Duration,
    /// Events surfaced by the wrapped pool, still "in flight" over the
    /// simulated wire: each becomes visible at its stamped deadline.
    /// Constant latency keeps deadlines monotone, so FIFO order is
    /// preserved.
    in_flight: VecDeque<(Instant, BackendEvent)>,
}

impl ClusterSimBackend {
    pub fn new(workers: usize, latency_ms: f64) -> Result<Self, String> {
        Ok(ClusterSimBackend {
            inner: MultisessionBackend::with_name(workers, "cluster")?,
            latency: Duration::from_secs_f64(latency_ms.max(0.0) / 1000.0),
            in_flight: VecDeque::new(),
        })
    }

    /// Pull everything the wrapped pool has ready and stamp each event
    /// with its arrival deadline. All event kinds cross the wire —
    /// results, loss notifications, *and* relayed progress conditions —
    /// so all are charged the one-way trip.
    fn absorb_ready(&mut self) -> Result<(), String> {
        let due = Instant::now() + self.latency;
        while let Some(ev) = self.inner.try_next_event()? {
            self.in_flight.push_back((due, ev));
        }
        Ok(())
    }

    fn pop_due(&mut self) -> Option<BackendEvent> {
        match self.in_flight.front() {
            Some((due, _)) if *due <= Instant::now() => self.in_flight.pop_front().map(|(_, e)| e),
            _ => None,
        }
    }
}

impl Backend for ClusterSimBackend {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn workers(&self) -> usize {
        self.inner.workers()
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        // One registration message travels to each remote node; it is a
        // single trip (the nodes are written to in parallel in spirit),
        // so charge one latency, not one per worker.
        std::thread::sleep(self.latency);
        self.inner.register_context(ctx)
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        std::thread::sleep(self.latency);
        self.inner.drop_context(ctx_id)
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        // One-way trip to the remote node.
        std::thread::sleep(self.latency);
        self.inner.submit(task)
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        loop {
            self.absorb_ready()?;
            if let Some(ev) = self.pop_due() {
                return Ok(ev);
            }
            match self.in_flight.front() {
                // Something is in flight: the caller asked to block, so
                // sleep out the remaining flight time.
                Some((due, _)) => {
                    let now = Instant::now();
                    if *due > now {
                        std::thread::sleep(*due - now);
                    }
                }
                // Nothing in flight at all: block on the pool, then the
                // event that arrives starts its flight.
                None => {
                    let ev = self.inner.next_event()?;
                    self.in_flight.push_back((Instant::now() + self.latency, ev));
                }
            }
        }
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        // Never sleeps: an event still in simulated flight is simply
        // not visible yet, and the caller's poll loop stays free to do
        // other work — the property that makes `resolved()` polling
        // concurrent rather than secretly blocking.
        self.absorb_ready()?;
        Ok(self.pop_due())
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        self.inner.cancel_queued()
    }

    fn data_cache(&self) -> bool {
        self.inner.data_cache()
    }

    fn put_blob(
        &mut self,
        ctx_id: u64,
        digest: u64,
        blob: super::blobstore::CacheSource,
    ) -> Result<(), String> {
        // One trip to announce the blob to the cluster; the bytes
        // themselves ship lazily inside the wrapped pool's dispatch,
        // and the whole point of the cache is that repeat calls skip
        // that shipping entirely.
        std::thread::sleep(self.latency);
        self.inner.put_blob(ctx_id, digest, blob)
    }
}
