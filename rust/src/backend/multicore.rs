//! The `plan(multicore)` backend: a native thread pool (the fork analog —
//! shared-memory workers on the local machine).
//!
//! Tasks cross the boundary in wire form (closures captured by value),
//! preserving the future framework's by-value globals semantics: a
//! forked R worker sees a *copy-on-write snapshot*, not live state.
//! Nothing is ever *encoded* though — this is the zero-copy fast path:
//! shared [`TaskContext`]s are immutable `Arc`s every worker thread
//! reads (registered once, never serialized), and chunk payloads carry
//! `WireSlice::Shared` windows into the dispatch core's `Arc`-frozen
//! element storage, so submitting a chunk moves two indices and an
//! `Arc` bump instead of cloning or serializing elements. The wire
//! byte counters stay at exactly zero on this backend.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::{Backend, BackendEvent};
use crate::future_core::{TaskContext, TaskPayload};

struct Shared {
    queue: Mutex<VecDeque<TaskPayload>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    /// Contexts visible to all worker threads, keyed by context id.
    contexts: Mutex<HashMap<u64, Arc<TaskContext>>>,
}

pub struct MulticoreBackend {
    shared: Arc<Shared>,
    events_rx: Receiver<BackendEvent>,
    _events_tx: Sender<BackendEvent>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl MulticoreBackend {
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            contexts: Mutex::new(HashMap::new()),
        });
        let (tx, rx) = channel::<BackendEvent>();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = shared.clone();
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || loop {
                let task = {
                    let mut q = shared.queue.lock().unwrap();
                    loop {
                        if *shared.shutdown.lock().unwrap() {
                            return;
                        }
                        if let Some(t) = q.pop_front() {
                            break t;
                        }
                        q = shared.cv.wait(q).unwrap();
                    }
                };
                let ctx = task
                    .kind
                    .context_id()
                    .and_then(|id| shared.contexts.lock().unwrap().get(&id).cloned());
                let tx_progress = tx.clone();
                let outcome = super::task_runner::run_task(
                    &task,
                    ctx.as_deref(),
                    w,
                    Some(&mut |task_id, cond| {
                        let _ = tx_progress.send(BackendEvent::Progress { task_id, cond });
                    }),
                );
                if tx.send(BackendEvent::Done(outcome)).is_err() {
                    return;
                }
            }));
        }
        MulticoreBackend { shared, events_rx: rx, _events_tx: tx, handles, workers }
    }
}

impl Backend for MulticoreBackend {
    fn name(&self) -> &'static str {
        "multicore"
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        self.shared.contexts.lock().unwrap().insert(ctx.id, ctx);
        Ok(())
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        self.shared.contexts.lock().unwrap().remove(&ctx_id);
        Ok(())
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        self.shared.queue.lock().unwrap().push_back(task);
        self.shared.cv.notify_one();
        Ok(())
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        self.events_rx.recv().map_err(|e| format!("multicore backend: {e}"))
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        match self.events_rx.try_recv() {
            Ok(ev) => Ok(Some(ev)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(e) => Err(format!("multicore backend: {e}")),
        }
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        let mut q = self.shared.queue.lock().unwrap();
        q.drain(..).map(|t| t.id).collect()
    }
}

impl Drop for MulticoreBackend {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::future_core::TaskKind;
    use crate::rlite::parse_expr;
    use crate::rlite::serialize::WireVal;

    fn payload(id: u64, src: &str) -> TaskPayload {
        TaskPayload {
            id,
            kind: TaskKind::Expr {
                expr: parse_expr(src).unwrap(),
                globals: vec![],
                nesting: Default::default(),
            },
            time_scale: 0.0,
            capture_stdout: true,
        }
    }

    #[test]
    fn runs_tasks_on_multiple_threads() {
        let mut b = MulticoreBackend::new(3);
        for id in 1..=6 {
            b.submit(payload(id, &format!("{id} * 2"))).unwrap();
        }
        let mut seen = std::collections::HashMap::new();
        let mut workers = std::collections::HashSet::new();
        while seen.len() < 6 {
            if let BackendEvent::Done(o) = b.next_event().unwrap() {
                workers.insert(o.worker);
                match &o.values.unwrap()[0] {
                    WireVal::Dbl(v, _) => {
                        seen.insert(o.id, v[0]);
                    }
                    other => panic!("{other:?}"),
                }
            }
        }
        for id in 1..=6u64 {
            assert_eq!(seen[&id], (id * 2) as f64);
        }
    }

    #[test]
    fn cancel_queued_drops_pending() {
        let mut b = MulticoreBackend::new(1);
        // First task blocks the single worker briefly.
        let mut slow = payload(1, "Sys.sleep(0.2)");
        slow.time_scale = 1.0;
        b.submit(slow).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(30));
        b.submit(payload(2, "2")).unwrap();
        b.submit(payload(3, "3")).unwrap();
        let cancelled = b.cancel_queued();
        assert!(
            !cancelled.is_empty(),
            "expected queued tasks to be cancellable, got {cancelled:?}"
        );
        assert!(cancelled.contains(&2) || cancelled.contains(&3), "{cancelled:?}");
        // First task still completes.
        match b.next_event().unwrap() {
            BackendEvent::Done(o) => assert_eq!(o.id, 1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn slice_tasks_resolve_registered_contexts() {
        use crate::future_core::ContextBody;
        let mut b = MulticoreBackend::new(2);
        let f = {
            let mut i = crate::rlite::eval::Interp::new();
            i.eval_program("__f <- function(x) x * 5").unwrap();
            let v = crate::rlite::env::lookup(&i.global, "__f").unwrap();
            crate::rlite::serialize::to_wire(&v).unwrap()
        };
        b.register_context(Arc::new(TaskContext {
            id: 11,
            body: ContextBody::Map { f, extra: vec![] },
            globals: vec![],
            cached_globals: vec![],
            nesting: Default::default(),
            kernel: None,
            reduce: None,
        }))
        .unwrap();
        b.submit(TaskPayload {
            id: 1,
            kind: TaskKind::MapSlice {
                ctx: 11,
                items: vec![WireVal::Dbl(vec![3.0], None)].into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        })
        .unwrap();
        loop {
            if let BackendEvent::Done(o) = b.next_event().unwrap() {
                match &o.values.unwrap()[0] {
                    WireVal::Dbl(v, _) => assert_eq!(v[0], 15.0),
                    other => panic!("{other:?}"),
                }
                break;
            }
        }
        b.drop_context(11).unwrap();
    }
}
