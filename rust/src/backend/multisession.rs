//! The `plan(multisession)` backend: a pool of persistent worker
//! *subprocesses* speaking the framed stdio protocol — the PSOCK-cluster
//! analog, with true process isolation. Also backs the paper's
//! `future.callr::callr` and `future.mirai::mirai_multisession` plans.
//!
//! Transport: length-prefixed frames whose payload is the backend's
//! [`WireCodec`] — compact binary by default, JSON when debugging (see
//! [`crate::wire::codec`]). The codec is captured once at construction
//! and stamped into each worker's environment, so parent and workers
//! always agree.
//!
//! Shared task contexts are encoded **once** and the same frame is
//! written to every worker's stdin (`RegisterContext`), so the per-map
//! logical volume for the function/extras/globals is O(1) and the
//! physical volume O(workers), not O(chunks). Worker processes cache
//! contexts by id (see [`super::worker`]).

use std::collections::VecDeque;
use std::io::{BufReader, Write};
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::worker::{ParentMsg, ParentMsgRef, WorkerMsg, WORKER_SENTINEL};
use super::{Backend, BackendEvent};
use crate::future_core::{TaskContext, TaskPayload};
use crate::wire::codec::{read_frame, write_frame, WIRE_CODEC_ENV};
use crate::wire::WireCodec;

struct WorkerProc {
    child: Child,
    stdin: ChildStdin,
    busy: bool,
    _reader: JoinHandle<()>,
}

pub struct MultisessionBackend {
    codec: WireCodec,
    workers: Vec<WorkerProc>,
    /// (worker_idx, msg) events from reader threads.
    rx: Receiver<(usize, WorkerMsg)>,
    _tx: Sender<(usize, WorkerMsg)>,
    queue: VecDeque<TaskPayload>,
    name: &'static str,
}

impl MultisessionBackend {
    pub fn new(n: usize) -> Result<Self, String> {
        Self::with_name(n, "multisession")
    }

    pub fn with_name(n: usize, name: &'static str) -> Result<Self, String> {
        Self::with_codec(n, name, WireCodec::active())
    }

    /// Construct with an explicit codec — used by tests and benches that
    /// compare transports without touching the process environment.
    pub fn with_codec(n: usize, name: &'static str, codec: WireCodec) -> Result<Self, String> {
        let n = n.max(1);
        let bin = super::worker::worker_binary()?;
        let (tx, rx) = channel::<(usize, WorkerMsg)>();
        let mut workers = Vec::with_capacity(n);
        for idx in 0..n {
            let mut child = Command::new(&bin)
                .arg(WORKER_SENTINEL)
                .env("FUTURIZE_WORKER_IDX", idx.to_string())
                .env(WIRE_CODEC_ENV, codec.env_value())
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(|e| format!("failed to spawn worker {}: {e}", bin.display()))?;
            let stdin = child.stdin.take().ok_or("no stdin")?;
            let stdout = child.stdout.take().ok_or("no stdout")?;
            let tx = tx.clone();
            let reader = std::thread::spawn(move || {
                let mut br = BufReader::new(stdout);
                loop {
                    let frame = match read_frame(&mut br) {
                        Ok(Some(f)) => f,
                        Ok(None) => break,
                        Err(e) => {
                            eprintln!("futurize: worker stream broke: {e}");
                            break;
                        }
                    };
                    match codec.decode::<WorkerMsg>(&frame) {
                        Ok(msg) => {
                            if tx.send((idx, msg)).is_err() {
                                break;
                            }
                        }
                        Err(e) => eprintln!("futurize: bad worker message: {e}"),
                    }
                }
            });
            workers.push(WorkerProc { child, stdin, busy: false, _reader: reader });
        }
        Ok(MultisessionBackend { codec, workers, rx, _tx: tx, queue: VecDeque::new(), name })
    }

    /// Write an already-encoded protocol frame to every worker. The
    /// message was encoded (and its logical bytes recorded) once; each
    /// worker copy still crosses the process boundary, so `write_frame`
    /// accounts one physical copy per worker.
    fn broadcast(&mut self, payload: &[u8]) -> Result<(), String> {
        for w in self.workers.iter_mut() {
            write_frame(&mut w.stdin, payload).map_err(|e| format!("worker write: {e}"))?;
            w.stdin.flush().map_err(|e| format!("worker flush: {e}"))?;
        }
        Ok(())
    }

    fn dispatch(&mut self) -> Result<(), String> {
        while let Some(idle) = self.workers.iter().position(|w| !w.busy) {
            let Some(task) = self.queue.pop_front() else { break };
            let payload = self
                .codec
                .encode(&ParentMsg::Task(task))
                .map_err(|e| format!("serialize task: {e}"))?;
            let w = &mut self.workers[idle];
            write_frame(&mut w.stdin, &payload).map_err(|e| format!("worker write: {e}"))?;
            w.stdin.flush().map_err(|e| format!("worker flush: {e}"))?;
            w.busy = true;
        }
        Ok(())
    }

    fn handle(&mut self, idx: usize, msg: WorkerMsg) -> Result<BackendEvent, String> {
        match msg {
            WorkerMsg::Progress { task_id, cond } => {
                Ok(BackendEvent::Progress { task_id, cond })
            }
            WorkerMsg::Done(outcome) => {
                self.workers[idx].busy = false;
                self.dispatch()?;
                Ok(BackendEvent::Done(outcome))
            }
        }
    }
}

impl Backend for MultisessionBackend {
    fn name(&self) -> &'static str {
        self.name
    }

    fn workers(&self) -> usize {
        self.workers.len()
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        // Borrowing mirror: encode straight out of the Arc, no deep clone.
        let payload = self
            .codec
            .encode(&ParentMsgRef::RegisterContext(&ctx))
            .map_err(|e| format!("serialize context: {e}"))?;
        self.broadcast(&payload)
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        let payload = self
            .codec
            .encode(&ParentMsg::DropContext(ctx_id))
            .map_err(|e| format!("serialize context drop: {e}"))?;
        self.broadcast(&payload)
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        self.queue.push_back(task);
        self.dispatch()
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        let (idx, msg) =
            self.rx.recv().map_err(|e| format!("multisession backend: {e}"))?;
        self.handle(idx, msg)
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        match self.rx.try_recv() {
            Ok((idx, msg)) => Ok(Some(self.handle(idx, msg)?)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(e) => Err(format!("multisession backend: {e}")),
        }
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        self.queue.drain(..).map(|t| t.id).collect()
    }
}

impl Drop for MultisessionBackend {
    fn drop(&mut self) {
        if let Ok(payload) = self.codec.encode(&ParentMsg::Shutdown) {
            for w in &mut self.workers {
                let _ = write_frame(&mut w.stdin, &payload);
                let _ = w.stdin.flush();
            }
        }
        for w in &mut self.workers {
            let _ = w.child.wait();
        }
    }
}
