//! The `plan(multisession)` backend: a pool of persistent worker
//! *subprocesses* speaking the JSON stdio protocol — the PSOCK-cluster
//! analog, with true process isolation. Also backs the paper's
//! `future.callr::callr` and `future.mirai::mirai_multisession` plans.
//!
//! Shared task contexts are serialized **once** and the same line is
//! written to every worker's stdin (`RegisterContext`), so the per-map
//! serialized volume for the function/extras/globals is O(workers), not
//! O(chunks). Worker processes cache contexts by id (see
//! [`super::worker`]).

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::worker::{ParentMsg, WorkerMsg, WORKER_SENTINEL};
use super::{Backend, BackendEvent};
use crate::future_core::{TaskContext, TaskPayload};

struct WorkerProc {
    child: Child,
    stdin: ChildStdin,
    busy: bool,
    _reader: JoinHandle<()>,
}

pub struct MultisessionBackend {
    workers: Vec<WorkerProc>,
    /// (worker_idx, msg) events from reader threads.
    rx: Receiver<(usize, WorkerMsg)>,
    _tx: Sender<(usize, WorkerMsg)>,
    queue: VecDeque<TaskPayload>,
    name: &'static str,
}

impl MultisessionBackend {
    pub fn new(n: usize) -> Result<Self, String> {
        Self::with_name(n, "multisession")
    }

    pub fn with_name(n: usize, name: &'static str) -> Result<Self, String> {
        let n = n.max(1);
        let bin = super::worker::worker_binary()?;
        let (tx, rx) = channel::<(usize, WorkerMsg)>();
        let mut workers = Vec::with_capacity(n);
        for idx in 0..n {
            let mut child = Command::new(&bin)
                .arg(WORKER_SENTINEL)
                .env("FUTURIZE_WORKER_IDX", idx.to_string())
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(|e| format!("failed to spawn worker {}: {e}", bin.display()))?;
            let stdin = child.stdin.take().ok_or("no stdin")?;
            let stdout = child.stdout.take().ok_or("no stdout")?;
            let tx = tx.clone();
            let reader = std::thread::spawn(move || {
                let br = BufReader::new(stdout);
                for line in br.lines() {
                    let line = match line {
                        Ok(l) => l,
                        Err(_) => break,
                    };
                    if line.trim().is_empty() {
                        continue;
                    }
                    match crate::wire::from_str::<WorkerMsg>(&line) {
                        Ok(msg) => {
                            if tx.send((idx, msg)).is_err() {
                                break;
                            }
                        }
                        Err(e) => eprintln!("futurize: bad worker message: {e}"),
                    }
                }
            });
            workers.push(WorkerProc { child, stdin, busy: false, _reader: reader });
        }
        Ok(MultisessionBackend { workers, rx, _tx: tx, queue: VecDeque::new(), name })
    }

    /// Write an already-serialized protocol line to every worker.
    fn broadcast(&mut self, line: &str) -> Result<(), String> {
        for (k, w) in self.workers.iter_mut().enumerate() {
            // The line was serialized once; every extra worker copy still
            // crosses the process boundary, so account for it.
            if k > 0 {
                crate::wire::stats::record(line.len());
            }
            writeln!(w.stdin, "{line}").map_err(|e| format!("worker write: {e}"))?;
            w.stdin.flush().map_err(|e| format!("worker flush: {e}"))?;
        }
        Ok(())
    }

    fn dispatch(&mut self) -> Result<(), String> {
        while let Some(idle) = self.workers.iter().position(|w| !w.busy) {
            let Some(task) = self.queue.pop_front() else { break };
            let w = &mut self.workers[idle];
            let msg = crate::wire::to_string(&ParentMsg::Task(task))
                .map_err(|e| format!("serialize task: {e}"))?;
            writeln!(w.stdin, "{msg}").map_err(|e| format!("worker write: {e}"))?;
            w.stdin.flush().map_err(|e| format!("worker flush: {e}"))?;
            w.busy = true;
        }
        Ok(())
    }

    fn handle(&mut self, idx: usize, msg: WorkerMsg) -> Result<BackendEvent, String> {
        match msg {
            WorkerMsg::Progress { task_id, cond } => {
                Ok(BackendEvent::Progress { task_id, cond })
            }
            WorkerMsg::Done(outcome) => {
                self.workers[idx].busy = false;
                self.dispatch()?;
                Ok(BackendEvent::Done(outcome))
            }
        }
    }
}

impl Backend for MultisessionBackend {
    fn name(&self) -> &'static str {
        self.name
    }

    fn workers(&self) -> usize {
        self.workers.len()
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        let msg = crate::wire::to_string(&ParentMsg::RegisterContext((*ctx).clone()))
            .map_err(|e| format!("serialize context: {e}"))?;
        self.broadcast(&msg)
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        let msg = crate::wire::to_string(&ParentMsg::DropContext(ctx_id))
            .map_err(|e| format!("serialize context drop: {e}"))?;
        self.broadcast(&msg)
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        self.queue.push_back(task);
        self.dispatch()
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        let (idx, msg) =
            self.rx.recv().map_err(|e| format!("multisession backend: {e}"))?;
        self.handle(idx, msg)
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        match self.rx.try_recv() {
            Ok((idx, msg)) => Ok(Some(self.handle(idx, msg)?)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(e) => Err(format!("multisession backend: {e}")),
        }
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        self.queue.drain(..).map(|t| t.id).collect()
    }
}

impl Drop for MultisessionBackend {
    fn drop(&mut self) {
        for w in &mut self.workers {
            let _ = writeln!(w.stdin, "{}", crate::wire::to_string(&ParentMsg::Shutdown).unwrap());
            let _ = w.stdin.flush();
        }
        for w in &mut self.workers {
            let _ = w.child.wait();
        }
    }
}
