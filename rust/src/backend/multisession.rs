//! The `plan(multisession)` backend: a pool of persistent worker
//! *subprocesses* speaking the framed stdio protocol — the PSOCK-cluster
//! analog, with true process isolation. Also backs the paper's
//! `future.callr::callr` and `future.mirai::mirai_multisession` plans.
//!
//! Transport: length-prefixed frames whose payload is the backend's
//! [`WireCodec`] — compact binary by default, JSON when debugging (see
//! [`crate::wire::codec`]). The codec is captured once at construction
//! and stamped into each worker's environment, so parent and workers
//! always agree.
//!
//! Shared task contexts are encoded **once** and the same frame is
//! written to every worker's stdin (`RegisterContext`), so the per-map
//! logical volume for the function/extras/globals is O(1) and the
//! physical volume O(workers), not O(chunks). Worker processes cache
//! contexts by id (see [`super::worker`]). The frame carries the plan
//! stack's remaining levels (`TaskContext::nesting`); because respawn
//! replays every cached context frame, a replacement worker inherits
//! the same inner backend for nested futurized maps as the casualty.
//!
//! ## Supervision
//!
//! A worker that dies mid-task (OOM-kill, segfault, `exit()`) must
//! never wedge the session. The parent tracks which task each worker is
//! running; every reader thread sends an [`PipeEvent::Exit`] sentinel
//! when its stream ends (clean EOF, broken pipe, or a frame that fails
//! to decode — a desynced protocol is treated as a dead worker, not
//! skipped over). On a loss the backend reaps the child, spawns a
//! replacement into the same slot with a bumped *generation* (stale
//! events from the previous incumbent are discarded by generation
//! stamp), replays every active [`TaskContext`] frame from a
//! parent-side cache to it, and emits [`BackendEvent::WorkerLost`]
//! naming the slot and the orphaned task so the dispatch core can
//! resubmit or raise a `FutureError`. Broadcast and task writes that
//! fail mid-stream route through the same path — the one dead worker is
//! replaced instead of the whole map call failing.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{BufReader, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::blobstore::CacheSource;
use super::worker::{ParentMsg, ParentMsgRef, WorkerMsg, WORKER_SENTINEL};
use super::{Backend, BackendEvent};
use crate::future_core::{TaskContext, TaskPayload};
use crate::wire::codec::{read_frame, write_frame, WIRE_CODEC_ENV};
use crate::wire::WireCodec;

/// What a reader thread forwards to the backend: a decoded protocol
/// message, or the news that the stream is over and the worker is gone.
enum PipeEvent {
    Msg(WorkerMsg),
    /// The reader terminated: clean EOF, broken stream, or a frame that
    /// failed to decode (protocol desync). In every case the worker is
    /// unusable and must be supervised.
    Exit { reason: String },
}

struct WorkerProc {
    child: Child,
    stdin: ChildStdin,
    /// Task currently executing on this worker, if any — the knowledge
    /// that turns "a worker died" into "task N was lost".
    running: Option<u64>,
    /// Incarnation counter for this slot. Events stamped with an older
    /// generation belong to a reaped predecessor and are dropped.
    gen: u64,
    /// False once the slot's process is gone and could not be replaced
    /// (or, during `Drop`, once it has been reaped).
    alive: bool,
    /// Reader thread, joined during supervision so every event the
    /// worker managed to deliver is on the channel before the slot's
    /// generation is bumped (a completed task must never be
    /// misreported as lost just because its `Done` was still queued).
    reader: Option<std::thread::JoinHandle<()>>,
    /// Data-plane cache ledger: digests this worker's blob store holds
    /// (as far as the parent knows — worker-side eviction is healed by
    /// the `CacheMiss` negative-ack path). Monotone for the worker's
    /// lifetime and *not* cleared on `drop_context`, which is what
    /// makes a second map call over the same data ship zero blob
    /// bytes. A replacement starts empty.
    resident: HashSet<u64>,
}

/// Parent-side record of one extracted blob: the `Arc`-kept payload
/// (alive for `CacheMiss`/respawn re-puts until the last referencing
/// context drops), which active contexts reference it, and the
/// lazily-encoded `CachePut` frame every ship of it reuses. Shared
/// with the TCP cluster backend, which keeps the identical ledger over
/// a socket transport.
pub(crate) struct BlobEntry {
    pub(crate) source: CacheSource,
    pub(crate) refs: HashSet<u64>,
    pub(crate) frame: Option<Vec<u8>>,
    /// Approximate payload bytes, for hit/put accounting.
    pub(crate) bytes: u64,
}

/// Encode (once) and return the `CachePut` frame for `digest`. A free
/// function over the field so callers can keep a disjoint `&mut`
/// borrow of the worker table while holding the returned frame.
pub(crate) fn ensure_blob_frame(
    codec: WireCodec,
    blobs: &mut HashMap<u64, BlobEntry>,
    digest: u64,
) -> Result<Option<&Vec<u8>>, String> {
    let Some(entry) = blobs.get_mut(&digest) else { return Ok(None) };
    if entry.frame.is_none() {
        let bytes = codec
            .encode(&ParentMsgRef::CachePut { digest, blob: entry.source.to_ref() })
            .map_err(|e| format!("serialize cache blob: {e}"))?;
        entry.frame = Some(bytes);
    }
    Ok(entry.frame.as_ref())
}

pub struct MultisessionBackend {
    codec: WireCodec,
    /// Worker binary, kept for respawns.
    bin: PathBuf,
    workers: Vec<WorkerProc>,
    /// (worker_idx, generation, event) from reader threads.
    rx: Receiver<(usize, u64, PipeEvent)>,
    tx: Sender<(usize, u64, PipeEvent)>,
    queue: VecDeque<TaskPayload>,
    /// Parent-side cache of the encoded `RegisterContext` frame of every
    /// active context, replayed to replacement workers at respawn.
    contexts: HashMap<u64, Vec<u8>>,
    /// Events produced outside the reader channel (losses detected on
    /// the write path, outcomes salvaged during supervision), drained
    /// ahead of it.
    local_events: VecDeque<BackendEvent>,
    /// Raw reader events pulled off `rx` while salvaging a dying
    /// worker's deliveries; re-processed ahead of `rx` so per-worker
    /// ordering is preserved.
    pipe_stash: VecDeque<(usize, u64, PipeEvent)>,
    /// Extracted data-plane blobs by digest (see [`BlobEntry`]).
    blobs: HashMap<u64, BlobEntry>,
    /// Which blob digests each active context references, in put order.
    ctx_blobs: HashMap<u64, Vec<u64>>,
    /// Encoded `Task` frames of in-flight tasks whose context
    /// references cached blobs, kept for `CacheMiss` redelivery.
    /// Removed when the task's `Done` arrives (or its worker is lost).
    task_frames: HashMap<u64, Vec<u8>>,
    name: &'static str,
}

/// Total worker processes this process has ever spawned (all
/// multisession-protocol backends, including cluster_sim). Test hook
/// for the per-worker inner-backend cache: nested plans must spawn
/// inner pools once per worker, not once per chunk.
static WORKERS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Monotonic count of worker-process spawns in this process.
pub fn workers_spawned() -> u64 {
    WORKERS_SPAWNED.load(Ordering::Relaxed)
}

/// Total `CachePut` frames replayed to replacement workers during
/// supervision (all multisession-protocol backends). Test hook: the
/// respawn-with-cache suite asserts replay covers exactly the digests
/// referenced by still-active contexts, not every blob ever shipped.
static BLOBS_REPLAYED: AtomicU64 = AtomicU64::new(0);

/// Monotonic count of supervision-time blob replays in this process.
pub fn blobs_replayed() -> u64 {
    BLOBS_REPLAYED.load(Ordering::Relaxed)
}

/// Tick the shared spawn counter for a worker process launched by a
/// sibling backend (the TCP cluster spawns through its own transport
/// but participates in the same per-worker accounting).
pub(crate) fn record_worker_spawned() {
    WORKERS_SPAWNED.fetch_add(1, Ordering::Relaxed);
}

/// Tick the shared supervision-replay counter (see [`blobs_replayed`]).
pub(crate) fn record_blob_replayed() {
    BLOBS_REPLAYED.fetch_add(1, Ordering::Relaxed);
}

/// Spawn one worker process into slot `idx` at generation `gen` and
/// start its reader thread.
fn spawn_worker(
    bin: &Path,
    codec: WireCodec,
    tx: &Sender<(usize, u64, PipeEvent)>,
    idx: usize,
    gen: u64,
) -> Result<WorkerProc, String> {
    let mut child = Command::new(bin)
        .arg(WORKER_SENTINEL)
        .env("FUTURIZE_WORKER_IDX", idx.to_string())
        .env(WIRE_CODEC_ENV, codec.env_value())
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .map_err(|e| format!("failed to spawn worker {}: {e}", bin.display()))?;
    WORKERS_SPAWNED.fetch_add(1, Ordering::Relaxed);
    let stdin = child.stdin.take().ok_or("no stdin")?;
    let stdout = child.stdout.take().ok_or("no stdout")?;
    let tx = tx.clone();
    let reader = std::thread::spawn(move || {
        let mut br = BufReader::new(stdout);
        loop {
            let frame = match read_frame(&mut br) {
                Ok(Some(f)) => f,
                Ok(None) => {
                    let _ = tx.send((
                        idx,
                        gen,
                        PipeEvent::Exit { reason: "worker process exited".into() },
                    ));
                    return;
                }
                Err(e) => {
                    let _ = tx.send((
                        idx,
                        gen,
                        PipeEvent::Exit { reason: format!("worker stream broke: {e}") },
                    ));
                    return;
                }
            };
            match codec.decode::<WorkerMsg>(&frame) {
                Ok(msg) => {
                    if matches!(msg, WorkerMsg::Done(_)) {
                        // Result-volume accounting: fused reductions
                        // assert these frames stay O(workers), not O(n).
                        crate::wire::stats::record_result(frame.len());
                    }
                    if tx.send((idx, gen, PipeEvent::Msg(msg))).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    // A frame that fails to decode leaves the stream
                    // state untrustworthy; continuing would read a
                    // misaligned protocol forever. Report the worker as
                    // failed and stop.
                    let _ = tx.send((
                        idx,
                        gen,
                        PipeEvent::Exit { reason: format!("protocol desync: {e}") },
                    ));
                    return;
                }
            }
        }
    });
    Ok(WorkerProc {
        child,
        stdin,
        running: None,
        gen,
        alive: true,
        reader: Some(reader),
        resident: HashSet::new(),
    })
}

impl MultisessionBackend {
    pub fn new(n: usize) -> Result<Self, String> {
        Self::with_name(n, "multisession")
    }

    pub fn with_name(n: usize, name: &'static str) -> Result<Self, String> {
        Self::with_codec(n, name, WireCodec::active())
    }

    /// Construct with an explicit codec — used by tests and benches that
    /// compare transports without touching the process environment.
    pub fn with_codec(n: usize, name: &'static str, codec: WireCodec) -> Result<Self, String> {
        let n = n.max(1);
        let bin = super::worker::worker_binary()?;
        let (tx, rx) = channel::<(usize, u64, PipeEvent)>();
        let mut workers = Vec::with_capacity(n);
        for idx in 0..n {
            workers.push(spawn_worker(&bin, codec, &tx, idx, 0)?);
        }
        Ok(MultisessionBackend {
            codec,
            bin,
            workers,
            rx,
            tx,
            queue: VecDeque::new(),
            contexts: HashMap::new(),
            local_events: VecDeque::new(),
            pipe_stash: VecDeque::new(),
            blobs: HashMap::new(),
            ctx_blobs: HashMap::new(),
            task_frames: HashMap::new(),
            name,
        })
    }

    /// Reap a lost worker, spawn a replacement (next generation) into
    /// the same slot, and replay every active context frame to it.
    /// Returns the task the worker was running when it died, if any.
    /// The caller is responsible for surfacing the matching
    /// [`BackendEvent::WorkerLost`].
    fn supervise(&mut self, idx: usize, reason: &str) -> Option<u64> {
        // Reap the process, then join its reader: after the join, every
        // event the worker managed to deliver is on the channel.
        let (reader, cur_gen) = {
            let w = &mut self.workers[idx];
            let _ = w.child.kill();
            let _ = w.child.wait();
            w.alive = false;
            (w.reader.take(), w.gen)
        };
        if let Some(h) = reader {
            let _ = h.join();
        }
        // Salvage the casualty's already-delivered events before bumping
        // the generation would discard them: a task whose Done was
        // queued but unread *completed* — it must not be reported lost
        // (and, under retries, re-executed). Other workers' events are
        // stashed and re-processed ahead of the channel, preserving
        // their order.
        while let Ok((i2, g2, ev)) = self.rx.try_recv() {
            if i2 == idx && g2 == cur_gen {
                match ev {
                    PipeEvent::Msg(WorkerMsg::Done(outcome)) => {
                        self.workers[idx].running = None;
                        self.task_frames.remove(&outcome.id);
                        self.local_events.push_back(BackendEvent::Done(outcome));
                    }
                    // The store answering it is being reaped; the task
                    // is lost and will be resubmitted through the
                    // normal WorkerLost path.
                    PipeEvent::Msg(WorkerMsg::CacheMiss { .. }) => {}
                    PipeEvent::Msg(WorkerMsg::Progress { task_id, cond }) => {
                        self.local_events.push_back(BackendEvent::Progress { task_id, cond });
                    }
                    // The loss is what we are handling right now.
                    PipeEvent::Exit { .. } => {}
                }
            } else {
                self.pipe_stash.push_back((i2, g2, ev));
            }
        }
        let (lost, gen) = {
            let w = &mut self.workers[idx];
            (w.running.take(), w.gen + 1)
        };
        if let Some(t) = lost {
            self.task_frames.remove(&t);
        }
        eprintln!("futurize: {} worker {idx} lost ({reason}); spawning replacement", self.name);
        match spawn_worker(&self.bin, self.codec, &self.tx, idx, gen) {
            Ok(mut proc) => {
                // Replay active shared contexts so in-flight map calls
                // can keep submitting slices to the replacement.
                for payload in self.contexts.values() {
                    if write_frame(&mut proc.stdin, payload)
                        .and_then(|()| proc.stdin.flush())
                        .is_err()
                    {
                        let _ = proc.child.kill();
                        let _ = proc.child.wait();
                        proc.alive = false;
                        break;
                    }
                }
                // Replay cached blobs referenced by *still-active*
                // contexts — the replacement's store is empty, and an
                // in-flight map must not need a CacheMiss round for
                // data the parent already knows it requires. Digests
                // whose last context dropped are gone from `blobs` and
                // are deliberately not replayed.
                if proc.alive {
                    let mut digests: Vec<u64> = self
                        .contexts
                        .keys()
                        .filter_map(|c| self.ctx_blobs.get(c))
                        .flatten()
                        .copied()
                        .collect();
                    digests.sort_unstable();
                    digests.dedup();
                    for d in digests {
                        let bytes = self.blobs.get(&d).map(|b| b.bytes).unwrap_or(0);
                        let Ok(Some(frame)) =
                            ensure_blob_frame(self.codec, &mut self.blobs, d)
                        else {
                            continue;
                        };
                        if write_frame(&mut proc.stdin, frame)
                            .and_then(|()| proc.stdin.flush())
                            .is_err()
                        {
                            let _ = proc.child.kill();
                            let _ = proc.child.wait();
                            proc.alive = false;
                            break;
                        }
                        proc.resident.insert(d);
                        crate::wire::stats::record_cache_put(bytes);
                        BLOBS_REPLAYED.fetch_add(1, Ordering::Relaxed);
                    }
                }
                self.workers[idx] = proc;
            }
            Err(e) => {
                eprintln!("futurize: could not respawn {} worker {idx}: {e}", self.name);
                // Retire the slot; stale events from the reaped child
                // must still be discarded.
                self.workers[idx].gen = gen;
            }
        }
        lost
    }

    /// Write an already-encoded protocol frame to every live worker. The
    /// message was encoded (and its logical bytes recorded) once; each
    /// worker copy still crosses the process boundary, so `write_frame`
    /// accounts one physical copy per worker. A worker that dies
    /// mid-broadcast is supervised (replaced, contexts replayed) and
    /// reported via [`BackendEvent::WorkerLost`] instead of failing the
    /// whole call — the healthy workers already received the frame.
    fn broadcast(&mut self, payload: &[u8]) -> Result<(), String> {
        let mut lost_any = false;
        for idx in 0..self.workers.len() {
            if !self.workers[idx].alive {
                continue;
            }
            let ok = {
                let w = &mut self.workers[idx];
                write_frame(&mut w.stdin, payload).and_then(|()| w.stdin.flush()).is_ok()
            };
            if !ok {
                // The replacement receives this frame too: register
                // frames are cached before broadcast and replayed by
                // supervise(); a drop frame for a context it never had
                // is a no-op on the worker.
                let lost = self.supervise(idx, "broadcast write failed");
                self.local_events.push_back(BackendEvent::WorkerLost { worker: idx, task: lost });
                lost_any = true;
            }
        }
        if lost_any {
            // The replacement is idle; hand it any queued work.
            self.dispatch()?;
        }
        Ok(())
    }

    fn dispatch(&mut self) -> Result<(), String> {
        let mut respawns = 0usize;
        loop {
            let Some(idle) = self.workers.iter().position(|w| w.alive && w.running.is_none())
            else {
                break;
            };
            let Some(task) = self.queue.pop_front() else { break };
            // Data-plane cache, the lazy-ship half: make every blob the
            // task's context references resident on the chosen worker
            // before the task frame itself goes out (stdin FIFO then
            // guarantees resolution). A digest already on the worker's
            // ledger ships nothing — that is the cross-call win.
            let ctx_digests: Vec<u64> = task
                .kind
                .context_id()
                .and_then(|c| self.ctx_blobs.get(&c))
                .cloned()
                .unwrap_or_default();
            let mut put_failed = false;
            for d in &ctx_digests {
                let bytes = self.blobs.get(d).map(|b| b.bytes).unwrap_or(0);
                if self.workers[idle].resident.contains(d) {
                    crate::wire::stats::record_cache_hit(bytes);
                    continue;
                }
                let Some(frame) = ensure_blob_frame(self.codec, &mut self.blobs, *d)? else {
                    continue;
                };
                let w = &mut self.workers[idle];
                if write_frame(&mut w.stdin, frame).and_then(|()| w.stdin.flush()).is_err() {
                    put_failed = true;
                    break;
                }
                w.resident.insert(*d);
                crate::wire::stats::record_cache_put(bytes);
            }
            if put_failed {
                self.queue.push_front(task);
                respawns += 1;
                if respawns > self.workers.len() * 2 {
                    return Err(
                        "multisession: workers are dying faster than they can be respawned"
                            .into(),
                    );
                }
                let lost = self.supervise(idle, "cache put write failed");
                self.local_events.push_back(BackendEvent::WorkerLost { worker: idle, task: lost });
                continue;
            }
            let payload = self
                .codec
                .encode(&ParentMsgRef::Task(&task))
                .map_err(|e| format!("serialize task: {e}"))?;
            let id = task.id;
            let w = &mut self.workers[idle];
            match write_frame(&mut w.stdin, &payload).and_then(|()| w.stdin.flush()) {
                Ok(()) => {
                    w.running = Some(id);
                    if !ctx_digests.is_empty() {
                        // Keep the encoded frame for CacheMiss
                        // redelivery; dropped again on Done.
                        self.task_frames.insert(id, payload);
                    }
                }
                Err(_) => {
                    // The worker died between events. The task was never
                    // delivered — put it back and hand it to the
                    // replacement on the next turn of the loop.
                    self.queue.push_front(task);
                    respawns += 1;
                    if respawns > self.workers.len() * 2 {
                        return Err(
                            "multisession: workers are dying faster than they can be respawned"
                                .into(),
                        );
                    }
                    let lost = self.supervise(idle, "task write failed");
                    self.local_events
                        .push_back(BackendEvent::WorkerLost { worker: idle, task: lost });
                }
            }
        }
        Ok(())
    }

    /// Process one reader-channel event. `None` means the event was
    /// internal (stale generation, or fully absorbed) and the caller
    /// should keep polling.
    fn handle(
        &mut self,
        idx: usize,
        gen: u64,
        ev: PipeEvent,
    ) -> Result<Option<BackendEvent>, String> {
        if self.workers[idx].gen != gen {
            // An event from a reaped predecessor of this slot (its loss
            // was already handled on the write path). Nothing it says
            // can be trusted or matched to current state.
            return Ok(None);
        }
        match ev {
            PipeEvent::Msg(WorkerMsg::Progress { task_id, cond }) => {
                Ok(Some(BackendEvent::Progress { task_id, cond }))
            }
            PipeEvent::Msg(WorkerMsg::Done(outcome)) => {
                self.workers[idx].running = None;
                self.task_frames.remove(&outcome.id);
                self.dispatch()?;
                Ok(Some(BackendEvent::Done(outcome)))
            }
            PipeEvent::Msg(WorkerMsg::CacheMiss { task_id, digests }) => {
                // The worker's store no longer holds digests the parent
                // ledger believed resident (fresh respawn that raced a
                // task, LRU eviction). It discarded the task; re-put
                // the blobs and re-send the stored task frame — stdin
                // FIFO makes the retry resolve. Entirely internal: the
                // dispatch core never sees a miss.
                let mut healthy = true;
                for d in &digests {
                    crate::wire::stats::record_cache_miss();
                    let bytes = self.blobs.get(d).map(|b| b.bytes).unwrap_or(0);
                    match ensure_blob_frame(self.codec, &mut self.blobs, *d)? {
                        Some(frame) => {
                            let w = &mut self.workers[idx];
                            if write_frame(&mut w.stdin, frame)
                                .and_then(|()| w.stdin.flush())
                                .is_ok()
                            {
                                w.resident.insert(*d);
                                crate::wire::stats::record_cache_put(bytes);
                            } else {
                                healthy = false;
                                break;
                            }
                        }
                        // The parent no longer holds the blob — an
                        // invariant break this task cannot recover
                        // from on this worker.
                        None => {
                            healthy = false;
                            break;
                        }
                    }
                }
                let frame = if healthy { self.task_frames.get(&task_id).cloned() } else { None };
                match frame {
                    Some(f) => {
                        let w = &mut self.workers[idx];
                        if write_frame(&mut w.stdin, &f).and_then(|()| w.stdin.flush()).is_ok() {
                            Ok(None)
                        } else {
                            let lost = self.supervise(idx, "cache re-put write failed");
                            self.dispatch()?;
                            Ok(Some(BackendEvent::WorkerLost { worker: idx, task: lost }))
                        }
                    }
                    // Treat the slot as lost so the dispatch core's
                    // retry machinery takes over instead of the map
                    // hanging on a task that can never complete.
                    None => {
                        let lost = self.supervise(idx, "cache state unavailable for retry");
                        self.dispatch()?;
                        Ok(Some(BackendEvent::WorkerLost { worker: idx, task: lost }))
                    }
                }
            }
            PipeEvent::Exit { reason } => {
                let lost = self.supervise(idx, &reason);
                self.dispatch()?;
                Ok(Some(BackendEvent::WorkerLost { worker: idx, task: lost }))
            }
        }
    }
}

impl Backend for MultisessionBackend {
    fn name(&self) -> &'static str {
        self.name
    }

    fn workers(&self) -> usize {
        self.workers.len()
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        // Borrowing mirror: encode straight out of the Arc, no deep clone.
        let payload = self
            .codec
            .encode(&ParentMsgRef::RegisterContext(&ctx))
            .map_err(|e| format!("serialize context: {e}"))?;
        // Cache before broadcasting: a worker replaced during (or after)
        // the broadcast gets the frame replayed from this cache.
        self.contexts.insert(ctx.id, payload.clone());
        self.broadcast(&payload)
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        self.contexts.remove(&ctx_id);
        // Release the context's blob references; a blob with no
        // remaining referents is dropped parent-side (bounded memory).
        // Worker resident ledgers are deliberately untouched — the
        // worker-side LRU keeps the bytes across calls, and a repeat
        // map over the same data re-puts parent-side cheaply (the Arc
        // comes back from the caller) while shipping nothing.
        if let Some(digests) = self.ctx_blobs.remove(&ctx_id) {
            for d in digests {
                if let Some(e) = self.blobs.get_mut(&d) {
                    e.refs.remove(&ctx_id);
                    if e.refs.is_empty() {
                        self.blobs.remove(&d);
                    }
                }
            }
        }
        let payload = self
            .codec
            .encode(&ParentMsg::DropContext(ctx_id))
            .map_err(|e| format!("serialize context drop: {e}"))?;
        self.broadcast(&payload)
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        self.queue.push_back(task);
        self.dispatch()
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        loop {
            if let Some(ev) = self.local_events.pop_front() {
                return Ok(ev);
            }
            if let Some((idx, gen, ev)) = self.pipe_stash.pop_front() {
                if let Some(ev) = self.handle(idx, gen, ev)? {
                    return Ok(ev);
                }
                continue;
            }
            if !self.workers.iter().any(|w| w.alive) {
                // Every slot is dead and respawning failed: erroring out
                // beats blocking on a channel no one will ever write to.
                return Err(format!(
                    "{}: all workers lost and none could be respawned",
                    self.name
                ));
            }
            let (idx, gen, ev) =
                self.rx.recv().map_err(|e| format!("multisession backend: {e}"))?;
            if let Some(ev) = self.handle(idx, gen, ev)? {
                return Ok(ev);
            }
        }
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        loop {
            if let Some(ev) = self.local_events.pop_front() {
                return Ok(Some(ev));
            }
            if let Some((idx, gen, ev)) = self.pipe_stash.pop_front() {
                if let Some(ev) = self.handle(idx, gen, ev)? {
                    return Ok(Some(ev));
                }
                continue;
            }
            match self.rx.try_recv() {
                Ok((idx, gen, ev)) => {
                    if let Some(ev) = self.handle(idx, gen, ev)? {
                        return Ok(Some(ev));
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => return Ok(None),
                Err(e) => return Err(format!("multisession backend: {e}")),
            }
        }
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        self.queue.drain(..).map(|t| t.id).collect()
    }

    fn data_cache(&self) -> bool {
        true
    }

    fn put_blob(&mut self, ctx_id: u64, digest: u64, blob: CacheSource) -> Result<(), String> {
        // Parent-side ledger only: nothing is shipped here. dispatch()
        // makes the digest resident on a worker the first time a task
        // referencing it lands there.
        let entry = self.blobs.entry(digest).or_insert_with(|| BlobEntry {
            bytes: blob.approx_bytes() as u64,
            source: blob,
            refs: HashSet::new(),
            frame: None,
        });
        entry.refs.insert(ctx_id);
        let list = self.ctx_blobs.entry(ctx_id).or_default();
        if !list.contains(&digest) {
            list.push(digest);
        }
        Ok(())
    }
}

impl Drop for MultisessionBackend {
    fn drop(&mut self) {
        if let Ok(payload) = self.codec.encode(&ParentMsg::Shutdown) {
            for w in self.workers.iter_mut().filter(|w| w.alive) {
                let _ = write_frame(&mut w.stdin, &payload);
                let _ = w.stdin.flush();
            }
        }
        // Grace period, then kill: a wedged worker (stuck mid-task, never
        // reading the Shutdown) must not hang session teardown forever.
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let mut pending = false;
            for w in self.workers.iter_mut().filter(|w| w.alive) {
                match w.child.try_wait() {
                    Ok(Some(_)) => w.alive = false,
                    Ok(None) => pending = true,
                    Err(_) => w.alive = false,
                }
            }
            if !pending || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        for w in self.workers.iter_mut().filter(|w| w.alive) {
            let _ = w.child.kill();
            let _ = w.child.wait();
        }
    }
}
