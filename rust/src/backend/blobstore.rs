//! The content-addressed data-plane cache.
//!
//! Iterative workloads (boot resampling, CV folds, a glmnet lambda
//! path) map over the *same* multi-megabyte data many times per
//! session. PR 2's Arc-freeze made that free for in-process backends;
//! this module extends "ship once" across the process boundary and
//! across calls. At freeze time the dispatch core digests large frozen
//! payloads ([`crate::rlite::serialize::digest_val`] and friends — a
//! structural FNV-1a walk, no copy) and replaces them with digest
//! references; process backends ship the bytes as a
//! `ParentMsg::CachePut` frame the *first* time a digest lands on a
//! given worker and send only the 8-byte digest thereafter. The
//! parent keeps a per-worker ledger of resident digests; workers keep
//! an LRU [`BlobStore`] with a byte budget. A worker that no longer
//! holds a referenced digest (fresh respawn, eviction) answers the
//! task with a `CacheMiss` negative-ack and the parent re-puts — a
//! cold worker can never wedge a map.
//!
//! Kill switches: `FUTURIZE_NO_CACHE=1` in the environment or
//! `futurize(cache = "off")` per call disable extraction entirely,
//! which the differential test suite uses to prove bit-identical
//! results either way.

use std::collections::HashMap;
use std::sync::Arc;

use serde_derive::{Deserialize, Serialize};

use crate::rlite::serialize::WireVal;

/// Environment kill switch: `FUTURIZE_NO_CACHE=1` disables the cache.
pub const NO_CACHE_ENV: &str = "FUTURIZE_NO_CACHE";

/// Worker-side blob-store byte budget override.
pub const CACHE_BYTES_ENV: &str = "FUTURIZE_CACHE_BYTES";

/// Default worker-side blob-store budget (~256 MiB).
pub const DEFAULT_CACHE_BYTES: usize = 256 << 20;

/// Payloads below this size ship inline — digesting and ledger
/// bookkeeping only pay off once the blob dwarfs the 8-byte reference.
pub const CACHE_MIN_BYTES: usize = 64 << 10;

/// True unless `FUTURIZE_NO_CACHE=1`.
pub fn cache_enabled() -> bool {
    std::env::var(NO_CACHE_ENV).as_deref() != Ok("1")
}

/// The worker-side blob-store byte budget.
pub fn cache_budget() -> usize {
    std::env::var(CACHE_BYTES_ENV)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_CACHE_BYTES)
}

/// A cacheable payload as it travels in a `CachePut` frame.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub enum CacheBlob {
    /// A frozen map-element vector (`ElementSource::Items`).
    Items(Vec<WireVal>),
    /// A frozen foreach binding vector (`ElementSource::Bindings`).
    Bindings(Vec<Vec<(String, WireVal)>>),
    /// One oversized context global.
    Val(WireVal),
}

/// Encode-only borrowing mirror of [`CacheBlob`]: lets the parent
/// serialize a blob straight out of its `Arc` without deep-cloning.
/// Variant names and order MUST match [`CacheBlob`] exactly — both
/// codecs tag enums by variant, so the two encode byte-identically
/// (pinned alongside `ref_mirror_encodes_identically`).
#[derive(Serialize)]
pub enum CacheBlobRef<'a> {
    Items(&'a [WireVal]),
    Bindings(&'a [Vec<(String, WireVal)>]),
    Val(&'a WireVal),
}

/// Parent-side handle on a frozen payload: the `Arc` the dispatch core
/// already holds, kept alive for as long as any active context
/// references its digest so a `CacheMiss`/respawn re-put never needs
/// the original caller's data.
#[derive(Clone)]
pub enum CacheSource {
    Items(Arc<Vec<WireVal>>),
    Bindings(Arc<Vec<Vec<(String, WireVal)>>>),
    Val(Arc<WireVal>),
}

impl CacheSource {
    /// The borrowing encode mirror for this source.
    pub fn to_ref(&self) -> CacheBlobRef<'_> {
        match self {
            CacheSource::Items(a) => CacheBlobRef::Items(a.as_slice()),
            CacheSource::Bindings(a) => CacheBlobRef::Bindings(a.as_slice()),
            CacheSource::Val(a) => CacheBlobRef::Val(a),
        }
    }

    /// Approximate in-memory payload size (same estimator the
    /// extraction threshold uses), for hit/evict accounting.
    pub fn approx_bytes(&self) -> usize {
        match self {
            CacheSource::Items(a) => a.iter().map(|v| v.approx_size()).sum(),
            CacheSource::Bindings(a) => a
                .iter()
                .map(|row| row.iter().map(|(n, v)| n.len() + v.approx_size()).sum::<usize>())
                .sum(),
            CacheSource::Val(a) => a.approx_size(),
        }
    }
}

/// A blob as the worker stores it: `Arc`-wrapped so resolving a task
/// reference is a pointer bump, never a deep copy.
#[derive(Clone)]
pub enum StoredBlob {
    Items(Arc<Vec<WireVal>>),
    Bindings(Arc<Vec<Vec<(String, WireVal)>>>),
    Val(Arc<WireVal>),
}

struct Entry {
    blob: StoredBlob,
    bytes: usize,
    /// Which task-processing epoch inserted this entry. Entries from
    /// the *current* epoch are eviction-exempt: a task's whole re-put
    /// working set must survive until that task runs, otherwise a
    /// budget smaller than one working set could evict blob A while
    /// re-putting blob B forever. The budget is therefore soft within
    /// a single task's working set.
    epoch: u64,
    /// LRU clock.
    tick: u64,
}

/// The worker-side LRU blob store.
pub struct BlobStore {
    entries: HashMap<u64, Entry>,
    budget: usize,
    used: usize,
    epoch: u64,
    clock: u64,
}

impl BlobStore {
    pub fn new(budget: usize) -> BlobStore {
        BlobStore { entries: HashMap::new(), budget, used: 0, epoch: 0, clock: 0 }
    }

    /// Mark the start of a new task frame: previously inserted blobs
    /// become eligible for eviction again.
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
    }

    /// Insert a blob under its digest, evicting least-recently-used
    /// entries from earlier epochs if the budget demands it.
    pub fn insert(&mut self, digest: u64, blob: CacheBlob) {
        if self.entries.contains_key(&digest) {
            return;
        }
        let stored = match blob {
            CacheBlob::Items(v) => StoredBlob::Items(Arc::new(v)),
            CacheBlob::Bindings(v) => StoredBlob::Bindings(Arc::new(v)),
            CacheBlob::Val(v) => StoredBlob::Val(Arc::new(v)),
        };
        let bytes = match &stored {
            StoredBlob::Items(a) => a.iter().map(|v| v.approx_size()).sum(),
            StoredBlob::Bindings(a) => a
                .iter()
                .map(|row| row.iter().map(|(n, v)| n.len() + v.approx_size()).sum::<usize>())
                .sum(),
            StoredBlob::Val(a) => a.approx_size(),
        };
        self.clock += 1;
        self.used += bytes;
        self.entries
            .insert(digest, Entry { blob: stored, bytes, epoch: self.epoch, tick: self.clock });
        while self.used > self.budget {
            let victim = self
                .entries
                .iter()
                .filter(|(d, e)| **d != digest && e.epoch < self.epoch)
                .min_by_key(|(_, e)| e.tick)
                .map(|(d, _)| *d);
            let Some(d) = victim else { break };
            if let Some(e) = self.entries.remove(&d) {
                self.used -= e.bytes;
                crate::wire::stats::record_cache_evict(e.bytes as u64);
            }
        }
    }

    fn touch(&mut self, digest: u64) {
        self.clock += 1;
        if let Some(e) = self.entries.get_mut(&digest) {
            e.tick = self.clock;
        }
    }

    /// Resolve an items blob, refreshing its LRU position.
    pub fn get_items(&mut self, digest: u64) -> Option<Arc<Vec<WireVal>>> {
        self.touch(digest);
        match self.entries.get(&digest).map(|e| &e.blob) {
            Some(StoredBlob::Items(a)) => Some(a.clone()),
            _ => None,
        }
    }

    /// Resolve a bindings blob, refreshing its LRU position.
    pub fn get_bindings(&mut self, digest: u64) -> Option<Arc<Vec<Vec<(String, WireVal)>>>> {
        self.touch(digest);
        match self.entries.get(&digest).map(|e| &e.blob) {
            Some(StoredBlob::Bindings(a)) => Some(a.clone()),
            _ => None,
        }
    }

    /// Resolve a single-value blob, refreshing its LRU position.
    pub fn get_val(&mut self, digest: u64) -> Option<Arc<WireVal>> {
        self.touch(digest);
        match self.entries.get(&digest).map(|e| &e.blob) {
            Some(StoredBlob::Val(a)) => Some(a.clone()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::WireCodec;

    fn dbl(n: usize, fill: f64) -> WireVal {
        WireVal::Dbl(vec![fill; n], None)
    }

    #[test]
    fn blob_ref_mirror_encodes_identically() {
        let items = vec![dbl(3, 1.0), dbl(2, 2.0)];
        let bindings = vec![vec![("x".to_string(), dbl(2, 3.0))]];
        let val = dbl(4, 4.0);
        let owned = [
            CacheBlob::Items(items.clone()),
            CacheBlob::Bindings(bindings.clone()),
            CacheBlob::Val(val.clone()),
        ];
        let borrowed = [
            CacheBlobRef::Items(&items),
            CacheBlobRef::Bindings(&bindings),
            CacheBlobRef::Val(&val),
        ];
        for (o, b) in owned.iter().zip(borrowed.iter()) {
            for codec in [WireCodec::Binary, WireCodec::Json] {
                let eo = codec.encode(o).unwrap();
                let eb = codec.encode(b).unwrap();
                assert_eq!(eo, eb, "{codec:?}: CacheBlobRef drifted from CacheBlob");
                let back: CacheBlob = codec.decode(&eo).unwrap();
                assert_eq!(
                    std::mem::discriminant(o),
                    std::mem::discriminant(&back),
                    "{codec:?}"
                );
            }
        }
    }

    #[test]
    fn lru_evicts_older_epochs_only() {
        let one_k = dbl(128, 1.0); // ~1 KiB of doubles
        let bytes = one_k.approx_size();
        let mut store = BlobStore::new(bytes * 2 + 64);
        store.bump_epoch();
        store.insert(1, CacheBlob::Val(one_k.clone()));
        store.insert(2, CacheBlob::Val(dbl(128, 2.0)));
        // Same epoch: inserting a third over budget must NOT evict the
        // first two (they are this task's working set).
        store.insert(3, CacheBlob::Val(dbl(128, 3.0)));
        assert!(store.get_val(1).is_some());
        assert!(store.get_val(2).is_some());
        assert!(store.get_val(3).is_some());
        // Next task frame: old entries become evictable; the LRU one
        // (digest 1 untouched longest after we refresh 2 and 3) goes.
        store.bump_epoch();
        store.get_val(2);
        store.get_val(3);
        store.insert(4, CacheBlob::Val(dbl(128, 4.0)));
        assert!(store.get_val(1).is_none(), "LRU entry from old epoch must be evicted");
        assert!(store.get_val(4).is_some());
    }

    #[test]
    fn env_parsing_defaults() {
        assert_eq!(DEFAULT_CACHE_BYTES, 256 << 20);
        assert!(CACHE_MIN_BYTES >= 1 << 10);
    }
}
