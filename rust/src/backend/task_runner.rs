//! Shared task execution: every backend ultimately calls [`run_task`].
//!
//! A task runs in a *fresh* interpreter seeded only with its exported
//! globals — the same isolation a PSOCK worker gives R. Stdout and
//! conditions are captured for as-is relay in the parent (paper §4.9);
//! progress-class conditions are additionally streamed through
//! `progress_hook` the moment they are signaled (paper §4.10).
//!
//! Slice tasks ([`TaskKind::MapSlice`] / [`TaskKind::ForeachSlice`])
//! carry only their elements — as `WireSlice` windows that read
//! straight out of the dispatch core's `Arc`-shared storage on
//! in-process backends (the zero-copy fast path) and arrive as owned
//! decoded vectors on process workers. The function/extras/globals they
//! execute against live in a [`TaskContext`] the backend registered
//! beforehand and resolves for [`run_task`]. A slice arriving for an
//! unknown context is a protocol violation and yields an error outcome
//! rather than a panic.

use std::cell::RefCell;
use std::rc::Rc;

use crate::future_core::{ContextBody, TaskContext, TaskKind, TaskOutcome, TaskPayload};
use crate::rlite::conditions::{CaptureLog, RCondition};
use crate::rlite::env::{define, Env, EnvRef};
use crate::rlite::eval::{HandlerFrame, Interp, InterpConfig, OutSink, Signal};
use crate::rlite::serialize::{from_wire, to_wire_owned, WireVal};
use crate::rlite::value::RVal;
use crate::rng::RngStream;

/// Condition classes streamed near-live instead of relayed at resolve
/// time. Mirrors progressr's `progression` condition class.
pub const LIVE_CLASSES: &[&str] = &["progression", "immediateCondition"];

/// `FUTURIZE_INTERP_COMPAT=1` disables the per-element fast paths
/// (iteration-frame reuse and hoisted capture), restoring the
/// allocate-per-element loop shape this PR replaced. Used by
/// `benches/interp_micro.rs` to measure the optimization in one binary.
fn compat_mode() -> bool {
    std::env::var("FUTURIZE_INTERP_COMPAT").map(|v| v == "1").unwrap_or(false)
}

/// Per-slice capture scope: the Collect handler + stdout sink are pushed
/// once per slice (not once per element) and drained into a single
/// [`CaptureLog`], which is exactly what the per-element merge produced.
struct SliceCapture {
    sink: Rc<RefCell<Vec<RCondition>>>,
    buf: Rc<RefCell<String>>,
    rng_before: bool,
}

impl SliceCapture {
    fn begin(interp: &mut Interp) -> SliceCapture {
        let sink: Rc<RefCell<Vec<RCondition>>> = Rc::new(RefCell::new(Vec::new()));
        let buf: Rc<RefCell<String>> = Rc::new(RefCell::new(String::new()));
        interp
            .handlers
            .push(HandlerFrame::Collect { classes: vec!["condition".into()], sink: sink.clone() });
        interp.out.push(OutSink::Capture(buf.clone()));
        let rng_before = interp.rng_used;
        interp.rng_used = false;
        SliceCapture { sink, buf, rng_before }
    }

    fn finish(self, interp: &mut Interp) -> CaptureLog {
        interp.out.pop();
        interp.handlers.pop();
        let rng_used = interp.rng_used;
        interp.rng_used = self.rng_before || rng_used;
        CaptureLog {
            stdout: std::mem::take(&mut *self.buf.borrow_mut()),
            conditions: std::mem::take(&mut *self.sink.borrow_mut()),
            rng_used,
        }
    }
}

/// An iteration-frame pool of size one: hands out a cleared child frame
/// of `parent` per element, reusing the allocation as long as nothing
/// kept a reference to it (checked via `Rc::strong_count` after each
/// call — the belt to the static escape analysis' braces).
struct FrameReuse {
    parent: EnvRef,
    /// The reusable frame, absent while lent out or after an escape.
    spare: Option<EnvRef>,
    enabled: bool,
}

impl FrameReuse {
    fn new(parent: EnvRef, enabled: bool) -> FrameReuse {
        FrameReuse { parent, spare: None, enabled }
    }

    fn take(&mut self) -> EnvRef {
        match self.spare.take() {
            Some(e) => {
                e.borrow_mut().vars.clear();
                e
            }
            None => Env::child_of(&self.parent),
        }
    }

    fn give_back(&mut self, fenv: EnvRef) {
        // Reuse only when we hold the sole reference: a closure created
        // in the frame, an `environment()` capture, or an escaped child
        // env all keep the count above 1, and such a frame must survive
        // untouched (R frames are garbage-collected, not recycled).
        if self.enabled && Rc::strong_count(&fenv) == 1 {
            self.spare = Some(fenv);
        }
    }
}

/// Execute one payload, invoking `progress_hook` for every live-class
/// condition as it is signaled. `ctx` must be the registered
/// [`TaskContext`] matching `payload.kind.context_id()` (or `None` for
/// context-free tasks).
pub fn run_task(
    payload: &TaskPayload,
    ctx: Option<&TaskContext>,
    worker_idx: usize,
    mut progress_hook: Option<&mut dyn FnMut(u64, RCondition)>,
) -> TaskOutcome {
    let started = crate::future_core::driver::now_unix();
    let mut interp = Interp::with_config(InterpConfig {
        time_scale: payload.time_scale,
        ..Default::default()
    });
    // Inherit the plan-stack levels the parent did not consume: a
    // nested futurized map inside the task body instantiates its own
    // inner backend from this instead of degrading to sequential. An
    // empty inherited stack means nested calls default to sequential
    // (the implicit-inner nesting guard). Context-free Expr tasks
    // (low-level future()) carry their nesting in the payload itself.
    if let Some(ctx) = ctx {
        interp.session.adopt_nesting(&ctx.nesting);
    } else if let TaskKind::Expr { nesting, .. } = &payload.kind {
        interp.session.adopt_nesting(nesting);
    }
    // Re-prime a cached inner backend for the adopted stack, if this
    // worker kept one from an earlier task: nested maps then reuse the
    // live worker pool instead of spawning a fresh one per chunk.
    crate::backend::inner_cache::lend(&mut interp.session);
    // Stream live-class conditions through the hook; mark them so they are
    // not double-relayed from the final capture log.
    let streamed: Rc<RefCell<Vec<RCondition>>> = Rc::new(RefCell::new(Vec::new()));
    if progress_hook.is_some() {
        for class in LIVE_CLASSES {
            let streamed = streamed.clone();
            interp.handlers.push(HandlerFrame::Native {
                class: class.to_string(),
                hook: Rc::new(RefCell::new(move |c: &RCondition| {
                    streamed.borrow_mut().push(c.clone());
                })),
            });
        }
    }

    let genv = interp.global.clone();
    let (result, mut log) = execute_kind(&mut interp, &payload.kind, ctx, &genv);

    // Drain streamed conditions through the hook and strip them from the
    // log (they have already reached the parent).
    let streamed = streamed.borrow();
    if let Some(hook) = progress_hook.as_deref_mut() {
        for c in streamed.iter() {
            hook(payload.id, c.clone());
        }
    }
    if !streamed.is_empty() {
        log.conditions.retain(|c| !LIVE_CLASSES.iter().any(|lc| c.inherits(lc)));
    }

    let nested_workers = interp.session.peak_backend_workers;
    // Park the live inner backend (if any) in this worker's cache
    // before the interpreter drops — the next task with the same
    // inherited stack picks it up via `lend`.
    crate::backend::inner_cache::restore(&mut interp.session);
    // Worker-side reduction fusion: when the context carries a plan and
    // the slice passed the plan's exactness gate, ship a constant-size
    // partial aggregate instead of the O(slice) values. A gate miss
    // ships the full values — the parent folds them with the exact
    // sequential semantics, so the result is identical either way.
    let mut partial = None;
    let result = match (ctx.and_then(|c| c.reduce), result) {
        (Some(plan), Ok(vals)) => match crate::transpile::reduce::fold_slice(&plan, &vals) {
            Some(p) => {
                crate::transpile::reduce::note_slice_folded();
                partial = Some(p);
                Ok(vec![])
            }
            None => {
                crate::transpile::reduce::note_slice_fallback();
                Ok(vals)
            }
        },
        (_, r) => r,
    };
    TaskOutcome {
        id: payload.id,
        values: result,
        log,
        worker: worker_idx,
        started_unix: started,
        finished_unix: crate::future_core::driver::now_unix(),
        nested_workers,
        partial,
    }
}

fn execute_kind(
    interp: &mut Interp,
    kind: &TaskKind,
    ctx: Option<&TaskContext>,
    genv: &crate::rlite::env::EnvRef,
) -> (Result<Vec<WireVal>, RCondition>, CaptureLog) {
    match kind {
        TaskKind::Expr { expr, globals, .. } => {
            install_globals(genv, globals);
            let (r, log) = interp.eval_captured(expr, genv);
            (wrap_single(r), log)
        }
        // Digest references are resolved into plain slice kinds before
        // run_task is reached (worker main loop, batchtools job
        // threads); one arriving here is a dispatch bug, not a user
        // error.
        TaskKind::MapSliceRef { digest, .. } | TaskKind::ForeachSliceRef { digest, .. } => (
            Err(RCondition::error_cond(format!(
                "futurize internal error: unresolved cache ref {digest:#018x} \
                 reached the task runner"
            ))),
            CaptureLog::default(),
        ),
        TaskKind::MapSlice { ctx: ctx_id, items, seeds } => {
            let Some(ctx) = ctx else {
                return (Err(missing_context(*ctx_id)), CaptureLog::default());
            };
            let ContextBody::Map { f, extra } = &ctx.body else {
                return (Err(context_mismatch(*ctx_id, "MapSlice")), CaptureLog::default());
            };
            // Fused-kernel dispatch: a context that froze with a
            // KernelPlan runs its slice through the native kernel —
            // no interpreter, no globals install, no capture scope
            // (recognized bodies are pure: no conditions, no stdout,
            // no RNG, so an empty CaptureLog is exactly what the
            // interpreted path would produce). Any item missing the
            // runtime gate drops the whole slice back to the
            // interpreter below.
            if let Some(plan) = &ctx.kernel {
                if let Some(vals) = plan.run_slice(items) {
                    crate::transpile::fusion::note_fused_slice();
                    return (Ok(vals), CaptureLog::default());
                }
                crate::transpile::fusion::note_fallback_slice();
            }
            install_globals(genv, &ctx.globals);
            let func = from_wire(f, genv);
            let extra_vals: Vec<(Option<String>, RVal)> =
                extra.iter().map(|(n, w)| (n.clone(), from_wire(w, genv))).collect();
            let compat = compat_mode();
            // Frame reuse: a non-env-capturing closure body gets one
            // iteration frame for the whole slice (zero per-element
            // frame allocations), guarded at runtime by the Rc count.
            // Closures always route through the pool — with reuse
            // disabled (escaping body, or compat mode restoring the
            // legacy fresh-frame shape) it simply allocates per call.
            let closure = match &func {
                RVal::Closure(c) => Some(c.clone()),
                _ => None,
            };
            let mut reuse = FrameReuse::new(
                closure.as_ref().map(|c| c.env.clone()).unwrap_or_else(|| genv.clone()),
                closure
                    .as_ref()
                    .is_some_and(|c| !compat && !crate::globals::env_may_escape(&c.body)),
            );

            let mut out = Vec::with_capacity(items.len());
            let mut log = CaptureLog::default();
            let slice_capture = if compat { None } else { Some(SliceCapture::begin(interp)) };
            let mut err: Option<RCondition> = None;
            // One argument buffer for the whole slice on the closure
            // path: call_closure_in drains it, so refilling reuses its
            // capacity (extra-arg values are Rc-cheap clones; only
            // named-extra Strings copy). A builtin callee consumes an
            // owned Vec per call, as before this PR.
            let mut call_args: Vec<(Option<String>, RVal)> =
                Vec::with_capacity(1 + extra_vals.len());
            // Baseline for per-element nested-root resets on unseeded
            // maps: the root inherited from the parent session via
            // NestingInfo, so futureSeed() still steers nested seeded
            // maps even when the outer map declares no seed.
            let root0 = interp.session.rng_root_seed;
            for (k, item_w) in items.iter().enumerate() {
                if let Some(seeds) = seeds {
                    interp.rng = RngStream::new(seeds[k]);
                    // Fork the RNG tree per level: a nested seed = TRUE
                    // map derives its per-element streams from *this*
                    // element's stream, so nested draws depend only on
                    // the outer root seed and element index — never on
                    // topology, chunking, or worker placement.
                    interp.session.rng_root_seed = crate::rng::nested_root_seed(&seeds[k]);
                } else {
                    // Unseeded outer map: re-pin the nested-root
                    // baseline per element, so a nested seed = TRUE
                    // map's draws do not depend on how many earlier
                    // elements shared this task's session (chunking/
                    // topology invariance); sibling nested maps within
                    // one element still diverge via the per-call root
                    // advance in element_seeds.
                    interp.session.rng_root_seed = root0;
                }
                let item = from_wire(item_w, genv);
                let elem_capture = if compat { Some(SliceCapture::begin(interp)) } else { None };
                let r = match &closure {
                    Some(c) => {
                        call_args.clear();
                        call_args.push((None, item));
                        call_args.extend(extra_vals.iter().cloned());
                        let fenv = reuse.take();
                        let r = interp.call_closure_in(c, &mut call_args, &fenv);
                        reuse.give_back(fenv);
                        r
                    }
                    None => {
                        let mut args = Vec::with_capacity(1 + extra_vals.len());
                        args.push((None, item));
                        args.extend(extra_vals.iter().cloned());
                        interp.call_function(&func, args, genv)
                    }
                };
                if let Some(cap) = elem_capture {
                    log.merge(cap.finish(interp));
                }
                match r {
                    Ok(v) => match to_wire_owned(v) {
                        Ok(w) => out.push(w),
                        Err(e) => {
                            err = Some(RCondition::error_cond(e));
                            break;
                        }
                    },
                    Err(sig) => {
                        err = Some(signal_to_cond(sig));
                        break;
                    }
                }
            }
            if let Some(cap) = slice_capture {
                log.merge(cap.finish(interp));
            }
            match err {
                Some(cond) => (Err(cond), log),
                None => (Ok(out), log),
            }
        }
        TaskKind::ForeachSlice { ctx: ctx_id, bindings, seeds } => {
            let Some(ctx) = ctx else {
                return (Err(missing_context(*ctx_id)), CaptureLog::default());
            };
            let ContextBody::Foreach { body } = &ctx.body else {
                return (Err(context_mismatch(*ctx_id, "ForeachSlice")), CaptureLog::default());
            };
            install_globals(genv, &ctx.globals);
            let compat = compat_mode();
            let mut reuse = FrameReuse::new(
                genv.clone(),
                !compat && !crate::globals::env_may_escape(body),
            );
            let mut out = Vec::with_capacity(bindings.len());
            let mut log = CaptureLog::default();
            let slice_capture = if compat { None } else { Some(SliceCapture::begin(interp)) };
            let mut err: Option<RCondition> = None;
            let root0 = interp.session.rng_root_seed;
            for (k, bs) in bindings.iter().enumerate() {
                if let Some(seeds) = seeds {
                    interp.rng = RngStream::new(seeds[k]);
                    // Same per-level RNG fork as the map-slice loop.
                    interp.session.rng_root_seed = crate::rng::nested_root_seed(&seeds[k]);
                } else {
                    // Same per-element baseline re-pin as the map-slice
                    // loop (chunking invariance for nested seeded maps
                    // under an unseeded outer).
                    interp.session.rng_root_seed = root0;
                }
                let iter_env = reuse.take();
                for (name, w) in bs {
                    define(&iter_env, name, from_wire(w, genv));
                }
                let elem_capture = if compat { Some(SliceCapture::begin(interp)) } else { None };
                let r = interp.eval(body, &iter_env);
                if let Some(cap) = elem_capture {
                    log.merge(cap.finish(interp));
                }
                reuse.give_back(iter_env);
                match r {
                    Ok(v) => match to_wire_owned(v) {
                        Ok(w) => out.push(w),
                        Err(e) => {
                            err = Some(RCondition::error_cond(e));
                            break;
                        }
                    },
                    Err(sig) => {
                        err = Some(signal_to_cond(sig));
                        break;
                    }
                }
            }
            if let Some(cap) = slice_capture {
                log.merge(cap.finish(interp));
            }
            match err {
                Some(cond) => (Err(cond), log),
                None => (Ok(out), log),
            }
        }
    }
}

fn missing_context(id: u64) -> RCondition {
    RCondition::error_cond(format!(
        "futurize internal error: task references unregistered TaskContext {id}"
    ))
}

fn context_mismatch(id: u64, kind: &str) -> RCondition {
    RCondition::error_cond(format!(
        "futurize internal error: TaskContext {id} has the wrong body kind for a {kind} task"
    ))
}

fn wrap_single(
    r: Result<RVal, Signal>,
) -> Result<Vec<WireVal>, RCondition> {
    match r {
        Ok(v) => to_wire_owned(v).map(|w| vec![w]).map_err(RCondition::error_cond),
        Err(sig) => Err(signal_to_cond(sig)),
    }
}

fn signal_to_cond(sig: Signal) -> RCondition {
    match sig {
        Signal::Error(c) => c,
        Signal::Unwind { cond, .. } => cond,
        other => {
            RCondition::error_cond(format!("non-error control signal escaped task: {other:?}"))
        }
    }
}

fn install_globals(genv: &crate::rlite::env::EnvRef, globals: &[(String, WireVal)]) {
    for (name, w) in globals {
        define(genv, name, from_wire(w, genv));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::future_core::{ContextBody, TaskContext, TaskKind, TaskPayload};
    use crate::rlite::parse_expr;
    use crate::rlite::serialize::to_wire;

    fn expr_task(src: &str, globals: Vec<(String, WireVal)>) -> TaskPayload {
        TaskPayload {
            id: 1,
            kind: TaskKind::Expr {
                expr: parse_expr(src).unwrap(),
                globals,
                nesting: Default::default(),
            },
            time_scale: 0.0,
            capture_stdout: true,
        }
    }

    #[test]
    fn expr_task_returns_value_and_log() {
        let t = expr_task("{ cat(\"out\")\nmessage(\"msg\")\n6 * 7 }", vec![]);
        let o = run_task(&t, None, 0, None);
        let vals = o.values.unwrap();
        assert_eq!(vals.len(), 1);
        assert_eq!(o.log.stdout, "out");
        assert_eq!(o.log.conditions.len(), 1);
    }

    #[test]
    fn expr_task_error_keeps_condition() {
        let t = expr_task("stop(\"task failed\")", vec![]);
        let o = run_task(&t, None, 0, None);
        let err = o.values.unwrap_err();
        assert_eq!(err.message, "task failed");
        assert!(err.inherits("error"));
    }

    #[test]
    fn globals_are_installed() {
        let g = vec![("a".to_string(), WireVal::Dbl(vec![5.0], None))];
        let t = expr_task("a * 2", g);
        let o = run_task(&t, None, 0, None);
        match &o.values.unwrap()[0] {
            WireVal::Dbl(v, _) => assert_eq!(v[0], 10.0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn live_conditions_stream_through_hook() {
        let t = expr_task(
            "signalCondition(simpleCondition(\"tick\", class = \"progression\"))",
            vec![],
        );
        let mut seen = Vec::new();
        let o = run_task(&t, None, 0, Some(&mut |_, c| seen.push(c)));
        assert_eq!(seen.len(), 1);
        assert_eq!(seen[0].message, "tick");
        // Streamed conditions do not reappear in the final log.
        assert!(o.log.conditions.is_empty());
    }

    #[test]
    fn tasks_are_isolated() {
        // A task cannot see variables from a previous task's interpreter.
        let t1 = expr_task("leak <- 99", vec![]);
        run_task(&t1, None, 0, None);
        let t2 = expr_task("exists(\"leak\")", vec![]);
        let o = run_task(&t2, None, 0, None);
        match &o.values.unwrap()[0] {
            WireVal::Lgl(v, _) => assert!(!v[0]),
            other => panic!("{other:?}"),
        }
    }

    fn map_context(id: u64, f_src: &str) -> TaskContext {
        let mut i = Interp::new();
        i.eval_program(&format!("__f <- {f_src}")).unwrap();
        let f = crate::rlite::env::lookup(&i.global, "__f").unwrap();
        TaskContext {
            id,
            body: ContextBody::Map { f: to_wire(&f).unwrap(), extra: vec![] },
            globals: vec![],
            cached_globals: vec![],
            nesting: Default::default(),
            kernel: None,
            reduce: None,
        }
    }

    /// Attach the freeze-time kernel plan to a map context, as
    /// `run_map` would (panics if the body does not match the catalog).
    fn fuse(ctx: &mut TaskContext) {
        let kernel = {
            let ContextBody::Map { f, extra } = &ctx.body else { unreachable!() };
            crate::transpile::fusion::recognize(f, extra, &ctx.globals)
        };
        ctx.kernel = Some(kernel.expect("body must match the kernel catalog"));
    }

    #[test]
    fn fused_map_slice_matches_interpreted_bitwise() {
        let mut ctx = map_context(31, "function(x) 3 * x * x + 2 * x + 1");
        let interp_vals =
            run_task(&map_slice_task(31, 16), Some(&ctx), 0, None).values.unwrap();
        fuse(&mut ctx);
        let fused_before = crate::transpile::fusion::slices_fused();
        let o = run_task(&map_slice_task(31, 16), Some(&ctx), 0, None);
        assert!(
            crate::transpile::fusion::slices_fused() > fused_before,
            "kernel dispatch must fire"
        );
        let fused_vals = o.values.unwrap();
        assert_eq!(fused_vals.len(), interp_vals.len());
        for (f, i) in fused_vals.iter().zip(&interp_vals) {
            let (WireVal::Dbl(fv, None), WireVal::Dbl(iv, None)) = (f, i) else {
                panic!("shape mismatch: {f:?} vs {i:?}");
            };
            assert_eq!(fv[0].to_bits(), iv[0].to_bits(), "bitwise divergence");
        }
        assert!(o.log.stdout.is_empty() && o.log.conditions.is_empty() && !o.log.rng_used);
    }

    #[test]
    fn fused_gate_miss_falls_back_to_interpreter() {
        let mut ctx = map_context(32, "function(x) x * 2 + 1");
        fuse(&mut ctx);
        // A vector item misses the scalar gate: the whole slice must
        // run interpreted (which vectorizes elementwise).
        let t = TaskPayload {
            id: 33,
            kind: TaskKind::MapSlice {
                ctx: 32,
                items: vec![WireVal::Dbl(vec![1.0, 2.0], None)].into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        };
        let before = crate::transpile::fusion::slices_fallback();
        let o = run_task(&t, Some(&ctx), 0, None);
        assert!(
            crate::transpile::fusion::slices_fallback() > before,
            "fallback counter must tick"
        );
        match &o.values.unwrap()[0] {
            WireVal::Dbl(v, _) => assert_eq!(v, &[3.0, 5.0]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn map_slice_executes_against_context() {
        let ctx = map_context(7, "function(x) x + 100");
        let t = TaskPayload {
            id: 2,
            kind: TaskKind::MapSlice {
                ctx: 7,
                items: vec![WireVal::Dbl(vec![1.0], None), WireVal::Dbl(vec![2.0], None)]
                    .into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        };
        let o = run_task(&t, Some(&ctx), 0, None);
        let vals = o.values.unwrap();
        assert_eq!(vals.len(), 2);
        match &vals[1] {
            WireVal::Dbl(v, _) => assert_eq!(v[0], 102.0),
            other => panic!("{other:?}"),
        }
    }

    fn map_slice_task(ctx_id: u64, n: usize) -> TaskPayload {
        TaskPayload {
            id: 10,
            kind: TaskKind::MapSlice {
                ctx: ctx_id,
                items: (0..n)
                    .map(|k| WireVal::Dbl(vec![k as f64], None))
                    .collect::<Vec<_>>()
                    .into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        }
    }

    /// Frame allocations for one N-element slice of `f_src`.
    fn frame_allocs(f_src: &str, n: usize) -> u64 {
        let ctx = map_context(11, f_src);
        let t = map_slice_task(11, n);
        let before = crate::rlite::env::frames_allocated();
        let o = run_task(&t, Some(&ctx), 0, None);
        let delta = crate::rlite::env::frames_allocated() - before;
        assert!(o.values.is_ok(), "{:?}", o.values);
        delta
    }

    #[test]
    fn map_loop_reuses_iteration_frame() {
        // A non-capturing closure body must not allocate environment
        // frames per element: the per-slice overhead (fresh interp
        // global env, closure re-rooting, one reusable frame) is
        // constant in N.
        let small = frame_allocs("function(x) x * 2 + 1", 4);
        let large = frame_allocs("function(x) x * 2 + 1", 128);
        assert_eq!(
            small, large,
            "frame allocations must not scale with element count (got {small} for N=4, {large} for N=128)"
        );
    }

    #[test]
    fn map_loop_escaping_body_falls_back_to_fresh_frames() {
        // A body that defines a closure captures its frame: reuse must
        // back off (allocations scale with N) and results stay correct.
        let small = frame_allocs("function(x) { g <- function(y) y + x\ng(1) }", 4);
        let large = frame_allocs("function(x) { g <- function(y) y + x\ng(1) }", 64);
        assert!(large > small, "escaping bodies must get fresh frames per element");
        let ctx = map_context(12, "function(x) { g <- function(y) y + x\ng(1) }");
        let o = run_task(&map_slice_task(12, 3), Some(&ctx), 0, None);
        let vals = o.values.unwrap();
        match &vals[2] {
            WireVal::Dbl(v, _) => assert_eq!(v[0], 3.0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn map_loop_super_assign_sees_fresh_frame_per_element() {
        // Each element call must start from an empty frame even under
        // reuse: a stale binding from element k must not leak into k+1.
        let ctx = map_context(
            13,
            "function(x) { if (exists(\"stale\")) stop(\"leaked\")\nstale <- x\nstale * 2 }",
        );
        let o = run_task(&map_slice_task(13, 5), Some(&ctx), 0, None);
        let vals = o.values.unwrap();
        assert_eq!(vals.len(), 5);
    }

    /// Frame allocations for one run_task of a *nested* session (depth
    /// 1, inherited `[sequential]` stack) whose body runs an inner
    /// futurized map of `inner_n` non-capturing elements.
    fn nested_frame_allocs(inner_n: usize) -> u64 {
        use crate::backend::PlanSpec;
        use crate::future_core::NestingInfo;
        let ctx = {
            let mut i = Interp::new();
            i.eval_program(&format!(
                "__f <- function(x) sum(future_sapply(1:{inner_n}, function(y) y * 2 + x))"
            ))
            .unwrap();
            let f = crate::rlite::env::lookup(&i.global, "__f").unwrap();
            TaskContext {
                id: 21,
                body: ContextBody::Map { f: to_wire(&f).unwrap(), extra: vec![] },
                globals: vec![],
                cached_globals: vec![],
                nesting: NestingInfo {
                    stack: vec![PlanSpec::sequential()],
                    outer_workers: 2,
                    depth: 1,
                    root_seed: 42,
                },
                kernel: None,
                reduce: None,
            }
        };
        let t = TaskPayload {
            id: 22,
            kind: TaskKind::MapSlice {
                ctx: 21,
                items: vec![WireVal::Dbl(vec![1.0], None)].into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        };
        let before = crate::rlite::env::frames_allocated();
        let o = run_task(&t, Some(&ctx), 0, None);
        let delta = crate::rlite::env::frames_allocated() - before;
        // sum over y of (2y + 1) = n(n+1) + n.
        let expect = (inner_n * (inner_n + 1) + inner_n) as f64;
        match &o.values.unwrap()[0] {
            WireVal::Dbl(v, _) => assert_eq!(v[0], expect),
            other => panic!("{other:?}"),
        }
        delta
    }

    #[test]
    fn nested_map_keeps_zero_per_element_frame_allocs() {
        // The inner map of a nested session (both levels sequential, so
        // everything stays on this thread and the thread-local counter
        // sees it) must still reuse its iteration frame: total frame
        // allocations are constant in the inner element count.
        let small = nested_frame_allocs(8);
        let large = nested_frame_allocs(128);
        assert_eq!(
            small, large,
            "nested-session frame allocations must not scale with inner element count \
             (got {small} for N=8, {large} for N=128)"
        );
    }

    #[test]
    fn nested_session_dynamic_name_reads_do_not_intern() {
        // The Symbol::probe read path (dynamic `exists()` of an unbound
        // name) must not leak interner slots in nested worker sessions
        // either — the adopted plan stack must not change lookup paths.
        use crate::rlite::intern::Symbol;
        let name = "nested_probe_only_name_zq";
        assert!(Symbol::probe(name).is_none(), "test name already interned elsewhere");
        let ctx = map_context(23, &format!("function(x) exists(\"{name}\")"));
        let o = run_task(&map_slice_task(23, 2), Some(&ctx), 0, None);
        match &o.values.unwrap()[0] {
            WireVal::Lgl(v, _) => assert!(!v[0]),
            other => panic!("{other:?}"),
        }
        assert!(
            Symbol::probe(name).is_none(),
            "nested-session dynamic read must probe, not intern"
        );
    }

    #[test]
    fn map_slice_without_context_is_an_error_outcome() {
        let t = TaskPayload {
            id: 3,
            kind: TaskKind::MapSlice { ctx: 99, items: vec![].into(), seeds: None },
            time_scale: 0.0,
            capture_stdout: true,
        };
        let o = run_task(&t, None, 0, None);
        let err = o.values.unwrap_err();
        assert!(err.message.contains("unregistered TaskContext"), "{}", err.message);
    }
}
