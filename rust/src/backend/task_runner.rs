//! Shared task execution: every backend ultimately calls [`run_task`].
//!
//! A task runs in a *fresh* interpreter seeded only with its exported
//! globals — the same isolation a PSOCK worker gives R. Stdout and
//! conditions are captured for as-is relay in the parent (paper §4.9);
//! progress-class conditions are additionally streamed through
//! `progress_hook` the moment they are signaled (paper §4.10).
//!
//! Slice tasks ([`TaskKind::MapSlice`] / [`TaskKind::ForeachSlice`])
//! carry only their elements — as `WireSlice` windows that read
//! straight out of the dispatch core's `Arc`-shared storage on
//! in-process backends (the zero-copy fast path) and arrive as owned
//! decoded vectors on process workers. The function/extras/globals they
//! execute against live in a [`TaskContext`] the backend registered
//! beforehand and resolves for [`run_task`]. A slice arriving for an
//! unknown context is a protocol violation and yields an error outcome
//! rather than a panic.

use std::cell::RefCell;
use std::rc::Rc;

use crate::future_core::{ContextBody, TaskContext, TaskKind, TaskOutcome, TaskPayload};
use crate::rlite::conditions::{CaptureLog, RCondition};
use crate::rlite::env::{define, Env};
use crate::rlite::eval::{HandlerFrame, Interp, InterpConfig, Signal};
use crate::rlite::serialize::{from_wire, to_wire, WireVal};
use crate::rlite::value::RVal;
use crate::rng::RngStream;

/// Condition classes streamed near-live instead of relayed at resolve
/// time. Mirrors progressr's `progression` condition class.
pub const LIVE_CLASSES: &[&str] = &["progression", "immediateCondition"];

/// Execute one payload, invoking `progress_hook` for every live-class
/// condition as it is signaled. `ctx` must be the registered
/// [`TaskContext`] matching `payload.kind.context_id()` (or `None` for
/// context-free tasks).
pub fn run_task(
    payload: &TaskPayload,
    ctx: Option<&TaskContext>,
    worker_idx: usize,
    mut progress_hook: Option<&mut dyn FnMut(u64, RCondition)>,
) -> TaskOutcome {
    let started = crate::future_core::driver::now_unix();
    let mut interp = Interp::with_config(InterpConfig {
        time_scale: payload.time_scale,
        ..Default::default()
    });
    // Stream live-class conditions through the hook; mark them so they are
    // not double-relayed from the final capture log.
    let streamed: Rc<RefCell<Vec<RCondition>>> = Rc::new(RefCell::new(Vec::new()));
    if progress_hook.is_some() {
        for class in LIVE_CLASSES {
            let streamed = streamed.clone();
            interp.handlers.push(HandlerFrame::Native {
                class: class.to_string(),
                hook: Rc::new(RefCell::new(move |c: &RCondition| {
                    streamed.borrow_mut().push(c.clone());
                })),
            });
        }
    }

    let genv = interp.global.clone();
    let (result, mut log) = execute_kind(&mut interp, &payload.kind, ctx, &genv);

    // Drain streamed conditions through the hook and strip them from the
    // log (they have already reached the parent).
    let streamed = streamed.borrow();
    if let Some(hook) = progress_hook.as_deref_mut() {
        for c in streamed.iter() {
            hook(payload.id, c.clone());
        }
    }
    if !streamed.is_empty() {
        log.conditions.retain(|c| !LIVE_CLASSES.iter().any(|lc| c.inherits(lc)));
    }

    TaskOutcome {
        id: payload.id,
        values: result,
        log,
        worker: worker_idx,
        started_unix: started,
        finished_unix: crate::future_core::driver::now_unix(),
    }
}

fn execute_kind(
    interp: &mut Interp,
    kind: &TaskKind,
    ctx: Option<&TaskContext>,
    genv: &crate::rlite::env::EnvRef,
) -> (Result<Vec<WireVal>, RCondition>, CaptureLog) {
    match kind {
        TaskKind::Expr { expr, globals } => {
            install_globals(genv, globals);
            let (r, log) = interp.eval_captured(expr, genv);
            (wrap_single(r), log)
        }
        TaskKind::MapSlice { ctx: ctx_id, items, seeds } => {
            let Some(ctx) = ctx else {
                return (Err(missing_context(*ctx_id)), CaptureLog::default());
            };
            let ContextBody::Map { f, extra } = &ctx.body else {
                return (Err(context_mismatch(*ctx_id, "MapSlice")), CaptureLog::default());
            };
            install_globals(genv, &ctx.globals);
            let func = from_wire(f, genv);
            let extra_vals: Vec<(Option<String>, RVal)> =
                extra.iter().map(|(n, w)| (n.clone(), from_wire(w, genv))).collect();
            let mut out = Vec::with_capacity(items.len());
            let mut log = CaptureLog::default();
            for (k, item_w) in items.iter().enumerate() {
                if let Some(seeds) = seeds {
                    interp.rng = RngStream::new(seeds[k]);
                }
                let item = from_wire(item_w, genv);
                let mut call_args = vec![(None, item)];
                call_args.extend(extra_vals.clone());
                let (r, elem_log) = capture_call(interp, &func, call_args, genv);
                log.merge(elem_log);
                match r {
                    Ok(v) => match to_wire(&v) {
                        Ok(w) => out.push(w),
                        Err(e) => return (Err(RCondition::error_cond(e)), log),
                    },
                    Err(cond) => return (Err(cond), log),
                }
            }
            (Ok(out), log)
        }
        TaskKind::ForeachSlice { ctx: ctx_id, bindings, seeds } => {
            let Some(ctx) = ctx else {
                return (Err(missing_context(*ctx_id)), CaptureLog::default());
            };
            let ContextBody::Foreach { body } = &ctx.body else {
                return (Err(context_mismatch(*ctx_id, "ForeachSlice")), CaptureLog::default());
            };
            install_globals(genv, &ctx.globals);
            let mut out = Vec::with_capacity(bindings.len());
            let mut log = CaptureLog::default();
            for (k, bs) in bindings.iter().enumerate() {
                if let Some(seeds) = seeds {
                    interp.rng = RngStream::new(seeds[k]);
                }
                let iter_env = Env::child_of(genv);
                for (name, w) in bs {
                    define(&iter_env, name, from_wire(w, genv));
                }
                let (r, elem_log) = interp.eval_captured(body, &iter_env);
                log.merge(elem_log);
                match r {
                    Ok(v) => match to_wire(&v) {
                        Ok(w) => out.push(w),
                        Err(e) => return (Err(RCondition::error_cond(e)), log),
                    },
                    Err(sig) => return (Err(signal_to_cond(sig)), log),
                }
            }
            (Ok(out), log)
        }
    }
}

fn missing_context(id: u64) -> RCondition {
    RCondition::error_cond(format!(
        "futurize internal error: task references unregistered TaskContext {id}"
    ))
}

fn context_mismatch(id: u64, kind: &str) -> RCondition {
    RCondition::error_cond(format!(
        "futurize internal error: TaskContext {id} has the wrong body kind for a {kind} task"
    ))
}

fn capture_call(
    interp: &mut Interp,
    func: &RVal,
    args: Vec<(Option<String>, RVal)>,
    genv: &crate::rlite::env::EnvRef,
) -> (Result<RVal, RCondition>, CaptureLog) {
    // Wrap the call in eval_captured semantics manually: we capture via a
    // synthetic expression would lose the argument values, so replicate
    // the capture plumbing around call_function.
    let sink: Rc<RefCell<Vec<RCondition>>> = Rc::new(RefCell::new(Vec::new()));
    let buf: Rc<RefCell<String>> = Rc::new(RefCell::new(String::new()));
    interp
        .handlers
        .push(HandlerFrame::Collect { classes: vec!["condition".into()], sink: sink.clone() });
    interp.out.push(crate::rlite::eval::OutSink::Capture(buf.clone()));
    let rng_before = interp.rng_used;
    interp.rng_used = false;
    let r = interp.call_function(func, args, genv);
    let rng_used = interp.rng_used;
    interp.rng_used = rng_before || rng_used;
    interp.out.pop();
    interp.handlers.pop();
    let log =
        CaptureLog { stdout: buf.borrow().clone(), conditions: sink.borrow().clone(), rng_used };
    (r.map_err(signal_to_cond), log)
}

fn wrap_single(
    r: Result<RVal, Signal>,
) -> Result<Vec<WireVal>, RCondition> {
    match r {
        Ok(v) => to_wire(&v).map(|w| vec![w]).map_err(RCondition::error_cond),
        Err(sig) => Err(signal_to_cond(sig)),
    }
}

fn signal_to_cond(sig: Signal) -> RCondition {
    match sig {
        Signal::Error(c) => c,
        Signal::Unwind { cond, .. } => cond,
        other => {
            RCondition::error_cond(format!("non-error control signal escaped task: {other:?}"))
        }
    }
}

fn install_globals(genv: &crate::rlite::env::EnvRef, globals: &[(String, WireVal)]) {
    for (name, w) in globals {
        define(genv, name, from_wire(w, genv));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::future_core::{ContextBody, TaskContext, TaskKind, TaskPayload};
    use crate::rlite::parse_expr;

    fn expr_task(src: &str, globals: Vec<(String, WireVal)>) -> TaskPayload {
        TaskPayload {
            id: 1,
            kind: TaskKind::Expr { expr: parse_expr(src).unwrap(), globals },
            time_scale: 0.0,
            capture_stdout: true,
        }
    }

    #[test]
    fn expr_task_returns_value_and_log() {
        let t = expr_task("{ cat(\"out\")\nmessage(\"msg\")\n6 * 7 }", vec![]);
        let o = run_task(&t, None, 0, None);
        let vals = o.values.unwrap();
        assert_eq!(vals.len(), 1);
        assert_eq!(o.log.stdout, "out");
        assert_eq!(o.log.conditions.len(), 1);
    }

    #[test]
    fn expr_task_error_keeps_condition() {
        let t = expr_task("stop(\"task failed\")", vec![]);
        let o = run_task(&t, None, 0, None);
        let err = o.values.unwrap_err();
        assert_eq!(err.message, "task failed");
        assert!(err.inherits("error"));
    }

    #[test]
    fn globals_are_installed() {
        let g = vec![("a".to_string(), WireVal::Dbl(vec![5.0], None))];
        let t = expr_task("a * 2", g);
        let o = run_task(&t, None, 0, None);
        match &o.values.unwrap()[0] {
            WireVal::Dbl(v, _) => assert_eq!(v[0], 10.0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn live_conditions_stream_through_hook() {
        let t = expr_task(
            "signalCondition(simpleCondition(\"tick\", class = \"progression\"))",
            vec![],
        );
        let mut seen = Vec::new();
        let o = run_task(&t, None, 0, Some(&mut |_, c| seen.push(c)));
        assert_eq!(seen.len(), 1);
        assert_eq!(seen[0].message, "tick");
        // Streamed conditions do not reappear in the final log.
        assert!(o.log.conditions.is_empty());
    }

    #[test]
    fn tasks_are_isolated() {
        // A task cannot see variables from a previous task's interpreter.
        let t1 = expr_task("leak <- 99", vec![]);
        run_task(&t1, None, 0, None);
        let t2 = expr_task("exists(\"leak\")", vec![]);
        let o = run_task(&t2, None, 0, None);
        match &o.values.unwrap()[0] {
            WireVal::Lgl(v, _) => assert!(!v[0]),
            other => panic!("{other:?}"),
        }
    }

    fn map_context(id: u64, f_src: &str) -> TaskContext {
        let mut i = Interp::new();
        i.eval_program(&format!("__f <- {f_src}")).unwrap();
        let f = crate::rlite::env::lookup(&i.global, "__f").unwrap();
        TaskContext {
            id,
            body: ContextBody::Map { f: to_wire(&f).unwrap(), extra: vec![] },
            globals: vec![],
        }
    }

    #[test]
    fn map_slice_executes_against_context() {
        let ctx = map_context(7, "function(x) x + 100");
        let t = TaskPayload {
            id: 2,
            kind: TaskKind::MapSlice {
                ctx: 7,
                items: vec![WireVal::Dbl(vec![1.0], None), WireVal::Dbl(vec![2.0], None)]
                    .into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        };
        let o = run_task(&t, Some(&ctx), 0, None);
        let vals = o.values.unwrap();
        assert_eq!(vals.len(), 2);
        match &vals[1] {
            WireVal::Dbl(v, _) => assert_eq!(v[0], 102.0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn map_slice_without_context_is_an_error_outcome() {
        let t = TaskPayload {
            id: 3,
            kind: TaskKind::MapSlice { ctx: 99, items: vec![].into(), seeds: None },
            time_scale: 0.0,
            capture_stdout: true,
        };
        let o = run_task(&t, None, 0, None);
        let err = o.values.unwrap_err();
        assert!(err.message.contains("unregistered TaskContext"), "{}", err.message);
    }
}
