//! The `plan(sequential)` backend: tasks run inline at submit time, in a
//! fresh interpreter (same isolation semantics as the parallel backends,
//! so code validated here behaves identically under `multisession` —
//! the property future.tests checks). Like `multicore`, it rides the
//! zero-copy fast path: contexts are shared `Arc`s and chunk payloads
//! are `WireSlice` windows, so no wire bytes are ever encoded.
//!
//! Plan stacks: the inline task still adopts `TaskContext::nesting`, so
//! `plan(list(sequential, multicore(2)))` runs nested futurized maps on
//! a real 2-thread inner backend — sequential level 1 does not flatten
//! the levels below it.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use super::{Backend, BackendEvent};
use crate::future_core::{TaskContext, TaskPayload};

pub struct SequentialBackend {
    events: VecDeque<BackendEvent>,
    contexts: HashMap<u64, Arc<TaskContext>>,
}

impl SequentialBackend {
    pub fn new() -> Self {
        SequentialBackend { events: VecDeque::new(), contexts: HashMap::new() }
    }
}

impl Default for SequentialBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for SequentialBackend {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn workers(&self) -> usize {
        1
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        self.contexts.insert(ctx.id, ctx);
        Ok(())
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        self.contexts.remove(&ctx_id);
        Ok(())
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        // Run inline; progress conditions become queued Progress events so
        // ordering matches the parallel backends (progress before done).
        let ctx = task.kind.context_id().and_then(|id| self.contexts.get(&id)).cloned();
        let mut progress: Vec<BackendEvent> = Vec::new();
        let outcome =
            super::task_runner::run_task(&task, ctx.as_deref(), 0, Some(&mut |task_id, cond| {
                progress.push(BackendEvent::Progress { task_id, cond });
            }));
        self.events.extend(progress);
        self.events.push_back(BackendEvent::Done(outcome));
        Ok(())
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        self.events.pop_front().ok_or_else(|| "sequential backend: no pending events".into())
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        Ok(self.events.pop_front())
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        vec![] // nothing is ever queued
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::future_core::TaskKind;
    use crate::rlite::parse_expr;

    #[test]
    fn runs_inline_and_queues_done() {
        let mut b = SequentialBackend::new();
        b.submit(TaskPayload {
            id: 7,
            kind: TaskKind::Expr {
                expr: parse_expr("1 + 1").unwrap(),
                globals: vec![],
                nesting: Default::default(),
            },
            time_scale: 0.0,
            capture_stdout: true,
        })
        .unwrap();
        match b.next_event().unwrap() {
            BackendEvent::Done(o) => assert_eq!(o.id, 7),
            other => panic!("{other:?}"),
        }
        assert!(b.try_next_event().unwrap().is_none());
    }
}
