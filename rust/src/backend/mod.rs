//! Execution backends — the *how/where* half of the paper's separation
//! of concerns, selected by the end-user via `plan()`.
//!
//! | plan() name                                | backend           |
//! |--------------------------------------------|-------------------|
//! | `sequential`                               | [`sequential`]    |
//! | `multicore`                                | [`multicore`] (native threads, the fork analog) |
//! | `multisession`, `future.callr::callr`, `future.mirai::mirai_multisession` | [`multisession`] (worker subprocesses over stdio, the PSOCK analog) |
//! | `cluster`                                  | [`cluster_sim`] (process workers + injected per-message latency) |
//! | `cluster_tcp`, `cluster` with `tcp://` workers | [`cluster_tcp`] (real socket transport: handshake, heartbeats, spawn or attach) |
//! | `future.batchtools::batchtools_slurm` etc. | [`batchtools_sim`] (file-based job queue + polling scheduler) |
//!
//! Every backend implements [`Backend`] and must pass the conformance
//! suite in `rust/tests/backend_conformance.rs` — the future.tests
//! analog the paper cites for guaranteeing Future-API compliance.
//!
//! ## The streaming pipeline and the `TaskContext` protocol
//!
//! The dispatch core (`future_core::dispatch`) drives every backend the
//! same way:
//!
//! 1. [`Backend::register_context`] ships the map call's shared
//!    [`TaskContext`] (function, extra args, globals) **once**. Process
//!    backends encode it with the session's wire codec (compact binary
//!    by default; see [`crate::wire::codec`]) and forward the frame to
//!    each persistent worker as a `ParentMsg::RegisterContext` message;
//!    the worker caches it by id. In-process backends just store the
//!    `Arc` — nothing is encoded at all on the zero-copy fast path.
//!    Serialized volume per map call is therefore O(workers × payload),
//!    not O(chunks × payload), and exactly zero for
//!    `sequential`/`multicore`.
//! 2. [`Backend::submit`] receives chunk payloads *incrementally* —
//!    only ~`scheduling × workers` are in flight at once — whose
//!    `TaskKind::MapSlice`/`ForeachSlice` reference the context by id.
//! 3. [`Backend::next_event`] streams `Progress` conditions near-live
//!    and `Done` outcomes as they complete; the dispatch core feeds the
//!    next chunk on each `Done`.
//! 4. On a worker error under `stop_on_error`, the dispatch core calls
//!    [`Backend::cancel_queued`]; queued-but-unstarted tasks must never
//!    execute afterwards (the conformance suite asserts this).
//! 5. [`Backend::drop_context`] releases the context when the map call
//!    finishes (success *or* error), so worker-side caches don't leak.
//! 6. **Supervision.** A process backend must never let a dead worker
//!    wedge the session: worker death is detected (reader-thread exit,
//!    dead job-thread executor), the worker is reaped, a replacement is
//!    spawned with all active contexts replayed, and a
//!    [`BackendEvent::WorkerLost`] names the casualty so the dispatch
//!    core can resubmit (under `futurize(retries = N)`) or raise a
//!    `FutureError`-style condition. The conformance suite kills
//!    workers mid-map and asserts completion-or-error within a bounded
//!    wall clock.

pub mod batchtools_sim;
pub mod blobstore;
pub mod cluster_sim;
pub mod cluster_tcp;
pub mod inner_cache;
pub mod multicore;
pub mod multisession;
pub mod sequential;
pub mod task_runner;
pub mod worker;

use std::sync::Arc;

use serde_derive::{Deserialize, Serialize};

use crate::future_core::{TaskContext, TaskOutcome, TaskPayload};
use crate::rlite::conditions::RCondition;

/// Which backend family a plan names.
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub enum BackendKind {
    Sequential,
    Multicore,
    Multisession,
    ClusterSim,
    /// Real socket-based cluster: workers connect over TCP (locally
    /// spawned or externally attached) and speak the framed worker
    /// protocol with handshake + heartbeat supervision.
    ClusterTcp,
    BatchtoolsSim,
}

/// One fully resolved level of a `plan()` stack. Serializable because
/// the levels *below* the current one travel to workers inside every
/// registered [`TaskContext`] (see `future_core::NestingInfo`), so a
/// worker evaluating a nested futurized map can instantiate its own
/// inner backend instead of silently degrading to sequential.
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub struct PlanSpec {
    pub kind: BackendKind,
    /// Requested worker count (0 = all available cores).
    pub workers: usize,
    /// Cluster node names (display/trace only).
    pub worker_names: Vec<String>,
    /// cluster_sim: one-way message latency in milliseconds.
    pub latency_ms: f64,
    /// batchtools_sim: scheduler poll interval in milliseconds.
    pub poll_ms: f64,
    /// cluster_tcp: address to bind the worker listener to
    /// (host:port). Empty = ephemeral localhost (spawn mode). Derived
    /// from the first `tcp://` worker name, which switches the backend
    /// into attach mode (externally launched workers dial in).
    #[serde(default)]
    pub tcp_listen: String,
    /// cluster_tcp: worker launch command template (`{addr}`
    /// substituted). Empty = launch this binary with
    /// `worker --connect`; `"-"`/`"attach"` = never spawn.
    #[serde(default)]
    pub tcp_spawn: String,
    /// cluster_tcp: worker heartbeat interval in milliseconds (0
    /// disables heartbeat reaping).
    #[serde(default)]
    pub heartbeat_ms: f64,
    /// The plan name as the user wrote it (e.g.
    /// "future.mirai::mirai_multisession") for display.
    pub display: String,
    /// True when the user wrote the worker count themselves (`workers =
    /// n`, a node-name vector, or `backend(n)`). An *implicit* count is
    /// re-derived when the level is inherited by a nested session: the
    /// machine's cores are divided by the parallelism already in use
    /// above it — the future-style guard that keeps an inherited
    /// `multicore` level from oversubscribing cores² ways.
    pub explicit_workers: bool,
}

impl PlanSpec {
    pub fn sequential() -> Self {
        PlanSpec {
            kind: BackendKind::Sequential,
            workers: 1,
            worker_names: vec![],
            latency_ms: 0.0,
            poll_ms: 0.0,
            tcp_listen: String::new(),
            tcp_spawn: String::new(),
            heartbeat_ms: 0.0,
            display: "sequential".into(),
            explicit_workers: true,
        }
    }

    pub fn multicore(workers: usize) -> Self {
        PlanSpec {
            kind: BackendKind::Multicore,
            workers,
            worker_names: vec![],
            latency_ms: 0.0,
            poll_ms: 0.0,
            tcp_listen: String::new(),
            tcp_spawn: String::new(),
            heartbeat_ms: 0.0,
            display: "multicore".into(),
            explicit_workers: true,
        }
    }

    pub fn multisession(workers: usize) -> Self {
        PlanSpec {
            kind: BackendKind::Multisession,
            workers,
            worker_names: vec![],
            latency_ms: 0.0,
            poll_ms: 0.0,
            tcp_listen: String::new(),
            tcp_spawn: String::new(),
            heartbeat_ms: 0.0,
            display: "multisession".into(),
            explicit_workers: true,
        }
    }

    /// Resolve a `plan()` backend name. Accepts every name used in the
    /// paper's §4.8 backend-flexibility tour.
    pub fn from_name(
        name: &str,
        workers: Option<usize>,
        worker_names: Vec<String>,
        latency_ms: Option<f64>,
        poll_ms: Option<f64>,
    ) -> Result<PlanSpec, String> {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        let kind = match name {
            "sequential" => BackendKind::Sequential,
            "multicore" => BackendKind::Multicore,
            "multisession" => BackendKind::Multisession,
            // callr and mirai are PSOCK-like process backends in spirit.
            "future.callr::callr" | "callr" => BackendKind::Multisession,
            "future.mirai::mirai_multisession" | "mirai_multisession" => {
                BackendKind::Multisession
            }
            "cluster_tcp" => BackendKind::ClusterTcp,
            // `tcp://` worker names switch `cluster` from the latency
            // simulator to the real socket backend in attach mode.
            "cluster" if worker_names.iter().any(|n| n.starts_with("tcp://")) => {
                BackendKind::ClusterTcp
            }
            "cluster" => BackendKind::ClusterSim,
            n if n.starts_with("future.batchtools::") || n.starts_with("batchtools_") => {
                BackendKind::BatchtoolsSim
            }
            other => return Err(format!("unknown future backend '{other}'")),
        };
        let default_workers = match kind {
            BackendKind::Sequential => 1,
            BackendKind::ClusterSim if !worker_names.is_empty() => worker_names.len(),
            BackendKind::ClusterTcp if !worker_names.is_empty() => worker_names.len(),
            BackendKind::BatchtoolsSim => cores,
            _ => cores,
        };
        let explicit_workers =
            kind == BackendKind::Sequential || workers.is_some() || !worker_names.is_empty();
        // First tcp:// worker name is the attach-mode listen address;
        // its presence (rather than a spawn command) is what tells the
        // backend not to launch local workers.
        let tcp_listen = worker_names
            .iter()
            .find_map(|n| n.strip_prefix("tcp://"))
            .unwrap_or("")
            .to_string();
        Ok(PlanSpec {
            workers: workers.unwrap_or(default_workers).max(1),
            worker_names,
            latency_ms: latency_ms
                .unwrap_or(if kind == BackendKind::ClusterSim { 1.0 } else { 0.0 }),
            poll_ms: poll_ms.unwrap_or(if kind == BackendKind::BatchtoolsSim { 20.0 } else { 0.0 }),
            tcp_listen,
            tcp_spawn: String::new(),
            heartbeat_ms: if kind == BackendKind::ClusterTcp { 2000.0 } else { 0.0 },
            display: name.to_string(),
            kind,
            explicit_workers,
        })
    }

    /// The worker count this level actually gets in a session whose
    /// enclosing plan levels already occupy `outer_workers`-way
    /// parallelism. An explicit count is honored as written — the stack
    /// author asked for outer×inner effective parallelism, which is
    /// surfaced in trace events rather than blocked. An implicit count
    /// (the "all cores" default) divides the machine's cores among the
    /// outer workers, so an inherited level never silently
    /// oversubscribes cores² ways.
    pub fn effective_workers(&self, outer_workers: usize) -> usize {
        if self.kind == BackendKind::Sequential {
            return 1;
        }
        if self.explicit_workers || outer_workers <= 1 {
            self.workers.max(1)
        } else {
            (self.workers / outer_workers.max(1)).max(1)
        }
    }

    pub fn describe(&self) -> String {
        match self.kind {
            BackendKind::Sequential => "sequential".into(),
            _ => format!("{} ({} workers)", self.display, self.workers),
        }
    }
}

/// An event surfaced by a backend.
#[derive(Debug)]
pub enum BackendEvent {
    /// A near-live progress/custom condition from a still-running task.
    Progress { task_id: u64, cond: RCondition },
    /// A task finished (successfully or not).
    Done(TaskOutcome),
    /// A worker died (crash, OOM-kill, `exit()`, protocol desync) and a
    /// `Done` for `task` will therefore never arrive. Process backends
    /// emit this from their supervision path after reaping the worker
    /// and (where the pool is persistent) spawning a replacement that
    /// has every active [`TaskContext`] replayed to it. The dispatch
    /// core decides recovery: resubmit the lost chunk while the map
    /// call's `retries` budget lasts, otherwise raise a
    /// `FutureError`-style condition naming the worker and task.
    /// `task` is `None` when the worker was idle at death (nothing was
    /// lost — the event is informational and the pool has healed).
    WorkerLost { worker: usize, task: Option<u64> },
}

/// The Future-API surface every backend must provide.
pub trait Backend: Send {
    fn name(&self) -> &'static str;
    fn workers(&self) -> usize;
    /// Make a shared [`TaskContext`] available to every worker before
    /// slice tasks referencing it are submitted. Ships the context once
    /// per worker (process backends) or stores the `Arc` (in-process
    /// backends).
    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String>;
    /// Release a context registered with [`Backend::register_context`].
    /// Called by the dispatch core once the map call has fully resolved;
    /// no task referencing the context is in flight at that point.
    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String>;
    /// Queue a task for execution. Must not block on task completion
    /// (sequential backends may run the task inline).
    fn submit(&mut self, task: TaskPayload) -> Result<(), String>;
    /// Block until the next event is available.
    fn next_event(&mut self) -> Result<BackendEvent, String>;
    /// Non-blocking poll.
    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String>;
    /// Cancellation of queued (not yet running) tasks — structured
    /// concurrency support (paper §5.3), invoked by the dispatch core's
    /// fail-fast path. Cancelled tasks must never execute and never
    /// produce events; returns the ids of the cancelled tasks so the
    /// caller can stop waiting on them.
    fn cancel_queued(&mut self) -> Vec<u64>;
    /// Whether this backend participates in the content-addressed
    /// data-plane cache (see [`blobstore`]). In-process backends keep
    /// the default `false`: their zero-copy `Arc` fast path already
    /// ships nothing, so extraction would only add digesting overhead.
    fn data_cache(&self) -> bool {
        false
    }
    /// Register a blob the dispatch core extracted for context
    /// `ctx_id`. The backend records it in its parent-side ledger and
    /// ships it lazily (first task per worker) or spools it (file
    /// backends); the `CacheSource` keeps the payload alive for
    /// `CacheMiss`/respawn re-puts until the context drops.
    fn put_blob(
        &mut self,
        _ctx_id: u64,
        _digest: u64,
        _blob: blobstore::CacheSource,
    ) -> Result<(), String> {
        Err("this backend does not support the data-plane cache".into())
    }
}

/// Instantiate the backend for one plan level. `outer_workers` is the
/// parallelism already in use by enclosing plan levels (1 in a
/// top-level session); it scales implicit worker counts down via
/// [`PlanSpec::effective_workers`].
pub fn instantiate(plan: &PlanSpec, outer_workers: usize) -> Result<Box<dyn Backend>, String> {
    let workers = plan.effective_workers(outer_workers);
    Ok(match plan.kind {
        BackendKind::Sequential => Box::new(sequential::SequentialBackend::new()),
        BackendKind::Multicore => Box::new(multicore::MulticoreBackend::new(workers)),
        BackendKind::Multisession => Box::new(multisession::MultisessionBackend::new(workers)?),
        BackendKind::ClusterSim => {
            Box::new(cluster_sim::ClusterSimBackend::new(workers, plan.latency_ms)?)
        }
        BackendKind::ClusterTcp => Box::new(cluster_tcp::ClusterTcpBackend::new(
            workers,
            &plan.tcp_listen,
            &plan.tcp_spawn,
            plan.heartbeat_ms,
        )?),
        BackendKind::BatchtoolsSim => {
            Box::new(batchtools_sim::BatchtoolsSimBackend::new(workers, plan.poll_ms)?)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_name_resolution() {
        let p = PlanSpec::from_name("multisession", Some(4), vec![], None, None).unwrap();
        assert_eq!(p.kind, BackendKind::Multisession);
        assert_eq!(p.workers, 4);

        let p = PlanSpec::from_name("future.mirai::mirai_multisession", None, vec![], None, None)
            .unwrap();
        assert_eq!(p.kind, BackendKind::Multisession);

        let p = PlanSpec::from_name("future.batchtools::batchtools_slurm", None, vec![], None, None)
            .unwrap();
        assert_eq!(p.kind, BackendKind::BatchtoolsSim);

        assert!(PlanSpec::from_name("nosuch", None, vec![], None, None).is_err());
    }

    #[test]
    fn cluster_workers_from_names() {
        let p = PlanSpec::from_name(
            "cluster",
            None,
            vec!["n1".into(), "n1".into(), "n2".into()],
            None,
            None,
        )
        .unwrap();
        assert_eq!(p.workers, 3);
    }

    #[test]
    fn cluster_tcp_resolution() {
        let p = PlanSpec::from_name("cluster_tcp", Some(2), vec![], None, None).unwrap();
        assert_eq!(p.kind, BackendKind::ClusterTcp);
        assert_eq!(p.heartbeat_ms, 2000.0);
        assert!(p.tcp_listen.is_empty(), "no tcp:// names = spawn mode");

        // tcp:// worker names promote `cluster` to the real backend in
        // attach mode, with the first name as the listen address.
        let p =
            PlanSpec::from_name("cluster", None, vec!["tcp://0.0.0.0:7001".into()], None, None)
                .unwrap();
        assert_eq!(p.kind, BackendKind::ClusterTcp);
        assert_eq!(p.tcp_listen, "0.0.0.0:7001");
        assert_eq!(p.workers, 1);

        // Plain node names keep the latency simulator.
        let p = PlanSpec::from_name("cluster", None, vec!["n1".into()], None, None).unwrap();
        assert_eq!(p.kind, BackendKind::ClusterSim);
    }

    #[test]
    fn sequential_defaults_to_one_worker() {
        let p = PlanSpec::from_name("sequential", None, vec![], None, None).unwrap();
        assert_eq!(p.workers, 1);
    }

    #[test]
    fn implicit_worker_counts_divide_among_outer_levels() {
        let mut p = PlanSpec::from_name("multicore", None, vec![], None, None).unwrap();
        assert!(!p.explicit_workers, "defaulted count must not read as explicit");
        p.workers = 8; // pretend an 8-core machine
        assert_eq!(p.effective_workers(1), 8);
        assert_eq!(p.effective_workers(4), 2);
        assert_eq!(p.effective_workers(16), 1, "never drops below one worker");
        // Explicit counts are honored as written, even nested.
        let e = PlanSpec::multicore(2);
        assert_eq!(e.effective_workers(4), 2);
        // Sequential is always exactly one worker.
        assert_eq!(PlanSpec::sequential().effective_workers(4), 1);
    }
}
