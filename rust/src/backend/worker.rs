//! The multisession worker protocol (PSOCK analog).
//!
//! A worker is this same binary re-executed with the sentinel first
//! argument [`WORKER_SENTINEL`]. Parent → worker messages are
//! length-prefixed [`ParentMsg`] frames on stdin; worker → parent
//! messages are [`WorkerMsg`] frames on stdout (see
//! [`crate::wire::codec`] for the frame layout). Frame payloads use the
//! compact binary codec by default; `FUTURIZE_WIRE_CODEC=json` switches
//! both sides to human-readable JSON for debugging — the parent stamps
//! its codec choice into the spawned worker's environment, so the two
//! can never disagree. Task stdout is captured by the task runner, so
//! the protocol channel stays clean.
//!
//! Shared task contexts: `RegisterContext` ships a map call's
//! [`TaskContext`] once per worker; the worker caches it by id and
//! resolves it for every `MapSlice`/`ForeachSlice` task that follows.
//! `DropContext` evicts it when the map call resolves. stdin delivery is
//! ordered, so a context always arrives before any task referencing it.
//! The context also carries the parent's *remaining plan stack*
//! (`TaskContext::nesting`), which the task runner installs into the
//! worker-side session so nested futurized maps instantiate their own
//! inner backend — and which supervision replays to respawned workers
//! along with the rest of the context cache.

use std::collections::HashMap;
use std::io::Write;

use serde_derive::{Deserialize, Serialize};

use super::blobstore::{self, BlobStore};
use crate::future_core::{TaskContext, TaskKind, TaskOutcome, TaskPayload};
use crate::rlite::conditions::RCondition;
use crate::rlite::serialize::WireSlice;
use crate::wire::codec::{read_frame, write_frame};
use crate::wire::WireCodec;

/// argv[1] sentinel that switches a process into worker mode.
pub const WORKER_SENTINEL: &str = "__futurize_worker__";

/// Environment variable overriding which binary to spawn as a worker
/// (used by integration tests and benches, where `current_exe()` is the
/// test harness rather than the CLI).
pub const WORKER_BIN_ENV: &str = "FUTURIZE_WORKER_BIN";

#[derive(Debug, Serialize, Deserialize)]
pub enum ParentMsg {
    Task(TaskPayload),
    /// Cache a shared task context for subsequent slice tasks.
    RegisterContext(TaskContext),
    /// Evict a cached context (its map call has fully resolved).
    DropContext(u64),
    Shutdown,
    /// Ship a data-plane blob into the worker's LRU store (see
    /// `backend::blobstore`). Sent at most once per (digest, worker)
    /// in steady state; re-sent on `CacheMiss`/respawn. Appended after
    /// the original variants so their wire tags stay stable.
    CachePut { digest: u64, blob: super::blobstore::CacheBlob },
}

/// Encode-only borrowing mirror of [`ParentMsg`]: lets the parent
/// serialize a context straight out of its `Arc` without deep-cloning
/// the whole function/globals payload first. Variant names and order
/// MUST match [`ParentMsg`] exactly — both codecs tag enums by variant
/// (index or name), so the two encode byte-identically (pinned by the
/// `ref_mirror_encodes_identically` test).
#[derive(Serialize)]
pub enum ParentMsgRef<'a> {
    Task(&'a TaskPayload),
    RegisterContext(&'a TaskContext),
    #[allow(dead_code)]
    DropContext(u64),
    #[allow(dead_code)]
    Shutdown,
    CachePut { digest: u64, blob: super::blobstore::CacheBlobRef<'a> },
}

#[derive(Debug, Serialize, Deserialize)]
pub enum WorkerMsg {
    Progress { task_id: u64, cond: RCondition },
    Done(TaskOutcome),
    /// Negative-ack: a task referenced digests this worker's blob
    /// store no longer holds (fresh respawn, eviction). The task was
    /// discarded; the parent re-`CachePut`s the named digests and
    /// re-sends the task frame — stdin ordering guarantees the blobs
    /// arrive first. Appended after the original variants so their
    /// wire tags stay stable.
    CacheMiss { task_id: u64, digests: Vec<u64> },
}

/// Call this first in any binary that may be used as a worker host
/// (the CLI and every example do). If the process was spawned as a
/// worker it never returns.
pub fn maybe_worker() {
    let mut args = std::env::args();
    let _exe = args.next();
    if args.next().as_deref() == Some(WORKER_SENTINEL) {
        worker_main();
        std::process::exit(0);
    }
}

/// The worker main loop.
pub fn worker_main() {
    // The parent stamps its codec into our environment at spawn time.
    let codec = WireCodec::active();
    let stdin = std::io::stdin();
    let mut input = stdin.lock();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let mut contexts: HashMap<u64, TaskContext> = HashMap::new();
    let mut store = BlobStore::new(blobstore::cache_budget());
    loop {
        let frame = match read_frame(&mut input) {
            Ok(Some(f)) => f,
            Ok(None) => break,
            Err(e) => {
                eprintln!("futurize worker: protocol read failed: {e}");
                break;
            }
        };
        let msg: ParentMsg = match codec.decode(&frame) {
            Ok(m) => m,
            Err(e) => {
                // Parent and worker state have diverged; there is no safe
                // way to continue. Exit so the parent's supervision
                // replaces this worker.
                eprintln!("futurize worker: undecodable message, exiting: {e}");
                break;
            }
        };
        match msg {
            ParentMsg::Shutdown => break,
            ParentMsg::RegisterContext(ctx) => {
                contexts.insert(ctx.id, ctx);
            }
            ParentMsg::DropContext(id) => {
                contexts.remove(&id);
            }
            ParentMsg::CachePut { digest, blob } => {
                store.insert(digest, blob);
            }
            ParentMsg::Task(mut task) => {
                let worker_idx = std::env::var("FUTURIZE_WORKER_IDX")
                    .ok()
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(0);
                // Each task frame opens a new blob-store epoch: blobs
                // that arrived for *this* task are eviction-exempt
                // until it runs, so a tiny budget can't livelock the
                // CacheMiss → re-put loop.
                store.bump_epoch();
                let mut missing: Vec<u64> = Vec::new();
                // Materialize cached globals into the referenced
                // context (permanent: each miss round makes progress).
                if let Some(ctx) = task.kind.context_id().and_then(|id| contexts.get_mut(&id)) {
                    let cached = std::mem::take(&mut ctx.cached_globals);
                    for (name, digest) in cached {
                        match store.get_val(digest) {
                            Some(v) => ctx.globals.push((name, (*v).clone())),
                            None => {
                                missing.push(digest);
                                ctx.cached_globals.push((name, digest));
                            }
                        }
                    }
                }
                // Resolve element-vector refs into zero-copy windows
                // over the stored blob; the task runner only ever sees
                // plain slice kinds.
                let resolved = match &task.kind {
                    TaskKind::MapSliceRef { ctx, digest, start, end, seeds } => {
                        match store.get_items(*digest) {
                            Some(arc) => Some(TaskKind::MapSlice {
                                ctx: *ctx,
                                items: WireSlice::shared(arc, *start, *end),
                                seeds: seeds.clone(),
                            }),
                            None => {
                                missing.push(*digest);
                                None
                            }
                        }
                    }
                    TaskKind::ForeachSliceRef { ctx, digest, start, end, seeds } => {
                        match store.get_bindings(*digest) {
                            Some(arc) => Some(TaskKind::ForeachSlice {
                                ctx: *ctx,
                                bindings: WireSlice::shared(arc, *start, *end),
                                seeds: seeds.clone(),
                            }),
                            None => {
                                missing.push(*digest);
                                None
                            }
                        }
                    }
                    _ => None,
                };
                if let Some(kind) = resolved {
                    task.kind = kind;
                }
                if !missing.is_empty() {
                    // Discard the task and negative-ack: the parent
                    // re-puts the digests then re-sends the frame, and
                    // stdin FIFO ordering makes the retry resolve.
                    missing.sort_unstable();
                    missing.dedup();
                    let msg = WorkerMsg::CacheMiss { task_id: task.id, digests: missing };
                    let Ok(bytes) = codec.encode(&msg) else { break };
                    if write_frame(&mut out, &bytes).is_err() {
                        break;
                    }
                    let _ = out.flush();
                    continue;
                }
                let ctx = task.kind.context_id().and_then(|id| contexts.get(&id));
                // Progress messages must flush immediately for near-live
                // relay across the process boundary.
                let outcome = {
                    let out_cell = std::cell::RefCell::new(&mut out);
                    super::task_runner::run_task(
                        &task,
                        ctx,
                        worker_idx,
                        Some(&mut |task_id, cond| {
                            let mut o = out_cell.borrow_mut();
                            let msg = WorkerMsg::Progress { task_id, cond };
                            if let Ok(bytes) = codec.encode(&msg) {
                                let _ = write_frame(&mut **o, &bytes);
                                let _ = o.flush();
                            }
                        }),
                    )
                };
                let msg = WorkerMsg::Done(outcome);
                let Ok(bytes) = codec.encode(&msg) else { break };
                if write_frame(&mut out, &bytes).is_err() {
                    break;
                }
                let _ = out.flush();
            }
        }
    }
}

/// Resolve the worker binary path.
pub fn worker_binary() -> Result<std::path::PathBuf, String> {
    if let Ok(p) = std::env::var(WORKER_BIN_ENV) {
        return Ok(p.into());
    }
    std::env::current_exe().map_err(|e| format!("cannot locate worker binary: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::future_core::TaskKind;
    use crate::rlite::parse_expr;

    #[test]
    fn protocol_messages_roundtrip() {
        let task = TaskPayload {
            id: 3,
            kind: TaskKind::Expr {
                expr: parse_expr("1 + 2").unwrap(),
                globals: vec![],
                nesting: Default::default(),
            },
            time_scale: 1.0,
            capture_stdout: true,
        };
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let bytes = codec.encode(&ParentMsg::Task(task.clone())).unwrap();
            let back: ParentMsg = codec.decode(&bytes).unwrap();
            match back {
                ParentMsg::Task(t) => assert_eq!(t.id, 3, "{codec:?}"),
                other => panic!("{codec:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn context_messages_roundtrip() {
        use crate::future_core::{ContextBody, TaskContext};
        let ctx = TaskContext {
            id: 12,
            body: ContextBody::Foreach { body: parse_expr("x + 1").unwrap() },
            globals: vec![(
                "a".into(),
                crate::rlite::serialize::WireVal::Dbl(vec![1.5], None),
            )],
            cached_globals: vec![],
            nesting: Default::default(),
            kernel: None,
            reduce: None,
        };
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let bytes = codec.encode(&ParentMsg::RegisterContext(ctx.clone())).unwrap();
            match codec.decode::<ParentMsg>(&bytes).unwrap() {
                ParentMsg::RegisterContext(c) => {
                    assert_eq!(c.id, 12, "{codec:?}");
                    assert_eq!(c.globals.len(), 1, "{codec:?}");
                }
                other => panic!("{codec:?}: {other:?}"),
            }
            let bytes = codec.encode(&ParentMsg::DropContext(12)).unwrap();
            match codec.decode::<ParentMsg>(&bytes).unwrap() {
                ParentMsg::DropContext(id) => assert_eq!(id, 12, "{codec:?}"),
                other => panic!("{codec:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn cache_messages_roundtrip() {
        use super::super::blobstore::{CacheBlob, CacheBlobRef};
        let items = vec![crate::rlite::serialize::WireVal::Dbl(vec![1.0, 2.0], None)];
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let owned = codec
                .encode(&ParentMsg::CachePut { digest: 9, blob: CacheBlob::Items(items.clone()) })
                .unwrap();
            let borrowed = codec
                .encode(&ParentMsgRef::CachePut { digest: 9, blob: CacheBlobRef::Items(&items) })
                .unwrap();
            assert_eq!(owned, borrowed, "{codec:?}: CachePut mirror drifted from ParentMsg");
            match codec.decode::<ParentMsg>(&owned).unwrap() {
                ParentMsg::CachePut { digest, blob: CacheBlob::Items(v) } => {
                    assert_eq!(digest, 9, "{codec:?}");
                    assert_eq!(v.len(), 1, "{codec:?}");
                }
                other => panic!("{codec:?}: {other:?}"),
            }
            let miss = WorkerMsg::CacheMiss { task_id: 4, digests: vec![9, 11] };
            let bytes = codec.encode(&miss).unwrap();
            match codec.decode::<WorkerMsg>(&bytes).unwrap() {
                WorkerMsg::CacheMiss { task_id, digests } => {
                    assert_eq!(task_id, 4, "{codec:?}");
                    assert_eq!(digests, vec![9, 11], "{codec:?}");
                }
                other => panic!("{codec:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn ref_mirror_encodes_identically() {
        use crate::future_core::{ContextBody, TaskContext};
        let ctx = TaskContext {
            id: 7,
            body: ContextBody::Foreach { body: parse_expr("x * 2").unwrap() },
            globals: vec![(
                "g".into(),
                crate::rlite::serialize::WireVal::Dbl(vec![1.0, 2.0], None),
            )],
            cached_globals: vec![],
            nesting: Default::default(),
            kernel: None,
            reduce: None,
        };
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let owned = codec.encode(&ParentMsg::RegisterContext(ctx.clone())).unwrap();
            let borrowed = codec.encode(&ParentMsgRef::RegisterContext(&ctx)).unwrap();
            assert_eq!(owned, borrowed, "{codec:?}: mirror drifted from ParentMsg");
        }
    }

    #[test]
    fn binary_protocol_is_compact() {
        // The per-chunk hot path: a one-element MapSlice task message.
        // Binary must stay well under half the JSON footprint (the
        // BENCH_wire bench records the exact ratio).
        let task = TaskPayload {
            id: 12,
            kind: TaskKind::MapSlice {
                ctx: 3,
                items: vec![crate::rlite::serialize::WireVal::Dbl(vec![5.0], None)].into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        };
        let msg = ParentMsg::Task(task);
        let bin = WireCodec::Binary.encode(&msg).unwrap();
        let json = WireCodec::Json.encode(&msg).unwrap();
        assert!(
            bin.len() * 3 <= json.len(),
            "binary ({}) should be ≤ 1/3 of JSON ({}) on protocol messages",
            bin.len(),
            json.len()
        );
    }
}
