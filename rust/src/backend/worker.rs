//! The multisession worker protocol (PSOCK analog).
//!
//! A worker is this same binary re-executed with the sentinel first
//! argument [`WORKER_SENTINEL`]. Parent → worker messages are
//! newline-delimited JSON [`ParentMsg`] on stdin; worker → parent
//! messages are [`WorkerMsg`] on stdout. Task stdout is captured by the
//! task runner, so the protocol channel stays clean.
//!
//! Shared task contexts: `RegisterContext` ships a map call's
//! [`TaskContext`] once per worker; the worker caches it by id and
//! resolves it for every `MapSlice`/`ForeachSlice` task that follows.
//! `DropContext` evicts it when the map call resolves. stdin delivery is
//! ordered, so a context always arrives before any task referencing it.

use std::collections::HashMap;
use std::io::{BufRead, Write};

use serde_derive::{Deserialize, Serialize};

use crate::future_core::{TaskContext, TaskOutcome, TaskPayload};
use crate::rlite::conditions::RCondition;

/// argv[1] sentinel that switches a process into worker mode.
pub const WORKER_SENTINEL: &str = "__futurize_worker__";

/// Environment variable overriding which binary to spawn as a worker
/// (used by integration tests and benches, where `current_exe()` is the
/// test harness rather than the CLI).
pub const WORKER_BIN_ENV: &str = "FUTURIZE_WORKER_BIN";

#[derive(Debug, Serialize, Deserialize)]
pub enum ParentMsg {
    Task(TaskPayload),
    /// Cache a shared task context for subsequent slice tasks.
    RegisterContext(TaskContext),
    /// Evict a cached context (its map call has fully resolved).
    DropContext(u64),
    Shutdown,
}

#[derive(Debug, Serialize, Deserialize)]
pub enum WorkerMsg {
    Progress { task_id: u64, cond: RCondition },
    Done(TaskOutcome),
}

/// Call this first in any binary that may be used as a worker host
/// (the CLI and every example do). If the process was spawned as a
/// worker it never returns.
pub fn maybe_worker() {
    let mut args = std::env::args();
    let _exe = args.next();
    if args.next().as_deref() == Some(WORKER_SENTINEL) {
        worker_main();
        std::process::exit(0);
    }
}

/// The worker main loop.
pub fn worker_main() {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let mut contexts: HashMap<u64, TaskContext> = HashMap::new();
    for line in stdin.lock().lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let msg: ParentMsg = match crate::wire::from_str(&line) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("futurize worker: bad message: {e}");
                continue;
            }
        };
        match msg {
            ParentMsg::Shutdown => break,
            ParentMsg::RegisterContext(ctx) => {
                contexts.insert(ctx.id, ctx);
            }
            ParentMsg::DropContext(id) => {
                contexts.remove(&id);
            }
            ParentMsg::Task(task) => {
                let worker_idx = std::env::var("FUTURIZE_WORKER_IDX")
                    .ok()
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(0);
                let ctx = task.kind.context_id().and_then(|id| contexts.get(&id));
                // Progress messages must flush immediately for near-live
                // relay across the process boundary.
                let outcome = {
                    let out_cell = std::cell::RefCell::new(&mut out);
                    super::task_runner::run_task(
                        &task,
                        ctx,
                        worker_idx,
                        Some(&mut |task_id, cond| {
                            let mut o = out_cell.borrow_mut();
                            let msg = WorkerMsg::Progress { task_id, cond };
                            let _ = writeln!(o, "{}", crate::wire::to_string(&msg).unwrap());
                            let _ = o.flush();
                        }),
                    )
                };
                let msg = WorkerMsg::Done(outcome);
                if writeln!(out, "{}", crate::wire::to_string(&msg).unwrap()).is_err() {
                    break;
                }
                let _ = out.flush();
            }
        }
    }
}

/// Resolve the worker binary path.
pub fn worker_binary() -> Result<std::path::PathBuf, String> {
    if let Ok(p) = std::env::var(WORKER_BIN_ENV) {
        return Ok(p.into());
    }
    std::env::current_exe().map_err(|e| format!("cannot locate worker binary: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::future_core::TaskKind;
    use crate::rlite::parse_expr;

    #[test]
    fn protocol_messages_roundtrip() {
        let task = TaskPayload {
            id: 3,
            kind: TaskKind::Expr { expr: parse_expr("1 + 2").unwrap(), globals: vec![] },
            time_scale: 1.0,
            capture_stdout: true,
        };
        let s = crate::wire::to_string(&ParentMsg::Task(task)).unwrap();
        let back: ParentMsg = crate::wire::from_str(&s).unwrap();
        match back {
            ParentMsg::Task(t) => assert_eq!(t.id, 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn context_messages_roundtrip() {
        use crate::future_core::{ContextBody, TaskContext};
        let ctx = TaskContext {
            id: 12,
            body: ContextBody::Foreach { body: parse_expr("x + 1").unwrap() },
            globals: vec![(
                "a".into(),
                crate::rlite::serialize::WireVal::Dbl(vec![1.5], None),
            )],
        };
        let s = crate::wire::to_string(&ParentMsg::RegisterContext(ctx)).unwrap();
        match crate::wire::from_str::<ParentMsg>(&s).unwrap() {
            ParentMsg::RegisterContext(c) => {
                assert_eq!(c.id, 12);
                assert_eq!(c.globals.len(), 1);
            }
            other => panic!("{other:?}"),
        }
        let s = crate::wire::to_string(&ParentMsg::DropContext(12)).unwrap();
        match crate::wire::from_str::<ParentMsg>(&s).unwrap() {
            ParentMsg::DropContext(id) => assert_eq!(id, 12),
            other => panic!("{other:?}"),
        }
    }
}
