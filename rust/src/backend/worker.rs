//! The multisession worker protocol (PSOCK analog).
//!
//! A worker is this same binary re-executed with the sentinel first
//! argument [`WORKER_SENTINEL`]. Parent → worker messages are
//! length-prefixed [`ParentMsg`] frames on stdin; worker → parent
//! messages are [`WorkerMsg`] frames on stdout (see
//! [`crate::wire::codec`] for the frame layout). Frame payloads use the
//! compact binary codec by default; `FUTURIZE_WIRE_CODEC=json` switches
//! both sides to human-readable JSON for debugging — the parent stamps
//! its codec choice into the spawned worker's environment, so the two
//! can never disagree. Task stdout is captured by the task runner, so
//! the protocol channel stays clean.
//!
//! Shared task contexts: `RegisterContext` ships a map call's
//! [`TaskContext`] once per worker; the worker caches it by id and
//! resolves it for every `MapSlice`/`ForeachSlice` task that follows.
//! `DropContext` evicts it when the map call resolves. stdin delivery is
//! ordered, so a context always arrives before any task referencing it.
//! The context also carries the parent's *remaining plan stack*
//! (`TaskContext::nesting`), which the task runner installs into the
//! worker-side session so nested futurized maps instantiate their own
//! inner backend — and which supervision replays to respawned workers
//! along with the rest of the context cache.

use std::collections::HashMap;
use std::io::Write;

use serde_derive::{Deserialize, Serialize};

use super::blobstore::{self, BlobStore};
use crate::future_core::{TaskContext, TaskKind, TaskOutcome, TaskPayload};
use crate::rlite::conditions::RCondition;
use crate::rlite::serialize::WireSlice;
use crate::wire::codec::{read_frame, write_frame};
use crate::wire::WireCodec;

/// argv[1] sentinel that switches a process into worker mode.
pub const WORKER_SENTINEL: &str = "__futurize_worker__";

/// Environment variable overriding which binary to spawn as a worker
/// (used by integration tests and benches, where `current_exe()` is the
/// test harness rather than the CLI).
pub const WORKER_BIN_ENV: &str = "FUTURIZE_WORKER_BIN";

#[derive(Debug, Serialize, Deserialize)]
pub enum ParentMsg {
    Task(TaskPayload),
    /// Cache a shared task context for subsequent slice tasks.
    RegisterContext(TaskContext),
    /// Evict a cached context (its map call has fully resolved).
    DropContext(u64),
    Shutdown,
    /// Ship a data-plane blob into the worker's LRU store (see
    /// `backend::blobstore`). Sent at most once per (digest, worker)
    /// in steady state; re-sent on `CacheMiss`/respawn. Appended after
    /// the original variants so their wire tags stay stable.
    CachePut { digest: u64, blob: super::blobstore::CacheBlob },
    /// Cancel a task already written to this worker but (hopefully) not
    /// yet started. TCP transport only: the worker's *reader thread*
    /// purges it from the pending queue out-of-band — even while the
    /// main thread is busy running an earlier task — and acks with
    /// [`WorkerMsg::Cancelled`]. If the task already started (or
    /// finished) no ack is sent; its `Done` frame is the answer.
    /// Appended so the earlier variants' wire tags stay stable.
    CancelTask(u64),
}

/// Encode-only borrowing mirror of [`ParentMsg`]: lets the parent
/// serialize a context straight out of its `Arc` without deep-cloning
/// the whole function/globals payload first. Variant names and order
/// MUST match [`ParentMsg`] exactly — both codecs tag enums by variant
/// (index or name), so the two encode byte-identically (pinned by the
/// `ref_mirror_encodes_identically` test).
#[derive(Serialize)]
pub enum ParentMsgRef<'a> {
    Task(&'a TaskPayload),
    RegisterContext(&'a TaskContext),
    #[allow(dead_code)]
    DropContext(u64),
    #[allow(dead_code)]
    Shutdown,
    CachePut { digest: u64, blob: super::blobstore::CacheBlobRef<'a> },
    CancelTask(u64),
}

#[derive(Debug, Serialize, Deserialize)]
pub enum WorkerMsg {
    Progress { task_id: u64, cond: RCondition },
    Done(TaskOutcome),
    /// Negative-ack: a task referenced digests this worker's blob
    /// store no longer holds (fresh respawn, eviction). The task was
    /// discarded; the parent re-`CachePut`s the named digests and
    /// re-sends the task frame — stdin ordering guarantees the blobs
    /// arrive first. Appended after the original variants so their
    /// wire tags stay stable.
    CacheMiss { task_id: u64, digests: Vec<u64> },
    /// Liveness beacon on the TCP transport, emitted every
    /// `heartbeat_ms / 2` by a dedicated worker thread. The parent's
    /// reader thread refreshes the connection deadline and swallows it
    /// — a heartbeat is never surfaced as a backend event. Appended so
    /// the earlier variants' wire tags stay stable.
    Heartbeat,
    /// Ack that [`ParentMsg::CancelTask`] purged the task before it
    /// started: it will never run and will produce no further frames.
    Cancelled { task_id: u64 },
}

/// Call this first in any binary that may be used as a worker host
/// (the CLI and every example do). If the process was spawned as a
/// worker it never returns.
pub fn maybe_worker() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) == Some(WORKER_SENTINEL) {
        worker_main();
        std::process::exit(0);
    }
    // `<bin> worker --connect host:port` — the TCP cluster transport.
    // Handled here (not just in the CLI's arg parser) so tests, benches
    // and examples that re-exec themselves as workers all join TCP
    // pools with the same one-line `maybe_worker()` guard.
    if args.first().map(String::as_str) == Some("worker") {
        match (args.get(1).map(String::as_str), args.get(2)) {
            (Some("--connect"), Some(addr)) => match worker_tcp_main(addr) {
                Ok(()) => std::process::exit(0),
                Err(e) => {
                    eprintln!("futurize worker: {e}");
                    std::process::exit(1);
                }
            },
            _ => {
                eprintln!("usage: futurize-rs worker --connect <host:port>");
                std::process::exit(2);
            }
        }
    }
}

/// The stdio worker main loop (multisession transport).
pub fn worker_main() {
    // The parent stamps its codec into our environment at spawn time.
    let codec = WireCodec::active();
    let stdin = std::io::stdin();
    let mut input = stdin.lock();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let mut contexts: HashMap<u64, TaskContext> = HashMap::new();
    let mut store = BlobStore::new(blobstore::cache_budget());
    // Worker→parent frames must flush immediately (stdout is buffered)
    // for near-live Progress relay across the process boundary.
    let mut send = |msg: &WorkerMsg| -> bool {
        let Ok(bytes) = codec.encode(msg) else { return false };
        if write_frame(&mut out, &bytes).is_err() {
            return false;
        }
        out.flush().is_ok()
    };
    loop {
        let frame = match read_frame(&mut input) {
            Ok(Some(f)) => f,
            Ok(None) => break,
            Err(e) => {
                eprintln!("futurize worker: protocol read failed: {e}");
                break;
            }
        };
        let msg: ParentMsg = match codec.decode(&frame) {
            Ok(m) => m,
            Err(e) => {
                // Parent and worker state have diverged; there is no safe
                // way to continue. Exit so the parent's supervision
                // replaces this worker.
                eprintln!("futurize worker: undecodable message, exiting: {e}");
                break;
            }
        };
        if !handle_parent_msg(msg, &mut contexts, &mut store, &mut send) {
            break;
        }
    }
}

/// Process one parent→worker message against the worker's session
/// state (context cache + blob store), shared by the stdio and TCP
/// transports. `send` frames-and-flushes one [`WorkerMsg`] back to the
/// parent, returning `false` on a dead channel. Returns `false` when
/// the worker loop should end (shutdown, or the channel died).
fn handle_parent_msg(
    msg: ParentMsg,
    contexts: &mut HashMap<u64, TaskContext>,
    store: &mut BlobStore,
    send: &mut dyn FnMut(&WorkerMsg) -> bool,
) -> bool {
    match msg {
        ParentMsg::Shutdown => false,
        ParentMsg::RegisterContext(ctx) => {
            contexts.insert(ctx.id, ctx);
            true
        }
        ParentMsg::DropContext(id) => {
            contexts.remove(&id);
            true
        }
        ParentMsg::CachePut { digest, blob } => {
            store.insert(digest, blob);
            true
        }
        // Cancellation is a reader-thread concern on the TCP transport
        // (the queue purge happens there, see `worker_tcp_main`); on the
        // ordered stdio transport the parent never sends it, and a task
        // reaching this loop is by definition about to run.
        ParentMsg::CancelTask(_) => true,
        ParentMsg::Task(mut task) => {
            let worker_idx = std::env::var("FUTURIZE_WORKER_IDX")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            // Each task frame opens a new blob-store epoch: blobs
            // that arrived for *this* task are eviction-exempt
            // until it runs, so a tiny budget can't livelock the
            // CacheMiss → re-put loop.
            store.bump_epoch();
            let mut missing: Vec<u64> = Vec::new();
            // Materialize cached globals into the referenced
            // context (permanent: each miss round makes progress).
            if let Some(ctx) = task.kind.context_id().and_then(|id| contexts.get_mut(&id)) {
                let cached = std::mem::take(&mut ctx.cached_globals);
                for (name, digest) in cached {
                    match store.get_val(digest) {
                        Some(v) => ctx.globals.push((name, (*v).clone())),
                        None => {
                            missing.push(digest);
                            ctx.cached_globals.push((name, digest));
                        }
                    }
                }
            }
            // Resolve element-vector refs into zero-copy windows
            // over the stored blob; the task runner only ever sees
            // plain slice kinds.
            let resolved = match &task.kind {
                TaskKind::MapSliceRef { ctx, digest, start, end, seeds } => {
                    match store.get_items(*digest) {
                        Some(arc) => Some(TaskKind::MapSlice {
                            ctx: *ctx,
                            items: WireSlice::shared(arc, *start, *end),
                            seeds: seeds.clone(),
                        }),
                        None => {
                            missing.push(*digest);
                            None
                        }
                    }
                }
                TaskKind::ForeachSliceRef { ctx, digest, start, end, seeds } => {
                    match store.get_bindings(*digest) {
                        Some(arc) => Some(TaskKind::ForeachSlice {
                            ctx: *ctx,
                            bindings: WireSlice::shared(arc, *start, *end),
                            seeds: seeds.clone(),
                        }),
                        None => {
                            missing.push(*digest);
                            None
                        }
                    }
                }
                _ => None,
            };
            if let Some(kind) = resolved {
                task.kind = kind;
            }
            if !missing.is_empty() {
                // Discard the task and negative-ack: the parent
                // re-puts the digests then re-sends the frame, and
                // transport FIFO ordering makes the retry resolve.
                missing.sort_unstable();
                missing.dedup();
                return send(&WorkerMsg::CacheMiss { task_id: task.id, digests: missing });
            }
            let ctx = task.kind.context_id().and_then(|id| contexts.get(&id));
            let outcome = {
                let mut progress = |task_id: u64, cond: RCondition| {
                    let _ = send(&WorkerMsg::Progress { task_id, cond });
                };
                super::task_runner::run_task(&task, ctx, worker_idx, Some(&mut progress))
            };
            send(&WorkerMsg::Done(outcome))
        }
    }
}

/// Environment variable suppressing the TCP worker's heartbeat thread.
/// Test hook only: lets the supervision suite simulate a live-but-
/// unresponsive worker (connection open, no beacons) and assert the
/// parent's heartbeat deadline reaps it.
pub const NO_HEARTBEAT_ENV: &str = "FUTURIZE_TEST_NO_HEARTBEAT";

/// One entry in the TCP worker's pending queue, produced by its reader
/// thread.
enum TcpItem {
    Msg(ParentMsg),
    /// The parent connection closed or desynced; the worker must exit.
    Disconnect(String),
}

/// The TCP worker main loop (`futurize-rs worker --connect host:port`).
///
/// Connects, handshakes (see [`crate::wire::handshake`]), then splits
/// into three threads: a *reader* decoding parent frames into a pending
/// queue, a *heartbeat* emitting [`WorkerMsg::Heartbeat`] every half
/// heartbeat interval, and the main thread draining the queue through
/// the same [`handle_parent_msg`] logic as the stdio worker. All
/// worker→parent frames go through one mutex-held writer, so a
/// heartbeat can never interleave bytes into the middle of a `Done`
/// frame. Returns `Err` on connection loss so the process exits
/// nonzero and the parent's supervision ladder takes over.
pub fn worker_tcp_main(addr: &str) -> Result<(), String> {
    use crate::wire::handshake::{self, HandshakeReply, Hello};
    use std::sync::{Arc, Condvar, Mutex};

    let stream = std::net::TcpStream::connect(addr)
        .map_err(|e| format!("cannot connect to {addr}: {e}"))?;
    // Protocol frames are small and latency-bound; never Nagle-delay them.
    let _ = stream.set_nodelay(true);
    let tag = format!(
        "{}/pid-{}",
        std::env::var("HOSTNAME").unwrap_or_else(|_| "localhost".into()),
        std::process::id()
    );
    handshake::send(&mut &stream, &Hello::current(tag))
        .map_err(|e| format!("handshake send failed: {e}"))?;
    let (worker_idx, codec_name, heartbeat_ms) =
        match handshake::recv::<HandshakeReply, _>(&mut &stream)
            .map_err(|e| format!("handshake recv failed: {e}"))?
        {
            HandshakeReply::Welcome { worker_idx, codec, heartbeat_ms } => {
                (worker_idx, codec, heartbeat_ms)
            }
            HandshakeReply::Reject { reason } => {
                return Err(format!("parent rejected this worker: {reason}"));
            }
        };
    // Still single-threaded here, so stamping the environment is safe.
    // The task runner reads the worker index (seeding, diagnostics,
    // test hooks), and any *nested* backend this worker instantiates
    // inherits the negotiated codec through the usual env channel.
    std::env::set_var("FUTURIZE_WORKER_IDX", worker_idx.to_string());
    std::env::set_var(crate::wire::codec::WIRE_CODEC_ENV, &codec_name);
    let codec = WireCodec::active();

    let writer = Arc::new(Mutex::new(
        stream.try_clone().map_err(|e| format!("stream clone failed: {e}"))?,
    ));
    let queue =
        Arc::new((Mutex::new(std::collections::VecDeque::<TcpItem>::new()), Condvar::new()));

    // Reader thread. Cancellation is handled HERE, out-of-band from
    // task execution: a `CancelTask` purges the pending queue even
    // while the main thread is busy running an earlier task — which is
    // exactly what lets the parent retract work it has already written
    // to the socket (see `cancel_queued` in `backend::cluster_tcp`).
    {
        let queue = Arc::clone(&queue);
        let writer = Arc::clone(&writer);
        let mut rd = stream.try_clone().map_err(|e| format!("stream clone failed: {e}"))?;
        std::thread::spawn(move || loop {
            let item = match read_frame(&mut rd) {
                Ok(Some(frame)) => match codec.decode::<ParentMsg>(&frame) {
                    Ok(msg) => TcpItem::Msg(msg),
                    Err(e) => TcpItem::Disconnect(format!("undecodable frame: {e}")),
                },
                Ok(None) => TcpItem::Disconnect("connection closed".into()),
                Err(e) => TcpItem::Disconnect(format!("read failed: {e}")),
            };
            let stop = matches!(item, TcpItem::Disconnect(_));
            match item {
                TcpItem::Msg(ParentMsg::CancelTask(task_id)) => {
                    let (lock, _) = &*queue;
                    let mut q = lock.lock().unwrap();
                    let before = q.len();
                    q.retain(|it| {
                        !matches!(it, TcpItem::Msg(ParentMsg::Task(t)) if t.id == task_id)
                    });
                    let purged = q.len() < before;
                    drop(q);
                    if purged {
                        if let Ok(bytes) = codec.encode(&WorkerMsg::Cancelled { task_id }) {
                            let mut w = writer.lock().unwrap();
                            let _ = write_frame(&mut *w, &bytes);
                        }
                    }
                    // Not found ⇒ the task already started (or finished):
                    // its Done frame is the parent's answer.
                }
                item => {
                    let (lock, cv) = &*queue;
                    lock.lock().unwrap().push_back(item);
                    cv.notify_one();
                }
            }
            if stop {
                break;
            }
        });
    }

    // Heartbeat thread: half the reap interval keeps one lost beacon
    // from looking like a death. Dies with the process (or on the first
    // failed write — the reader will surface the disconnect).
    let suppress = std::env::var(NO_HEARTBEAT_ENV).map(|v| v == "1").unwrap_or(false);
    if !suppress && heartbeat_ms > 0.0 {
        let writer = Arc::clone(&writer);
        let period = std::time::Duration::from_secs_f64((heartbeat_ms / 2.0).max(1.0) / 1000.0);
        std::thread::spawn(move || loop {
            std::thread::sleep(period);
            let Ok(bytes) = codec.encode(&WorkerMsg::Heartbeat) else { break };
            let mut w = writer.lock().unwrap();
            if write_frame(&mut *w, &bytes).is_err() {
                break;
            }
        });
    }

    let mut contexts: HashMap<u64, TaskContext> = HashMap::new();
    let mut store = BlobStore::new(blobstore::cache_budget());
    let mut send = {
        let writer = Arc::clone(&writer);
        move |msg: &WorkerMsg| -> bool {
            let Ok(bytes) = codec.encode(msg) else { return false };
            let mut w = writer.lock().unwrap();
            write_frame(&mut *w, &bytes).is_ok()
        }
    };
    loop {
        let item = {
            let (lock, cv) = &*queue;
            let mut q = lock.lock().unwrap();
            loop {
                match q.pop_front() {
                    Some(item) => break item,
                    None => q = cv.wait(q).unwrap(),
                }
            }
        };
        match item {
            TcpItem::Disconnect(reason) => {
                return Err(format!("parent connection lost: {reason}"));
            }
            TcpItem::Msg(msg) => {
                if !handle_parent_msg(msg, &mut contexts, &mut store, &mut send) {
                    return Ok(());
                }
            }
        }
    }
}

/// Resolve the worker binary path.
pub fn worker_binary() -> Result<std::path::PathBuf, String> {
    if let Ok(p) = std::env::var(WORKER_BIN_ENV) {
        return Ok(p.into());
    }
    std::env::current_exe().map_err(|e| format!("cannot locate worker binary: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::future_core::TaskKind;
    use crate::rlite::parse_expr;

    #[test]
    fn protocol_messages_roundtrip() {
        let task = TaskPayload {
            id: 3,
            kind: TaskKind::Expr {
                expr: parse_expr("1 + 2").unwrap(),
                globals: vec![],
                nesting: Default::default(),
            },
            time_scale: 1.0,
            capture_stdout: true,
        };
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let bytes = codec.encode(&ParentMsg::Task(task.clone())).unwrap();
            let back: ParentMsg = codec.decode(&bytes).unwrap();
            match back {
                ParentMsg::Task(t) => assert_eq!(t.id, 3, "{codec:?}"),
                other => panic!("{codec:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn context_messages_roundtrip() {
        use crate::future_core::{ContextBody, TaskContext};
        let ctx = TaskContext {
            id: 12,
            body: ContextBody::Foreach { body: parse_expr("x + 1").unwrap() },
            globals: vec![(
                "a".into(),
                crate::rlite::serialize::WireVal::Dbl(vec![1.5], None),
            )],
            cached_globals: vec![],
            nesting: Default::default(),
            kernel: None,
            reduce: None,
        };
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let bytes = codec.encode(&ParentMsg::RegisterContext(ctx.clone())).unwrap();
            match codec.decode::<ParentMsg>(&bytes).unwrap() {
                ParentMsg::RegisterContext(c) => {
                    assert_eq!(c.id, 12, "{codec:?}");
                    assert_eq!(c.globals.len(), 1, "{codec:?}");
                }
                other => panic!("{codec:?}: {other:?}"),
            }
            let bytes = codec.encode(&ParentMsg::DropContext(12)).unwrap();
            match codec.decode::<ParentMsg>(&bytes).unwrap() {
                ParentMsg::DropContext(id) => assert_eq!(id, 12, "{codec:?}"),
                other => panic!("{codec:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn cache_messages_roundtrip() {
        use super::super::blobstore::{CacheBlob, CacheBlobRef};
        let items = vec![crate::rlite::serialize::WireVal::Dbl(vec![1.0, 2.0], None)];
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let owned = codec
                .encode(&ParentMsg::CachePut { digest: 9, blob: CacheBlob::Items(items.clone()) })
                .unwrap();
            let borrowed = codec
                .encode(&ParentMsgRef::CachePut { digest: 9, blob: CacheBlobRef::Items(&items) })
                .unwrap();
            assert_eq!(owned, borrowed, "{codec:?}: CachePut mirror drifted from ParentMsg");
            match codec.decode::<ParentMsg>(&owned).unwrap() {
                ParentMsg::CachePut { digest, blob: CacheBlob::Items(v) } => {
                    assert_eq!(digest, 9, "{codec:?}");
                    assert_eq!(v.len(), 1, "{codec:?}");
                }
                other => panic!("{codec:?}: {other:?}"),
            }
            let miss = WorkerMsg::CacheMiss { task_id: 4, digests: vec![9, 11] };
            let bytes = codec.encode(&miss).unwrap();
            match codec.decode::<WorkerMsg>(&bytes).unwrap() {
                WorkerMsg::CacheMiss { task_id, digests } => {
                    assert_eq!(task_id, 4, "{codec:?}");
                    assert_eq!(digests, vec![9, 11], "{codec:?}");
                }
                other => panic!("{codec:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn ref_mirror_encodes_identically() {
        use crate::future_core::{ContextBody, TaskContext};
        let ctx = TaskContext {
            id: 7,
            body: ContextBody::Foreach { body: parse_expr("x * 2").unwrap() },
            globals: vec![(
                "g".into(),
                crate::rlite::serialize::WireVal::Dbl(vec![1.0, 2.0], None),
            )],
            cached_globals: vec![],
            nesting: Default::default(),
            kernel: None,
            reduce: None,
        };
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let owned = codec.encode(&ParentMsg::RegisterContext(ctx.clone())).unwrap();
            let borrowed = codec.encode(&ParentMsgRef::RegisterContext(&ctx)).unwrap();
            assert_eq!(owned, borrowed, "{codec:?}: mirror drifted from ParentMsg");
        }
    }

    #[test]
    fn tcp_protocol_messages_roundtrip() {
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let owned = codec.encode(&ParentMsg::CancelTask(77)).unwrap();
            let borrowed = codec.encode(&ParentMsgRef::CancelTask(77)).unwrap();
            assert_eq!(owned, borrowed, "{codec:?}: CancelTask mirror drifted from ParentMsg");
            match codec.decode::<ParentMsg>(&owned).unwrap() {
                ParentMsg::CancelTask(id) => assert_eq!(id, 77, "{codec:?}"),
                other => panic!("{codec:?}: {other:?}"),
            }
            let bytes = codec.encode(&WorkerMsg::Heartbeat).unwrap();
            assert!(
                matches!(codec.decode::<WorkerMsg>(&bytes).unwrap(), WorkerMsg::Heartbeat),
                "{codec:?}"
            );
            let bytes = codec.encode(&WorkerMsg::Cancelled { task_id: 5 }).unwrap();
            match codec.decode::<WorkerMsg>(&bytes).unwrap() {
                WorkerMsg::Cancelled { task_id } => assert_eq!(task_id, 5, "{codec:?}"),
                other => panic!("{codec:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn binary_protocol_is_compact() {
        // The per-chunk hot path: a one-element MapSlice task message.
        // Binary must stay well under half the JSON footprint (the
        // BENCH_wire bench records the exact ratio).
        let task = TaskPayload {
            id: 12,
            kind: TaskKind::MapSlice {
                ctx: 3,
                items: vec![crate::rlite::serialize::WireVal::Dbl(vec![5.0], None)].into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        };
        let msg = ParentMsg::Task(task);
        let bin = WireCodec::Binary.encode(&msg).unwrap();
        let json = WireCodec::Json.encode(&msg).unwrap();
        assert!(
            bin.len() * 3 <= json.len(),
            "binary ({}) should be ≤ 1/3 of JSON ({}) on protocol messages",
            bin.len(),
            json.len()
        );
    }
}
