//! The `plan(future.batchtools::batchtools_slurm)` backend.
//!
//! batchtools talks to an HPC scheduler through a *filesystem* spool:
//! jobs are serialized to files, the scheduler picks them up on its own
//! cadence, results land back as files that the client discovers by
//! polling. We reproduce that architecture faithfully on one machine —
//! real job/result files in a spool directory, a scheduler thread with a
//! configurable poll interval, execution in scheduler-owned threads —
//! because the *latency regime* (submit cost ≫ task cost unless chunks
//! are large) is what the paper's `chunk_size`/`scheduling` options
//! exist for.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::{Backend, BackendEvent};
use crate::future_core::{TaskContext, TaskPayload};
use crate::wire::WireCodec;

pub struct BatchtoolsSimBackend {
    codec: WireCodec,
    spool: PathBuf,
    rx: Receiver<BackendEvent>,
    _tx: Sender<BackendEvent>,
    shutdown: Arc<AtomicBool>,
    scheduler: Option<JoinHandle<()>>,
    workers: usize,
}

impl BatchtoolsSimBackend {
    pub fn new(workers: usize, poll_ms: f64) -> Result<Self, String> {
        let workers = workers.max(1);
        // Job and context spool files carry the session codec's frames
        // (binary by default); the scheduler decodes with the same one.
        let codec = WireCodec::active();
        let spool = std::env::temp_dir().join(format!(
            "futurize-batchtools-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap_or_default()
                .as_nanos()
        ));
        std::fs::create_dir_all(spool.join("jobs")).map_err(|e| e.to_string())?;
        std::fs::create_dir_all(spool.join("running")).map_err(|e| e.to_string())?;
        std::fs::create_dir_all(spool.join("contexts")).map_err(|e| e.to_string())?;
        let (tx, rx) = channel::<BackendEvent>();
        let shutdown = Arc::new(AtomicBool::new(false));

        // The scheduler: polls the job dir, launches up to `workers`
        // concurrent job threads, each writing its result back through tx.
        let scheduler = {
            let spool = spool.clone();
            let shutdown = shutdown.clone();
            let tx = tx.clone();
            let poll = Duration::from_secs_f64((poll_ms.max(0.1)) / 1000.0);
            std::thread::spawn(move || {
                let mut running: Vec<JoinHandle<()>> = Vec::new();
                loop {
                    if shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    running.retain(|h| !h.is_finished());
                    // Pick up queued job files, oldest first.
                    let mut jobs: Vec<PathBuf> = std::fs::read_dir(spool.join("jobs"))
                        .map(|rd| {
                            rd.filter_map(|e| e.ok())
                                .map(|e| e.path())
                                .filter(|p| p.extension().map_or(false, |x| x == "job"))
                                .collect()
                        })
                        .unwrap_or_default();
                    jobs.sort();
                    for job in jobs {
                        if running.len() >= workers {
                            break;
                        }
                        // Claim: move into running/.
                        let claimed = spool.join("running").join(job.file_name().unwrap());
                        if std::fs::rename(&job, &claimed).is_err() {
                            continue;
                        }
                        let tx = tx.clone();
                        let spool = spool.clone();
                        running.push(std::thread::spawn(move || {
                            let Ok(bytes) = std::fs::read(&claimed) else { return };
                            let Ok(task) = codec.decode::<TaskPayload>(&bytes) else {
                                return;
                            };
                            // Shared contexts live as spool files written
                            // once per map call; job threads read them
                            // locally (a filesystem read, not a
                            // serialization trip).
                            let ctx = task.kind.context_id().and_then(|id| {
                                let p = spool.join("contexts").join(format!("{id}.ctx"));
                                std::fs::read(p)
                                    .ok()
                                    .and_then(|b| codec.decode::<TaskContext>(&b).ok())
                            });
                            // batchtools jobs cannot stream conditions
                            // live; progress arrives with the result, as
                            // on a real scheduler without a side channel.
                            let outcome = crate::backend::task_runner::run_task(
                                &task,
                                ctx.as_ref(),
                                0,
                                None,
                            );
                            let _ = std::fs::remove_file(&claimed);
                            let _ = tx.send(BackendEvent::Done(outcome));
                        }));
                    }
                    std::thread::sleep(poll);
                }
                for h in running {
                    let _ = h.join();
                }
            })
        };

        Ok(BatchtoolsSimBackend {
            codec,
            spool,
            rx,
            _tx: tx,
            shutdown,
            scheduler: Some(scheduler),
            workers,
        })
    }
}

impl Backend for BatchtoolsSimBackend {
    fn name(&self) -> &'static str {
        "batchtools"
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        // One context file per map call — the batchtools analog of
        // shipping shared data to the scheduler's shared filesystem once
        // instead of embedding it in every job file.
        let tmp = self.spool.join("contexts").join(format!("{}.tmp", ctx.id));
        let fin = self.spool.join("contexts").join(format!("{}.ctx", ctx.id));
        let bytes = self.codec.encode(&*ctx)?;
        std::fs::write(&tmp, &bytes).map_err(|e| e.to_string())?;
        crate::wire::stats::record_physical(bytes.len());
        // Atomic publish so a job thread never reads a partial file.
        std::fs::rename(&tmp, &fin).map_err(|e| e.to_string())?;
        Ok(())
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        let _ = std::fs::remove_file(self.spool.join("contexts").join(format!("{ctx_id}.ctx")));
        Ok(())
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        // Job files are named by zero-padded task id: ids are issued
        // monotonically, so the scheduler's sorted pickup preserves
        // submission order and `cancel_queued` can report exactly which
        // tasks it removed.
        let tmp = self.spool.join("jobs").join(format!("{:016}.tmp", task.id));
        let fin = self.spool.join("jobs").join(format!("{:016}.job", task.id));
        let bytes = self.codec.encode(&task)?;
        std::fs::write(&tmp, &bytes).map_err(|e| e.to_string())?;
        crate::wire::stats::record_physical(bytes.len());
        // Atomic publish so the scheduler never reads a partial file.
        std::fs::rename(&tmp, &fin).map_err(|e| e.to_string())?;
        Ok(())
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        self.rx.recv().map_err(|e| format!("batchtools backend: {e}"))
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        match self.rx.try_recv() {
            Ok(ev) => Ok(Some(ev)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(e) => Err(format!("batchtools backend: {e}")),
        }
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        // Delete not-yet-claimed job files — `scancel` for queued jobs.
        // A job the scheduler claims concurrently wins the rename race,
        // is not removed here, and therefore still runs (and is not
        // reported as cancelled).
        let mut ids = Vec::new();
        if let Ok(rd) = std::fs::read_dir(self.spool.join("jobs")) {
            for e in rd.filter_map(|e| e.ok()) {
                let path = e.path();
                let id = path
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .and_then(|s| s.parse::<u64>().ok());
                if let Some(id) = id {
                    if std::fs::remove_file(&path).is_ok() {
                        ids.push(id);
                    }
                }
            }
        }
        ids
    }
}

impl Drop for BatchtoolsSimBackend {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_dir_all(&self.spool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::future_core::TaskKind;
    use crate::rlite::parse_expr;

    #[test]
    fn jobs_flow_through_the_spool() {
        let mut b = BatchtoolsSimBackend::new(2, 5.0).unwrap();
        for id in 1..=4 {
            b.submit(TaskPayload {
                id,
                kind: TaskKind::Expr {
                    expr: parse_expr(&format!("{id} + 100")).unwrap(),
                    globals: vec![],
                },
                time_scale: 0.0,
                capture_stdout: true,
            })
            .unwrap();
        }
        let mut done = 0;
        while done < 4 {
            if let BackendEvent::Done(o) = b.next_event().unwrap() {
                assert!(o.values.is_ok());
                done += 1;
            }
        }
    }
}
