//! The `plan(future.batchtools::batchtools_slurm)` backend.
//!
//! batchtools talks to an HPC scheduler through a *filesystem* spool:
//! jobs are serialized to files, the scheduler picks them up on its own
//! cadence, results land back as files that the client discovers by
//! polling. We reproduce that architecture faithfully on one machine —
//! real job/result files in a spool directory, a scheduler thread with a
//! configurable poll interval, execution in scheduler-owned threads —
//! because the *latency regime* (submit cost ≫ task cost unless chunks
//! are large) is what the paper's `chunk_size`/`scheduling` options
//! exist for.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::blobstore::{CacheBlob, CacheSource};
use super::{Backend, BackendEvent};
use crate::future_core::{TaskContext, TaskKind, TaskOutcome, TaskPayload};
use crate::rlite::conditions::{CaptureLog, RCondition};
use crate::rlite::serialize::WireSlice;
use crate::wire::WireCodec;

/// A claimed job being executed by a scheduler-owned thread. The
/// executor slot, task id, and claimed-file path are known *outside*
/// the thread, so the scheduler can still account for the job if its
/// executor dies without reporting back.
struct RunningJob {
    slot: usize,
    task_id: u64,
    claimed: PathBuf,
    handle: JoinHandle<()>,
}

pub struct BatchtoolsSimBackend {
    codec: WireCodec,
    spool: PathBuf,
    rx: Receiver<BackendEvent>,
    _tx: Sender<BackendEvent>,
    shutdown: Arc<AtomicBool>,
    scheduler: Option<JoinHandle<()>>,
    workers: usize,
}

impl BatchtoolsSimBackend {
    pub fn new(workers: usize, poll_ms: f64) -> Result<Self, String> {
        let workers = workers.max(1);
        // Job and context spool files carry the session codec's frames
        // (binary by default); the scheduler decodes with the same one.
        let codec = WireCodec::active();
        let spool = std::env::temp_dir().join(format!(
            "futurize-batchtools-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap_or_default()
                .as_nanos()
        ));
        std::fs::create_dir_all(spool.join("jobs")).map_err(|e| e.to_string())?;
        std::fs::create_dir_all(spool.join("running")).map_err(|e| e.to_string())?;
        std::fs::create_dir_all(spool.join("contexts")).map_err(|e| e.to_string())?;
        std::fs::create_dir_all(spool.join("blobs")).map_err(|e| e.to_string())?;
        let (tx, rx) = channel::<BackendEvent>();
        let shutdown = Arc::new(AtomicBool::new(false));

        // The scheduler: polls the job dir, launches up to `workers`
        // concurrent job threads (each pinned to an executor *slot*),
        // each writing its result back through tx. The scheduler also
        // supervises: a job whose claimed `running/` file has a dead
        // executor (the thread panicked and never sent a `Done`) is
        // cleaned up and reported as a [`BackendEvent::WorkerLost`] so
        // the dispatch core can resubmit or raise — never hang.
        let scheduler = {
            let spool = spool.clone();
            let shutdown = shutdown.clone();
            let tx = tx.clone();
            let poll = Duration::from_secs_f64((poll_ms.max(0.1)) / 1000.0);
            std::thread::spawn(move || {
                let mut running: Vec<RunningJob> = Vec::new();
                loop {
                    if shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    // Reap finished executors. A panicked executor is a
                    // dead worker: its claimed job file is still in
                    // running/ and no Done was ever sent.
                    let mut k = 0;
                    while k < running.len() {
                        if running[k].handle.is_finished() {
                            let job = running.remove(k);
                            if job.handle.join().is_err() {
                                let _ = std::fs::remove_file(&job.claimed);
                                let _ = tx.send(BackendEvent::WorkerLost {
                                    worker: job.slot,
                                    task: Some(job.task_id),
                                });
                            }
                        } else {
                            k += 1;
                        }
                    }
                    // Pick up queued job files, oldest first.
                    let mut jobs: Vec<PathBuf> = std::fs::read_dir(spool.join("jobs"))
                        .map(|rd| {
                            rd.filter_map(|e| e.ok())
                                .map(|e| e.path())
                                .filter(|p| p.extension().map_or(false, |x| x == "job"))
                                .collect()
                        })
                        .unwrap_or_default();
                    jobs.sort();
                    for job in jobs {
                        if running.len() >= workers {
                            break;
                        }
                        // Job files are named by zero-padded task id;
                        // knowing the id before execution is what lets
                        // the scheduler report exactly which task a dead
                        // executor took down.
                        let task_id = job
                            .file_stem()
                            .and_then(|s| s.to_str())
                            .and_then(|s| s.parse::<u64>().ok())
                            .unwrap_or(0);
                        // Claim: move into running/.
                        let claimed = spool.join("running").join(job.file_name().unwrap());
                        if std::fs::rename(&job, &claimed).is_err() {
                            continue;
                        }
                        let slot = (0..workers)
                            .find(|s| running.iter().all(|r| r.slot != *s))
                            .unwrap_or(0);
                        let tx = tx.clone();
                        let spool = spool.clone();
                        let claimed_in = claimed.clone();
                        let handle = std::thread::spawn(move || {
                            // Every exit path cleans up the claimed file
                            // and sends an event — an unreadable or
                            // undecodable job must surface as an error
                            // outcome, never a silent drop that hangs
                            // the dispatch loop.
                            let fail = |msg: String| {
                                let _ = std::fs::remove_file(&claimed_in);
                                let now = crate::future_core::driver::now_unix();
                                let _ = tx.send(BackendEvent::Done(TaskOutcome {
                                    id: task_id,
                                    values: Err(RCondition::error_cond(msg)),
                                    log: CaptureLog::default(),
                                    worker: slot,
                                    started_unix: now,
                                    finished_unix: now,
                                    nested_workers: 0,
                                    partial: None,
                                }));
                            };
                            let bytes = match std::fs::read(&claimed_in) {
                                Ok(b) => b,
                                Err(e) => {
                                    return fail(format!(
                                        "batchtools: failed to read job file for task \
                                         {task_id}: {e}"
                                    ))
                                }
                            };
                            let task = match codec.decode::<TaskPayload>(&bytes) {
                                Ok(t) => t,
                                Err(e) => {
                                    return fail(format!(
                                        "batchtools: failed to decode job file for task \
                                         {task_id}: {e}"
                                    ))
                                }
                            };
                            // Shared contexts live as spool files written
                            // once per map call; job threads read them
                            // locally (a filesystem read, not a
                            // serialization trip).
                            let mut ctx = task.kind.context_id().and_then(|id| {
                                let p = spool.join("contexts").join(format!("{id}.ctx"));
                                std::fs::read(p)
                                    .ok()
                                    .and_then(|b| codec.decode::<TaskContext>(&b).ok())
                            });
                            // Data-plane cache resolution: blobs are
                            // spool files keyed by digest, shared by
                            // every job (and every map call) that
                            // references them — the batchtools analog
                            // of "ship once per worker". Files persist
                            // for the backend's lifetime, so there is
                            // no miss path here.
                            let read_blob = |digest: u64| -> Option<CacheBlob> {
                                let p = spool
                                    .join("blobs")
                                    .join(format!("{digest:016x}.blob"));
                                std::fs::read(p).ok().and_then(|b| codec.decode(&b).ok())
                            };
                            if let Some(c) = ctx.as_mut() {
                                let cached = std::mem::take(&mut c.cached_globals);
                                for (name, digest) in cached {
                                    match read_blob(digest) {
                                        Some(CacheBlob::Val(v)) => c.globals.push((name, v)),
                                        _ => {
                                            return fail(format!(
                                                "batchtools: missing cache blob \
                                                 {digest:#018x} for task {task_id}"
                                            ))
                                        }
                                    }
                                }
                            }
                            let mut task = task;
                            task.kind = match task.kind {
                                TaskKind::MapSliceRef { ctx, digest, start, end, seeds } => {
                                    match read_blob(digest) {
                                        Some(CacheBlob::Items(items)) => TaskKind::MapSlice {
                                            ctx,
                                            items: WireSlice::shared(
                                                Arc::new(items),
                                                start,
                                                end,
                                            ),
                                            seeds,
                                        },
                                        _ => {
                                            return fail(format!(
                                                "batchtools: missing cache blob \
                                                 {digest:#018x} for task {task_id}"
                                            ))
                                        }
                                    }
                                }
                                TaskKind::ForeachSliceRef { ctx, digest, start, end, seeds } => {
                                    match read_blob(digest) {
                                        Some(CacheBlob::Bindings(b)) => TaskKind::ForeachSlice {
                                            ctx,
                                            bindings: WireSlice::shared(
                                                Arc::new(b),
                                                start,
                                                end,
                                            ),
                                            seeds,
                                        },
                                        _ => {
                                            return fail(format!(
                                                "batchtools: missing cache blob \
                                                 {digest:#018x} for task {task_id}"
                                            ))
                                        }
                                    }
                                }
                                k => k,
                            };
                            // batchtools jobs cannot stream conditions
                            // live; progress arrives with the result, as
                            // on a real scheduler without a side channel.
                            let outcome = crate::backend::task_runner::run_task(
                                &task,
                                ctx.as_ref(),
                                slot,
                                None,
                            );
                            let _ = std::fs::remove_file(&claimed_in);
                            // Result-bytes accounting: a real scheduler
                            // writes the outcome back through the spool,
                            // so charge its encoded size exactly as the
                            // multisession reader threads do — the
                            // O(result-volume) metric stays
                            // backend-uniform.
                            if let Ok(b) = codec.encode(&outcome) {
                                crate::wire::stats::record_result(b.len());
                            }
                            let _ = tx.send(BackendEvent::Done(outcome));
                        });
                        running.push(RunningJob { slot, task_id, claimed, handle });
                    }
                    std::thread::sleep(poll);
                }
                for job in running {
                    let _ = job.handle.join();
                }
            })
        };

        Ok(BatchtoolsSimBackend {
            codec,
            spool,
            rx,
            _tx: tx,
            shutdown,
            scheduler: Some(scheduler),
            workers,
        })
    }

    /// The spool directory (`jobs/`, `running/`, `contexts/`) — exposed
    /// so fault-injection tests can plant corrupt job files and assert
    /// claimed files are cleaned up on failure paths.
    pub fn spool_dir(&self) -> &Path {
        &self.spool
    }
}

impl Backend for BatchtoolsSimBackend {
    fn name(&self) -> &'static str {
        "batchtools"
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        // One context file per map call — the batchtools analog of
        // shipping shared data to the scheduler's shared filesystem once
        // instead of embedding it in every job file.
        let tmp = self.spool.join("contexts").join(format!("{}.tmp", ctx.id));
        let fin = self.spool.join("contexts").join(format!("{}.ctx", ctx.id));
        let bytes = self.codec.encode(&*ctx)?;
        std::fs::write(&tmp, &bytes).map_err(|e| e.to_string())?;
        crate::wire::stats::record_physical(bytes.len());
        // Atomic publish so a job thread never reads a partial file.
        std::fs::rename(&tmp, &fin).map_err(|e| e.to_string())?;
        Ok(())
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        let _ = std::fs::remove_file(self.spool.join("contexts").join(format!("{ctx_id}.ctx")));
        Ok(())
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        // Job files are named by zero-padded task id: ids are issued
        // monotonically, so the scheduler's sorted pickup preserves
        // submission order and `cancel_queued` can report exactly which
        // tasks it removed.
        let tmp = self.spool.join("jobs").join(format!("{:016}.tmp", task.id));
        let fin = self.spool.join("jobs").join(format!("{:016}.job", task.id));
        let bytes = self.codec.encode(&task)?;
        std::fs::write(&tmp, &bytes).map_err(|e| e.to_string())?;
        crate::wire::stats::record_physical(bytes.len());
        // Atomic publish so the scheduler never reads a partial file.
        std::fs::rename(&tmp, &fin).map_err(|e| e.to_string())?;
        Ok(())
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        self.rx.recv().map_err(|e| format!("batchtools backend: {e}"))
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        match self.rx.try_recv() {
            Ok(ev) => Ok(Some(ev)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(e) => Err(format!("batchtools backend: {e}")),
        }
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        // Delete not-yet-claimed job files — `scancel` for queued jobs.
        // A job the scheduler claims concurrently wins the rename race,
        // is not removed here, and therefore still runs (and is not
        // reported as cancelled).
        let mut ids = Vec::new();
        if let Ok(rd) = std::fs::read_dir(self.spool.join("jobs")) {
            for e in rd.filter_map(|e| e.ok()) {
                let path = e.path();
                let id = path
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .and_then(|s| s.parse::<u64>().ok());
                if let Some(id) = id {
                    if std::fs::remove_file(&path).is_ok() {
                        ids.push(id);
                    }
                }
            }
        }
        ids
    }

    fn data_cache(&self) -> bool {
        true
    }

    fn put_blob(&mut self, _ctx_id: u64, digest: u64, blob: CacheSource) -> Result<(), String> {
        // Blobs are digest-keyed spool files on the (simulated) shared
        // filesystem — written once, read by every job of every map
        // call that references them, removed with the spool at
        // backend teardown. Write-if-absent is the dedup: a digest
        // already spooled (same call or a previous one) costs nothing.
        let fin = self.spool.join("blobs").join(format!("{digest:016x}.blob"));
        if fin.exists() {
            crate::wire::stats::record_cache_hit(blob.approx_bytes() as u64);
            return Ok(());
        }
        let bytes = self.codec.encode(&blob.to_ref())?;
        let tmp = self.spool.join("blobs").join(format!("{digest:016x}.tmp"));
        std::fs::write(&tmp, &bytes).map_err(|e| e.to_string())?;
        crate::wire::stats::record_physical(bytes.len());
        // Atomic publish so a job thread never reads a partial blob.
        std::fs::rename(&tmp, &fin).map_err(|e| e.to_string())?;
        crate::wire::stats::record_cache_put(blob.approx_bytes() as u64);
        Ok(())
    }
}

impl Drop for BatchtoolsSimBackend {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_dir_all(&self.spool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::future_core::TaskKind;
    use crate::rlite::parse_expr;

    #[test]
    fn jobs_flow_through_the_spool() {
        let mut b = BatchtoolsSimBackend::new(2, 5.0).unwrap();
        for id in 1..=4 {
            b.submit(TaskPayload {
                id,
                kind: TaskKind::Expr {
                    expr: parse_expr(&format!("{id} + 100")).unwrap(),
                    globals: vec![],
                    nesting: Default::default(),
                },
                time_scale: 0.0,
                capture_stdout: true,
            })
            .unwrap();
        }
        let mut done = 0;
        while done < 4 {
            if let BackendEvent::Done(o) = b.next_event().unwrap() {
                assert!(o.values.is_ok());
                done += 1;
            }
        }
    }
}
