//! Per-worker cache of instantiated inner backends (ISSUE 6 satellite,
//! carried over from the plan-stack PR).
//!
//! A worker session adopting an inherited plan stack used to
//! instantiate its inner backend *per task*: every chunk of an outer
//! map running under `plan(list(multisession(2), multisession(2)))`
//! spawned (and tore down) two fresh inner worker processes. This
//! module parks the live inner backend in a thread-local cache when the
//! task's interpreter winds down ([`restore`]) and re-primes it into
//! the next task's session ([`lend`]), keyed by the inherited plan
//! stack and outer-worker budget — so nested parallelism spawns once
//! per worker, not once per chunk.
//!
//! Soundness leans on two invariants: worker threads/processes are
//! persistent (multicore threads and multisession/cluster processes
//! both loop over tasks), and `SessionState::set_plan_stack` drops the
//! backend on any stack change — so a live backend taken from a
//! session always matches the session's *current* stack, and the
//! current-stack key is the right place to park it.

use std::cell::RefCell;
use std::collections::HashMap;

use crate::backend::{Backend, BackendKind};
use crate::future_core::SessionState;

thread_local! {
    static CACHE: RefCell<HashMap<String, Box<dyn Backend>>> = RefCell::new(HashMap::new());
}

/// Cache key: the full inherited stack (every level shapes what nested
/// calls instantiate) plus the outer-worker budget, which sizes
/// implicit worker counts.
fn key(session: &SessionState) -> String {
    format!("{:?}@{}", session.plan_stack(), session.outer_workers)
}

/// Skip caching for sequential top levels: instantiation is free and
/// the common leaf case (implicit sequential inner) would only churn
/// the map.
fn cacheable(session: &SessionState) -> bool {
    session.plan().kind != BackendKind::Sequential
}

/// Re-prime a parked inner backend into `session` if one matches its
/// adopted stack. Called by the task runner right after
/// `adopt_nesting`, before the task body runs.
pub fn lend(session: &mut SessionState) {
    if !cacheable(session) {
        return;
    }
    if let Some(b) = CACHE.with(|c| c.borrow_mut().remove(&key(session))) {
        session.prime_backend(b);
    }
}

/// Park `session`'s live inner backend (if any) for the next task on
/// this worker. Called by the task runner after the task body finished,
/// before the interpreter (and with it the backend) would drop.
pub fn restore(session: &mut SessionState) {
    if !cacheable(session) {
        return;
    }
    if let Some(b) = session.take_backend() {
        CACHE.with(|c| c.borrow_mut().insert(key(session), b));
    }
}

/// Number of backends parked on this thread (test hook).
pub fn cached_count() -> usize {
    CACHE.with(|c| c.borrow().len())
}

/// Drop every parked backend on this thread (test hook).
pub fn clear() {
    CACHE.with(|c| c.borrow_mut().clear());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::PlanSpec;

    #[test]
    fn sequential_levels_are_not_cached() {
        clear();
        let mut s = SessionState::default();
        s.set_plan_stack(vec![PlanSpec::sequential()]);
        s.backend().unwrap();
        restore(&mut s);
        assert_eq!(cached_count(), 0);
    }

    #[test]
    fn parked_backend_is_lent_back_for_the_same_stack() {
        clear();
        let mut s = SessionState::default();
        let mut plan = PlanSpec::sequential();
        plan.kind = BackendKind::Multicore;
        plan.workers = 2;
        plan.explicit_workers = true;
        s.set_plan_stack(vec![plan.clone()]);
        s.backend().unwrap();
        restore(&mut s);
        assert_eq!(cached_count(), 1);
        // A fresh session with the same stack picks the pool back up
        // without instantiating (prime does not record peak workers).
        let mut s2 = SessionState::default();
        s2.set_plan_stack(vec![plan]);
        lend(&mut s2);
        assert_eq!(cached_count(), 0);
        assert_eq!(s2.peak_backend_workers, 0, "prime must not count as use");
        assert_eq!(s2.backend().unwrap().workers(), 2);
        assert_eq!(s2.peak_backend_workers, 2, "access must count");
        // A *different* stack must not receive it.
        restore(&mut s2);
        assert_eq!(cached_count(), 1);
        let mut s3 = SessionState::default();
        let mut other = PlanSpec::sequential();
        other.kind = BackendKind::Multicore;
        other.workers = 3;
        other.explicit_workers = true;
        s3.set_plan_stack(vec![other]);
        lend(&mut s3);
        assert_eq!(cached_count(), 1, "mismatched stack must leave the cache alone");
        clear();
    }
}
