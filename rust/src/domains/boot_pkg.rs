//! boot (paper §4.6): bootstrap resampling. `boot()` supports the
//! package's own parallel sub-API (`parallel = "snow"/"multicore"`,
//! `ncpus`, `cl` — including the ncpus > 1 footgun the paper documents)
//! and the transpiler-injected `.futurize_opts` path, which routes the
//! replicate loop through the future driver with per-replicate RNG
//! streams (`seed = TRUE` by default, since boot is resampling).

use super::split_futurize_opts;
use crate::future_core::driver::map_elements;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::{define, Env, EnvRef};
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};
use crate::transpile::{FuturizeOptions, SeedSetting};

pub fn register(r: &mut Reg) {
    r.normal("boot", "boot", boot_fn);
    r.normal("boot", "censboot", censboot_fn);
    r.normal("boot", "tsboot", tsboot_fn);
    r.normal("boot", "boot.ci", boot_ci_fn);
}

struct BootArgs {
    data: RVal,
    statistic: RVal,
    r: usize,
    stype: String,
    parallel_legacy: bool,
    opts: Option<FuturizeOptions>,
}

fn parse_boot_args(i: &mut Interp, args: &Args, env: &EnvRef) -> Result<BootArgs, Signal> {
    let (user, opts) = split_futurize_opts(args);
    let b = user.bind(&["data", "statistic", "R", "stype", "sim", "parallel", "ncpus", "cl", "l"]);
    let data = b.req(0, "data")?;
    let statistic = super::super::apis::as_function(&b.req(1, "statistic")?, env)?;
    let r = b.req(2, "R")?.as_usize().map_err(Signal::error)?;
    let stype = b
        .opt(3)
        .map(|v| v.as_str())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or_else(|| "i".into());
    // The package's own sub-API (what futurize hides): parallel only
    // happens when parallel != "no" AND ncpus > 1 — the footgun the
    // paper's §4.6 footnote documents.
    let parallel_mode = b
        .opt(5)
        .map(|v| v.as_str())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or_else(|| "no".into());
    let ncpus =
        b.opt(6).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(1);
    let parallel_legacy = parallel_mode != "no" && ncpus > 1;
    let _ = i;
    Ok(BootArgs { data, statistic, r, stype, parallel_legacy, opts })
}

/// Build the per-replicate closure in rlite so it serializes to workers:
/// captures `data`, `statistic`, `n`, `stype`.
fn replicate_closure(i: &mut Interp, env: &EnvRef, ba: &BootArgs) -> Result<RVal, Signal> {
    let n = match &ba.data {
        RVal::List(l) if l.class.as_deref() == Some("data.frame") => {
            l.vals.first().map(|c| c.len()).unwrap_or(0)
        }
        other => other.len(),
    };
    let src = if ba.stype == "w" {
        // Frequency weights f/n, as boot's stype = "w". tabulate() is
        // native (perf: the interpreted increment loop cost ~55us per
        // replicate, see EXPERIMENTS.md §Perf).
        "function(r) {\n  idx <- sample(n, size = n, replace = TRUE)\n  statistic(data, tabulate(idx, n) / n)\n}"
    } else {
        "function(r) {\n  idx <- sample(n, size = n, replace = TRUE)\n  statistic(data, idx)\n}"
    };
    let fenv = Env::child_of(env);
    define(&fenv, "data", ba.data.clone());
    define(&fenv, "statistic", ba.statistic.clone());
    define(&fenv, "n", RVal::scalar_int(n as i64));
    let expr = crate::rlite::parse_expr(src).map_err(Signal::error)?;
    i.eval(&expr, &fenv)
}

/// Original-sample statistic value (t0).
fn t0_value(i: &mut Interp, env: &EnvRef, ba: &BootArgs) -> EvalResult {
    let n = match &ba.data {
        RVal::List(l) if l.class.as_deref() == Some("data.frame") => {
            l.vals.first().map(|c| c.len()).unwrap_or(0)
        }
        other => other.len(),
    };
    let second = if ba.stype == "w" {
        RVal::dbl(vec![1.0 / n as f64; n])
    } else {
        RVal::int((1..=n as i64).collect())
    };
    i.call_function(&ba.statistic, vec![(None, ba.data.clone()), (None, second)], env)
}

fn run_boot(i: &mut Interp, env: &EnvRef, ba: BootArgs) -> EvalResult {
    let t0 = t0_value(i, env, &ba)?;
    let f = replicate_closure(i, env, &ba)?;
    let items: Vec<RVal> = (1..=ba.r as i64).map(RVal::scalar_int).collect();
    let t_vals: Vec<RVal> = if let Some(opts) = &ba.opts {
        let mut o = opts.clone();
        if o.seed.is_none() {
            o.seed = Some(SeedSetting::True);
        }
        map_elements(i, env, items, &f, vec![], &o.to_map_options(true))?
    } else if ba.parallel_legacy {
        // The package's own parallel path also goes through the session
        // plan — honest simulation of "snow" with whatever plan is set.
        let o = FuturizeOptions { seed: Some(SeedSetting::True), ..Default::default() };
        map_elements(i, env, items, &f, vec![], &o.to_map_options(true))?
    } else {
        super::super::apis::seq_map(i, env, &items, &f, &[])?
    };
    let t: Vec<f64> =
        t_vals.iter().map(|v| v.as_f64()).collect::<Result<_, _>>().map_err(Signal::error)?;
    let mut out = RList::named(
        vec![t0, RVal::dbl(t), RVal::scalar_int(ba.r as i64)],
        vec!["t0".into(), "t".into(), "R".into()],
    );
    out.class = Some("boot".into());
    Ok(RVal::List(out))
}

fn boot_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let ba = parse_boot_args(i, &args, env)?;
    run_boot(i, env, ba)
}

/// censboot: case resampling for censored data — same resampling core
/// with stype fixed to "i".
fn censboot_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let mut ba = parse_boot_args(i, &args, env)?;
    ba.stype = "i".into();
    run_boot(i, env, ba)
}

/// tsboot: block bootstrap for time series (fixed block length `l`).
fn tsboot_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_futurize_opts(&args);
    let b = user.bind(&["tseries", "statistic", "R", "l", "sim"]);
    let ts = b.req(0, "tseries")?;
    let statistic = super::super::apis::as_function(&b.req(1, "statistic")?, env)?;
    let r = b.req(2, "R")?.as_usize().map_err(Signal::error)?;
    let l = b.opt(3).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(5);
    let n = ts.len();
    if n == 0 || l == 0 {
        return Err(Signal::error("tsboot: empty series or zero block length"));
    }
    // Per-replicate closure: stitch ceil(n/l) random blocks, truncate to n.
    let src = "function(r) {\n  n_blocks <- ceiling(n / l)\n  starts <- sample(n - l + 1, size = n_blocks, replace = TRUE)\n  xs <- numeric(0)\n  for (s in starts) xs <- c(xs, series[s:(s + l - 1)])\n  statistic(xs[1:n])\n}";
    let fenv = Env::child_of(env);
    define(&fenv, "series", ts.clone());
    define(&fenv, "statistic", statistic.clone());
    define(&fenv, "n", RVal::scalar_int(n as i64));
    define(&fenv, "l", RVal::scalar_int(l as i64));
    let f = i.eval(&crate::rlite::parse_expr(src).map_err(Signal::error)?, &fenv)?;
    let t0 = i.call_function(&statistic, vec![(None, ts.clone())], env)?;
    let items: Vec<RVal> = (1..=r as i64).map(RVal::scalar_int).collect();
    let t_vals: Vec<RVal> = if let Some(opts) = opts {
        let mut o = opts;
        if o.seed.is_none() {
            o.seed = Some(SeedSetting::True);
        }
        map_elements(i, env, items, &f, vec![], &o.to_map_options(true))?
    } else {
        super::super::apis::seq_map(i, env, &items, &f, &[])?
    };
    let t: Vec<f64> =
        t_vals.iter().map(|v| v.as_f64()).collect::<Result<_, _>>().map_err(Signal::error)?;
    let mut out = RList::named(
        vec![t0, RVal::dbl(t), RVal::scalar_int(r as i64)],
        vec!["t0".into(), "t".into(), "R".into()],
    );
    out.class = Some("boot".into());
    Ok(RVal::List(out))
}

/// boot.ci(b): basic percentile interval from the replicate distribution.
fn boot_ci_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["boot.out", "conf"]);
    let obj = b.req(0, "boot.out")?;
    let conf =
        b.opt(1).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(0.95);
    let RVal::List(l) = &obj else {
        return Err(Signal::error("boot.ci: not a boot object"));
    };
    let t = l.get("t").ok_or_else(|| Signal::error("no t"))?;
    let mut t = t.as_dbl_vec().map_err(Signal::error)?;
    t.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let alpha = (1.0 - conf) / 2.0;
    let lo = t[((t.len() as f64 - 1.0) * alpha) as usize];
    let hi = t[((t.len() as f64 - 1.0) * (1.0 - alpha)).ceil() as usize];
    Ok(RVal::Dbl(crate::rlite::value::RVec::named(
        vec![lo, hi],
        vec!["lower".into(), "upper".into()],
    )))
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn boot_replicates_shape() {
        let v = run(
            "data(bigcity)\nratio <- function(d, w) sum(d$x * w) / sum(d$u * w)\n\
             set.seed(1)\nb <- boot(bigcity, statistic = ratio, R = 50, stype = \"w\")\nlength(b$t)",
        );
        assert_eq!(v, RVal::scalar_int(50));
    }

    #[test]
    fn boot_t_centred_near_t0() {
        let v = run(
            "data(bigcity)\nratio <- function(d, w) sum(d$x * w) / sum(d$u * w)\n\
             set.seed(1)\nb <- boot(bigcity, statistic = ratio, R = 200, stype = \"w\")\n\
             abs(mean(b$t) - b$t0) < 0.05",
        );
        assert_eq!(v, RVal::scalar_bool(true));
    }

    #[test]
    fn futurized_boot_is_reproducible_across_worker_counts() {
        let go = |workers: usize| -> RVal {
            run(&format!(
                "plan(multicore, workers = {workers})\nfutureSeed(99)\ndata(bigcity)\n\
                 ratio <- function(d, w) sum(d$x * w) / sum(d$u * w)\n\
                 b <- boot(bigcity, statistic = ratio, R = 40, stype = \"w\") |> futurize()\nb$t"
            ))
        };
        assert_eq!(go(1), go(3));
    }

    #[test]
    fn tsboot_blocks() {
        let v = run(
            "set.seed(2)\nts <- rnorm(60)\nb <- tsboot(ts, statistic = mean, R = 25, l = 10)\nlength(b$t)",
        );
        assert_eq!(v, RVal::scalar_int(25));
    }

    #[test]
    fn boot_ci_brackets_t0() {
        let v = run(
            "data(bigcity)\nratio <- function(d, w) sum(d$x * w) / sum(d$u * w)\n\
             set.seed(3)\nb <- boot(bigcity, statistic = ratio, R = 199, stype = \"w\")\n\
             ci <- boot.ci(b)\nc(ci[\"lower\"] < b$t0, b$t0 < ci[\"upper\"])",
        );
        assert_eq!(v, RVal::lgl(vec![true, true]));
    }

    #[test]
    fn legacy_parallel_footgun_ncpus_1_is_sequential() {
        // boot's own sub-API: parallel = "snow" with default ncpus = 1
        // does NOT parallelize (paper §4.6 footnote) — it still works,
        // sequentially.
        let v = run(
            "data(bigcity)\nratio <- function(d, w) sum(d$x * w) / sum(d$u * w)\n\
             set.seed(1)\nb <- boot(bigcity, statistic = ratio, R = 10, stype = \"w\", parallel = \"snow\")\nlength(b$t)",
        );
        assert_eq!(v, RVal::scalar_int(10));
    }
}
