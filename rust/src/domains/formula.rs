//! Model formulas: `y ~ x + (1 | g)`, `Species ~ .`, `y ~ s(x)`.
//!
//! `~` is a special form that captures both sides *unevaluated* and
//! stores their deparsed text in a `"formula"` object; domain packages
//! interpret the text (response, fixed terms, random-intercept group,
//! smooth terms) via [`parse_formula_parts`].

use crate::rlite::ast::Arg;
use crate::rlite::builtins::Reg;
use crate::rlite::deparse::deparse;
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};

pub fn register(r: &mut Reg) {
    r.special("stats", "~", tilde_fn);
}

fn tilde_fn(_i: &mut Interp, args: &[Arg], _env: &EnvRef) -> EvalResult {
    let (lhs, rhs) = match args.len() {
        1 => (String::new(), deparse(&args[0].value)),
        2 => (deparse(&args[0].value), deparse(&args[1].value)),
        n => return Err(Signal::error(format!("~ expects 1 or 2 operands, got {n}"))),
    };
    let mut l = RList::named(
        vec![RVal::scalar_str(lhs), RVal::scalar_str(rhs)],
        vec!["lhs".into(), "rhs".into()],
    );
    l.class = Some("formula".into());
    Ok(RVal::List(l))
}

/// A decomposed model formula.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FormulaParts {
    /// Response text (may be `cbind(a, b)`).
    pub response: String,
    /// Plain fixed-effect terms (`x`, `period`); `.` expands to "all
    /// other columns" at fit time.
    pub fixed: Vec<String>,
    /// Random-intercept grouping factors from `(1 | g)` terms.
    pub random_intercepts: Vec<String>,
    /// Smooth terms from `s(x)`.
    pub smooths: Vec<String>,
    /// Was the RHS just `.`?
    pub dot: bool,
}

/// Interpret a `"formula"` RVal.
pub fn parse_formula_parts(v: &RVal) -> Result<FormulaParts, String> {
    let RVal::List(l) = v else {
        return Err(format!("expected a formula, got {}", v.class()));
    };
    if l.class.as_deref() != Some("formula") {
        return Err(format!("expected a formula, got {}", v.class()));
    }
    let lhs = l.get("lhs").and_then(|x| x.as_str().ok()).unwrap_or_default();
    let rhs = l.get("rhs").and_then(|x| x.as_str().ok()).unwrap_or_default();
    let mut parts = FormulaParts { response: lhs, ..Default::default() };
    for term in split_terms(&rhs) {
        let t = term.trim();
        if t.is_empty() || t == "1" {
            continue;
        }
        if t == "." {
            parts.dot = true;
        } else if let Some(inner) = t.strip_prefix("s(").and_then(|s| s.strip_suffix(')')) {
            parts.smooths.push(inner.trim().to_string());
        } else if t.starts_with('(') && t.contains('|') {
            let inner = t.trim_start_matches('(').trim_end_matches(')');
            let group = inner.split('|').nth(1).unwrap_or("").trim();
            parts.random_intercepts.push(group.to_string());
        } else {
            parts.fixed.push(t.to_string());
        }
    }
    Ok(parts)
}

/// Split an RHS on top-level `+` (not inside parentheses).
fn split_terms(rhs: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for c in rhs.chars() {
        match c {
            '(' => {
                depth += 1;
                cur.push(c);
            }
            ')' => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            '+' if depth == 0 => {
                out.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::eval::Interp;

    fn formula(src: &str) -> FormulaParts {
        let v = Interp::new().eval_program(src).unwrap();
        parse_formula_parts(&v).unwrap()
    }

    #[test]
    fn simple_formula() {
        let p = formula("y ~ x");
        assert_eq!(p.response, "y");
        assert_eq!(p.fixed, vec!["x"]);
    }

    #[test]
    fn dot_formula() {
        let p = formula("Species ~ .");
        assert_eq!(p.response, "Species");
        assert!(p.dot);
    }

    #[test]
    fn mixed_model_formula() {
        let p = formula("cbind(incidence, size - incidence) ~ period + (1 | herd)");
        assert_eq!(p.response, "cbind(incidence, size - incidence)");
        assert_eq!(p.fixed, vec!["period"]);
        assert_eq!(p.random_intercepts, vec!["herd"]);
    }

    #[test]
    fn smooth_formula() {
        let p = formula("y ~ s(x)");
        assert_eq!(p.smooths, vec!["x"]);
    }
}
