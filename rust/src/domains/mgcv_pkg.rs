//! mgcv (paper §4.7): Big Additive Models. `bam()` fits a penalized
//! spline smoother by accumulating per-chunk Gram matrices — the chunk
//! loop is exactly what mgcv parallelizes with its `cluster` argument
//! and what `.futurize_opts` routes through the future driver. Each
//! chunk's X^T X runs on the AOT JAX/Pallas `gram` artifact via PJRT
//! (with a bit-checked native fallback), making this the flagship
//! three-layer path.

use super::formula::parse_formula_parts;
use super::split_futurize_opts;
use crate::future_core::driver::map_elements;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::{define, Env, EnvRef};
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};
use crate::runtime::GRAM_N;

/// Number of cubic B-spline basis functions (≤ GRAM_P so chunk grams fit
/// the AOT artifact block).
pub const K_BASIS: usize = 20;

pub fn register(r: &mut Reg) {
    r.normal("mgcv", "bam", bam_fn);
    r.normal("mgcv", "predict.bam", predict_bam_fn);
    r.normal("mgcv", ".bam_chunk_gram", bam_chunk_gram_fn);
    r.normal("mgcv", ".bam_basis_predict", bam_basis_predict_fn);
}

/// Cubic B-spline basis on [lo, hi] with K_BASIS functions (uniform
/// knots), evaluated by Cox–de Boor.
pub fn bspline_basis(x: &[f64], lo: f64, hi: f64) -> Vec<Vec<f64>> {
    let k = K_BASIS;
    let degree = 3usize;
    let n_knots = k + degree + 1;
    let inner = k - degree;
    let span = (hi - lo).max(1e-12);
    // Clamped uniform knot vector.
    let mut knots = Vec::with_capacity(n_knots);
    for _ in 0..=degree {
        knots.push(lo);
    }
    for j in 1..inner {
        knots.push(lo + span * j as f64 / inner as f64);
    }
    for _ in 0..=degree {
        knots.push(hi);
    }
    let mut basis = vec![vec![0.0; x.len()]; k];
    for (i, &xv) in x.iter().enumerate() {
        let xv = xv.clamp(lo, hi - 1e-9 * span);
        // Cox–de Boor, degree 0 up.
        let mut b = vec![0.0; knots.len() - 1];
        for j in 0..knots.len() - 1 {
            if knots[j] <= xv && xv < knots[j + 1] {
                b[j] = 1.0;
            }
        }
        for d in 1..=degree {
            for j in 0..knots.len() - 1 - d {
                let left = if knots[j + d] > knots[j] {
                    (xv - knots[j]) / (knots[j + d] - knots[j]) * b[j]
                } else {
                    0.0
                };
                let right = if knots[j + d + 1] > knots[j + 1] {
                    (knots[j + d + 1] - xv) / (knots[j + d + 1] - knots[j + 1]) * b[j + 1]
                } else {
                    0.0
                };
                b[j] = left + right;
            }
        }
        for j in 0..k {
            basis[j][i] = b[j];
        }
    }
    basis
}

/// Second-difference penalty matrix D'D (the standard P-spline penalty).
fn penalty(k: usize) -> Vec<f64> {
    let mut p = vec![0.0; k * k];
    for r in 0..k.saturating_sub(2) {
        // row of D: [1, -2, 1] at offset r
        let idx = [r, r + 1, r + 2];
        let w = [1.0, -2.0, 1.0];
        for a in 0..3 {
            for b in 0..3 {
                p[idx[a] * k + idx[b]] += w[a] * w[b];
            }
        }
    }
    p
}

/// Internal: gram + X^T y for one chunk of rows — the worker-side heavy
/// call (PJRT artifact inside `hlo_gram`/`kernels::gram`).
fn bam_chunk_gram_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "y", "lo", "hi"]);
    let x = b.req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    let y = b.req(1, "y")?.as_dbl_vec().map_err(Signal::error)?;
    let lo = b.req(2, "lo")?.as_f64().map_err(Signal::error)?;
    let hi = b.req(3, "hi")?.as_f64().map_err(Signal::error)?;
    let basis = bspline_basis(&x, lo, hi);
    let (g, xty) = crate::runtime::kernels::gram(&basis, &y).map_err(Signal::error)?;
    let mut out: Vec<RVal> = vec![RVal::dbl(g), RVal::dbl(xty)];
    out.push(RVal::scalar_int(x.len() as i64));
    Ok(RVal::list(out))
}

/// Internal: predict one chunk — basis × beta.
fn bam_basis_predict_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "beta", "lo", "hi"]);
    let x = b.req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    let beta = b.req(1, "beta")?.as_dbl_vec().map_err(Signal::error)?;
    let lo = b.req(2, "lo")?.as_f64().map_err(Signal::error)?;
    let hi = b.req(3, "hi")?.as_f64().map_err(Signal::error)?;
    let basis = bspline_basis(&x, lo, hi);
    let preds: Vec<f64> = (0..x.len())
        .map(|i| basis.iter().zip(&beta).map(|(col, b)| col[i] * b).sum())
        .collect();
    Ok(RVal::dbl(preds))
}

/// bam(y ~ s(x), data, rho/sp = smoothing parameter): chunked penalized
/// spline fit. With `.futurize_opts` (or mgcv's own `cluster =`), chunk
/// grams run concurrently.
fn bam_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, fopts) = split_futurize_opts(&args);
    let b = user.bind(&["formula", "data", "sp", "cluster", "chunk.size"]);
    let formula = b.req(0, "formula")?;
    let data = b.req(1, "data")?;
    let sp = b.opt(2).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(1.0);
    let legacy_cluster = b.opt(3).is_some_and(|v| !v.is_null());
    let chunk = b
        .opt(4)
        .map(|v| v.as_usize())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or(GRAM_N);
    let parts = parse_formula_parts(&formula).map_err(Signal::error)?;
    let sx = parts
        .smooths
        .first()
        .ok_or_else(|| Signal::error("bam: formula needs a s(x) term"))?;
    let y = super::df_column(&data, &parts.response).map_err(Signal::error)?;
    let x = super::df_column(&data, sx).map_err(Signal::error)?;
    let lo = x.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    // Chunk rows.
    let mut items = Vec::new();
    let mut s = 0usize;
    while s < x.len() {
        let e = (s + chunk).min(x.len());
        items.push(RVal::list(vec![
            RVal::dbl(x[s..e].to_vec()),
            RVal::dbl(y[s..e].to_vec()),
        ]));
        s = e;
    }
    let src = "function(ch) .bam_chunk_gram(ch[[1]], ch[[2]], lo, hi)";
    let fenv = Env::child_of(env);
    define(&fenv, "lo", RVal::scalar_dbl(lo));
    define(&fenv, "hi", RVal::scalar_dbl(hi));
    let f = i.eval(&crate::rlite::parse_expr(src).map_err(Signal::error)?, &fenv)?;
    let chunk_results: Vec<RVal> = if let Some(opts) = fopts {
        map_elements(i, env, items, &f, vec![], &opts.to_map_options(false))?
    } else if legacy_cluster {
        map_elements(
            i,
            env,
            items,
            &f,
            vec![],
            &crate::transpile::FuturizeOptions::default().to_map_options(false),
        )?
    } else {
        crate::apis::seq_map(i, env, &items, &f, &[])?
    };
    // Accumulate gram + xty over chunks, add penalty, solve.
    let k = K_BASIS;
    let mut g_acc = vec![0.0; k * k];
    let mut xty_acc = vec![0.0; k];
    for r in &chunk_results {
        let RVal::List(l) = r else { return Err(Signal::error("bam: bad chunk result")) };
        let g = l.vals[0].as_dbl_vec().map_err(Signal::error)?;
        let xty = l.vals[1].as_dbl_vec().map_err(Signal::error)?;
        for j in 0..k * k {
            g_acc[j] += g[j];
        }
        for j in 0..k {
            xty_acc[j] += xty[j];
        }
    }
    let pen = penalty(k);
    for j in 0..k * k {
        g_acc[j] += sp * pen[j];
    }
    let beta =
        crate::runtime::kernels::ridge_solve(&g_acc, &xty_acc, 1e-8).map_err(Signal::error)?;
    // In-sample RMSE for reporting.
    let basis = bspline_basis(&x, lo, hi);
    let fitted: Vec<f64> = (0..x.len())
        .map(|i2| basis.iter().zip(&beta).map(|(c, b)| c[i2] * b).sum())
        .collect();
    let rmse = (y
        .iter()
        .zip(&fitted)
        .map(|(a, b)| (a - b).powi(2))
        .sum::<f64>()
        / y.len() as f64)
        .sqrt();
    let mut out = RList::named(
        vec![
            RVal::dbl(beta),
            RVal::scalar_dbl(lo),
            RVal::scalar_dbl(hi),
            RVal::scalar_dbl(sp),
            RVal::scalar_dbl(rmse),
            RVal::scalar_int(chunk_results.len() as i64),
        ],
        vec![
            "beta".into(),
            "lo".into(),
            "hi".into(),
            "sp".into(),
            "rmse".into(),
            "n_chunks".into(),
        ],
    );
    out.class = Some("bam".into());
    Ok(RVal::List(out))
}

/// predict.bam(model, newdata): chunked prediction, parallelizable the
/// same way.
fn predict_bam_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, fopts) = split_futurize_opts(&args);
    let b = user.bind(&["object", "newdata", "chunk.size"]);
    let model = b.req(0, "object")?;
    let newdata = b.req(1, "newdata")?;
    let chunk = b
        .opt(2)
        .map(|v| v.as_usize())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or(GRAM_N);
    let RVal::List(m) = &model else { return Err(Signal::error("predict.bam: not a bam fit")) };
    let beta = m.get("beta").unwrap().clone();
    let lo = m.get("lo").unwrap().clone();
    let hi = m.get("hi").unwrap().clone();
    let x = match &newdata {
        RVal::List(l) if l.class.as_deref() == Some("data.frame") => {
            l.vals[0].as_dbl_vec().map_err(Signal::error)?
        }
        other => other.as_dbl_vec().map_err(Signal::error)?,
    };
    let mut items = Vec::new();
    let mut s = 0usize;
    while s < x.len() {
        let e = (s + chunk).min(x.len());
        items.push(RVal::dbl(x[s..e].to_vec()));
        s = e;
    }
    let src = "function(ch) .bam_basis_predict(ch, beta, lo, hi)";
    let fenv = Env::child_of(env);
    define(&fenv, "beta", beta);
    define(&fenv, "lo", lo);
    define(&fenv, "hi", hi);
    let f = i.eval(&crate::rlite::parse_expr(src).map_err(Signal::error)?, &fenv)?;
    let results: Vec<RVal> = if let Some(opts) = fopts {
        map_elements(i, env, items, &f, vec![], &opts.to_map_options(false))?
    } else {
        crate::apis::seq_map(i, env, &items, &f, &[])?
    };
    let mut out = Vec::with_capacity(x.len());
    for r in results {
        out.extend(r.as_dbl_vec().map_err(Signal::error)?);
    }
    Ok(RVal::dbl(out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn basis_partition_of_unity() {
        let x: Vec<f64> = (0..50).map(|i| i as f64 / 49.0).collect();
        let basis = bspline_basis(&x, 0.0, 1.0);
        for i in 0..x.len() {
            let s: f64 = basis.iter().map(|c| c[i]).sum();
            assert!((s - 1.0).abs() < 1e-9, "sum {s} at {i}");
        }
    }

    #[test]
    fn bam_fits_smooth_signal() {
        let v = run(
            "set.seed(21)\nn <- 600\nx <- runif(n, 0, 10)\ny <- sin(x) + rnorm(n, sd = 0.1)\n\
             df <- data.frame(y = y, x = x)\nm <- bam(y ~ s(x), data = df, sp = 0.1)\nm$rmse",
        );
        assert!(v.as_f64().unwrap() < 0.2, "rmse {v}");
    }

    #[test]
    fn bam_uses_multiple_chunks() {
        let v = run(
            "set.seed(22)\nn <- 600\nx <- runif(n, 0, 10)\ny <- sin(x)\n\
             df <- data.frame(y = y, x = x)\nm <- bam(y ~ s(x), data = df)\nm$n_chunks",
        );
        assert!(v.as_f64().unwrap() >= 3.0);
    }

    #[test]
    fn futurized_bam_matches_sequential() {
        let seq = run(
            "set.seed(23)\nn <- 500\nx <- runif(n, 0, 6)\ny <- cos(x) + rnorm(n, sd = 0.05)\n\
             df <- data.frame(y = y, x = x)\nm <- bam(y ~ s(x), data = df)\nm$beta",
        );
        let par = run(
            "plan(multicore, workers = 3)\nset.seed(23)\nn <- 500\nx <- runif(n, 0, 6)\ny <- cos(x) + rnorm(n, sd = 0.05)\n\
             df <- data.frame(y = y, x = x)\nm <- bam(y ~ s(x), data = df) |> futurize()\nm$beta",
        );
        let a = seq.as_dbl_vec().unwrap();
        let b = par.as_dbl_vec().unwrap();
        for (x1, x2) in a.iter().zip(&b) {
            assert!((x1 - x2).abs() < 1e-6);
        }
    }

    #[test]
    fn predict_bam_roundtrip() {
        let v = run(
            "set.seed(24)\nn <- 400\nx <- runif(n, 0, 5)\ny <- sin(x)\n\
             df <- data.frame(y = y, x = x)\nm <- bam(y ~ s(x), data = df, sp = 0.01)\n\
             p <- predict.bam(m, c(1, 2, 3))\nabs(p - sin(c(1, 2, 3)))",
        );
        for e in v.as_dbl_vec().unwrap() {
            assert!(e < 0.1, "pred err {e}");
        }
    }
}
