//! lme4 (paper §4.6): mixed-effects models. We implement a single-
//! grouping-factor linear mixed model fit by profiled GLS (DESIGN.md
//! documents this substitution for the full lme4 machinery: it exercises
//! the identical parallel surfaces — `allFit()` re-fitting under several
//! optimizers, and `bootMer()` parametric bootstrap). The binomial GLMM
//! of the cbpp example is fit on the empirical-logit scale.

use super::formula::parse_formula_parts;
use super::split_futurize_opts;
use crate::future_core::driver::map_elements;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::{define, Env, EnvRef};
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};
use crate::transpile::SeedSetting;

pub fn register(r: &mut Reg) {
    r.normal("lme4", "lmer", |i, a, e| fit_model_fn(i, a, e, false));
    r.normal("lme4", "glmer", |i, a, e| fit_model_fn(i, a, e, true));
    r.normal("lme4", "allFit", all_fit_fn);
    r.normal("lme4", "bootMer", boot_mer_fn);
    r.normal("lme4", "fixef", fixef_fn);
    r.normal("lme4", ".lmm_refit", lmm_refit_fn);
}

/// The optimizer roster allFit() tries (lme4's actual set).
pub const OPTIMIZERS: &[&str] = &[
    "bobyqa",
    "Nelder_Mead",
    "nlminbwrap",
    "nmkbw",
    "optimx.L-BFGS-B",
    "nloptwrap.NLOPT_LN_NELDERMEAD",
    "nloptwrap.NLOPT_LN_BOBYQA",
];

/// Profiled-likelihood LMM fit: y = Xβ + b_g + ε, b ~ N(0, σ²θ).
/// Golden-section search over the variance ratio θ; GLS per θ.
/// Different "optimizers" vary the search discipline (tolerance /
/// bracketing), converging to the same optimum within tolerance — the
/// behaviour allFit() exists to check.
pub fn fit_lmm(
    y: &[f64],
    x_cols: &[Vec<f64>],
    groups: &[usize],
    n_groups: usize,
    optimizer: &str,
) -> Result<LmmFit, String> {
    let (tol, max_iter) = match optimizer {
        "bobyqa" => (1e-8, 200),
        "Nelder_Mead" => (1e-6, 120),
        "nlminbwrap" => (1e-7, 160),
        "nmkbw" => (1e-5, 80),
        _ => (1e-7, 140),
    };
    // Design with intercept.
    let n = y.len();
    let p = x_cols.len() + 1;
    let mut cols: Vec<Vec<f64>> = vec![vec![1.0; n]];
    cols.extend(x_cols.iter().cloned());
    let dev = |theta: f64| -> (f64, Vec<f64>) {
        gls_profile(y, &cols, groups, n_groups, theta)
    };
    // Golden-section on log(theta) in [1e-6, 1e3].
    let golden = 0.618_033_988_75f64;
    let (mut lo, mut hi) = (-6.0f64, 3.0f64);
    let mut iters = 0;
    let mut m1 = hi - golden * (hi - lo);
    let mut m2 = lo + golden * (hi - lo);
    let mut f1 = dev(10f64.powf(m1)).0;
    let mut f2 = dev(10f64.powf(m2)).0;
    while (hi - lo) > tol && iters < max_iter {
        if f1 < f2 {
            hi = m2;
            m2 = m1;
            f2 = f1;
            m1 = hi - golden * (hi - lo);
            f1 = dev(10f64.powf(m1)).0;
        } else {
            lo = m1;
            m1 = m2;
            f1 = f2;
            m2 = lo + golden * (hi - lo);
            f2 = dev(10f64.powf(m2)).0;
        }
        iters += 1;
    }
    let theta = 10f64.powf((lo + hi) / 2.0);
    let (deviance, beta) = dev(theta);
    Ok(LmmFit { beta, theta, deviance, iters, p, optimizer: optimizer.to_string() })
}

#[derive(Clone, Debug)]
pub struct LmmFit {
    pub beta: Vec<f64>,
    pub theta: f64,
    pub deviance: f64,
    pub iters: usize,
    pub p: usize,
    pub optimizer: String,
}

/// GLS deviance + fixed effects at a given variance ratio θ, using the
/// group-wise Sherman–Morrison structure of V = I + θ Z Z'.
fn gls_profile(
    y: &[f64],
    cols: &[Vec<f64>],
    groups: &[usize],
    n_groups: usize,
    theta: f64,
) -> (f64, Vec<f64>) {
    let n = y.len();
    let p = cols.len();
    // Per-group sizes.
    let mut gsize = vec![0usize; n_groups];
    for &g in groups {
        gsize[g] += 1;
    }
    // Weighted cross-products under V^{-1} = I - (θ/(1+θ n_g)) per group
    // (Sherman–Morrison on the group blocks).
    let mut xtx = vec![0.0; p * p];
    let mut xty = vec![0.0; p];
    let mut yty = 0.0;
    // Plain parts.
    for i in 0..n {
        for a in 0..p {
            for bcol in a..p {
                xtx[a * p + bcol] += cols[a][i] * cols[bcol][i];
            }
            xty[a] += cols[a][i] * y[i];
        }
        yty += y[i] * y[i];
    }
    // Group-sum corrections.
    let mut gx = vec![vec![0.0; p]; n_groups];
    let mut gy = vec![0.0; n_groups];
    for i in 0..n {
        let g = groups[i];
        for a in 0..p {
            gx[g][a] += cols[a][i];
        }
        gy[g] += y[i];
    }
    for g in 0..n_groups {
        let w = theta / (1.0 + theta * gsize[g] as f64);
        for a in 0..p {
            for bcol in a..p {
                xtx[a * p + bcol] -= w * gx[g][a] * gx[g][bcol];
            }
            xty[a] -= w * gx[g][a] * gy[g];
        }
        yty -= w * gy[g] * gy[g];
    }
    for a in 0..p {
        for bcol in 0..a {
            xtx[a * p + bcol] = xtx[bcol * p + a];
        }
    }
    let beta = crate::runtime::kernels::ridge_solve(&xtx, &xty, 1e-10).unwrap_or(vec![0.0; p]);
    // Residual quadratic form and log|V|.
    let mut quad = yty;
    for a in 0..p {
        quad -= beta[a] * xty[a];
    }
    let quad = quad.max(1e-12);
    let mut logdet = 0.0;
    for g in 0..n_groups {
        logdet += (1.0 + theta * gsize[g] as f64).ln();
    }
    let sigma2 = quad / n as f64;
    let deviance = n as f64 * sigma2.ln() + logdet;
    (deviance, beta)
}

/// Pull (y, X columns, group codes) from a formula + data.frame. Binomial
/// responses `cbind(a, b)` are mapped to the empirical logit.
fn build_design(
    i: &mut Interp,
    env: &EnvRef,
    formula: &RVal,
    data: &RVal,
) -> Result<(Vec<f64>, Vec<Vec<f64>>, Vec<usize>, usize, String), Signal> {
    let parts = parse_formula_parts(formula).map_err(Signal::error)?;
    let RVal::List(df) = data else {
        return Err(Signal::error("lmer: data must be a data.frame"));
    };
    // Response: plain column or cbind(a, b) empirical logit.
    let y: Vec<f64> = if parts.response.starts_with("cbind(") {
        let expr = crate::rlite::parse_expr(&parts.response).map_err(Signal::error)?;
        let fenv = Env::child_of(env);
        if let (Some(names), true) = (&df.names, true) {
            for (k, n) in names.iter().enumerate() {
                define(&fenv, n, df.vals[k].clone());
            }
        }
        let both = i.eval(&expr, &fenv)?.as_dbl_vec().map_err(Signal::error)?;
        let n = both.len() / 2;
        (0..n)
            .map(|k| {
                let a = both[k] + 0.5;
                let b = both[n + k] + 0.5;
                (a / b).ln()
            })
            .collect()
    } else {
        super::df_column(data, &parts.response).map_err(Signal::error)?
    };
    let mut x_cols = Vec::new();
    for t in &parts.fixed {
        x_cols.push(super::df_column(data, t).map_err(Signal::error)?);
    }
    let group_col = parts
        .random_intercepts
        .first()
        .ok_or_else(|| Signal::error("lmer: needs a (1 | group) term"))?;
    let raw = df
        .get(group_col)
        .ok_or_else(|| Signal::error(format!("no column '{group_col}'")))?
        .as_str_vec()
        .map_err(Signal::error)?;
    let mut levels: Vec<String> = raw.clone();
    levels.sort();
    levels.dedup();
    let groups: Vec<usize> =
        raw.iter().map(|v| levels.iter().position(|l| l == v).unwrap()).collect();
    Ok((y, x_cols, groups, levels.len(), group_col.clone()))
}

fn fit_to_rval(fit: &LmmFit) -> RVal {
    let mut l = RList::named(
        vec![
            RVal::dbl(fit.beta.clone()),
            RVal::scalar_dbl(fit.theta),
            RVal::scalar_dbl(fit.deviance),
            RVal::scalar_int(fit.iters as i64),
            RVal::scalar_str(fit.optimizer.clone()),
        ],
        vec![
            "beta".into(),
            "theta".into(),
            "deviance".into(),
            "iters".into(),
            "optimizer".into(),
        ],
    );
    l.class = Some("merMod".into());
    RVal::List(l)
}

/// lmer(formula, data) / glmer(formula, data, family): fit the model.
/// The fit object additionally carries the design for refits.
fn fit_model_fn(i: &mut Interp, args: Args, env: &EnvRef, _glm: bool) -> EvalResult {
    let (user, _) = split_futurize_opts(&args);
    let b = user.bind(&["formula", "data", "family"]);
    let formula = b.req(0, "formula")?;
    let data = b.req(1, "data")?;
    let (y, x_cols, groups, n_groups, gname) = build_design(i, env, &formula, &data)?;
    let fit = fit_lmm(&y, &x_cols, &groups, n_groups, "bobyqa").map_err(Signal::error)?;
    let mut v = fit_to_rval(&fit);
    if let RVal::List(l) = &mut v {
        l.set("y", RVal::dbl(y));
        l.set("x", RVal::list(x_cols.into_iter().map(RVal::dbl).collect()));
        l.set("groups", RVal::dbl(groups.iter().map(|&g| g as f64).collect()));
        l.set("n_groups", RVal::scalar_int(n_groups as i64));
        l.set("group_name", RVal::scalar_str(gname));
    }
    Ok(v)
}

/// Internal refit builtin used by allFit/bootMer workers.
fn lmm_refit_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["y", "x", "groups", "n_groups", "optimizer"]);
    let y = b.req(0, "y")?.as_dbl_vec().map_err(Signal::error)?;
    let x_cols: Vec<Vec<f64>> = match b.req(1, "x")? {
        RVal::List(l) => l
            .vals
            .iter()
            .map(|c| c.as_dbl_vec())
            .collect::<Result<_, _>>()
            .map_err(Signal::error)?,
        other => vec![other.as_dbl_vec().map_err(Signal::error)?],
    };
    let groups: Vec<usize> = b
        .req(2, "groups")?
        .as_dbl_vec()
        .map_err(Signal::error)?
        .into_iter()
        .map(|g| g as usize)
        .collect();
    let n_groups = b.req(3, "n_groups")?.as_usize().map_err(Signal::error)?;
    let optimizer = b.req(4, "optimizer")?.as_str().map_err(Signal::error)?;
    let fit = fit_lmm(&y, &x_cols, &groups, n_groups, &optimizer).map_err(Signal::error)?;
    Ok(fit_to_rval(&fit))
}

/// allFit(model): refit under every optimizer — the parallel surface.
fn all_fit_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, fopts) = split_futurize_opts(&args);
    let b = user.bind(&["model", "parallel", "ncpus", "cl"]);
    let model = b.req(0, "model")?;
    let RVal::List(m) = &model else {
        return Err(Signal::error("allFit: not a merMod object"));
    };
    let src = "function(opt) .lmm_refit(y, x, groups, n_groups, opt)";
    let fenv = Env::child_of(env);
    for key in ["y", "x", "groups", "n_groups"] {
        define(&fenv, key, m.get(key).cloned().unwrap_or(RVal::Null));
    }
    let f = i.eval(&crate::rlite::parse_expr(src).map_err(Signal::error)?, &fenv)?;
    let items: Vec<RVal> =
        OPTIMIZERS.iter().map(|o| RVal::scalar_str(o.to_string())).collect();
    // allFit's own sub-API mirrors boot's (parallel/ncpus/cl, all three
    // needed); futurize hides it.
    let legacy = b.opt(1).map(|v| v.as_str().unwrap_or_default()).unwrap_or_default() != ""
        && b.opt(2).map(|v| v.as_usize().unwrap_or(1)).unwrap_or(1) > 1;
    let fits = if let Some(opts) = fopts {
        map_elements(i, env, items, &f, vec![], &opts.to_map_options(false))?
    } else if legacy {
        map_elements(
            i,
            env,
            items,
            &f,
            vec![],
            &crate::transpile::FuturizeOptions::default().to_map_options(false),
        )?
    } else {
        crate::apis::seq_map(i, env, &items, &f, &[])?
    };
    let mut out = RList::named(
        fits,
        OPTIMIZERS.iter().map(|o| o.to_string()).collect(),
    );
    out.class = Some("allFit".into());
    Ok(RVal::List(out))
}

/// bootMer(model, FUN, nsim): parametric bootstrap — simulate from the
/// fitted model, refit, apply FUN. Parallel over simulations with
/// per-simulation RNG streams.
fn boot_mer_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, fopts) = split_futurize_opts(&args);
    let b = user.bind(&["x", "FUN", "nsim"]);
    let model = b.req(0, "x")?;
    let fun = crate::apis::as_function(&b.req(1, "FUN")?, env)?;
    let nsim = b.opt(2).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(100);
    let RVal::List(m) = &model else {
        return Err(Signal::error("bootMer: not a merMod object"));
    };
    // Simulate y* = Xβ + b*_g + ε* on the worker, refit, FUN(fit).
    let src = "function(s) {\n  n <- length(y)\n  bg <- rnorm(n_groups, sd = sqrt(theta) * sigma)\n  ystar <- yhat + bg[groups + 1] + rnorm(n, sd = sigma)\n  fit <- .lmm_refit(ystar, x, groups, n_groups, \"bobyqa\")\n  FUN(fit)\n}";
    // Fitted values Xβ.
    let y = m.get("y").unwrap().as_dbl_vec().map_err(Signal::error)?;
    let beta = m.get("beta").unwrap().as_dbl_vec().map_err(Signal::error)?;
    let x_cols: Vec<Vec<f64>> = match m.get("x") {
        Some(RVal::List(l)) => l
            .vals
            .iter()
            .map(|c| c.as_dbl_vec())
            .collect::<Result<_, _>>()
            .map_err(Signal::error)?,
        _ => vec![],
    };
    let n = y.len();
    let yhat: Vec<f64> = (0..n)
        .map(|i2| {
            beta[0]
                + x_cols.iter().enumerate().map(|(j, c)| beta[j + 1] * c[i2]).sum::<f64>()
        })
        .collect();
    let theta = m.get("theta").unwrap().as_f64().map_err(Signal::error)?;
    // Residual sigma estimate.
    let groups_f = m.get("groups").unwrap().as_dbl_vec().map_err(Signal::error)?;
    let resid_var = {
        let ss: f64 = y.iter().zip(&yhat).map(|(a, b)| (a - b).powi(2)).sum();
        (ss / n as f64).max(1e-8)
    };
    let fenv = Env::child_of(env);
    define(&fenv, "y", RVal::dbl(y));
    define(&fenv, "yhat", RVal::dbl(yhat));
    define(&fenv, "x", m.get("x").cloned().unwrap_or(RVal::Null));
    define(&fenv, "groups", RVal::dbl(groups_f));
    define(&fenv, "n_groups", m.get("n_groups").cloned().unwrap_or(RVal::Null));
    define(&fenv, "theta", RVal::scalar_dbl(theta));
    define(&fenv, "sigma", RVal::scalar_dbl(resid_var.sqrt()));
    define(&fenv, "FUN", fun);
    let f = i.eval(&crate::rlite::parse_expr(src).map_err(Signal::error)?, &fenv)?;
    let items: Vec<RVal> = (1..=nsim as i64).map(RVal::scalar_int).collect();
    let results = if let Some(opts) = fopts {
        let mut o = opts;
        if o.seed.is_none() {
            o.seed = Some(SeedSetting::True);
        }
        map_elements(i, env, items, &f, vec![], &o.to_map_options(true))?
    } else {
        crate::apis::seq_map(i, env, &items, &f, &[])?
    };
    Ok(RVal::simplify(results, None))
}

fn fixef_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let model = args.bind(&["object"]).req(0, "object")?;
    match &model {
        RVal::List(l) => Ok(l.get("beta").cloned().unwrap_or(RVal::Null)),
        other => Err(Signal::error(format!("fixef: not a model: {}", other.class()))),
    }
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn lmm_recovers_fixed_effect() {
        // y = 2 + 3x + group effect + noise.
        let v = run(
            "set.seed(11)\nn <- 120\ng <- rep(c(\"a\",\"b\",\"c\",\"d\"), each = 30)\n\
             x <- rnorm(n)\ny <- 2 + 3 * x + rnorm(n, sd = 0.3)\n\
             df <- data.frame(y = y, x = x, g = g)\n\
             m <- lmer(y ~ x + (1 | g), data = df)\nfixef(m)",
        );
        let beta = v.as_dbl_vec().unwrap();
        assert!((beta[0] - 2.0).abs() < 0.3, "intercept {}", beta[0]);
        assert!((beta[1] - 3.0).abs() < 0.15, "slope {}", beta[1]);
    }

    #[test]
    fn all_fit_optimizers_agree() {
        let v = run(
            "set.seed(12)\nn <- 80\ng <- rep(c(\"a\",\"b\"), each = 40)\nx <- rnorm(n)\n\
             y <- 1 + 2 * x + rnorm(n, sd = 0.5)\ndf <- data.frame(y = y, x = x, g = g)\n\
             m <- lmer(y ~ x + (1 | g), data = df)\n\
             fits <- allFit(m)\n\
             slopes <- sapply(fits, function(f) f$beta[2])\nmax(slopes) - min(slopes)",
        );
        assert!(v.as_f64().unwrap() < 1e-3, "optimizers disagree: {v}");
    }

    #[test]
    fn glmer_cbpp_period_effect_negative() {
        // The paper's cbpp model: incidence declines over periods.
        let v = run(
            "data(cbpp)\nm <- glmer(cbind(incidence, size - incidence) ~ period + (1 | herd), data = cbpp, family = \"binomial\")\nfixef(m)",
        );
        let beta = v.as_dbl_vec().unwrap();
        assert!(beta[1] < 0.0, "period effect should be negative: {beta:?}");
    }

    #[test]
    fn futurized_all_fit_matches() {
        let seq = run(
            "set.seed(13)\nn <- 60\ng <- rep(c(\"a\",\"b\",\"c\"), each = 20)\nx <- rnorm(n)\n\
             y <- x + rnorm(n)\ndf <- data.frame(y = y, x = x, g = g)\n\
             m <- lmer(y ~ x + (1 | g), data = df)\n\
             fits <- allFit(m)\nsapply(fits, function(f) f$deviance)",
        );
        let par = run(
            "plan(multicore, workers = 3)\nset.seed(13)\nn <- 60\ng <- rep(c(\"a\",\"b\",\"c\"), each = 20)\nx <- rnorm(n)\n\
             y <- x + rnorm(n)\ndf <- data.frame(y = y, x = x, g = g)\n\
             m <- lmer(y ~ x + (1 | g), data = df)\n\
             fits <- allFit(m) |> futurize()\nsapply(fits, function(f) f$deviance)",
        );
        assert_eq!(seq, par);
    }

    #[test]
    fn boot_mer_runs() {
        let v = run(
            "set.seed(14)\nn <- 40\ng <- rep(c(\"a\",\"b\"), each = 20)\nx <- rnorm(n)\n\
             y <- x + rnorm(n)\ndf <- data.frame(y = y, x = x, g = g)\n\
             m <- lmer(y ~ x + (1 | g), data = df)\n\
             bs <- bootMer(m, function(f) f$beta[2], nsim = 10)\nlength(bs)",
        );
        assert_eq!(v, RVal::scalar_int(10));
    }
}
