//! Datasets used by the paper's §4.6 examples, synthesized
//! deterministically (DESIGN.md substitution table): `bigcity` (boot),
//! `iris` (caret), `cbpp` (lme4). `data(name)` defines the dataset in the
//! calling environment, as in R.

use crate::rlite::ast::Arg;
use crate::rlite::builtins::Reg;
use crate::rlite::env::{define, EnvRef};
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};
use crate::rng::RngStream;

pub fn register(r: &mut Reg) {
    r.special("datasets", "data", data_fn);
}

fn data_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let name = match args.first().map(|a| &a.value) {
        Some(crate::rlite::ast::Expr::Sym(s)) => s.to_string(),
        Some(crate::rlite::ast::Expr::Str(s)) => s.clone(),
        _ => return Err(Signal::error("data: expected a dataset name")),
    };
    let v = load(&name).ok_or_else(|| {
        Signal::error(format!("data set '{name}' not found"))
    })?;
    define(env, &name, v);
    let _ = i;
    Ok(RVal::scalar_str(name))
}

/// Load a dataset by name.
pub fn load(name: &str) -> Option<RVal> {
    match name {
        "bigcity" => Some(bigcity()),
        "iris" => Some(iris()),
        "cbpp" => Some(cbpp()),
        "crude" => Some(crude()),
        _ => None,
    }
}

fn df(cols: Vec<(&str, RVal)>) -> RVal {
    let names: Vec<String> = cols.iter().map(|(n, _)| n.to_string()).collect();
    let vals: Vec<RVal> = cols.into_iter().map(|(_, v)| v).collect();
    let mut l = RList::named(vals, names);
    l.class = Some("data.frame".into());
    RVal::List(l)
}

/// `boot::bigcity` analog: 49 US cities, populations (thousands) in 1920
/// (`u`) and 1930 (`x`). Synthesized with the same marginal behaviour:
/// 1930 ≈ 1.2× 1920 with heavy right tail; the ratio statistic
/// sum(x)/sum(u) lands near the published ≈1.24.
pub fn bigcity() -> RVal {
    let mut g = RngStream::from_seed(1920);
    let n = 49;
    let mut u = Vec::with_capacity(n);
    let mut x = Vec::with_capacity(n);
    for _ in 0..n {
        // Log-normal-ish city sizes in [40, 900] thousand.
        let base = (40.0 + 860.0 * g.next_f64().powi(3)).round();
        let growth = 1.15 + 0.25 * g.next_f64();
        u.push(base);
        x.push((base * growth).round());
    }
    df(vec![("u", RVal::dbl(u)), ("x", RVal::dbl(x))])
}

/// `iris` analog: 150 observations, 3 species × 50, four measurements
/// with species-dependent means (separable like the real data).
pub fn iris() -> RVal {
    let mut g = RngStream::from_seed(1935);
    let species = ["setosa", "versicolor", "virginica"];
    // (sl, sw, pl, pw) means per species, mirroring the real structure.
    let means = [
        [5.0, 3.4, 1.46, 0.24],
        [5.9, 2.77, 4.26, 1.33],
        [6.6, 2.97, 5.55, 2.03],
    ];
    let sds = [0.35, 0.33, 0.3, 0.2];
    let mut cols: Vec<Vec<f64>> = vec![Vec::new(); 4];
    let mut sp: Vec<String> = Vec::new();
    for (s, name) in species.iter().enumerate() {
        for _ in 0..50 {
            for j in 0..4 {
                let v = means[s][j] + sds[j] * g.next_normal();
                cols[j].push((v * 10.0).round() / 10.0);
            }
            sp.push(name.to_string());
        }
    }
    let mut it = cols.into_iter();
    df(vec![
        ("Sepal.Length", RVal::dbl(it.next().unwrap())),
        ("Sepal.Width", RVal::dbl(it.next().unwrap())),
        ("Petal.Length", RVal::dbl(it.next().unwrap())),
        ("Petal.Width", RVal::dbl(it.next().unwrap())),
        ("Species", RVal::chr(sp)),
    ])
}

/// `lme4::cbpp` analog: contagious bovine pleuropneumonia — 56 rows,
/// 15 herds × 4 periods (one missing combination trimmed), incidence out
/// of herd size with a declining period effect and herd-level variation.
pub fn cbpp() -> RVal {
    let mut g = RngStream::from_seed(1964);
    let mut herd = Vec::new();
    let mut period = Vec::new();
    let mut incidence = Vec::new();
    let mut size = Vec::new();
    let period_logit = [-2.0, -3.0, -3.3, -3.6];
    for h in 1..=15 {
        let herd_effect = 0.6 * g.next_normal();
        for (p, &pl) in period_logit.iter().enumerate() {
            if h == 15 && p == 3 {
                continue; // 56 rows, as in the real data + 1 trim
            }
            let sz = (8.0 + 25.0 * g.next_f64()).round();
            let logit: f64 = pl + herd_effect;
            let prob = 1.0 / (1.0 + (-logit).exp());
            let inc = (0..sz as usize).filter(|_| g.next_f64() < prob).count();
            herd.push(format!("H{h:02}"));
            period.push((p + 1) as f64);
            incidence.push(inc as f64);
            size.push(sz);
        }
    }
    df(vec![
        ("herd", RVal::chr(herd)),
        ("period", RVal::dbl(period)),
        ("incidence", RVal::dbl(incidence)),
        ("size", RVal::dbl(size)),
    ])
}

/// `tm::crude` analog: a small corpus of oil-market headlines.
pub fn crude() -> RVal {
    let texts = [
        "Crude oil prices rose sharply after the OPEC meeting in Vienna",
        "Diamond Shamrock cut its contract price for crude oil by 1.50 dollars",
        "OPEC ministers said they would defend the 18 dollar benchmark price",
        "Texaco lowered posted prices for crude oil across all grades",
        "Analysts expect crude supplies to tighten as refinery demand grows",
        "The national oil company announced new exploration in the gulf",
        "Futures for light sweet crude settled higher on the exchange",
        "Heavy crude discounts widened as fuel oil demand weakened",
        "Production quotas were discussed at the emergency OPEC session",
        "Spot prices for brent crude slipped amid ample supply",
    ];
    RVal::chr(texts.iter().map(|s| s.to_string()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::eval::Interp;

    #[test]
    fn bigcity_shape_and_ratio() {
        let v = bigcity();
        let RVal::List(l) = &v else { panic!() };
        assert_eq!(l.vals[0].len(), 49);
        let u: Vec<f64> = l.get("u").unwrap().as_dbl_vec().unwrap();
        let x: Vec<f64> = l.get("x").unwrap().as_dbl_vec().unwrap();
        let ratio = x.iter().sum::<f64>() / u.iter().sum::<f64>();
        assert!((1.1..1.45).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn iris_has_150_rows_3_species() {
        let v = iris();
        let RVal::List(l) = &v else { panic!() };
        assert_eq!(l.vals[0].len(), 150);
        let sp = l.get("Species").unwrap().as_str_vec().unwrap();
        assert_eq!(sp.iter().filter(|s| *s == "setosa").count(), 50);
    }

    #[test]
    fn data_defines_in_env() {
        let mut i = Interp::new();
        let v = i.eval_program("data(bigcity)\nnrow(bigcity)").unwrap();
        assert_eq!(v.as_f64().unwrap(), 49.0);
    }

    #[test]
    fn datasets_are_deterministic() {
        assert_eq!(bigcity(), bigcity());
        assert_eq!(iris(), iris());
        assert_eq!(cbpp(), cbpp());
    }
}
