//! tm (paper §4.7): text mining. A corpus is a character vector tagged
//! with class "corpus"; `tm_map()` transforms each document (the
//! parallel surface — tm's own engine knob `tm_parlapply_engine()` is
//! what futurize hides), `TermDocumentMatrix()` counts term×document
//! frequencies, `tm_index()` filters.

use super::split_futurize_opts;
use crate::future_core::driver::map_elements;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal, RVec};

pub fn register(r: &mut Reg) {
    r.normal("tm", "Corpus", corpus_fn);
    r.normal("tm", "VCorpus", corpus_fn);
    r.normal("tm", "VectorSource", |_i, a, _e| a.bind(&["x"]).req(0, "x"));
    r.normal("tm", "tm_map", tm_map_fn);
    r.normal("tm", "tm_index", tm_index_fn);
    r.normal("tm", "TermDocumentMatrix", tdm_fn);
    r.normal("tm", "content_transformer", |_i, a, _e| a.bind(&["FUN"]).req(0, "FUN"));
    r.normal("tm", "removePunctuation", remove_punct_fn);
    r.normal("tm", "stripWhitespace", strip_ws_fn);
    r.normal("tm", "removeWords", remove_words_fn);
    r.normal("tm", "stopwords", stopwords_fn);
}

fn corpus_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    let docs = x.as_str_vec().map_err(Signal::error)?;
    let mut l = RList::named(
        vec![RVal::chr(docs)],
        vec!["content".into()],
    );
    l.class = Some("corpus".into());
    Ok(RVal::List(l))
}

fn corpus_docs(v: &RVal) -> Result<Vec<String>, Signal> {
    match v {
        RVal::List(l) if l.class.as_deref() == Some("corpus") => {
            l.get("content").unwrap().as_str_vec().map_err(Signal::error)
        }
        other => other.as_str_vec().map_err(Signal::error),
    }
}

/// tm_map(corpus, FUN): transform every document.
fn tm_map_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, fopts) = split_futurize_opts(&args);
    let b = user.bind(&["x", "FUN"]);
    let corpus = b.req(0, "x")?;
    let f = crate::apis::as_function(&b.req(1, "FUN")?, env)?;
    let docs = corpus_docs(&corpus)?;
    let items: Vec<RVal> = docs.into_iter().map(RVal::scalar_str).collect();
    let results = if let Some(opts) = fopts {
        map_elements(i, env, items, &f, b.rest, &opts.to_map_options(false))?
    } else {
        crate::apis::seq_map(i, env, &items, &f, &b.rest)?
    };
    let out: Vec<String> = results
        .iter()
        .map(|r| r.as_str_vec().map(|v| v.join(" ")))
        .collect::<Result<_, _>>()
        .map_err(Signal::error)?;
    let mut l = RList::named(vec![RVal::chr(out)], vec!["content".into()]);
    l.class = Some("corpus".into());
    Ok(RVal::List(l))
}

/// tm_index(corpus, FUN): logical filter over documents.
fn tm_index_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, fopts) = split_futurize_opts(&args);
    let b = user.bind(&["x", "FUN"]);
    let corpus = b.req(0, "x")?;
    let f = crate::apis::as_function(&b.req(1, "FUN")?, env)?;
    let docs = corpus_docs(&corpus)?;
    let items: Vec<RVal> = docs.into_iter().map(RVal::scalar_str).collect();
    let results = if let Some(opts) = fopts {
        map_elements(i, env, items, &f, b.rest, &opts.to_map_options(false))?
    } else {
        crate::apis::seq_map(i, env, &items, &f, &b.rest)?
    };
    let flags: Result<Vec<bool>, _> = results.iter().map(|r| r.as_bool()).collect();
    Ok(RVal::lgl(flags.map_err(Signal::error)?))
}

/// TermDocumentMatrix(corpus): term × document counts. Per-document
/// tokenization is the parallel surface.
fn tdm_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, fopts) = split_futurize_opts(&args);
    let b = user.bind(&["x"]);
    let corpus = b.req(0, "x")?;
    let docs = corpus_docs(&corpus)?;
    // Per-document token counting, futurizable.
    let counts: Vec<std::collections::HashMap<String, usize>> = if let Some(opts) = fopts {
        // Tokenize on workers via an rlite shim returning tokens.
        let shim = i.eval(
            &crate::rlite::parse_expr("function(doc) strsplit(tolower(doc), \" \")[[1]]")
                .map_err(Signal::error)?,
            env,
        )?;
        let items: Vec<RVal> = docs.iter().map(|d| RVal::scalar_str(d.clone())).collect();
        let toks = map_elements(i, env, items, &shim, vec![], &opts.to_map_options(false))?;
        toks.iter()
            .map(|t| {
                let mut m = std::collections::HashMap::new();
                for w in t.as_str_vec().unwrap_or_default() {
                    let w = normalize(&w);
                    if !w.is_empty() {
                        *m.entry(w).or_insert(0) += 1;
                    }
                }
                m
            })
            .collect()
    } else {
        docs.iter()
            .map(|d| {
                let mut m = std::collections::HashMap::new();
                for w in d.to_lowercase().split_whitespace() {
                    let w = normalize(w);
                    if !w.is_empty() {
                        *m.entry(w).or_insert(0) += 1;
                    }
                }
                m
            })
            .collect()
    };
    let mut terms: Vec<String> =
        counts.iter().flat_map(|m| m.keys().cloned()).collect();
    terms.sort();
    terms.dedup();
    // Matrix as list of per-document count columns, named by terms.
    let cols: Vec<RVal> = counts
        .iter()
        .map(|m| {
            RVal::dbl(terms.iter().map(|t| *m.get(t).unwrap_or(&0) as f64).collect())
        })
        .collect();
    let mut l = RList::named(
        vec![
            RVal::Chr(RVec::plain(terms)),
            RVal::list(cols),
            RVal::scalar_int(docs.len() as i64),
        ],
        vec!["terms".into(), "counts".into(), "n_docs".into()],
    );
    l.class = Some("TermDocumentMatrix".into());
    Ok(RVal::List(l))
}

fn normalize(w: &str) -> String {
    w.chars().filter(|c| c.is_alphanumeric()).collect::<String>().to_lowercase()
}

fn remove_punct_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?.as_str_vec().map_err(Signal::error)?;
    Ok(RVal::chr(
        x.iter()
            .map(|s| s.chars().filter(|c| !c.is_ascii_punctuation()).collect())
            .collect(),
    ))
}

fn strip_ws_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?.as_str_vec().map_err(Signal::error)?;
    Ok(RVal::chr(
        x.iter().map(|s| s.split_whitespace().collect::<Vec<_>>().join(" ")).collect(),
    ))
}

fn remove_words_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "words"]);
    let x = b.req(0, "x")?.as_str_vec().map_err(Signal::error)?;
    let words = b.req(1, "words")?.as_str_vec().map_err(Signal::error)?;
    Ok(RVal::chr(
        x.iter()
            .map(|s| {
                s.split_whitespace()
                    .filter(|w| !words.contains(&w.to_lowercase()))
                    .collect::<Vec<_>>()
                    .join(" ")
            })
            .collect(),
    ))
}

fn stopwords_fn(_i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    Ok(RVal::chr(
        ["the", "a", "an", "and", "or", "of", "in", "on", "for", "to", "at", "its", "it",
            "as", "by", "with", "would", "said", "they"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    ))
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn tm_map_transforms_documents() {
        let v = run(
            "data(crude)\ncorpus <- Corpus(VectorSource(crude))\n\
             up <- tm_map(corpus, toupper)\nup$content[1]",
        );
        let s = v.as_str().unwrap();
        assert_eq!(s, s.to_uppercase());
    }

    #[test]
    fn futurized_tm_map_matches() {
        let seq = run(
            "data(crude)\nc1 <- tm_map(Corpus(VectorSource(crude)), tolower)\nc1$content",
        );
        let par = run(
            "plan(multicore, workers = 3)\ndata(crude)\n\
             c1 <- tm_map(Corpus(VectorSource(crude)), tolower) |> futurize()\nc1$content",
        );
        assert_eq!(seq, par);
    }

    #[test]
    fn tdm_counts_terms() {
        let v = run(
            "corpus <- Corpus(VectorSource(c(\"oil oil price\", \"price up\")))\n\
             tdm <- TermDocumentMatrix(corpus)\ntdm$terms",
        );
        assert_eq!(
            v.as_str_vec().unwrap(),
            vec!["oil".to_string(), "price".to_string(), "up".to_string()]
        );
    }

    #[test]
    fn tm_index_filters() {
        let v = run(
            "data(crude)\ncorpus <- Corpus(VectorSource(crude))\n\
             hits <- tm_index(corpus, function(d) nchar(d) > 60)\nsum(hits) > 0",
        );
        assert_eq!(v, RVal::scalar_bool(true));
    }
}
