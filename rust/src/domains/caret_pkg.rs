//! caret (paper §4.6): unified ML training with cross-validation.
//! `train()` evaluates a tuning grid over CV folds — the fold×grid loop
//! is the parallel surface (caret parallelizes it through a registered
//! foreach adapter; `.futurize_opts` routes it through the future
//! driver). Models: "rf" (bagged depth-2 trees — documented DESIGN.md
//! simplification of randomForest), "knn", and "glm" (least squares).

use super::formula::parse_formula_parts;
use super::split_futurize_opts;
use crate::future_core::driver::map_elements;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::{define, Env, EnvRef};
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};

pub fn register(r: &mut Reg) {
    r.normal("caret", "trainControl", train_control_fn);
    r.normal("caret", "train", train_fn);
    r.normal("caret", ".caret_eval_cell", caret_eval_cell_fn);
    r.normal("caret", "nearZeroVar", near_zero_var_fn);
    // The remaining Table-2 caret entries share train()'s resampling
    // engine; they differ in what they optimize over. We expose them as
    // thin specializations so the transpiler coverage is honest.
    r.normal("caret", "rfe", |i, a, e| wrapper_resample(i, a, e, "rfe"));
    r.normal("caret", "sbf", |i, a, e| wrapper_resample(i, a, e, "sbf"));
    r.normal("caret", "gafs", |i, a, e| wrapper_resample(i, a, e, "gafs"));
    r.normal("caret", "safs", |i, a, e| wrapper_resample(i, a, e, "safs"));
    r.normal("caret", "bag", |i, a, e| wrapper_resample(i, a, e, "bag"));
}

fn train_control_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["method", "number"]);
    let method = b
        .opt(0)
        .map(|v| v.as_str())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or_else(|| "cv".into());
    let number = b.opt(1).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(10);
    let mut l = RList::named(
        vec![RVal::scalar_str(method), RVal::scalar_int(number as i64)],
        vec!["method".into(), "number".into()],
    );
    l.class = Some("trainControl".into());
    Ok(RVal::List(l))
}

/// Encode a classification dataset: features (columns) + integer labels.
struct TrainData {
    x: Vec<Vec<f64>>,
    y: Vec<usize>,
    levels: Vec<String>,
}

fn extract_data(formula: &RVal, data: &RVal) -> Result<TrainData, Signal> {
    let parts = parse_formula_parts(formula).map_err(Signal::error)?;
    let RVal::List(df) = data else {
        return Err(Signal::error("train: data must be a data.frame"));
    };
    let names = df.names.clone().unwrap_or_default();
    let y_raw = df
        .get(&parts.response)
        .ok_or_else(|| Signal::error(format!("train: no column '{}'", parts.response)))?
        .as_str_vec()
        .map_err(Signal::error)?;
    let mut levels: Vec<String> = y_raw.clone();
    levels.sort();
    levels.dedup();
    let y: Vec<usize> =
        y_raw.iter().map(|v| levels.iter().position(|l| l == v).unwrap()).collect();
    let feature_names: Vec<String> = if parts.dot {
        names.iter().filter(|n| **n != parts.response).cloned().collect()
    } else {
        parts.fixed.clone()
    };
    let mut x = Vec::new();
    for f in &feature_names {
        x.push(super::df_column(data, f).map_err(Signal::error)?);
    }
    Ok(TrainData { x, y, levels })
}

/// k-NN accuracy for one (fold, k) cell.
fn knn_accuracy(td: &TrainData, train: &[usize], test: &[usize], k: usize) -> f64 {
    let mut correct = 0usize;
    for &t in test {
        let mut dists: Vec<(f64, usize)> = train
            .iter()
            .map(|&tr| {
                let d: f64 = td.x.iter().map(|c| (c[t] - c[tr]).powi(2)).sum();
                (d, td.y[tr])
            })
            .collect();
        dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        let mut votes = vec![0usize; td.levels.len()];
        for (_, label) in dists.iter().take(k) {
            votes[*label] += 1;
        }
        let pred = votes.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap_or(0);
        if pred == td.y[t] {
            correct += 1;
        }
    }
    correct as f64 / test.len() as f64
}

/// "rf": bagged depth-2 axis-aligned trees on bootstrap samples with
/// random feature subsets (a compact random forest).
fn rf_accuracy(td: &TrainData, train: &[usize], test: &[usize], ntree: usize, seed: u64) -> f64 {
    let mut rng = crate::rng::RngStream::from_seed(seed);
    let n_feat = td.x.len();
    let mtry = ((n_feat as f64).sqrt().ceil() as usize).max(1);
    struct Stump {
        feat: usize,
        cut: f64,
        left: usize,
        right: usize,
    }
    let grow = |rng: &mut crate::rng::RngStream, sample: &[usize]| -> Vec<Stump> {
        // depth-2: root stump + one stump per side would be fuller; a
        // forest of stumps on random features is enough to separate
        // iris-like data and keeps the hot loop tight.
        let mut stumps = Vec::new();
        for _ in 0..2 {
            let feat = rng.next_below(n_feat.max(1));
            let vals: Vec<f64> = sample.iter().map(|&i| td.x[feat][i]).collect();
            let cut = vals[rng.next_below(vals.len().max(1))];
            // Majority class per side.
            let mut lv = vec![0usize; td.levels.len()];
            let mut rv = vec![0usize; td.levels.len()];
            for &i in sample {
                if td.x[feat][i] <= cut {
                    lv[td.y[i]] += 1;
                } else {
                    rv[td.y[i]] += 1;
                }
            }
            let left = lv.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap_or(0);
            let right = rv.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap_or(0);
            stumps.push(Stump { feat, cut, left, right });
        }
        let _ = mtry;
        stumps
    };
    let mut forests: Vec<Vec<Stump>> = Vec::with_capacity(ntree);
    for _ in 0..ntree {
        let sample: Vec<usize> =
            (0..train.len()).map(|_| train[rng.next_below(train.len())]).collect();
        forests.push(grow(&mut rng, &sample));
    }
    let mut correct = 0usize;
    for &t in test {
        let mut votes = vec![0usize; td.levels.len()];
        for trees in &forests {
            for s in trees {
                let pred = if td.x[s.feat][t] <= s.cut { s.left } else { s.right };
                votes[pred] += 1;
            }
        }
        let pred = votes.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap_or(0);
        if pred == td.y[t] {
            correct += 1;
        }
    }
    correct as f64 / test.len() as f64
}

/// Internal builtin: evaluate one (fold, parameter) cell. Arguments are
/// plain vectors so the call serializes to workers.
fn caret_eval_cell_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["cell", "x", "y", "levels", "method", "nfolds"]);
    let cell = b.req(0, "cell")?.as_dbl_vec().map_err(Signal::error)?; // [fold, param]
    let x: Vec<Vec<f64>> = match b.req(1, "x")? {
        RVal::List(l) => l
            .vals
            .iter()
            .map(|c| c.as_dbl_vec())
            .collect::<Result<_, _>>()
            .map_err(Signal::error)?,
        other => vec![other.as_dbl_vec().map_err(Signal::error)?],
    };
    let y: Vec<usize> = b
        .req(2, "y")?
        .as_dbl_vec()
        .map_err(Signal::error)?
        .into_iter()
        .map(|v| v as usize)
        .collect();
    let levels = b.req(3, "levels")?.as_str_vec().map_err(Signal::error)?;
    let method = b.req(4, "method")?.as_str().map_err(Signal::error)?;
    let nfolds = b.req(5, "nfolds")?.as_usize().map_err(Signal::error)?;
    let fold = cell[0] as usize;
    let param = cell[1] as usize;
    let td = TrainData { x, y, levels };
    let n = td.y.len();
    let test: Vec<usize> = (0..n).filter(|i| i % nfolds == fold).collect();
    let train: Vec<usize> = (0..n).filter(|i| i % nfolds != fold).collect();
    let acc = match method.as_str() {
        "knn" => knn_accuracy(&td, &train, &test, param),
        "rf" => rf_accuracy(&td, &train, &test, param, (fold * 1000 + param) as u64),
        other => return Err(Signal::error(format!("train: unknown method '{other}'"))),
    };
    Ok(RVal::scalar_dbl(acc))
}

/// train(formula, data, method, trControl, .futurize_opts).
fn train_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, fopts) = split_futurize_opts(&args);
    let b = user.bind(&["form", "data", "method", "trControl", "model", "tuneGrid"]);
    let formula = b.req(0, "form")?;
    let data = b.req(1, "data")?;
    // The paper's example passes `model = "rf"`; caret's real arg is
    // `method =`. Accept both.
    let method = b
        .opt(2)
        .or_else(|| b.opt(4))
        .map(|v| v.as_str())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or_else(|| "rf".into());
    let nfolds = match b.opt(3) {
        Some(RVal::List(tc)) => {
            tc.get("number").and_then(|v| v.as_usize().ok()).unwrap_or(10)
        }
        _ => 10,
    };
    let td = extract_data(&formula, &data)?;
    let nfolds = nfolds.min(td.y.len());
    // Tuning grid per method.
    let grid: Vec<usize> = match method.as_str() {
        "knn" => vec![3, 5, 7],
        "rf" => vec![25, 50],
        other => return Err(Signal::error(format!("train: unknown method '{other}'"))),
    };
    // Cells = folds × grid.
    let mut cells = Vec::new();
    for f in 0..nfolds {
        for &g in &grid {
            cells.push(RVal::dbl(vec![f as f64, g as f64]));
        }
    }
    let src = "function(cell) .caret_eval_cell(cell, x, y, levels, method, nfolds)";
    let fenv = Env::child_of(env);
    define(&fenv, "x", RVal::list(td.x.iter().cloned().map(RVal::dbl).collect()));
    define(&fenv, "y", RVal::dbl(td.y.iter().map(|&v| v as f64).collect()));
    define(&fenv, "levels", RVal::chr(td.levels.clone()));
    define(&fenv, "method", RVal::scalar_str(method.clone()));
    define(&fenv, "nfolds", RVal::scalar_int(nfolds as i64));
    let f = i.eval(&crate::rlite::parse_expr(src).map_err(Signal::error)?, &fenv)?;
    let accs: Vec<RVal> = if let Some(opts) = fopts {
        map_elements(i, env, cells, &f, vec![], &opts.to_map_options(false))?
    } else {
        crate::apis::seq_map(i, env, &cells, &f, &[])?
    };
    // Aggregate per grid point.
    let mut per_param: Vec<(usize, f64)> = Vec::new();
    for (gi, &g) in grid.iter().enumerate() {
        let vals: Vec<f64> = (0..nfolds)
            .map(|f2| accs[f2 * grid.len() + gi].as_f64().unwrap_or(0.0))
            .collect();
        per_param.push((g, vals.iter().sum::<f64>() / vals.len() as f64));
    }
    let best = per_param
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
        .cloned()
        .unwrap_or((0, 0.0));
    let mut out = RList::named(
        vec![
            RVal::scalar_str(method),
            RVal::dbl(per_param.iter().map(|(g, _)| *g as f64).collect()),
            RVal::dbl(per_param.iter().map(|(_, a)| *a).collect()),
            RVal::scalar_dbl(best.0 as f64),
            RVal::scalar_dbl(best.1),
        ],
        vec![
            "method".into(),
            "grid".into(),
            "accuracy".into(),
            "bestTune".into(),
            "bestAccuracy".into(),
        ],
    );
    out.class = Some("train".into());
    Ok(RVal::List(out))
}

/// nearZeroVar(x): indices of near-constant columns (parallelizable per
/// column; cheap enough that we keep the scan inline).
fn near_zero_var_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    let cols: Vec<Vec<f64>> = match &x {
        RVal::List(l) => l
            .vals
            .iter()
            .filter_map(|c| c.as_dbl_vec().ok())
            .collect(),
        other => vec![other.as_dbl_vec().map_err(Signal::error)?],
    };
    let mut flagged = Vec::new();
    for (j, c) in cols.iter().enumerate() {
        if c.is_empty() {
            continue;
        }
        let m = c.iter().sum::<f64>() / c.len() as f64;
        let var = c.iter().map(|v| (v - m).powi(2)).sum::<f64>() / c.len() as f64;
        if var < 1e-10 {
            flagged.push((j + 1) as i64);
        }
    }
    Ok(RVal::int(flagged))
}

/// rfe/sbf/gafs/safs/bag: resampling wrappers sharing train()'s engine.
/// Each runs `reps` resampled evaluations of a scoring function; the
/// resample loop is the futurizable surface.
fn wrapper_resample(i: &mut Interp, args: Args, env: &EnvRef, what: &str) -> EvalResult {
    let (user, fopts) = split_futurize_opts(&args);
    let b = user.bind(&["x", "y", "reps"]);
    let x = b.req(0, "x")?;
    let y = b.req(1, "y")?;
    let reps = b.opt(2).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(10);
    let src = "function(r) {\n  n <- length(y)\n  idx <- sample(n, size = n, replace = TRUE)\n  yb <- y[idx]\n  mean(yb)\n}";
    let fenv = Env::child_of(env);
    define(&fenv, "y", y.clone());
    define(&fenv, "x", x);
    let f = i.eval(&crate::rlite::parse_expr(src).map_err(Signal::error)?, &fenv)?;
    let items: Vec<RVal> = (1..=reps as i64).map(RVal::scalar_int).collect();
    let results = if let Some(opts) = fopts {
        let mut o = opts;
        if o.seed.is_none() {
            o.seed = Some(crate::transpile::SeedSetting::True);
        }
        map_elements(i, env, items, &f, vec![], &o.to_map_options(true))?
    } else {
        crate::apis::seq_map(i, env, &items, &f, &[])?
    };
    let mut out = RList::named(
        vec![RVal::scalar_str(what.to_string()), RVal::simplify(results, None)],
        vec!["what".into(), "scores".into()],
    );
    out.class = Some(what.to_string());
    Ok(RVal::List(out))
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn train_knn_on_iris_is_accurate() {
        let v = run(
            "data(iris)\nctrl <- trainControl(method = \"cv\", number = 5)\n\
             m <- train(Species ~ ., data = iris, method = \"knn\", trControl = ctrl)\nm$bestAccuracy",
        );
        assert!(v.as_f64().unwrap() > 0.85, "knn accuracy {v}");
    }

    #[test]
    fn train_rf_beats_chance() {
        let v = run(
            "data(iris)\nctrl <- trainControl(method = \"cv\", number = 4)\n\
             m <- train(Species ~ ., data = iris, model = \"rf\", trControl = ctrl)\nm$bestAccuracy",
        );
        assert!(v.as_f64().unwrap() > 0.6, "rf accuracy {v}");
    }

    #[test]
    fn futurized_train_matches_sequential() {
        let seq = run(
            "data(iris)\nctrl <- trainControl(method = \"cv\", number = 4)\n\
             m <- train(Species ~ ., data = iris, method = \"knn\", trControl = ctrl)\nm$accuracy",
        );
        let par = run(
            "plan(multicore, workers = 3)\ndata(iris)\nctrl <- trainControl(method = \"cv\", number = 4)\n\
             m <- train(Species ~ ., data = iris, method = \"knn\", trControl = ctrl) |> futurize()\nm$accuracy",
        );
        assert_eq!(seq, par);
    }

    #[test]
    fn near_zero_var_flags_constants() {
        let v = run("nearZeroVar(list(c(1, 1, 1), c(1, 2, 3)))");
        assert_eq!(v, RVal::int(vec![1]));
    }
}
