//! glmnet (paper §4.6): pathwise coordinate-descent elastic net and
//! `cv.glmnet()` cross-validation. The CV fold loop is the parallel
//! surface (glmnet's own `parallel = TRUE` requires a registered foreach
//! adapter; `.futurize_opts` routes it through the future driver).
//!
//! The coordinate-descent core is a faithful (if compact) implementation
//! of Friedman et al.'s algorithm: soft-thresholding updates over a
//! warm-started, log-spaced lambda path, on standardized predictors.

use super::split_futurize_opts;
use crate::future_core::driver::map_elements;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::{define, Env, EnvRef};
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};

pub fn register(r: &mut Reg) {
    r.normal("glmnet", "cv.glmnet", cv_glmnet_fn);
    r.normal("glmnet", "glmnet", glmnet_fn);
    r.normal("glmnet", ".glmnet_fold_mse", glmnet_fold_mse_fn);
}

/// Extract (columns, y) from matrix-like x.
fn design(x: &RVal, y: &RVal) -> Result<(Vec<Vec<f64>>, Vec<f64>), Signal> {
    let cols: Vec<Vec<f64>> = match x {
        RVal::List(l) => l
            .vals
            .iter()
            .map(|c| c.as_dbl_vec())
            .collect::<Result<_, _>>()
            .map_err(Signal::error)?,
        other => vec![other.as_dbl_vec().map_err(Signal::error)?],
    };
    let y = y.as_dbl_vec().map_err(Signal::error)?;
    if cols.is_empty() || cols[0].len() != y.len() {
        return Err(Signal::error("glmnet: x/y dimension mismatch"));
    }
    Ok((cols, y))
}

/// Pathwise coordinate descent for the elastic net on standardized
/// columns. Returns per-lambda coefficient vectors (original scale) and
/// intercepts.
pub fn coord_descent_path(
    cols: &[Vec<f64>],
    y: &[f64],
    lambdas: &[f64],
    alpha: f64,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    let n = y.len();
    let p = cols.len();
    let nf = n as f64;
    // Standardize.
    let mut means = vec![0.0; p];
    let mut sds = vec![1.0; p];
    let mut xs: Vec<Vec<f64>> = Vec::with_capacity(p);
    for (j, c) in cols.iter().enumerate() {
        let m = c.iter().sum::<f64>() / nf;
        let v = (c.iter().map(|x| (x - m).powi(2)).sum::<f64>() / nf).sqrt();
        means[j] = m;
        sds[j] = if v > 1e-12 { v } else { 1.0 };
        xs.push(c.iter().map(|x| (x - m) / sds[j]).collect());
    }
    let ymean = y.iter().sum::<f64>() / nf;
    let yc: Vec<f64> = y.iter().map(|v| v - ymean).collect();

    let mut beta = vec![0.0; p];
    let mut resid = yc.clone();
    let mut betas_out = Vec::with_capacity(lambdas.len());
    let mut intercepts = Vec::with_capacity(lambdas.len());
    for &lam in lambdas {
        // Coordinate descent to convergence at this lambda (warm start).
        for _ in 0..200 {
            let mut max_delta: f64 = 0.0;
            for j in 0..p {
                let xj = &xs[j];
                // Partial residual correlation (x standardized: x'x/n = 1).
                let rho: f64 =
                    xj.iter().zip(&resid).map(|(a, b)| a * b).sum::<f64>() / nf + beta[j];
                let z = 1.0 + lam * (1.0 - alpha);
                let new = soft_threshold(rho, lam * alpha) / z;
                let delta = new - beta[j];
                if delta != 0.0 {
                    for i in 0..n {
                        resid[i] -= delta * xj[i];
                    }
                    beta[j] = new;
                    max_delta = max_delta.max(delta.abs());
                }
            }
            if max_delta < 1e-7 {
                break;
            }
        }
        // De-standardize.
        let b_orig: Vec<f64> = beta.iter().zip(&sds).map(|(b, s)| b / s).collect();
        let icpt =
            ymean - b_orig.iter().zip(&means).map(|(b, m)| b * m).sum::<f64>();
        betas_out.push(b_orig);
        intercepts.push(icpt);
    }
    (betas_out, intercepts)
}

fn soft_threshold(z: f64, g: f64) -> f64 {
    if z > g {
        z - g
    } else if z < -g {
        z + g
    } else {
        0.0
    }
}

/// Default lambda path: log-spaced from lambda_max down 2 decades.
pub fn lambda_path(cols: &[Vec<f64>], y: &[f64], k: usize) -> Vec<f64> {
    let n = y.len() as f64;
    let ymean = y.iter().sum::<f64>() / n;
    let mut lmax: f64 = 1e-3;
    for c in cols {
        let m = c.iter().sum::<f64>() / n;
        let sd = (c.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n).sqrt().max(1e-12);
        let dot: f64 =
            c.iter().zip(y).map(|(x, yv)| (x - m) / sd * (yv - ymean)).sum::<f64>() / n;
        lmax = lmax.max(dot.abs());
    }
    (0..k)
        .map(|i| lmax * (0.01f64).powf(i as f64 / (k as f64 - 1.0)))
        .collect()
}

/// glmnet(x, y, alpha = 1, lambda = NULL): the full-path fit.
fn glmnet_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "y", "alpha", "lambda", "nlambda"]);
    let (cols, y) = design(&b.req(0, "x")?, &b.req(1, "y")?)?;
    let alpha = b.opt(2).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(1.0);
    let nlambda =
        b.opt(4).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(20);
    let lambdas = match b.opt(3).filter(|v| !v.is_null()) {
        Some(v) => v.as_dbl_vec().map_err(Signal::error)?,
        None => lambda_path(&cols, &y, nlambda),
    };
    let (betas, icpts) = coord_descent_path(&cols, &y, &lambdas, alpha);
    let beta_lists: Vec<RVal> = betas.into_iter().map(RVal::dbl).collect();
    let mut out = RList::named(
        vec![RVal::dbl(lambdas), RVal::list(beta_lists), RVal::dbl(icpts)],
        vec!["lambda".into(), "beta".into(), "a0".into()],
    );
    out.class = Some("glmnet".into());
    Ok(RVal::List(out))
}

/// Internal per-fold worker: fit the path on train rows, return held-out
/// MSE per lambda. Registered as a builtin so it is available inside
/// worker processes without shipping code.
fn glmnet_fold_mse_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "y", "test_idx", "lambda", "alpha"]);
    let (cols, y) = design(&b.req(0, "x")?, &b.req(1, "y")?)?;
    let test_idx: Vec<usize> = b
        .req(2, "test_idx")?
        .as_dbl_vec()
        .map_err(Signal::error)?
        .into_iter()
        .map(|v| v as usize - 1)
        .collect();
    let lambdas = b.req(3, "lambda")?.as_dbl_vec().map_err(Signal::error)?;
    let alpha = b.opt(4).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(1.0);
    let test_set: std::collections::HashSet<usize> = test_idx.iter().copied().collect();
    let train: Vec<usize> = (0..y.len()).filter(|i| !test_set.contains(i)).collect();
    let tr_cols: Vec<Vec<f64>> =
        cols.iter().map(|c| train.iter().map(|&i| c[i]).collect()).collect();
    let tr_y: Vec<f64> = train.iter().map(|&i| y[i]).collect();
    let (betas, icpts) = coord_descent_path(&tr_cols, &tr_y, &lambdas, alpha);
    let mse: Vec<f64> = betas
        .iter()
        .zip(&icpts)
        .map(|(beta, icpt)| {
            let se: f64 = test_idx
                .iter()
                .map(|&i| {
                    let pred: f64 =
                        icpt + beta.iter().zip(&cols).map(|(b, c)| b * c[i]).sum::<f64>();
                    (y[i] - pred).powi(2)
                })
                .sum();
            se / test_idx.len() as f64
        })
        .collect();
    Ok(RVal::dbl(mse))
}

/// cv.glmnet(x, y, nfolds = 10, alpha = 1): k-fold CV over the lambda
/// path; the fold loop is the futurizable surface.
fn cv_glmnet_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, fopts) = split_futurize_opts(&args);
    let b = user.bind(&["x", "y", "nfolds", "alpha", "parallel", "nlambda"]);
    let x = b.req(0, "x")?;
    let yv = b.req(1, "y")?;
    let (cols, y) = design(&x, &yv)?;
    let nfolds =
        b.opt(2).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(10);
    let alpha = b.opt(3).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(1.0);
    let legacy_parallel =
        b.opt(4).map(|v| v.as_bool()).transpose().map_err(Signal::error)?.unwrap_or(false);
    let nlambda =
        b.opt(5).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(20);
    let lambdas = lambda_path(&cols, &y, nlambda);
    let n = y.len();
    // Deterministic interleaved folds (R uses sample(); we keep the fold
    // assignment reproducible without consuming the session RNG).
    let fold_of: Vec<usize> = (0..n).map(|i| i % nfolds).collect();
    let mut fold_tests: Vec<Vec<f64>> = vec![Vec::new(); nfolds];
    for (i, &f) in fold_of.iter().enumerate() {
        fold_tests[f].push((i + 1) as f64);
    }
    // Per-fold closure calling the native fold fitter (a builtin, so it
    // resolves inside worker processes).
    let src = "function(test_idx) .glmnet_fold_mse(x, y, test_idx, lambda, alpha)";
    let fenv = Env::child_of(env);
    define(&fenv, "x", x.clone());
    define(&fenv, "y", yv.clone());
    define(&fenv, "lambda", RVal::dbl(lambdas.clone()));
    define(&fenv, "alpha", RVal::scalar_dbl(alpha));
    let f = i.eval(&crate::rlite::parse_expr(src).map_err(Signal::error)?, &fenv)?;
    let items: Vec<RVal> = fold_tests.into_iter().map(RVal::dbl).collect();
    let per_fold: Vec<RVal> = if let Some(opts) = fopts {
        map_elements(i, env, items, &f, vec![], &opts.to_map_options(false))?
    } else if legacy_parallel {
        // glmnet's own parallel=TRUE path: requires an adapter; we route
        // through the current plan, mirroring doFuture registration.
        map_elements(
            i,
            env,
            items,
            &f,
            vec![],
            &crate::transpile::FuturizeOptions::default().to_map_options(false),
        )?
    } else {
        crate::apis::seq_map(i, env, &items, &f, &[])?
    };
    // Aggregate: mean and sd of MSE across folds per lambda.
    let k = lambdas.len();
    let mut cvm = vec![0.0; k];
    let mut cvsd = vec![0.0; k];
    let mut per: Vec<Vec<f64>> = Vec::with_capacity(per_fold.len());
    for r in &per_fold {
        per.push(r.as_dbl_vec().map_err(Signal::error)?);
    }
    for j in 0..k {
        let vals: Vec<f64> = per.iter().map(|f| f[j]).collect();
        let m = vals.iter().sum::<f64>() / vals.len() as f64;
        cvm[j] = m;
        cvsd[j] = (vals.iter().map(|v| (v - m).powi(2)).sum::<f64>()
            / (vals.len() as f64 - 1.0).max(1.0))
        .sqrt();
    }
    let best = cvm
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0);
    // lambda.1se: largest lambda with cvm within one SE of the minimum.
    let thresh = cvm[best] + cvsd[best];
    let lambda_1se = lambdas
        .iter()
        .zip(&cvm)
        .filter(|(_, &m)| m <= thresh)
        .map(|(l, _)| *l)
        .fold(f64::MIN, f64::max);
    let mut out = RList::named(
        vec![
            RVal::dbl(lambdas.clone()),
            RVal::dbl(cvm),
            RVal::dbl(cvsd),
            RVal::scalar_dbl(lambdas[best]),
            RVal::scalar_dbl(lambda_1se),
        ],
        vec![
            "lambda".into(),
            "cvm".into(),
            "cvsd".into(),
            "lambda.min".into(),
            "lambda.1se".into(),
        ],
    );
    out.class = Some("cv.glmnet".into());
    Ok(RVal::List(out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn lasso_recovers_sparse_signal() {
        // y = 2*x1 + 0*x2 + noise → beta2 shrinks to ~0 at moderate λ.
        let mut g = crate::rng::RngStream::from_seed(4);
        let n = 200;
        let x1: Vec<f64> = (0..n).map(|_| g.next_normal()).collect();
        let x2: Vec<f64> = (0..n).map(|_| g.next_normal()).collect();
        let y: Vec<f64> =
            x1.iter().zip(&x2).map(|(a, _)| 2.0 * a + 0.1 * g.next_normal()).collect();
        let (betas, _) =
            coord_descent_path(&[x1, x2], &y, &[0.1], 1.0);
        assert!((betas[0][0] - 2.0).abs() < 0.3, "beta1 {}", betas[0][0]);
        assert!(betas[0][1].abs() < 0.05, "beta2 {}", betas[0][1]);
    }

    #[test]
    fn path_is_monotone_in_sparsity() {
        let mut g = crate::rng::RngStream::from_seed(5);
        let n = 100;
        let cols: Vec<Vec<f64>> =
            (0..5).map(|_| (0..n).map(|_| g.next_normal()).collect()).collect();
        let y: Vec<f64> = (0..n).map(|i| cols[0][i] + 0.5 * cols[1][i]).collect();
        let lambdas = lambda_path(&cols, &y, 10);
        let (betas, _) = coord_descent_path(&cols, &y, &lambdas, 1.0);
        let nz_first = betas[0].iter().filter(|b| b.abs() > 1e-9).count();
        let nz_last = betas[9].iter().filter(|b| b.abs() > 1e-9).count();
        assert!(nz_first <= nz_last);
    }

    #[test]
    fn cv_glmnet_runs_and_orders_lambda() {
        let v = run(
            "set.seed(6)\nn <- 80\nx <- matrix(rnorm(n * 4), nrow = n, ncol = 4)\n\
             y <- rnorm(n)\ncv <- cv.glmnet(x, y, nfolds = 4, nlambda = 8)\nlength(cv$cvm)",
        );
        assert_eq!(v, RVal::scalar_int(8));
    }

    #[test]
    fn futurized_cv_matches_sequential() {
        let seq = run(
            "set.seed(7)\nn <- 60\nx <- matrix(rnorm(n * 3), nrow = n, ncol = 3)\ny <- rnorm(n)\n\
             cv <- cv.glmnet(x, y, nfolds = 3, nlambda = 6)\ncv$cvm",
        );
        let par = run(
            "plan(multicore, workers = 3)\nset.seed(7)\nn <- 60\nx <- matrix(rnorm(n * 3), nrow = n, ncol = 3)\ny <- rnorm(n)\n\
             cv <- cv.glmnet(x, y, nfolds = 3, nlambda = 6) |> futurize()\ncv$cvm",
        );
        assert_eq!(seq, par);
    }
}
