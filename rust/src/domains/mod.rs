//! Domain-specific packages (paper Table 2): boot, caret, glmnet, lme4,
//! mgcv, tm analogs, plus the datasets their examples use.
//!
//! Each function offers its package's *own* (awkward) parallel sub-API —
//! the `parallel`/`ncpus`/`cl`-style knobs the paper's §4.6 critiques —
//! and the `.futurize_opts` hook the transpiler injects, which routes the
//! hot loop through the future driver instead.

pub mod boot_pkg;
pub mod caret_pkg;
pub mod datasets;
pub mod formula;
pub mod glmnet_pkg;
pub mod lme4_pkg;
pub mod mgcv_pkg;
pub mod tm_pkg;

use crate::rlite::builtins::Reg;

pub fn register_builtins(r: &mut Reg) {
    formula::register(r);
    datasets::register(r);
    boot_pkg::register(r);
    glmnet_pkg::register(r);
    lme4_pkg::register(r);
    caret_pkg::register(r);
    mgcv_pkg::register(r);
    tm_pkg::register(r);
}

use crate::rlite::builtins::Args;
use crate::rlite::value::RVal;
use crate::transpile::{options_from_value, FuturizeOptions};

/// Split off the transpiler-injected `.futurize_opts` argument. Returns
/// (user args, Some(opts) if futurized).
pub(crate) fn split_futurize_opts(args: &Args) -> (Args, Option<FuturizeOptions>) {
    let mut user = Vec::new();
    let mut opts = None;
    for (name, v) in &args.items {
        if name.as_deref() == Some(".futurize_opts") {
            opts = Some(options_from_value(v));
        } else {
            user.push((name.clone(), v.clone()));
        }
    }
    (Args::new(user), opts)
}

/// Extract a data.frame column as f64s.
pub(crate) fn df_column(df: &RVal, name: &str) -> Result<Vec<f64>, String> {
    match df {
        RVal::List(l) => l
            .get(name)
            .ok_or_else(|| format!("no column '{name}'"))?
            .as_dbl_vec(),
        other => Err(format!("expected a data.frame, got {}", other.class())),
    }
}
