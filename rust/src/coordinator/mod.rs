//! The session coordinator: the embedding-facing API that examples,
//! tests, benches and the CLI use. Wraps an [`Interp`] with convenience
//! evaluation methods, timing, and access to the execution trace.

use crate::future_core::TraceEvent;
use crate::rlite::eval::{Interp, InterpConfig, Signal};
use crate::rlite::value::RVal;

/// Session construction options.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// `Sys.sleep()` scale factor (benches use e.g. 0.01).
    pub time_scale: f64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { time_scale: 1.0 }
    }
}

/// An interactive futurize session.
pub struct Session {
    pub interp: Interp,
}

impl Default for Session {
    fn default() -> Self {
        Self::new()
    }
}

impl Session {
    pub fn new() -> Self {
        Session { interp: Interp::new() }
    }

    pub fn with_config(cfg: SessionConfig) -> Self {
        Session {
            interp: Interp::with_config(InterpConfig {
                time_scale: cfg.time_scale,
                ..Default::default()
            }),
        }
    }

    /// Evaluate a program; the last expression's value is returned.
    pub fn eval_str(&mut self, src: &str) -> Result<RVal, String> {
        self.interp.eval_program(src).map_err(render_signal)
    }

    /// Evaluate, capturing stdout + relayed conditions as text.
    pub fn eval_captured(&mut self, src: &str) -> (Result<RVal, String>, String) {
        let exprs = match crate::rlite::parse_program(src) {
            Ok(e) => e,
            Err(e) => return (Err(e), String::new()),
        };
        let genv = self.interp.global.clone();
        let (r, out) = self.interp.capture_stdout(move |i| {
            let mut last = RVal::Null;
            for e in &exprs {
                match i.eval(e, &genv) {
                    Ok(v) => last = v,
                    Err(sig) => return Err(sig),
                }
            }
            Ok(last)
        });
        (r.map_err(render_signal), out)
    }

    /// Evaluate and time a program; returns (value, seconds).
    pub fn eval_timed(&mut self, src: &str) -> Result<(RVal, f64), String> {
        let t0 = std::time::Instant::now();
        let v = self.eval_str(src)?;
        Ok((v, t0.elapsed().as_secs_f64()))
    }

    /// The task→worker trace of the most recent futurized map call
    /// (regenerates the paper's Figure 1).
    pub fn last_trace(&self) -> &[TraceEvent] {
        &self.interp.session.last_trace
    }

    /// Render the last trace as an ASCII timeline (one row per worker).
    pub fn render_trace(&self) -> String {
        let trace = self.last_trace();
        if trace.is_empty() {
            return "(no trace)".into();
        }
        let t_end = trace.iter().map(|e| e.end).fold(0.0f64, f64::max).max(1e-9);
        let width = 60usize;
        let n_workers = trace.iter().map(|e| e.worker).max().unwrap_or(0) + 1;
        let mut rows = vec![vec![b'.'; width]; n_workers];
        for (k, ev) in trace.iter().enumerate() {
            let s = ((ev.start / t_end) * (width as f64 - 1.0)) as usize;
            let e = ((ev.end / t_end) * (width as f64 - 1.0)) as usize;
            let label = b'a' + (k % 26) as u8;
            for c in rows[ev.worker].iter_mut().take(e.min(width - 1) + 1).skip(s) {
                *c = label;
            }
        }
        let mut out = String::new();
        for (w, row) in rows.iter().enumerate() {
            out.push_str(&format!("worker {w}: "));
            out.push_str(std::str::from_utf8(row).unwrap());
            out.push('\n');
        }
        out.push_str(&format!("total: {:.3}s\n", t_end));
        out
    }
}

fn render_signal(sig: Signal) -> String {
    match sig {
        Signal::Error(c) => c.render(),
        other => format!("unexpected control signal: {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_quickstart() {
        let mut s = Session::new();
        s.eval_str("plan(multicore, workers = 2)").unwrap();
        let v = s.eval_str("unlist(lapply(1:4, function(x) x^2) |> futurize())").unwrap();
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 4.0, 9.0, 16.0]);
    }

    #[test]
    fn trace_is_recorded() {
        let mut s = Session::with_config(SessionConfig { time_scale: 0.001 });
        s.eval_str("plan(multicore, workers = 3)").unwrap();
        s.eval_str(
            "slow_fcn <- function(x) { Sys.sleep(1)\nx }\nys <- lapply(1:8, slow_fcn) |> futurize(scheduling = Inf)",
        )
        .unwrap();
        let trace = s.last_trace();
        assert_eq!(trace.len(), 8);
        let workers: std::collections::HashSet<usize> =
            trace.iter().map(|e| e.worker).collect();
        assert!(workers.len() >= 2, "tasks should spread over workers: {workers:?}");
        let rendered = s.render_trace();
        assert!(rendered.contains("worker 0"));
    }

    #[test]
    fn eval_captured_collects_output() {
        let mut s = Session::new();
        let (r, out) = s.eval_captured("cat(\"hello \")\nmessage(\"world\")\n1");
        assert!(r.is_ok());
        assert!(out.contains("hello"));
        assert!(out.contains("world"));
    }

    #[test]
    fn error_renders_r_style() {
        let mut s = Session::new();
        let err = s.eval_str("lapply(1:2, function(x) stop(\"bad\")) |> futurize()").unwrap_err();
        assert!(err.contains("bad"), "{err}");
    }
}
