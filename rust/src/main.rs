//! futurize-rs CLI: run rlite scripts with the futurize ecosystem, host
//! worker subprocesses, and print Table-1/2 support info.
//!
//! (Arguments are parsed by hand: the offline crate set has no clap.)

use futurize::backend::worker;
use futurize::coordinator::{Session, SessionConfig};

const USAGE: &str = "\
futurize-rs — unified, transpiling map-reduce parallelism (futurize reproduction)

USAGE:
    futurize-rs run <script.R> [--time-scale X] [--trace]
    futurize-rs eval <expr> [--time-scale X]
    futurize-rs lint <script.R>
    futurize-rs supported [package]
    futurize-rs doctor
    futurize-rs worker --connect <host:port>
";

fn truncate(s: &str, max: usize) -> String {
    if s.chars().count() <= max {
        s.to_string()
    } else {
        let head: String = s.chars().take(max.saturating_sub(3)).collect();
        format!("{head}...")
    }
}

fn main() {
    // Worker mode: the multisession backend re-executes this binary with
    // a sentinel argv[1]; never returns if so.
    worker::maybe_worker();

    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };

    let flag_f64 = |name: &str, default: f64| -> f64 {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let has_flag = |name: &str| args.iter().any(|a| a == name);

    match cmd.as_str() {
        "run" => {
            let Some(script) = args.get(1).filter(|a| !a.starts_with("--")) else {
                eprintln!("futurize-rs run: missing script path");
                std::process::exit(2);
            };
            let src = match std::fs::read_to_string(script) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("futurize-rs: cannot read {script}: {e}");
                    std::process::exit(2);
                }
            };
            let mut session = Session::with_config(SessionConfig {
                time_scale: flag_f64("--time-scale", 1.0),
            });
            match session.eval_str(&src) {
                Ok(v) => {
                    println!("{v}");
                    if has_flag("--trace") {
                        println!("{}", session.render_trace());
                    }
                }
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(1);
                }
            }
        }
        "eval" => {
            let Some(expr) = args.get(1) else {
                eprintln!("futurize-rs eval: missing expression");
                std::process::exit(2);
            };
            let mut session = Session::with_config(SessionConfig {
                time_scale: flag_f64("--time-scale", 1.0),
            });
            match session.eval_str(expr) {
                Ok(v) => println!("{v}"),
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(1);
                }
            }
        }
        "lint" => {
            let Some(script) = args.get(1).filter(|a| !a.starts_with("--")) else {
                eprintln!("futurize-rs lint: missing script path");
                std::process::exit(2);
            };
            let src = match std::fs::read_to_string(script) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("futurize-rs: cannot read {script}: {e}");
                    std::process::exit(2);
                }
            };
            let findings = match futurize::transpile::analysis::lint_source(&src) {
                Ok(f) => f,
                Err(e) => {
                    eprintln!("futurize-rs lint: parse error in {script}: {e}");
                    std::process::exit(2);
                }
            };
            if findings.is_empty() {
                println!("{script}: no findings");
                return;
            }
            let mut worst_is_actionable = false;
            for f in &findings {
                println!("{script} (statement {}): {}", f.stmt, truncate(&f.call, 72));
                print!("{}", futurize::rlite::diag::render_table(&f.diags));
                println!();
                worst_is_actionable |= f
                    .diags
                    .iter()
                    .any(|d| d.level >= futurize::rlite::diag::LintLevel::Warn);
            }
            if worst_is_actionable {
                std::process::exit(1);
            }
        }
        "supported" => match args.get(1) {
            Some(pkg) => {
                for f in futurize::transpile::supported_functions(pkg) {
                    println!("{f}");
                }
            }
            None => {
                for p in futurize::transpile::supported_packages() {
                    let n = futurize::transpile::supported_functions(p).len();
                    println!("{p} ({n} functions)");
                }
            }
        },
        "doctor" => {
            println!("futurize-rs {}", env!("CARGO_PKG_VERSION"));
            println!(
                "cores: {}",
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            );
            println!("pjrt artifacts: {}", futurize::runtime::pjrt_available());
            println!(
                "worker binary: {}",
                worker::worker_binary().map(|p| p.display().to_string()).unwrap_or_default()
            );
            let mut s = Session::new();
            let v = s
                .eval_str(
                    "plan(multisession, workers = 2)\nunlist(lapply(1:4, function(x) x * 2) |> futurize())",
                )
                .unwrap_or_else(|e| panic!("self-test failed: {e}"));
            println!("multisession self-test: {v}");
        }
        // Unreachable in practice — `maybe_worker()` above consumes
        // every `worker` invocation (valid or not) and exits. Kept as a
        // safety net so a refactor of that guard degrades to a usage
        // error instead of "unknown command".
        "worker" => {
            eprintln!("futurize-rs worker: expected --connect <host:port>");
            std::process::exit(2);
        }
        other => {
            eprintln!("futurize-rs: unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
}
