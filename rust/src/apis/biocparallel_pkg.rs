//! BiocParallel (paper Table 1, §4.5): Bioconductor's parallel-evaluation
//! core. The futurize transpiler routes these through `BPPARAM =
//! FutureParam(...)`, letting Bioconductor workflows use every future
//! backend.

use super::{as_function, simplify_to};
use crate::future_core::driver::map_elements;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};
use crate::transpile::{options_from_value, FuturizeOptions};

pub fn register(r: &mut Reg) {
    r.normal("BiocParallel", "bplapply", bplapply_fn);
    r.normal("BiocParallel", "bpmapply", bpmapply_fn);
    r.normal("BiocParallel", "bpvec", bpvec_fn);
    r.normal("BiocParallel", "bpiterate", bpiterate_fn);
    r.normal("BiocParallel", "bpaggregate", bpaggregate_fn);
    r.normal("BiocParallel", "FutureParam", future_param_fn);
    r.normal("BiocParallel", "SerialParam", serial_param_fn);
}

/// FutureParam(seed = , chunk.size = ): the future-backed BPPARAM.
fn future_param_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut l = RList::default();
    for (name, v) in &args.items {
        if let Some(n) = name {
            l.set(n, v.clone());
        }
    }
    l.class = Some("FutureParam".into());
    Ok(RVal::List(l))
}

fn serial_param_fn(_i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let mut v = future_param_fn(_i, args, env)?;
    if let RVal::List(l) = &mut v {
        l.class = Some("SerialParam".into());
    }
    Ok(v)
}

/// Split off BPPARAM; a FutureParam turns on the parallel path.
fn split_bpparam(args: &Args) -> (Args, bool, FuturizeOptions) {
    let mut user = Vec::new();
    let mut parallel = false;
    let mut opts = FuturizeOptions::default();
    for (name, v) in &args.items {
        if name.as_deref() == Some("BPPARAM") {
            if let RVal::List(l) = v {
                if l.class.as_deref() == Some("FutureParam") {
                    parallel = true;
                    opts = options_from_value(v);
                }
            }
        } else {
            user.push((name.clone(), v.clone()));
        }
    }
    (Args::new(user), parallel, opts)
}

fn bplapply_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (args, parallel, opts) = split_bpparam(&args);
    let b = args.bind(&["X", "FUN"]);
    let x = b.req(0, "X")?;
    let f = as_function(&b.req(1, "FUN")?, env)?;
    let results = if parallel {
        map_elements(i, env, x.iter_elements(), &f, b.rest, &opts.to_map_options(false))?
    } else {
        super::seq_map(i, env, &x.iter_elements(), &f, &b.rest)?
    };
    simplify_to(results, x.element_names(), "list")
}

fn bpmapply_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (args, parallel, opts) = split_bpparam(&args);
    let b = args.bind(&["FUN"]);
    let f = as_function(&b.req(0, "FUN")?, env)?;
    let seqs: Vec<Vec<RVal>> = b
        .rest
        .iter()
        .filter(|(n, _)| n.as_deref() != Some("MoreArgs") && n.as_deref() != Some("SIMPLIFY"))
        .map(|(_, v)| v.iter_elements())
        .collect();
    let n = seqs.iter().map(|s| s.len()).max().unwrap_or(0);
    let items: Vec<RVal> = (0..n)
        .map(|k| RVal::list(seqs.iter().map(|s| s[k % s.len()].clone()).collect()))
        .collect();
    let results = if parallel {
        super::future_apply::map_tuple(i, env, items, &f, &[], &opts, seqs.len())?
    } else {
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            let RVal::List(l) = item else { unreachable!() };
            let call_args: Vec<(Option<String>, RVal)> =
                l.vals.into_iter().map(|v| (None, v)).collect();
            out.push(i.call_function(&f, call_args, env)?);
        }
        out
    };
    simplify_to(results, None, "auto")
}

/// bpvec(X, FUN): FUN receives whole *subvectors* (not elements) and the
/// results are concatenated — BiocParallel's vectorized form.
fn bpvec_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (args, parallel, opts) = split_bpparam(&args);
    let b = args.bind(&["X", "FUN"]);
    let x = b.req(0, "X")?;
    let f = as_function(&b.req(1, "FUN")?, env)?;
    let xs = x.as_dbl_vec().map_err(Signal::error)?;
    let workers = if parallel { i.session.workers().max(1) } else { 1 };
    let chunks = crate::scheduling::make_chunks(
        xs.len(),
        workers,
        &opts.to_map_options(false).policy,
    );
    let items: Vec<RVal> =
        chunks.iter().map(|&(s, e)| RVal::dbl(xs[s..e].to_vec())).collect();
    let results = if parallel {
        map_elements(i, env, items, &f, b.rest, &opts.to_map_options(false))?
    } else {
        super::seq_map(i, env, &items, &f, &b.rest)?
    };
    let mut out = Vec::with_capacity(xs.len());
    for r in results {
        out.extend(r.as_dbl_vec().map_err(Signal::error)?);
    }
    Ok(RVal::dbl(out))
}

/// bpiterate(ITER, FUN): pull items from a generator closure until NULL.
fn bpiterate_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (args, parallel, opts) = split_bpparam(&args);
    let b = args.bind(&["ITER", "FUN"]);
    let iter = as_function(&b.req(0, "ITER")?, env)?;
    let f = as_function(&b.req(1, "FUN")?, env)?;
    // Drain the iterator sequentially (it is stateful), then map.
    let mut items = Vec::new();
    loop {
        let v = i.call_function(&iter, vec![], env)?;
        if v.is_null() {
            break;
        }
        items.push(v);
        if items.len() > 1_000_000 {
            return Err(Signal::error("bpiterate: iterator never returned NULL"));
        }
    }
    let results = if parallel {
        map_elements(i, env, items, &f, b.rest, &opts.to_map_options(false))?
    } else {
        super::seq_map(i, env, &items, &f, &b.rest)?
    };
    simplify_to(results, None, "list")
}

/// bpaggregate(x, by, FUN): group x by `by` then apply FUN per group.
fn bpaggregate_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (args, parallel, opts) = split_bpparam(&args);
    let b = args.bind(&["x", "by", "FUN"]);
    let x = b.req(0, "x")?;
    let by = b.req(1, "by")?.as_str_vec().map_err(Signal::error)?;
    let f = as_function(&b.req(2, "FUN")?, env)?;
    let (groups, items) = super::base_r::group_by(&x, &by)?;
    let results = if parallel {
        map_elements(i, env, items, &f, b.rest, &opts.to_map_options(false))?
    } else {
        super::seq_map(i, env, &items, &f, &b.rest)?
    };
    simplify_to(results, Some(groups), "auto")
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn bplapply_sequential_default() {
        let v = run("r <- bplapply(1:3, function(x) x + 1)\nunlist(r)");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn bplapply_with_futureparam_parallel() {
        let seq = run("bplapply(1:8, function(x) x^2)");
        let par = run(
            "plan(multicore, workers = 3)\nbplapply(1:8, function(x) x^2, BPPARAM = BiocParallel::FutureParam())",
        );
        assert_eq!(seq, par);
    }

    #[test]
    fn bpvec_concatenates_chunks() {
        let v = run(
            "plan(multicore, workers = 2)\nbpvec(1:10, function(chunk) chunk * 2, BPPARAM = BiocParallel::FutureParam())",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), (1..=10).map(|x| (x * 2) as f64).collect::<Vec<_>>());
    }

    #[test]
    fn bpiterate_drains_generator() {
        let v = run(
            "i <- 0\nmk <- function() { i <<- 0\nfunction() NULL }\n\
             count <- 3\nnext_val <- function() { if (count == 0) return(NULL)\ncount <<- count - 1\ncount + 1 }\n\
             r <- bpiterate(next_val, function(x) x * 10)\nunlist(r)",
        );
        // Generator yields 3, 2, 1.
        assert_eq!(v.as_dbl_vec().unwrap(), vec![30.0, 20.0, 10.0]);
    }

    #[test]
    fn bpaggregate_groups() {
        let v = run(
            "bpaggregate(c(1, 2, 3, 4), c(\"a\", \"a\", \"b\", \"b\"), sum)",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), vec![3.0, 7.0]);
    }
}
