//! furrr — the future-based purrr mirrors (`future_map()` etc.), the
//! transpile targets for Table 1 row "purrr". Options arrive as
//! `.options = furrr_options(...)`, furrr's own convention.

use super::purrr_pkg::{Arity, VARIANTS};
use super::{as_function, map_maybe_reduced, simplify_to, static_name};
use crate::future_core::driver::{map_elements, MapRun};
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::RVal;
use crate::transpile::{apply_option_pairs, options_from_value, FuturizeOptions};

pub fn register(r: &mut Reg) {
    for &(name, arity, want) in VARIANTS {
        let fname = static_name(format!("future_{name}"));
        r.normal("furrr", fname, move |i, a, e| future_map_variant(i, a, e, arity, want));
    }
    r.normal("furrr", "future_walk", |i, a, e| {
        let b = a.bind(&[".x"]);
        let x = b.req(0, ".x")?;
        future_map_variant(i, a, e, Arity::Map1, "list")?;
        Ok(x)
    });
    r.normal("furrr", "future_modify", |i, a, e| future_map_variant(i, a, e, Arity::Map1, "auto"));
    // The remaining purrr helpers (predicate/index variants) reuse the
    // sequential predicate pass + parallel transform.
    for name in ["future_modify_if", "future_map_if"] {
        r.normal("furrr", name, future_modify_if_fn);
    }
    for name in ["future_modify_at", "future_map_at"] {
        r.normal("furrr", name, future_modify_at_fn);
    }
    r.normal("furrr", "future_invoke_map", future_invoke_map_fn);
}

/// Split off `.options` (a furrr_options object) from the arguments.
/// The transpiler's reduction markers ride as `future.*` named
/// arguments even on furrr targets; they merge on top of `.options`.
fn split_options(args: &Args) -> (Vec<(Option<String>, RVal)>, FuturizeOptions) {
    let mut user = Vec::new();
    let mut opts = FuturizeOptions::default();
    let mut markers: Vec<(String, RVal)> = Vec::new();
    for (name, v) in &args.items {
        match name.as_deref() {
            Some(".options") => opts = options_from_value(v),
            Some(n) if n.starts_with("future.") => markers.push((n.to_string(), v.clone())),
            _ => user.push((name.clone(), v.clone())),
        }
    }
    apply_option_pairs(&mut opts, &markers);
    (user, opts)
}

fn future_map_variant(
    i: &mut Interp,
    args: Args,
    env: &EnvRef,
    arity: Arity,
    want: &str,
) -> EvalResult {
    let (user, opts) = split_options(&args);
    let args = Args::new(user);
    match arity {
        Arity::Map1 => {
            let b = args.bind(&[".x", ".f"]);
            let x = b.req(0, ".x")?;
            let f = as_function(&b.req(1, ".f")?, env)?;
            match map_maybe_reduced(i, env, x.iter_elements(), &f, b.rest, &opts, want)? {
                MapRun::Reduced(v) => Ok(v),
                MapRun::Values(results) => simplify_to(results, x.element_names(), want),
            }
        }
        Arity::Map2 => {
            let b = args.bind(&[".x", ".y", ".f"]);
            let x = b.req(0, ".x")?;
            let y = b.req(1, ".y")?;
            let f = as_function(&b.req(2, ".f")?, env)?;
            let xs = x.iter_elements();
            let ys = y.iter_elements();
            let n = xs.len().max(ys.len());
            let items: Vec<RVal> = (0..n)
                .map(|k| RVal::list(vec![xs[k % xs.len()].clone(), ys[k % ys.len()].clone()]))
                .collect();
            let results = super::future_apply::map_tuple(i, env, items, &f, &b.rest, &opts, 2)?;
            simplify_to(results, x.element_names(), want)
        }
        Arity::PMap => {
            let b = args.bind(&[".l", ".f"]);
            let l = match b.req(0, ".l")? {
                RVal::List(l) => l,
                other => {
                    return Err(Signal::error(format!(
                        "future_pmap: .l must be a list, got {}",
                        other.class()
                    )))
                }
            };
            let f = as_function(&b.req(1, ".f")?, env)?;
            let seqs: Vec<Vec<RVal>> = l.vals.iter().map(|v| v.iter_elements()).collect();
            let n = seqs.iter().map(|s| s.len()).max().unwrap_or(0);
            let items: Vec<RVal> = (0..n)
                .map(|k| RVal::list(seqs.iter().map(|s| s[k % s.len()].clone()).collect()))
                .collect();
            let results =
                super::future_apply::map_tuple(i, env, items, &f, &b.rest, &opts, seqs.len())?;
            simplify_to(results, None, want)
        }
        Arity::IMap => {
            let b = args.bind(&[".x", ".f"]);
            let x = b.req(0, ".x")?;
            let f = as_function(&b.req(1, ".f")?, env)?;
            let elems = x.iter_elements();
            let names = x.element_names();
            let items: Vec<RVal> = elems
                .iter()
                .enumerate()
                .map(|(k, e)| {
                    let tag = match &names {
                        Some(ns) if !ns[k].is_empty() => RVal::scalar_str(ns[k].clone()),
                        _ => RVal::scalar_int((k + 1) as i64),
                    };
                    RVal::list(vec![e.clone(), tag])
                })
                .collect();
            let results = super::future_apply::map_tuple(i, env, items, &f, &b.rest, &opts, 2)?;
            simplify_to(results, names, want)
        }
    }
}

fn future_modify_if_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_options(&args);
    let args2 = Args::new(user);
    let b = args2.bind(&[".x", ".p", ".f"]);
    let x = b.req(0, ".x")?;
    let p = as_function(&b.req(1, ".p")?, env)?;
    let f = as_function(&b.req(2, ".f")?, env)?;
    let elems = x.iter_elements();
    // Predicate sequentially (cheap), transform in parallel (hot).
    let mut selected = Vec::new();
    let mut mask = Vec::with_capacity(elems.len());
    for e in &elems {
        let hit =
            i.call_function(&p, vec![(None, e.clone())], env)?.as_bool().map_err(Signal::error)?;
        mask.push(hit);
        if hit {
            selected.push(e.clone());
        }
    }
    let transformed = map_elements(i, env, selected, &f, vec![], &opts.to_map_options(false))?;
    let mut ti = transformed.into_iter();
    let out: Vec<RVal> = elems
        .into_iter()
        .zip(&mask)
        .map(|(e, &hit)| if hit { ti.next().unwrap() } else { e })
        .collect();
    let mut l = crate::rlite::value::RList::plain(out);
    l.names = x.element_names();
    Ok(RVal::List(l))
}

fn future_modify_at_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_options(&args);
    let args2 = Args::new(user);
    let b = args2.bind(&[".x", ".at", ".f"]);
    let x = b.req(0, ".x")?;
    let at = b.req(1, ".at")?;
    let f = as_function(&b.req(2, ".f")?, env)?;
    let n = x.len();
    let mut mask = vec![false; n];
    match &at {
        RVal::Chr(keys) => {
            if let Some(names) = x.names() {
                for (k, nm) in names.iter().enumerate() {
                    if keys.vals.contains(nm) {
                        mask[k] = true;
                    }
                }
            }
        }
        other => {
            for idx in other.as_dbl_vec().map_err(Signal::error)? {
                let k = idx as usize;
                if k >= 1 && k <= n {
                    mask[k - 1] = true;
                }
            }
        }
    }
    let elems = x.iter_elements();
    let selected: Vec<RVal> =
        elems.iter().zip(&mask).filter(|(_, &m)| m).map(|(e, _)| e.clone()).collect();
    let transformed = map_elements(i, env, selected, &f, vec![], &opts.to_map_options(false))?;
    let mut ti = transformed.into_iter();
    let out: Vec<RVal> = elems
        .into_iter()
        .zip(&mask)
        .map(|(e, &hit)| if hit { ti.next().unwrap() } else { e })
        .collect();
    let mut l = crate::rlite::value::RList::plain(out);
    l.names = x.element_names();
    Ok(RVal::List(l))
}

fn future_invoke_map_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_options(&args);
    let args2 = Args::new(user);
    let b = args2.bind(&[".f", ".x"]);
    let fs = b.req(0, ".f")?.iter_elements();
    let xs = match b.opt(1) {
        Some(RVal::List(l)) => l.vals,
        _ => vec![RVal::Null; fs.len()],
    };
    let items: Vec<RVal> = fs
        .iter()
        .enumerate()
        .map(|(k, f)| {
            RVal::list(vec![f.clone(), xs.get(k % xs.len().max(1)).cloned().unwrap_or(RVal::Null)])
        })
        .collect();
    let shim_src = "function(pair) { f <- pair[[1]]\nargs <- pair[[2]]\nif (is.null(args)) f() else do.call(f, as.list(args)) }";
    let shim = i.eval(&crate::rlite::parse_expr(shim_src).map_err(Signal::error)?, env)?;
    let results = map_elements(i, env, items, &shim, vec![], &opts.to_map_options(false))?;
    simplify_to(results, None, "list")
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn future_map_matches_map() {
        let seq = run("map(1:8, function(x) x^2)");
        let par = run("plan(multicore, workers = 3)\nfurrr::future_map(1:8, function(x) x^2)");
        assert_eq!(seq, par);
    }

    #[test]
    fn future_map_dbl_with_options() {
        let v = run(
            "plan(multicore, workers = 2)\nfurrr::future_map_dbl(1:4, function(x) x + 0.5, .options = furrr_options(chunk_size = 1))",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.5, 2.5, 3.5, 4.5]);
    }

    #[test]
    fn future_map2_zips() {
        let v = run(
            "plan(multicore, workers = 2)\nfurrr::future_map2_dbl(1:3, 4:6, function(a, b) a * b)",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), vec![4.0, 10.0, 18.0]);
    }

    #[test]
    fn future_pmap() {
        let v = run(
            "plan(multicore, workers = 2)\nfurrr::future_pmap_dbl(list(1:2, 3:4), function(a, b) a + b)",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), vec![4.0, 6.0]);
    }

    #[test]
    fn future_imap_uses_names() {
        let v = run(
            "plan(multicore, workers = 2)\nfurrr::future_imap_chr(c(a = 1, b = 2), function(x, nm) paste0(nm, \"=\", x))",
        );
        assert_eq!(v.as_str_vec().unwrap(), vec!["a=1".to_string(), "b=2".to_string()]);
    }

    #[test]
    fn future_map_seeded_reproducible() {
        let a = run(
            "plan(multicore, workers = 3)\nfutureSeed(5)\nfurrr::future_map_dbl(1:6, function(x) rnorm(1), .options = furrr_options(seed = TRUE))",
        );
        let b = run(
            "plan(multicore, workers = 2)\nfutureSeed(5)\nfurrr::future_map_dbl(1:6, function(x) rnorm(1), .options = furrr_options(seed = TRUE))",
        );
        assert_eq!(a, b);
    }
}
