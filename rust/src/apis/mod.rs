//! The map-reduce API families of the paper's Table 1, each in both its
//! sequential form (what users write) and its future-based form (what
//! the transpiler targets).
//!
//! | family        | sequential module     | parallel module / mechanism      |
//! |---------------|-----------------------|----------------------------------|
//! | base, stats   | [`base_r`]            | [`future_apply`] (`future_*`)    |
//! | purrr         | [`purrr_pkg`]         | [`furrr_pkg`] (`future_map*`)    |
//! | crossmap      | [`crossmap_pkg`]      | same module (`future_x*`)        |
//! | foreach       | [`foreach_pkg`] `%do%`| `%dofuture%` (doFuture)          |
//! | plyr          | [`plyr_pkg`]          | `.parallel = TRUE` path          |
//! | BiocParallel  | [`biocparallel_pkg`]  | `BPPARAM = FutureParam()` path   |

pub mod base_r;
pub mod biocparallel_pkg;
pub mod crossmap_pkg;
pub mod foreach_pkg;
pub mod furrr_pkg;
pub mod future_apply;
pub mod plyr_pkg;
pub mod purrr_pkg;

use crate::rlite::builtins::Reg;
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::RVal;

pub fn register_builtins(r: &mut Reg) {
    base_r::register(r);
    future_apply::register(r);
    purrr_pkg::register(r);
    furrr_pkg::register(r);
    crossmap_pkg::register(r);
    foreach_pkg::register(r);
    plyr_pkg::register(r);
    biocparallel_pkg::register(r);
}

/// Leak a generated function name into a `'static` registry key.
pub(crate) fn static_name(s: String) -> &'static str {
    Box::leak(s.into_boxed_str())
}

/// Sequential element-wise application: `f(item, extra...)` inline in the
/// current session (side effects and conditions propagate immediately, as
/// in plain `lapply`).
pub(crate) fn seq_map(
    i: &mut Interp,
    env: &EnvRef,
    items: &[RVal],
    f: &RVal,
    extra: &[(Option<String>, RVal)],
) -> Result<Vec<RVal>, Signal> {
    let mut out = Vec::with_capacity(items.len());
    for item in items {
        let mut args = vec![(None, item.clone())];
        args.extend(extra.iter().cloned());
        out.push(i.call_function(f, args, env)?);
    }
    Ok(out)
}

/// Resolve a function argument (closure, builtin, or name) — `match.fun`.
pub(crate) fn as_function(v: &RVal, env: &EnvRef) -> Result<RVal, Signal> {
    match v {
        RVal::Chr(_) => {
            let name = v.as_str().map_err(Signal::error)?;
            crate::rlite::env::lookup(env, &name)
                .or_else(|| {
                    crate::rlite::builtins::lookup_builtin(&name).map(|d| RVal::Builtin(d.id))
                })
                .ok_or_else(|| Signal::error(format!("could not find function \"{name}\"")))
        }
        other if other.is_function() => Ok(other.clone()),
        other => Err(Signal::error(format!("not a function: {}", other.class()))),
    }
}

/// Typed simplification used by `sapply`-style and `map_dbl`-style
/// functions. `want` is one of "list", "dbl", "int", "chr", "lgl",
/// "auto".
pub(crate) fn simplify_to(
    results: Vec<RVal>,
    names: Option<Vec<String>>,
    want: &str,
) -> EvalResult {
    match want {
        "list" => {
            let mut l = crate::rlite::value::RList::plain(results);
            l.names = names;
            Ok(RVal::List(l))
        }
        "auto" => Ok(RVal::simplify(results, names)),
        "dbl" | "int" => {
            let mut vals = Vec::with_capacity(results.len());
            for r in &results {
                if r.len() != 1 {
                    return Err(Signal::error(format!(
                        "Result must be length 1, not {}",
                        r.len()
                    )));
                }
                vals.push(r.as_f64().map_err(Signal::error)?);
            }
            if want == "int" {
                Ok(RVal::Int(crate::rlite::value::RVec::with_names(
                    vals.into_iter().map(|x| x as i64).collect(),
                    names,
                )))
            } else {
                Ok(RVal::Dbl(crate::rlite::value::RVec::with_names(vals, names)))
            }
        }
        "chr" => {
            let mut vals = Vec::with_capacity(results.len());
            for r in &results {
                if r.len() != 1 {
                    return Err(Signal::error("Result must be length 1"));
                }
                vals.push(r.as_str_vec().map_err(Signal::error)?.remove(0));
            }
            Ok(RVal::Chr(crate::rlite::value::RVec::with_names(vals, names)))
        }
        "lgl" => {
            let mut vals = Vec::with_capacity(results.len());
            for r in &results {
                if r.len() != 1 {
                    return Err(Signal::error("Result must be length 1"));
                }
                vals.push(r.as_bool().map_err(Signal::error)?);
            }
            Ok(RVal::Lgl(crate::rlite::value::RVec::with_names(vals, names)))
        }
        other => Err(Signal::error(format!("unknown simplification '{other}'"))),
    }
}
