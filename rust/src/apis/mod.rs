//! The map-reduce API families of the paper's Table 1, each in both its
//! sequential form (what users write) and its future-based form (what
//! the transpiler targets).
//!
//! | family        | sequential module     | parallel module / mechanism      |
//! |---------------|-----------------------|----------------------------------|
//! | base, stats   | [`base_r`]            | [`future_apply`] (`future_*`)    |
//! | purrr         | [`purrr_pkg`]         | [`furrr_pkg`] (`future_map*`)    |
//! | crossmap      | [`crossmap_pkg`]      | same module (`future_x*`)        |
//! | foreach       | [`foreach_pkg`] `%do%`| `%dofuture%` (doFuture)          |
//! | plyr          | [`plyr_pkg`]          | `.parallel = TRUE` path          |
//! | BiocParallel  | [`biocparallel_pkg`]  | `BPPARAM = FutureParam()` path   |

pub mod base_r;
pub mod biocparallel_pkg;
pub mod crossmap_pkg;
pub mod foreach_pkg;
pub mod furrr_pkg;
pub mod future_apply;
pub mod plyr_pkg;
pub mod purrr_pkg;

use crate::future_core::driver::MapRun;
use crate::rlite::builtins::Reg;
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::RVal;

pub fn register_builtins(r: &mut Reg) {
    base_r::register(r);
    future_apply::register(r);
    purrr_pkg::register(r);
    furrr_pkg::register(r);
    crossmap_pkg::register(r);
    foreach_pkg::register(r);
    plyr_pkg::register(r);
    biocparallel_pkg::register(r);
}

/// Leak a generated function name into a `'static` registry key.
pub(crate) fn static_name(s: String) -> &'static str {
    Box::leak(s.into_boxed_str())
}

/// Sequential element-wise application: `f(item, extra...)` inline in the
/// current session (side effects and conditions propagate immediately, as
/// in plain `lapply`).
pub(crate) fn seq_map(
    i: &mut Interp,
    env: &EnvRef,
    items: &[RVal],
    f: &RVal,
    extra: &[(Option<String>, RVal)],
) -> Result<Vec<RVal>, Signal> {
    let mut out = Vec::with_capacity(items.len());
    for item in items {
        let mut args = vec![(None, item.clone())];
        args.extend(extra.iter().cloned());
        out.push(i.call_function(f, args, env)?);
    }
    Ok(out)
}

/// `map_elements` with the transpiler's fused-reduction markers
/// honored: when `opts` carries a recognized reduction and the kept
/// outer call's symbol still resolves to the genuine builtin, workers
/// fold their slices and the merged aggregate comes back packaged for
/// that outer call — wrapped in a length-1 list for the `Reduce(f, ...)`
/// form (whose fold over one element is the identity), or as a dummy
/// vector of the exact result length for `length()`. `want` is the
/// caller's simplification mode; only `"auto"` (sapply-style) applies
/// the column-flattening rule the `length()` merge state replays.
pub(crate) fn map_maybe_reduced(
    i: &mut Interp,
    env: &EnvRef,
    items: Vec<RVal>,
    f: &RVal,
    extra: Vec<(Option<String>, RVal)>,
    opts: &crate::transpile::FuturizeOptions,
    want: &str,
) -> Result<MapRun, Signal> {
    use crate::transpile::reduce::{self, ReduceOp};
    let n_items = items.len();
    let mut mopts = opts.to_map_options(false);
    if mopts.reduce.is_some_and(|spec| reduce::shadowed(env, &spec)) {
        let op = mopts.reduce.map(|spec| spec.plan.op.source_name()).unwrap_or("reduce");
        reduce::note_plan_rejected_shadowed();
        mopts.lint.reduce_rejected = Some(format!(
            "'{op}' is shadowed by a user binding in the calling environment"
        ));
        mopts.reduce = None;
    }
    let run = crate::future_core::driver::map_elements_run(i, env, items, f, extra, &mopts)?;
    let Some(spec) = mopts.reduce else { return Ok(run) };
    Ok(match run {
        MapRun::Reduced(v) if spec.wrap => MapRun::Reduced(RVal::list(vec![v])),
        MapRun::Reduced(_) if spec.plan.op == ReduceOp::Count && want != "auto" => {
            // Non-simplifying targets (lapply/map/map_dbl): the length
            // is always the element count; the merge-state dummy
            // replays sapply's simplify rule instead.
            MapRun::Reduced(RVal::Int(crate::rlite::value::RVec::plain(vec![0; n_items])))
        }
        other => other,
    })
}

/// Resolve a function argument (closure, builtin, or name) — `match.fun`.
pub(crate) fn as_function(v: &RVal, env: &EnvRef) -> Result<RVal, Signal> {
    match v {
        RVal::Chr(_) => {
            let name = v.as_str().map_err(Signal::error)?;
            crate::rlite::env::lookup(env, &name)
                .or_else(|| {
                    crate::rlite::builtins::lookup_builtin(&name).map(|d| RVal::Builtin(d.id))
                })
                .ok_or_else(|| Signal::error(format!("could not find function \"{name}\"")))
        }
        other if other.is_function() => Ok(other.clone()),
        other => Err(Signal::error(format!("not a function: {}", other.class()))),
    }
}

/// Typed simplification used by `sapply`-style and `map_dbl`-style
/// functions. `want` is one of "list", "dbl", "int", "chr", "lgl",
/// "auto".
pub(crate) fn simplify_to(
    results: Vec<RVal>,
    names: Option<Vec<String>>,
    want: &str,
) -> EvalResult {
    match want {
        "list" => {
            let mut l = crate::rlite::value::RList::plain(results);
            l.names = names;
            Ok(RVal::List(l))
        }
        "auto" => Ok(RVal::simplify(results, names)),
        "dbl" | "int" => {
            let mut vals = Vec::with_capacity(results.len());
            for r in &results {
                if r.len() != 1 {
                    return Err(Signal::error(format!(
                        "Result must be length 1, not {}",
                        r.len()
                    )));
                }
                vals.push(r.as_f64().map_err(Signal::error)?);
            }
            if want == "int" {
                Ok(RVal::Int(crate::rlite::value::RVec::with_names(
                    vals.into_iter().map(|x| x as i64).collect(),
                    names,
                )))
            } else {
                Ok(RVal::Dbl(crate::rlite::value::RVec::with_names(vals, names)))
            }
        }
        "chr" => {
            let mut vals = Vec::with_capacity(results.len());
            for r in &results {
                if r.len() != 1 {
                    return Err(Signal::error("Result must be length 1"));
                }
                vals.push(r.as_str_vec().map_err(Signal::error)?.remove(0));
            }
            Ok(RVal::Chr(crate::rlite::value::RVec::with_names(vals, names)))
        }
        "lgl" => {
            let mut vals = Vec::with_capacity(results.len());
            for r in &results {
                if r.len() != 1 {
                    return Err(Signal::error("Result must be length 1"));
                }
                vals.push(r.as_bool().map_err(Signal::error)?);
            }
            Ok(RVal::Lgl(crate::rlite::value::RVec::with_names(vals, names)))
        }
        other => Err(Signal::error(format!("unknown simplification '{other}'"))),
    }
}
