//! Sequential purrr family (paper Table 1 row "purrr").
//!
//! All `map*` variants share one template parameterized by input arity
//! (map / map2 / pmap / imap) and output shape (list / dbl / int / chr /
//! lgl / same-as-input). `.f` may be a function or (as in purrr) a
//! character name.

use super::{as_function, seq_map, simplify_to};
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};

#[derive(Clone, Copy, PartialEq)]
pub(crate) enum Arity {
    Map1,
    Map2,
    PMap,
    IMap,
}

pub(crate) const VARIANTS: &[(&str, Arity, &str)] = &[
    ("map", Arity::Map1, "list"),
    ("map_dbl", Arity::Map1, "dbl"),
    ("map_int", Arity::Map1, "int"),
    ("map_chr", Arity::Map1, "chr"),
    ("map_lgl", Arity::Map1, "lgl"),
    ("map2", Arity::Map2, "list"),
    ("map2_dbl", Arity::Map2, "dbl"),
    ("map2_int", Arity::Map2, "int"),
    ("map2_chr", Arity::Map2, "chr"),
    ("map2_lgl", Arity::Map2, "lgl"),
    ("pmap", Arity::PMap, "list"),
    ("pmap_dbl", Arity::PMap, "dbl"),
    ("pmap_chr", Arity::PMap, "chr"),
    ("imap", Arity::IMap, "list"),
    ("imap_dbl", Arity::IMap, "dbl"),
    ("imap_chr", Arity::IMap, "chr"),
];

pub fn register(r: &mut Reg) {
    for &(name, arity, want) in VARIANTS {
        r.normal("purrr", name, move |i, a, e| map_variant(i, a, e, arity, want, false));
    }
    r.normal("purrr", "walk", |i, a, e| {
        let b = a.bind(&[".x"]);
        let x = b.req(0, ".x")?;
        map_variant(i, a, e, Arity::Map1, "list", false)?;
        Ok(x) // walk returns .x invisibly
    });
    r.normal("purrr", "modify", |i, a, e| map_variant(i, a, e, Arity::Map1, "auto", false));
    r.normal("purrr", "modify_if", modify_if_fn);
    r.normal("purrr", "modify_at", modify_at_fn);
    r.normal("purrr", "map_if", map_if_fn);
    r.normal("purrr", "map_at", map_at_fn);
    r.normal("purrr", "invoke_map", invoke_map_fn);
}

pub(crate) fn map_variant(
    i: &mut Interp,
    args: Args,
    env: &EnvRef,
    arity: Arity,
    want: &str,
    _parallel_marker: bool,
) -> EvalResult {
    match arity {
        Arity::Map1 => {
            let b = args.bind(&[".x", ".f"]);
            let x = b.req(0, ".x")?;
            let f = as_function(&b.req(1, ".f")?, env)?;
            let results = seq_map(i, env, &x.iter_elements(), &f, &b.rest)?;
            simplify_to(results, x.element_names(), want)
        }
        Arity::Map2 => {
            let b = args.bind(&[".x", ".y", ".f"]);
            let x = b.req(0, ".x")?;
            let y = b.req(1, ".y")?;
            let f = as_function(&b.req(2, ".f")?, env)?;
            let xs = x.iter_elements();
            let ys = y.iter_elements();
            if xs.len() != ys.len() && ys.len() != 1 && xs.len() != 1 {
                return Err(Signal::error(format!(
                    "map2: .x (length {}) and .y (length {}) are incompatible",
                    xs.len(),
                    ys.len()
                )));
            }
            let n = xs.len().max(ys.len());
            let mut results = Vec::with_capacity(n);
            for k in 0..n {
                let mut call_args = vec![
                    (None, xs[k % xs.len()].clone()),
                    (None, ys[k % ys.len()].clone()),
                ];
                call_args.extend(b.rest.iter().cloned());
                results.push(i.call_function(&f, call_args, env)?);
            }
            simplify_to(results, x.element_names(), want)
        }
        Arity::PMap => {
            let b = args.bind(&[".l", ".f"]);
            let l = match b.req(0, ".l")? {
                RVal::List(l) => l,
                other => {
                    return Err(Signal::error(format!(
                        "pmap: .l must be a list, got {}",
                        other.class()
                    )))
                }
            };
            let f = as_function(&b.req(1, ".f")?, env)?;
            let seqs: Vec<Vec<RVal>> = l.vals.iter().map(|v| v.iter_elements()).collect();
            let n = seqs.iter().map(|s| s.len()).max().unwrap_or(0);
            let mut results = Vec::with_capacity(n);
            for k in 0..n {
                let mut call_args: Vec<(Option<String>, RVal)> = seqs
                    .iter()
                    .enumerate()
                    .map(|(j, s)| {
                        let nm = l
                            .names
                            .as_ref()
                            .and_then(|ns| ns.get(j))
                            .filter(|s| !s.is_empty())
                            .cloned();
                        (nm, s[k % s.len()].clone())
                    })
                    .collect();
                call_args.extend(b.rest.iter().cloned());
                results.push(i.call_function(&f, call_args, env)?);
            }
            simplify_to(results, None, want)
        }
        Arity::IMap => {
            let b = args.bind(&[".x", ".f"]);
            let x = b.req(0, ".x")?;
            let f = as_function(&b.req(1, ".f")?, env)?;
            let elems = x.iter_elements();
            let names = x.element_names();
            let mut results = Vec::with_capacity(elems.len());
            for (k, e) in elems.iter().enumerate() {
                // Second argument: name if named, else 1-based index.
                let tag = match &names {
                    Some(ns) if !ns[k].is_empty() => RVal::scalar_str(ns[k].clone()),
                    _ => RVal::scalar_int((k + 1) as i64),
                };
                let mut call_args = vec![(None, e.clone()), (None, tag)];
                call_args.extend(b.rest.iter().cloned());
                results.push(i.call_function(&f, call_args, env)?);
            }
            simplify_to(results, names, want)
        }
    }
}

fn predicate_mask(
    i: &mut Interp,
    env: &EnvRef,
    elems: &[RVal],
    p: &RVal,
) -> Result<Vec<bool>, Signal> {
    let mut mask = Vec::with_capacity(elems.len());
    for e in elems {
        mask.push(
            i.call_function(p, vec![(None, e.clone())], env)?
                .as_bool()
                .map_err(Signal::error)?,
        );
    }
    Ok(mask)
}

fn apply_where(
    i: &mut Interp,
    env: &EnvRef,
    x: &RVal,
    mask: &[bool],
    f: &RVal,
) -> EvalResult {
    let elems = x.iter_elements();
    let mut out = Vec::with_capacity(elems.len());
    for (k, e) in elems.into_iter().enumerate() {
        if mask[k] {
            out.push(i.call_function(f, vec![(None, e)], env)?);
        } else {
            out.push(e);
        }
    }
    let mut l = RList::plain(out);
    l.names = x.element_names();
    Ok(RVal::List(l))
}

fn modify_if_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&[".x", ".p", ".f"]);
    let x = b.req(0, ".x")?;
    let p = as_function(&b.req(1, ".p")?, env)?;
    let f = as_function(&b.req(2, ".f")?, env)?;
    let mask = predicate_mask(i, env, &x.iter_elements(), &p)?;
    apply_where(i, env, &x, &mask, &f)
}

fn map_if_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    modify_if_fn(i, args, env)
}

fn modify_at_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&[".x", ".at", ".f"]);
    let x = b.req(0, ".x")?;
    let at = b.req(1, ".at")?;
    let f = as_function(&b.req(2, ".f")?, env)?;
    let n = x.len();
    let mut mask = vec![false; n];
    match &at {
        RVal::Chr(keys) => {
            if let Some(names) = x.names() {
                for (k, nm) in names.iter().enumerate() {
                    if keys.vals.contains(nm) {
                        mask[k] = true;
                    }
                }
            }
        }
        other => {
            for idx in other.as_dbl_vec().map_err(Signal::error)? {
                let k = idx as usize;
                if k >= 1 && k <= n {
                    mask[k - 1] = true;
                }
            }
        }
    }
    apply_where(i, env, &x, &mask, &f)
}

fn map_at_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    modify_at_fn(i, args, env)
}

/// invoke_map(.f, .x): .f is a list of functions, .x a list of arg-lists.
fn invoke_map_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&[".f", ".x"]);
    let fs = b.req(0, ".f")?.iter_elements();
    let xs = match b.opt(1) {
        Some(RVal::List(l)) => l.vals,
        _ => vec![RVal::Null; fs.len()],
    };
    let mut results = Vec::with_capacity(fs.len());
    for (k, fval) in fs.iter().enumerate() {
        let f = as_function(fval, env)?;
        let call_args: Vec<(Option<String>, RVal)> = match xs.get(k % xs.len().max(1)) {
            Some(RVal::List(l)) => l.vals.iter().map(|v| (None, v.clone())).collect(),
            Some(RVal::Null) | None => vec![],
            Some(other) => vec![(None, other.clone())],
        };
        results.push(i.call_function(&f, call_args, env)?);
    }
    simplify_to(results, None, "list")
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn map_returns_list() {
        let v = run("map(1:3, function(x) x + 1)");
        assert!(matches!(v, RVal::List(_)));
        assert_eq!(v.len(), 3);
    }

    #[test]
    fn map_dbl_typed() {
        assert_eq!(run("map_dbl(1:3, function(x) x * 1.5)"), RVal::dbl(vec![1.5, 3.0, 4.5]));
    }

    #[test]
    fn map_dbl_rejects_nonscalar() {
        assert!(Interp::new().eval_program("map_dbl(1:3, function(x) c(x, x))").is_err());
    }

    #[test]
    fn map2_zips() {
        assert_eq!(
            run("map2_dbl(1:3, c(10, 20, 30), function(a, b) a + b)"),
            RVal::dbl(vec![11.0, 22.0, 33.0])
        );
    }

    #[test]
    fn pmap_over_list() {
        assert_eq!(
            run("pmap_dbl(list(1:2, 3:4, 5:6), function(a, b, c) a + b + c)"),
            RVal::dbl(vec![9.0, 12.0])
        );
    }

    #[test]
    fn imap_passes_names_or_index() {
        let v = run("imap_chr(c(a = 1, b = 2), function(x, nm) paste0(nm, x))");
        assert_eq!(v.as_str_vec().unwrap(), vec!["a1".to_string(), "b2".to_string()]);
        let v = run("imap_chr(c(5, 6), function(x, idx) paste0(idx, \":\", x))");
        assert_eq!(v.as_str_vec().unwrap(), vec!["1:5".to_string(), "2:6".to_string()]);
    }

    #[test]
    fn map_with_extra_args() {
        // map(xs, rnorm, n = 10) — the paper's §4.2 pipeline shape.
        let v = run("set.seed(1)\nr <- map(1:3, rnorm, n = 10)\nlength(r[[2]])");
        assert_eq!(v, RVal::scalar_int(10));
    }

    #[test]
    fn modify_if_applies_selectively() {
        let v = run("r <- modify_if(c(1, 5, 2), function(x) x > 3, function(x) x * 100)\nunlist(r)");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 500.0, 2.0]);
    }

    #[test]
    fn walk_returns_input() {
        let v = run("walk(1:3, function(x) x)");
        assert_eq!(v, RVal::int(vec![1, 2, 3]));
    }

    #[test]
    fn invoke_map_calls_each() {
        let v = run("r <- invoke_map(list(function() 1, function() 2))\nunlist(r)");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 2.0]);
    }
}
