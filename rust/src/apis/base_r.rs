//! Sequential base-R map-reduce functions (paper Table 1, rows
//! "base"/"stats"). These are the forms users write; `futurize()`
//! rewrites them into the [`super::future_apply`] forms.

use super::{as_function, seq_map, simplify_to};
use crate::rlite::ast::Arg;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};

pub fn register(r: &mut Reg) {
    r.normal("base", "lapply", lapply_fn);
    r.normal("base", "sapply", sapply_fn);
    r.normal("base", "vapply", vapply_fn);
    r.normal("base", "mapply", mapply_fn);
    r.normal("base", ".mapply", dot_mapply_fn);
    r.normal("base", "Map", map_base_fn);
    r.normal("base", "apply", apply_fn);
    r.normal("base", "tapply", tapply_fn);
    r.normal("base", "by", by_fn);
    r.normal("base", "eapply", eapply_fn);
    r.special("base", "replicate", replicate_fn);
    r.normal("base", "Filter", filter_fn);
    r.normal("stats", "kernapply", kernapply_fn);
}

/// Split `(X, FUN, ...)` and resolve FUN.
fn xf_args(
    args: &Args,
    env: &EnvRef,
    x_name: &str,
    f_name: &str,
) -> Result<(RVal, RVal, Vec<(Option<String>, RVal)>), Signal> {
    let b = args.bind(&[x_name, f_name]);
    let x = b.req(0, x_name)?;
    let f = as_function(&b.req(1, f_name)?, env)?;
    Ok((x, f, b.rest))
}

fn lapply_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (x, f, extra) = xf_args(&args, env, "X", "FUN")?;
    let results = seq_map(i, env, &x.iter_elements(), &f, &extra)?;
    simplify_to(results, x.element_names(), "list")
}

fn sapply_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (x, f, extra) = xf_args(&args, env, "X", "FUN")?;
    let extra: Vec<_> =
        extra.into_iter().filter(|(n, _)| n.as_deref() != Some("simplify")).collect();
    let results = seq_map(i, env, &x.iter_elements(), &f, &extra)?;
    let names = x.element_names().or_else(|| {
        // sapply over character vectors uses the values as names, as in R.
        match &x {
            RVal::Chr(v) => Some(v.vals.to_vec()),
            _ => None,
        }
    });
    simplify_to(results, names, "auto")
}

fn vapply_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["X", "FUN", "FUN.VALUE"]);
    let x = b.req(0, "X")?;
    let f = as_function(&b.req(1, "FUN")?, env)?;
    let proto = b.req(2, "FUN.VALUE")?;
    let results = seq_map(i, env, &x.iter_elements(), &f, &b.rest)?;
    // Type/length check against the prototype.
    for r in &results {
        if r.len() != proto.len() {
            return Err(Signal::error(format!(
                "values must be length {}, but FUN(X[[i]]) result is length {}",
                proto.len(),
                r.len()
            )));
        }
        if r.class() != proto.class() && !(proto.class() == "numeric" && r.class() == "integer")
        {
            return Err(Signal::error(format!(
                "values must be type '{}', but FUN(X[[i]]) result is type '{}'",
                proto.class(),
                r.class()
            )));
        }
    }
    let want = match proto.class() {
        "numeric" | "integer" => "dbl",
        "character" => "chr",
        "logical" => "lgl",
        _ => "auto",
    };
    simplify_to(results, x.element_names(), want)
}

/// mapply(FUN, ..., MoreArgs = NULL): zip the `...` collections.
fn mapply_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["FUN"]);
    let f = as_function(&b.req(0, "FUN")?, env)?;
    let mut seqs: Vec<(Option<String>, Vec<RVal>)> = Vec::new();
    let mut more: Vec<(Option<String>, RVal)> = Vec::new();
    for (name, v) in b.rest {
        if name.as_deref() == Some("MoreArgs") {
            if let RVal::List(l) = v {
                for (k, mv) in l.vals.iter().enumerate() {
                    let nm = l.names.as_ref().and_then(|ns| ns.get(k)).cloned();
                    more.push((nm, mv.clone()));
                }
            }
        } else if name.as_deref() == Some("SIMPLIFY") {
            // handled below via auto
        } else {
            seqs.push((name, v.iter_elements()));
        }
    }
    if seqs.is_empty() {
        return Err(Signal::error("mapply: no arguments to map over"));
    }
    let n = seqs.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
    let mut results = Vec::with_capacity(n);
    for k in 0..n {
        let mut call_args: Vec<(Option<String>, RVal)> = seqs
            .iter()
            .map(|(nm, s)| (nm.clone(), s[k % s.len()].clone()))
            .collect();
        call_args.extend(more.iter().cloned());
        results.push(i.call_function(&f, call_args, env)?);
    }
    simplify_to(results, None, "auto")
}

fn dot_mapply_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["FUN", "dots", "MoreArgs"]);
    let f = as_function(&b.req(0, "FUN")?, env)?;
    let dots = match b.req(1, "dots")? {
        RVal::List(l) => l,
        other => {
            return Err(Signal::error(format!(
                ".mapply: dots must be a list, got {}",
                other.class()
            )))
        }
    };
    let seqs: Vec<Vec<RVal>> = dots.vals.iter().map(|v| v.iter_elements()).collect();
    let n = seqs.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut results = Vec::with_capacity(n);
    for k in 0..n {
        let call_args: Vec<(Option<String>, RVal)> =
            seqs.iter().map(|s| (None, s[k % s.len()].clone())).collect();
        results.push(i.call_function(&f, call_args, env)?);
    }
    simplify_to(results, None, "list")
}

/// Map(f, ...): mapply without simplification.
fn map_base_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["f"]);
    let f = as_function(&b.req(0, "f")?, env)?;
    let seqs: Vec<Vec<RVal>> = b.rest.iter().map(|(_, v)| v.iter_elements()).collect();
    let n = seqs.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut results = Vec::with_capacity(n);
    for k in 0..n {
        let call_args: Vec<(Option<String>, RVal)> =
            seqs.iter().map(|s| (None, s[k % s.len()].clone())).collect();
        results.push(i.call_function(&f, call_args, env)?);
    }
    simplify_to(results, None, "list")
}

/// apply(X, MARGIN, FUN): X is our column-list "matrix".
fn apply_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["X", "MARGIN", "FUN"]);
    let x = b.req(0, "X")?;
    let margin = b.req(1, "MARGIN")?.as_usize().map_err(Signal::error)?;
    let f = as_function(&b.req(2, "FUN")?, env)?;
    let cols = match &x {
        RVal::List(l) => l.vals.clone(),
        other => vec![other.clone()],
    };
    let items: Vec<RVal> = match margin {
        2 => cols,
        1 => {
            let nrow = cols.first().map(|c| c.len()).unwrap_or(0);
            (0..nrow)
                .map(|r| {
                    let row: Vec<f64> = cols
                        .iter()
                        .map(|c| c.as_dbl_vec().map(|v| v[r]).unwrap_or(f64::NAN))
                        .collect();
                    RVal::dbl(row)
                })
                .collect()
        }
        other => return Err(Signal::error(format!("apply: MARGIN must be 1 or 2, got {other}"))),
    };
    let results = seq_map(i, env, &items, &f, &b.rest)?;
    simplify_to(results, None, "auto")
}

/// tapply(X, INDEX, FUN): group X by INDEX values, apply FUN per group.
fn tapply_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["X", "INDEX", "FUN"]);
    let x = b.req(0, "X")?;
    let index = b.req(1, "INDEX")?.as_str_vec().map_err(Signal::error)?;
    let f = as_function(&b.req(2, "FUN")?, env)?;
    let (groups, items) = group_by(&x, &index)?;
    let results = seq_map(i, env, &items, &f, &b.rest)?;
    simplify_to(results, Some(groups), "auto")
}

pub(crate) fn group_by(x: &RVal, index: &[String]) -> Result<(Vec<String>, Vec<RVal>), Signal> {
    let elems = x.iter_elements();
    if elems.len() != index.len() {
        return Err(Signal::error("arguments must have same length"));
    }
    let mut groups: Vec<String> = index.to_vec();
    groups.sort();
    groups.dedup();
    let mut items = Vec::with_capacity(groups.len());
    for g in &groups {
        let members: Vec<RVal> = elems
            .iter()
            .zip(index)
            .filter(|(_, idx)| *idx == g)
            .map(|(e, _)| e.clone())
            .collect();
        items.push(
            crate::rlite::builtins::core::combine(members.into_iter().map(|v| (None, v)).collect())
                .unwrap_or(RVal::Null),
        );
    }
    Ok((groups, items))
}

/// by(data, INDICES, FUN): split a data.frame by row groups.
fn by_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["data", "INDICES", "FUN"]);
    let data = b.req(0, "data")?;
    let idx = b.req(1, "INDICES")?.as_str_vec().map_err(Signal::error)?;
    let f = as_function(&b.req(2, "FUN")?, env)?;
    let RVal::List(df) = &data else {
        return Err(Signal::error("by: data must be a data.frame"));
    };
    let mut groups: Vec<String> = idx.clone();
    groups.sort();
    groups.dedup();
    let mut items = Vec::with_capacity(groups.len());
    for g in &groups {
        let rows: Vec<usize> =
            idx.iter().enumerate().filter(|(_, v)| *v == g).map(|(k, _)| k).collect();
        let cols: Vec<RVal> = df
            .vals
            .iter()
            .map(|c| {
                crate::rlite::eval::index_get(
                    c,
                    &[RVal::dbl(rows.iter().map(|&r| (r + 1) as f64).collect())],
                    false,
                )
                .unwrap_or(RVal::Null)
            })
            .collect();
        let mut sub = RList { vals: cols, names: df.names.clone(), class: df.class.clone() };
        sub.class = Some("data.frame".into());
        items.push(RVal::List(sub));
    }
    let results = seq_map(i, env, &items, &f, &b.rest)?;
    simplify_to(results, Some(groups), "list")
}

/// eapply(env, FUN): apply over an environment's bindings.
fn eapply_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["env", "FUN"]);
    let target = match b.req(0, "env")? {
        RVal::Env(e) => e,
        other => {
            return Err(Signal::error(format!("eapply: not an environment: {}", other.class())))
        }
    };
    let f = as_function(&b.req(1, "FUN")?, env)?;
    let mut bindings: Vec<(String, RVal)> = crate::rlite::env::local_bindings(&target);
    bindings.sort_by(|a, b| a.0.cmp(&b.0));
    let names: Vec<String> = bindings.iter().map(|(n, _)| n.clone()).collect();
    let items: Vec<RVal> = bindings.into_iter().map(|(_, v)| v).collect();
    let results = seq_map(i, env, &items, &f, &b.rest)?;
    simplify_to(results, Some(names), "list")
}

/// replicate(n, expr): special form — re-evaluates `expr` n times.
fn replicate_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let mut n: Option<usize> = None;
    let mut expr = None;
    let mut pos = 0;
    for a in args {
        match a.name.as_deref() {
            Some("n") => n = Some(i.eval(&a.value, env)?.as_usize().map_err(Signal::error)?),
            Some("expr") => expr = Some(&a.value),
            Some("simplify") => {}
            None => {
                match pos {
                    0 => n = Some(i.eval(&a.value, env)?.as_usize().map_err(Signal::error)?),
                    1 => expr = Some(&a.value),
                    _ => {}
                }
                pos += 1;
            }
            _ => {}
        }
    }
    let n = n.ok_or_else(|| Signal::error("replicate: missing n"))?;
    let expr = expr.ok_or_else(|| Signal::error("replicate: missing expr"))?;
    let mut results = Vec::with_capacity(n);
    for _ in 0..n {
        results.push(i.eval(expr, env)?);
    }
    simplify_to(results, None, "auto")
}

fn filter_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["f", "x"]);
    let f = as_function(&b.req(0, "f")?, env)?;
    let x = b.req(1, "x")?;
    let elems = x.iter_elements();
    let mut keep = Vec::with_capacity(elems.len());
    for e in &elems {
        let v = i.call_function(&f, vec![(None, e.clone())], env)?;
        keep.push(v.as_bool().map_err(Signal::error)?);
    }
    let kept: Vec<RVal> =
        elems.into_iter().zip(&keep).filter(|(_, &k)| k).map(|(e, _)| e).collect();
    match x {
        RVal::List(_) => Ok(RVal::list(kept)),
        _ => crate::rlite::builtins::core::combine(kept.into_iter().map(|v| (None, v)).collect()),
    }
}

/// stats::kernapply(x, k): apply a smoothing kernel by convolution.
fn kernapply_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "k"]);
    let x = b.req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    let k = b.req(1, "k")?.as_dbl_vec().map_err(Signal::error)?;
    Ok(RVal::dbl(kernapply_native(&x, &k)))
}

/// Centered moving-kernel convolution (valid region), shared with the
/// future variant so both paths agree exactly.
pub(crate) fn kernapply_native(x: &[f64], k: &[f64]) -> Vec<f64> {
    let m = k.len();
    if x.len() < m {
        return vec![];
    }
    (0..=(x.len() - m))
        .map(|s| x[s..s + m].iter().zip(k).map(|(a, b)| a * b).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn lapply_returns_list() {
        let v = run("lapply(1:3, function(x) x^2)");
        match v {
            RVal::List(l) => {
                assert_eq!(l.len(), 3);
                assert_eq!(l.vals[2].as_f64().unwrap(), 9.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sapply_simplifies() {
        assert_eq!(run("sapply(1:4, function(x) x * 2)"), RVal::dbl(vec![2.0, 4.0, 6.0, 8.0]));
    }

    #[test]
    fn vapply_checks_prototype() {
        assert_eq!(
            run("vapply(1:3, function(x) x + 0.5, numeric(1))"),
            RVal::dbl(vec![1.5, 2.5, 3.5])
        );
        assert!(Interp::new()
            .eval_program("vapply(1:3, function(x) c(x, x), numeric(1))")
            .is_err());
        assert!(Interp::new()
            .eval_program("vapply(1:3, function(x) \"s\", numeric(1))")
            .is_err());
    }

    #[test]
    fn mapply_zips() {
        assert_eq!(
            run("mapply(function(a, b) a + b, 1:3, c(10, 20, 30))"),
            RVal::dbl(vec![11.0, 22.0, 33.0])
        );
    }

    #[test]
    fn map_base_does_not_simplify() {
        let v = run("Map(function(a, b) a * b, 1:2, 3:4)");
        assert!(matches!(v, RVal::List(_)));
    }

    #[test]
    fn tapply_groups() {
        let v = run("tapply(c(1, 2, 3, 4), c(\"a\", \"b\", \"a\", \"b\"), sum)");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![4.0, 6.0]);
        assert_eq!(v.names().unwrap(), &["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn replicate_reevaluates() {
        let v = run("set.seed(1)\nr <- replicate(3, rnorm(2))\nlength(r)");
        assert_eq!(v, RVal::scalar_int(6)); // simplified to 6 numbers
    }

    #[test]
    fn filter_keeps_matching() {
        assert_eq!(run("Filter(function(x) x > 2, c(1, 2, 3, 4))"), RVal::dbl(vec![3.0, 4.0]));
    }

    #[test]
    fn apply_margins() {
        assert_eq!(
            run("m <- matrix(1:6, nrow = 2, ncol = 3)\napply(m, 2, sum)"),
            RVal::dbl(vec![3.0, 7.0, 11.0])
        );
        assert_eq!(
            run("m <- matrix(1:6, nrow = 2, ncol = 3)\napply(m, 1, sum)"),
            RVal::dbl(vec![9.0, 12.0])
        );
    }

    #[test]
    fn kernapply_smooths() {
        let v = run("kernapply(c(1, 2, 3, 4), c(0.5, 0.5))");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.5, 2.5, 3.5]);
    }

    #[test]
    fn eapply_over_environment() {
        let v = run("e <- new.env()\ne$a <- 1\ne$b <- 2\nr <- eapply(e, function(x) x * 10)\nunlist(r)");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![10.0, 20.0]);
    }

    #[test]
    fn lapply_preserves_names() {
        let v = run("lapply(c(a = 1, b = 2), function(x) x)");
        assert_eq!(v.names().unwrap(), &["a".to_string(), "b".to_string()]);
    }
}
