//! foreach + iterators (paper Table 1 row "foreach", §4.3).
//!
//! `foreach(x = xs, ...) %do% { body }` evaluates `body` once per zipped
//! iteration with the loop variables bound. `%dofuture%` (doFuture) is
//! the parallel form the transpiler targets. `times(n) %do% body`
//! mirrors `replicate()` and defaults to `seed = TRUE` when futurized.
//! Iterators: `icount()` (position counter) and `iter(obj)`.

use crate::future_core::driver::{foreach_elements_run, MapRun};
use crate::rlite::ast::Arg;
use crate::rlite::builtins::{lookup_builtin, Args, Reg};
use crate::rlite::env::{define, Env, EnvRef};
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};
use crate::transpile::reduce::{self, ReducePlan, ReduceSpec};
use crate::transpile::{options_from_value, FuturizeOptions, SeedSetting};

pub fn register(r: &mut Reg) {
    r.normal("foreach", "foreach", foreach_ctor);
    r.normal("foreach", "times", times_ctor);
    r.special("foreach", "%do%", do_seq);
    r.special("foreach", "%dopar%", do_par_fallback);
    r.special("doFuture", "%dofuture%", do_future);
    r.normal("iterators", "icount", icount_ctor);
    r.normal("iterators", "iter", iter_ctor);
}

/// foreach(x = xs, y = ys, .combine = c, ...) — an iteration spec object.
fn foreach_ctor(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut vars: Vec<(String, RVal)> = Vec::new();
    let mut combine = RVal::Null;
    let mut fut_opts = RVal::Null;
    for (name, v) in args.items {
        match name.as_deref() {
            Some(".combine") => combine = v,
            Some(".options.future") => fut_opts = v,
            Some(n) => vars.push((n.to_string(), v)),
            None => {
                return Err(Signal::error(
                    "foreach: iteration variables must be named (e.g. foreach(x = xs))",
                ))
            }
        }
    }
    if vars.is_empty() {
        return Err(Signal::error("foreach: no iteration variables"));
    }
    let names: Vec<String> =
        vars.iter().map(|(n, _)| n.clone()).chain(["__combine".into(), "__opts".into()]).collect();
    let vals: Vec<RVal> =
        vars.into_iter().map(|(_, v)| v).chain([combine, fut_opts]).collect();
    let mut l = RList::named(vals, names);
    l.class = Some("foreach".into());
    Ok(RVal::List(l))
}

/// times(n) — n anonymous iterations.
fn times_ctor(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let n = args.bind(&["n"]).req(0, "n")?.as_usize().map_err(Signal::error)?;
    let mut l = RList::named(vec![RVal::scalar_int(n as i64)], vec!["n".into()]);
    l.class = Some("times".into());
    Ok(RVal::List(l))
}

/// icount() — an iterator yielding 1, 2, 3, ... bounded by the other
/// iteration variables.
fn icount_ctor(_i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    let mut l = RList::named(vec![], vec![]);
    l.class = Some("icount".into());
    Ok(RVal::List(l))
}

/// iter(obj) — explicit element iterator (elements of obj).
fn iter_ctor(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["obj"]).req(0, "obj")?;
    let mut l = RList::named(vec![x], vec!["obj".into()]);
    l.class = Some("iter".into());
    Ok(RVal::List(l))
}

/// Expand a foreach spec into per-iteration variable bindings.
pub(crate) fn expand_bindings(
    spec: &RVal,
) -> Result<(Vec<Vec<(String, RVal)>>, RVal, RVal), Signal> {
    let RVal::List(l) = spec else {
        return Err(Signal::error("%do%: lhs must be a foreach() or times() object"));
    };
    match l.class.as_deref() {
        Some("times") => {
            let n = l.get("n").and_then(|v| v.as_i64().ok()).unwrap_or(0) as usize;
            Ok(((0..n).map(|_| vec![]).collect(), RVal::Null, RVal::Null))
        }
        Some("foreach") => {
            let names = l.names.clone().unwrap_or_default();
            let mut seqs: Vec<(String, Option<Vec<RVal>>)> = Vec::new(); // None = icount
            let mut combine = RVal::Null;
            let mut opts = RVal::Null;
            for (k, name) in names.iter().enumerate() {
                let v = &l.vals[k];
                match name.as_str() {
                    "__combine" => combine = v.clone(),
                    "__opts" => opts = v.clone(),
                    _ => match v {
                        RVal::List(inner) if inner.class.as_deref() == Some("icount") => {
                            seqs.push((name.clone(), None));
                        }
                        RVal::List(inner) if inner.class.as_deref() == Some("iter") => {
                            let obj = inner.get("obj").cloned().unwrap_or(RVal::Null);
                            seqs.push((name.clone(), Some(obj.iter_elements())));
                        }
                        other => seqs.push((name.clone(), Some(other.iter_elements()))),
                    },
                }
            }
            let n = seqs
                .iter()
                .filter_map(|(_, s)| s.as_ref().map(|v| v.len()))
                .min()
                .ok_or_else(|| Signal::error("foreach: only icount() iterators — unbounded"))?;
            let mut bindings = Vec::with_capacity(n);
            for k in 0..n {
                let mut row = Vec::with_capacity(seqs.len());
                for (name, s) in &seqs {
                    let v = match s {
                        Some(vals) => vals[k].clone(),
                        None => RVal::scalar_int((k + 1) as i64),
                    };
                    row.push((name.clone(), v));
                }
                bindings.push(row);
            }
            Ok((bindings, combine, opts))
        }
        other => Err(Signal::error(format!(
            "%do%: lhs must be foreach() or times(), got {other:?}"
        ))),
    }
}

/// Is `v` the genuine builtin named `name` (not a user rebinding)?
fn is_builtin(v: &RVal, name: &str) -> bool {
    matches!(v, RVal::Builtin(id) if lookup_builtin(name).is_some_and(|d| d.id == *id))
}

/// Reduce per-iteration results per `.combine` (default: list).
fn reduce_combine(
    i: &mut Interp,
    env: &EnvRef,
    results: Vec<RVal>,
    combine: &RVal,
) -> EvalResult {
    if combine.is_null() {
        return Ok(RVal::list(results));
    }
    if combine.is_function() {
        // `.combine = c` used to re-copy the growing accumulator once
        // per iteration (quadratic in the iteration count);
        // combine_results preallocates from the known total and
        // replays the pairwise coercion ladder exactly.
        if is_builtin(combine, "c") {
            return reduce::combine_results(results);
        }
        let mut it = results.into_iter();
        let Some(mut acc) = it.next() else { return Ok(RVal::Null) };
        for r in it {
            acc = i.call_function(combine, vec![(None, acc), (None, r)], env)?;
        }
        return Ok(acc);
    }
    Err(Signal::error("foreach: .combine must be a function"))
}

/// Map a runtime `.combine` value onto a worker-side reduction plan.
/// Only the genuine builtins fuse — a user-defined combine function
/// (even one rebinding a catalog name) must see every per-iteration
/// result, so it keeps the full-result path.
fn combine_reduce_spec(combine: &RVal, opts: &FuturizeOptions) -> Option<ReduceSpec> {
    if opts.reduce.as_deref() == Some("off") {
        return None;
    }
    let name = ["+", "*", "min", "max", "c"].into_iter().find(|n| is_builtin(combine, n))?;
    Some(ReduceSpec {
        plan: ReducePlan {
            op: reduce::ReduceOp::parse(name).expect("combine op in catalog"),
            assoc: opts.reduce.as_deref() == Some("assoc"),
        },
        wrap: false,
    })
}

/// Sequential `%do%`: body evaluated in a child of the calling
/// environment (lexical visibility of locals, as in foreach).
fn do_seq(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let spec = i.eval(&args[0].value, env)?;
    let body = &args[1].value;
    let (bindings, combine, _) = expand_bindings(&spec)?;
    let mut results = Vec::with_capacity(bindings.len());
    for row in bindings {
        let iter_env = Env::child_of(env);
        for (name, v) in row {
            define(&iter_env, &name, v);
        }
        results.push(i.eval(body, &iter_env)?);
    }
    reduce_combine(i, env, results, &combine)
}

/// `%dopar%` without a registered adapter behaves like `%do%` plus the
/// canonical foreach warning — the paper's §1 lock-in critique.
fn do_par_fallback(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    i.signal_condition(crate::rlite::conditions::RCondition::warning_cond(
        "executing %dopar% sequentially: no parallel backend registered",
    ))?;
    do_seq(i, args, env)
}

/// `%dofuture%`: the doFuture parallel form.
fn do_future(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let spec = i.eval(&args[0].value, env)?;
    let body = &args[1].value;
    let (bindings, combine, optsval) = expand_bindings(&spec)?;
    let mut opts: FuturizeOptions = options_from_value(&optsval);
    // times() implies resampling: default seed = TRUE (paper §4.3).
    if opts.seed.is_none() {
        if let RVal::List(l) = &spec {
            if l.class.as_deref() == Some("times") {
                opts.seed = Some(SeedSetting::True);
            }
        }
    }
    let mut map_opts = opts.to_map_options(false);
    if map_opts.reduce.is_none() {
        map_opts.reduce = combine_reduce_spec(&combine, &opts);
    }
    // A user `.combine` (anything beyond the genuine builtin catalog)
    // cannot be proven associative — record it so the analyzer can
    // flag order-dependence under `reduce = "assoc"` (FZ005).
    if matches!(combine, RVal::Closure(_)) {
        map_opts.lint.nonassoc_combine = Some(".combine".into());
    }
    match foreach_elements_run(i, env, bindings, body, &map_opts)? {
        MapRun::Values(results) => reduce_combine(i, env, results, &combine),
        // Fused: the chunk partials were merged with the combine's own
        // semantics; the value is already the fold result.
        MapRun::Reduced(v) => Ok(v),
    }
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn do_iterates_and_collects_list() {
        let v = run("r <- foreach(x = 1:3) %do% { x * 2 }\nunlist(r)");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn do_zips_multiple_variables() {
        let v = run("r <- foreach(a = 1:3, b = c(10, 20, 30)) %do% { a + b }\nunlist(r)");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn combine_with_c() {
        let v = run("foreach(x = 1:4, .combine = c) %do% { x^2 }");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 4.0, 9.0, 16.0]);
    }

    #[test]
    fn icount_provides_indices() {
        let v = run(
            "r <- foreach(d = c(9, 8), i = icount()) %do% { list(value = d, index = i) }\nr[[2]]$index",
        );
        assert_eq!(v, RVal::scalar_int(2));
    }

    #[test]
    fn times_do() {
        let v = run("r <- times(5) %do% 7\nunlist(r)");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![7.0; 5]);
    }

    #[test]
    fn dofuture_matches_do() {
        let seq = run("foreach(x = 1:6, .combine = c) %do% { x + 1 }");
        let par = run(
            "plan(multicore, workers = 3)\nforeach(x = 1:6, .combine = c) %dofuture% { x + 1 }",
        );
        assert_eq!(seq, par);
    }

    #[test]
    fn dofuture_sees_globals() {
        let v = run(
            "plan(multicore, workers = 2)\noffset <- 100\nr <- foreach(x = 1:3) %dofuture% { x + offset }\nunlist(r)",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), vec![101.0, 102.0, 103.0]);
    }

    #[test]
    fn dopar_warns_and_runs() {
        let mut i = Interp::new();
        let (r, out) = i.capture_stdout(|i| {
            i.eval_program("foreach(x = 1:2, .combine = c) %dopar% { x }")
        });
        assert_eq!(r.unwrap().as_dbl_vec().unwrap(), vec![1.0, 2.0]);
        assert!(out.contains("sequentially"), "{out}");
    }

    #[test]
    fn iterate_data_frame_columns() {
        // §4.3's iterators example: foreach over a data.frame iterates
        // columns.
        let v = run(
            "df <- data.frame(a = 1:4, b = c(\"w\", \"x\", \"y\", \"z\"))\n\
             r <- foreach(d = df, i = icount()) %do% { list(value = d, index = i) }\nlength(r)",
        );
        assert_eq!(v, RVal::scalar_int(2));
    }
}
