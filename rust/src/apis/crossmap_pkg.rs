//! crossmap (paper Table 1): apply a function to every *combination* of
//! list elements. Hosts its own future variants ("Requires: (itself)").

use super::{as_function, simplify_to};
use crate::future_core::driver::map_elements;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::RVal;
use crate::transpile::{options_from_value, FuturizeOptions};

pub fn register(r: &mut Reg) {
    r.normal("crossmap", "xmap", |i, a, e| xmap_impl(i, a, e, "list", false));
    r.normal("crossmap", "xmap_dbl", |i, a, e| xmap_impl(i, a, e, "dbl", false));
    r.normal("crossmap", "xmap_chr", |i, a, e| xmap_impl(i, a, e, "chr", false));
    r.normal("crossmap", "xwalk", |i, a, e| xmap_impl(i, a, e, "walk", false));
    r.normal("crossmap", "map_vec", |i, a, e| map_vec_impl(i, a, e));
    r.normal("crossmap", "map2_vec", map2_vec_impl);
    r.normal("crossmap", "pmap_vec", pmap_vec_impl);
    r.normal("crossmap", "imap_vec", imap_vec_impl);
    // future variants (transpile targets).
    r.normal("crossmap", "future_xmap", |i, a, e| xmap_impl(i, a, e, "list", true));
    r.normal("crossmap", "future_xmap_dbl", |i, a, e| xmap_impl(i, a, e, "dbl", true));
    r.normal("crossmap", "future_xmap_chr", |i, a, e| xmap_impl(i, a, e, "chr", true));
    r.normal("crossmap", "future_xwalk", |i, a, e| xmap_impl(i, a, e, "walk", true));
    r.normal("crossmap", "future_map_vec", |i, a, e| map_vec_future(i, a, e));
    r.normal("crossmap", "future_map2_vec", map2_vec_impl);
    r.normal("crossmap", "future_pmap_vec", pmap_vec_impl);
    r.normal("crossmap", "future_imap_vec", imap_vec_impl);
}

/// Cartesian product of the elements of each list entry, in
/// column-major order (first entry varies fastest), as crossmap does.
pub(crate) fn cross_product(seqs: &[Vec<RVal>]) -> Vec<Vec<RVal>> {
    let total: usize = seqs.iter().map(|s| s.len().max(1)).product();
    let mut out = Vec::with_capacity(total);
    for mut idx in 0..total {
        let mut row = Vec::with_capacity(seqs.len());
        for s in seqs {
            let n = s.len().max(1);
            row.push(s[idx % n].clone());
            idx /= n;
        }
        out.push(row);
    }
    out
}

fn split_options(args: &Args) -> (Args, FuturizeOptions) {
    let mut user = Vec::new();
    let mut opts = FuturizeOptions::default();
    for (name, v) in &args.items {
        if name.as_deref() == Some(".options") {
            opts = options_from_value(v);
        } else {
            user.push((name.clone(), v.clone()));
        }
    }
    (Args::new(user), opts)
}

fn xmap_impl(i: &mut Interp, args: Args, env: &EnvRef, want: &str, parallel: bool) -> EvalResult {
    let (args, opts) = split_options(&args);
    let b = args.bind(&[".l", ".f"]);
    let l = match b.req(0, ".l")? {
        RVal::List(l) => l,
        other => {
            return Err(Signal::error(format!("xmap: .l must be a list, got {}", other.class())))
        }
    };
    let f = as_function(&b.req(1, ".f")?, env)?;
    let seqs: Vec<Vec<RVal>> = l.vals.iter().map(|v| v.iter_elements()).collect();
    let combos = cross_product(&seqs);
    let results = if parallel {
        let items: Vec<RVal> = combos.into_iter().map(RVal::list).collect();
        super::future_apply::map_tuple(i, env, items, &f, &b.rest, &opts, seqs.len())?
    } else {
        let mut out = Vec::with_capacity(combos.len());
        for row in combos {
            let mut call_args: Vec<(Option<String>, RVal)> =
                row.into_iter().map(|v| (None, v)).collect();
            call_args.extend(b.rest.iter().cloned());
            out.push(i.call_function(&f, call_args, env)?);
        }
        out
    };
    if want == "walk" {
        return Ok(RVal::Null);
    }
    simplify_to(results, None, want)
}

fn map_vec_impl(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    super::purrr_pkg::map_variant(i, args, env, super::purrr_pkg::Arity::Map1, "auto", false)
}

fn map_vec_future(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (args, opts) = split_options(&args);
    let b = args.bind(&[".x", ".f"]);
    let x = b.req(0, ".x")?;
    let f = as_function(&b.req(1, ".f")?, env)?;
    let results = map_elements(i, env, x.iter_elements(), &f, b.rest, &opts.to_map_options(false))?;
    simplify_to(results, x.element_names(), "auto")
}

fn map2_vec_impl(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (args, _) = split_options(&args);
    super::purrr_pkg::map_variant(i, args, env, super::purrr_pkg::Arity::Map2, "auto", false)
}

fn pmap_vec_impl(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (args, _) = split_options(&args);
    super::purrr_pkg::map_variant(i, args, env, super::purrr_pkg::Arity::PMap, "auto", false)
}

fn imap_vec_impl(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (args, _) = split_options(&args);
    super::purrr_pkg::map_variant(i, args, env, super::purrr_pkg::Arity::IMap, "auto", false)
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn xmap_covers_all_combinations() {
        let v = run("xmap_dbl(list(1:2, c(10, 20)), function(a, b) a + b)");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![11.0, 12.0, 21.0, 22.0]);
    }

    #[test]
    fn future_xmap_matches_xmap() {
        let seq = run("xmap_dbl(list(1:3, 1:3), function(a, b) a * b)");
        let par = run(
            "plan(multicore, workers = 2)\ncrossmap::future_xmap_dbl(list(1:3, 1:3), function(a, b) a * b)",
        );
        assert_eq!(seq, par);
    }

    #[test]
    fn map_vec_simplifies() {
        assert_eq!(run("map_vec(1:3, function(x) x * 2)"), RVal::dbl(vec![2.0, 4.0, 6.0]));
    }
}
