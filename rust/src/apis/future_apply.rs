//! future.apply — the future-based forms of the base-R family
//! (`future_lapply()` etc.), the transpile targets for Table 1 row 1.
//!
//! Options arrive in future.apply's own convention (`future.seed=`,
//! `future.chunk.size=`, `future.scheduling=`, `future.stdout=`,
//! `future.conditions=`) — produced by the futurize transpiler's
//! option-mapping step.

use super::{as_function, map_maybe_reduced, simplify_to};
use crate::future_core::driver::{foreach_elements, map_elements, MapRun};
use crate::rlite::ast::Arg;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::RVal;
use crate::transpile::{options_from_pairs, FuturizeOptions};

pub fn register(r: &mut Reg) {
    r.normal("future.apply", "future_lapply", |i, a, e| fut_apply(i, a, e, "list"));
    r.normal("future.apply", "future_sapply", |i, a, e| fut_apply(i, a, e, "auto"));
    r.normal("future.apply", "future_vapply", fut_vapply);
    r.normal("future.apply", "future_mapply", fut_mapply);
    r.normal("future.apply", "future_Map", fut_map_base);
    r.normal("future.apply", "future_.mapply", fut_dot_mapply);
    r.normal("future.apply", "future_apply", fut_apply_matrix);
    r.normal("future.apply", "future_tapply", fut_tapply);
    r.normal("future.apply", "future_by", fut_by);
    r.normal("future.apply", "future_eapply", fut_eapply);
    r.special("future.apply", "future_replicate", fut_replicate);
    r.normal("future.apply", "future_Filter", fut_filter);
    r.normal("future.apply", "future_kernapply", fut_kernapply);
}

/// Split arguments into (positional/user, future.* options).
pub(crate) fn split_future_opts(
    args: &Args,
) -> (Vec<(Option<String>, RVal)>, FuturizeOptions) {
    let mut user = Vec::new();
    let mut optpairs = Vec::new();
    for (name, v) in &args.items {
        match name {
            Some(n) if n.starts_with("future.") => optpairs.push((n.clone(), v.clone())),
            _ => user.push((name.clone(), v.clone())),
        }
    }
    (user, options_from_pairs(&optpairs))
}

fn bind2<'a>(
    user: &'a [(Option<String>, RVal)],
    a: &str,
    b: &str,
) -> (Option<&'a RVal>, Option<&'a RVal>, Vec<(Option<String>, RVal)>) {
    let mut x = None;
    let mut f = None;
    let mut rest = Vec::new();
    let mut positional = Vec::new();
    for (name, v) in user {
        match name.as_deref() {
            Some(n) if n == a => x = Some(v),
            Some(n) if n == b => f = Some(v),
            Some(_) => rest.push((name.clone(), v.clone())),
            None => positional.push(v),
        }
    }
    let mut pos = positional.into_iter();
    if x.is_none() {
        x = pos.next();
    }
    if f.is_none() {
        f = pos.next();
    }
    for v in pos {
        rest.push((None, v.clone()));
    }
    (x, f, rest)
}

fn fut_apply(i: &mut Interp, args: Args, env: &EnvRef, want: &str) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    let (x, f, rest) = bind2(&user, "X", "FUN");
    let x = x.ok_or_else(|| Signal::error("missing X"))?.clone();
    let f = as_function(f.ok_or_else(|| Signal::error("missing FUN"))?, env)?;
    let results = match map_maybe_reduced(i, env, x.iter_elements(), &f, rest, &opts, want)? {
        MapRun::Reduced(v) => return Ok(v),
        MapRun::Values(results) => results,
    };
    let names = x.element_names().or(match (&x, want) {
        (RVal::Chr(v), "auto") => Some(v.vals.to_vec()),
        _ => None,
    });
    simplify_to(results, names, want)
}

fn fut_vapply(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    // X, FUN, FUN.VALUE
    let mut x = None;
    let mut f = None;
    let mut proto = None;
    let mut rest = Vec::new();
    let mut positional = Vec::new();
    for (name, v) in user {
        match name.as_deref() {
            Some("X") => x = Some(v),
            Some("FUN") => f = Some(v),
            Some("FUN.VALUE") => proto = Some(v),
            Some(_) => rest.push((name, v)),
            None => positional.push(v),
        }
    }
    let mut pos = positional.into_iter();
    let x = x.or_else(|| pos.next()).ok_or_else(|| Signal::error("missing X"))?;
    let f = f.or_else(|| pos.next()).ok_or_else(|| Signal::error("missing FUN"))?;
    let f = as_function(&f, env)?;
    let proto =
        proto.or_else(|| pos.next()).ok_or_else(|| Signal::error("missing FUN.VALUE"))?;
    for v in pos {
        rest.push((None, v));
    }
    let results =
        map_elements(i, env, x.iter_elements(), &f, rest, &opts.to_map_options(false))?;
    for r in &results {
        if r.len() != proto.len() {
            return Err(Signal::error(format!(
                "values must be length {}, but FUN(X[[i]]) result is length {}",
                proto.len(),
                r.len()
            )));
        }
    }
    let want = match proto.class() {
        "numeric" | "integer" => "dbl",
        "character" => "chr",
        "logical" => "lgl",
        _ => "auto",
    };
    simplify_to(results, x.element_names(), want)
}

/// Split off the first argument (by name or first positional), keeping
/// the rest in order.
fn bind1<'a>(
    user: &'a [(Option<String>, RVal)],
    a: &str,
) -> (Option<&'a RVal>, Vec<(Option<String>, RVal)>) {
    let mut x = None;
    let mut rest = Vec::new();
    for (name, v) in user {
        match name.as_deref() {
            Some(n) if n == a && x.is_none() => x = Some(v),
            None if x.is_none() && name.is_none() => x = Some(v),
            _ => rest.push((name.clone(), v.clone())),
        }
    }
    (x, rest)
}

fn fut_mapply(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    let (f, rest0) = bind1(&user, "FUN");
    let f = as_function(f.ok_or_else(|| Signal::error("missing FUN"))?, env)?;
    let mut seqs: Vec<(Option<String>, Vec<RVal>)> = Vec::new();
    let mut more: Vec<(Option<String>, RVal)> = Vec::new();
    for (name, v) in rest0 {
        if name.as_deref() == Some("MoreArgs") {
            if let RVal::List(l) = v {
                for (k, mv) in l.vals.iter().enumerate() {
                    let nm = l.names.as_ref().and_then(|ns| ns.get(k)).cloned();
                    more.push((nm, mv.clone()));
                }
            }
        } else if name.as_deref() != Some("SIMPLIFY") {
            seqs.push((name, v.iter_elements()));
        }
    }
    seqs.retain(|(_, s)| !s.is_empty());
    let n = seqs.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
    // Zip into per-element binding rows and run as a foreach-style chunk
    // (each element is a tuple of arguments).
    let mut items: Vec<RVal> = Vec::with_capacity(n);
    for k in 0..n {
        let row: Vec<RVal> = seqs.iter().map(|(_, s)| s[k % s.len()].clone()).collect();
        items.push(RVal::list(row));
    }
    // Wrapper closure: f applied to the elements of the tuple.
    let results = map_tuple(i, env, items, &f, &more, &opts, seqs.len())?;
    simplify_to(results, None, "auto")
}

/// `future_.mapply(FUN, dots, MoreArgs)`: dots is a list of sequences.
fn fut_dot_mapply(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    let args2 = Args::new(user);
    let b = args2.bind(&["FUN", "dots", "MoreArgs"]);
    let f = as_function(&b.req(0, "FUN")?, env)?;
    let dots = match b.req(1, "dots")? {
        RVal::List(l) => l,
        other => {
            return Err(Signal::error(format!(
                "future_.mapply: dots must be a list, got {}",
                other.class()
            )))
        }
    };
    let seqs: Vec<Vec<RVal>> = dots
        .vals
        .iter()
        .map(|v| v.iter_elements())
        .filter(|s| !s.is_empty())
        .collect();
    let n = seqs.iter().map(|s| s.len()).max().unwrap_or(0);
    let items: Vec<RVal> = (0..n)
        .map(|k| RVal::list(seqs.iter().map(|s| s[k % s.len()].clone()).collect()))
        .collect();
    let results = map_tuple(i, env, items, &f, &[], &opts, seqs.len())?;
    simplify_to(results, None, "list")
}

fn fut_map_base(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    let (f, rest) = bind1(&user, "f");
    let f = as_function(f.ok_or_else(|| Signal::error("missing f"))?, env)?;
    let seqs: Vec<Vec<RVal>> = rest.iter().map(|(_, v)| v.iter_elements()).collect();
    let n = seqs.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut items = Vec::with_capacity(n);
    for k in 0..n {
        let row: Vec<RVal> = seqs.iter().map(|s| s[k % s.len()].clone()).collect();
        items.push(RVal::list(row));
    }
    let results = map_tuple(i, env, items, &f, &[], &opts, seqs.len())?;
    simplify_to(results, None, "list")
}

/// Run `f` over tuple items (each an RVal::List of the per-position
/// arguments) by wrapping it in a do.call shim closure.
pub(crate) fn map_tuple(
    i: &mut Interp,
    env: &EnvRef,
    items: Vec<RVal>,
    f: &RVal,
    more: &[(Option<String>, RVal)],
    opts: &FuturizeOptions,
    _arity: usize,
) -> Result<Vec<RVal>, Signal> {
    // shim: function(.tuple) do.call(.f, c(.tuple, .more))
    let shim_src = "function(.tuple, .f, .more) do.call(.f, append(.tuple, .more))";
    let shim_expr = crate::rlite::parse_expr(shim_src).map_err(Signal::error)?;
    let shim = i.eval(&shim_expr, env)?;
    let more_list = RVal::List(crate::rlite::value::RList {
        vals: more.iter().map(|(_, v)| v.clone()).collect(),
        names: Some(more.iter().map(|(n, _)| n.clone().unwrap_or_default()).collect()),
        class: None,
    });
    let extra = vec![(Some(".f".to_string()), f.clone()), (Some(".more".to_string()), more_list)];
    map_elements(i, env, items, &shim, extra, &opts.to_map_options(false))
}

fn fut_apply_matrix(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    let mut x = None;
    let mut margin = None;
    let mut f = None;
    let mut rest = Vec::new();
    let mut positional = Vec::new();
    for (name, v) in user {
        match name.as_deref() {
            Some("X") => x = Some(v),
            Some("MARGIN") => margin = Some(v),
            Some("FUN") => f = Some(v),
            Some(_) => rest.push((name, v)),
            None => positional.push(v),
        }
    }
    let mut pos = positional.into_iter();
    let x = x.or_else(|| pos.next()).ok_or_else(|| Signal::error("missing X"))?;
    let margin = margin
        .or_else(|| pos.next())
        .ok_or_else(|| Signal::error("missing MARGIN"))?
        .as_usize()
        .map_err(Signal::error)?;
    let f = f.or_else(|| pos.next()).ok_or_else(|| Signal::error("missing FUN"))?;
    let f = as_function(&f, env)?;
    let cols = match &x {
        RVal::List(l) => l.vals.clone(),
        other => vec![other.clone()],
    };
    let items: Vec<RVal> = match margin {
        2 => cols,
        1 => {
            let nrow = cols.first().map(|c| c.len()).unwrap_or(0);
            (0..nrow)
                .map(|r| {
                    let row: Vec<f64> = cols
                        .iter()
                        .map(|c| c.as_dbl_vec().map(|v| v[r]).unwrap_or(f64::NAN))
                        .collect();
                    RVal::dbl(row)
                })
                .collect()
        }
        other => return Err(Signal::error(format!("MARGIN must be 1 or 2, got {other}"))),
    };
    let results = map_elements(i, env, items, &f, rest, &opts.to_map_options(false))?;
    simplify_to(results, None, "auto")
}

fn fut_tapply(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    let mut pos = user
        .iter()
        .filter(|(n, _)| n.is_none())
        .map(|(_, v)| v.clone())
        .collect::<Vec<_>>()
        .into_iter();
    let x = pos.next().ok_or_else(|| Signal::error("missing X"))?;
    let index = pos.next().ok_or_else(|| Signal::error("missing INDEX"))?;
    let f = as_function(&pos.next().ok_or_else(|| Signal::error("missing FUN"))?, env)?;
    let (groups, items) =
        super::base_r::group_by(&x, &index.as_str_vec().map_err(Signal::error)?)?;
    let results = map_elements(i, env, items, &f, vec![], &opts.to_map_options(false))?;
    simplify_to(results, Some(groups), "auto")
}

fn fut_by(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    // Delegate grouping to the sequential implementation, then map the
    // groups in parallel: group extraction is cheap, FUN is the hot part.
    // For simplicity reuse sequential by() shape via base_r, but through
    // map_elements.
    fut_tapply_like_by(i, args, env)
}

fn fut_tapply_like_by(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    let mut pos = user
        .iter()
        .filter(|(n, _)| n.is_none())
        .map(|(_, v)| v.clone())
        .collect::<Vec<_>>()
        .into_iter();
    let data = pos.next().ok_or_else(|| Signal::error("missing data"))?;
    let idx = pos
        .next()
        .ok_or_else(|| Signal::error("missing INDICES"))?
        .as_str_vec()
        .map_err(Signal::error)?;
    let f = as_function(&pos.next().ok_or_else(|| Signal::error("missing FUN"))?, env)?;
    let RVal::List(df) = &data else {
        return Err(Signal::error("future_by: data must be a data.frame"));
    };
    let mut groups: Vec<String> = idx.clone();
    groups.sort();
    groups.dedup();
    let mut items = Vec::with_capacity(groups.len());
    for g in &groups {
        let rows: Vec<usize> =
            idx.iter().enumerate().filter(|(_, v)| *v == g).map(|(k, _)| k).collect();
        let cols: Vec<RVal> = df
            .vals
            .iter()
            .map(|c| {
                crate::rlite::eval::index_get(
                    c,
                    &[RVal::dbl(rows.iter().map(|&r| (r + 1) as f64).collect())],
                    false,
                )
                .unwrap_or(RVal::Null)
            })
            .collect();
        let mut sub = crate::rlite::value::RList {
            vals: cols,
            names: df.names.clone(),
            class: Some("data.frame".into()),
        };
        sub.class = Some("data.frame".into());
        items.push(RVal::List(sub));
    }
    let results = map_elements(i, env, items, &f, vec![], &opts.to_map_options(false))?;
    simplify_to(results, Some(groups), "list")
}

fn fut_eapply(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    let (e, f, _) = bind2(&user, "env", "FUN");
    let target = match e.ok_or_else(|| Signal::error("missing env"))? {
        RVal::Env(e) => e.clone(),
        other => return Err(Signal::error(format!("not an environment: {}", other.class()))),
    };
    let f = as_function(f.ok_or_else(|| Signal::error("missing FUN"))?, env)?;
    let mut bindings: Vec<(String, RVal)> = crate::rlite::env::local_bindings(&target);
    bindings.sort_by(|a, b| a.0.cmp(&b.0));
    let names: Vec<String> = bindings.iter().map(|(n, _)| n.clone()).collect();
    let items: Vec<RVal> = bindings.into_iter().map(|(_, v)| v).collect();
    let results = map_elements(i, env, items, &f, vec![], &opts.to_map_options(false))?;
    simplify_to(results, Some(names), "list")
}

/// future_replicate(n, expr, future.seed = TRUE): special form — each
/// replication is one foreach-style element with its own RNG stream.
fn fut_replicate(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let mut n = None;
    let mut expr = None;
    let mut optpairs: Vec<(String, RVal)> = Vec::new();
    let mut pos = 0;
    for a in args {
        match a.name.as_deref() {
            Some(name) if name.starts_with("future.") => {
                let v = i.eval(&a.value, env)?;
                optpairs.push((name.to_string(), v));
            }
            Some("n") => n = Some(i.eval(&a.value, env)?.as_usize().map_err(Signal::error)?),
            Some("expr") => expr = Some(a.value.clone()),
            Some("simplify") => {}
            None => {
                match pos {
                    0 => n = Some(i.eval(&a.value, env)?.as_usize().map_err(Signal::error)?),
                    1 => expr = Some(a.value.clone()),
                    _ => {}
                }
                pos += 1;
            }
            _ => {}
        }
    }
    let n = n.ok_or_else(|| Signal::error("future_replicate: missing n"))?;
    let expr = expr.ok_or_else(|| Signal::error("future_replicate: missing expr"))?;
    let mut opts = options_from_pairs(&optpairs);
    if opts.seed.is_none() {
        opts.seed = Some(crate::transpile::SeedSetting::True);
    }
    let bindings: Vec<Vec<(String, RVal)>> = (0..n).map(|_| vec![]).collect();
    let results = foreach_elements(i, env, bindings, &expr, &opts.to_map_options(true))?;
    simplify_to(results, None, "auto")
}

fn fut_filter(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    let (f, x, _) = bind2(&user, "f", "x");
    let f = as_function(f.ok_or_else(|| Signal::error("missing f"))?, env)?;
    let x = x.ok_or_else(|| Signal::error("missing x"))?.clone();
    let elems = x.iter_elements();
    let flags =
        map_elements(i, env, elems.clone(), &f, vec![], &opts.to_map_options(false))?;
    let mut kept = Vec::new();
    for (e, flag) in elems.into_iter().zip(&flags) {
        if flag.as_bool().map_err(Signal::error)? {
            kept.push(e);
        }
    }
    match x {
        RVal::List(_) => Ok(RVal::list(kept)),
        _ => crate::rlite::builtins::core::combine(kept.into_iter().map(|v| (None, v)).collect()),
    }
}

/// future_kernapply: chunk the series with kernel-width overlap so the
/// concatenated per-chunk convolutions equal the sequential result.
fn fut_kernapply(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let (user, opts) = split_future_opts(&args);
    let (x, k, _) = bind2(&user, "x", "k");
    let x = x.ok_or_else(|| Signal::error("missing x"))?.as_dbl_vec().map_err(Signal::error)?;
    let k = k.ok_or_else(|| Signal::error("missing k"))?.clone();
    let kv = k.as_dbl_vec().map_err(Signal::error)?;
    let m = kv.len();
    if x.len() < m {
        return Ok(RVal::dbl(vec![]));
    }
    let workers = i.session.workers().max(1);
    let out_len = x.len() - m + 1;
    let per = out_len.div_ceil(workers);
    let mut items = Vec::new();
    let mut s = 0;
    while s < out_len {
        let e = (s + per).min(out_len);
        // Overlap: chunk needs x[s .. e+m-1].
        items.push(RVal::dbl(x[s..(e + m - 1)].to_vec()));
        s = e;
    }
    let shim_expr = crate::rlite::parse_expr("function(chunk, k) kernapply(chunk, k)")
        .map_err(Signal::error)?;
    let shim = i.eval(&shim_expr, env)?;
    let results = map_elements(
        i,
        env,
        items,
        &shim,
        vec![(Some("k".into()), k)],
        &opts.to_map_options(false),
    )?;
    let mut out = Vec::with_capacity(out_len);
    for r in results {
        out.extend(r.as_dbl_vec().map_err(Signal::error)?);
    }
    Ok(RVal::dbl(out))
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn future_lapply_matches_lapply() {
        let seq = run("lapply(1:10, function(x) x^2)");
        let par = run(
            "plan(multicore, workers = 3)\nfuture.apply::future_lapply(1:10, function(x) x^2)",
        );
        assert_eq!(seq, par);
    }

    #[test]
    fn future_sapply_simplifies() {
        let v = run("plan(multicore, workers = 2)\nfuture.apply::future_sapply(1:4, sqrt)");
        assert_eq!(v.len(), 4);
        assert!((v.as_dbl_vec().unwrap()[3] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn future_mapply_zips() {
        let v = run(
            "plan(multicore, workers = 2)\nfuture.apply::future_mapply(function(a, b) a + b, 1:3, c(10, 20, 30))",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn future_replicate_seeded() {
        let a = run("futureSeed(7)\nfuture.apply::future_replicate(3, rnorm(2))");
        let b = run("futureSeed(7)\nfuture.apply::future_replicate(3, rnorm(2))");
        assert_eq!(a, b);
    }

    #[test]
    fn future_kernapply_matches_sequential() {
        let seq = run("kernapply(c(1, 2, 3, 4, 5, 6, 7, 8), c(0.25, 0.5, 0.25))");
        let par = run(
            "plan(multicore, workers = 3)\nfuture.apply::future_kernapply(c(1, 2, 3, 4, 5, 6, 7, 8), c(0.25, 0.5, 0.25))",
        );
        assert_eq!(seq, par);
    }

    #[test]
    fn future_filter_matches() {
        let v = run("plan(multicore, workers = 2)\nfuture.apply::future_Filter(function(x) x %% 2 == 0, 1:10)");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![2.0, 4.0, 6.0, 8.0, 10.0]);
    }

    #[test]
    fn future_tapply_groups() {
        let v = run(
            "plan(multicore, workers = 2)\nfuture.apply::future_tapply(c(1, 2, 3, 4), c(\"a\", \"b\", \"a\", \"b\"), sum)",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), vec![4.0, 6.0]);
    }
}
