//! plyr (paper Table 1): the split-apply-combine toolkit (Wickham 2011).
//! Naming scheme: `<in><out>ply` with in/out ∈ {l=list, a=array/vector,
//! d=data.frame, m=multi-arg}. Futurization goes through plyr's own
//! `.parallel = TRUE` sub-API (served by doFuture underneath), which the
//! transpiler sets.

use super::{as_function, simplify_to};
use crate::future_core::driver::map_elements;
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal};
use crate::transpile::{options_from_value, FuturizeOptions};

pub fn register(r: &mut Reg) {
    for (name, out) in [("llply", 'l'), ("laply", 'a'), ("ldply", 'd')] {
        r.normal("plyr", name, move |i, a, e| list_in_ply(i, a, e, out));
    }
    for (name, out) in [("alply", 'l'), ("aaply", 'a'), ("adply", 'd')] {
        r.normal("plyr", name, move |i, a, e| list_in_ply(i, a, e, out));
    }
    for (name, out) in [("dlply", 'l'), ("daply", 'a'), ("ddply", 'd')] {
        r.normal("plyr", name, move |i, a, e| df_in_ply(i, a, e, out));
    }
    for (name, out) in [("mlply", 'l'), ("maply", 'a'), ("mdply", 'd')] {
        r.normal("plyr", name, move |i, a, e| multi_in_ply(i, a, e, out));
    }
}

fn split_opts(args: &Args) -> (Args, bool, FuturizeOptions) {
    let mut user = Vec::new();
    let mut parallel = false;
    let mut opts = FuturizeOptions::default();
    for (name, v) in &args.items {
        match name.as_deref() {
            Some(".parallel") => parallel = v.as_bool().unwrap_or(false),
            Some(".futurize_opts") => opts = options_from_value(v),
            Some(".progress") | Some(".inform") => {}
            _ => user.push((name.clone(), v.clone())),
        }
    }
    (Args::new(user), parallel, opts)
}

fn run_map(
    i: &mut Interp,
    env: &EnvRef,
    items: Vec<RVal>,
    f: &RVal,
    extra: Vec<(Option<String>, RVal)>,
    parallel: bool,
    opts: &FuturizeOptions,
) -> Result<Vec<RVal>, Signal> {
    if parallel {
        map_elements(i, env, items, f, extra, &opts.to_map_options(false))
    } else {
        super::seq_map(i, env, &items, f, &extra)
    }
}

fn shape_output(results: Vec<RVal>, names: Option<Vec<String>>, out: char) -> EvalResult {
    match out {
        'l' => simplify_to(results, names, "list"),
        'a' => simplify_to(results, names, "auto"),
        'd' => {
            // rbind per-element records into a data.frame: each result
            // must be a named list/df-row; columns are unioned.
            let mut cols: Vec<String> = Vec::new();
            for r in &results {
                if let Some(ns) = r.names() {
                    for n in ns {
                        if !cols.contains(n) {
                            cols.push(n.clone());
                        }
                    }
                }
            }
            if cols.is_empty() {
                // Fall back: single unnamed column V1.
                let vals: Result<Vec<f64>, _> = results.iter().map(|r| r.as_f64()).collect();
                let vals = vals.map_err(Signal::error)?;
                let mut l = RList::named(vec![RVal::dbl(vals)], vec!["V1".into()]);
                l.class = Some("data.frame".into());
                return Ok(RVal::List(l));
            }
            let mut columns: Vec<Vec<RVal>> = vec![Vec::new(); cols.len()];
            for r in &results {
                for (ci, cname) in cols.iter().enumerate() {
                    let cell = match r {
                        RVal::List(l) => l.get(cname).cloned().unwrap_or(RVal::Null),
                        other => {
                            let idx = other
                                .names()
                                .and_then(|ns| ns.iter().position(|n| n == cname));
                            match idx {
                                Some(k) => other.iter_elements()[k].clone(),
                                None => RVal::Null,
                            }
                        }
                    };
                    columns[ci].push(cell);
                }
            }
            let col_vals: Vec<RVal> = columns
                .into_iter()
                .map(|cells| {
                    crate::rlite::builtins::core::combine(
                        cells.into_iter().map(|v| (None, v)).collect(),
                    )
                    .unwrap_or(RVal::Null)
                })
                .collect();
            let mut l = RList::named(col_vals, cols);
            l.class = Some("data.frame".into());
            Ok(RVal::List(l))
        }
        other => Err(Signal::error(format!("plyr: unknown output shape '{other}'"))),
    }
}

/// llply / laply / ldply (and the a* family over vectors).
fn list_in_ply(i: &mut Interp, args: Args, env: &EnvRef, out: char) -> EvalResult {
    let (args, parallel, opts) = split_opts(&args);
    let b = args.bind(&[".data", ".fun"]);
    let data = b.req(0, ".data")?;
    let f = as_function(&b.req(1, ".fun")?, env)?;
    let results = run_map(i, env, data.iter_elements(), &f, b.rest, parallel, &opts)?;
    shape_output(results, data.element_names(), out)
}

/// ddply / dlply / daply: split a data.frame by grouping variables.
fn df_in_ply(i: &mut Interp, args: Args, env: &EnvRef, out: char) -> EvalResult {
    let (args, parallel, opts) = split_opts(&args);
    let b = args.bind(&[".data", ".variables", ".fun"]);
    let data = b.req(0, ".data")?;
    let vars = b.req(1, ".variables")?.as_str_vec().map_err(Signal::error)?;
    let f = as_function(&b.req(2, ".fun")?, env)?;
    let RVal::List(df) = &data else {
        return Err(Signal::error("ddply: .data must be a data.frame"));
    };
    // Group labels: join the values of the grouping columns per row.
    let nrow = df.vals.first().map(|c| c.len()).unwrap_or(0);
    let mut labels = vec![String::new(); nrow];
    for v in &vars {
        let col = df
            .get(v)
            .ok_or_else(|| Signal::error(format!("ddply: no column '{v}'")))?
            .as_str_vec()
            .map_err(Signal::error)?;
        for (r, lab) in labels.iter_mut().enumerate() {
            if !lab.is_empty() {
                lab.push('.');
            }
            lab.push_str(&col[r]);
        }
    }
    let mut groups: Vec<String> = labels.clone();
    groups.sort();
    groups.dedup();
    let mut items = Vec::with_capacity(groups.len());
    for g in &groups {
        let rows: Vec<usize> =
            labels.iter().enumerate().filter(|(_, l)| *l == g).map(|(k, _)| k).collect();
        let cols: Vec<RVal> = df
            .vals
            .iter()
            .map(|c| {
                crate::rlite::eval::index_get(
                    c,
                    &[RVal::dbl(rows.iter().map(|&r| (r + 1) as f64).collect())],
                    false,
                )
                .unwrap_or(RVal::Null)
            })
            .collect();
        let mut sub = RList { vals: cols, names: df.names.clone(), class: None };
        sub.class = Some("data.frame".into());
        items.push(RVal::List(sub));
    }
    let results = run_map(i, env, items, &f, b.rest, parallel, &opts)?;
    shape_output(results, Some(groups), out)
}

/// mlply / maply / mdply: rows of a data.frame (or list of vectors) as
/// call arguments.
fn multi_in_ply(i: &mut Interp, args: Args, env: &EnvRef, out: char) -> EvalResult {
    let (args, parallel, opts) = split_opts(&args);
    let b = args.bind(&[".data", ".fun"]);
    let data = b.req(0, ".data")?;
    let f = as_function(&b.req(1, ".fun")?, env)?;
    let RVal::List(df) = &data else {
        return Err(Signal::error("mlply: .data must be a data.frame or list of columns"));
    };
    let nrow = df.vals.first().map(|c| c.len()).unwrap_or(0);
    let names = df.names.clone().unwrap_or_default();
    let mut items = Vec::with_capacity(nrow);
    for r in 0..nrow {
        let row: Vec<RVal> = df.vals.iter().map(|c| c.iter_elements()[r].clone()).collect();
        let mut l = RList::plain(row);
        if !names.is_empty() {
            l.names = Some(names.clone());
        }
        items.push(RVal::List(l));
    }
    let results = if parallel {
        super::future_apply::map_tuple(i, env, items, &f, &b.rest, &opts, names.len())?
    } else {
        let mut out_vals = Vec::with_capacity(items.len());
        for item in items {
            let RVal::List(l) = item else { unreachable!() };
            let call_args: Vec<(Option<String>, RVal)> = l
                .vals
                .iter()
                .enumerate()
                .map(|(k, v)| {
                    let nm = l
                        .names
                        .as_ref()
                        .and_then(|ns| ns.get(k))
                        .filter(|s| !s.is_empty())
                        .cloned();
                    (nm, v.clone())
                })
                .collect();
            out_vals.push(i.call_function(&f, call_args, env)?);
        }
        out_vals
    };
    shape_output(results, None, out)
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn llply_matches_lapply() {
        let a = run("llply(1:3, function(x) x * 3)");
        let b = run("lapply(1:3, function(x) x * 3)");
        assert_eq!(a, b);
    }

    #[test]
    fn laply_simplifies() {
        assert_eq!(run("laply(1:3, function(x) x + 1)"), RVal::dbl(vec![2.0, 3.0, 4.0]));
    }

    #[test]
    fn llply_parallel_matches_sequential() {
        let seq = run("llply(1:8, function(x) x^2)");
        let par = run("plan(multicore, workers = 3)\nllply(1:8, function(x) x^2, .parallel = TRUE)");
        assert_eq!(seq, par);
    }

    #[test]
    fn ddply_groups_data_frame() {
        let v = run(
            "df <- data.frame(g = c(\"a\", \"b\", \"a\"), x = c(1, 2, 3))\n\
             r <- ddply(df, \"g\", function(d) list(total = sum(d$x)))\nr$total",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), vec![4.0, 2.0]);
    }

    #[test]
    fn mlply_rows_as_args() {
        let v = run(
            "df <- data.frame(a = 1:2, b = c(10, 20))\n\
             r <- mlply(df, function(a, b) a + b)\nunlist(r)",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), vec![11.0, 22.0]);
    }

    #[test]
    fn ldply_binds_rows() {
        let v = run(
            "r <- ldply(1:2, function(x) list(v = x, sq = x^2))\nr$sq",
        );
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 4.0]);
    }
}
