//! The streaming dispatch core: an incremental, backpressured
//! [`FutureSet`] that replaces the old batch-synchronous `run_chunks`
//! loop.
//!
//! Differences from the batch driver it replaces:
//!
//! - **Shared task contexts.** The function, extra arguments, and
//!   globals of a map call are registered with the backend once as a
//!   [`TaskContext`](super::TaskContext) (process backends forward it
//!   once per worker); chunk payloads reference it by id. Serialized
//!   payload volume drops from O(chunks × payload) to O(workers ×
//!   payload).
//! - **Incremental dispatch with backpressure.** Only
//!   [`ChunkPolicy::in_flight_cap`] chunks (≈ `scheduling × workers`)
//!   are in flight at a time; the next chunk is fed to the backend as
//!   each `Done` event arrives. Late chunks are therefore assigned to
//!   whichever worker frees up first — which is what makes
//!   [`ChunkPolicy::Adaptive`] (large chunks early, small chunks late)
//!   eliminate stragglers without per-element messaging cost.
//! - **Streaming reduction.** Outcomes are folded into the result
//!   vector the moment they arrive instead of being buffered until the
//!   last chunk completes; captured logs are relayed incrementally, in
//!   input order, as each prefix of chunks completes.
//! - **Fail-fast cancellation.** With `stop_on_error`, the first worker
//!   error triggers `Backend::cancel_queued()`, in-flight tasks are
//!   drained, and the error surfaces without executing the remaining
//!   queued chunks (structured concurrency, paper §5.3).
//! - **Worker-loss recovery.** A [`BackendEvent::WorkerLost`] for an
//!   in-flight chunk either resubmits it (same elements, same seeds,
//!   fresh task id) while the `futurize(retries = N)` budget lasts, or
//!   surfaces a `FutureError`-style condition naming the lost worker
//!   and task — the map call completes or errors, it never hangs on a
//!   `Done` that cannot arrive.

use std::collections::HashMap;
use std::sync::Arc;

use super::driver::{now_unix, MapOptions, MapRun, SeedOption};
use super::{ContextBody, TaskContext, TaskKind, TaskOutcome, TaskPayload, TraceEvent};
use crate::backend::blobstore::{self, CacheSource};
use crate::backend::BackendEvent;
use crate::rlite::conditions::RCondition;
use crate::rlite::eval::{Interp, Signal};
use crate::rlite::serialize::{
    digest_bindings, digest_items, digest_val, from_wire_owned, WireSlice, WireVal,
};
use crate::rlite::value::RVal;
use crate::rng::RngState;
use crate::scheduling::make_chunks;
use crate::transpile::reduce::ReduceState;

/// The per-element inputs of one map call, frozen once behind an `Arc`
/// and sliced into chunk payloads on demand (at submit time, not
/// upfront). Each chunk gets a [`WireSlice::shared`] window into the
/// same storage — the zero-copy fast path: submitting a chunk to an
/// in-process backend moves an `Arc` bump and two indices, never the
/// elements themselves. Process backends serialize the window contents
/// at write time, so nothing changes for them semantically.
pub enum ElementSource {
    /// Items for `ContextBody::Map`.
    Items(Arc<Vec<WireVal>>),
    /// Per-iteration bindings for `ContextBody::Foreach`.
    Bindings(Arc<Vec<Vec<(String, WireVal)>>>),
}

impl ElementSource {
    pub fn len(&self) -> usize {
        match self {
            ElementSource::Items(v) => v.len(),
            ElementSource::Bindings(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Build the task kind for one chunk window. With `digest` set the
    /// element storage is resident in the workers' blob stores (the
    /// parent shipped it via `put_blob`), so the payload carries only
    /// the digest and the window indices — O(1) bytes per chunk instead
    /// of O(chunk) — and the worker re-slices its cached copy.
    fn slice_kind(
        &self,
        ctx: u64,
        digest: Option<u64>,
        start: usize,
        end: usize,
        seeds: &Option<Vec<RngState>>,
    ) -> TaskKind {
        let seeds = seeds.as_ref().map(|s| s[start..end].to_vec());
        match (self, digest) {
            (ElementSource::Items(_), Some(digest)) => {
                TaskKind::MapSliceRef { ctx, digest, start, end, seeds }
            }
            (ElementSource::Bindings(_), Some(digest)) => {
                TaskKind::ForeachSliceRef { ctx, digest, start, end, seeds }
            }
            (ElementSource::Items(items), None) => TaskKind::MapSlice {
                ctx,
                items: WireSlice::shared(items.clone(), start, end),
                seeds,
            },
            (ElementSource::Bindings(bindings), None) => TaskKind::ForeachSlice {
                ctx,
                bindings: WireSlice::shared(bindings.clone(), start, end),
                seeds,
            },
        }
    }
}

/// A set of futures covering one map call: owns the chunk plan, the
/// in-flight window, and the incremental reduction state.
pub struct FutureSet {
    ctx: Arc<TaskContext>,
    source: ElementSource,
    /// Content digest of the full element vector when it rides the
    /// data-plane cache: chunks then ship digest-ref payloads and the
    /// workers slice their resident copy.
    items_digest: Option<u64>,
    seeds: Option<Vec<RngState>>,
    /// Sys.sleep scale, stamped onto every chunk payload.
    time_scale: f64,
    /// Relay stdout? Stamped onto every chunk payload.
    capture_stdout: bool,
    /// Contiguous chunk ranges, in input order.
    chunks: Vec<(usize, usize)>,
    /// Backpressure: max chunks submitted but not yet `Done`.
    cap: usize,
    /// Next chunk index to submit.
    next_chunk: usize,
    /// task id → (chunk index, chunk start).
    in_flight: HashMap<u64, (usize, usize)>,
    /// Completed chunks not yet relayed (waiting on an earlier chunk),
    /// keyed by chunk index.
    pending_relay: HashMap<usize, TaskOutcome>,
    /// Next chunk index due for ordered relay.
    relay_cursor: usize,
    /// Per-element results, filled as outcomes stream in.
    out: Vec<Option<RVal>>,
    /// First worker error in input order. Set exclusively by the
    /// ordered relay, which visits chunks in ascending index order, so
    /// first-set wins and the result is deterministic under races.
    first_error: Option<RCondition>,
    /// Any error observed at all — set at arrival time, before the
    /// ordered relay catches up, so fail-fast cancellation is prompt.
    error_seen: bool,
    /// Set once `cancel_queued` has fired; no further chunks are fed.
    cancelled: bool,
    /// Worker-crash resubmissions consumed so far, per chunk index —
    /// the `futurize(retries = N)` budget is per chunk, so one flaky
    /// worker can't starve an unrelated straggler of its retries.
    attempts: HashMap<usize, u32>,
    /// Parent half of the fused-reduction combine tree, present iff the
    /// context carries a [`ReducePlan`](crate::transpile::reduce::ReducePlan).
    reduce_state: Option<ReduceState>,
    /// Per-chunk reduction contributions, parked until their
    /// chunk-ordered fold turn in [`FutureSet::relay_ready`].
    reduce_pending: HashMap<usize, Contribution>,
    trace: Vec<TraceEvent>,
    t0: f64,
}

/// One chunk's contribution to a fused reduction: a worker-folded
/// partial aggregate, or the full slice values when the slice failed
/// the plan's exactness gate. Folding happens in the ordered relay —
/// exactly once per chunk index, which also makes retried chunks count
/// once (only the winning resubmission's outcome is ever absorbed).
enum Contribution {
    Partial { value: RVal, n: u64, m: u64 },
    Values(Vec<RVal>),
}

impl FutureSet {
    pub fn new(
        ctx: Arc<TaskContext>,
        source: ElementSource,
        items_digest: Option<u64>,
        seeds: Option<Vec<RngState>>,
        workers: usize,
        time_scale: f64,
        opts: &MapOptions,
    ) -> Self {
        let n = source.len();
        let chunks = make_chunks(n, workers, &opts.policy);
        let cap = opts.policy.in_flight_cap(workers);
        let reduce_state = ctx.reduce.map(ReduceState::new);
        FutureSet {
            ctx,
            source,
            items_digest,
            seeds,
            time_scale,
            capture_stdout: opts.stdout,
            chunks,
            cap,
            next_chunk: 0,
            in_flight: HashMap::new(),
            pending_relay: HashMap::new(),
            relay_cursor: 0,
            out: (0..n).map(|_| None).collect(),
            first_error: None,
            error_seen: false,
            cancelled: false,
            attempts: HashMap::new(),
            reduce_state,
            reduce_pending: HashMap::new(),
            trace: Vec::new(),
            t0: now_unix(),
        }
    }

    /// Drive the set to completion on the session's backend: register
    /// the shared context, stream chunks under backpressure, reduce
    /// outcomes incrementally, and fail fast on worker errors when
    /// `stop_on_error` is set. Returns per-element values in input
    /// order — or the folded aggregate when the context carries a
    /// reduction plan.
    pub fn run(mut self, i: &mut Interp, opts: &MapOptions) -> Result<MapRun, Signal> {
        let n = self.source.len();
        if n == 0 {
            // No chunks ran: the trace of this call is empty, not the
            // previous call's. An empty input never reduces worker-side
            // (there is nothing to fold); callers apply the operation's
            // empty-case identity themselves.
            i.session.last_trace.clear();
            return Ok(MapRun::Values(vec![]));
        }
        {
            let backend = i.session.backend().map_err(Signal::error)?;
            backend.register_context(self.ctx.clone()).map_err(Signal::error)?;
        }
        // Per-depth ledger bookkeeping: the drive loop below may stash
        // outcomes for enclosing loops (and vice versa); registering the
        // loop lets the ledger prune unclaimed strays once the last
        // active loop exits.
        i.session.pending.enter();
        let result = self.drive(i, opts);
        i.session.pending.exit();
        // Always release the context, even on the error path: process
        // workers cache contexts by id and would otherwise leak them.
        let ctx_id = self.ctx.id;
        if let Ok(backend) = i.session.backend() {
            let _ = backend.drop_context(ctx_id);
        }
        i.session.last_trace = std::mem::take(&mut self.trace);
        i.session.last_trace.sort_by(|a, b| a.task_id.cmp(&b.task_id));
        let () = result?;
        if let Some(cond) = self.first_error.take() {
            return Err(Signal::Error(cond));
        }
        if self.error_seen {
            // Unreachable in practice (the erroring chunk always relays
            // before the drain finishes), but never panic on the expect
            // below if that invariant is ever broken.
            return Err(Signal::error("a future failed but its error was lost"));
        }
        if let Some(state) = self.reduce_state.take() {
            // Reduce mode: per-element slots were never filled; the
            // ordered relay folded every chunk's contribution already.
            return Ok(MapRun::Reduced(state.finish()?));
        }
        Ok(MapRun::Values(
            self.out
                .into_iter()
                .map(|v| v.expect("all elements resolved"))
                .collect(),
        ))
    }

    /// The event loop: fill the in-flight window, consume one event,
    /// repeat until every submitted chunk has resolved and nothing is
    /// left to submit.
    fn drive(&mut self, i: &mut Interp, opts: &MapOptions) -> Result<(), Signal> {
        loop {
            if let Err(sig) = self.fill_window(i) {
                self.abort(i);
                return Err(sig);
            }
            // Reclaim outcomes a nested dispatch (a futurized map run
            // from inside a condition handler) stole off the shared
            // event channel and stashed for us.
            if let Err(sig) = self.reclaim_stashed(i, opts) {
                self.abort(i);
                return Err(sig);
            }
            self.maybe_cancel(i, opts);
            if self.in_flight.is_empty() {
                // Nothing running and (all chunks submitted, or feeding
                // stopped after cancellation) — done.
                return Ok(());
            }
            let ev = {
                let backend = i.session.backend().map_err(Signal::error)?;
                backend.next_event().map_err(Signal::error)?
            };
            match ev {
                BackendEvent::Progress { cond, .. } => {
                    // Near-live relay (paper §4.10): progress conditions
                    // pass through the parent handler stack immediately.
                    if let Err(sig) = i.signal_condition(cond) {
                        self.abort(i);
                        return Err(sig);
                    }
                }
                BackendEvent::Done(outcome) => {
                    if let Err(sig) = self.absorb(i, outcome, opts) {
                        self.abort(i);
                        return Err(sig);
                    }
                }
                BackendEvent::WorkerLost { worker, task } => {
                    if let Err(sig) = self.handle_worker_lost(i, worker, task, opts) {
                        self.abort(i);
                        return Err(sig);
                    }
                }
            }
            self.maybe_cancel(i, opts);
        }
    }

    /// Fail fast: once an error has been observed under `stop_on_error`,
    /// cancel everything queued; in-flight tasks drain through the
    /// normal loop.
    fn maybe_cancel(&mut self, i: &mut Interp, opts: &MapOptions) {
        if opts.stop_on_error && self.error_seen && !self.cancelled {
            self.cancelled = true;
            let ids = match i.session.backend() {
                Ok(backend) => backend.cancel_queued(),
                Err(_) => vec![],
            };
            self.forget_cancelled(&ids);
        }
    }

    /// Worker-loss recovery (the supervision contract's dispatch half):
    /// while the chunk's `retries` budget lasts, resubmit it — same
    /// elements, same per-element seeds (so `seed = TRUE` results are
    /// invariant across the resubmit), fresh task id; once exhausted,
    /// surface a `FutureError`-style condition naming the worker and
    /// task, routed through the ordered relay like any chunk error.
    fn handle_worker_lost(
        &mut self,
        i: &mut Interp,
        worker: usize,
        task: Option<u64>,
        opts: &MapOptions,
    ) -> Result<(), Signal> {
        let Some(id) = task else {
            // The worker was idle: nothing of anyone's was lost and the
            // backend has already replaced it.
            return Ok(());
        };
        let Some((chunk_idx, _start)) = self.in_flight.remove(&id) else {
            // Not ours: a low-level future() or an enclosing map call —
            // record the loss for its owner (see SessionState::lost_tasks).
            i.session.lost_tasks.insert(id, worker);
            return Ok(());
        };
        let attempts = self.attempts.entry(chunk_idx).or_insert(0);
        if !self.cancelled && *attempts < opts.retries {
            *attempts += 1;
            let attempt = *attempts;
            i.signal_condition(RCondition::warning_cond(format!(
                "futurize: worker {worker} was lost while running task {id}; \
                 resubmitting its chunk (retry {attempt} of {})",
                opts.retries
            )))?;
            return self.submit_chunk(i, chunk_idx);
        }
        self.error_seen = true;
        let backend = i.session.backend().map(|b| b.name()).unwrap_or("future");
        let cond = super::worker_lost_condition(backend, worker, id, Some(opts.retries));
        let now = now_unix();
        self.pending_relay.insert(
            chunk_idx,
            TaskOutcome {
                id,
                values: Err(cond),
                log: Default::default(),
                worker,
                started_unix: now,
                finished_unix: now,
                nested_workers: 0,
                partial: None,
            },
        );
        self.relay_ready(i, opts)
    }

    /// Submit chunk `chunk_idx` (first attempt or crash resubmission):
    /// build the slice payload under a fresh task id, hand it to the
    /// backend, and track it in flight — only after a successful submit,
    /// so a failed submit never leaves a task id the drain loop would
    /// wait on forever.
    fn submit_chunk(&mut self, i: &mut Interp, chunk_idx: usize) -> Result<(), Signal> {
        let (start, end) = self.chunks[chunk_idx];
        let id = i.session.fresh_task_id();
        let payload = TaskPayload {
            id,
            kind: self.source.slice_kind(self.ctx.id, self.items_digest, start, end, &self.seeds),
            time_scale: self.time_scale,
            capture_stdout: self.capture_stdout,
        };
        let backend = i.session.backend().map_err(Signal::error)?;
        backend.submit(payload).map_err(Signal::error)?;
        self.in_flight.insert(id, (chunk_idx, start));
        Ok(())
    }

    /// Absorb any of this set's outcomes that a nested dispatch pulled
    /// off the backend channel and parked in `session.pending`.
    fn reclaim_stashed(&mut self, i: &mut Interp, opts: &MapOptions) -> Result<(), Signal> {
        // Losses of ours another event loop observed on the shared
        // channel and recorded in the session-wide ledger.
        loop {
            let Some(id) = self
                .in_flight
                .keys()
                .copied()
                .find(|id| i.session.lost_tasks.contains_key(id))
            else {
                break;
            };
            let worker = i.session.lost_tasks.remove(&id).unwrap_or(0);
            self.handle_worker_lost(i, worker, Some(id), opts)?;
        }
        loop {
            let Some(id) = self
                .in_flight
                .keys()
                .copied()
                .find(|id| i.session.pending.is_ready(*id))
            else {
                return Ok(());
            };
            let Some(outcome) = i.session.pending.take_ready(id) else {
                return Ok(());
            };
            self.absorb(i, outcome, opts)?;
        }
    }

    /// Submit chunks until the backpressure cap is reached (or feeding
    /// has been cancelled).
    fn fill_window(&mut self, i: &mut Interp) -> Result<(), Signal> {
        while !self.cancelled
            && self.next_chunk < self.chunks.len()
            && self.in_flight.len() < self.cap
        {
            self.submit_chunk(i, self.next_chunk)?;
            self.next_chunk += 1;
        }
        Ok(())
    }

    /// Fold one outcome into the result vector and relay any newly
    /// contiguous prefix of chunk logs, preserving the input-order relay
    /// contract of the batch driver.
    fn absorb(
        &mut self,
        i: &mut Interp,
        outcome: TaskOutcome,
        opts: &MapOptions,
    ) -> Result<(), Signal> {
        let Some((chunk_idx, start)) = self.in_flight.remove(&outcome.id) else {
            // Not ours: an outstanding low-level future(), or a chunk of
            // an enclosing map call whose events we pulled off the
            // shared channel (nested dispatch from a condition handler).
            // Stash it in the session's pending table; wait_for() and
            // the enclosing drive loop both reclaim from there.
            stash_foreign_outcome(i, outcome);
            return Ok(());
        };
        self.trace.push(TraceEvent {
            task_id: outcome.id,
            worker: outcome.worker,
            start: outcome.started_unix - self.t0,
            end: outcome.finished_unix - self.t0,
            inner_workers: outcome.nested_workers,
        });
        // Streaming reduction: values land in their slots immediately.
        // Values are taken out of the outcome (relay only needs the log
        // and the error case), so the decoded buffers *move* into the
        // result vector — zero re-copies on the in-process fast path.
        // In reduce mode the chunk's contribution (a worker-folded
        // partial, or full values when the exactness gate rejected the
        // slice) is parked instead, to be folded in chunk order by the
        // relay below.
        let mut outcome = outcome;
        match std::mem::replace(&mut outcome.values, Ok(vec![])) {
            Ok(vals) => {
                if self.reduce_state.is_some() {
                    let contrib = match outcome.partial.take() {
                        Some(p) => Contribution::Partial {
                            value: from_wire_owned(p.value, &i.global),
                            n: p.n,
                            m: p.m,
                        },
                        None => Contribution::Values(
                            vals.into_iter().map(|w| from_wire_owned(w, &i.global)).collect(),
                        ),
                    };
                    self.reduce_pending.insert(chunk_idx, contrib);
                } else {
                    for (k, w) in vals.into_iter().enumerate() {
                        self.out[start + k] = Some(from_wire_owned(w, &i.global));
                    }
                }
            }
            Err(cond) => {
                self.error_seen = true;
                outcome.values = Err(cond);
            }
        }
        self.pending_relay.insert(chunk_idx, outcome);
        self.relay_ready(i, opts)
    }

    /// Relay logs (and record errors) for every chunk whose predecessors
    /// have all been relayed.
    fn relay_ready(&mut self, i: &mut Interp, opts: &MapOptions) -> Result<(), Signal> {
        while let Some(outcome) = self.pending_relay.remove(&self.relay_cursor) {
            let chunk_idx = self.relay_cursor;
            self.relay_cursor += 1;
            // Fold this chunk's reduction contribution now, in chunk
            // order — the fold visits each chunk index exactly once, so
            // a resubmitted chunk can never double-count its partial.
            if let Some(state) = self.reduce_state.as_mut() {
                match self.reduce_pending.remove(&chunk_idx) {
                    Some(Contribution::Partial { value, n, m }) => {
                        state.push_partial(value, n, m)?;
                    }
                    Some(Contribution::Values(vals)) => state.push_values(&vals)?,
                    // Error chunks contribute nothing; the error itself
                    // surfaces through first_error below.
                    None => {}
                }
            }
            if opts.stdout || opts.conditions {
                let mut log = outcome.log.clone();
                if !opts.stdout {
                    log.stdout.clear();
                }
                if !opts.conditions {
                    log.conditions.clear();
                }
                i.relay(&log)?;
            }
            // RNG misuse detection (paper §5.2 recommendation 3).
            if outcome.log.rng_used && matches!(opts.seed, SeedOption::False) {
                i.signal_condition(RCondition::warning_cond(
                    "UNRELIABLE VALUE: one of the futures unexpectedly generated random numbers \
                     without declaring so. Use 'seed = TRUE' to resolve this."
                        .to_string(),
                ))?;
            }
            if let Err(cond) = outcome.values {
                if self.first_error.is_none() {
                    self.first_error = Some(cond);
                }
            }
        }
        Ok(())
    }

    /// Stop waiting on tasks the backend confirmed it cancelled —
    /// without this, the drive/drain loops would block forever on
    /// `Done` events that can no longer arrive.
    fn forget_cancelled(&mut self, ids: &[u64]) {
        for id in ids {
            self.in_flight.remove(id);
        }
    }

    /// Best-effort teardown after a relay/handler error: cancel the
    /// queue and drain in-flight tasks so the persistent backend is
    /// clean for the next map call.
    fn abort(&mut self, i: &mut Interp) {
        self.cancelled = true;
        let ids = match i.session.backend() {
            Ok(backend) => backend.cancel_queued(),
            Err(_) => return,
        };
        self.forget_cancelled(&ids);
        // Discard outcomes of ours that a nested dispatch already
        // stashed — they will never arrive as fresh events.
        let stashed: Vec<u64> = self
            .in_flight
            .keys()
            .copied()
            .filter(|id| i.session.pending.is_ready(*id))
            .collect();
        for id in stashed {
            i.session.pending.discard(id);
            self.in_flight.remove(&id);
        }
        while !self.in_flight.is_empty() {
            let ev = match i.session.backend() {
                Ok(backend) => backend.next_event(),
                Err(_) => break,
            };
            match ev {
                Ok(BackendEvent::Done(outcome)) => {
                    if self.in_flight.remove(&outcome.id).is_none() {
                        stash_foreign_outcome(i, outcome);
                    }
                }
                Ok(BackendEvent::Progress { .. }) => {}
                Ok(BackendEvent::WorkerLost { worker, task }) => {
                    // No retry during teardown: the lost task will never
                    // produce a Done, so just stop waiting on it (or
                    // record the loss for its owner).
                    if let Some(id) = task {
                        if self.in_flight.remove(&id).is_none() {
                            i.session.lost_tasks.insert(id, worker);
                        }
                    }
                }
                Err(_) => break,
            }
        }
    }
}

/// Route a `Done` event that doesn't belong to the current `FutureSet`
/// into the session's pending table: a low-level `future()` handle's
/// `value()`/`resolved()` looks there, and an enclosing map call's
/// drive loop reclaims its own ids from there (nested dispatch).
fn stash_foreign_outcome(i: &mut Interp, outcome: TaskOutcome) {
    i.session.pending.stash(outcome);
}

/// Does the data-plane cache apply to this call? Three gates: the
/// per-call option (`futurize(cache = "off")`), the process-wide kill
/// switch (`FUTURIZE_NO_CACHE=1`), and the backend (only process
/// backends ship bytes over a wire; in-process backends already share
/// the element `Arc`s, so caching would be pure overhead).
fn cache_active(i: &mut Interp, opts: &MapOptions) -> bool {
    opts.cache
        && blobstore::cache_enabled()
        && i.session.backend().map(|b| b.data_cache()).unwrap_or(false)
}

/// Freeze-time extraction for the data-plane cache: pull every global
/// binding at or over the blob threshold out of the inline context,
/// digest it, and queue one `CacheSource` put per *distinct* digest —
/// two bindings aliasing the same frozen vector encode once, the second
/// is a pure digest reference. Small bindings stay inline: digesting
/// and ledger lookups cost more than just shipping them.
#[allow(clippy::type_complexity)]
fn extract_cached_globals(
    globals: Vec<(String, WireVal)>,
) -> (Vec<(String, WireVal)>, Vec<(String, u64)>, Vec<(u64, CacheSource)>) {
    let mut inline = Vec::new();
    let mut cached = Vec::new();
    let mut puts: Vec<(u64, CacheSource)> = Vec::new();
    for (name, v) in globals {
        if v.approx_size() < blobstore::CACHE_MIN_BYTES {
            inline.push((name, v));
            continue;
        }
        let v = Arc::new(v);
        let d = digest_val(&v);
        if !puts.iter().any(|(pd, _)| *pd == d) {
            puts.push((d, CacheSource::Val(v)));
        }
        cached.push((name, d));
    }
    (inline, cached, puts)
}

/// Ship queued blobs to the backend's data plane under the owning
/// context id. The backend keeps the parent-side ledger: blobs already
/// resident on a worker are *not* re-sent — that is the whole point.
fn ship_blobs(
    i: &mut Interp,
    ctx_id: u64,
    puts: Vec<(u64, CacheSource)>,
) -> Result<(), Signal> {
    if puts.is_empty() {
        return Ok(());
    }
    let backend = i.session.backend().map_err(Signal::error)?;
    for (d, src) in puts {
        backend.put_blob(ctx_id, d, src).map_err(Signal::error)?;
    }
    Ok(())
}

/// Build and run a [`FutureSet`] for a map-style call.
#[allow(clippy::too_many_arguments)]
pub fn run_map(
    i: &mut Interp,
    f: WireVal,
    items: Vec<WireVal>,
    extra: Vec<(Option<String>, WireVal)>,
    globals: Vec<(String, WireVal)>,
    seeds: Option<Vec<RngState>>,
    opts: &MapOptions,
) -> Result<MapRun, Signal> {
    let nesting = i.session.nesting_for_context();
    // Freeze-time kernel recognition: matched bodies ship a fused plan
    // with the context; `FUTURIZE_NO_FUSION=1` suppresses it here, in
    // the parent, so the switch reaches process backends too. The same
    // switch governs reduction fusion: with it off the plan is never
    // attached and every chunk ships its full values.
    let kernel = crate::transpile::fusion::maybe_recognize(&f, &extra, &globals);
    let reduce = opts
        .reduce
        .filter(|_| crate::transpile::fusion::enabled())
        .map(|spec| spec.plan);
    if reduce.is_some() {
        crate::transpile::reduce::note_plan_attached();
    }
    // Parallel-safety lint, after kernel/reduce recognition (so the
    // rejection explanations are accurate) and before any backend or
    // worker exists (so `lint = "error"` raises with zero spawns).
    let lint_mode = crate::rlite::diag::effective_mode(opts.lint.mode);
    if lint_mode != crate::rlite::diag::LintMode::Off {
        let diags =
            crate::transpile::analysis::analyze_map(&f, &extra, &globals, kernel.is_some(), opts);
        crate::transpile::analysis::surface(i, &diags, lint_mode)?;
    }
    // Data-plane cache (freeze-time half): on a cache-capable backend,
    // oversized globals and the frozen element vector ship as
    // content-addressed blobs — once per worker, referenced by digest
    // thereafter — instead of riding every context and chunk payload.
    let use_cache = cache_active(i, opts);
    let (globals, cached_globals, mut puts) =
        if use_cache { extract_cached_globals(globals) } else { (globals, vec![], vec![]) };
    let items = Arc::new(items);
    let items_digest = if use_cache
        && items.iter().map(|v| v.approx_size()).sum::<usize>() >= blobstore::CACHE_MIN_BYTES
    {
        let d = digest_items(&items);
        if !puts.iter().any(|(pd, _)| *pd == d) {
            puts.push((d, CacheSource::Items(items.clone())));
        }
        Some(d)
    } else {
        None
    };
    let ctx_id = i.session.fresh_context_id();
    ship_blobs(i, ctx_id, puts)?;
    let ctx = Arc::new(TaskContext {
        id: ctx_id,
        body: ContextBody::Map { f, extra },
        globals,
        cached_globals,
        nesting,
        kernel,
        reduce,
    });
    let workers = i.session.workers();
    let time_scale = i.config.time_scale;
    FutureSet::new(
        ctx,
        ElementSource::Items(items),
        items_digest,
        seeds,
        workers,
        time_scale,
        opts,
    )
    .run(i, opts)
}

/// Build and run a [`FutureSet`] for a foreach-style call.
pub fn run_foreach(
    i: &mut Interp,
    body: crate::rlite::ast::Expr,
    bindings: Vec<Vec<(String, WireVal)>>,
    globals: Vec<(String, WireVal)>,
    seeds: Option<Vec<RngState>>,
    opts: &MapOptions,
) -> Result<MapRun, Signal> {
    let nesting = i.session.nesting_for_context();
    let reduce = opts
        .reduce
        .filter(|_| crate::transpile::fusion::enabled())
        .map(|spec| spec.plan);
    if reduce.is_some() {
        crate::transpile::reduce::note_plan_attached();
    }
    let lint_mode = crate::rlite::diag::effective_mode(opts.lint.mode);
    if lint_mode != crate::rlite::diag::LintMode::Off {
        let names: Vec<String> = bindings
            .first()
            .map(|b| b.iter().map(|(n, _)| n.clone()).collect())
            .unwrap_or_default();
        let diags = crate::transpile::analysis::analyze_foreach(&body, &names, &globals, opts);
        crate::transpile::analysis::surface(i, &diags, lint_mode)?;
    }
    let use_cache = cache_active(i, opts);
    let (globals, cached_globals, mut puts) =
        if use_cache { extract_cached_globals(globals) } else { (globals, vec![], vec![]) };
    let bindings = Arc::new(bindings);
    let rows_bytes = |rows: &[Vec<(String, WireVal)>]| -> usize {
        rows.iter()
            .map(|row| row.iter().map(|(n, v)| n.len() + v.approx_size()).sum::<usize>())
            .sum()
    };
    let bindings_digest = if use_cache && rows_bytes(&bindings) >= blobstore::CACHE_MIN_BYTES {
        let d = digest_bindings(&bindings);
        if !puts.iter().any(|(pd, _)| *pd == d) {
            puts.push((d, CacheSource::Bindings(bindings.clone())));
        }
        Some(d)
    } else {
        None
    };
    let ctx_id = i.session.fresh_context_id();
    ship_blobs(i, ctx_id, puts)?;
    let ctx = Arc::new(TaskContext {
        id: ctx_id,
        body: ContextBody::Foreach { body },
        globals,
        cached_globals,
        nesting,
        kernel: None,
        reduce,
    });
    let workers = i.session.workers();
    let time_scale = i.config.time_scale;
    FutureSet::new(
        ctx,
        ElementSource::Bindings(bindings),
        bindings_digest,
        seeds,
        workers,
        time_scale,
        opts,
    )
    .run(i, opts)
}
