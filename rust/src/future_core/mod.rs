//! The future abstraction: `plan()`, task payloads, shared task
//! contexts, future handles, and the streaming map driver every
//! `future_*` function delegates to ([`driver`] + [`dispatch`]).
//!
//! This module is the rlite-facing half of the "future ecosystem" the
//! paper builds on: it owns the *what-to-run* representation
//! ([`TaskPayload`], [`TaskContext`]) and the developer-visible
//! lifecycle (`future()` → `resolved()` → `value()`), while
//! [`crate::backend`] owns the *how/where* (the paper's end-user
//! concern, selected via `plan()`).

pub mod dispatch;
pub mod driver;

use std::collections::HashMap;

use serde_derive::{Deserialize, Serialize};

use crate::backend::{Backend, BackendEvent, BackendKind, PlanSpec};
use crate::rlite::ast::{Arg, Expr};
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::conditions::{CaptureLog, RCondition, Severity};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::serialize::{WireSlice, WireVal};
use crate::rlite::value::{RList, RVal};
use crate::rng::RngState;

/// What a worker should execute.
///
/// Slice payloads are [`WireSlice`]s: the dispatch core hands every
/// chunk a zero-copy window into the map call's `Arc`-frozen element
/// storage. In-process backends consume the window directly (no
/// cloning, no encoding); process backends serialize it as a plain
/// element sequence.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub enum TaskKind {
    /// A single expression with exported globals (low-level `future()`,
    /// domain functions).
    Expr { expr: Expr, globals: Vec<(String, WireVal)> },
    /// A slice of map elements, executed against a [`TaskContext`]
    /// previously registered with the backend: run `ctx.f(item,
    /// ctx.extra...)` per element. `seeds` carries one pre-allocated
    /// L'Ecuyer stream per element (`seed = TRUE`), making results
    /// invariant to chunking and order.
    MapSlice { ctx: u64, items: WireSlice<WireVal>, seeds: Option<Vec<RngState>> },
    /// A slice of foreach iterations against a registered context: per
    /// element, bind the iteration variables then evaluate `ctx.body`.
    ForeachSlice {
        ctx: u64,
        bindings: WireSlice<Vec<(String, WireVal)>>,
        seeds: Option<Vec<RngState>>,
    },
}

impl TaskKind {
    /// The shared [`TaskContext`] this task references, if any.
    pub fn context_id(&self) -> Option<u64> {
        match self {
            TaskKind::Expr { .. } => None,
            TaskKind::MapSlice { ctx, .. } | TaskKind::ForeachSlice { ctx, .. } => Some(*ctx),
        }
    }
}

/// The per-map-call state every chunk of the call shares: the function
/// (or foreach body), its extra arguments, and the exported globals.
///
/// The batch driver used to deep-copy all of this into every chunk
/// payload — O(chunks × payload) serialized bytes. A `TaskContext` is
/// instead registered with the backend **once per map call** (process
/// backends ship it once per *worker*; see `ParentMsg::RegisterContext`)
/// and chunk payloads reference it by `id`, so per-chunk messages carry
/// only the elements themselves.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct TaskContext {
    pub id: u64,
    pub body: ContextBody,
    /// Exported globals, installed into the worker's fresh interpreter
    /// before each task of this context runs.
    pub globals: Vec<(String, WireVal)>,
}

/// What a context's tasks execute per element.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub enum ContextBody {
    /// `f(item, extra...)` per element.
    Map { f: WireVal, extra: Vec<(Option<String>, WireVal)> },
    /// Bind iteration variables, then evaluate `body`.
    Foreach { body: Expr },
}

/// A unit of work shipped to a backend.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct TaskPayload {
    pub id: u64,
    pub kind: TaskKind,
    /// Sys.sleep scale, forwarded so workers honour bench-time scaling.
    pub time_scale: f64,
    /// Relay stdout? (future's `stdout = TRUE` default)
    pub capture_stdout: bool,
}

/// What a worker produced.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct TaskOutcome {
    pub id: u64,
    /// Per-element values for chunk tasks; single value for Expr tasks.
    pub values: Result<Vec<WireVal>, RCondition>,
    pub log: CaptureLog,
    /// Which worker ran it (for the Figure-1 trace).
    pub worker: usize,
    /// Start/end offsets in seconds relative to task pickup, plus
    /// wall-clock capture for tracing.
    pub started_unix: f64,
    pub finished_unix: f64,
}

/// Build the `FutureError`-style condition raised when a worker dies
/// while running a task — the analog of R future's "Failed to retrieve
/// the result of MultisessionFuture" `FutureError`, but naming the lost
/// worker and task. `retries` is the exhausted budget, mentioned in the
/// message when it was non-zero (`None` for low-level futures, which
/// have no retry budget).
pub fn worker_lost_condition(
    backend: &str,
    worker: usize,
    task: u64,
    retries: Option<u32>,
) -> RCondition {
    let suffix = match retries {
        Some(n) if n > 0 => {
            format!(" (retries = {n} exhausted)")
        }
        _ => String::new(),
    };
    RCondition {
        severity: Severity::Error,
        message: format!(
            "FutureError: failed to retrieve the result of task {task} — \
             {backend} worker {worker} terminated unexpectedly{suffix}"
        ),
        classes: vec!["FutureError".into(), "error".into(), "condition".into()],
        call: None,
        data: None,
    }
}

/// One entry of the execution trace (regenerates the paper's Figure 1).
#[derive(Clone, Debug, Serialize)]
pub struct TraceEvent {
    pub task_id: u64,
    pub worker: usize,
    pub start: f64,
    pub end: f64,
}

/// Per-session future-ecosystem state, owned by the interpreter.
pub struct SessionState {
    /// The plan stack (`plan()` pushes/replaces the top).
    pub plan: PlanSpec,
    /// Lazily instantiated backend for the current plan.
    backend: Option<Box<dyn Backend>>,
    /// Pending low-level futures: id → resolved outcome (if arrived).
    pending: HashMap<u64, Option<TaskOutcome>>,
    /// Tasks reported lost by a [`BackendEvent::WorkerLost`] that the
    /// event's receiver did not own: task id → worker index. A map
    /// call's drive loop reclaims its own ids from here (and retries
    /// them); `value()` raises a `FutureError` for a lost low-level
    /// future. Without this ledger a loss observed by the "wrong" event
    /// loop would strand the owner waiting forever.
    pub lost_tasks: HashMap<u64, usize>,
    next_task_id: u64,
    next_context_id: u64,
    /// Trace of the most recent futurized map call.
    pub last_trace: Vec<TraceEvent>,
    /// Session RNG seed used to derive per-element streams.
    pub rng_root_seed: u64,
}

impl Default for SessionState {
    fn default() -> Self {
        SessionState {
            plan: PlanSpec::sequential(),
            backend: None,
            pending: HashMap::new(),
            lost_tasks: HashMap::new(),
            next_task_id: 0,
            next_context_id: 0,
            last_trace: Vec::new(),
            rng_root_seed: 42,
        }
    }
}

impl SessionState {
    pub fn set_plan(&mut self, plan: PlanSpec) {
        if self.plan != plan {
            // Tear down the old worker pool, as future does on plan change.
            self.backend = None;
            self.plan = plan;
        }
    }

    pub fn fresh_task_id(&mut self) -> u64 {
        self.next_task_id += 1;
        self.next_task_id
    }

    pub fn fresh_context_id(&mut self) -> u64 {
        self.next_context_id += 1;
        self.next_context_id
    }

    /// Install a specific backend instance for the current plan —
    /// embedder hook for custom [`Backend`] implementations (and the
    /// dispatch-core test suite's instrumented probe backends).
    pub fn install_backend(&mut self, backend: Box<dyn Backend>) {
        self.backend = Some(backend);
    }

    /// Instantiate (or reuse) the backend for the current plan.
    pub fn backend(&mut self) -> Result<&mut Box<dyn Backend>, String> {
        if self.backend.is_none() {
            self.backend = Some(crate::backend::instantiate(&self.plan)?);
        }
        Ok(self.backend.as_mut().unwrap())
    }

    pub fn workers(&mut self) -> usize {
        match self.backend() {
            Ok(b) => b.workers(),
            Err(_) => 1,
        }
    }
}

// ---------------------------------------------------------------------------
// rlite-facing builtins: plan(), nbrOfWorkers(), future(), value(), ...
// ---------------------------------------------------------------------------

pub fn register_builtins(r: &mut Reg) {
    r.special("future", "plan", plan_fn);
    r.normal("future", "nbrOfWorkers", nbr_of_workers_fn);
    r.normal("parallelly", "availableCores", available_cores_fn);
    r.special("future", "future", future_fn);
    r.normal("future", "value", value_fn);
    r.normal("future", "resolved", resolved_fn);
    r.special("future", "futureSeed", future_seed_fn);
    r.special("future", "%<-%", future_assign_fn);
}

/// `plan(backend, workers = n)` — a special form: the backend may be an
/// unevaluated symbol (`multisession`), a namespaced symbol
/// (`future.mirai::mirai_multisession`), or a string.
fn plan_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let Some(first) = args.first() else {
        // plan() with no args: report current plan name.
        return Ok(RVal::scalar_str(i.session.plan.describe()));
    };
    let kind_name = match &first.value {
        Expr::Sym(s) => s.to_string(),
        Expr::Ns { pkg, name } => format!("{pkg}::{name}"),
        Expr::Str(s) => s.clone(),
        other => {
            // Maybe an expression evaluating to a string.
            i.eval(other, env)?.as_str().map_err(Signal::error)?
        }
    };
    let mut workers: Option<usize> = None;
    let mut worker_names: Vec<String> = Vec::new();
    let mut latency_ms: Option<f64> = None;
    let mut poll_ms: Option<f64> = None;
    for a in &args[1..] {
        match a.name.as_deref() {
            Some("workers") => {
                let v = i.eval(&a.value, env)?;
                match &v {
                    RVal::Chr(names) => {
                        worker_names = names.vals.to_vec();
                        workers = Some(names.vals.len());
                    }
                    other => workers = Some(other.as_usize().map_err(Signal::error)?),
                }
            }
            Some("latency_ms") => {
                latency_ms = Some(i.eval(&a.value, env)?.as_f64().map_err(Signal::error)?)
            }
            Some("poll_ms") => {
                poll_ms = Some(i.eval(&a.value, env)?.as_f64().map_err(Signal::error)?)
            }
            _ => {}
        }
    }
    let spec = PlanSpec::from_name(&kind_name, workers, worker_names, latency_ms, poll_ms)
        .map_err(Signal::error)?;
    i.session.set_plan(spec);
    Ok(RVal::Null)
}

fn nbr_of_workers_fn(i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    Ok(RVal::scalar_int(i.session.workers() as i64))
}

fn available_cores_fn(_i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    Ok(RVal::scalar_int(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as i64,
    ))
}

/// `future(expr)` — the low-level API: launch one future on the current
/// backend, return a handle.
fn future_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let expr =
        args.first().ok_or_else(|| Signal::error("future: missing expression"))?;
    let id = submit_expr(i, &expr.value, env)?;
    let mut l = RList::named(vec![RVal::scalar_int(id as i64)], vec!["id".into()]);
    l.class = Some("Future".into());
    Ok(RVal::List(l))
}

/// `x %<-% expr` — future assignment sugar: evaluates eagerly-as-future
/// and binds the *value* (rlite has no promises, so this resolves on
/// first use, i.e. immediately at bind time).
fn future_assign_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let target = match &args[0].value {
        Expr::Sym(s) => *s,
        other => {
            return Err(Signal::error(format!(
                "invalid %<-% target: {}",
                crate::rlite::deparse::deparse(other)
            )))
        }
    };
    let id = submit_expr(i, &args[1].value, env)?;
    let v = wait_for(i, id, env)?;
    crate::rlite::env::define_sym(env, target, v.clone());
    Ok(v)
}

/// Submit one expression as a future; returns the task id.
fn submit_expr(i: &mut Interp, expr: &Expr, env: &EnvRef) -> Result<u64, Signal> {
    let export = crate::globals::identify_globals(expr, env).map_err(Signal::error)?;
    let mut globals = Vec::new();
    for (name, v) in export.values {
        globals.push((name, crate::rlite::serialize::to_wire(&v).map_err(Signal::error)?));
    }
    let id = i.session.fresh_task_id();
    let payload = TaskPayload {
        id,
        kind: TaskKind::Expr { expr: expr.clone(), globals },
        time_scale: i.config.time_scale,
        capture_stdout: true,
    };
    i.session.backend().map_err(Signal::error)?.submit(payload).map_err(Signal::error)?;
    i.session.pending.insert(id, None);
    Ok(id)
}

fn future_id(v: &RVal) -> Result<u64, Signal> {
    match v {
        RVal::List(l) if l.class.as_deref() == Some("Future") => {
            Ok(l.get("id").and_then(|x| x.as_i64().ok()).unwrap_or(0) as u64)
        }
        other => Err(Signal::error(format!("not a Future: {}", other.class()))),
    }
}

/// Block until task `id` resolves; relay its output; return its value.
/// A worker that dies while running `id` surfaces as a `FutureError`
/// condition (R future's semantics for an unreliable worker) — the wait
/// never hangs on a `Done` that can no longer arrive.
fn wait_for(i: &mut Interp, id: u64, env: &EnvRef) -> EvalResult {
    loop {
        if let Some(Some(outcome)) = i.session.pending.get(&id) {
            let outcome = outcome.clone();
            i.session.pending.remove(&id);
            return finish_outcome(i, outcome, env);
        }
        if let Some(worker) = i.session.lost_tasks.remove(&id) {
            i.session.pending.remove(&id);
            let backend = i.session.backend().map(|b| b.name()).unwrap_or("future");
            return Err(Signal::Error(worker_lost_condition(backend, worker, id, None)));
        }
        let ev = i
            .session
            .backend()
            .map_err(Signal::error)?
            .next_event()
            .map_err(Signal::error)?;
        match ev {
            BackendEvent::Progress { cond, .. } => {
                i.signal_condition(cond)?;
            }
            BackendEvent::Done(outcome) => {
                if outcome.id == id {
                    i.session.pending.remove(&id);
                    return finish_outcome(i, outcome, env);
                }
                i.session.pending.insert(outcome.id, Some(outcome));
            }
            BackendEvent::WorkerLost { worker, task } => {
                // Record the loss (ours included — picked up at the top
                // of the next iteration); the backend has already healed
                // its pool.
                if let Some(tid) = task {
                    i.session.lost_tasks.insert(tid, worker);
                }
            }
        }
    }
}

fn finish_outcome(i: &mut Interp, outcome: TaskOutcome, _env: &EnvRef) -> EvalResult {
    i.relay(&outcome.log)?;
    match outcome.values {
        Ok(vals) => {
            let genv = i.global.clone();
            let mut out: Vec<RVal> = vals
                .into_iter()
                .map(|w| crate::rlite::serialize::from_wire_owned(w, &genv))
                .collect();
            Ok(out.pop().unwrap_or(RVal::Null))
        }
        Err(cond) => Err(Signal::Error(cond)),
    }
}

fn value_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let f = args.bind(&["future"]).req(0, "future")?;
    let id = future_id(&f)?;
    wait_for(i, id, env)
}

fn resolved_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let f = args.bind(&["future"]).req(0, "future")?;
    let id = future_id(&f)?;
    // Drain any ready events without blocking on this id.
    while let Ok(Some(ev)) = i.session.backend().map_err(Signal::error)?.try_next_event() {
        match ev {
            BackendEvent::Progress { cond, .. } => {
                i.signal_condition(cond)?;
            }
            BackendEvent::Done(outcome) => {
                i.session.pending.insert(outcome.id, Some(outcome));
            }
            BackendEvent::WorkerLost { worker, task } => {
                if let Some(tid) = task {
                    i.session.lost_tasks.insert(tid, worker);
                }
            }
        }
    }
    // A lost future is resolved in R's sense: its (error) result is
    // ready to collect — `value()` raises the FutureError.
    Ok(RVal::scalar_bool(
        matches!(i.session.pending.get(&id), Some(Some(_)))
            || i.session.lost_tasks.contains_key(&id),
    ))
}

/// `futureSeed(seed)` — set the root seed used to derive per-element
/// L'Ecuyer streams when `seed = TRUE`.
fn future_seed_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let v = i.eval(&args[0].value, env)?;
    i.session.rng_root_seed = v.as_i64().map_err(Signal::error)? as u64;
    Ok(RVal::Null)
}

/// Map a backend kind to a human-readable name (used in traces/benches).
pub fn backend_kind_name(kind: &BackendKind) -> &'static str {
    match kind {
        BackendKind::Sequential => "sequential",
        BackendKind::Multicore => "multicore",
        BackendKind::Multisession => "multisession",
        BackendKind::ClusterSim => "cluster",
        BackendKind::BatchtoolsSim => "batchtools",
    }
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn plan_default_is_sequential() {
        assert_eq!(run("plan()"), RVal::scalar_str("sequential"));
    }

    #[test]
    fn plan_switches_backend() {
        let v = run("plan(multicore, workers = 2)\nnbrOfWorkers()");
        assert_eq!(v, RVal::scalar_int(2));
    }

    #[test]
    fn plan_accepts_namespaced_backends() {
        // future.mirai::mirai_multisession maps onto the process backend.
        let v = run("plan(future.mirai::mirai_multisession, workers = 2)\nplan()");
        assert!(v.as_str().unwrap().contains("multisession"), "{v}");
    }

    #[test]
    fn low_level_future_value_roundtrip() {
        let v = run("plan(sequential)\nf <- future(21 * 2)\nvalue(f)");
        assert_eq!(v, RVal::scalar_dbl(42.0));
    }

    #[test]
    fn future_exports_globals() {
        let v = run("plan(multicore, workers = 2)\na <- 5\nf <- future(a + 1)\nvalue(f)");
        assert_eq!(v, RVal::scalar_dbl(6.0));
    }

    #[test]
    fn future_error_propagates() {
        let mut i = Interp::new();
        let r = i.eval_program("plan(sequential)\nf <- future(stop(\"worker boom\"))\nvalue(f)");
        match r {
            Err(crate::rlite::eval::Signal::Error(c)) => assert_eq!(c.message, "worker boom"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn resolved_eventually_true() {
        let v = run(
            "plan(multicore, workers = 1)\nf <- future(1 + 1)\nv <- value(f)\nv",
        );
        assert_eq!(v, RVal::scalar_dbl(2.0));
    }
}
