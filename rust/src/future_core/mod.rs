//! The future abstraction: `plan()`, task payloads, shared task
//! contexts, future handles, and the streaming map driver every
//! `future_*` function delegates to ([`driver`] + [`dispatch`]).
//!
//! This module is the rlite-facing half of the "future ecosystem" the
//! paper builds on: it owns the *what-to-run* representation
//! ([`TaskPayload`], [`TaskContext`]) and the developer-visible
//! lifecycle (`future()` → `resolved()` → `value()`), while
//! [`crate::backend`] owns the *how/where* (the paper's end-user
//! concern, selected via `plan()`).

pub mod dispatch;
pub mod driver;

use std::collections::HashMap;

use serde_derive::{Deserialize, Serialize};

use crate::backend::{Backend, BackendEvent, BackendKind, PlanSpec};
use crate::rlite::ast::{Arg, Expr};
use crate::rlite::builtins::{Args, Reg};
use crate::rlite::conditions::{CaptureLog, RCondition, Severity};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::serialize::{WireSlice, WireVal};
use crate::rlite::value::{RList, RVal};
use crate::rng::RngState;

/// What a worker should execute.
///
/// Slice payloads are [`WireSlice`]s: the dispatch core hands every
/// chunk a zero-copy window into the map call's `Arc`-frozen element
/// storage. In-process backends consume the window directly (no
/// cloning, no encoding); process backends serialize it as a plain
/// element sequence.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub enum TaskKind {
    /// A single expression with exported globals (low-level `future()`,
    /// domain functions). Context-free tasks carry their own
    /// [`NestingInfo`] so a `future()` consumes one plan level exactly
    /// like a map call: nested futurized code inside it inherits the
    /// remaining stack instead of degrading to sequential.
    Expr { expr: Expr, globals: Vec<(String, WireVal)>, nesting: NestingInfo },
    /// A slice of map elements, executed against a [`TaskContext`]
    /// previously registered with the backend: run `ctx.f(item,
    /// ctx.extra...)` per element. `seeds` carries one pre-allocated
    /// L'Ecuyer stream per element (`seed = TRUE`), making results
    /// invariant to chunking and order.
    MapSlice { ctx: u64, items: WireSlice<WireVal>, seeds: Option<Vec<RngState>> },
    /// A slice of foreach iterations against a registered context: per
    /// element, bind the iteration variables then evaluate `ctx.body`.
    ForeachSlice {
        ctx: u64,
        bindings: WireSlice<Vec<(String, WireVal)>>,
        seeds: Option<Vec<RngState>>,
    },
    /// Like [`TaskKind::MapSlice`], but the element vector travels as a
    /// data-plane cache digest plus a `[start, end)` window instead of
    /// inline bytes (see `backend::blobstore`). The worker resolves the
    /// digest against its blob store *before* the task runner sees the
    /// task — a resolved ref is rewritten into a plain `MapSlice` — or
    /// answers with a `CacheMiss` negative-ack so the parent re-puts.
    /// Appended after the original variants to keep their wire tags
    /// stable.
    MapSliceRef { ctx: u64, digest: u64, start: usize, end: usize, seeds: Option<Vec<RngState>> },
    /// The foreach analog of [`TaskKind::MapSliceRef`].
    ForeachSliceRef {
        ctx: u64,
        digest: u64,
        start: usize,
        end: usize,
        seeds: Option<Vec<RngState>>,
    },
}

impl TaskKind {
    /// The shared [`TaskContext`] this task references, if any.
    pub fn context_id(&self) -> Option<u64> {
        match self {
            TaskKind::Expr { .. } => None,
            TaskKind::MapSlice { ctx, .. }
            | TaskKind::ForeachSlice { ctx, .. }
            | TaskKind::MapSliceRef { ctx, .. }
            | TaskKind::ForeachSliceRef { ctx, .. } => Some(*ctx),
        }
    }
}

/// The per-map-call state every chunk of the call shares: the function
/// (or foreach body), its extra arguments, and the exported globals.
///
/// The batch driver used to deep-copy all of this into every chunk
/// payload — O(chunks × payload) serialized bytes. A `TaskContext` is
/// instead registered with the backend **once per map call** (process
/// backends ship it once per *worker*; see `ParentMsg::RegisterContext`)
/// and chunk payloads reference it by `id`, so per-chunk messages carry
/// only the elements themselves.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct TaskContext {
    pub id: u64,
    pub body: ContextBody,
    /// Exported globals, installed into the worker's fresh interpreter
    /// before each task of this context runs.
    pub globals: Vec<(String, WireVal)>,
    /// Oversized globals extracted into the data-plane cache: `(name,
    /// digest)` pairs the worker materializes from its blob store into
    /// `globals` at first use (see `backend::blobstore`). Empty when
    /// the cache is off or nothing crossed the size threshold, so the
    /// context encodes the same handful of extra bytes either way.
    pub cached_globals: Vec<(String, u64)>,
    /// The plan-stack levels *below* the one running this context's
    /// tasks, inherited by worker sessions so nested futurized calls
    /// instantiate their own inner backend (paper's `plan(list(...))`
    /// topologies). Riding inside the context means supervision replays
    /// it to respawned workers for free, along with everything else.
    pub nesting: NestingInfo,
    /// Fused-kernel plan for this context's map body, attached at
    /// freeze time when the AOT recognizer matched it against the
    /// kernel catalog. `None` means every slice runs interpreted —
    /// including when `FUTURIZE_NO_FUSION=1` suppressed recognition in
    /// the parent, which is what makes the kill switch effective across
    /// process backends without respawning workers.
    pub kernel: Option<crate::transpile::fusion::KernelPlan>,
    /// Fused-reduction plan: the map's results feed a recognized
    /// reduction, so workers fold each slice locally and ship a
    /// constant-size partial aggregate instead of per-element results.
    /// Attached only when the dispatch-time kill switch allows it, so
    /// `FUTURIZE_NO_FUSION=1` keeps the full-result path without
    /// respawning workers.
    pub reduce: Option<crate::transpile::reduce::ReducePlan>,
}

/// How a [`TaskContext`]'s tasks relate to the session's plan stack.
///
/// Shipped once per map call inside `RegisterContext`; the worker's
/// fresh session adopts it (`SessionState::adopt_nesting`) before the
/// first element runs, so a nested futurized map inside the task body
/// sees the inherited stack instead of falling back to sequential.
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub struct NestingInfo {
    /// Remaining plan levels. Empty means nested calls in the worker
    /// default to sequential — the future framework's implicit-inner
    /// guard against accidental recursive parallelism.
    pub stack: Vec<PlanSpec>,
    /// Product of the worker counts of every consumed level (≥ 1).
    /// Inherited levels with an *implicit* worker count divide the
    /// machine's cores by this, bounding total oversubscription.
    pub outer_workers: usize,
    /// Nesting depth of the session consuming this context (1 = a
    /// worker of a top-level map call).
    pub depth: usize,
    /// The parent session's root RNG seed at context creation. Worker
    /// sessions adopt it, so a nested `seed = TRUE` map under an
    /// *unseeded* outer map still respects `futureSeed()` (the seeded
    /// outer path overrides it per element with the stream fork).
    pub root_seed: u64,
}

impl Default for NestingInfo {
    fn default() -> Self {
        NestingInfo { stack: vec![], outer_workers: 1, depth: 1, root_seed: 42 }
    }
}

/// What a context's tasks execute per element.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub enum ContextBody {
    /// `f(item, extra...)` per element.
    Map { f: WireVal, extra: Vec<(Option<String>, WireVal)> },
    /// Bind iteration variables, then evaluate `body`.
    Foreach { body: Expr },
}

/// A unit of work shipped to a backend.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct TaskPayload {
    pub id: u64,
    pub kind: TaskKind,
    /// Sys.sleep scale, forwarded so workers honour bench-time scaling.
    pub time_scale: f64,
    /// Relay stdout? (future's `stdout = TRUE` default)
    pub capture_stdout: bool,
}

/// What a worker produced.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct TaskOutcome {
    pub id: u64,
    /// Per-element values for chunk tasks; single value for Expr tasks.
    pub values: Result<Vec<WireVal>, RCondition>,
    pub log: CaptureLog,
    /// Which worker ran it (for the Figure-1 trace).
    pub worker: usize,
    /// Start/end offsets in seconds relative to task pickup, plus
    /// wall-clock capture for tracing.
    pub started_unix: f64,
    pub finished_unix: f64,
    /// Largest worker count of any *inner* backend the task's session
    /// instantiated from its inherited plan stack — via a nested
    /// futurized call or anything else that touches the backend, e.g.
    /// `nbrOfWorkers()` (0 = the inherited plan was never used). Folded
    /// into [`TraceEvent::inner_workers`] so outer×inner effective
    /// parallelism is observable from the parent's trace.
    pub nested_workers: usize,
    /// Worker-side folded partial aggregate for a slice of a context
    /// with a [`ReducePlan`](crate::transpile::reduce::ReducePlan).
    /// When set, `values` is `Ok(vec![])` — the O(n) per-element results
    /// never cross the wire. `None` on a reduce-planned context means
    /// the slice's values failed the plan's exactness gate and shipped
    /// in full (the parent folds them in chunk order instead).
    pub partial: Option<crate::transpile::reduce::ReducePartial>,
}

/// Build the `FutureError`-style condition raised when a worker dies
/// while running a task — the analog of R future's "Failed to retrieve
/// the result of MultisessionFuture" `FutureError`, but naming the lost
/// worker and task. `retries` is the exhausted budget, mentioned in the
/// message when it was non-zero (`None` for low-level futures, which
/// have no retry budget).
pub fn worker_lost_condition(
    backend: &str,
    worker: usize,
    task: u64,
    retries: Option<u32>,
) -> RCondition {
    let suffix = match retries {
        Some(n) if n > 0 => {
            format!(" (retries = {n} exhausted)")
        }
        _ => String::new(),
    };
    RCondition {
        severity: Severity::Error,
        message: format!(
            "FutureError: failed to retrieve the result of task {task} — \
             {backend} worker {worker} terminated unexpectedly{suffix}"
        ),
        classes: vec!["FutureError".into(), "error".into(), "condition".into()],
        call: None,
        data: None,
    }
}

/// One entry of the execution trace (regenerates the paper's Figure 1).
#[derive(Clone, Debug, Serialize)]
pub struct TraceEvent {
    pub task_id: u64,
    pub worker: usize,
    pub start: f64,
    pub end: f64,
    /// Worker count of the largest inner backend the task's session
    /// instantiated from its inherited plan stack (0 = the inherited
    /// plan was never used; 1 can also mean a backend-touching call
    /// like `nbrOfWorkers()` on the implicit sequential level). The map
    /// call's effective parallelism under a plan stack is
    /// `distinct(worker) × max(inner_workers, 1)`.
    pub inner_workers: usize,
}

/// The per-depth outcome ledger — PR 1's flat `pending` map, grown to
/// understand re-entrant dispatch. Entries are either *placeholders* a
/// `future()` handle registered (owned until `value()` collects them,
/// at whatever depth that happens) or *strays*: outcomes one drive loop
/// pulled off the shared backend channel on behalf of another (a nested
/// futurized map, `wait_for`, or an enclosing map call). The ledger
/// counts how many drive loops are active; when the outermost one
/// exits, strays nobody reclaimed (their owner aborted mid-call) are
/// pruned, so an abandoned nested dispatch can never leak outcomes into
/// the session for its lifetime.
#[derive(Default)]
pub struct PendingLedger {
    entries: HashMap<u64, PendingEntry>,
    depth: usize,
}

struct PendingEntry {
    outcome: Option<TaskOutcome>,
    /// True for `future()` placeholders: a live handle will collect
    /// this entry eventually, so depth-0 pruning must keep it.
    owned: bool,
}

impl PendingLedger {
    /// Register a `future()` placeholder for `id`.
    pub fn expect(&mut self, id: u64) {
        self.entries.insert(id, PendingEntry { outcome: None, owned: true });
    }

    /// Park an outcome the current event loop does not own.
    pub fn stash(&mut self, outcome: TaskOutcome) {
        match self.entries.get_mut(&outcome.id) {
            Some(e) => e.outcome = Some(outcome),
            None => {
                let id = outcome.id;
                self.entries.insert(id, PendingEntry { outcome: Some(outcome), owned: false });
            }
        }
    }

    /// Take the outcome for `id` if it has arrived (placeholders whose
    /// result is still in flight stay registered).
    pub fn take_ready(&mut self, id: u64) -> Option<TaskOutcome> {
        if self.is_ready(id) {
            self.entries.remove(&id).and_then(|e| e.outcome)
        } else {
            None
        }
    }

    pub fn is_ready(&self, id: u64) -> bool {
        self.entries.get(&id).is_some_and(|e| e.outcome.is_some())
    }

    /// Drop all state for `id` (lost futures, aborted chunks).
    pub fn discard(&mut self, id: u64) {
        self.entries.remove(&id);
    }

    /// A drive loop (map-call dispatch or `future()` wait) is entering.
    pub fn enter(&mut self) {
        self.depth += 1;
    }

    /// The matching exit; at depth 0, prune unclaimed strays.
    pub fn exit(&mut self) {
        self.depth = self.depth.saturating_sub(1);
        if self.depth == 0 {
            self.entries.retain(|_, e| e.owned);
        }
    }

    /// True when nothing is stashed or expected (used by tests to pin
    /// the depth-0 pruning contract).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Per-session future-ecosystem state, owned by the interpreter.
pub struct SessionState {
    /// The plan stack: level 0 is this session's backend, deeper levels
    /// are inherited by workers for nested futurized calls. Never
    /// empty — `[sequential]` is the base state.
    plan_stack: Vec<PlanSpec>,
    /// Lazily instantiated backend for the stack's top level.
    backend: Option<Box<dyn Backend>>,
    /// Outcomes in flight between re-entrant event loops and `future()`
    /// handles, tracked per dispatch depth.
    pub pending: PendingLedger,
    /// Tasks reported lost by a [`BackendEvent::WorkerLost`] that the
    /// event's receiver did not own: task id → worker index. A map
    /// call's drive loop reclaims its own ids from here (and retries
    /// them); `value()` raises a `FutureError` for a lost low-level
    /// future. Without this ledger a loss observed by the "wrong" event
    /// loop would strand the owner waiting forever.
    pub lost_tasks: HashMap<u64, usize>,
    next_task_id: u64,
    next_context_id: u64,
    /// Trace of the most recent futurized map call.
    pub last_trace: Vec<TraceEvent>,
    /// Session RNG seed used to derive per-element streams.
    pub rng_root_seed: u64,
    /// Worker-count product of the plan levels enclosing sessions have
    /// already consumed (1 in a top-level session).
    pub outer_workers: usize,
    /// How many plan levels enclosing sessions consumed (0 at the top
    /// level, 1 inside a worker of a top-level map call, …).
    pub nest_depth: usize,
    /// Largest worker count of any backend this session instantiated —
    /// worker sessions report it in [`TaskOutcome::nested_workers`] so
    /// parents can trace effective nested parallelism.
    pub peak_backend_workers: usize,
}

impl Default for SessionState {
    fn default() -> Self {
        SessionState {
            plan_stack: vec![PlanSpec::sequential()],
            backend: None,
            pending: PendingLedger::default(),
            lost_tasks: HashMap::new(),
            next_task_id: 0,
            next_context_id: 0,
            last_trace: Vec::new(),
            rng_root_seed: 42,
            outer_workers: 1,
            nest_depth: 0,
            peak_backend_workers: 0,
        }
    }
}

impl SessionState {
    /// The plan level this session executes on.
    pub fn plan(&self) -> &PlanSpec {
        &self.plan_stack[0]
    }

    /// The full plan stack (level 0 first).
    pub fn plan_stack(&self) -> &[PlanSpec] {
        &self.plan_stack
    }

    pub fn set_plan(&mut self, plan: PlanSpec) {
        self.set_plan_stack(vec![plan]);
    }

    /// Install a plan stack (`plan(list(...))`). An empty stack resets
    /// to `[sequential]`.
    pub fn set_plan_stack(&mut self, mut stack: Vec<PlanSpec>) {
        if stack.is_empty() {
            stack.push(PlanSpec::sequential());
        }
        if self.plan_stack != stack {
            // Tear down the old worker pool, as future does on plan change.
            self.backend = None;
            self.plan_stack = stack;
        }
    }

    /// The nesting metadata stamped into a new [`TaskContext`]: the
    /// plan levels this session will *not* consume, for its workers.
    pub fn nesting_for_context(&mut self) -> NestingInfo {
        let level_workers = self.workers().max(1);
        NestingInfo {
            stack: self.plan_stack[1..].to_vec(),
            outer_workers: self.outer_workers.max(1) * level_workers,
            depth: self.nest_depth + 1,
            root_seed: self.rng_root_seed,
        }
    }

    /// Adopt inherited nesting state in a worker session (called by the
    /// task runner before the first element of a context executes). An
    /// empty inherited stack is the implicit inner level: sequential.
    pub fn adopt_nesting(&mut self, nesting: &NestingInfo) {
        let stack = if nesting.stack.is_empty() {
            vec![PlanSpec::sequential()]
        } else {
            nesting.stack.clone()
        };
        self.set_plan_stack(stack);
        self.outer_workers = nesting.outer_workers.max(1);
        self.nest_depth = nesting.depth;
        self.rng_root_seed = nesting.root_seed;
    }

    pub fn fresh_task_id(&mut self) -> u64 {
        self.next_task_id += 1;
        self.next_task_id
    }

    pub fn fresh_context_id(&mut self) -> u64 {
        self.next_context_id += 1;
        self.next_context_id
    }

    /// Install a specific backend instance for the current plan —
    /// embedder hook for custom [`Backend`] implementations (and the
    /// dispatch-core test suite's instrumented probe backends).
    pub fn install_backend(&mut self, backend: Box<dyn Backend>) {
        self.peak_backend_workers = self.peak_backend_workers.max(backend.workers());
        self.backend = Some(backend);
    }

    /// Instantiate (or reuse) the backend for the stack's top level.
    /// Peak workers are recorded on every access (not just
    /// instantiation) so a cache-primed backend still counts the
    /// moment a nested map actually uses it.
    pub fn backend(&mut self) -> Result<&mut Box<dyn Backend>, String> {
        if self.backend.is_none() {
            self.backend =
                Some(crate::backend::instantiate(&self.plan_stack[0], self.outer_workers)?);
        }
        let b = self.backend.as_mut().unwrap();
        self.peak_backend_workers = self.peak_backend_workers.max(b.workers());
        Ok(b)
    }

    /// Remove the live backend without tearing it down — the worker's
    /// inner-backend cache parks it between tasks. Because
    /// [`SessionState::set_plan_stack`] drops the backend on any stack
    /// change, a taken backend always matches the *current* stack.
    pub fn take_backend(&mut self) -> Option<Box<dyn Backend>> {
        self.backend.take()
    }

    /// Re-install a previously taken backend *without* recording peak
    /// workers: priming from the cache must not make an unused nesting
    /// level look used ([`SessionState::backend`] records the peak on
    /// actual access).
    pub fn prime_backend(&mut self, backend: Box<dyn Backend>) {
        self.backend = Some(backend);
    }

    pub fn workers(&mut self) -> usize {
        match self.backend() {
            Ok(b) => b.workers(),
            Err(_) => 1,
        }
    }
}

// ---------------------------------------------------------------------------
// rlite-facing builtins: plan(), nbrOfWorkers(), future(), value(), ...
// ---------------------------------------------------------------------------

pub fn register_builtins(r: &mut Reg) {
    r.special("future", "plan", plan_fn);
    r.special("future", "tweak", tweak_fn);
    r.normal("future", "nbrOfWorkers", nbr_of_workers_fn);
    r.normal("parallelly", "availableCores", available_cores_fn);
    r.special("future", "future", future_fn);
    r.normal("future", "value", value_fn);
    r.normal("future", "resolved", resolved_fn);
    r.special("future", "futureSeed", future_seed_fn);
    r.special("future", "%<-%", future_assign_fn);
}

/// Render a plan stack for `plan()` with no arguments.
fn describe_stack(stack: &[PlanSpec]) -> String {
    stack.iter().map(|p| p.describe()).collect::<Vec<_>>().join(" -> ")
}

/// Apply `workers = n` / `latency_ms = x` / `poll_ms = x` overrides to a
/// parsed plan level. A single leading *unnamed* numeric argument is the
/// `backend(n)` worker-count shorthand. Unknown named arguments are
/// ignored, matching `plan()`'s historic tolerance.
fn apply_plan_args(
    i: &mut Interp,
    spec: &mut PlanSpec,
    args: &[Arg],
    env: &EnvRef,
) -> Result<(), Signal> {
    for (k, a) in args.iter().enumerate() {
        match a.name.as_deref() {
            None if k == 0 => {
                let v = i.eval(&a.value, env)?;
                spec.workers = v.as_usize().map_err(Signal::error)?.max(1);
                spec.explicit_workers = true;
            }
            None => {
                return Err(Signal::error(
                    "plan: unexpected unnamed backend argument (only the first may be a \
                     worker count)",
                ))
            }
            Some("workers") => {
                let v = i.eval(&a.value, env)?;
                match &v {
                    RVal::Chr(names) => {
                        let tcp = names.vals.iter().any(|n| n.starts_with("tcp://"));
                        spec.worker_names = names.vals.to_vec();
                        // A tcp:// entry is a *listen address*, not a
                        // node: it must not clobber a worker count the
                        // user already gave (`plan(cluster, 4, workers
                        // = "tcp://0.0.0.0:7001")` awaits 4 workers).
                        if !(tcp && spec.explicit_workers) {
                            spec.workers = names.vals.len().max(1);
                        }
                        // Promote the latency simulator to the real
                        // socket backend in attach mode (mirrors the
                        // same promotion in `PlanSpec::from_name`,
                        // which never saw these names).
                        if tcp && spec.kind == crate::backend::BackendKind::ClusterSim {
                            spec.kind = crate::backend::BackendKind::ClusterTcp;
                            if spec.heartbeat_ms <= 0.0 {
                                spec.heartbeat_ms = 2000.0;
                            }
                        }
                        if let Some(listen) =
                            names.vals.iter().find_map(|n| n.strip_prefix("tcp://"))
                        {
                            spec.tcp_listen = listen.to_string();
                        }
                    }
                    other => spec.workers = other.as_usize().map_err(Signal::error)?.max(1),
                }
                spec.explicit_workers = true;
            }
            Some("latency_ms") => {
                spec.latency_ms = i.eval(&a.value, env)?.as_f64().map_err(Signal::error)?;
            }
            Some("poll_ms") => {
                spec.poll_ms = i.eval(&a.value, env)?.as_f64().map_err(Signal::error)?;
            }
            Some("heartbeat_ms") => {
                spec.heartbeat_ms = i.eval(&a.value, env)?.as_f64().map_err(Signal::error)?;
            }
            Some("spawn") => {
                spec.tcp_spawn = i.eval(&a.value, env)?.as_str().map_err(Signal::error)?;
            }
            _ => {}
        }
    }
    Ok(())
}

/// Parse one level of a plan stack. Accepts a bare backend symbol
/// (`multicore`), a namespaced symbol (`future.callr::callr`), a string,
/// a `tweak(backend, workers = n, ...)` call, the `backend(n)` /
/// `backend(workers = n)` shorthand, or any expression evaluating to a
/// backend name or a `tweak()`-built FutureStrategy value.
fn plan_level_from_expr(i: &mut Interp, e: &Expr, env: &EnvRef) -> Result<PlanSpec, Signal> {
    match e {
        Expr::Sym(s) => match PlanSpec::from_name(s.as_str(), None, vec![], None, None) {
            Ok(spec) => Ok(spec),
            // Not a backend name: maybe a variable bound to a name
            // string or a tweak()-built strategy (`plan(s)`).
            Err(err) => match crate::rlite::env::lookup_sym(env, *s) {
                Some(v) => plan_level_from_value(&v),
                None => Err(Signal::error(err)),
            },
        },
        Expr::Ns { pkg, name } => {
            PlanSpec::from_name(&format!("{pkg}::{name}"), None, vec![], None, None)
                .map_err(Signal::error)
        }
        Expr::Str(s) => PlanSpec::from_name(s, None, vec![], None, None).map_err(Signal::error),
        Expr::Call { func, args } => {
            // `tweak(backend, ...)`: a base level plus overrides.
            if matches!(func.as_ref(), Expr::Sym(s) if s.as_str() == "tweak") {
                let Some(first) = args.first() else {
                    return Err(Signal::error("tweak: missing backend argument"));
                };
                let mut spec = plan_level_from_expr(i, &first.value, env)?;
                apply_plan_args(i, &mut spec, &args[1..], env)?;
                return Ok(spec);
            }
            // The `backend(n)` / `backend(workers = n)` shorthand —
            // only when the callee *names* a backend. Any other call
            // is an ordinary expression evaluating to a backend name
            // or strategy value (e.g. `plan(paste0("multi", "core"))`).
            let head_name = match func.as_ref() {
                Expr::Sym(s) => Some(s.as_str().to_string()),
                Expr::Ns { pkg, name } => Some(format!("{pkg}::{name}")),
                _ => None,
            };
            if let Some(name) = head_name {
                if let Ok(mut spec) = PlanSpec::from_name(&name, None, vec![], None, None) {
                    apply_plan_args(i, &mut spec, args, env)?;
                    return Ok(spec);
                }
            }
            let v = i.eval(e, env)?;
            plan_level_from_value(&v)
        }
        other => {
            let v = i.eval(other, env)?;
            plan_level_from_value(&v)
        }
    }
}

/// Interpret an evaluated value as a plan level: a backend-name string
/// or a FutureStrategy list built by `tweak()`.
fn plan_level_from_value(v: &RVal) -> Result<PlanSpec, Signal> {
    match v {
        RVal::Chr(_) => {
            let name = v.as_str().map_err(Signal::error)?;
            PlanSpec::from_name(&name, None, vec![], None, None).map_err(Signal::error)
        }
        RVal::List(l) if l.class.as_deref() == Some("FutureStrategy") => {
            let name = l
                .get("backend")
                .and_then(|x| x.as_str().ok())
                .ok_or_else(|| Signal::error("plan: FutureStrategy is missing its backend"))?;
            let explicit = l
                .get("explicit_workers")
                .and_then(|x| x.as_bool().ok())
                .unwrap_or(false);
            let workers = if explicit {
                l.get("workers").and_then(|x| x.as_usize().ok())
            } else {
                None
            };
            let worker_names = l
                .get("worker_names")
                .and_then(|x| x.as_str_vec().ok())
                .unwrap_or_default();
            let latency_ms = l.get("latency_ms").and_then(|x| x.as_f64().ok());
            let poll_ms = l.get("poll_ms").and_then(|x| x.as_f64().ok());
            let mut spec = PlanSpec::from_name(&name, workers, worker_names, latency_ms, poll_ms)
                .map_err(Signal::error)?;
            if let Some(hb) = l.get("heartbeat_ms").and_then(|x| x.as_f64().ok()) {
                spec.heartbeat_ms = hb;
            }
            if let Some(spawn) = l.get("spawn").and_then(|x| x.as_str().ok()) {
                if !spawn.is_empty() {
                    spec.tcp_spawn = spawn;
                }
            }
            Ok(spec)
        }
        other => Err(Signal::error(format!(
            "plan: cannot interpret a {} as a backend",
            other.class()
        ))),
    }
}

/// Build a value-level plan strategy (`tweak()`'s return value): a
/// classed list `plan()` accepts anywhere a backend name is accepted,
/// including as a `plan(list(...))` stack level.
fn strategy_value(spec: &PlanSpec) -> RVal {
    let mut l = RList::named(
        vec![
            RVal::scalar_str(spec.display.clone()),
            RVal::scalar_int(spec.workers as i64),
            RVal::scalar_bool(spec.explicit_workers),
            RVal::scalar_dbl(spec.latency_ms),
            RVal::scalar_dbl(spec.poll_ms),
            RVal::scalar_dbl(spec.heartbeat_ms),
            RVal::scalar_str(spec.tcp_spawn.clone()),
            RVal::chr(spec.worker_names.clone()),
        ],
        vec![
            "backend".into(),
            "workers".into(),
            "explicit_workers".into(),
            "latency_ms".into(),
            "poll_ms".into(),
            "heartbeat_ms".into(),
            "spawn".into(),
            "worker_names".into(),
        ],
    );
    l.class = Some("FutureStrategy".into());
    RVal::List(l)
}

/// `tweak(backend, workers = n, ...)` — a special form returning a
/// FutureStrategy value: the backend with option overrides applied,
/// usable as `plan(s)` or inside a `plan(list(...))` stack.
fn tweak_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let Some(first) = args.first() else {
        return Err(Signal::error("tweak: missing backend argument"));
    };
    let mut spec = plan_level_from_expr(i, &first.value, env)?;
    apply_plan_args(i, &mut spec, &args[1..], env)?;
    Ok(strategy_value(&spec))
}

/// `plan(backend, workers = n)` or `plan(list(level1, level2, ...))` — a
/// special form. The single-level form takes a backend symbol,
/// namespaced symbol, or string; the list form installs a *plan stack*
/// (paper/future's nested topologies): level 1 runs this session's map
/// calls, level 2 is inherited by its workers for nested futurized
/// calls, and so on. Levels may be tweaked in place:
/// `plan(list(multisession(2), multicore(2)))`.
fn plan_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let Some(first) = args.first() else {
        // plan() with no args: report the current stack.
        return Ok(RVal::scalar_str(describe_stack(i.session.plan_stack())));
    };
    if let Expr::Call { func, args: elems } = &first.value {
        if matches!(func.as_ref(), Expr::Sym(s) if s.as_str() == "list") {
            let mut stack = Vec::with_capacity(elems.len());
            for el in elems {
                stack.push(plan_level_from_expr(i, &el.value, env)?);
            }
            if stack.is_empty() {
                return Err(Signal::error("plan(list()): a plan stack needs at least one level"));
            }
            i.session.set_plan_stack(stack);
            return Ok(RVal::Null);
        }
    }
    let mut spec = plan_level_from_expr(i, &first.value, env)?;
    apply_plan_args(i, &mut spec, &args[1..], env)?;
    i.session.set_plan(spec);
    Ok(RVal::Null)
}

fn nbr_of_workers_fn(i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    Ok(RVal::scalar_int(i.session.workers() as i64))
}

fn available_cores_fn(_i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    Ok(RVal::scalar_int(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as i64,
    ))
}

/// `future(expr)` — the low-level API: launch one future on the current
/// backend, return a handle.
fn future_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let expr =
        args.first().ok_or_else(|| Signal::error("future: missing expression"))?;
    let id = submit_expr(i, &expr.value, env)?;
    let mut l = RList::named(vec![RVal::scalar_int(id as i64)], vec!["id".into()]);
    l.class = Some("Future".into());
    Ok(RVal::List(l))
}

/// `x %<-% expr` — future assignment sugar: evaluates eagerly-as-future
/// and binds the *value* (rlite has no promises, so this resolves on
/// first use, i.e. immediately at bind time).
fn future_assign_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let target = match &args[0].value {
        Expr::Sym(s) => *s,
        other => {
            return Err(Signal::error(format!(
                "invalid %<-% target: {}",
                crate::rlite::deparse::deparse(other)
            )))
        }
    };
    let id = submit_expr(i, &args[1].value, env)?;
    let v = wait_for(i, id, env)?;
    crate::rlite::env::define_sym(env, target, v.clone());
    Ok(v)
}

/// Submit one expression as a future; returns the task id.
fn submit_expr(i: &mut Interp, expr: &Expr, env: &EnvRef) -> Result<u64, Signal> {
    let export = crate::globals::identify_globals(expr, env).map_err(Signal::error)?;
    let mut globals = Vec::new();
    for (name, v) in export.values {
        globals.push((name, crate::rlite::serialize::to_wire(&v).map_err(Signal::error)?));
    }
    let id = i.session.fresh_task_id();
    let nesting = i.session.nesting_for_context();
    let payload = TaskPayload {
        id,
        kind: TaskKind::Expr { expr: expr.clone(), globals, nesting },
        time_scale: i.config.time_scale,
        capture_stdout: true,
    };
    i.session.backend().map_err(Signal::error)?.submit(payload).map_err(Signal::error)?;
    i.session.pending.expect(id);
    Ok(id)
}

fn future_id(v: &RVal) -> Result<u64, Signal> {
    match v {
        RVal::List(l) if l.class.as_deref() == Some("Future") => {
            Ok(l.get("id").and_then(|x| x.as_i64().ok()).unwrap_or(0) as u64)
        }
        other => Err(Signal::error(format!("not a Future: {}", other.class()))),
    }
}

/// Block until task `id` resolves; relay its output; return its value.
/// A worker that dies while running `id` surfaces as a `FutureError`
/// condition (R future's semantics for an unreliable worker) — the wait
/// never hangs on a `Done` that can no longer arrive.
fn wait_for(i: &mut Interp, id: u64, env: &EnvRef) -> EvalResult {
    // This wait is an event loop like a map call's drive loop: register
    // it with the ledger so stray outcomes it parks are depth-tracked.
    i.session.pending.enter();
    let r = wait_for_inner(i, id, env);
    i.session.pending.exit();
    r
}

fn wait_for_inner(i: &mut Interp, id: u64, env: &EnvRef) -> EvalResult {
    loop {
        if let Some(outcome) = i.session.pending.take_ready(id) {
            return finish_outcome(i, outcome, env);
        }
        if let Some(worker) = i.session.lost_tasks.remove(&id) {
            i.session.pending.discard(id);
            let backend = i.session.backend().map(|b| b.name()).unwrap_or("future");
            return Err(Signal::Error(worker_lost_condition(backend, worker, id, None)));
        }
        let ev = i
            .session
            .backend()
            .map_err(Signal::error)?
            .next_event()
            .map_err(Signal::error)?;
        match ev {
            BackendEvent::Progress { cond, .. } => {
                i.signal_condition(cond)?;
            }
            BackendEvent::Done(outcome) => {
                if outcome.id == id {
                    i.session.pending.discard(id);
                    return finish_outcome(i, outcome, env);
                }
                i.session.pending.stash(outcome);
            }
            BackendEvent::WorkerLost { worker, task } => {
                // Record the loss (ours included — picked up at the top
                // of the next iteration); the backend has already healed
                // its pool.
                if let Some(tid) = task {
                    i.session.lost_tasks.insert(tid, worker);
                }
            }
        }
    }
}

fn finish_outcome(i: &mut Interp, outcome: TaskOutcome, _env: &EnvRef) -> EvalResult {
    i.relay(&outcome.log)?;
    match outcome.values {
        Ok(vals) => {
            let genv = i.global.clone();
            let mut out: Vec<RVal> = vals
                .into_iter()
                .map(|w| crate::rlite::serialize::from_wire_owned(w, &genv))
                .collect();
            Ok(out.pop().unwrap_or(RVal::Null))
        }
        Err(cond) => Err(Signal::Error(cond)),
    }
}

fn value_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let f = args.bind(&["future"]).req(0, "future")?;
    let id = future_id(&f)?;
    wait_for(i, id, env)
}

fn resolved_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let f = args.bind(&["future"]).req(0, "future")?;
    let id = future_id(&f)?;
    // Drain any ready events without blocking on this id.
    while let Ok(Some(ev)) = i.session.backend().map_err(Signal::error)?.try_next_event() {
        match ev {
            BackendEvent::Progress { cond, .. } => {
                i.signal_condition(cond)?;
            }
            BackendEvent::Done(outcome) => {
                i.session.pending.stash(outcome);
            }
            BackendEvent::WorkerLost { worker, task } => {
                if let Some(tid) = task {
                    i.session.lost_tasks.insert(tid, worker);
                }
            }
        }
    }
    // A lost future is resolved in R's sense: its (error) result is
    // ready to collect — `value()` raises the FutureError.
    Ok(RVal::scalar_bool(
        i.session.pending.is_ready(id) || i.session.lost_tasks.contains_key(&id),
    ))
}

/// `futureSeed(seed)` — set the root seed used to derive per-element
/// L'Ecuyer streams when `seed = TRUE`.
fn future_seed_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let v = i.eval(&args[0].value, env)?;
    i.session.rng_root_seed = v.as_i64().map_err(Signal::error)? as u64;
    Ok(RVal::Null)
}

/// Map a backend kind to a human-readable name (used in traces/benches).
pub fn backend_kind_name(kind: &BackendKind) -> &'static str {
    match kind {
        BackendKind::Sequential => "sequential",
        BackendKind::Multicore => "multicore",
        BackendKind::Multisession => "multisession",
        BackendKind::ClusterSim => "cluster",
        BackendKind::BatchtoolsSim => "batchtools",
    }
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn plan_default_is_sequential() {
        assert_eq!(run("plan()"), RVal::scalar_str("sequential"));
    }

    #[test]
    fn plan_switches_backend() {
        let v = run("plan(multicore, workers = 2)\nnbrOfWorkers()");
        assert_eq!(v, RVal::scalar_int(2));
    }

    #[test]
    fn plan_accepts_namespaced_backends() {
        // future.mirai::mirai_multisession maps onto the process backend.
        let v = run("plan(future.mirai::mirai_multisession, workers = 2)\nplan()");
        assert!(v.as_str().unwrap().contains("multisession"), "{v}");
    }

    #[test]
    fn low_level_future_value_roundtrip() {
        let v = run("plan(sequential)\nf <- future(21 * 2)\nvalue(f)");
        assert_eq!(v, RVal::scalar_dbl(42.0));
    }

    #[test]
    fn future_exports_globals() {
        let v = run("plan(multicore, workers = 2)\na <- 5\nf <- future(a + 1)\nvalue(f)");
        assert_eq!(v, RVal::scalar_dbl(6.0));
    }

    #[test]
    fn future_error_propagates() {
        let mut i = Interp::new();
        let r = i.eval_program("plan(sequential)\nf <- future(stop(\"worker boom\"))\nvalue(f)");
        match r {
            Err(crate::rlite::eval::Signal::Error(c)) => assert_eq!(c.message, "worker boom"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn resolved_eventually_true() {
        let v = run(
            "plan(multicore, workers = 1)\nf <- future(1 + 1)\nv <- value(f)\nv",
        );
        assert_eq!(v, RVal::scalar_dbl(2.0));
    }

    #[test]
    fn low_level_future_inherits_the_plan_stack() {
        // future() consumes one plan level exactly like a map call: its
        // body session sees level 2, not the implicit sequential.
        let v = run("plan(list(sequential, multicore(2)))\nf <- future(nbrOfWorkers())\nvalue(f)");
        assert_eq!(v, RVal::scalar_int(2));
        let v = run("plan(sequential)\nf <- future(nbrOfWorkers())\nvalue(f)");
        assert_eq!(v, RVal::scalar_int(1));
    }

    #[test]
    fn plan_accepts_evaluated_backend_expressions() {
        // A call that is not a backend(n) shorthand evaluates normally.
        let mut i = Interp::new();
        i.eval_program("plan(paste0(\"multi\", \"core\"))").unwrap();
        assert_eq!(i.session.plan().kind, crate::backend::BackendKind::Multicore);
        // A variable bound to a backend-name string works too.
        let mut i = Interp::new();
        i.eval_program("p <- \"multisession\"\nplan(p)").unwrap();
        assert_eq!(i.session.plan().kind, crate::backend::BackendKind::Multisession);
    }

    #[test]
    fn plan_list_installs_a_stack() {
        use crate::backend::BackendKind;
        let mut i = Interp::new();
        i.eval_program("plan(list(multisession(2), multicore(2)))").unwrap();
        let stack = i.session.plan_stack().to_vec();
        assert_eq!(stack.len(), 2);
        assert_eq!(stack[0].kind, BackendKind::Multisession);
        assert_eq!(stack[0].workers, 2);
        assert!(stack[0].explicit_workers);
        assert_eq!(stack[1].kind, BackendKind::Multicore);
        assert_eq!(stack[1].workers, 2);
        let desc = i.eval_program("plan()").unwrap();
        let desc = desc.as_str().unwrap();
        assert!(desc.contains("multisession") && desc.contains("->"), "{desc}");
    }

    #[test]
    fn tweak_builds_strategy_values_plan_accepts() {
        let mut i = Interp::new();
        i.eval_program("s <- tweak(multicore, workers = 3)\nplan(s)").unwrap();
        assert_eq!(i.session.plan().workers, 3);
        assert!(i.session.plan().explicit_workers);
        // tweak() inline in a stack, mixed with a bare symbol level.
        let mut i = Interp::new();
        i.eval_program("plan(list(tweak(multisession, workers = 2), sequential))").unwrap();
        assert_eq!(i.session.plan_stack().len(), 2);
        assert_eq!(i.session.plan_stack()[0].workers, 2);
    }

    #[test]
    fn nesting_info_consumes_one_level_per_session() {
        use super::SessionState;
        let mut i = Interp::new();
        i.eval_program("plan(list(multicore(2), multicore(3)))").unwrap();
        let n = i.session.nesting_for_context();
        assert_eq!(n.stack.len(), 1);
        assert_eq!(n.stack[0].workers, 3);
        assert_eq!(n.outer_workers, 2);
        assert_eq!(n.depth, 1);
        // A (simulated) worker session adopting the inherited stack.
        let mut w = SessionState::default();
        w.adopt_nesting(&n);
        assert_eq!(w.plan().workers, 3);
        assert_eq!(w.outer_workers, 2);
        assert_eq!(w.nest_depth, 1);
        // Its own contexts inherit the rest: the implicit sequential level.
        let n2 = w.nesting_for_context();
        assert!(n2.stack.is_empty());
        assert_eq!(n2.outer_workers, 6);
        assert_eq!(n2.depth, 2);
        let mut w2 = SessionState::default();
        w2.adopt_nesting(&n2);
        assert_eq!(w2.plan().kind, crate::backend::BackendKind::Sequential);
    }

    #[test]
    fn pending_ledger_prunes_strays_but_keeps_futures() {
        use super::{PendingLedger, TaskOutcome};
        let outcome = |id: u64| TaskOutcome {
            id,
            values: Ok(vec![]),
            log: Default::default(),
            worker: 0,
            started_unix: 0.0,
            finished_unix: 0.0,
            nested_workers: 0,
            partial: None,
        };
        let mut l = PendingLedger::default();
        l.expect(1); // a future() placeholder
        l.enter(); // outer drive loop
        l.enter(); // nested drive loop
        l.stash(outcome(1)); // the future resolves via a foreign loop
        l.stash(outcome(2)); // a stray owned by the (aborting) outer loop
        l.exit();
        assert!(l.is_ready(2), "strays survive while any loop is active");
        l.exit();
        assert!(l.is_ready(1), "owned future outcomes survive depth 0");
        assert!(!l.is_ready(2), "unclaimed strays are pruned at depth 0");
        assert_eq!(l.take_ready(1).unwrap().id, 1);
        assert!(l.is_empty());
    }
}
