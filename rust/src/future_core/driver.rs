//! The chunked map driver — the engine every `future_*` function and
//! every futurized domain function delegates to.
//!
//! Pipeline: identify + export globals → derive per-element RNG streams
//! (`seed = TRUE`) → chunk per the scheduling policy → submit chunks to
//! the plan's backend → stream progress conditions near-live → collect
//! outcomes → relay captured stdout/conditions *in input order* → reduce
//! back to per-element values.

use super::{TaskKind, TaskOutcome, TaskPayload, TraceEvent};
use crate::rlite::ast::Expr;
use crate::rlite::conditions::RCondition;
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{Interp, Signal};
use crate::rlite::serialize::{from_wire, to_wire, WireVal};
use crate::rlite::value::RVal;
use crate::rng::{make_streams, RngState};
use crate::scheduling::ChunkPolicy;

/// Execution options distilled from `futurize()`'s unified surface.
#[derive(Clone, Debug)]
pub struct MapOptions {
    pub seed: SeedOption,
    pub policy: ChunkPolicy,
    /// Relay stdout from workers (future's `stdout = TRUE`).
    pub stdout: bool,
    /// Relay conditions from workers (future's `conditions` option).
    pub conditions: bool,
}

impl Default for MapOptions {
    fn default() -> Self {
        MapOptions {
            seed: SeedOption::False,
            policy: ChunkPolicy::default(),
            stdout: true,
            conditions: true,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SeedOption {
    /// No RNG management; warn if the task draws random numbers.
    False,
    /// Derive one L'Ecuyer stream per element from the session root seed.
    True,
    /// As `True` but from an explicit seed.
    Seed(u64),
}

/// Apply `f(item, extra...)` to every element, concurrently per the
/// current plan. Returns per-element results in input order.
pub fn map_elements(
    i: &mut Interp,
    env: &EnvRef,
    items: Vec<RVal>,
    f: &RVal,
    extra: Vec<(Option<String>, RVal)>,
    opts: &MapOptions,
) -> Result<Vec<RVal>, Signal> {
    let n = items.len();
    if n == 0 {
        return Ok(vec![]);
    }
    let f_wire = to_wire(f).map_err(Signal::error)?;
    let items_wire: Vec<WireVal> =
        items.iter().map(to_wire).collect::<Result<_, _>>().map_err(Signal::error)?;
    let mut extra_wire = Vec::with_capacity(extra.len());
    for (name, v) in &extra {
        extra_wire.push((name.clone(), to_wire(v).map_err(Signal::error)?));
    }
    let seeds = element_seeds(i, opts, n);
    let workers = i.session.workers();
    let chunks = crate::scheduling::make_chunks(n, workers, &opts.policy);

    let mut payloads = Vec::with_capacity(chunks.len());
    for &(start, end) in &chunks {
        let id = i.session.fresh_task_id();
        payloads.push((
            id,
            start,
            TaskPayload {
                id,
                kind: TaskKind::MapChunk {
                    f: f_wire.clone(),
                    items: items_wire[start..end].to_vec(),
                    extra: extra_wire.clone(),
                    seeds: seeds.as_ref().map(|s| s[start..end].to_vec()),
                    globals: vec![],
                },
                time_scale: i.config.time_scale,
                capture_stdout: opts.stdout,
            },
        ));
    }
    run_chunks(i, env, payloads, opts, n)
}

/// Foreach-style execution: per element, bind iteration variables then
/// evaluate `body`. `globals` are the free variables of `body` minus the
/// binding names, resolved in `env`.
pub fn foreach_elements(
    i: &mut Interp,
    env: &EnvRef,
    bindings: Vec<Vec<(String, RVal)>>,
    body: &Expr,
    opts: &MapOptions,
) -> Result<Vec<RVal>, Signal> {
    let n = bindings.len();
    if n == 0 {
        return Ok(vec![]);
    }
    // Globals: free vars of body minus per-iteration bindings.
    let bound: Vec<&str> = bindings[0].iter().map(|(k, _)| k.as_str()).collect();
    let mut globals = Vec::new();
    for name in crate::globals::free_variables(body) {
        if bound.contains(&name.as_str()) {
            continue;
        }
        if let Some(v) = crate::rlite::env::lookup(env, &name) {
            if matches!(v, RVal::Builtin(_)) {
                continue;
            }
            globals.push((name.clone(), to_wire(&v).map_err(Signal::error)?));
        } else if crate::rlite::builtins::lookup_builtin(&name).is_none() {
            return Err(Signal::error(format!(
                "Failed to identify a global variable: '{name}' is not defined"
            )));
        }
    }
    let mut bindings_wire: Vec<Vec<(String, WireVal)>> = Vec::with_capacity(n);
    for bs in &bindings {
        let mut row = Vec::with_capacity(bs.len());
        for (k, v) in bs {
            row.push((k.clone(), to_wire(v).map_err(Signal::error)?));
        }
        bindings_wire.push(row);
    }
    let seeds = element_seeds(i, opts, n);
    let workers = i.session.workers();
    let chunks = crate::scheduling::make_chunks(n, workers, &opts.policy);
    let mut payloads = Vec::with_capacity(chunks.len());
    for &(start, end) in &chunks {
        let id = i.session.fresh_task_id();
        payloads.push((
            id,
            start,
            TaskPayload {
                id,
                kind: TaskKind::ForeachChunk {
                    bindings: bindings_wire[start..end].to_vec(),
                    body: body.clone(),
                    seeds: seeds.as_ref().map(|s| s[start..end].to_vec()),
                    globals: globals.clone(),
                },
                time_scale: i.config.time_scale,
                capture_stdout: opts.stdout,
            },
        ));
    }
    run_chunks(i, env, payloads, opts, n)
}

fn element_seeds(i: &Interp, opts: &MapOptions, n: usize) -> Option<Vec<RngState>> {
    match opts.seed {
        SeedOption::False => None,
        SeedOption::True => Some(make_streams(i.session.rng_root_seed, n)),
        SeedOption::Seed(s) => Some(make_streams(s, n)),
    }
}

/// Submit all payloads, stream progress, collect outcomes, relay logs in
/// chunk order, reassemble per-element values in input order.
fn run_chunks(
    i: &mut Interp,
    _env: &EnvRef,
    payloads: Vec<(u64, usize, TaskPayload)>,
    opts: &MapOptions,
    n: usize,
) -> Result<Vec<RVal>, Signal> {
    use std::collections::HashMap;

    let order: Vec<(u64, usize)> = payloads.iter().map(|(id, start, _)| (*id, *start)).collect();
    let expected: usize = payloads.len();
    {
        let backend = i.session.backend().map_err(Signal::error)?;
        for (_, _, p) in payloads {
            backend.submit(p).map_err(Signal::error)?;
        }
    }
    let mut outcomes: HashMap<u64, TaskOutcome> = HashMap::with_capacity(expected);
    let t0 = now_unix();
    while outcomes.len() < expected {
        let ev = {
            let backend = i.session.backend().map_err(Signal::error)?;
            backend.next_event().map_err(Signal::error)?
        };
        match ev {
            super::BackendEvent::Progress { cond, .. } => {
                // Near-live relay (paper §4.10): progress conditions pass
                // through the parent handler stack immediately.
                i.signal_condition(cond)?;
            }
            super::BackendEvent::Done(outcome) => {
                outcomes.insert(outcome.id, outcome);
            }
        }
    }
    // Trace for Figure 1.
    i.session.last_trace = outcomes
        .values()
        .map(|o| TraceEvent {
            task_id: o.id,
            worker: o.worker,
            start: o.started_unix - t0,
            end: o.finished_unix - t0,
        })
        .collect();
    i.session.last_trace.sort_by(|a, b| a.task_id.cmp(&b.task_id));

    // Relay + reassemble in input (chunk) order.
    let genv = i.global.clone();
    let mut out: Vec<Option<RVal>> = (0..n).map(|_| None).collect();
    let mut first_error: Option<RCondition> = None;
    for (id, start) in &order {
        let outcome = outcomes.remove(id).expect("outcome present");
        if opts.stdout || opts.conditions {
            let mut log = outcome.log.clone();
            if !opts.stdout {
                log.stdout.clear();
            }
            if !opts.conditions {
                log.conditions.clear();
            }
            i.relay(&log)?;
        }
        // RNG misuse detection (paper §5.2 recommendation 3).
        if outcome.log.rng_used && matches!(opts.seed, SeedOption::False) {
            i.signal_condition(RCondition::warning_cond(
                "UNRELIABLE VALUE: one of the futures unexpectedly generated random numbers \
                 without declaring so. Use 'seed = TRUE' to resolve this."
                    .to_string(),
            ))?;
        }
        match outcome.values {
            Ok(vals) => {
                for (k, w) in vals.iter().enumerate() {
                    out[start + k] = Some(from_wire(w, &genv));
                }
            }
            Err(cond) => {
                if first_error.is_none() {
                    first_error = Some(cond);
                }
            }
        }
    }
    if let Some(cond) = first_error {
        return Err(Signal::Error(cond));
    }
    Ok(out.into_iter().map(|v| v.expect("all elements resolved")).collect())
}

pub fn now_unix() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default()
        .as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::env::define;
    use crate::rlite::eval::Interp;

    fn make_closure(i: &mut Interp, src: &str) -> RVal {
        i.eval_program(&format!("__f <- {src}")).unwrap();
        crate::rlite::env::lookup(&i.global, "__f").unwrap()
    }

    #[test]
    fn map_elements_sequential_squares() {
        let mut i = Interp::new();
        let f = make_closure(&mut i, "function(x) x^2");
        let items: Vec<RVal> = (1..=5).map(|k| RVal::scalar_dbl(k as f64)).collect();
        let genv = i.global.clone();
        let out = map_elements(&mut i, &genv, items, &f, vec![], &MapOptions::default()).unwrap();
        let got: Vec<f64> = out.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, vec![1.0, 4.0, 9.0, 16.0, 25.0]);
    }

    #[test]
    fn map_elements_multicore_preserves_order() {
        let mut i = Interp::new();
        i.eval_program("plan(multicore, workers = 3)").unwrap();
        let f = make_closure(&mut i, "function(x) x * 10");
        let items: Vec<RVal> = (1..=20).map(|k| RVal::scalar_dbl(k as f64)).collect();
        let genv = i.global.clone();
        let out = map_elements(&mut i, &genv, items, &f, vec![], &MapOptions::default()).unwrap();
        let got: Vec<f64> = out.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, (1..=20).map(|k| (k * 10) as f64).collect::<Vec<_>>());
    }

    #[test]
    fn seed_true_is_chunking_invariant() {
        // Same per-element streams regardless of worker count/chunking —
        // the property behind the paper's litmus test.
        let draw = |workers: usize, chunk_size: Option<usize>| -> Vec<f64> {
            let mut i = Interp::new();
            i.eval_program(&format!("plan(multicore, workers = {workers})")).unwrap();
            let f = make_closure(&mut i, "function(x) rnorm(1)");
            let items: Vec<RVal> = (1..=8).map(|k| RVal::scalar_dbl(k as f64)).collect();
            let genv = i.global.clone();
            let opts = MapOptions {
                seed: SeedOption::Seed(123),
                policy: ChunkPolicy { chunk_size, scheduling: 1.0 },
                ..Default::default()
            };
            map_elements(&mut i, &genv, items, &f, vec![], &opts)
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect()
        };
        let a = draw(1, None);
        let b = draw(4, None);
        let c = draw(2, Some(1));
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn rng_without_seed_warns() {
        let mut i = Interp::new();
        let f = make_closure(&mut i, "function(x) rnorm(1)");
        let items = vec![RVal::scalar_dbl(1.0)];
        let genv = i.global.clone();
        let (r, captured) = i.capture_stdout(|i| {
            let genv2 = genv.clone();
            map_elements(i, &genv2, items, &f, vec![], &MapOptions::default())
        });
        r.unwrap();
        assert!(captured.contains("UNRELIABLE VALUE"), "{captured}");
    }

    #[test]
    fn worker_error_propagates_with_original_message() {
        let mut i = Interp::new();
        i.eval_program("plan(multicore, workers = 2)").unwrap();
        let f = make_closure(&mut i, "function(x) if (x == 3) stop(\"bad x\") else x");
        let items: Vec<RVal> = (1..=5).map(|k| RVal::scalar_dbl(k as f64)).collect();
        let genv = i.global.clone();
        let err =
            map_elements(&mut i, &genv, items, &f, vec![], &MapOptions::default()).unwrap_err();
        match err {
            Signal::Error(c) => assert_eq!(c.message, "bad x"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn extra_args_forwarded() {
        let mut i = Interp::new();
        let f = make_closure(&mut i, "function(x, n) x + n");
        let items = vec![RVal::scalar_dbl(1.0), RVal::scalar_dbl(2.0)];
        let genv = i.global.clone();
        let out = map_elements(
            &mut i,
            &genv,
            items,
            &f,
            vec![(Some("n".into()), RVal::scalar_dbl(10.0))],
            &MapOptions::default(),
        )
        .unwrap();
        assert_eq!(out[1].as_f64().unwrap(), 12.0);
    }

    #[test]
    fn foreach_elements_binds_variables() {
        let mut i = Interp::new();
        let genv = i.global.clone();
        define(&genv, "offset", RVal::scalar_dbl(100.0));
        let body = crate::rlite::parse_expr("x * 2 + offset").unwrap();
        let bindings: Vec<Vec<(String, RVal)>> =
            (1..=3).map(|k| vec![("x".to_string(), RVal::scalar_dbl(k as f64))]).collect();
        let out =
            foreach_elements(&mut i, &genv, bindings, &body, &MapOptions::default()).unwrap();
        let got: Vec<f64> = out.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, vec![102.0, 104.0, 106.0]);
    }
}
