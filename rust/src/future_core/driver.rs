//! The map driver — the engine every `future_*` function and every
//! futurized domain function delegates to.
//!
//! Pipeline: identify + export globals → derive per-element RNG streams
//! (`seed = TRUE`) → build one shared [`TaskContext`](super::TaskContext)
//! holding the function/extras/globals → hand the element stream to the
//! [`dispatch`](super::dispatch) core, which registers the context with
//! the plan's backend (shipped once per worker, not once per chunk),
//! feeds chunk slices incrementally under backpressure, streams progress
//! conditions near-live, folds outcomes into the result vector as they
//! arrive, and relays captured stdout/conditions *in input order*.
//!
//! Error handling: by default every chunk runs and the earliest error in
//! input order is reported (the batch driver's semantics). With
//! [`MapOptions::stop_on_error`], the first worker error triggers
//! `Backend::cancel_queued()` so remaining queued chunks never execute,
//! in-flight chunks drain, and the error surfaces immediately.

use super::dispatch;
use crate::rlite::ast::Expr;
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{Interp, Signal};
use crate::rlite::serialize::{to_wire, WireVal};
use crate::rlite::value::RVal;
use crate::rng::{make_streams, RngState};
use crate::scheduling::ChunkPolicy;
use crate::transpile::reduce::ReduceSpec;

/// Execution options distilled from `futurize()`'s unified surface.
#[derive(Clone, Debug)]
pub struct MapOptions {
    pub seed: SeedOption,
    pub policy: ChunkPolicy,
    /// Relay stdout from workers (future's `stdout = TRUE`).
    pub stdout: bool,
    /// Relay conditions from workers (future's `conditions` option).
    pub conditions: bool,
    /// Fail fast: cancel queued chunks and surface the first worker
    /// error immediately instead of running the whole input.
    pub stop_on_error: bool,
    /// How many times a chunk whose worker *died* (crash/OOM/exit — not
    /// an ordinary R error) may be resubmitted before the map call
    /// raises a `FutureError`-style condition. 0 (the default) fails
    /// fast, matching R future's unreliable-worker behaviour; rush-style
    /// bounded retry is opt-in via `futurize(retries = N)`.
    pub retries: u32,
    /// Fused-reduction request: the map's results feed a recognized
    /// reduction, so workers should fold slices locally and the
    /// dispatch core should merge the partials ([`MapRun::Reduced`]).
    pub reduce: Option<ReduceSpec>,
    /// Parallel-safety analyzer configuration: lint mode plus the
    /// distilled reduction facts the freeze-time detectors need
    /// (`transpile::analysis`).
    pub lint: crate::rlite::diag::LintSettings,
    /// Data-plane cache (`futurize(cache = "auto"|"off")`): oversized
    /// exports and the frozen element vector ship as content-addressed
    /// blobs once per worker and are referenced by digest thereafter.
    /// On by default; `FUTURIZE_NO_CACHE=1` overrides per process.
    pub cache: bool,
}

impl Default for MapOptions {
    fn default() -> Self {
        MapOptions {
            seed: SeedOption::False,
            policy: ChunkPolicy::default(),
            stdout: true,
            conditions: true,
            stop_on_error: false,
            retries: 0,
            reduce: None,
            lint: Default::default(),
            cache: true,
        }
    }
}

/// The outcome of one map run: per-element values in input order, or —
/// when a reduction plan rode the context — the merged reduced value.
#[derive(Debug)]
pub enum MapRun {
    Values(Vec<RVal>),
    Reduced(RVal),
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SeedOption {
    /// No RNG management; warn if the task draws random numbers.
    False,
    /// Derive one L'Ecuyer stream per element from the session root seed.
    True,
    /// As `True` but from an explicit seed.
    Seed(u64),
}

/// Apply `f(item, extra...)` to every element, concurrently per the
/// current plan. Returns per-element results in input order; any
/// reduction request in `opts` is ignored.
pub fn map_elements(
    i: &mut Interp,
    env: &EnvRef,
    items: Vec<RVal>,
    f: &RVal,
    extra: Vec<(Option<String>, RVal)>,
    opts: &MapOptions,
) -> Result<Vec<RVal>, Signal> {
    let opts = MapOptions { reduce: None, ..opts.clone() };
    match map_elements_run(i, env, items, f, extra, &opts)? {
        MapRun::Values(v) => Ok(v),
        MapRun::Reduced(_) => unreachable!("no reduction was requested"),
    }
}

/// As [`map_elements`], but honouring [`MapOptions::reduce`]: with a
/// reduction plan attached the run yields [`MapRun::Reduced`].
pub fn map_elements_run(
    i: &mut Interp,
    _env: &EnvRef,
    items: Vec<RVal>,
    f: &RVal,
    extra: Vec<(Option<String>, RVal)>,
    opts: &MapOptions,
) -> Result<MapRun, Signal> {
    let n = items.len();
    if n == 0 {
        i.session.last_trace.clear();
        return Ok(MapRun::Values(vec![]));
    }
    let f_wire = to_wire(f).map_err(Signal::error)?;
    // Consuming conversion: per-element scalars are uniquely owned, so
    // their COW buffers move into the wire payload instead of copying.
    let items_wire: Vec<WireVal> = items
        .into_iter()
        .map(crate::rlite::serialize::to_wire_owned)
        .collect::<Result<_, _>>()
        .map_err(Signal::error)?;
    let mut extra_wire = Vec::with_capacity(extra.len());
    for (name, v) in &extra {
        extra_wire.push((name.clone(), to_wire(v).map_err(Signal::error)?));
    }
    let seeds = element_seeds(i, opts, n);
    dispatch::run_map(i, f_wire, items_wire, extra_wire, vec![], seeds, opts)
}

/// Foreach-style execution: per element, bind iteration variables then
/// evaluate `body`. Returns per-element results in input order; any
/// reduction request in `opts` is ignored.
pub fn foreach_elements(
    i: &mut Interp,
    env: &EnvRef,
    bindings: Vec<Vec<(String, RVal)>>,
    body: &Expr,
    opts: &MapOptions,
) -> Result<Vec<RVal>, Signal> {
    let opts = MapOptions { reduce: None, ..opts.clone() };
    match foreach_elements_run(i, env, bindings, body, &opts)? {
        MapRun::Values(v) => Ok(v),
        MapRun::Reduced(_) => unreachable!("no reduction was requested"),
    }
}

/// As [`foreach_elements`], but honouring [`MapOptions::reduce`]:
/// `globals` are the free variables of `body` minus the binding names,
/// resolved in `env` and shipped once in the shared context.
pub fn foreach_elements_run(
    i: &mut Interp,
    env: &EnvRef,
    bindings: Vec<Vec<(String, RVal)>>,
    body: &Expr,
    opts: &MapOptions,
) -> Result<MapRun, Signal> {
    let n = bindings.len();
    if n == 0 {
        i.session.last_trace.clear();
        return Ok(MapRun::Values(vec![]));
    }
    // Globals: free vars of body minus per-iteration bindings.
    let bound: Vec<&str> = bindings[0].iter().map(|(k, _)| k.as_str()).collect();
    let mut globals = Vec::new();
    for sym in crate::globals::free_variables(body) {
        if bound.contains(&sym.as_str()) {
            continue;
        }
        if let Some(v) = crate::rlite::env::lookup_sym(env, sym) {
            if matches!(v, RVal::Builtin(_)) {
                continue;
            }
            globals.push((sym.to_string(), to_wire(&v).map_err(Signal::error)?));
        } else if sym.builtin_id().is_none() {
            return Err(Signal::error(format!(
                "Failed to identify a global variable: '{sym}' is not defined"
            )));
        }
    }
    let mut bindings_wire: Vec<Vec<(String, WireVal)>> = Vec::with_capacity(n);
    for bs in &bindings {
        let mut row = Vec::with_capacity(bs.len());
        for (k, v) in bs {
            row.push((k.clone(), to_wire(v).map_err(Signal::error)?));
        }
        bindings_wire.push(row);
    }
    let seeds = element_seeds(i, opts, n);
    dispatch::run_foreach(i, body.clone(), bindings_wire, globals, seeds, opts)
}

fn element_seeds(i: &mut Interp, opts: &MapOptions, n: usize) -> Option<Vec<RngState>> {
    match opts.seed {
        SeedOption::False => None,
        SeedOption::True => {
            // Consume root-seed state: a second seed = TRUE map in the
            // same session (incl. sibling *nested* maps inside one
            // element) derives a fresh, independent stream family —
            // deterministically, so topology invariance is untouched.
            let root = i.session.rng_root_seed;
            i.session.rng_root_seed = crate::rng::advance_root_seed(root);
            Some(make_streams(root, n))
        }
        // An explicit seed is self-contained and repeatable: it does
        // not consume session state.
        SeedOption::Seed(s) => Some(make_streams(s, n)),
    }
}

pub fn now_unix() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default()
        .as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::env::define;
    use crate::rlite::eval::Interp;

    fn make_closure(i: &mut Interp, src: &str) -> RVal {
        i.eval_program(&format!("__f <- {src}")).unwrap();
        crate::rlite::env::lookup(&i.global, "__f").unwrap()
    }

    #[test]
    fn map_elements_sequential_squares() {
        let mut i = Interp::new();
        let f = make_closure(&mut i, "function(x) x^2");
        let items: Vec<RVal> = (1..=5).map(|k| RVal::scalar_dbl(k as f64)).collect();
        let genv = i.global.clone();
        let out = map_elements(&mut i, &genv, items, &f, vec![], &MapOptions::default()).unwrap();
        let got: Vec<f64> = out.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, vec![1.0, 4.0, 9.0, 16.0, 25.0]);
    }

    #[test]
    fn map_elements_multicore_preserves_order() {
        let mut i = Interp::new();
        i.eval_program("plan(multicore, workers = 3)").unwrap();
        let f = make_closure(&mut i, "function(x) x * 10");
        let items: Vec<RVal> = (1..=20).map(|k| RVal::scalar_dbl(k as f64)).collect();
        let genv = i.global.clone();
        let out = map_elements(&mut i, &genv, items, &f, vec![], &MapOptions::default()).unwrap();
        let got: Vec<f64> = out.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, (1..=20).map(|k| (k * 10) as f64).collect::<Vec<_>>());
    }

    #[test]
    fn seed_true_is_chunking_invariant() {
        // Same per-element streams regardless of worker count/chunking —
        // the property behind the paper's litmus test.
        let draw = |workers: usize, policy: ChunkPolicy| -> Vec<f64> {
            let mut i = Interp::new();
            i.eval_program(&format!("plan(multicore, workers = {workers})")).unwrap();
            let f = make_closure(&mut i, "function(x) rnorm(1)");
            let items: Vec<RVal> = (1..=8).map(|k| RVal::scalar_dbl(k as f64)).collect();
            let genv = i.global.clone();
            let opts = MapOptions { seed: SeedOption::Seed(123), policy, ..Default::default() };
            map_elements(&mut i, &genv, items, &f, vec![], &opts)
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect()
        };
        let a = draw(1, ChunkPolicy::default());
        let b = draw(4, ChunkPolicy::default());
        let c = draw(2, ChunkPolicy::Static { chunk_size: Some(1), scheduling: 1.0 });
        let d = draw(3, ChunkPolicy::adaptive());
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert_eq!(a, d);
    }

    #[test]
    fn rng_without_seed_warns() {
        let mut i = Interp::new();
        let f = make_closure(&mut i, "function(x) rnorm(1)");
        let items = vec![RVal::scalar_dbl(1.0)];
        let genv = i.global.clone();
        let (r, captured) = i.capture_stdout(|i| {
            let genv2 = genv.clone();
            map_elements(i, &genv2, items, &f, vec![], &MapOptions::default())
        });
        r.unwrap();
        assert!(captured.contains("UNRELIABLE VALUE"), "{captured}");
    }

    #[test]
    fn worker_error_propagates_with_original_message() {
        let mut i = Interp::new();
        i.eval_program("plan(multicore, workers = 2)").unwrap();
        let f = make_closure(&mut i, "function(x) if (x == 3) stop(\"bad x\") else x");
        let items: Vec<RVal> = (1..=5).map(|k| RVal::scalar_dbl(k as f64)).collect();
        let genv = i.global.clone();
        let err =
            map_elements(&mut i, &genv, items, &f, vec![], &MapOptions::default()).unwrap_err();
        match err {
            Signal::Error(c) => assert_eq!(c.message, "bad x"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn earliest_error_wins_regardless_of_completion_order() {
        // Two failing elements; the one earlier in input order must be
        // reported even if the later one finishes first.
        let mut i = Interp::new();
        i.eval_program("plan(multicore, workers = 2)").unwrap();
        let f = make_closure(
            &mut i,
            "function(x) if (x == 2) { Sys.sleep(0.05)\nstop(\"early\") } else if (x == 7) stop(\"late\") else x",
        );
        let items: Vec<RVal> = (1..=8).map(|k| RVal::scalar_dbl(k as f64)).collect();
        let genv = i.global.clone();
        let opts = MapOptions {
            policy: ChunkPolicy::Static { chunk_size: None, scheduling: f64::INFINITY },
            ..Default::default()
        };
        let err = map_elements(&mut i, &genv, items, &f, vec![], &opts).unwrap_err();
        match err {
            Signal::Error(c) => assert_eq!(c.message, "early"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stop_on_error_surfaces_error() {
        let mut i = Interp::new();
        i.eval_program("plan(multicore, workers = 2)").unwrap();
        let f = make_closure(&mut i, "function(x) if (x == 1) stop(\"fail fast\") else x");
        let items: Vec<RVal> = (1..=12).map(|k| RVal::scalar_dbl(k as f64)).collect();
        let genv = i.global.clone();
        let opts = MapOptions {
            stop_on_error: true,
            policy: ChunkPolicy::Static { chunk_size: None, scheduling: f64::INFINITY },
            ..Default::default()
        };
        let err = map_elements(&mut i, &genv, items, &f, vec![], &opts).unwrap_err();
        match err {
            Signal::Error(c) => assert_eq!(c.message, "fail fast"),
            other => panic!("{other:?}"),
        }
        // The backend must be clean for the next call.
        let g = make_closure(&mut i, "function(x) x + 1");
        let items: Vec<RVal> = (1..=4).map(|k| RVal::scalar_dbl(k as f64)).collect();
        let out =
            map_elements(&mut i, &genv, items, &g, vec![], &MapOptions::default()).unwrap();
        let got: Vec<f64> = out.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn extra_args_forwarded() {
        let mut i = Interp::new();
        let f = make_closure(&mut i, "function(x, n) x + n");
        let items = vec![RVal::scalar_dbl(1.0), RVal::scalar_dbl(2.0)];
        let genv = i.global.clone();
        let out = map_elements(
            &mut i,
            &genv,
            items,
            &f,
            vec![(Some("n".into()), RVal::scalar_dbl(10.0))],
            &MapOptions::default(),
        )
        .unwrap();
        assert_eq!(out[1].as_f64().unwrap(), 12.0);
    }

    #[test]
    fn foreach_elements_binds_variables() {
        let mut i = Interp::new();
        let genv = i.global.clone();
        define(&genv, "offset", RVal::scalar_dbl(100.0));
        let body = crate::rlite::parse_expr("x * 2 + offset").unwrap();
        let bindings: Vec<Vec<(String, RVal)>> =
            (1..=3).map(|k| vec![("x".to_string(), RVal::scalar_dbl(k as f64))]).collect();
        let out =
            foreach_elements(&mut i, &genv, bindings, &body, &MapOptions::default()).unwrap();
        let got: Vec<f64> = out.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, vec![102.0, 104.0, 106.0]);
    }

    #[test]
    fn adaptive_policy_end_to_end() {
        let mut i = Interp::new();
        i.eval_program("plan(multicore, workers = 4)").unwrap();
        let f = make_closure(&mut i, "function(x) x * 2");
        let items: Vec<RVal> = (1..=33).map(|k| RVal::scalar_dbl(k as f64)).collect();
        let genv = i.global.clone();
        let opts = MapOptions { policy: ChunkPolicy::adaptive(), ..Default::default() };
        let out = map_elements(&mut i, &genv, items, &f, vec![], &opts).unwrap();
        let got: Vec<f64> = out.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, (1..=33).map(|k| (k * 2) as f64).collect::<Vec<_>>());
    }
}
