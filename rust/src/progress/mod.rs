//! progressr analog (paper §4.10): near-live progress reporting from
//! parallel workers.
//!
//! `p <- progressor(along = xs)` creates a closure that signals a
//! `progression` condition each time it is called. On a worker, the
//! task runner streams progression conditions to the parent immediately
//! (see [`crate::backend::task_runner::LIVE_CLASSES`]); in the parent,
//! `handlers(global = TRUE)` installs a display hook that renders a
//! progress line to stderr as updates arrive.

use std::cell::RefCell;
use std::rc::Rc;

use crate::rlite::builtins::{Args, Reg};
use crate::rlite::conditions::RCondition;
use crate::rlite::env::{Env, EnvRef};
use crate::rlite::eval::{EvalResult, HandlerFrame, Interp, Signal};
use crate::rlite::value::RVal;

pub fn register_builtins(r: &mut Reg) {
    r.normal("progressr", "progressor", progressor_fn);
    r.normal("progressr", "handlers", handlers_fn);
    r.normal("progressr", ".progress_step", progress_step_fn);
    r.special("progressr", "with_progress", with_progress_fn);
}

/// `progressor(along = xs)` / `progressor(steps = n)`: returns a closure
/// `p(msg = "")` that signals one progression step. The closure body
/// calls the internal `.progress_step(total, msg)` builtin, so it
/// serializes cleanly to workers.
fn progressor_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let total = if let Some(along) = args.named("along") {
        along.len()
    } else if let Some(steps) = args.named("steps") {
        steps.as_usize().map_err(Signal::error)?
    } else if let Some((_, v)) = args.items.first() {
        v.len()
    } else {
        0
    };
    let src = format!("function(msg = \"\") .progress_step({total}, msg)");
    let expr = crate::rlite::parse_expr(&src).map_err(Signal::error)?;
    i.eval(&expr, &Env::child_of(env))
}

/// Internal: signal one progression condition.
fn progress_step_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["total", "msg"]);
    let total = b.opt(0).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(0);
    let msg =
        b.opt(1).map(|v| v.as_str()).transpose().map_err(Signal::error)?.unwrap_or_default();
    let cond = RCondition::custom(
        "progression",
        msg,
        Some(crate::wire::JsonValue::obj(vec![
            ("amount", crate::wire::JsonValue::num(1.0)),
            ("total", crate::wire::JsonValue::num(total as f64)),
        ])),
    );
    i.signal_condition(cond)?;
    Ok(RVal::Null)
}

/// `handlers(global = TRUE)`: install the parent-side display hook that
/// renders progression conditions to stderr as they are relayed.
fn handlers_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let enable = args
        .named("global")
        .map(|v| v.as_bool())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or(true);
    if enable {
        install_display(i);
    }
    Ok(RVal::scalar_bool(enable))
}

/// `with_progress({ ... })`: scoped variant — display hook active only
/// for the wrapped expression.
fn with_progress_fn(
    i: &mut Interp,
    args: &[crate::rlite::ast::Arg],
    env: &EnvRef,
) -> EvalResult {
    let expr = args.first().ok_or_else(|| Signal::error("with_progress: missing expr"))?;
    install_display(i);
    let r = i.eval(&expr.value, env);
    i.handlers.pop();
    r
}

/// The display hook: tracks completed steps and writes a single-line
/// progress bar to the error stream.
fn install_display(i: &mut Interp) {
    let count = Rc::new(RefCell::new(0usize));
    let line = Rc::new(RefCell::new(String::new()));
    i.handlers.push(HandlerFrame::Native {
        class: "progression".into(),
        hook: Rc::new(RefCell::new(move |c: &RCondition| {
            let mut n = count.borrow_mut();
            *n += 1;
            let total = c
                .data
                .as_ref()
                .and_then(|d| d.get("total"))
                .and_then(|t| t.as_u64())
                .unwrap_or(0);
            let rendered = if total > 0 {
                let pct = (*n as f64 / total as f64 * 100.0).min(100.0);
                format!("[{:>3.0}%] {}/{} {}", pct, n, total, c.message)
            } else {
                format!("[step {}] {}", n, c.message)
            };
            *line.borrow_mut() = rendered;
            // Rendering goes to the process stderr; tests observe the
            // relayed conditions themselves instead of scraping output.
            eprint!("\r{}", line.borrow());
            if total > 0 && *n >= total as usize {
                eprintln!();
            }
        })),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::eval::Interp;

    #[test]
    fn progressor_signals_progression_conditions() {
        let mut i = Interp::new();
        // Capture conditions at the interpreter boundary.
        let exprs = crate::rlite::parse_program(
            "p <- progressor(steps = 3)\nfor (k in 1:3) p()\n\"done\"",
        )
        .unwrap();
        let genv = i.global.clone();
        let mut all = crate::rlite::conditions::CaptureLog::default();
        let mut last = RVal::Null;
        for e in &exprs {
            let (r, log) = i.eval_captured(e, &genv);
            last = r.unwrap();
            all.merge(log);
        }
        assert_eq!(last, RVal::scalar_str("done"));
        let progressions: Vec<_> =
            all.conditions.iter().filter(|c| c.inherits("progression")).collect();
        assert_eq!(progressions.len(), 3);
    }

    #[test]
    fn progress_relays_from_parallel_workers() {
        // The §4.10 pattern: progressor inside local(), futurized lapply.
        let mut i = Interp::new();
        let src = "plan(multicore, workers = 2)\n\
                   xs <- 1:6\n\
                   ys <- local({\n  p <- progressor(along = xs)\n  lapply(xs, function(x) { p()\n x^2 })\n}) |> futurize()\n\
                   unlist(ys)";
        let exprs = crate::rlite::parse_program(src).unwrap();
        let genv = i.global.clone();
        let mut all = crate::rlite::conditions::CaptureLog::default();
        let mut last = RVal::Null;
        for e in &exprs {
            let (r, log) = i.eval_captured(e, &genv);
            last = r.unwrap_or_else(|e| panic!("{e:?}"));
            all.merge(log);
        }
        assert_eq!(
            last.as_dbl_vec().unwrap(),
            vec![1.0, 4.0, 9.0, 16.0, 25.0, 36.0]
        );
        let progressions =
            all.conditions.iter().filter(|c| c.inherits("progression")).count();
        assert_eq!(progressions, 6, "one progression per element");
    }
}
