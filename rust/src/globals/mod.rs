//! Static identification of global variables — the `globals` package
//! analog (paper §2.4: "globals are automatically identified through
//! static-code analysis").
//!
//! Given an expression that will run on a worker, we walk the AST
//! tracking locally-bound names (function parameters, loop variables,
//! assignment targets) and collect every free symbol. Free symbols that
//! resolve in the calling environment are exported to the worker; free
//! symbols that resolve to builtins need no export (every "package"
//! ships inside the worker binary — the `packages` option becomes a
//! load-check rather than a code shipment).
//!
//! This module also hosts the *frame escape analysis* used by the
//! per-element map loop: a closure body through which no reference to
//! the call frame can leak may have its frame reused across elements
//! ([`env_may_escape`]).

use std::collections::HashSet;

use crate::rlite::ast::{Arg, Expr};
use crate::rlite::builtins;
use crate::rlite::env::{self, EnvRef};
use crate::rlite::intern::Symbol;
use crate::rlite::value::RVal;

/// Free variables of `expr`, in first-use order.
pub fn free_variables(expr: &Expr) -> Vec<Symbol> {
    let mut bound: HashSet<Symbol> = HashSet::new();
    let mut free: Vec<Symbol> = Vec::new();
    let mut seen: HashSet<Symbol> = HashSet::new();
    walk(expr, &mut bound, &mut free, &mut seen);
    free
}

fn note(sym: Symbol, bound: &HashSet<Symbol>, free: &mut Vec<Symbol>, seen: &mut HashSet<Symbol>) {
    if !bound.contains(&sym) && seen.insert(sym) {
        free.push(sym);
    }
}

fn walk(e: &Expr, bound: &mut HashSet<Symbol>, free: &mut Vec<Symbol>, seen: &mut HashSet<Symbol>) {
    match e {
        Expr::Sym(name) => note(*name, bound, free, seen),
        Expr::Call { func, args } => {
            walk(func, bound, free, seen);
            walk_args(args, bound, free, seen);
        }
        Expr::Function { params, body } => {
            // Parameters bind inside the function body only.
            let mut inner = bound.clone();
            for p in params {
                inner.insert(p.name);
            }
            for p in params {
                if let Some(d) = &p.default {
                    walk(d, &mut inner, free, seen);
                }
            }
            walk(body, &mut inner, free, seen);
        }
        Expr::Block(stmts) => {
            for s in stmts {
                walk(s, bound, free, seen);
            }
        }
        Expr::If { cond, then, els } => {
            walk(cond, bound, free, seen);
            walk(then, bound, free, seen);
            if let Some(e) = els {
                walk(e, bound, free, seen);
            }
        }
        Expr::For { var, seq, body } => {
            walk(seq, bound, free, seen);
            bound.insert(*var);
            walk(body, bound, free, seen);
        }
        Expr::While { cond, body } => {
            walk(cond, bound, free, seen);
            walk(body, bound, free, seen);
        }
        Expr::Assign { target, value } => {
            // RHS first: `x <- x + 1` with global x reads the global.
            walk(value, bound, free, seen);
            match target.as_ref() {
                Expr::Sym(name) => {
                    bound.insert(*name);
                }
                other => walk(other, bound, free, seen),
            }
        }
        Expr::SuperAssign { target, value } => {
            // `x <<- v` *reads* an enclosing binding: x stays free.
            walk(value, bound, free, seen);
            if let Expr::Sym(name) = target.as_ref() {
                note(*name, bound, free, seen);
            }
        }
        Expr::Index { obj, args, .. } => {
            walk(obj, bound, free, seen);
            walk_args(args, bound, free, seen);
        }
        Expr::Dollar { obj, .. } => walk(obj, bound, free, seen),
        _ => {}
    }
}

fn walk_args(
    args: &[Arg],
    bound: &mut HashSet<Symbol>,
    free: &mut Vec<Symbol>,
    seen: &mut HashSet<Symbol>,
) {
    for a in args {
        walk(&a.value, bound, free, seen);
    }
}

/// Function names whose *call* can hand out a reference to the current
/// evaluation frame (directly or via a child environment). A body
/// containing any of these — or any nested `function`/`\(x)` definition,
/// which closes over the frame — disqualifies frame reuse.
const ENV_ESCAPE_FNS: &[&str] = &[
    "environment",
    "new.env",
    "local",
    "eval",
    "evalq",
    "sys.call",
    "sys.function",
    "parent.frame",
    "delayedAssign",
    "attach",
];

/// Conservative escape analysis for the per-element frame-reuse
/// optimization: can evaluating `e` as a closure body leak a reference
/// to the evaluation frame? True for nested function definitions (they
/// capture the frame as their enclosing environment) and for calls to
/// environment-reifying builtins. The map loop additionally guards with
/// a runtime `Rc::strong_count` check, so this analysis only needs to be
/// *usually* right to be profitable — but it must never be wrong in the
/// "no escape" direction together with that guard.
pub fn env_may_escape(e: &Expr) -> bool {
    match e {
        Expr::Function { .. } => true,
        Expr::Call { func, args } => {
            let head_escapes = match func.as_ref() {
                Expr::Sym(s) => ENV_ESCAPE_FNS.contains(&s.as_str()),
                Expr::Ns { name, .. } => ENV_ESCAPE_FNS.contains(&name.as_str()),
                other => env_may_escape(other),
            };
            head_escapes || args.iter().any(|a| env_may_escape(&a.value))
        }
        Expr::Block(stmts) => stmts.iter().any(env_may_escape),
        Expr::If { cond, then, els } => {
            env_may_escape(cond)
                || env_may_escape(then)
                || els.as_deref().is_some_and(env_may_escape)
        }
        Expr::For { seq, body, .. } => env_may_escape(seq) || env_may_escape(body),
        Expr::While { cond, body } => env_may_escape(cond) || env_may_escape(body),
        Expr::Assign { target, value } | Expr::SuperAssign { target, value } => {
            env_may_escape(target) || env_may_escape(value)
        }
        Expr::Index { obj, args, .. } => {
            env_may_escape(obj) || args.iter().any(|a| env_may_escape(&a.value))
        }
        Expr::Dollar { obj, .. } => env_may_escape(obj),
        _ => false,
    }
}

/// A resolved globals export: values to ship plus packages to check.
#[derive(Clone, Debug, Default)]
pub struct GlobalsExport {
    pub values: Vec<(String, RVal)>,
    pub packages: Vec<String>,
}

/// Resolve the free variables of `expr` against `env`, splitting them
/// into exportable values and builtin namespaces ("packages").
///
/// Unresolvable symbols are an error, mirroring the future package's
/// "Failed to identify a global variable" diagnostics.
pub fn identify_globals(expr: &Expr, env: &EnvRef) -> Result<GlobalsExport, String> {
    let mut out = GlobalsExport::default();
    let mut pkgs: HashSet<String> = HashSet::new();
    for sym in free_variables(expr) {
        if let Some(v) = env::lookup_sym(env, sym) {
            // Builtin references resolve implicitly on the worker.
            if let RVal::Builtin(_) = v {
                continue;
            }
            out.values.push((sym.to_string(), v));
        } else if let Some(def) = builtins::lookup_builtin(sym.as_str()) {
            pkgs.insert(def.pkg.to_string());
        } else {
            return Err(format!(
                "Failed to identify a global variable: '{sym}' is not defined"
            ));
        }
    }
    let mut pkgs: Vec<String> = pkgs.into_iter().collect();
    pkgs.sort();
    out.packages = pkgs;
    Ok(out)
}

/// Total serialized size of exported globals, for diagnostics and the
/// future ecosystem's export-size accounting.
pub fn export_size_bytes(export: &GlobalsExport) -> usize {
    export
        .values
        .iter()
        .map(|(n, v)| {
            n.len()
                + crate::rlite::serialize::to_wire(v).map(|w| w.approx_size()).unwrap_or(0)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::env::{define, Env};
    use crate::rlite::parse_expr;

    fn free_names(e: &Expr) -> Vec<String> {
        free_variables(e).into_iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn finds_free_variables() {
        let e = parse_expr("function(x) x + a + b").unwrap();
        assert_eq!(free_names(&e), vec!["+", "a", "b"]);
    }

    #[test]
    fn params_and_locals_are_bound() {
        let e = parse_expr("function(x) { y <- x * 2\ny + x }").unwrap();
        let frees = free_names(&e);
        assert!(!frees.contains(&"x".to_string()));
        assert!(!frees.contains(&"y".to_string()));
    }

    #[test]
    fn loop_variable_is_bound() {
        let e = parse_expr("for (i in 1:10) s <- s + i").unwrap();
        let frees = free_names(&e);
        assert!(!frees.contains(&"i".to_string()));
        assert!(frees.contains(&"s".to_string()));
    }

    #[test]
    fn rhs_before_binding() {
        // `x <- x + 1` reads a global x before rebinding.
        let e = parse_expr("x <- x + 1").unwrap();
        assert!(free_names(&e).contains(&"x".to_string()));
    }

    #[test]
    fn identify_splits_values_and_packages() {
        let env = Env::new_ref();
        define(&env, "a", crate::rlite::value::RVal::scalar_dbl(1.0));
        let e = parse_expr("lapply(xs, function(x) x + a)").unwrap();
        define(&env, "xs", crate::rlite::value::RVal::dbl(vec![1.0]));
        let g = identify_globals(&e, &env).unwrap();
        let names: Vec<&str> = g.values.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"a"));
        assert!(names.contains(&"xs"));
        assert!(g.packages.contains(&"base".to_string()));
    }

    #[test]
    fn missing_global_is_an_error() {
        let env = Env::new_ref();
        let e = parse_expr("f(undefined_thing)").unwrap();
        let err = identify_globals(&e, &env).unwrap_err();
        assert!(err.contains("Failed to identify a global variable"), "{err}");
    }

    #[test]
    fn escape_analysis_flags_env_reifiers() {
        for src in [
            "environment()",
            "local({ x + 1 })",
            "{ g <- function(y) y + x\ng(x) }",
            "\\(y) y",
            "eval(e)",
            "new.env()",
            "list(environment(), 1)",
        ] {
            let e = parse_expr(src).unwrap();
            assert!(env_may_escape(&e), "{src} must be flagged as escaping");
        }
    }

    #[test]
    fn escape_analysis_clears_plain_bodies() {
        for src in [
            "x * 2 + 1",
            "sum(x[1:10]) / 10",
            "{ s <- 0\nfor (i in 1:5) s <- s + i\ns }",
            "if (x > 0) sqrt(x) else -x",
            "counter <<- counter + 1",
            "get(\"x\")",
        ] {
            let e = parse_expr(src).unwrap();
            assert!(!env_may_escape(&e), "{src} must be reusable");
        }
    }
}
