//! PJRT runtime: load the AOT JAX/Pallas artifacts (`artifacts/*.hlo.txt`)
//! once, execute them from map-task bodies via the `hlo_*()` builtins.
//!
//! Python is build-time only (`make artifacts`); at run time the rust
//! binary is self-contained. Each artifact has a registered *native
//! fallback* implementing the same math in Rust, used when artifacts are
//! absent (hermetic tests) or the crate is built without the `pjrt`
//! feature; correctness tests assert PJRT and native agree
//! (`rust/tests/pjrt_artifacts.rs`).

use std::cell::RefCell;
use std::collections::HashMap;

use crate::rlite::builtins::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::RVal;

pub mod elementwise;
pub mod kernels;

/// Fixed shapes of the compiled artifacts (must match python/compile).
pub const CHUNK_N: usize = 128; // chunk_map: f32[128] -> f32[128]
pub const BOOT_N: usize = 64; //   boot_stat: f32[64], f32[64], f32[64] -> f32[2]
pub const GRAM_N: usize = 256; //  gram: f32[256,32], f32[256] -> (f32[32,32], f32[32])
pub const GRAM_P: usize = 32;

/// A loaded, compiled artifact.
enum Compiled {
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtLoadedExecutable),
    Missing,
}

struct Engine {
    client_ok: bool,
    artifacts: HashMap<String, Compiled>,
    dir: std::path::PathBuf,
    #[cfg(feature = "pjrt")]
    client: Option<xla::PjRtClient>,
}

// PJRT handles are not Send (Rc-based), so each thread owns its own
// client + compiled-executable cache. Compilation happens once per
// thread per artifact; worker pools are persistent, so this amortizes.
thread_local! {
    static ENGINE: RefCell<Engine> = RefCell::new(Engine {
        client_ok: false,
        artifacts: HashMap::new(),
        dir: std::env::var("FUTURIZE_ARTIFACTS")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|_| std::path::PathBuf::from("artifacts")),
        #[cfg(feature = "pjrt")]
        client: None,
    });
}

/// Execute artifact `name` with f32 input buffers. Outputs are returned
/// flattened in row-major order; `None` means the artifact or the PJRT
/// path is unavailable (callers fall back to the native kernels).
/// Engine preference: `FUTURIZE_ENGINE=pjrt` (default) executes the AOT
/// artifacts via PJRT; `native` short-circuits to the bit-checked Rust
/// kernels. Measured on this CPU testbed the interpret-mode Pallas
/// artifacts carry ~20ms/call of grid-interpretation overhead (they are
/// compile targets for TPU, not CPU hot paths) — see EXPERIMENTS.md
/// §Perf for the numbers and the TPU roofline estimate.
fn engine_pref() -> bool {
    static PREF: once_cell::sync::Lazy<bool> = once_cell::sync::Lazy::new(|| {
        std::env::var("FUTURIZE_ENGINE").map(|v| v != "native").unwrap_or(true)
    });
    *PREF
}

pub fn pjrt_execute(name: &str, inputs: &[(&[f32], &[usize])]) -> Option<Vec<f32>> {
    if !engine_pref() {
        return None;
    }
    #[cfg(feature = "pjrt")]
    {
        ENGINE.with(|cell| {
            let mut eng = cell.borrow_mut();
            if !eng.client_ok {
                eng.client = xla::PjRtClient::cpu().ok();
                eng.client_ok = true;
            }
            eng.client.as_ref()?;
            if !eng.artifacts.contains_key(name) {
                let path = eng.dir.join(format!("{name}.hlo.txt"));
                let compiled = if path.exists() {
                    match xla::HloModuleProto::from_text_file(path.to_str()?) {
                        Ok(proto) => {
                            let comp = xla::XlaComputation::from_proto(&proto);
                            match eng.client.as_ref().unwrap().compile(&comp) {
                                Ok(exe) => Compiled::Pjrt(exe),
                                Err(e) => {
                                    eprintln!("futurize: compile {name} failed: {e}");
                                    Compiled::Missing
                                }
                            }
                        }
                        Err(e) => {
                            eprintln!("futurize: load {name} failed: {e}");
                            Compiled::Missing
                        }
                    }
                } else {
                    Compiled::Missing
                };
                eng.artifacts.insert(name.to_string(), compiled);
            }
            match eng.artifacts.get(name) {
                Some(Compiled::Pjrt(exe)) => {
                    let mut literals = Vec::with_capacity(inputs.len());
                    for (data, shape) in inputs {
                        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                        let lit = xla::Literal::vec1(data).reshape(&dims).ok()?;
                        literals.push(lit);
                    }
                    let result = exe.execute::<xla::Literal>(&literals).ok()?;
                    let out = result[0][0].to_literal_sync().ok()?;
                    // Single-output artifacts have a plain root; multi-
                    // output ones a tuple root. Flatten either in order.
                    let is_tuple = matches!(out.shape(), Ok(xla::Shape::Tuple(_)));
                    if is_tuple {
                        let parts = out.to_tuple().ok()?;
                        let mut flat = Vec::new();
                        for p in parts {
                            flat.extend(p.to_vec::<f32>().ok()?);
                        }
                        Some(flat)
                    } else {
                        out.to_vec::<f32>().ok()
                    }
                }
                _ => None,
            }
        })
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = (name, inputs);
        None
    }
}

/// Whether PJRT artifacts are live (reported by examples/benches).
pub fn pjrt_available() -> bool {
    pjrt_execute("chunk_map", &[(&[0f32; CHUNK_N], &[CHUNK_N])]).is_some()
}

pub fn register_builtins(r: &mut Reg) {
    r.normal("futurize", "hlo_chunk_map", hlo_chunk_map_fn);
    r.normal("futurize", "hlo_boot_stat", hlo_boot_stat_fn);
    r.normal("futurize", "hlo_gram", hlo_gram_fn);
    r.normal("futurize", "hlo_ridge", hlo_ridge_fn);
    r.normal("futurize", "hlo_available", |_i, _a, _e| {
        Ok(RVal::scalar_bool(pjrt_available()))
    });
}

/// `hlo_chunk_map(x)`: the L1 Pallas "chunk map" kernel — elementwise
/// 3x^2 + 2x + 1 over a padded f32[128] block.
fn hlo_chunk_map_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    Ok(RVal::dbl(kernels::chunk_map(&x)))
}

/// `hlo_boot_stat(x, u, w)`: weighted ratio statistic sum(w*x)/sum(w*u)
/// — the boot/bigcity statistic, on the padded f32[64] block.
fn hlo_boot_stat_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "u", "w"]);
    let x = b.req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    let u = b.req(1, "u")?.as_dbl_vec().map_err(Signal::error)?;
    let w = b.req(2, "w")?.as_dbl_vec().map_err(Signal::error)?;
    Ok(RVal::scalar_dbl(kernels::boot_stat(&x, &u, &w).map_err(Signal::error)?))
}

/// `hlo_gram(x_cols, y)`: X^T X and X^T y for a design matrix given as a
/// list of column vectors — the ridge/GAM fold solver's heavy half.
/// Returns `list(row_1, ..., row_p, xty)`.
fn hlo_gram_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "y"]);
    let xv = b.req(0, "x")?;
    let cols: Vec<Vec<f64>> = match &xv {
        RVal::List(l) => l
            .vals
            .iter()
            .map(|c| c.as_dbl_vec())
            .collect::<Result<_, _>>()
            .map_err(Signal::error)?,
        other => vec![other.as_dbl_vec().map_err(Signal::error)?],
    };
    let y = b.req(1, "y")?.as_dbl_vec().map_err(Signal::error)?;
    let (gram, xty) = kernels::gram(&cols, &y).map_err(Signal::error)?;
    let p = cols.len();
    let mut out = Vec::with_capacity(p + 1);
    for row in gram.chunks(p) {
        out.push(RVal::dbl(row.to_vec()));
    }
    out.push(RVal::dbl(xty));
    Ok(RVal::list(out))
}

/// `hlo_ridge(x_cols, y, lam)`: the full ridge fold — the gram half
/// (XLA when bit-identical, native otherwise), then the native Cholesky
/// solve of `(G + λI) β = X^T y`. Returns the coefficient vector β.
fn hlo_ridge_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "y", "lam"]);
    let xv = b.req(0, "x")?;
    let cols: Vec<Vec<f64>> = match &xv {
        RVal::List(l) => l
            .vals
            .iter()
            .map(|c| c.as_dbl_vec())
            .collect::<Result<_, _>>()
            .map_err(Signal::error)?,
        other => vec![other.as_dbl_vec().map_err(Signal::error)?],
    };
    let y = b.req(1, "y")?.as_dbl_vec().map_err(Signal::error)?;
    let lam = b.req(2, "lam")?.as_f64().map_err(Signal::error)?;
    let (gram, xty) = kernels::gram(&cols, &y).map_err(Signal::error)?;
    let beta = kernels::ridge_solve(&gram, &xty, lam).map_err(Signal::error)?;
    Ok(RVal::dbl(beta))
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn chunk_map_polynomial() {
        let v = run("hlo_chunk_map(c(0, 1, 2))");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 6.0, 17.0]);
    }

    #[test]
    fn boot_stat_ratio() {
        let v = run("hlo_boot_stat(c(2, 4), c(1, 1), c(1, 1))");
        assert!((v.as_f64().unwrap() - 3.0).abs() < 1e-6);
    }

    #[test]
    fn gram_small() {
        let v = run("g <- hlo_gram(list(c(1, 0), c(0, 2)), c(3, 4))\ng[[3]]");
        let xty = v.as_dbl_vec().unwrap();
        assert!((xty[0] - 3.0).abs() < 1e-5);
        assert!((xty[1] - 8.0).abs() < 1e-5);
    }

    #[test]
    fn ridge_small() {
        // Identity design, λ = 1: (I + I) β = X^T y → β = y / 2.
        let v = run("hlo_ridge(list(c(1, 0), c(0, 1)), c(3, 4), 1)");
        let beta = v.as_dbl_vec().unwrap();
        assert!((beta[0] - 1.5).abs() < 1e-6);
        assert!((beta[1] - 2.0).abs() < 1e-6);
    }
}
