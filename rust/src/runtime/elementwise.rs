//! Generalized elementwise-expression kernel: a tiny stack VM over f64
//! scalars that executes recognized arithmetic map bodies (ISSUE 6
//! tentpole) without touching the interpreter.
//!
//! Unlike the fixed-shape PJRT artifacts (`chunk_map` is hard-wired to
//! 3x²+2x+1 over f32[128] blocks), an [`ElemOp`] program encodes an
//! *arbitrary* arithmetic expression tree over the map element and
//! captured scalars, compiled by `transpile::fusion` in postorder. Every
//! opcode mirrors the exact f64 operation rlite's scalar arithmetic
//! performs — [`ElemOp::Neg`] is `0.0 - v` (the interpreter's unary
//! minus, which differs from `-v` at `v = 0.0`), [`ElemOp::Mod`] is
//! `rem_euclid`, [`ElemOp::IntDiv`] is `(a / b).floor()` — so a fused
//! slice is bit-identical to the interpreted one, non-finite corners
//! included.

use serde_derive::{Deserialize, Serialize};

/// One opcode of a postorder stack program. Binary ops pop the right
/// operand first; the program always nets exactly one value.
#[derive(Clone, Copy, Debug, PartialEq, Serialize, Deserialize)]
pub enum ElemOp {
    /// Push the map element.
    Par,
    /// Push a literal or captured scalar resolved at recognition time.
    Const(f64),
    Add,
    Sub,
    Mul,
    Div,
    /// `^` — `f64::powf`, as rlite's `pow` builtin computes it.
    Pow,
    /// `%%` — `f64::rem_euclid`, as rlite's `%%` builtin computes it.
    Mod,
    /// `%/%` — `(a / b).floor()`, as rlite's `%/%` builtin computes it.
    IntDiv,
    /// Unary minus — `0.0 - v`, rlite's exact spelling (preserves the
    /// sign of zero differently than `-v`).
    Neg,
    Sqrt,
    Exp,
    /// Single-argument `log` (natural logarithm).
    Ln,
    Log2,
    Log10,
    Abs,
    Floor,
    /// `ceiling`.
    Ceil,
    Sin,
    Cos,
}

/// Peak operand-stack depth of a well-formed program — callers size the
/// reusable evaluation stack once per slice with this.
pub fn max_depth(prog: &[ElemOp]) -> usize {
    let (mut depth, mut peak) = (0usize, 0usize);
    for op in prog {
        match op {
            ElemOp::Par | ElemOp::Const(_) => {
                depth += 1;
                peak = peak.max(depth);
            }
            ElemOp::Add
            | ElemOp::Sub
            | ElemOp::Mul
            | ElemOp::Div
            | ElemOp::Pow
            | ElemOp::Mod
            | ElemOp::IntDiv => depth = depth.saturating_sub(1),
            _ => {}
        }
    }
    peak
}

/// Evaluate `prog` at element value `x`. `stack` is caller-provided
/// scratch (cleared here) so the per-element loop allocates nothing.
/// Programs come from the fusion compiler and are well-formed by
/// construction; a malformed one yields `NaN`, never a panic.
#[inline]
pub fn eval(prog: &[ElemOp], x: f64, stack: &mut Vec<f64>) -> f64 {
    stack.clear();
    macro_rules! bin {
        ($f:expr) => {{
            let b = stack.pop().unwrap_or(f64::NAN);
            let a = stack.pop().unwrap_or(f64::NAN);
            #[allow(clippy::redundant_closure_call)]
            stack.push($f(a, b));
        }};
    }
    macro_rules! un {
        ($f:expr) => {{
            let v = stack.pop().unwrap_or(f64::NAN);
            #[allow(clippy::redundant_closure_call)]
            stack.push($f(v));
        }};
    }
    for op in prog {
        match *op {
            ElemOp::Par => stack.push(x),
            ElemOp::Const(c) => stack.push(c),
            ElemOp::Add => bin!(|a: f64, b: f64| a + b),
            ElemOp::Sub => bin!(|a: f64, b: f64| a - b),
            ElemOp::Mul => bin!(|a: f64, b: f64| a * b),
            ElemOp::Div => bin!(|a: f64, b: f64| a / b),
            ElemOp::Pow => bin!(|a: f64, b: f64| a.powf(b)),
            ElemOp::Mod => bin!(|a: f64, b: f64| a.rem_euclid(b)),
            ElemOp::IntDiv => bin!(|a: f64, b: f64| (a / b).floor()),
            ElemOp::Neg => un!(|v: f64| 0.0 - v),
            ElemOp::Sqrt => un!(f64::sqrt),
            ElemOp::Exp => un!(f64::exp),
            ElemOp::Ln => un!(f64::ln),
            ElemOp::Log2 => un!(f64::log2),
            ElemOp::Log10 => un!(f64::log10),
            ElemOp::Abs => un!(f64::abs),
            ElemOp::Floor => un!(f64::floor),
            ElemOp::Ceil => un!(f64::ceil),
            ElemOp::Sin => un!(f64::sin),
            ElemOp::Cos => un!(f64::cos),
        }
    }
    stack.pop().unwrap_or(f64::NAN)
}

#[cfg(test)]
mod tests {
    use super::*;
    use ElemOp::*;

    fn run(prog: &[ElemOp], x: f64) -> f64 {
        eval(prog, x, &mut Vec::new())
    }

    #[test]
    fn polynomial_program() {
        // 3*x*x + 2*x + 1 in postorder.
        let prog = [Const(3.0), Par, Mul, Par, Mul, Const(2.0), Par, Mul, Add, Const(1.0), Add];
        assert_eq!(run(&prog, 0.0), 1.0);
        assert_eq!(run(&prog, 1.0), 6.0);
        assert_eq!(run(&prog, 2.0), 17.0);
        assert_eq!(max_depth(&prog), 3);
    }

    #[test]
    fn neg_matches_interpreter_zero_semantics() {
        // rlite's unary minus is 0.0 - v: -(0.0) stays +0.0.
        let prog = [Par, Neg];
        assert_eq!(run(&prog, 0.0).to_bits(), 0.0f64.to_bits());
        assert_eq!(run(&prog, 2.5), -2.5);
    }

    #[test]
    fn non_finite_corners_flow_through() {
        let prog = [Par, Const(0.0), Div];
        assert!(run(&prog, 1.0).is_infinite());
        assert!(run(&prog, 0.0).is_nan());
        let sq = [Par, Sqrt];
        assert!(run(&sq, -1.0).is_nan());
    }

    #[test]
    fn intdiv_and_mod_mirror_builtins() {
        let m = [Par, Const(3.0), Mod];
        assert_eq!(run(&m, -7.0), (-7.0f64).rem_euclid(3.0));
        let d = [Par, Const(3.0), IntDiv];
        assert_eq!(run(&d, -7.0), (-7.0f64 / 3.0).floor());
    }

    #[test]
    fn roundtrips_serde() {
        let prog = vec![Par, Const(2.0), Mul, Const(1.0), Add];
        let bytes = crate::wire::bin::to_bytes(&prog).unwrap();
        let back: Vec<ElemOp> = crate::wire::bin::from_bytes(&bytes).unwrap();
        assert_eq!(prog, back);
    }
}
