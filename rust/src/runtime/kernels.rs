//! Kernel entry points: native f64 reference first, PJRT artifact
//! adopted only when bit-identical.
//!
//! Shapes are fixed at AOT time (PJRT requires static shapes); inputs are
//! zero-padded to the block size and outputs truncated back. The Pallas
//! kernels use masking so padding never contaminates results.
//!
//! The artifacts compute in f32, so their round-tripped results can
//! diverge from the native f64 path in the low mantissa bits. Because
//! fusion's contract (and the futurize paper's) is that backend choice
//! never changes results, every entry point here computes the native f64
//! answer first and adopts the PJRT result only when it is *bitwise*
//! equal — the accelerator then serves as a checked fast path, never a
//! source of drift.

use super::{pjrt_execute, BOOT_N, CHUNK_N, GRAM_N, GRAM_P};

/// f32 results round-tripped to f64 are adopted only when every lane is
/// bitwise-equal to the native f64 reference.
fn bits_equal(pjrt: &[f32], native: &[f64]) -> bool {
    pjrt.len() >= native.len()
        && native.iter().zip(pjrt).all(|(&n, &p)| (p as f64).to_bits() == n.to_bits())
}

/// Elementwise 3x² + 2x + 1 (the "slow_fcn" compute payload).
pub fn chunk_map(x: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(x.len());
    for block in x.chunks(CHUNK_N) {
        let native: Vec<f64> = block.iter().map(|&v| 3.0 * v * v + 2.0 * v + 1.0).collect();
        let mut buf = [0f32; CHUNK_N];
        for (i, &v) in block.iter().enumerate() {
            buf[i] = v as f32;
        }
        match pjrt_execute("chunk_map", &[(&buf, &[CHUNK_N])]) {
            Some(res) if bits_equal(&res[..block.len()], &native) => {
                out.extend(res[..block.len()].iter().map(|&v| v as f64))
            }
            _ => out.extend(native),
        }
    }
    out
}

/// Interpreter-exact weighted ratio `sum(x·w) / sum(u·w)`: left-to-right
/// f64 folds from 0.0, division last, *no* zero-denominator guard — a
/// zero denominator yields `NaN`/`±Inf` exactly as rlite's `sum(...)/
/// sum(...)` does. This is the fused `boot_stat` entry point; the
/// guarded [`boot_stat`] below keeps its error contract for the
/// explicit `hlo_boot_stat()` builtin.
pub fn weighted_ratio(x: &[f64], u: &[f64], w: &[f64]) -> f64 {
    let mut num = 0.0f64;
    for (a, b) in x.iter().zip(w) {
        num += a * b;
    }
    let mut den = 0.0f64;
    for (a, b) in u.iter().zip(w) {
        den += a * b;
    }
    num / den
}

/// Weighted ratio statistic sum(w·x)/sum(w·u) — the `boot` bigcity
/// statistic (ratio of urban 1930 to 1920 populations under resampling
/// weights).
pub fn boot_stat(x: &[f64], u: &[f64], w: &[f64]) -> Result<f64, String> {
    if x.len() != u.len() || x.len() != w.len() {
        return Err("boot_stat: x, u, w must have equal length".into());
    }
    let num: f64 = x.iter().zip(w).map(|(a, b)| a * b).sum();
    let den: f64 = u.iter().zip(w).map(|(a, b)| a * b).sum();
    if den == 0.0 {
        return Err("boot_stat: zero denominator".into());
    }
    if x.len() <= BOOT_N {
        let mut bx = [0f32; BOOT_N];
        let mut bu = [0f32; BOOT_N];
        let mut bw = [0f32; BOOT_N];
        for i in 0..x.len() {
            bx[i] = x[i] as f32;
            bu[i] = u[i] as f32;
            bw[i] = w[i] as f32; // padding keeps w = 0 → no contribution
        }
        if let Some(res) =
            pjrt_execute("boot_stat", &[(&bx, &[BOOT_N]), (&bu, &[BOOT_N]), (&bw, &[BOOT_N])])
        {
            // Artifact returns (num, den) separately; adopt only when the
            // f32 sums round-trip to the exact f64 reference bits.
            if res.len() >= 2 && bits_equal(&res[..2], &[num, den]) {
                return Ok(res[0] as f64 / res[1] as f64);
            }
        }
    }
    Ok(num / den)
}

/// Gram matrix X^T X (p×p, row-major) and X^T y for a column-major design
/// matrix. The PJRT path requires n ≤ 256 and p ≤ 32 (the AOT block);
/// larger problems use the native path.
pub fn gram(cols: &[Vec<f64>], y: &[f64]) -> Result<(Vec<f64>, Vec<f64>), String> {
    let p = cols.len();
    if p == 0 {
        return Err("gram: empty design matrix".into());
    }
    let n = cols[0].len();
    if cols.iter().any(|c| c.len() != n) || y.len() != n {
        return Err("gram: ragged design matrix".into());
    }
    // Native f64 reference.
    let mut g = vec![0f64; p * p];
    for i in 0..p {
        for j in i..p {
            let s: f64 = cols[i].iter().zip(&cols[j]).map(|(a, b)| a * b).sum();
            g[i * p + j] = s;
            g[j * p + i] = s;
        }
    }
    let xty: Vec<f64> =
        cols.iter().map(|c| c.iter().zip(y).map(|(a, b)| a * b).sum()).collect();
    if n <= GRAM_N && p <= GRAM_P {
        // Pack row-major padded f32[GRAM_N, GRAM_P].
        let mut xbuf = vec![0f32; GRAM_N * GRAM_P];
        for (j, col) in cols.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                xbuf[i * GRAM_P + j] = v as f32;
            }
        }
        let mut ybuf = [0f32; GRAM_N];
        for (i, &v) in y.iter().enumerate() {
            ybuf[i] = v as f32;
        }
        if let Some(res) =
            pjrt_execute("gram", &[(&xbuf, &[GRAM_N, GRAM_P]), (&ybuf, &[GRAM_N])])
        {
            if res.len() >= GRAM_P * GRAM_P + GRAM_P {
                let gp: Vec<f32> = (0..p)
                    .flat_map(|i| res[i * GRAM_P..i * GRAM_P + p].iter().copied())
                    .collect();
                let xp: Vec<f32> =
                    res[GRAM_P * GRAM_P..GRAM_P * GRAM_P + p].to_vec();
                if bits_equal(&gp, &g) && bits_equal(&xp, &xty) {
                    let g64 = gp.iter().map(|&v| v as f64).collect();
                    let x64 = xp.iter().map(|&v| v as f64).collect();
                    return Ok((g64, x64));
                }
            }
        }
    }
    Ok((g, xty))
}

/// Solve the (small, symmetric positive-definite) system `(G + λI) β = b`
/// by Cholesky — the cheap O(p³) half kept native by design (the heavy
/// O(n·p²) gram runs on XLA).
pub fn ridge_solve(g: &[f64], b: &[f64], lambda: f64) -> Result<Vec<f64>, String> {
    let p = b.len();
    if g.len() != p * p {
        return Err("ridge_solve: dimension mismatch".into());
    }
    // A = G + λI
    let mut a = g.to_vec();
    for i in 0..p {
        a[i * p + i] += lambda;
    }
    // Cholesky: A = L L^T
    let mut l = vec![0f64; p * p];
    for i in 0..p {
        for j in 0..=i {
            let mut s = a[i * p + j];
            for k in 0..j {
                s -= l[i * p + k] * l[j * p + k];
            }
            if i == j {
                if s <= 0.0 {
                    return Err("ridge_solve: matrix not positive definite".into());
                }
                l[i * p + i] = s.sqrt();
            } else {
                l[i * p + j] = s / l[j * p + j];
            }
        }
    }
    // Forward/back substitution.
    let mut z = vec![0f64; p];
    for i in 0..p {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * p + k] * z[k];
        }
        z[i] = s / l[i * p + i];
    }
    let mut beta = vec![0f64; p];
    for i in (0..p).rev() {
        let mut s = z[i];
        for k in (i + 1)..p {
            s -= l[k * p + i] * beta[k];
        }
        beta[i] = s / l[i * p + i];
    }
    Ok(beta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_map_handles_multi_block() {
        let x: Vec<f64> = (0..300).map(|i| i as f64 / 10.0).collect();
        let y = chunk_map(&x);
        assert_eq!(y.len(), 300);
        for (xi, yi) in x.iter().zip(&y) {
            assert!((yi - (3.0 * xi * xi + 2.0 * xi + 1.0)).abs() < 1e-3);
        }
    }

    #[test]
    fn gram_matches_naive() {
        let cols = vec![vec![1.0, 2.0, 3.0], vec![0.5, -1.0, 2.0]];
        let y = vec![1.0, 0.0, 1.0];
        let (g, xty) = gram(&cols, &y).unwrap();
        assert!((g[0] - 14.0).abs() < 1e-4); // 1+4+9
        assert!((g[1] - 4.5).abs() < 1e-4); // 0.5-2+6
        assert!((g[3] - 5.25).abs() < 1e-4); // 0.25+1+4
        assert!((xty[0] - 4.0).abs() < 1e-4);
        assert!((xty[1] - 2.5).abs() < 1e-4);
    }

    #[test]
    fn ridge_solve_recovers_identity() {
        // G = I, b = [1, 2], λ = 0 → β = b.
        let beta = ridge_solve(&[1.0, 0.0, 0.0, 1.0], &[1.0, 2.0], 0.0).unwrap();
        assert!((beta[0] - 1.0).abs() < 1e-12);
        assert!((beta[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ridge_regularization_shrinks() {
        let cols = vec![vec![1.0, 2.0, 3.0, 4.0]];
        let y = vec![2.0, 4.0, 6.0, 8.0];
        let (g, xty) = gram(&cols, &y).unwrap();
        let b0 = ridge_solve(&g, &xty, 0.0).unwrap()[0];
        let b1 = ridge_solve(&g, &xty, 10.0).unwrap()[0];
        assert!((b0 - 2.0).abs() < 1e-4);
        assert!(b1 < b0);
    }
}
