//! rlite — the mini-R language substrate.
//!
//! The futurize paper's mechanism is *expression* manipulation: capture an
//! unevaluated call, identify its head function and namespace, rewrite it,
//! evaluate the rewritten form in the caller's environment. Reproducing
//! that faithfully requires a language whose programs are data. rlite is
//! that substrate: a small, eagerly-evaluated R dialect with
//!
//! - vectors (logical/integer/double/character) with names,
//! - lists, closures, `NULL`,
//! - `<-`/`=` assignment, `if`/`for`/`while`, `function(x, y = 1)` and
//!   `\(x)` lambdas, `{ }` blocks,
//! - the native pipe `|>` (desugared at parse time, exactly as in R 4.1),
//! - user infix operators `%op%` (notably `%do%` / `%dofuture%`),
//! - `pkg::name` namespace access,
//! - a condition system (`message`, `warning`, `stop`, custom condition
//!   classes, `suppressMessages`/`suppressWarnings`, `tryCatch`,
//!   `withCallingHandlers`) and capturable stdout,
//! - a builtin library large enough to express every example in the
//!   paper (Sections 4.1-4.10).

pub mod ast;
pub mod builtins;
pub mod conditions;
pub mod deparse;
pub mod diag;
pub mod env;
pub mod eval;
pub mod intern;
pub mod lexer;
pub mod parser;
pub mod serialize;
pub mod shape;
pub mod value;

pub use ast::{Arg, Expr, Param};
pub use env::{Env, EnvRef};
pub use eval::{EvalResult, Interp, Signal};
pub use intern::Symbol;
pub use value::RVal;

/// Parse a complete program (sequence of expressions).
pub fn parse_program(src: &str) -> Result<Vec<Expr>, String> {
    let toks = lexer::lex(src)?;
    parser::Parser::new(toks).parse_program()
}

/// Parse a single expression.
pub fn parse_expr(src: &str) -> Result<Expr, String> {
    let exprs = parse_program(src)?;
    match exprs.len() {
        1 => Ok(exprs.into_iter().next().unwrap()),
        0 => Err("empty input".into()),
        n => Err(format!("expected a single expression, got {n}")),
    }
}
